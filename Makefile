# LIFT reproduction — common entry points.
#
# `artifacts` needs a python with jax installed; it lowers every L1/L2
# graph to HLO text under artifacts/ (see python/compile/aot.py). The
# rust side runs without artifacts for everything that goes through the
# XlaBuilder toolkit (mask engine, property tests, quickstart selftest);
# artifact-dependent integration tests skip themselves when absent.

.PHONY: artifacts artifacts-e2e test test-nosimd test-qscan bench bench-check clippy matrix-smoke matrix-race serve-smoke torture-smoke

artifacts:
	cd python && python -m compile.aot --outdir ../artifacts

artifacts-e2e:
	cd python && python -m compile.aot --outdir ../artifacts --presets e2e

test:
	cargo build --release && cargo test -q

# the same suite with the AVX2 GEMM microkernels pinned off — proves the
# portable scalar path stands on its own (CI runs this too)
test-nosimd:
	LIFT_NO_SIMD=1 cargo test -q

# the same suite with every rank-reduce scan forced through the int8
# blockwise quantized tier (ISSUE 10) — selection must stay within the
# LIFT_QSCAN_TOL mask-overlap contract while all training math stays
# f64 (CI runs this too)
test-qscan:
	LIFT_QSCAN=1 cargo test -q

bench:
	cargo bench

# appends to BENCH_trajectory.json, then fails if any speedup row
# (warm refresh, arenas, async ckpt, worker-pool fan-outs) regressed
# beyond $$BENCH_CHECK_TOL (default 0.4) vs the previous same-mode run.
# Worker count for all pool measurements comes from LIFT_WORKERS.
bench-check:
	cargo bench -- --fast --check

clippy:
	cargo clippy --all-targets

# the ISSUE-5 acceptance flow, locally: run an artifact-free grid over
# the preset + interval + seed axes, kill it mid-campaign, resume it,
# and leave both ledgers under /tmp for inspection (CI diffs them).
matrix-smoke:
	cargo build --release
	target/release/lift matrix --toy --methods lift,full \
	  --axis "interval=2,4;seed=1,2" --steps 8 --ckpt-every 2 \
	  --out /tmp/lift_mx_straight
	LIFT_MATRIX_KILL_AFTER=3 target/release/lift matrix --toy \
	  --methods lift,full --axis "interval=2,4;seed=1,2" --steps 8 \
	  --ckpt-every 2 --runner-id local --out /tmp/lift_mx_resumed; test $$? -eq 41
	target/release/lift matrix --toy --methods lift,full \
	  --axis "interval=2,4;seed=1,2" --steps 8 --ckpt-every 2 \
	  --runner-id local --out /tmp/lift_mx_resumed

# the ISSUE-8 acceptance flow, locally: register 3 tenants and replay ONE
# seeded request mix twice — once under a budget small enough to churn
# the LRU (evictions asserted) and once with a hold-everything budget —
# then diff the dumped outputs byte-for-byte. The demo itself asserts
# per-tenant divergence from the base, overlay ≡ full materialization,
# hot-swap atomicity, and 1-worker ≡ N-worker bit-identity.
serve-smoke:
	cargo build --release
	target/release/lift serve --tenants 3 --requests 48 --batch 8 \
	  --budget-kb 16 --expect-resident 0 --swaps 1 --seed 5 \
	  --dir /tmp/lift_serve_lru --dump /tmp/lift_serve_lru.dump \
	  | tee /tmp/lift_serve_lru.log
	grep -q "evictions=[1-9]" /tmp/lift_serve_lru.log
	target/release/lift serve --tenants 3 --requests 48 --batch 8 \
	  --budget-kb 4096 --expect-resident 3 --swaps 1 --seed 5 \
	  --dir /tmp/lift_serve_nolru --dump /tmp/lift_serve_nolru.dump
	cmp /tmp/lift_serve_lru.dump /tmp/lift_serve_nolru.dump
	@echo "serve smoke OK: eviction-churn outputs byte-identical to no-LRU run"

# the ISSUE-9 acceptance flow, locally: replay seeded fault schedules
# (util::fault) against train-resume, a 2-runner lease campaign, and a
# serve register/swap/evict mix. The command itself asserts every
# schedule recovered bit-identically (or failed loudly by name) and
# sweeps torn artifacts; running it twice and byte-comparing the reports
# proves the whole harness — injection sites, retries, recovery — is
# deterministic. LIFT_NO_FSYNC only skips real fsyncs, never injection.
torture-smoke:
	cargo build --release
	LIFT_NO_FSYNC=1 target/release/lift torture --schedules 8 --seed 7 \
	  --out /tmp/lift_torture_a
	LIFT_NO_FSYNC=1 target/release/lift torture --schedules 8 --seed 7 \
	  --out /tmp/lift_torture_b
	cmp /tmp/lift_torture_a/torture_report.txt /tmp/lift_torture_b/torture_report.txt
	@echo "torture smoke OK: all schedules recovered, same-seed reports byte-identical"

# the ISSUE-6 acceptance flow, locally: two concurrent runners shard ONE
# campaign directory via cell leases (no coordinator), then the merged
# ledger is diffed cell-for-cell against a single-runner run — equal
# modulo the wall-clock seconds field, with every lease released.
matrix-race:
	cargo build --release
	target/release/lift matrix --toy --methods lift,full \
	  --axis "interval=2,4;seed=1,2" --steps 8 --ckpt-every 2 \
	  --out /tmp/lift_mx_solo
	target/release/lift matrix --toy --methods lift,full \
	  --axis "interval=2,4;seed=1,2" --steps 8 --ckpt-every 2 \
	  --out /tmp/lift_mx_race --runner-id racer_a & \
	target/release/lift matrix --toy --methods lift,full \
	  --axis "interval=2,4;seed=1,2" --steps 8 --ckpt-every 2 \
	  --out /tmp/lift_mx_race --runner-id racer_b; \
	rc_b=$$?; wait $$!; rc_a=$$?; test $$rc_a -eq 0 && test $$rc_b -eq 0
	python3 -c 'import glob, json, os; \
	solo = sorted(glob.glob("/tmp/lift_mx_solo/*.json")); \
	assert len(solo) == 8, solo; \
	pairs = [(json.load(open(p)), json.load(open(os.path.join("/tmp/lift_mx_race", os.path.basename(p))))) for p in solo]; \
	[ (a.pop("seconds"), b.pop("seconds")) for a, b in pairs ]; \
	assert all(a == b for a, b in pairs), "race ledger diverged from single-runner"; \
	assert not glob.glob("/tmp/lift_mx_race/*.lease"), "leases left behind"; \
	print("matrix race OK: merged ledger matches single-runner modulo seconds")'

//! End-to-end driver (the repo's full-system validation):
//! pretrain a transformer from scratch on the synthetic corpus, log the
//! loss curve, then run a LIFT-vs-FullFT fine-tune head-to-head, proving
//! every layer composes: pallas kernels -> jax graphs -> HLO artifacts ->
//! rust coordinator -> masked sparse optimizer -> eval harness.
//!
//! Default preset is `base` (~16M params, hundreds of steps on 1 CPU).
//! For the ~100M-parameter run: `make artifacts-e2e` then
//! `cargo run --release --example e2e_train -- --preset e2e --steps 60`.
//! Results are recorded in EXPERIMENTS.md.

use lift::data::tasks::{TaskFamily, TaskMixSource, TaskSet, ARITH};
use lift::lift::LiftCfg;
use lift::methods::{make_method, Method, Scope};
use lift::runtime::{model_exec::ModelExec, Runtime};
use lift::train::{eval, pretrain, train, TrainCfg};
use lift::util::cli::Args;

fn main() -> anyhow::Result<()> {
    lift::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let preset = args.str("preset", "base");
    let pt_steps = args.usize("steps", 400);
    let ft_steps = args.usize("ft-steps", 200);
    let rank = args.usize("rank", 32);

    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &preset)?;
    println!(
        "== e2e: preset {} | {:.1}M params | batch {} x seq {} ==",
        preset,
        exec.preset.n_params() as f64 / 1e6,
        exec.preset.batch,
        exec.preset.seq
    );

    // ---- phase 1: pretrain from scratch, log the loss curve
    let mut rng = lift::util::rng::Rng::new(1);
    let mut params = lift::model::init_params(&exec.preset, &mut rng);
    let mut corpus = pretrain::world(&exec);
    let mut ctx = pretrain::make_ctx(&rt, &exec, 1);
    let mut full = lift::methods::full::FullFt::new();
    let cfg = TrainCfg {
        steps: pt_steps,
        lr: 6e-4,
        warmup_frac: 0.05,
        log_every: 0,
        seed: 1,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let log = train(&exec, &mut corpus, &mut full, &mut ctx, &mut params, &cfg)?;
    println!("\npretraining loss curve ({} steps):", pt_steps);
    let stride = (pt_steps / 16).max(1);
    for (i, l) in log.losses.iter().enumerate() {
        if i % stride == 0 || i + 1 == log.losses.len() {
            let bar = "#".repeat(((l / log.losses[0]) * 48.0) as usize);
            println!("  step {i:>5}  loss {l:>7.4}  {bar}");
        }
    }
    let toks = pt_steps * exec.preset.batch * exec.preset.seq;
    println!(
        "pretrain: {:.1}s total, {:.3}s/step, {:.0} tokens/s",
        t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64() / pt_steps as f64,
        toks as f64 / t0.elapsed().as_secs_f64()
    );
    println!(
        "held-out ppl: {:.2}",
        eval::perplexity(&exec, &params, &corpus, 4, 99)?
    );

    // ---- phase 2: LIFT vs Full FT fine-tune on the arithmetic suite
    let families: Vec<TaskFamily> = ARITH.to_vec();
    let sets: Vec<TaskSet> = families
        .iter()
        .map(|&f| TaskSet::generate(f, &corpus.vocab, &corpus.kg, 600, 60, 1))
        .collect();
    println!("\nfine-tuning {} steps on the 7-family arithmetic suite:", ft_steps);
    for m in ["lift", "full"] {
        let mut p2 = params.clone();
        let mut src = TaskMixSource {
            sets: sets.clone(),
            batch: exec.preset.batch,
            seq: exec.preset.seq,
        };
        let mut method = make_method(
            m,
            rank,
            LiftCfg { rank, ..Default::default() },
            100,
            Scope::default(),
        )?;
        let fcfg = TrainCfg {
            steps: ft_steps,
            lr: if m == "full" { 3e-4 } else { 1e-3 },
            warmup_frac: 0.03,
            log_every: 0,
            seed: 2,
            ..Default::default()
        };
        let flog = train(&exec, &mut src, &mut *method, &mut ctx, &mut p2, &fcfg)?;
        let mut avg = 0.0;
        print!("  {:<8}", method.name());
        for s in &sets {
            let a = eval::accuracy(&exec, &p2, &s.test)?;
            print!(" {}={a:.1}", s.family.name());
            avg += a / sets.len() as f64;
        }
        println!(
            "  | avg={avg:.2} trainable={} opt={}KiB {:.2}s/step",
            method.trainable(),
            method.opt_bytes() / 1024,
            flog.seconds / ft_steps as f64
        );
    }
    Ok(())
}

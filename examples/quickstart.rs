//! Quickstart: the smallest complete LIFT workflow.
//!
//! 1. load the `tiny` preset's AOT artifacts,
//! 2. pretrain (or load the cached checkpoint),
//! 3. fine-tune the top-5%-principal weights with LIFT on arithmetic,
//! 4. evaluate, and show the memory ledger.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use lift::data::tasks::{TaskFamily, TaskMixSource, TaskSet};
use lift::lift::LiftCfg;
use lift::methods::{make_method, Method, Scope};
use lift::runtime::{model_exec::ModelExec, Runtime};
use lift::train::{eval, pretrain, train, TrainCfg};

fn main() -> anyhow::Result<()> {
    lift::util::logging::init();
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, "tiny")?;
    println!(
        "model: {} ({:.2}M params, d={}, {} layers)",
        exec.preset.name,
        exec.preset.n_params() as f64 / 1e6,
        exec.preset.d,
        exec.preset.layers
    );

    // pretrained base (cached under runs/ after the first call)
    let mut params = pretrain::ensure_pretrained(&rt, &exec, 1500, 1)?;
    let corpus = pretrain::world(&exec);
    println!(
        "pretrained held-out ppl: {:.2}",
        eval::perplexity(&exec, &params, &corpus, 4, 99)?
    );

    // fine-tune with LIFT on two arithmetic families
    let families = [TaskFamily::AddSub, TaskFamily::GsmHard];
    let sets: Vec<TaskSet> = families
        .iter()
        .map(|&f| TaskSet::generate(f, &corpus.vocab, &corpus.kg, 800, 100, 1))
        .collect();
    println!("\nbefore fine-tuning:");
    for s in &sets {
        println!("  {:<10} {:.1}%", s.family.name(), eval::accuracy(&exec, &params, &s.test)?);
    }

    let mut src = TaskMixSource {
        sets: sets.clone(),
        batch: exec.preset.batch,
        seq: exec.preset.seq,
    };
    let mut ctx = pretrain::make_ctx(&rt, &exec, 1);
    let mut method = make_method(
        "lift",
        32,
        LiftCfg { rank: 32, ..Default::default() },
        100,
        Scope::default(),
    )?;
    let cfg = TrainCfg {
        steps: 300,
        lr: 1e-3,
        warmup_frac: 0.03,
        log_every: 50,
        seed: 1,
    };
    let log = train(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg)?;

    println!("\nafter {} LIFT steps ({:.0}s):", cfg.steps, log.seconds);
    for s in &sets {
        println!("  {:<10} {:.1}%", s.family.name(), eval::accuracy(&exec, &params, &s.test)?);
    }
    println!(
        "\ntrainable: {} of {} params ({:.1}%), optimizer state: {} KiB",
        method.trainable(),
        exec.preset.n_params(),
        100.0 * method.trainable() as f64 / exec.preset.n_params() as f64,
        method.opt_bytes() / 1024
    );
    Ok(())
}

//! Quickstart: the smallest complete LIFT workflow.
//!
//! 1. load the `tiny` preset's AOT artifacts,
//! 2. pretrain (or load the cached checkpoint),
//! 3. fine-tune the top-5%-principal weights with LIFT on arithmetic,
//! 4. evaluate, and show the memory ledger.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`
//!
//! Without artifacts (no jax available, or the host-interpreter xla
//! stub), it degrades to an artifact-free selftest of the layer-parallel
//! mask engine: a determinism check plus the measured sequential-vs-
//! parallel refresh row, a scalar-vs-SIMD GEMM dispatch row (~1.0x where
//! AVX2 is absent or `LIFT_NO_SIMD=1`), a versioned-snapshot round trip,
//! and a 3-tenant pass through the per-tenant delta server. CI uses that
//! as the smoke invocation.
//!
//! Checkpoint/restore CLI (ISSUE 3 — see `rust/src/ckpt/` for the
//! on-disk format):
//!
//! ```text
//! lift train --preset tiny --method lift --rank 32 \
//!     --ckpt-every 50 --ckpt-dir runs/ckpt      # snapshot every 50 steps
//!                                               # (written off-loop; the loss
//!                                               # curve streams to the
//!                                               # curve.sidecar next to them)
//! lift train ... --ckpt-keep 3                  # keep-last-N retention
//! lift train --preset tiny --method lift --rank 32 \
//!     --ckpt-dir runs/ckpt --resume latest      # continue the newest snapshot
//! lift train ... --resume runs/ckpt/step_00000050.snap   # or a specific one
//!
//! lift matrix --methods lift,full --selectors weight_mag,random \
//!     --ranks 8,32 --seeds 1,2 --steps 200 --out results/matrix
//!     # resumable N-axis scenario grid (exp::grid): any subset of
//!     # preset × method × suite × rank × interval × seed, e.g.
//!     #   --suites arith,nlu --intervals 50,100 --presets tiny,small
//!     #   --axis "interval=50,100;seed=1,2,3"   (one spec string)
//!     # Each cell persists a v2 outcome (versioned ledger: target-suite
//!     # scores + held-out source-domain retention, exp::retention) plus
//!     # snapshots under --out; rerunning skips finished cells, resumes
//!     # interrupted ones from their newest snapshot, and recomputes
//!     # only corrupt outcomes (loudly). Pre-v2 ledgers REFUSE the run
//!     # until migrated with --migrate-v1 — finished v1 work is never
//!     # silently recomputed. Ends with summary.txt: per-method target
//!     # (`tgt`) and source-retention (`ret`) columns per rank.
//!     # --toy runs artifact-free synthetic cells; --workers caps the
//!     # cell fan-out (default: LIFT_WORKERS / available parallelism).
//!
//! lift matrix ... --out shared/campaign --runner-id host1   # on host 1
//! lift matrix ... --out shared/campaign --runner-id host2   # on host 2
//!     # multi-runner campaigns (exp::lease): N `lift matrix` processes
//!     # pointed at ONE --out directory — same machine or hosts sharing
//!     # a filesystem — shard the campaign with zero coordination
//!     # service. Each cell is claimed by an atomic `<cell-id>.lease`
//!     # file (create-new semantics; runner id + monotonic fencing
//!     # token + TTL deadline): live leases defer the cell to its
//!     # holder, a crashed runner's leases expire after --lease-ttl
//!     # (default 600s — size it above the slowest cell) and are taken
//!     # over at a higher token, and outcome commits are fenced so a
//!     # stalled zombie can never overwrite its usurper's work. Reuse a
//!     # stable --runner-id across restarts to reclaim your own leases
//!     # immediately; --no-lease turns the protocol off for strictly
//!     # single-process campaigns.
//!
//! lift serve --tenants 120 --requests 256 --budget-kb 4096
//!     # LIFT-as-a-service demo (rust/src/serve/): one resident toy base
//!     # plus N per-tenant sparse deltas — the paper's top-5% principal
//!     # weights as `{mask indices, values, base digest}` LIFTSNAP files
//!     # — overlaid at request time through a byte-budgeted LRU of
//!     # row-granular views. Requests are grouped by tenant and fanned
//!     # over the engine pool; the demo asserts overlay-apply ≡ full
//!     # tenant materialization bitwise, per-tenant divergence from the
//!     # base, hot-swap atomicity on live updates, and 1-worker ≡
//!     # N-worker outputs. `make serve-smoke` replays one request mix
//!     # under an eviction-churning budget and a hold-everything budget
//!     # and diffs the dumped outputs byte-for-byte.
//! ```
//!
//! # Quantized scan tier (ISSUE 10)
//!
//! `lift train ... --qscan` (or `qscan=1` as a matrix axis, or
//! `LIFT_QSCAN=1` to force it process-wide) routes the rank-reduce
//! *scan* — the Gram build and subspace-iteration passes that find the
//! principal subspace — through blockwise int8 kernels
//! (`util::gemm::gram_q8` / `matmul_q8`: per-64-column absmax scales,
//! i32 accumulation, f32 scale-out in fixed block order, so scalar and
//! AVX2 dispatch stay bit-identical). Everything that *changes weights*
//! stays full precision: the Rayleigh–Ritz solve, the final principal
//! apply, and all training math run in f64/f32 exactly as before.
//!
//! That split is why quantization is safe here: LIFT only uses the
//! low-rank approximation to *rank* weights and keep the top-k — a
//! selection, not a value — so small perturbations of the subspace can
//! only flip entries right at the threshold. The documented contract is
//! `util::eigh::LIFT_QSCAN_TOL`: the quantized scan's mask must overlap
//! the f64 scan's by at least that fraction (property-tested across
//! shapes and spectra; a final f64 polish pass inside the quantized
//! iteration keeps the margin robust rather than marginal). The same
//! reasoning does NOT extend to training updates, which accumulate —
//! that is why only the scan is quantized. `make test-qscan` runs the
//! whole suite with the tier forced on; `[gemm-q]` in `cargo bench`
//! measures the f64-vs-int8 Gram build.
//!
//! # Durability contract (ISSUE 9)
//!
//! Every durable artifact above — snapshots, the curve sidecar, outcome
//! ledgers, lease files, tenant deltas — is committed by one idiom:
//! write to a `.tmp` sibling, fsync it, rename over the destination,
//! fsync the parent directory (`ckpt::write_atomic`; `LIFT_NO_FSYNC=1`
//! skips the fsyncs for throwaway runs). A crash at any instant
//! therefore leaves either the old complete copy or the new complete
//! copy, never a torn one; orphaned `.tmp` files are inert debris that
//! readers skip with a warning and the next commit consumes. Transient
//! IO errors (EINTR/EAGAIN) are retried with bounded backoff inside the
//! commit; permanent ones (ENOSPC, EIO, EACCES) surface loudly — and an
//! *unreadable* artifact is never treated as a *missing* or *corrupt*
//! one (an unreadable lease defers its cell; an unreadable ledger entry
//! aborts the campaign instead of silently recomputing).
//!
//! ```text
//! lift torture --schedules 32 --seed 7 --out results/torture
//!     # deterministic crash/fault torture harness (exp::torture): replays
//!     # seeded fault schedules (ENOSPC, EIO, EACCES, short writes,
//!     # crash-before/after-rename — util::fault) against train-resume, a
//!     # 2-runner lease campaign, and a serve register/swap/evict mix,
//!     # asserting recovery ≡ straight run bit-identical, zero torn
//!     # artifacts, and every injected fault either retried or surfaced
//!     # by name. Same seed => byte-identical report. `make torture-smoke`
//!     # runs it twice and diffs the reports. LIFT_FAULT_SCHEDULE /
//!     # LIFT_FAULT_SEED arm the same injection layer on any `lift` run.
//! ```

use std::sync::Arc;

use lift::data::tasks::{TaskFamily, TaskMixSource, TaskSet};
use lift::exp::harness::{
    mask_requests, measure_gemm_simd, measure_mask_refresh, measure_step_all, measure_warm_refresh,
    tiny_layer_shapes,
};
use lift::lift::engine::{default_workers, MaskEngine};
use lift::lift::{LiftCfg, Selector};
use lift::methods::{make_method, Method, Scope};
use lift::runtime::{model_exec::ModelExec, ArtifactStatus, Linalg, Runtime};
use lift::tensor::Tensor;
use lift::train::{eval, pretrain, train, TrainCfg};
use lift::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    lift::util::logging::init();
    // `?` on a present-but-broken artifacts dir fails loudly rather than
    // masking itself as the selftest passing; the skip policy lives in
    // Runtime::artifact_status
    let rt = match Runtime::artifact_status()? {
        ArtifactStatus::Ready(rt) => rt,
        ArtifactStatus::StubOnly => {
            println!("AOT artifacts present, but this build links the xla stub.");
            println!("Running the artifact-free mask-engine selftest instead.");
            println!("(link the native xla crate for the full workflow)\n");
            return selftest();
        }
        ArtifactStatus::Missing(e) => {
            println!("AOT artifacts not generated: {e}");
            println!("Running the artifact-free mask-engine selftest instead.");
            println!("For the full workflow: `make artifacts` (needs python + jax).\n");
            return selftest();
        }
    };
    let exec = ModelExec::load(&rt, "tiny")?;
    println!(
        "model: {} ({:.2}M params, d={}, {} layers)",
        exec.preset.name,
        exec.preset.n_params() as f64 / 1e6,
        exec.preset.d,
        exec.preset.layers
    );

    // pretrained base (cached under runs/ after the first call)
    let mut params = pretrain::ensure_pretrained(&rt, &exec, 1500, 1)?;
    let corpus = pretrain::world(&exec);
    println!(
        "pretrained held-out ppl: {:.2}",
        eval::perplexity(&exec, &params, &corpus, 4, 99)?
    );

    // fine-tune with LIFT on two arithmetic families
    let families = [TaskFamily::AddSub, TaskFamily::GsmHard];
    let sets: Vec<TaskSet> = families
        .iter()
        .map(|&f| TaskSet::generate(f, &corpus.vocab, &corpus.kg, 800, 100, 1))
        .collect();
    println!("\nbefore fine-tuning:");
    for s in &sets {
        println!("  {:<10} {:.1}%", s.family.name(), eval::accuracy(&exec, &params, &s.test)?);
    }

    let mut src = TaskMixSource {
        sets: sets.clone(),
        batch: exec.preset.batch,
        seq: exec.preset.seq,
    };
    let mut ctx = pretrain::make_ctx(&rt, &exec, 1);
    let mut method = make_method(
        "lift",
        32,
        LiftCfg { rank: 32, ..Default::default() },
        100,
        Scope::default(),
    )?;
    let cfg = TrainCfg {
        steps: 300,
        lr: 1e-3,
        warmup_frac: 0.03,
        log_every: 50,
        seed: 1,
        ..Default::default()
    };
    let log = train(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg)?;

    println!("\nafter {} LIFT steps ({:.0}s):", cfg.steps, log.seconds);
    for s in &sets {
        println!("  {:<10} {:.1}%", s.family.name(), eval::accuracy(&exec, &params, &s.test)?);
    }
    println!(
        "\ntrainable: {} of {} params ({:.1}%), optimizer state: {} KiB",
        method.trainable(),
        exec.preset.n_params(),
        100.0 * method.trainable() as f64 / exec.preset.n_params() as f64,
        method.opt_bytes() / 1024
    );
    Ok(())
}

/// Artifact-free smoke path: principal-weight selection for a
/// tiny-preset-shaped model through the layer-parallel `MaskEngine`,
/// checking the determinism contract and printing the measured speedup.
fn selftest() -> anyhow::Result<()> {
    let la = Arc::new(Linalg::new(&xla::PjRtClient::cpu()?));
    let shapes = tiny_layer_shapes();
    let mut rng = Rng::new(1);
    let ws: Vec<Tensor> = shapes
        .iter()
        .map(|&(m, n)| Tensor::randn(&[m, n], 0.05, &mut rng))
        .collect();
    let reqs = mask_requests(&ws, 32);
    let cfg = LiftCfg {
        rank: 32,
        ..Default::default()
    };
    let workers = default_workers();
    let seq = MaskEngine::with_workers(la.clone(), 1).select_all(Selector::Lift, &cfg, &reqs, 7)?;
    let par = MaskEngine::with_workers(la.clone(), workers)
        .select_all(Selector::Lift, &cfg, &reqs, 7)?;
    anyhow::ensure!(seq == par, "selftest: parallel masks diverged from sequential");
    let selected: usize = seq.iter().map(|m| m.len()).sum();
    let total: usize = shapes.iter().map(|&(m, n)| m * n).sum();
    println!(
        "mask selftest OK: {} matrices, {selected}/{total} weights selected \
         ({:.1}%), parallel == sequential with {workers} workers",
        shapes.len(),
        100.0 * selected as f64 / total as f64
    );
    // quantized scan tier (ISSUE 10): the int8 scan's selection must
    // overlap the f64 scan's within the documented contract, and stay
    // worker-count deterministic like every other path
    {
        let qcfg = LiftCfg { rank: 32, qscan: true, ..Default::default() };
        let q1 = MaskEngine::with_workers(la.clone(), 1)
            .select_all(Selector::Lift, &qcfg, &reqs, 7)?;
        let qn = MaskEngine::with_workers(la.clone(), workers)
            .select_all(Selector::Lift, &qcfg, &reqs, 7)?;
        anyhow::ensure!(q1 == qn, "selftest: qscan masks diverged across worker counts");
        let tol = lift::util::eigh::LIFT_QSCAN_TOL;
        for (i, (qm, fm)) in q1.iter().zip(&seq).enumerate() {
            let f: std::collections::HashSet<u32> = fm.iter().copied().collect();
            let inter = qm.iter().filter(|x| f.contains(x)).count();
            let overlap = inter as f64 / fm.len().max(1) as f64;
            anyhow::ensure!(
                overlap >= tol,
                "selftest: qscan mask {i} overlaps f64 by {overlap:.4} < contract {tol}"
            );
        }
        println!("qscan selftest OK: int8 scan masks within the {tol} overlap contract, 1w == {workers}w");
    }
    let row = measure_mask_refresh(&la, &shapes, 32, 32, workers, 3)?;
    println!("{}", row.row());
    // and the batched optimizer step (several layers' worth of matrices)
    let mut step_shapes = Vec::new();
    for _ in 0..4 {
        step_shapes.extend(tiny_layer_shapes());
    }
    let row = measure_step_all(&step_shapes, 32, workers, 3, 10)?;
    println!("{}", row.row());
    // warm-started exact refresh vs cold on a drifting steady state
    // (seq = cold, Nw column = warm — see measure_warm_refresh)
    let row = measure_warm_refresh(&shapes, 16, 2)?;
    println!("{}", row.row());
    // SIMD microkernel dispatch: scalar vs runtime-detected (reads ~1.0x
    // on hosts without AVX2 or under LIFT_NO_SIMD=1 — that's expected)
    let row = measure_gemm_simd(2);
    println!("{}", row.row());
    // versioned-snapshot round trip (the ISSUE-3 ckpt subsystem): train a
    // couple of toy steps, snapshot, reload, digest-compare
    {
        use lift::exp::matrix::{synth_step, toy_ctx, toy_params};
        use lift::train::{train_with, TrainCfg};
        let mut ctx = toy_ctx(workers, 7)?;
        let mut params = toy_params(7);
        let mut method = make_method(
            "lift",
            4,
            LiftCfg { rank: 4, ..Default::default() },
            2,
            Scope::default(),
        )?;
        let dir = std::env::temp_dir().join(format!("lift_quickstart_ckpt_{}", std::process::id()));
        let cfg = TrainCfg {
            steps: 2,
            log_every: 0,
            ckpt_every: 2,
            ckpt_dir: Some(dir.clone()),
            ..Default::default()
        };
        train_with(&mut synth_step, &mut *method, &mut ctx, &mut params, &cfg, None)?;
        let snap = lift::ckpt::latest_snapshot(&dir)?
            .ok_or_else(|| anyhow::anyhow!("selftest: no snapshot written"))?;
        let state = lift::ckpt::load_trainer(&snap)?;
        let mut fresh = make_method(
            "lift",
            4,
            LiftCfg { rank: 4, ..Default::default() },
            2,
            Scope::default(),
        )?;
        fresh.load_state(&state.method_state)?;
        anyhow::ensure!(
            fresh.state_digest() == method.state_digest(),
            "selftest: snapshot state digest drifted"
        );
        let bytes = std::fs::metadata(&snap)?.len();
        println!(
            "ckpt selftest OK: {} B snapshot at step {}, save -> load -> digest match",
            bytes, state.step
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
    // serve selftest (ISSUE 8): three tenants through the per-tenant
    // delta server — overlay ≡ dense materialization bitwise, and every
    // tenant's answer diverges from the base's
    {
        use lift::exp::matrix::{toy_params, toy_preset};
        use lift::serve::{base_digest, synth_delta, Request, Server};
        let base = toy_params(7);
        let digest = base_digest(&base);
        let dir = std::env::temp_dir().join(format!("lift_quickstart_serve_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut server = Server::new(&base, &toy_preset(), &dir, 1 << 20, workers)?;
        for i in 0..3usize {
            server
                .store()
                .register(&synth_delta(&base, &format!("t{i}"), digest, 2, 70 + i as u64))?;
        }
        let reqs: Vec<Request> =
            (0..3).map(|i| Request { tenant: format!("t{i}"), seed: 40 + i as u64 }).collect();
        let outs = server.handle_batch(&reqs)?;
        for (r, out) in reqs.iter().zip(&outs) {
            anyhow::ensure!(
                *out != server.base_forward(r.seed),
                "serve selftest: tenant {} output identical to base",
                r.tenant
            );
        }
        let mut one = Server::new(&base, &toy_preset(), &dir, 1 << 20, 1)?;
        let outs1 = one.handle_batch(&reqs)?;
        anyhow::ensure!(
            outs.iter().zip(&outs1).all(|(a, b)| a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())),
            "serve selftest: {workers}-worker outputs != 1-worker outputs"
        );
        println!(
            "serve selftest OK: 3 tenants overlaid on one base ({} B resident), \
             outputs diverge from base, 1w == {workers}w",
            server.lru().resident_bytes()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
    // scenario-grid selftest (ISSUE 5): the N-axis expansion is a pure
    // function of cell field values — axis insertion order must not move
    // a single cell id (ledger entries key on them)
    {
        use lift::exp::grid::{Axis, Grid};
        use lift::exp::matrix::LEDGER_VERSION;
        let forward = Grid::new(4)
            .with_axis(Axis::Method(vec!["lift".into(), "weight_mag".into()]))
            .with_axis(Axis::Interval(vec![2, 4]))
            .with_axis(Axis::Seed(vec![1, 2]))
            .expand();
        let reversed = Grid::new(4)
            .with_axis(Axis::Seed(vec![1, 2]))
            .with_axis(Axis::Interval(vec![2, 4]))
            .with_axis(Axis::Method(vec!["lift".into(), "weight_mag".into()]))
            .expand();
        anyhow::ensure!(
            forward.len() == 8 && forward == reversed,
            "grid selftest: axis order moved cell ids"
        );
        println!(
            "grid selftest OK: {} cells (outcome ledger v{LEDGER_VERSION}), \
             axis-order-invariant ids, e.g. {}",
            forward.len(),
            forward[0].id()
        );
    }
    Ok(())
}

//! Perturbation probe (the paper's §4 intuition, interactively):
//! noise the principal weights of the pretrained model and watch fact
//! recall collapse while weight-magnitude/random noise barely moves it.
//!
//! Run: `cargo run --release --example perturbation_probe [-- --scale 0.02]`

use lift::analysis::perturb;
use lift::lift::{LiftCfg, Selector};
use lift::runtime::{model_exec::ModelExec, Linalg, Runtime};
use lift::train::{eval, pretrain};
use lift::util::cli::Args;
use lift::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    lift::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let scale = args.f32("scale", 0.02);
    let frac = args.f32("frac", 0.05) as f64;

    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, "tiny")?;
    let params = pretrain::ensure_pretrained(&rt, &exec, 1500, 1)?;
    let corpus = pretrain::world(&exec);
    let la = Linalg::new(&rt.client);
    let total: usize = lift::model::trainable_matrices(&exec.preset, false)
        .iter()
        .map(|&i| params[i].len())
        .sum();
    let n = (total as f64 * frac) as usize;

    println!("perturbing {n} of {total} matrix params (scale {scale}):\n");
    let ppl0 = eval::perplexity(&exec, &params, &corpus, 4, 99)?;
    let rec0 = eval::fact_recall(&rt, &exec, &params, &corpus, 50, 7)?;
    println!("{:<14} {:>10} {:>12}", "selector", "ppl", "P(answer)");
    println!("{:<14} {:>10.3} {:>12.4}   (clean model)", "-", ppl0, rec0);
    for (name, sel) in [
        ("lift", Selector::Lift),
        ("weight_mag", Selector::WeightMag),
        ("random", Selector::Random),
    ] {
        let mut rng = Rng::new(7);
        let cfg = LiftCfg { rank: 32, ..Default::default() };
        let noisy = perturb::perturb(&la, &exec.preset, &params, sel, &cfg, n, scale, &mut rng)?;
        let ppl = eval::perplexity(&exec, &noisy, &corpus, 4, 99)?;
        let rec = eval::fact_recall(&rt, &exec, &noisy, &corpus, 50, 7)?;
        println!("{name:<14} {ppl:>10.3} {rec:>12.4}");
    }
    println!("\n(the LIFT row should be dramatically worse — those are the principal weights)");
    Ok(())
}

//! Method shootout: fine-tune every method in the zoo on one task family
//! and print a ranked comparison — a fast way to reproduce the paper's
//! headline ordering on your own machine.
//!
//! Run: `cargo run --release --example method_shootout -- [--task gsm] [--steps 150]`

use lift::data::tasks::{TaskFamily, TaskMixSource, TaskSet};
use lift::lift::LiftCfg;
use lift::methods::{make_method, Method, Scope};
use lift::runtime::{model_exec::ModelExec, Runtime};
use lift::train::{eval, pretrain, train, TrainCfg};
use lift::util::cli::Args;

fn family_of(name: &str) -> TaskFamily {
    match name {
        "gsm" => TaskFamily::GsmHard,
        "addsub" => TaskFamily::AddSub,
        "boolq" => TaskFamily::BoolQ,
        "arc" => TaskFamily::ArcC,
        "gpqa" => TaskFamily::Gpqa,
        _ => TaskFamily::GsmHard,
    }
}

fn main() -> anyhow::Result<()> {
    lift::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize("steps", 150);
    let rank = args.usize("rank", 32);
    let fam = family_of(&args.str("task", "gsm"));

    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, "tiny")?;
    let base = pretrain::ensure_pretrained(&rt, &exec, 1500, 1)?;
    let corpus = pretrain::world(&exec);
    let set = TaskSet::generate(fam, &corpus.vocab, &corpus.kg, 800, 100, 1);
    println!(
        "task {} | {} train / {} test | rank {rank} | {steps} steps\n",
        fam.name(),
        set.train.len(),
        set.test.len()
    );

    let mut board: Vec<(String, f64, usize)> = Vec::new();
    for m in [
        "lift", "full", "lora", "dora", "pissa", "s2ft", "sift", "spiel",
        "weight_mag", "grad_mag", "movement", "random",
    ] {
        let mut params = base.clone();
        let mut src = TaskMixSource {
            sets: vec![set.clone()],
            batch: exec.preset.batch,
            seq: exec.preset.seq,
        };
        let mut ctx = pretrain::make_ctx(&rt, &exec, 1);
        let mut method = make_method(
            m,
            rank,
            LiftCfg { rank, ..Default::default() },
            100,
            Scope::default(),
        )?;
        let cfg = TrainCfg {
            steps,
            lr: lift::exp::harness::default_lr(m),
            warmup_frac: 0.03,
            log_every: 0,
            seed: 1,
            ..Default::default()
        };
        train(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg)?;
        let acc = eval::accuracy(&exec, &params, &set.test)?;
        println!("  finished {:<18} acc {acc:.2}", method.name());
        board.push((method.name(), acc, method.trainable()));
    }
    board.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\n==== leaderboard ({}) ====", fam.name());
    for (i, (name, acc, trainable)) in board.iter().enumerate() {
        println!("{:>2}. {:<18} {acc:>7.2}%   ({trainable} trainable)", i + 1, name);
    }
    Ok(())
}

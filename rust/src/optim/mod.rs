//! Optimizers: dense AdamW (Full FT / adapters) and the paper's sparse
//! AdamW with packed moment vectors (Algorithm 1).

pub mod sparse;

pub use sparse::{refresh_all, step_all, KernelAdam, SparseAdam};

use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug)]
pub struct AdamCfg {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamCfg {
    fn default() -> Self {
        AdamCfg {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
        }
    }
}

/// Dense AdamW over one tensor.
#[derive(Clone, Debug)]
pub struct DenseAdam {
    pub cfg: AdamCfg,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: usize,
}

impl DenseAdam {
    pub fn new(numel: usize, cfg: AdamCfg) -> DenseAdam {
        DenseAdam {
            cfg,
            m: vec![0.0; numel],
            v: vec![0.0; numel],
            t: 0,
        }
    }

    /// One AdamW step; `w` and `g` must have the state's length.
    pub fn step(&mut self, w: &mut [f32], g: &[f32], lr: f32) {
        assert_eq!(w.len(), self.m.len());
        assert_eq!(g.len(), self.m.len());
        self.t += 1;
        let c = self.cfg;
        let bc1 = 1.0 - c.beta1.powi(self.t as i32);
        let bc2 = 1.0 - c.beta2.powi(self.t as i32);
        for i in 0..w.len() {
            let gi = g[i];
            self.m[i] = c.beta1 * self.m[i] + (1.0 - c.beta1) * gi;
            self.v[i] = c.beta2 * self.v[i] + (1.0 - c.beta2) * gi * gi;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            w[i] -= lr * (mhat / (vhat.sqrt() + c.eps) + c.weight_decay * w[i]);
        }
    }

    pub fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }
}

/// Dense AdamW over a full parameter list.
pub struct DenseAdamSet {
    pub states: Vec<DenseAdam>,
}

impl DenseAdamSet {
    pub fn new(params: &[Tensor], cfg: AdamCfg) -> DenseAdamSet {
        DenseAdamSet {
            states: params.iter().map(|p| DenseAdam::new(p.len(), cfg)).collect(),
        }
    }

    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        for ((p, g), st) in params.iter_mut().zip(grads).zip(&mut self.states) {
            st.step(&mut p.data, &g.data, lr);
        }
    }

    /// Layer-parallel twin of [`DenseAdamSet::step`]: per-tensor AdamW
    /// steps share no state, so the `par_map` fan-out is bit-identical
    /// to the sequential loop for any worker count.
    pub fn step_all(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32, workers: usize) {
        let jobs: Vec<(&mut DenseAdam, &mut Tensor, &Tensor)> = self
            .states
            .iter_mut()
            .zip(params.iter_mut())
            .zip(grads)
            .map(|((st, p), g)| (st, p, g))
            .collect();
        crate::lift::engine::par_map(workers, jobs, |_, (st, p, g)| {
            st.step(&mut p.data, &g.data, lr)
        });
    }

    pub fn state_bytes(&self) -> usize {
        self.states.iter().map(|s| s.state_bytes()).sum()
    }
}

/// Linear warmup then linear decay to zero (the paper's schedule).
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base: f32,
    pub warmup: usize,
    pub total: usize,
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        if self.total == 0 {
            return self.base;
        }
        if step < self.warmup {
            return self.base * (step as f32 + 1.0) / (self.warmup.max(1) as f32);
        }
        let rest = (self.total - self.warmup).max(1) as f32;
        let frac = 1.0 - (step - self.warmup) as f32 / rest;
        self.base * frac.max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_converges_on_quadratic() {
        // minimize ||w - target||^2
        let target = [3.0f32, -2.0, 0.5];
        let mut w = vec![0.0f32; 3];
        let mut opt = DenseAdam::new(3, AdamCfg::default());
        for _ in 0..2000 {
            let g: Vec<f32> = w.iter().zip(&target).map(|(wi, t)| 2.0 * (wi - t)).collect();
            opt.step(&mut w, &g, 0.01);
        }
        for (wi, t) in w.iter().zip(&target) {
            assert!((wi - t).abs() < 1e-2, "{wi} vs {t}");
        }
    }

    #[test]
    fn weight_decay_shrinks() {
        let mut w = vec![1.0f32];
        let cfg = AdamCfg {
            weight_decay: 0.1,
            ..Default::default()
        };
        let mut opt = DenseAdam::new(1, cfg);
        for _ in 0..100 {
            opt.step(&mut w, &[0.0], 0.01);
        }
        assert!(w[0] < 1.0 && w[0] > 0.0);
    }

    #[test]
    fn dense_set_step_all_matches_step() {
        let mut rng = crate::util::rng::Rng::new(6);
        let mut p1: Vec<Tensor> = (0..5)
            .map(|_| Tensor::randn(&[7, 3], 1.0, &mut rng))
            .collect();
        let mut p2 = p1.clone();
        let grads: Vec<Tensor> = (0..5)
            .map(|_| Tensor::randn(&[7, 3], 1.0, &mut rng))
            .collect();
        let mut s1 = DenseAdamSet::new(&p1, AdamCfg::default());
        let mut s2 = DenseAdamSet::new(&p2, AdamCfg::default());
        for _ in 0..3 {
            s1.step(&mut p1, &grads, 0.01);
            s2.step_all(&mut p2, &grads, 0.01, 3);
        }
        assert_eq!(p1, p2, "weights must be bit-identical");
        for (a, b) in s1.states.iter().zip(&s2.states) {
            assert_eq!(a.m, b.m);
            assert_eq!(a.v, b.v);
            assert_eq!(a.t, b.t);
        }
    }

    #[test]
    fn schedule_shape() {
        let s = LrSchedule {
            base: 1.0,
            warmup: 10,
            total: 110,
        };
        assert!(s.at(0) < 0.2);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
        assert!(s.at(60) < 1.0 && s.at(60) > 0.0);
        assert!(s.at(109) < 0.05);
    }

    #[test]
    fn matches_reference_formula() {
        // one hand-computed step: w=1, g=0.5, lr=0.1, defaults, t=1
        let mut w = vec![1.0f32];
        let mut opt = DenseAdam::new(1, AdamCfg::default());
        opt.step(&mut w, &[0.5], 0.1);
        // mhat = g, vhat = g^2 -> update = g/(|g|+eps) = 1
        assert!((w[0] - 0.9).abs() < 1e-5, "{}", w[0]);
    }
}

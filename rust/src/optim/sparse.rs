//! Sparse AdamW with packed moment vectors — Algorithm 1 of the paper.
//!
//! Moments are stored only for the masked ("principal") weights as dense
//! vectors of length k; on mask refresh the state migrates: entries that
//! survive in the new mask keep their moments, new entries start at zero
//! (Algorithm 1 lines 5-12). This is the memory contribution: optimizer
//! state is `2k` floats instead of `2mn` (Fig. 6).
//!
//! Two execution paths, numerically identical:
//!   * host loops (default — k is small on this box), and
//!   * the `sparse_adam_<bucket>` Pallas artifact via PJRT (`KernelAdam`),
//!     used on the e2e path and cross-checked in tests.

use std::collections::HashMap;

use anyhow::Result;

use super::AdamCfg;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Packed sparse AdamW state for one weight matrix.
#[derive(Clone, Debug)]
pub struct SparseAdam {
    pub cfg: AdamCfg,
    /// flat indices of the masked entries, sorted ascending
    pub idx: Vec<u32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: usize,
}

impl SparseAdam {
    pub fn new(mut idx: Vec<u32>, cfg: AdamCfg) -> SparseAdam {
        idx.sort_unstable();
        idx.dedup();
        let k = idx.len();
        SparseAdam {
            cfg,
            idx,
            m: vec![0.0; k],
            v: vec![0.0; k],
            t: 0,
        }
    }

    pub fn k(&self) -> usize {
        self.idx.len()
    }

    /// Optimizer-state bytes (the Fig. 6 metric).
    pub fn state_bytes(&self) -> usize {
        self.idx.len() * 4 + (self.m.len() + self.v.len()) * 4
    }

    /// One masked AdamW step on the host path.
    pub fn step(&mut self, w: &mut [f32], g_full: &[f32], lr: f32) {
        self.t += 1;
        let c = self.cfg;
        let bc1 = 1.0 - c.beta1.powi(self.t as i32);
        let bc2 = 1.0 - c.beta2.powi(self.t as i32);
        for (j, &flat) in self.idx.iter().enumerate() {
            let i = flat as usize;
            let gi = g_full[i];
            self.m[j] = c.beta1 * self.m[j] + (1.0 - c.beta1) * gi;
            self.v[j] = c.beta2 * self.v[j] + (1.0 - c.beta2) * gi * gi;
            let mhat = self.m[j] / bc1;
            let vhat = self.v[j] / bc2;
            w[i] -= lr * (mhat / (vhat.sqrt() + c.eps) + c.weight_decay * w[i]);
        }
    }

    /// Mask refresh (Algorithm 1 lines 5-12): moments for indices present
    /// in both masks survive; fresh indices start cold. One-shot wrapper
    /// over [`SparseAdam::refresh_with`].
    pub fn refresh(&mut self, new_idx: Vec<u32>) {
        self.refresh_with(new_idx, &mut RefreshScratch::default());
    }

    /// [`SparseAdam::refresh`] with a caller-owned scratch: the survivor
    /// lookup table and the replacement moment vectors are drawn from
    /// (and returned to) `scratch`, so a batched refresh over a whole
    /// model reuses three allocations instead of making three per
    /// matrix. Numerically identical to the one-shot form.
    pub fn refresh_with(&mut self, new_idx: Vec<u32>, scratch: &mut RefreshScratch) {
        scratch.old.clear();
        for (j, &i) in self.idx.iter().enumerate() {
            scratch.old.insert(i, j as u32);
        }
        let mut new_idx = new_idx;
        new_idx.sort_unstable();
        new_idx.dedup();
        scratch.m.clear();
        scratch.m.resize(new_idx.len(), 0.0);
        scratch.v.clear();
        scratch.v.resize(new_idx.len(), 0.0);
        for (j, &i) in new_idx.iter().enumerate() {
            if let Some(&oj) = scratch.old.get(&i) {
                scratch.m[j] = self.m[oj as usize];
                scratch.v[j] = self.v[oj as usize];
            }
        }
        self.idx = new_idx;
        // swap the built vectors in; the retired ones become next
        // matrix's scratch capacity
        std::mem::swap(&mut self.m, &mut scratch.m);
        std::mem::swap(&mut self.v, &mut scratch.v);
    }

    /// Fraction of the new mask that survived from the old one.
    pub fn overlap(&self, new_idx: &[u32]) -> f64 {
        if new_idx.is_empty() {
            return 0.0;
        }
        let old: std::collections::HashSet<u32> = self.idx.iter().copied().collect();
        new_idx.iter().filter(|i| old.contains(i)).count() as f64 / new_idx.len() as f64
    }
}

/// Scratch for [`SparseAdam::refresh_with`]: the survivor lookup table
/// plus the two replacement moment vectors, reused across every matrix
/// of a batched refresh (and across refreshes, when the caller keeps it).
#[derive(Default)]
pub struct RefreshScratch {
    /// old flat index → packed position
    old: HashMap<u32, u32>,
    m: Vec<f32>,
    v: Vec<f32>,
}

/// Batched mask refresh across many matrices — the trainer-facing form of
/// Algorithm 1 lines 5-12. `masks[i]` is the new index set for
/// `states[i]`; each `SparseAdam` migrates (survivors keep moments, fresh
/// entries start cold) through one shared [`RefreshScratch`]. Returns
/// the mean survivor overlap for diagnostics. Masks typically come from
/// one layer-parallel `lift::engine::MaskEngine::select_all_warm` call.
pub fn refresh_all(states: &mut [(usize, SparseAdam)], masks: Vec<Vec<u32>>) -> f64 {
    assert_eq!(
        states.len(),
        masks.len(),
        "refresh_all: {} states vs {} masks",
        states.len(),
        masks.len()
    );
    let n = states.len().max(1);
    let mut overlap = 0.0;
    let mut scratch = RefreshScratch::default();
    for ((_, st), idx) in states.iter_mut().zip(masks) {
        overlap += st.overlap(&idx);
        st.refresh_with(idx, &mut scratch);
    }
    overlap / n as f64
}

/// Batched optimizer step across many matrices — the trainer-facing twin
/// of [`refresh_all`] (`Method::step_all` routes here). Each state gets
/// exclusive access to its parameter's data; per-matrix [`SparseAdam`]
/// steps share nothing, so fanning them over `workers` threads through
/// `lift::engine::par_map` is bit-identical to the sequential loop for
/// any worker count (the cross-worker determinism suite in
/// `rust/tests/engine.rs` asserts this).
pub fn step_all(
    states: &mut [(usize, SparseAdam)],
    params: &mut [Tensor],
    grads: &[Tensor],
    lr: f32,
    workers: usize,
) {
    step_all_refs(
        states.iter_mut().map(|(pi, st)| (*pi, st)).collect(),
        params,
        grads,
        lr,
        workers,
    )
}

/// [`step_all`] over caller-collected state references, for methods whose
/// state tuples carry extra per-matrix fields (e.g. SpIEL's snapshots).
/// The disjoint-`&mut` carving lives in `lift::engine::par_over_params`.
pub fn step_all_refs(
    states: Vec<(usize, &mut SparseAdam)>,
    params: &mut [Tensor],
    grads: &[Tensor],
    lr: f32,
    workers: usize,
) {
    crate::lift::engine::par_over_params(states, params, grads, workers, |st, p, g| {
        st.step(&mut p.data, &g.data, lr)
    });
}

/// PJRT-kernel-backed variant: drives the `sparse_adam_<k>` Pallas artifact.
pub struct KernelAdam<'rt> {
    rt: &'rt Runtime,
    bucket: usize,
    exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
}

impl<'rt> KernelAdam<'rt> {
    /// Pick the smallest artifact bucket that fits k packed entries.
    pub fn new(rt: &'rt Runtime, k: usize) -> Result<KernelAdam<'rt>> {
        let bucket = *rt
            .manifest
            .adam_buckets
            .iter()
            .find(|&&b| b >= k)
            .or_else(|| rt.manifest.adam_buckets.last())
            .ok_or_else(|| anyhow::anyhow!("no adam buckets in manifest"))?;
        let file = rt
            .manifest
            .kernels
            .get(&format!("sparse_adam_{bucket}"))
            .ok_or_else(|| anyhow::anyhow!("sparse_adam_{bucket} not in manifest"))?;
        let exe = rt.load_artifact(file)?;
        Ok(KernelAdam { rt, bucket, exe })
    }

    /// One step over packed vectors via the Pallas kernel. Vectors shorter
    /// than the bucket are zero-padded (zero grad = no-op entries modulo
    /// weight decay on zero params, also a no-op).
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        p: &mut Vec<f32>,
        g: &[f32],
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        cfg: &AdamCfg,
        t: usize,
        lr: f32,
    ) -> Result<()> {
        let k = p.len();
        anyhow::ensure!(k <= self.bucket, "k={k} exceeds bucket {}", self.bucket);
        let pad = |x: &[f32]| {
            let mut out = x.to_vec();
            out.resize(self.bucket, 0.0);
            Tensor::from_vec(&[self.bucket], out)
        };
        let scalars = Tensor::from_vec(
            &[1, 8],
            vec![
                lr,
                cfg.beta1,
                cfg.beta2,
                cfg.eps,
                cfg.weight_decay,
                1.0 - cfg.beta1.powi(t as i32),
                1.0 - cfg.beta2.powi(t as i32),
                0.0,
            ],
        );
        let args = vec![
            crate::runtime::literal::tensor_to_literal(&pad(p))?,
            crate::runtime::literal::tensor_to_literal(&pad(g))?,
            crate::runtime::literal::tensor_to_literal(&pad(m))?,
            crate::runtime::literal::tensor_to_literal(&pad(v))?,
            crate::runtime::literal::tensor_to_literal(&scalars)?,
        ];
        let parts = self.rt.run_tuple(&self.exe, &args)?;
        anyhow::ensure!(parts.len() == 3, "sparse_adam kernel returned {}", parts.len());
        let pn = crate::runtime::literal::literal_to_vec_f32(&parts[0])?;
        let mn = crate::runtime::literal::literal_to_vec_f32(&parts[1])?;
        let vn = crate::runtime::literal::literal_to_vec_f32(&parts[2])?;
        p.copy_from_slice(&pn[..k]);
        m.copy_from_slice(&mn[..k]);
        v.copy_from_slice(&vn[..k]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn only_masked_entries_move() {
        let mut w = vec![1.0f32; 10];
        let g = vec![0.5f32; 10];
        let mut opt = SparseAdam::new(vec![2, 5, 7], AdamCfg::default());
        opt.step(&mut w, &g, 0.1);
        for (i, &wi) in w.iter().enumerate() {
            if [2, 5, 7].contains(&(i as u32)) {
                assert!((wi - 0.9).abs() < 1e-5, "masked {i} should step");
            } else {
                assert_eq!(wi, 1.0, "unmasked {i} must not move");
            }
        }
    }

    #[test]
    fn matches_dense_adam_on_mask() {
        // sparse Adam over the full index set == dense Adam
        let n = 16;
        let mut w1 = vec![0.3f32; n];
        let mut w2 = w1.clone();
        let mut sp = SparseAdam::new((0..n as u32).collect(), AdamCfg::default());
        let mut dn = super::super::DenseAdam::new(n, AdamCfg::default());
        let mut rng = crate::util::rng::Rng::new(4);
        for _ in 0..20 {
            let g = rng.normal_vec(n, 1.0);
            sp.step(&mut w1, &g, 0.01);
            dn.step(&mut w2, &g, 0.01);
        }
        for (a, b) in w1.iter().zip(&w2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn refresh_preserves_surviving_state() {
        let mut opt = SparseAdam::new(vec![1, 2, 3], AdamCfg::default());
        let mut w = vec![0.0f32; 8];
        opt.step(&mut w, &[1.0; 8], 0.1);
        let m_at_2 = opt.m[opt.idx.iter().position(|&i| i == 2).unwrap()];
        assert!(m_at_2 != 0.0);
        opt.refresh(vec![2, 6]);
        assert_eq!(opt.idx, vec![2, 6]);
        let j2 = opt.idx.iter().position(|&i| i == 2).unwrap();
        let j6 = opt.idx.iter().position(|&i| i == 6).unwrap();
        assert_eq!(opt.m[j2], m_at_2, "surviving entry keeps momentum");
        assert_eq!(opt.m[j6], 0.0, "fresh entry starts cold");
    }

    #[test]
    fn refresh_all_migrates_every_state() {
        let mut states = vec![
            (0usize, SparseAdam::new(vec![1, 2, 3], AdamCfg::default())),
            (4usize, SparseAdam::new(vec![0, 5], AdamCfg::default())),
        ];
        let mut w = vec![0.0f32; 8];
        for (_, st) in states.iter_mut() {
            st.step(&mut w, &[1.0; 8], 0.1);
        }
        let mean = refresh_all(&mut states, vec![vec![2, 6], vec![0, 5]]);
        // matrix 0 keeps 1/2 of its mask, matrix 1 keeps 2/2
        assert!((mean - 0.75).abs() < 1e-12, "mean overlap {mean}");
        assert_eq!(states[0].1.idx, vec![2, 6]);
        assert_eq!(states[1].1.idx, vec![0, 5]);
        assert!(states[1].1.m.iter().all(|&m| m != 0.0), "survivors keep state");
    }

    #[test]
    fn step_all_matches_sequential_loop() {
        let mut rng = crate::util::rng::Rng::new(8);
        let shapes = [(6usize, 8usize), (4, 4), (10, 3)];
        let mut params: Vec<Tensor> = shapes
            .iter()
            .map(|&(m, n)| Tensor::randn(&[m, n], 1.0, &mut rng))
            .collect();
        let grads: Vec<Tensor> = shapes
            .iter()
            .map(|&(m, n)| Tensor::randn(&[m, n], 1.0, &mut rng))
            .collect();
        let mut states: Vec<(usize, SparseAdam)> = shapes
            .iter()
            .enumerate()
            .map(|(i, &(m, n))| {
                let mut idx: Vec<u32> = rng
                    .sample_indices(m * n, m * n / 2)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect();
                idx.sort_unstable();
                (i, SparseAdam::new(idx, AdamCfg::default()))
            })
            .collect();
        let mut params_seq = params.clone();
        let mut states_seq = states.clone();
        for _ in 0..3 {
            for (pi, st) in states_seq.iter_mut() {
                st.step(&mut params_seq[*pi].data, &grads[*pi].data, 0.01);
            }
            step_all(&mut states, &mut params, &grads, 0.01, 4);
        }
        assert_eq!(params, params_seq, "weights must be bit-identical");
        for ((_, a), (_, b)) in states.iter().zip(&states_seq) {
            assert_eq!(a.m, b.m, "first moments must be bit-identical");
            assert_eq!(a.v, b.v, "second moments must be bit-identical");
            assert_eq!(a.t, b.t);
        }
    }

    #[test]
    fn step_all_leaves_stateless_params_alone() {
        let mut params = vec![
            Tensor::full(&[2, 2], 1.0),
            Tensor::full(&[2, 2], 1.0),
            Tensor::full(&[2, 2], 1.0),
        ];
        let grads = vec![
            Tensor::full(&[2, 2], 0.5),
            Tensor::full(&[2, 2], 0.5),
            Tensor::full(&[2, 2], 0.5),
        ];
        let mut states = vec![
            (0usize, SparseAdam::new(vec![0, 1, 2, 3], AdamCfg::default())),
            (2usize, SparseAdam::new(vec![1], AdamCfg::default())),
        ];
        step_all(&mut states, &mut params, &grads, 0.1, 2);
        assert!(params[0].data.iter().all(|&w| w != 1.0));
        assert!(params[1].data.iter().all(|&w| w == 1.0), "no state, no step");
        assert!(params[2].data[1] != 1.0 && params[2].data[0] == 1.0);
    }

    #[test]
    fn overlap_metric() {
        let opt = SparseAdam::new(vec![1, 2, 3, 4], AdamCfg::default());
        assert!((opt.overlap(&[3, 4, 5, 6]) - 0.5).abs() < 1e-12);
        assert_eq!(opt.overlap(&[]), 0.0);
    }

    #[test]
    fn state_bytes_scale_with_k() {
        let opt = SparseAdam::new((0..100).collect(), AdamCfg::default());
        assert_eq!(opt.state_bytes(), 100 * 12);
    }
}

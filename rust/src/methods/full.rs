//! Full fine-tuning: dense AdamW over every parameter.

use anyhow::Result;

use super::{Ctx, Method};
use crate::ckpt::codec::{Dec, Enc};
use crate::optim::DenseAdamSet;
use crate::tensor::Tensor;

pub struct FullFt {
    opt: Option<DenseAdamSet>,
    n_params: usize,
}

impl FullFt {
    pub fn new() -> FullFt {
        FullFt {
            opt: None,
            n_params: 0,
        }
    }
}

impl Default for FullFt {
    fn default() -> Self {
        Self::new()
    }
}

impl Method for FullFt {
    fn name(&self) -> String {
        "FullFT".into()
    }

    fn init(&mut self, ctx: &mut Ctx, params: &[Tensor]) -> Result<()> {
        self.n_params = params.iter().map(|p| p.len()).sum();
        self.opt = Some(DenseAdamSet::new(params, ctx.adam));
        Ok(())
    }

    fn step(
        &mut self,
        _ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        _step: usize,
        lr: f32,
    ) -> Result<()> {
        self.opt
            .as_mut()
            .expect("init not called")
            .step(params, grads, lr);
        Ok(())
    }

    /// Per-tensor dense Adam steps are independent — fan across the pool.
    fn step_all(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        _step: usize,
        lr: f32,
    ) -> Result<()> {
        self.opt
            .as_mut()
            .expect("init not called")
            .step_all(params, grads, lr, ctx.workers);
        Ok(())
    }

    fn trainable(&self) -> usize {
        self.n_params
    }

    fn opt_bytes(&self) -> usize {
        self.opt.as_ref().map(|o| o.state_bytes()).unwrap_or(0)
    }

    fn state_digest(&self) -> u64 {
        let words = self.opt.iter().flat_map(|o| {
            o.states
                .iter()
                .flat_map(|st| super::adam_words(st.t, &st.m, &st.v))
        });
        super::digest_words(words)
    }

    fn save_state(&self) -> Result<Vec<u8>> {
        let mut e = Enc::new();
        e.u8(b'F');
        e.usize(self.n_params);
        match &self.opt {
            Some(o) => {
                e.bool(true);
                e.usize(o.states.len());
                for st in &o.states {
                    e.dense_adam(st);
                }
            }
            None => e.bool(false),
        }
        Ok(e.into_bytes())
    }

    fn load_state(&mut self, state: &[u8]) -> Result<()> {
        let mut d = Dec::new(state);
        anyhow::ensure!(d.u8()? == b'F', "snapshot does not hold Full-FT state");
        self.n_params = d.usize()?;
        self.opt = if d.bool()? {
            let n = d.usize()?;
            let mut states = Vec::new();
            for _ in 0..n {
                states.push(d.dense_adam()?);
            }
            Some(DenseAdamSet { states })
        } else {
            None
        };
        d.finish()?;
        Ok(())
    }
}

//! Adapter-reparameterized baselines: LoRA, PiSSA, DoRA, Spectral.
//!
//! All of them train a small reparameterization of each weight matrix and
//! receive *exact* gradients by chain rule from the full gradient G that
//! the train-step executable already computes:
//!
//!   LoRA / PiSSA    W_eff = W0 + s·A B        dA = s·G Bᵀ, dB = s·Aᵀ G
//!   DoRA            W_eff_j = m_j·V_j/|V_j|,  V = W0 + A B (per column j)
//!   Spectral        W_eff = W_res + U diag(σ) Vᵀ  (top-r singular triplet)
//!
//! After each optimizer step the effective weight is recomputed and written
//! back into `params`, so the L2 executable always sees W_eff.

use anyhow::Result;

use super::{Ctx, Method, Scope};
use crate::ckpt::codec::{Dec, Enc};
use crate::optim::DenseAdam;
use crate::runtime::Linalg;
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdapterKind {
    LoRa,
    PiSsa,
    DoRa,
}

struct LoraState {
    pi: usize,
    w0: Tensor,     // frozen base (PiSSA: residual)
    a: Tensor,      // (m, r)
    b: Tensor,      // (r, n)
    mag: Vec<f32>,  // DoRA column magnitudes (n)
    opt_a: DenseAdam,
    opt_b: DenseAdam,
    opt_m: Option<DenseAdam>,
}

pub struct LoRa {
    rank: usize,
    scope: Scope,
    kind: AdapterKind,
    scale: f32,
    states: Vec<LoraState>,
}

impl LoRa {
    pub fn new(rank: usize, scope: Scope, kind: AdapterKind) -> LoRa {
        LoRa {
            rank,
            scope,
            kind,
            scale: if kind == AdapterKind::LoRa { 2.0 } else { 1.0 },
            states: Vec::new(),
        }
    }
}

/// Fan independent per-state adapter steps across the pool: each worker
/// runs `step_one` on one state and returns the recomputed effective
/// weight, which the caller writes back in parameter order. Shared by
/// the LoRA family and Spectral, whose `step_all`s differ only in state
/// type and per-state math.
fn par_adapter_steps<S: Send>(
    workers: usize,
    states: &mut [S],
    params: &mut [Tensor],
    grads: &[Tensor],
    pi_of: impl Fn(&S) -> usize + Sync,
    step_one: impl Fn(&mut S, &Tensor) -> Result<Tensor> + Sync,
) -> Result<()> {
    let jobs: Vec<(&mut S, &Tensor)> = states
        .iter_mut()
        .map(|st| {
            let g = &grads[pi_of(st)];
            (st, g)
        })
        .collect();
    let effs = crate::lift::engine::par_map(workers, jobs, |_, (st, g)| {
        let pi = pi_of(st);
        step_one(st, g).map(|w| (pi, w))
    });
    for res in effs {
        let (pi, w) = res?;
        params[pi] = w;
    }
    Ok(())
}

/// Effective weight of one adapter state (free function so the pooled
/// `step_all` workers can call it without borrowing the whole method).
fn lora_effective(kind: AdapterKind, scale: f32, la: &Linalg, st: &LoraState) -> Result<Tensor> {
    let mut v = la.matmul(&st.a, &st.b)?;
    v.scale(scale);
    v.add_scaled(&st.w0, 1.0);
    if kind == AdapterKind::DoRa {
        let (m, n) = v.dims2();
        // column-normalize, then apply magnitudes
        for j in 0..n {
            let mut norm = 0.0f64;
            for i in 0..m {
                let x = v.data[i * n + j] as f64;
                norm += x * x;
            }
            let norm = norm.sqrt().max(1e-8) as f32;
            let s = st.mag[j] / norm;
            for i in 0..m {
                v.data[i * n + j] *= s;
            }
        }
    }
    Ok(v)
}

/// One adapter state's optimizer step (chain rule through the
/// reparameterization, then the Adam updates); returns the recomputed
/// effective weight for the caller to write back. Touches only `st`, so
/// states step concurrently with bit-identical results.
fn lora_step_one(
    kind: AdapterKind,
    scale: f32,
    la: &Linalg,
    st: &mut LoraState,
    g: &Tensor,
    lr: f32,
) -> Result<Tensor> {
    let (m, n) = g.dims2();
    // dL/dV: for plain LoRA/PiSSA this is just G (V = W_eff);
    // DoRA projects G through the normalize-and-scale (per column)
    let dv = if kind == AdapterKind::DoRa {
        let mut v = la.matmul(&st.a, &st.b)?;
        v.scale(scale);
        v.add_scaled(&st.w0, 1.0);
        let mut dv = Tensor::zeros(&[m, n]);
        let mut dmag = vec![0.0f32; n];
        for j in 0..n {
            let mut norm = 0.0f64;
            let mut gdotu = 0.0f64;
            for i in 0..m {
                norm += (v.data[i * n + j] as f64).powi(2);
            }
            let norm = norm.sqrt().max(1e-8);
            for i in 0..m {
                gdotu += g.data[i * n + j] as f64 * v.data[i * n + j] as f64 / norm;
            }
            dmag[j] = gdotu as f32;
            let c = st.mag[j] as f64 / norm;
            for i in 0..m {
                let u = v.data[i * n + j] as f64 / norm;
                dv.data[i * n + j] = (c * (g.data[i * n + j] as f64 - gdotu * u)) as f32;
            }
        }
        if let Some(opt_m) = st.opt_m.as_mut() {
            opt_m.step(&mut st.mag, &dmag, lr);
        }
        dv
    } else {
        g.clone()
    };
    // chain rule through ΔW = s·A B
    let mut da = la.matmul_nt(&dv, &st.b)?; // (m, r) = dV Bᵀ
    let mut db = la.matmul_tn(&st.a, &dv)?; // (r, n) = Aᵀ dV
    da.scale(scale);
    db.scale(scale);
    st.opt_a.step(&mut st.a.data, &da.data, lr);
    st.opt_b.step(&mut st.b.data, &db.data, lr);
    lora_effective(kind, scale, la, st)
}

impl Method for LoRa {
    fn name(&self) -> String {
        match self.kind {
            AdapterKind::LoRa => format!("LoRA(r={})", self.rank),
            AdapterKind::PiSsa => format!("PiSSA(r={})", self.rank),
            AdapterKind::DoRa => format!("DoRA(r={})", self.rank),
        }
    }

    fn init(&mut self, ctx: &mut Ctx, params: &[Tensor]) -> Result<()> {
        let matrices = self.scope.matrices(&ctx.preset);
        anyhow::ensure!(!matrices.is_empty(), "no matrices in scope");
        for &pi in &matrices {
            let w = &params[pi];
            let (m, n) = w.dims2();
            let r = self.rank.min(m).min(n);
            let (w0, a, b) = if self.kind == AdapterKind::PiSsa {
                // principal singular triplet init; the residual is frozen
                let (q, bb) = ctx.la.svd_lowrank(w, r + 8, 2, &mut ctx.rng)?;
                let (a, b) = crate::runtime::linalg::truncate_factors(&q, &bb, r);
                let ab = ctx.la.matmul(&a, &b)?;
                let mut w0 = w.clone();
                w0.add_scaled(&ab, -1.0);
                (w0, a, b)
            } else {
                let a = Tensor::randn(&[m, r], 1.0 / (r as f32).sqrt(), &mut ctx.rng);
                let b = Tensor::zeros(&[r, n]);
                (w.clone(), a, b)
            };
            let mag = if self.kind == AdapterKind::DoRa {
                // init magnitudes to the base column norms
                (0..n)
                    .map(|j| {
                        (0..m)
                            .map(|i| (w.data[i * n + j] as f64).powi(2))
                            .sum::<f64>()
                            .sqrt() as f32
                    })
                    .collect()
            } else {
                Vec::new()
            };
            self.states.push(LoraState {
                pi,
                opt_a: DenseAdam::new(a.len(), ctx.adam),
                opt_b: DenseAdam::new(b.len(), ctx.adam),
                opt_m: if mag.is_empty() {
                    None
                } else {
                    Some(DenseAdam::new(mag.len(), ctx.adam))
                },
                w0,
                a,
                b,
                mag,
            });
        }
        Ok(())
    }

    fn step(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        _step: usize,
        lr: f32,
    ) -> Result<()> {
        let la = ctx.la.clone();
        for st in self.states.iter_mut() {
            let pi = st.pi;
            params[pi] = lora_step_one(self.kind, self.scale, &la, st, &grads[pi], lr)?;
        }
        Ok(())
    }

    /// Adapter states are independent: each worker steps one state's
    /// (A, B, magnitudes) and returns the new effective weight; write-back
    /// happens on the caller in param order.
    fn step_all(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        _step: usize,
        lr: f32,
    ) -> Result<()> {
        let la = ctx.la.clone();
        let (kind, scale) = (self.kind, self.scale);
        par_adapter_steps(
            ctx.workers,
            &mut self.states,
            params,
            grads,
            |st| st.pi,
            |st, g| lora_step_one(kind, scale, &la, st, g, lr),
        )
    }

    fn trainable(&self) -> usize {
        self.states
            .iter()
            .map(|st| st.a.len() + st.b.len() + st.mag.len())
            .sum()
    }

    fn opt_bytes(&self) -> usize {
        self.trainable() * 8
    }

    fn state_digest(&self) -> u64 {
        let mut words: Vec<u64> = Vec::new();
        for st in &self.states {
            words.push(st.pi as u64);
            for t in [&st.a, &st.b] {
                words.extend(t.data.iter().map(|x| x.to_bits() as u64));
            }
            words.extend(st.mag.iter().map(|x| x.to_bits() as u64));
            for o in [&st.opt_a, &st.opt_b] {
                words.extend(super::adam_words(o.t, &o.m, &o.v));
            }
            if let Some(o) = &st.opt_m {
                words.extend(super::adam_words(o.t, &o.m, &o.v));
            }
        }
        super::digest_words(words)
    }

    /// Factors, frozen bases (PiSSA residuals), DoRA magnitudes, and all
    /// adapter optimizers — `init` is skipped entirely on resume, so the
    /// frozen base must be in the snapshot too.
    fn save_state(&self) -> Result<Vec<u8>> {
        let mut e = Enc::new();
        e.u8(b'A');
        e.u8(match self.kind {
            AdapterKind::LoRa => 0,
            AdapterKind::PiSsa => 1,
            AdapterKind::DoRa => 2,
        });
        e.usize(self.rank);
        e.usize(self.states.len());
        for st in &self.states {
            e.usize(st.pi);
            e.tensor(&st.w0);
            e.tensor(&st.a);
            e.tensor(&st.b);
            e.f32s(&st.mag);
            e.dense_adam(&st.opt_a);
            e.dense_adam(&st.opt_b);
            match &st.opt_m {
                Some(o) => {
                    e.bool(true);
                    e.dense_adam(o);
                }
                None => e.bool(false),
            }
        }
        Ok(e.into_bytes())
    }

    fn load_state(&mut self, state: &[u8]) -> Result<()> {
        let mut d = Dec::new(state);
        anyhow::ensure!(d.u8()? == b'A', "snapshot does not hold adapter state");
        let kind_tag = match self.kind {
            AdapterKind::LoRa => 0u8,
            AdapterKind::PiSsa => 1,
            AdapterKind::DoRa => 2,
        };
        let same_spec = d.u8()? == kind_tag && d.usize()? == self.rank;
        anyhow::ensure!(
            same_spec,
            "{}: snapshot was written under a different adapter kind/rank spec — \
             resume must reconstruct the original make_method arguments",
            self.name()
        );
        let n = d.usize()?;
        let mut states = Vec::new();
        for _ in 0..n {
            let pi = d.usize()?;
            let w0 = d.tensor()?;
            let a = d.tensor()?;
            let b = d.tensor()?;
            let mag = d.f32s()?;
            let opt_a = d.dense_adam()?;
            let opt_b = d.dense_adam()?;
            let opt_m = if d.bool()? { Some(d.dense_adam()?) } else { None };
            anyhow::ensure!(
                opt_a.m.len() == a.len() && opt_b.m.len() == b.len(),
                "adapter optimizer lengths do not match their factors"
            );
            states.push(LoraState {
                pi,
                w0,
                a,
                b,
                mag,
                opt_a,
                opt_b,
                opt_m,
            });
        }
        self.states = states;
        d.finish()?;
        Ok(())
    }
}

/// Spectral adapter: fine-tune the top-r singular triplet (U, σ, V).
pub struct Spectral {
    rank: usize,
    scope: Scope,
    states: Vec<SpectralState>,
}

struct SpectralState {
    pi: usize,
    w_res: Tensor,
    u: Tensor,      // (m, r)
    v: Tensor,      // (n, r)
    s: Vec<f32>,    // (r)
    opt_u: DenseAdam,
    opt_v: DenseAdam,
    opt_s: DenseAdam,
}

impl Spectral {
    pub fn new(rank: usize, scope: Scope) -> Spectral {
        Spectral {
            rank,
            scope,
            states: Vec::new(),
        }
    }
}

fn spectral_effective(la: &Linalg, st: &SpectralState) -> Result<Tensor> {
    let mut w = self_effective(la, &st.u, &st.v, &st.s)?; // U diag(s) Vᵀ
    w.add_scaled(&st.w_res, 1.0);
    Ok(w)
}

/// One spectral state's optimizer step; returns the new effective weight.
fn spectral_step_one(la: &Linalg, st: &mut SpectralState, g: &Tensor, lr: f32) -> Result<Tensor> {
    let (_, r) = st.u.dims2();
    // dU = G V diag(s); dV = Gᵀ U diag(s); dσ_c = u_cᵀ G v_c
    let gv = la.matmul(g, &st.v)?; // (m, r)
    let gtu = la.matmul_tn(g, &st.u)?; // (n, r)
    let mut du = gv.clone();
    let mut dv = gtu.clone();
    let (m, _) = du.dims2();
    let (n, _) = dv.dims2();
    let mut ds = vec![0.0f32; r];
    for c in 0..r {
        let mut acc = 0.0f64;
        for i in 0..m {
            acc += st.u.data[i * r + c] as f64 * gv.data[i * r + c] as f64;
        }
        ds[c] = acc as f32;
        for i in 0..m {
            du.data[i * r + c] *= st.s[c];
        }
        for j in 0..n {
            dv.data[j * r + c] *= st.s[c];
        }
    }
    st.opt_u.step(&mut st.u.data, &du.data, lr);
    st.opt_v.step(&mut st.v.data, &dv.data, lr);
    st.opt_s.step(&mut st.s, &ds, lr);
    spectral_effective(la, st)
}

impl Method for Spectral {
    fn name(&self) -> String {
        format!("Spectral(r={})", self.rank)
    }

    fn init(&mut self, ctx: &mut Ctx, params: &[Tensor]) -> Result<()> {
        for &pi in &self.scope.matrices(&ctx.preset) {
            let w = &params[pi];
            let (m, n) = w.dims2();
            let r = self.rank.min(m).min(n);
            let (q, bb) = ctx.la.svd_lowrank(w, r + 8, 2, &mut ctx.rng)?;
            let (u, b) = crate::runtime::linalg::truncate_factors(&q, &bb, r);
            // split b (r, n) into s * vᵀ with unit rows
            let mut s = vec![0.0f32; r];
            let mut v = Tensor::zeros(&[n, r]);
            for c in 0..r {
                let row = &b.data[c * n..(c + 1) * n];
                let norm = crate::util::stats::l2_norm(row).max(1e-8) as f32;
                s[c] = norm;
                for j in 0..n {
                    v.data[j * r + c] = row[j] / norm;
                }
            }
            let ab = self_effective(&ctx.la, &u, &v, &s)?;
            let mut w_res = w.clone();
            w_res.add_scaled(&ab, -1.0);
            self.states.push(SpectralState {
                pi,
                opt_u: DenseAdam::new(u.len(), ctx.adam),
                opt_v: DenseAdam::new(v.len(), ctx.adam),
                opt_s: DenseAdam::new(s.len(), ctx.adam),
                w_res,
                u,
                v,
                s,
            });
        }
        Ok(())
    }

    fn step(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        _step: usize,
        lr: f32,
    ) -> Result<()> {
        let la = ctx.la.clone();
        for st in self.states.iter_mut() {
            let pi = st.pi;
            params[pi] = spectral_step_one(&la, st, &grads[pi], lr)?;
        }
        Ok(())
    }

    /// Spectral states are independent — same fan-out as the LoRA family.
    fn step_all(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        _step: usize,
        lr: f32,
    ) -> Result<()> {
        let la = ctx.la.clone();
        par_adapter_steps(
            ctx.workers,
            &mut self.states,
            params,
            grads,
            |st| st.pi,
            |st, g| spectral_step_one(&la, st, g, lr),
        )
    }

    fn trainable(&self) -> usize {
        self.states
            .iter()
            .map(|st| st.u.len() + st.v.len() + st.s.len())
            .sum()
    }

    fn opt_bytes(&self) -> usize {
        self.trainable() * 8
    }

    fn state_digest(&self) -> u64 {
        let mut words: Vec<u64> = Vec::new();
        for st in &self.states {
            words.push(st.pi as u64);
            for t in [&st.u, &st.v] {
                words.extend(t.data.iter().map(|x| x.to_bits() as u64));
            }
            words.extend(st.s.iter().map(|x| x.to_bits() as u64));
            for o in [&st.opt_u, &st.opt_v, &st.opt_s] {
                words.extend(super::adam_words(o.t, &o.m, &o.v));
            }
        }
        super::digest_words(words)
    }

    fn save_state(&self) -> Result<Vec<u8>> {
        let mut e = Enc::new();
        e.u8(b'E');
        e.usize(self.rank);
        e.usize(self.states.len());
        for st in &self.states {
            e.usize(st.pi);
            e.tensor(&st.w_res);
            e.tensor(&st.u);
            e.tensor(&st.v);
            e.f32s(&st.s);
            e.dense_adam(&st.opt_u);
            e.dense_adam(&st.opt_v);
            e.dense_adam(&st.opt_s);
        }
        Ok(e.into_bytes())
    }

    fn load_state(&mut self, state: &[u8]) -> Result<()> {
        let mut d = Dec::new(state);
        anyhow::ensure!(d.u8()? == b'E', "snapshot does not hold spectral state");
        anyhow::ensure!(
            d.usize()? == self.rank,
            "Spectral: snapshot was written under a different rank spec — \
             resume must reconstruct the original make_method arguments"
        );
        let n = d.usize()?;
        let mut states = Vec::new();
        for _ in 0..n {
            let pi = d.usize()?;
            let w_res = d.tensor()?;
            let u = d.tensor()?;
            let v = d.tensor()?;
            let s = d.f32s()?;
            let opt_u = d.dense_adam()?;
            let opt_v = d.dense_adam()?;
            let opt_s = d.dense_adam()?;
            anyhow::ensure!(
                opt_u.m.len() == u.len() && opt_v.m.len() == v.len() && opt_s.m.len() == s.len(),
                "spectral optimizer lengths do not match their factors"
            );
            states.push(SpectralState {
                pi,
                w_res,
                u,
                v,
                s,
                opt_u,
                opt_v,
                opt_s,
            });
        }
        self.states = states;
        d.finish()?;
        Ok(())
    }
}

fn self_effective(la: &Linalg, u: &Tensor, v: &Tensor, s: &[f32]) -> Result<Tensor> {
    let (m, r) = u.dims2();
    let mut us = u.clone();
    for i in 0..m {
        for c in 0..r {
            us.data[i * r + c] *= s[c];
        }
    }
    la.matmul_nt(&us, v)
}

//! SpIEL-style sparse fine-tuning: an *evolving* index set that grows by
//! gradient magnitude and prunes by smallest accumulated update
//! (Ansell et al. 2024's grow/drop cycle, simplified to its core loop).

use anyhow::Result;

use super::{Ctx, Method, Scope};
use crate::lift::{budget_for, topk_indices};
use crate::optim::SparseAdam;
use crate::tensor::Tensor;

pub struct Spiel {
    rank: usize,
    interval: usize,
    scope: Scope,
    /// fraction of the active set replaced per grow/drop cycle
    pub churn: f32,
    /// per matrix: (param idx, opt state, weight value at selection time)
    states: Vec<(usize, SparseAdam, Vec<f32>)>,
    matrices: Vec<usize>,
}

impl Spiel {
    pub fn new(rank: usize, interval: usize, scope: Scope) -> Spiel {
        Spiel {
            rank,
            interval,
            scope,
            churn: 0.3,
            states: Vec::new(),
            matrices: Vec::new(),
        }
    }
}

impl Method for Spiel {
    fn name(&self) -> String {
        format!("SpIEL(r={})", self.rank)
    }

    fn init(&mut self, ctx: &mut Ctx, params: &[Tensor]) -> Result<()> {
        self.matrices = self.scope.matrices(&ctx.preset);
        for &pi in &self.matrices {
            let w = &params[pi];
            let (m, n) = w.dims2();
            let k = budget_for(m, n, self.rank);
            // random initial set (SpIEL starts uniform)
            let mut idx: Vec<u32> = ctx
                .rng
                .sample_indices(w.len(), k)
                .into_iter()
                .map(|i| i as u32)
                .collect();
            idx.sort_unstable();
            let snapshot = idx.iter().map(|&i| w.data[i as usize]).collect();
            self.states
                .push((pi, SparseAdam::new(idx, ctx.adam), snapshot));
        }
        Ok(())
    }

    fn step(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        step: usize,
        lr: f32,
    ) -> Result<()> {
        if step > 0 && step % self.interval == 0 {
            for (pi, st, snapshot) in self.states.iter_mut() {
                let w = &params[*pi];
                let g = &grads[*pi];
                let k = st.k();
                let n_churn = ((k as f32 * self.churn) as usize).max(1).min(k - 1);
                // drop: smallest |w_now - w_at_selection| (least useful)
                let mut order: Vec<usize> = (0..k).collect();
                order.sort_by(|&a, &b| {
                    let da = (w.data[st.idx[a] as usize] - snapshot[a]).abs();
                    let db = (w.data[st.idx[b] as usize] - snapshot[b]).abs();
                    da.partial_cmp(&db).unwrap()
                });
                let keep: std::collections::HashSet<u32> = order[n_churn..]
                    .iter()
                    .map(|&j| st.idx[j])
                    .collect();
                // grow: largest |g| outside the kept set
                let mut new_idx: Vec<u32> = keep.iter().copied().collect();
                for &cand in topk_indices(&g.data, k + n_churn).iter() {
                    if new_idx.len() >= k {
                        break;
                    }
                    if !keep.contains(&cand) {
                        new_idx.push(cand);
                    }
                }
                // pad from random if gradient top-k overlapped too much
                while new_idx.len() < k {
                    let cand = ctx.rng.below(w.len()) as u32;
                    if !new_idx.contains(&cand) {
                        new_idx.push(cand);
                    }
                }
                st.refresh(new_idx);
                *snapshot = st.idx.iter().map(|&i| w.data[i as usize]).collect();
            }
        }
        for (pi, st, _) in self.states.iter_mut() {
            st.step(&mut params[*pi].data, &grads[*pi].data, lr);
        }
        Ok(())
    }

    fn trainable(&self) -> usize {
        self.states.iter().map(|(_, st, _)| st.k()).sum()
    }

    fn opt_bytes(&self) -> usize {
        self.states.iter().map(|(_, st, _)| st.state_bytes()).sum()
    }
}

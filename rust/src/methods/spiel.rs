//! SpIEL-style sparse fine-tuning: an *evolving* index set that grows by
//! gradient magnitude and prunes by smallest accumulated update
//! (Ansell et al. 2024's grow/drop cycle, simplified to its core loop).

use anyhow::Result;

use super::{Ctx, Method, Scope};
use crate::ckpt::codec::{Dec, Enc};
use crate::lift::{budget_for, topk_indices};
use crate::optim::SparseAdam;
use crate::tensor::Tensor;

pub struct Spiel {
    rank: usize,
    interval: usize,
    scope: Scope,
    /// fraction of the active set replaced per grow/drop cycle
    pub churn: f32,
    /// per matrix: (param idx, opt state, weight value at selection time)
    states: Vec<(usize, SparseAdam, Vec<f32>)>,
    matrices: Vec<usize>,
    /// last step that ran a grow/drop cycle — makes the cycle idempotent
    /// per trainer step, so `step` and `step_all` never churn twice
    last_cycled_step: Option<usize>,
}

impl Spiel {
    pub fn new(rank: usize, interval: usize, scope: Scope) -> Spiel {
        Spiel {
            rank,
            interval,
            scope,
            churn: 0.3,
            states: Vec::new(),
            matrices: Vec::new(),
            last_cycled_step: None,
        }
    }

    /// The grow/drop cycle, run every `interval` steps. Sequential on
    /// purpose: the random padding draws from `ctx.rng`, and keeping one
    /// canonical draw order is what makes the run worker-count
    /// invariant. The per-matrix Adam steps (the hot part) are what the
    /// pool parallelizes.
    fn grow_drop(&mut self, ctx: &mut Ctx, params: &[Tensor], grads: &[Tensor], step: usize) {
        if self.last_cycled_step == Some(step) {
            return;
        }
        self.last_cycled_step = Some(step);
        if step == 0 || step % self.interval != 0 {
            return;
        }
        for (pi, st, snapshot) in self.states.iter_mut() {
            let w = &params[*pi];
            let g = &grads[*pi];
            let k = st.k();
            let n_churn = ((k as f32 * self.churn) as usize).max(1).min(k - 1);
            // drop: smallest |w_now - w_at_selection| (least useful)
            let deltas: Vec<f32> = (0..k)
                .map(|j| (w.data[st.idx[j] as usize] - snapshot[j]).abs())
                .collect();
            let order = drop_order(&deltas);
            let keep: std::collections::HashSet<u32> = order[n_churn..]
                .iter()
                .map(|&j| st.idx[j])
                .collect();
            // grow: largest |g| outside the kept set
            let mut new_idx: Vec<u32> = keep.iter().copied().collect();
            for &cand in topk_indices(&g.data, k + n_churn).iter() {
                if new_idx.len() >= k {
                    break;
                }
                if !keep.contains(&cand) {
                    new_idx.push(cand);
                }
            }
            // pad from random if gradient top-k overlapped too much
            while new_idx.len() < k {
                let cand = ctx.rng.below(w.len()) as u32;
                if !new_idx.contains(&cand) {
                    new_idx.push(cand);
                }
            }
            st.refresh(new_idx);
            *snapshot = st.idx.iter().map(|&i| w.data[i as usize]).collect();
        }
    }
}

/// Ascending drop order over accumulated-update magnitudes. A NaN delta
/// means the entry diverged since selection — the least trustworthy
/// update of all — so NaN sorts *first* (dropped before any finite
/// delta); ties break by position, keeping the cycle deterministic.
fn drop_order(deltas: &[f32]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..deltas.len()).collect();
    order.sort_by(|&a, &b| match (deltas[a].is_nan(), deltas[b].is_nan()) {
        (true, true) => a.cmp(&b),
        (true, false) => std::cmp::Ordering::Less,
        (false, true) => std::cmp::Ordering::Greater,
        (false, false) => deltas[a].total_cmp(&deltas[b]).then(a.cmp(&b)),
    });
    order
}

#[cfg(test)]
mod tests {
    use super::drop_order;

    #[test]
    fn drop_order_is_nan_first_then_ascending() {
        // regression (ISSUE 10): the old comparator panicked on a
        // diverged (NaN) accumulated update mid-churn
        let deltas = [0.5f32, f32::NAN, 0.1, f32::NAN, 2.0];
        assert_eq!(drop_order(&deltas), vec![1, 3, 2, 0, 4]);
        // finite-only ordering unchanged, ties deterministic
        assert_eq!(drop_order(&[1.0, 0.0, 1.0]), vec![1, 0, 2]);
    }
}

impl Method for Spiel {
    fn name(&self) -> String {
        format!("SpIEL(r={})", self.rank)
    }

    fn init(&mut self, ctx: &mut Ctx, params: &[Tensor]) -> Result<()> {
        self.matrices = self.scope.matrices(&ctx.preset);
        for &pi in &self.matrices {
            let w = &params[pi];
            let (m, n) = w.dims2();
            let k = budget_for(m, n, self.rank);
            // random initial set (SpIEL starts uniform)
            let mut idx: Vec<u32> = ctx
                .rng
                .sample_indices(w.len(), k)
                .into_iter()
                .map(|i| i as u32)
                .collect();
            idx.sort_unstable();
            let snapshot = idx.iter().map(|&i| w.data[i as usize]).collect();
            self.states
                .push((pi, SparseAdam::new(idx, ctx.adam), snapshot));
        }
        Ok(())
    }

    fn step(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        step: usize,
        lr: f32,
    ) -> Result<()> {
        self.grow_drop(ctx, params, grads, step);
        for (pi, st, _) in self.states.iter_mut() {
            st.step(&mut params[*pi].data, &grads[*pi].data, lr);
        }
        Ok(())
    }

    /// Same grow/drop cycle (sequential, idempotent per step), then the
    /// packed Adam steps fan across the pool.
    fn step_all(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        step: usize,
        lr: f32,
    ) -> Result<()> {
        self.grow_drop(ctx, params, grads, step);
        crate::optim::sparse::step_all_refs(
            self.states
                .iter_mut()
                .map(|(pi, st, _)| (*pi, st))
                .collect(),
            params,
            grads,
            lr,
            ctx.workers,
        );
        Ok(())
    }

    fn trainable(&self) -> usize {
        self.states.iter().map(|(_, st, _)| st.k()).sum()
    }

    fn opt_bytes(&self) -> usize {
        self.states.iter().map(|(_, st, _)| st.state_bytes()).sum()
    }

    fn state_digest(&self) -> u64 {
        let words = self.states.iter().flat_map(|(pi, st, snapshot)| {
            std::iter::once(*pi as u64)
                .chain(st.idx.iter().map(|&i| i as u64))
                .chain(super::adam_words(st.t, &st.m, &st.v))
                .chain(snapshot.iter().map(|x| x.to_bits() as u64))
        });
        super::digest_words(words)
    }

    /// Index sets + packed Adam state + the weight-at-selection snapshots
    /// the drop criterion compares against, plus the cycle guard.
    fn save_state(&self) -> Result<Vec<u8>> {
        let mut e = Enc::new();
        e.u8(b'P');
        e.usize(self.rank);
        e.usize(self.interval);
        e.usizes(&self.matrices);
        e.opt_usize(self.last_cycled_step);
        e.f32(self.churn);
        e.usize(self.states.len());
        for (pi, st, snapshot) in &self.states {
            e.usize(*pi);
            e.sparse_adam(st);
            e.f32s(snapshot);
        }
        Ok(e.into_bytes())
    }

    fn load_state(&mut self, state: &[u8]) -> Result<()> {
        let mut d = Dec::new(state);
        anyhow::ensure!(d.u8()? == b'P', "snapshot does not hold SpIEL state");
        let same_spec = d.usize()? == self.rank && d.usize()? == self.interval;
        anyhow::ensure!(
            same_spec,
            "SpIEL: snapshot was written under a different rank/interval spec — \
             resume must reconstruct the original make_method arguments"
        );
        self.matrices = d.usizes()?;
        self.last_cycled_step = d.opt_usize()?;
        self.churn = d.f32()?;
        let n = d.usize()?;
        let mut states = Vec::new();
        for _ in 0..n {
            let pi = d.usize()?;
            let st = d.sparse_adam()?;
            let snapshot = d.f32s()?;
            anyhow::ensure!(
                snapshot.len() == st.k(),
                "SpIEL snapshot length {} != mask size {}",
                snapshot.len(),
                st.k()
            );
            states.push((pi, st, snapshot));
        }
        self.states = states;
        d.finish()?;
        Ok(())
    }
}

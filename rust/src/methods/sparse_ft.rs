//! Sparse fine-tuning over a selected index set — LIFT and the sparse
//! baselines share this engine; only the `Selector` differs.
//!
//! Mask lifecycle (paper §3.2 + Algorithm 1):
//!   * masks are computed lazily on the first step (GradMag/Movement need
//!     a gradient) and refreshed every `refresh_interval` steps
//!     (`0` = fixed mask for the whole run, as in SIFT);
//!   * on refresh the packed Adam moments migrate through
//!     `SparseAdam::refresh` — surviving entries keep state.

use anyhow::Result;

use super::{Ctx, Method, Scope};
use crate::lift::{budget_for, select_indices, LiftCfg, Selector};
use crate::optim::SparseAdam;
use crate::tensor::Tensor;

pub struct SparseFt {
    label: String,
    selector: Selector,
    rank: usize,
    cfg: LiftCfg,
    /// steps between mask refreshes; 0 = never refresh
    refresh_interval: usize,
    scope: Scope,
    /// (param index, optimizer state) per trainable matrix
    states: Vec<(usize, SparseAdam)>,
    /// movement scores per trainable matrix (Selector::Movement)
    scores: Vec<Vec<f32>>,
    matrices: Vec<usize>,
    initialized: bool,
    /// mask-overlap across refreshes, for diagnostics (mean over matrices)
    pub last_refresh_overlap: f64,
}

impl SparseFt {
    pub fn new(
        label: &str,
        selector: Selector,
        rank: usize,
        cfg: LiftCfg,
        refresh_interval: usize,
        scope: Scope,
    ) -> SparseFt {
        SparseFt {
            label: label.to_string(),
            selector,
            rank,
            cfg,
            refresh_interval,
            scope,
            states: Vec::new(),
            scores: Vec::new(),
            matrices: Vec::new(),
            initialized: false,
            last_refresh_overlap: 1.0,
        }
    }

    /// Current mask (flat indices) for a given param index, if trainable.
    pub fn mask_for(&self, param_idx: usize) -> Option<&[u32]> {
        self.states
            .iter()
            .find(|(i, _)| *i == param_idx)
            .map(|(_, st)| st.idx.as_slice())
    }

    fn budget(&self, shape: &[usize]) -> usize {
        budget_for(shape[0], shape[1], self.rank)
    }

    fn compute_masks(
        &mut self,
        ctx: &mut Ctx,
        params: &[Tensor],
        grads: Option<&[Tensor]>,
    ) -> Result<Vec<Vec<u32>>> {
        let mut masks = Vec::with_capacity(self.matrices.len());
        for (mi, &pi) in self.matrices.clone().iter().enumerate() {
            let w = &params[pi];
            let k = self.budget(&w.shape);
            let g = grads.map(|gs| &gs[pi]);
            let score = self.scores.get(mi).map(|s| s.as_slice()).filter(|s| !s.is_empty());
            let idx = select_indices(
                self.selector,
                &ctx.la,
                w,
                g,
                score,
                k,
                &self.cfg,
                &mut ctx.rng,
            )?;
            masks.push(idx);
        }
        Ok(masks)
    }
}

impl Method for SparseFt {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn init(&mut self, ctx: &mut Ctx, params: &[Tensor]) -> Result<()> {
        self.matrices = self.scope.matrices(&ctx.preset);
        anyhow::ensure!(!self.matrices.is_empty(), "no trainable matrices in scope");
        if self.selector == Selector::Movement {
            self.scores = self
                .matrices
                .iter()
                .map(|&pi| vec![0.0f32; params[pi].len()])
                .collect();
        }
        // selectors that don't need gradients can build masks now;
        // GradMag/Movement wait for the first step
        if !matches!(self.selector, Selector::GradMag | Selector::Movement) {
            let masks = self.compute_masks(ctx, params, None)?;
            self.states = self
                .matrices
                .iter()
                .zip(masks)
                .map(|(&pi, idx)| (pi, SparseAdam::new(idx, ctx.adam)))
                .collect();
            self.initialized = true;
        }
        Ok(())
    }

    fn step(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        step: usize,
        lr: f32,
    ) -> Result<()> {
        // movement scores accumulate every step: S += -w * g
        if self.selector == Selector::Movement {
            for (mi, &pi) in self.matrices.iter().enumerate() {
                let (w, g) = (&params[pi], &grads[pi]);
                let s = &mut self.scores[mi];
                for i in 0..s.len() {
                    s[i] -= w.data[i] * g.data[i];
                }
            }
        }
        if !self.initialized {
            let masks = self.compute_masks(ctx, params, Some(grads))?;
            self.states = self
                .matrices
                .iter()
                .zip(masks)
                .map(|(&pi, idx)| (pi, SparseAdam::new(idx, ctx.adam)))
                .collect();
            self.initialized = true;
        } else if self.refresh_interval > 0 && step > 0 && step % self.refresh_interval == 0 {
            let masks = self.compute_masks(ctx, params, Some(grads))?;
            let mut overlap = 0.0;
            for ((_, st), idx) in self.states.iter_mut().zip(masks) {
                overlap += st.overlap(&idx);
                st.refresh(idx);
            }
            self.last_refresh_overlap = overlap / self.states.len().max(1) as f64;
            log::debug!(
                "{}: mask refresh at step {step}, overlap {:.3}",
                self.label,
                self.last_refresh_overlap
            );
        }
        for (pi, st) in self.states.iter_mut() {
            st.step(&mut params[*pi].data, &grads[*pi].data, lr);
        }
        Ok(())
    }

    fn trainable(&self) -> usize {
        self.states.iter().map(|(_, st)| st.k()).sum()
    }

    fn opt_bytes(&self) -> usize {
        self.states.iter().map(|(_, st)| st.state_bytes()).sum()
    }
}

//! Sparse fine-tuning over a selected index set — LIFT and the sparse
//! baselines share this engine; only the `Selector` differs.
//!
//! Mask lifecycle (paper §3.2 + Algorithm 1):
//!   * masks are computed lazily on the first step (GradMag/Movement need
//!     a gradient) and refreshed every `refresh_interval` steps
//!     (`0` = fixed mask for the whole run, as in SIFT);
//!   * every (re)selection is ONE batched `MaskEngine::select_all` call
//!     that fans all matrices across worker threads — the trainer drives
//!     it through `Method::refresh_all`. Masks are a pure function of
//!     the run's RNG draw and each matrix's parameter index (see the
//!     engine's determinism contract), so worker count never changes
//!     which weights train;
//!   * on refresh the packed Adam moments migrate through
//!     `optim::sparse::refresh_all` — surviving entries keep state.

use anyhow::Result;

use super::{Ctx, Method, Scope};
use crate::ckpt::codec::{Dec, Enc};
use crate::lift::engine::MaskEngine;
use crate::lift::{budget_for, LiftCfg, MaskRequest, Selector};
use crate::optim::{self, SparseAdam};
use crate::tensor::Tensor;
use crate::util::eigh::SubspaceWarm;

/// Stable snapshot discriminant for a [`Selector`] (checkpoint format —
/// reorder the enum freely, never these values).
fn selector_tag(s: Selector) -> u8 {
    match s {
        Selector::Lift => 0,
        Selector::WeightMag => 1,
        Selector::GradMag => 2,
        Selector::Movement => 3,
        Selector::Random => 4,
    }
}

/// Stable snapshot discriminant for a rank-reduction strategy.
fn strategy_tag(s: crate::lift::RankStrategy) -> u8 {
    use crate::lift::RankStrategy;
    match s {
        RankStrategy::Largest => 0,
        RankStrategy::Smallest => 1,
        RankStrategy::Random => 2,
        RankStrategy::Hybrid => 3,
    }
}

pub struct SparseFt {
    label: String,
    selector: Selector,
    rank: usize,
    cfg: LiftCfg,
    /// steps between mask refreshes; 0 = never refresh
    refresh_interval: usize,
    scope: Scope,
    /// (param index, optimizer state) per trainable matrix
    states: Vec<(usize, SparseAdam)>,
    /// movement scores per trainable matrix (Selector::Movement)
    scores: Vec<Vec<f32>>,
    /// per-matrix warm-start carriers for the exact decomposition path
    /// (`eigh::svd_topr_warm`), parallel to `matrices`. Populated only
    /// by configs that route through the exact top-r subspace
    /// iteration; checkpointed bit-exactly so crash-resume replays warm
    /// refreshes identically.
    warm: Vec<Option<SubspaceWarm>>,
    matrices: Vec<usize>,
    initialized: bool,
    /// last step that ran mask maintenance (score accumulation, init,
    /// interval refresh), so drivers that call `step` directly (without
    /// the trainer's `refresh_all`) still get periodic refreshes, and
    /// trainer-driven runs don't maintain twice per step
    last_maintained_step: Option<usize>,
    /// mask-overlap across refreshes, for diagnostics (mean over matrices)
    pub last_refresh_overlap: f64,
}

impl SparseFt {
    pub fn new(
        label: &str,
        selector: Selector,
        rank: usize,
        cfg: LiftCfg,
        refresh_interval: usize,
        scope: Scope,
    ) -> SparseFt {
        SparseFt {
            label: label.to_string(),
            selector,
            rank,
            cfg,
            refresh_interval,
            scope,
            states: Vec::new(),
            scores: Vec::new(),
            warm: Vec::new(),
            matrices: Vec::new(),
            initialized: false,
            last_maintained_step: None,
            last_refresh_overlap: 1.0,
        }
    }

    /// Current mask (flat indices) for a given param index, if trainable.
    pub fn mask_for(&self, param_idx: usize) -> Option<&[u32]> {
        self.state_for(param_idx).map(|st| st.idx.as_slice())
    }

    /// Packed optimizer state for a given param index (diagnostics and
    /// the refresh-ordering regression test).
    pub fn state_for(&self, param_idx: usize) -> Option<&SparseAdam> {
        self.states
            .iter()
            .find(|(i, _)| *i == param_idx)
            .map(|(_, st)| st)
    }

    /// Movement scores accumulate once per trainer step: S += -w * g
    /// (the caller, `maintain`, guarantees once-per-step).
    fn accumulate_scores(&mut self, params: &[Tensor], grads: &[Tensor]) {
        if self.selector != Selector::Movement {
            return;
        }
        for (mi, &pi) in self.matrices.iter().enumerate() {
            let (w, g) = (&params[pi], &grads[pi]);
            let s = &mut self.scores[mi];
            for i in 0..s.len() {
                s[i] -= w.data[i] * g.data[i];
            }
        }
    }

    /// One batched, layer-parallel selection over every matrix in scope.
    /// Each matrix's warm-start carrier seeds its exact decomposition
    /// (when the config routes through that path) and is replaced with
    /// the carrier for the next refresh — the reason this takes
    /// `&mut self`.
    fn compute_masks(
        &mut self,
        ctx: &mut Ctx,
        params: &[Tensor],
        grads: Option<&[Tensor]>,
    ) -> Result<Vec<Vec<u32>>> {
        // one sequential draw per refresh keys every per-matrix stream;
        // the masks depend on this seed and the param index only, never
        // on worker count or scheduling order
        let seed = ctx.rng.next_u64();
        let engine = MaskEngine::with_workers(ctx.la.clone(), ctx.workers);
        // the carriers move out while the requests hold shared borrows
        // of self; they are put back below even when selection errors
        let mut warm = std::mem::take(&mut self.warm);
        let reqs: Vec<MaskRequest> = self
            .matrices
            .iter()
            .enumerate()
            .map(|(mi, &pi)| MaskRequest {
                tag: pi as u64,
                w: &params[pi],
                grad: grads.map(|gs| &gs[pi]),
                score: self
                    .scores
                    .get(mi)
                    .map(|s| s.as_slice())
                    .filter(|s| !s.is_empty()),
                k: budget_for(params[pi].shape[0], params[pi].shape[1], self.rank),
            })
            .collect();
        let masks = engine.select_all_warm(self.selector, &self.cfg, &reqs, seed, &mut warm);
        drop(reqs);
        self.warm = warm;
        masks
    }

    fn init_states(
        &mut self,
        ctx: &mut Ctx,
        params: &[Tensor],
        grads: Option<&[Tensor]>,
    ) -> Result<()> {
        let masks = self.compute_masks(ctx, params, grads)?;
        self.states = self
            .matrices
            .iter()
            .zip(masks)
            .map(|(&pi, idx)| (pi, SparseAdam::new(idx, ctx.adam)))
            .collect();
        self.initialized = true;
        Ok(())
    }

    /// Per-step mask maintenance (score accumulation, lazy init, interval
    /// refresh) — idempotent per trainer step.
    fn maintain(
        &mut self,
        ctx: &mut Ctx,
        params: &[Tensor],
        grads: &[Tensor],
        step: usize,
    ) -> Result<()> {
        if self.last_maintained_step == Some(step) {
            return Ok(());
        }
        self.last_maintained_step = Some(step);
        self.accumulate_scores(params, grads);
        if !self.initialized {
            self.init_states(ctx, params, Some(grads))?;
        } else if self.refresh_interval > 0 && step > 0 && step % self.refresh_interval == 0 {
            let masks = self.compute_masks(ctx, params, Some(grads))?;
            self.last_refresh_overlap = optim::refresh_all(&mut self.states, masks);
            log::debug!(
                "{}: mask refresh at step {step}, overlap {:.3}",
                self.label,
                self.last_refresh_overlap
            );
        }
        Ok(())
    }
}

impl Method for SparseFt {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn init(&mut self, ctx: &mut Ctx, params: &[Tensor]) -> Result<()> {
        self.matrices = self.scope.matrices(&ctx.preset);
        anyhow::ensure!(!self.matrices.is_empty(), "no trainable matrices in scope");
        // one warm-carrier slot per matrix; the first refresh is cold
        self.warm = (0..self.matrices.len()).map(|_| None).collect();
        if self.selector == Selector::Movement {
            self.scores = self
                .matrices
                .iter()
                .map(|&pi| vec![0.0f32; params[pi].len()])
                .collect();
        }
        // selectors that don't need gradients can build masks now;
        // GradMag/Movement wait for the first step
        if !matches!(self.selector, Selector::GradMag | Selector::Movement) {
            self.init_states(ctx, params, None)?;
        }
        Ok(())
    }

    /// The trainer-issued batched refresh: lazy first-step selection for
    /// gradient-needing selectors, then periodic re-selection + moment
    /// migration every `refresh_interval` steps. `step` runs the same
    /// maintenance when the trainer didn't, so direct-`step` drivers keep
    /// the seed's refresh behavior; `last_maintained_step` makes the two
    /// entry points idempotent per trainer step.
    fn refresh_all(
        &mut self,
        ctx: &mut Ctx,
        params: &[Tensor],
        grads: &[Tensor],
        step: usize,
    ) -> Result<()> {
        self.maintain(ctx, params, grads, step)
    }

    fn step(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        step: usize,
        lr: f32,
    ) -> Result<()> {
        self.maintain(ctx, params, grads, step)?;
        // a driver that swallowed an earlier maintenance error must not
        // silently train nothing (maintain dedupes per step, so a failed
        // init is not retried here)
        anyhow::ensure!(
            self.initialized,
            "{}: mask selection never succeeded — no trainable indices",
            self.label
        );
        for (pi, st) in self.states.iter_mut() {
            st.step(&mut params[*pi].data, &grads[*pi].data, lr);
        }
        Ok(())
    }

    /// Layer-parallel batched step: same maintenance (idempotent per
    /// trainer step, so trainer-driven `refresh_all` + `step_all` never
    /// maintains twice), then every matrix's packed Adam step fans
    /// across the worker pool — bit-identical to sequential `step`.
    fn step_all(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        step: usize,
        lr: f32,
    ) -> Result<()> {
        self.maintain(ctx, params, grads, step)?;
        anyhow::ensure!(
            self.initialized,
            "{}: mask selection never succeeded — no trainable indices",
            self.label
        );
        optim::sparse::step_all(&mut self.states, params, grads, lr, ctx.workers);
        Ok(())
    }

    fn trainable(&self) -> usize {
        self.states.iter().map(|(_, st)| st.k()).sum()
    }

    fn opt_bytes(&self) -> usize {
        self.states.iter().map(|(_, st)| st.state_bytes()).sum()
    }

    fn state_digest(&self) -> u64 {
        let words = self
            .states
            .iter()
            .flat_map(|(pi, st)| {
                std::iter::once(*pi as u64)
                    .chain(st.idx.iter().map(|&i| i as u64))
                    .chain(super::adam_words(st.t, &st.m, &st.v))
            })
            .chain(self.warm.iter().flat_map(|w| match w {
                // carriers are part of the replayable state: the
                // determinism and crash-resume suites must catch a
                // carrier that diverges even when this step's masks
                // happen to agree
                Some(c) => std::iter::once(1u64)
                    .chain([c.p as u64, c.n as u64])
                    .chain(c.xt.iter().map(|x| x.to_bits()))
                    .collect::<Vec<u64>>(),
                None => vec![0u64],
            }));
        super::digest_words(words)
    }

    /// Masks + packed Adam state + Movement scores + the maintenance
    /// guards — everything a resumed run needs to replay refresh
    /// scheduling and step bit-exactly. The construction spec is
    /// embedded first so `load_state` can refuse a snapshot written
    /// under different `make_method` arguments (which would otherwise
    /// resume silently as a hybrid run).
    fn save_state(&self) -> Result<Vec<u8>> {
        let mut e = Enc::new();
        e.u8(b'S');
        e.u8(selector_tag(self.selector));
        e.u8(strategy_tag(self.cfg.strategy));
        e.bool(self.cfg.exact);
        e.usize(self.cfg.rank);
        e.usize(self.cfg.power_iters);
        e.usize(self.cfg.oversample);
        e.usize(self.cfg.block);
        e.usize(self.rank);
        e.usize(self.refresh_interval);
        e.usizes(&self.matrices);
        e.bool(self.initialized);
        e.opt_usize(self.last_maintained_step);
        e.f64(self.last_refresh_overlap);
        e.usize(self.states.len());
        for (pi, st) in &self.states {
            e.usize(*pi);
            e.sparse_adam(st);
        }
        e.usize(self.scores.len());
        for s in &self.scores {
            e.f32s(s);
        }
        // warm-start carriers, bit-exact (f64): a resumed run's next
        // refresh must seed from the same block the straight run would
        e.usize(self.warm.len());
        for w in &self.warm {
            match w {
                Some(c) => {
                    e.bool(true);
                    e.usize(c.p);
                    e.usize(c.n);
                    e.f64s(&c.xt);
                }
                None => e.bool(false),
            }
        }
        Ok(e.into_bytes())
    }

    fn load_state(&mut self, state: &[u8]) -> Result<()> {
        let mut d = Dec::new(state);
        anyhow::ensure!(
            d.u8()? == b'S',
            "{}: snapshot does not hold sparse-FT state",
            self.label
        );
        let same_spec = d.u8()? == selector_tag(self.selector)
            && d.u8()? == strategy_tag(self.cfg.strategy)
            && d.bool()? == self.cfg.exact
            && d.usize()? == self.cfg.rank
            && d.usize()? == self.cfg.power_iters
            && d.usize()? == self.cfg.oversample
            && d.usize()? == self.cfg.block
            && d.usize()? == self.rank
            && d.usize()? == self.refresh_interval;
        anyhow::ensure!(
            same_spec,
            "{}: snapshot was written under a different method spec \
             (selector / rank / refresh interval / LRA config) — resume must \
             reconstruct the original make_method arguments",
            self.label
        );
        self.matrices = d.usizes()?;
        self.initialized = d.bool()?;
        self.last_maintained_step = d.opt_usize()?;
        self.last_refresh_overlap = d.f64()?;
        let n = d.usize()?;
        let mut states = Vec::new();
        for _ in 0..n {
            let pi = d.usize()?;
            states.push((pi, d.sparse_adam()?));
        }
        self.states = states;
        let ns = d.usize()?;
        let mut scores = Vec::new();
        for _ in 0..ns {
            scores.push(d.f32s()?);
        }
        self.scores = scores;
        let nw = d.usize()?;
        let mut warm = Vec::new();
        for _ in 0..nw {
            warm.push(if d.bool()? {
                let p = d.usize()?;
                let n = d.usize()?;
                let xt = d.f64s()?;
                anyhow::ensure!(
                    xt.len() == p * n,
                    "{}: warm carrier block is {} values for a {p}x{n} shape",
                    self.label,
                    xt.len()
                );
                Some(SubspaceWarm { p, n, xt })
            } else {
                None
            });
        }
        self.warm = warm;
        d.finish()?;
        anyhow::ensure!(
            !self.initialized || self.states.len() == self.matrices.len(),
            "{}: snapshot holds {} optimizer states for {} matrices",
            self.label,
            self.states.len(),
            self.matrices.len()
        );
        anyhow::ensure!(
            self.warm.len() == self.matrices.len(),
            "{}: snapshot holds {} warm carriers for {} matrices",
            self.label,
            self.warm.len(),
            self.matrices.len()
        );
        Ok(())
    }
}

//! Fine-tuning method zoo: LIFT + every baseline the paper compares.
//!
//! A `Method` consumes full gradients from the train-step executable and
//! owns how parameters move: dense AdamW (Full FT), masked sparse AdamW
//! (LIFT and the sparse baselines), or adapter reparameterizations whose
//! gradients are exact projections of the full gradient (LoRA / PiSSA /
//! DoRA / Spectral — chain rule through W_eff; see adapters.rs).

pub mod adapters;
pub mod full;
pub mod s2ft;
pub mod sparse_ft;
pub mod spiel;

use std::sync::Arc;

use anyhow::Result;

use crate::lift::{LiftCfg, Selector};
use crate::optim::AdamCfg;
use crate::runtime::manifest::PresetInfo;
use crate::runtime::Linalg;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Shared context handed to every method call. `la` is an `Arc` so the
/// layer-parallel mask engine can share the linalg toolkit (and its
/// compile caches) across its worker threads.
pub struct Ctx {
    pub la: Arc<Linalg>,
    pub preset: PresetInfo,
    pub rng: Rng,
    pub adam: AdamCfg,
    /// Worker threads for every batched per-matrix stage — mask
    /// selection, exact decompositions, and the batched optimizer step
    /// (`lift::engine::par_map`); 1 forces the sequential path. Results
    /// are bit-identical for any value (the engine's determinism
    /// contract).
    pub workers: usize,
}

pub trait Method {
    fn name(&self) -> String;
    /// Called once before training with the initial parameters.
    fn init(&mut self, ctx: &mut Ctx, params: &[Tensor]) -> Result<()>;
    /// Batched mask maintenance, issued by the trainer once per step
    /// *before* `step` (so selectors that need gradients see this step's
    /// grads). Sparse methods recompute/migrate every matrix's mask in
    /// one layer-parallel engine call; dense/adapter methods keep the
    /// default no-op.
    fn refresh_all(
        &mut self,
        _ctx: &mut Ctx,
        _params: &[Tensor],
        _grads: &[Tensor],
        _step: usize,
    ) -> Result<()> {
        Ok(())
    }
    /// One optimizer step given full grads (param order = manifest).
    fn step(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        step: usize,
        lr: f32,
    ) -> Result<()>;
    /// Batched optimizer step, issued by the trainer once per step
    /// *after* `refresh_all` (a mask swap must migrate Adam moments
    /// before the step reads them — see `train::train`). Methods with
    /// independent per-matrix updates fan them across `ctx.workers`
    /// threads via `lift::engine::par_map`; results are bit-identical to
    /// the sequential `step` for any worker count. The default delegates
    /// to `step`, so direct `step()` callers and methods without a
    /// batched path keep the old semantics.
    fn step_all(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        step: usize,
        lr: f32,
    ) -> Result<()> {
        self.step(ctx, params, grads, step, lr)
    }
    /// Number of trainable parameters (the rank-budget accounting).
    fn trainable(&self) -> usize;
    /// Optimizer-state bytes (Fig. 6 metric).
    fn opt_bytes(&self) -> usize;
    /// Deterministic digest of the method's internal state — optimizer
    /// moments, masks/factors, timesteps. The cross-worker determinism
    /// suite (`rust/tests/engine.rs`) uses it to prove 1-worker and
    /// N-worker runs agree bit-for-bit beyond the visible parameters.
    /// Methods without internal state keep the default.
    fn state_digest(&self) -> u64 {
        0
    }
    /// Serialize the method's complete training state — optimizer
    /// moments and timesteps, masks, adapter factors and frozen bases,
    /// accumulated scores, lazy-init and last-maintained-step guards —
    /// as one opaque payload for the versioned snapshot (`crate::ckpt`).
    /// Paired with [`Method::load_state`]; the crash-resume suite
    /// (`rust/tests/ckpt.rs`) asserts save → load → continue matches an
    /// uninterrupted run bit-for-bit on weights *and* `state_digest`.
    fn save_state(&self) -> Result<Vec<u8>> {
        anyhow::bail!("{}: checkpoint save not implemented", self.name())
    }
    /// Restore state captured by [`Method::save_state`] into a
    /// freshly-constructed method (same `make_method` arguments, `init`
    /// NOT called — load replaces it). Implementations must leave the
    /// method exactly as the saving instance was, including refresh
    /// scheduling guards, so a resumed run replays `refresh_all`
    /// decisions on the original step boundaries.
    fn load_state(&mut self, _state: &[u8]) -> Result<()> {
        anyhow::bail!("{}: checkpoint load not implemented", self.name())
    }
}

/// Order-sensitive 64-bit FNV-1a over words — the shared implementation
/// behind the `Method::state_digest` impls. f32 state is hashed via
/// `to_bits`, so the digest distinguishes values `==` would conflate
/// (-0.0 vs 0.0) and never conflates values bit-compare would split.
pub fn digest_words<I: IntoIterator<Item = u64>>(words: I) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Digest words for a packed Adam state (timestep + both moment vectors).
fn adam_words<'a>(t: usize, m: &'a [f32], v: &'a [f32]) -> impl Iterator<Item = u64> + 'a {
    std::iter::once(t as u64)
        .chain(m.iter().map(|x| x.to_bits() as u64))
        .chain(v.iter().map(|x| x.to_bits() as u64))
}

/// Which matrices a method may touch.
#[derive(Clone, Debug, Default)]
pub struct Scope {
    pub mlp_only: bool,
    /// restrict to one layer-type kind, e.g. "wq" (Fig. 11)
    pub kind: Option<String>,
}

impl Scope {
    pub fn matrices(&self, preset: &PresetInfo) -> Vec<usize> {
        match &self.kind {
            Some(k) => crate::model::matrices_of_kind(preset, k),
            None => crate::model::trainable_matrices(preset, self.mlp_only),
        }
    }
}

/// Build a method by name with a LoRA-rank-equivalent budget.
/// Names: full, lift, lift_mlp, lift_structured, weight_mag, grad_mag,
/// movement, random, sift, spiel, lora, pissa, dora, spectral, s2ft.
pub fn make_method(
    name: &str,
    rank: usize,
    lift_cfg: LiftCfg,
    refresh_interval: usize,
    scope: Scope,
) -> Result<Box<dyn Method>> {
    use sparse_ft::SparseFt;
    let m: Box<dyn Method> = match name {
        "full" => Box::new(full::FullFt::new()),
        "lift" => Box::new(SparseFt::new(
            "LIFT",
            Selector::Lift,
            rank,
            lift_cfg,
            refresh_interval,
            scope,
        )),
        "lift_mlp" => Box::new(SparseFt::new(
            "LIFT_MLP",
            Selector::Lift,
            rank,
            lift_cfg,
            refresh_interval,
            Scope {
                mlp_only: true,
                kind: None,
            },
        )),
        "lift_structured" => Box::new(SparseFt::new(
            "LIFT_Structured",
            Selector::Lift,
            rank,
            LiftCfg {
                block: 4,
                ..lift_cfg
            },
            refresh_interval,
            scope,
        )),
        "weight_mag" => Box::new(SparseFt::new(
            "WeightMag",
            Selector::WeightMag,
            rank,
            lift_cfg,
            refresh_interval,
            scope,
        )),
        "grad_mag" => Box::new(SparseFt::new(
            "GradMag",
            Selector::GradMag,
            rank,
            lift_cfg,
            refresh_interval,
            scope,
        )),
        "movement" => Box::new(SparseFt::new(
            "Movement",
            Selector::Movement,
            rank,
            lift_cfg,
            refresh_interval,
            scope,
        )),
        "random" => Box::new(SparseFt::new(
            "Random",
            Selector::Random,
            rank,
            lift_cfg,
            refresh_interval,
            scope,
        )),
        // SIFT: gradient-selected mask, fixed for the whole run
        "sift" => Box::new(SparseFt::new(
            "SIFT", Selector::GradMag, rank, lift_cfg, 0, scope,
        )),
        "spiel" => Box::new(spiel::Spiel::new(rank, refresh_interval.max(1), scope)),
        "lora" => Box::new(adapters::LoRa::new(rank, scope, adapters::AdapterKind::LoRa)),
        "pissa" => Box::new(adapters::LoRa::new(rank, scope, adapters::AdapterKind::PiSsa)),
        "dora" => Box::new(adapters::LoRa::new(rank, scope, adapters::AdapterKind::DoRa)),
        "spectral" => Box::new(adapters::Spectral::new(rank, scope)),
        "s2ft" => Box::new(s2ft::S2Ft::new(rank, scope)),
        other => anyhow::bail!("unknown method '{other}'"),
    };
    Ok(m)
}

/// All method names used across the paper's tables.
pub const PEFT_BASELINES: [&str; 5] = ["full", "lora", "dora", "pissa", "s2ft"];
pub const SPARSE_BASELINES: [&str; 5] = ["weight_mag", "grad_mag", "movement", "random", "sift"];

//! S2FT-style structured sparse fine-tuning: whole output *columns* of
//! each projection matrix are trainable (budget-matched to LoRA rank),
//! selected by column gradient energy on the first step.

use anyhow::Result;

use super::{Ctx, Method, Scope};
use crate::ckpt::codec::{Dec, Enc};
use crate::optim::DenseAdam;
use crate::tensor::Tensor;

pub struct S2Ft {
    rank: usize,
    scope: Scope,
    /// (param index, selected columns, optimizer over the packed columns)
    states: Vec<(usize, Vec<usize>, DenseAdam)>,
    matrices: Vec<usize>,
    initialized: bool,
}

impl S2Ft {
    pub fn new(rank: usize, scope: Scope) -> S2Ft {
        S2Ft {
            rank,
            scope,
            states: Vec::new(),
            matrices: Vec::new(),
            initialized: false,
        }
    }

    /// First-step column selection by gradient energy (deterministic, so
    /// it stays sequential; budget = r(m+n) params).
    fn ensure_selected(&mut self, ctx: &mut Ctx, grads: &[Tensor]) {
        if self.initialized {
            return;
        }
        for &pi in &self.matrices {
            let g = &grads[pi];
            let (m, n) = g.dims2();
            let budget = crate::lift::budget_for(m, n, self.rank);
            let n_cols = (budget / m).clamp(1, n);
            let mut energy = vec![0.0f32; n];
            for i in 0..m {
                for j in 0..n {
                    energy[j] += g.data[i * n + j] * g.data[i * n + j];
                }
            }
            let cols: Vec<usize> = crate::lift::topk_indices(&energy, n_cols)
                .into_iter()
                .map(|c| c as usize)
                .collect();
            let opt = DenseAdam::new(cols.len() * m, ctx.adam);
            self.states.push((pi, cols, opt));
        }
        self.initialized = true;
    }
}

/// One matrix's packed-column Adam step (shared by `step` / `step_all`).
fn s2ft_step_one(cols: &[usize], opt: &mut DenseAdam, p: &mut Tensor, g: &Tensor, lr: f32) {
    let (m, n) = p.dims2();
    // pack selected columns
    let mut wpack = Vec::with_capacity(cols.len() * m);
    let mut gpack = Vec::with_capacity(cols.len() * m);
    for &j in cols.iter() {
        for i in 0..m {
            wpack.push(p.data[i * n + j]);
            gpack.push(g.data[i * n + j]);
        }
    }
    opt.step(&mut wpack, &gpack, lr);
    for (cidx, &j) in cols.iter().enumerate() {
        for i in 0..m {
            p.data[i * n + j] = wpack[cidx * m + i];
        }
    }
}

impl Method for S2Ft {
    fn name(&self) -> String {
        format!("S2FT(r={})", self.rank)
    }

    fn init(&mut self, ctx: &mut Ctx, _params: &[Tensor]) -> Result<()> {
        self.matrices = self.scope.matrices(&ctx.preset);
        anyhow::ensure!(!self.matrices.is_empty(), "no matrices in scope");
        Ok(())
    }

    fn step(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        _step: usize,
        lr: f32,
    ) -> Result<()> {
        self.ensure_selected(ctx, grads);
        for (pi, cols, opt) in self.states.iter_mut() {
            s2ft_step_one(cols, opt, &mut params[*pi], &grads[*pi], lr);
        }
        Ok(())
    }

    /// Column packs touch disjoint matrices — fan across the pool.
    fn step_all(
        &mut self,
        ctx: &mut Ctx,
        params: &mut [Tensor],
        grads: &[Tensor],
        _step: usize,
        lr: f32,
    ) -> Result<()> {
        self.ensure_selected(ctx, grads);
        crate::lift::engine::par_over_params(
            self.states
                .iter_mut()
                .map(|(pi, cols, opt)| (*pi, (cols.as_slice(), opt)))
                .collect(),
            params,
            grads,
            ctx.workers,
            |(cols, opt), p, g| s2ft_step_one(cols, opt, p, g, lr),
        );
        Ok(())
    }

    fn trainable(&self) -> usize {
        self.states
            .iter()
            .map(|(_, cols, opt)| {
                debug_assert_eq!(opt.m.len() % cols.len().max(1), 0);
                opt.m.len()
            })
            .sum()
    }

    fn opt_bytes(&self) -> usize {
        self.states.iter().map(|(_, _, o)| o.state_bytes()).sum()
    }

    fn state_digest(&self) -> u64 {
        let words = self.states.iter().flat_map(|(pi, cols, opt)| {
            std::iter::once(*pi as u64)
                .chain(cols.iter().map(|&c| c as u64))
                .chain(super::adam_words(opt.t, &opt.m, &opt.v))
        });
        super::digest_words(words)
    }

    fn save_state(&self) -> Result<Vec<u8>> {
        let mut e = Enc::new();
        e.u8(b'2');
        e.usize(self.rank);
        e.usizes(&self.matrices);
        e.bool(self.initialized);
        e.usize(self.states.len());
        for (pi, cols, opt) in &self.states {
            e.usize(*pi);
            e.usizes(cols);
            e.dense_adam(opt);
        }
        Ok(e.into_bytes())
    }

    fn load_state(&mut self, state: &[u8]) -> Result<()> {
        let mut d = Dec::new(state);
        anyhow::ensure!(d.u8()? == b'2', "snapshot does not hold S2FT state");
        anyhow::ensure!(
            d.usize()? == self.rank,
            "S2FT: snapshot was written under a different rank spec — \
             resume must reconstruct the original make_method arguments"
        );
        self.matrices = d.usizes()?;
        self.initialized = d.bool()?;
        let n = d.usize()?;
        let mut states = Vec::new();
        for _ in 0..n {
            let pi = d.usize()?;
            let cols = d.usizes()?;
            let opt = d.dense_adam()?;
            anyhow::ensure!(
                cols.is_empty() || opt.m.len() % cols.len() == 0,
                "S2FT optimizer length {} is not a multiple of {} columns",
                opt.m.len(),
                cols.len()
            );
            states.push((pi, cols, opt));
        }
        self.states = states;
        d.finish()?;
        Ok(())
    }
}

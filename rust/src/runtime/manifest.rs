//! artifacts/manifest.json — the python<->rust interchange contract.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Layer-type tag ("embed", "attn_norm", "wq", ..., "final_norm").
    pub fn kind(&self) -> &str {
        match self.name.rsplit_once('.') {
            Some((_, k)) => k,
            None => &self.name,
        }
    }

    /// Layer index, if per-layer ("l3.wq" -> 3).
    pub fn layer(&self) -> Option<usize> {
        let (pre, _) = self.name.split_once('.')?;
        pre.strip_prefix('l')?.parse().ok()
    }

    /// 2-D projection matrices are the trainable set for PEFT methods.
    pub fn is_matrix(&self) -> bool {
        self.shape.len() == 2 && self.kind().starts_with('w')
    }

    /// True for MLP-module matrices (LIFT_MLP, Fig. 11 component study).
    pub fn is_mlp(&self) -> bool {
        matches!(self.kind(), "wgate" | "wup" | "wdown")
    }
}

#[derive(Clone, Debug)]
pub struct PresetInfo {
    pub name: String,
    pub d: usize,
    pub layers: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub heads: usize,
    pub params: Vec<ParamInfo>,
    pub executables: BTreeMap<String, String>,
}

impl PresetInfo {
    pub fn n_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub presets: BTreeMap<String, PresetInfo>,
    pub kernels: BTreeMap<String, String>,
    pub adam_buckets: Vec<usize>,
    pub oversample: usize,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let mut presets = BTreeMap::new();
        for (name, pj) in j.get("presets").and_then(|p| p.as_obj()).context("presets")? {
            let get = |k: &str| -> Result<usize> {
                pj.get(k)
                    .and_then(|x| x.as_usize())
                    .with_context(|| format!("preset {name}: field {k}"))
            };
            let mut params = Vec::new();
            for pe in pj.get("params").and_then(|x| x.as_arr()).context("params")? {
                let pname = pe.get("name").and_then(|x| x.as_str()).context("param name")?;
                let shape: Vec<usize> = pe
                    .get("shape")
                    .and_then(|x| x.as_arr())
                    .context("param shape")?
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect();
                params.push(ParamInfo {
                    name: pname.to_string(),
                    shape,
                });
            }
            let mut executables = BTreeMap::new();
            if let Some(ex) = pj.get("executables").and_then(|x| x.as_obj()) {
                for (k, v) in ex {
                    if let Some(s) = v.as_str() {
                        executables.insert(k.clone(), s.to_string());
                    }
                }
            }
            presets.insert(
                name.clone(),
                PresetInfo {
                    name: name.clone(),
                    d: get("d")?,
                    layers: get("layers")?,
                    ffn: get("ffn")?,
                    vocab: get("vocab")?,
                    seq: get("seq")?,
                    batch: get("batch")?,
                    heads: get("heads")?,
                    params,
                    executables,
                },
            );
        }
        let mut kernels = BTreeMap::new();
        if let Some(ks) = j.get("kernels").and_then(|x| x.as_obj()) {
            for (k, v) in ks {
                if let Some(s) = v.as_str() {
                    kernels.insert(k.clone(), s.to_string());
                }
            }
        }
        let adam_buckets = j
            .get("adam_buckets")
            .and_then(|x| x.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default();
        let oversample = j.get("oversample").and_then(|x| x.as_usize()).unwrap_or(8);
        Ok(Manifest {
            presets,
            kernels,
            adam_buckets,
            oversample,
        })
    }

    pub fn preset(&self, name: &str) -> Result<&PresetInfo> {
        self.presets
            .get(name)
            .with_context(|| format!("preset '{name}' not in manifest (have: {:?}) — for 'e2e' run `make artifacts-e2e`", self.presets.keys().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "adam_buckets": [4096],
      "oversample": 8,
      "kernels": {"svd_128x128_r40": "svd_128x128_r40.hlo.txt"},
      "presets": {"tiny": {
        "d": 128, "layers": 2, "ffn": 352, "vocab": 512, "seq": 64,
        "batch": 16, "heads": 2,
        "params": [
          {"name": "embed", "shape": [512, 128]},
          {"name": "l0.attn_norm", "shape": [128]},
          {"name": "l0.wq", "shape": [128, 128]},
          {"name": "l1.wdown", "shape": [352, 128]},
          {"name": "final_norm", "shape": [128]}
        ],
        "executables": {"train_step": "tiny.train_step.hlo.txt"}
      }}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let p = m.preset("tiny").unwrap();
        assert_eq!(p.d, 128);
        assert_eq!(p.params.len(), 5);
        assert_eq!(p.params[0].numel(), 512 * 128);
        assert_eq!(p.executables["train_step"], "tiny.train_step.hlo.txt");
        assert!(m.preset("nope").is_err());
    }

    #[test]
    fn param_kinds() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let p = m.preset("tiny").unwrap();
        assert_eq!(p.params[0].kind(), "embed");
        assert!(!p.params[0].is_matrix());
        assert_eq!(p.params[2].kind(), "wq");
        assert!(p.params[2].is_matrix());
        assert!(!p.params[2].is_mlp());
        assert_eq!(p.params[3].layer(), Some(1));
        assert!(p.params[3].is_mlp());
        assert_eq!(p.params[1].kind(), "attn_norm");
        assert!(!p.params[1].is_matrix());
    }
}

//! Preset-bound model executables: train_step / eval_step / logits_probe.
//!
//! Owns the compiled artifacts for one preset and the literal marshalling
//! for each call. Parameter order is exactly `manifest.presets[p].params`.

use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::literal::*;
use super::manifest::PresetInfo;
use super::Runtime;
use crate::tensor::Tensor;

/// One training/eval batch in host form.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,    // (B*S)
    pub targets: Vec<i32>,   // (B*S) next-token ids
    pub loss_mask: Vec<f32>, // (B*S) 1.0 where the loss counts
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    pub fn empty(batch: usize, seq: usize) -> Batch {
        Batch {
            tokens: vec![0; batch * seq],
            targets: vec![0; batch * seq],
            loss_mask: vec![0.0; batch * seq],
            batch,
            seq,
        }
    }
}

pub struct ModelExec {
    pub preset: PresetInfo,
    train: Arc<xla::PjRtLoadedExecutable>,
    eval: Arc<xla::PjRtLoadedExecutable>,
    probe: Mutex<Option<Arc<xla::PjRtLoadedExecutable>>>,
}

impl ModelExec {
    pub fn load(rt: &Runtime, preset_name: &str) -> Result<ModelExec> {
        let preset = rt.manifest.preset(preset_name)?.clone();
        let train = rt.load_artifact(
            preset
                .executables
                .get("train_step")
                .context("manifest missing train_step")?,
        )?;
        let eval = rt.load_artifact(
            preset
                .executables
                .get("eval_step")
                .context("manifest missing eval_step")?,
        )?;
        Ok(ModelExec {
            preset,
            train,
            eval,
            probe: Mutex::new(None),
        })
    }

    fn check_params(&self, params: &[Tensor]) -> Result<()> {
        anyhow::ensure!(
            params.len() == self.preset.params.len(),
            "param count {} != manifest {}",
            params.len(),
            self.preset.params.len()
        );
        for (t, info) in params.iter().zip(&self.preset.params) {
            anyhow::ensure!(
                t.shape == info.shape,
                "param {} shape {:?} != manifest {:?}",
                info.name,
                t.shape,
                info.shape
            );
        }
        Ok(())
    }

    fn marshal(&self, params: &[Tensor], batch: &Batch) -> Result<Vec<xla::Literal>> {
        self.check_params(params)?;
        anyhow::ensure!(
            batch.batch == self.preset.batch && batch.seq == self.preset.seq,
            "batch shape ({}, {}) != preset ({}, {})",
            batch.batch,
            batch.seq,
            self.preset.batch,
            self.preset.seq
        );
        let mut args = Vec::with_capacity(params.len() + 3);
        for t in params {
            args.push(tensor_to_literal(t)?);
        }
        args.push(i32_matrix_to_literal(batch.batch, batch.seq, &batch.tokens)?);
        args.push(i32_matrix_to_literal(batch.batch, batch.seq, &batch.targets)?);
        let mask = Tensor::from_vec(&[batch.batch, batch.seq], batch.loss_mask.clone());
        args.push(tensor_to_literal(&mask)?);
        Ok(args)
    }

    /// Forward+backward: returns (loss, grads) with grads in param order.
    pub fn train_step(&self, params: &[Tensor], batch: &Batch) -> Result<(f32, Vec<Tensor>)> {
        let args = self.marshal(params, batch)?;
        let rt_out = self.train.execute::<xla::Literal>(&args)?;
        let mut lit = rt_out[0][0].to_literal_sync()?;
        let parts = lit.decompose_tuple()?;
        anyhow::ensure!(
            parts.len() == 1 + params.len(),
            "train_step returned {} outputs, expected {}",
            parts.len(),
            1 + params.len()
        );
        let loss = literal_scalar_f32(&parts[0])?;
        let grads = parts[1..]
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()?;
        Ok((loss, grads))
    }

    /// Eval: returns (loss, greedy predictions (B*S)).
    pub fn eval_step(&self, params: &[Tensor], batch: &Batch) -> Result<(f32, Vec<i32>)> {
        let args = self.marshal(params, batch)?;
        let rt_out = self.eval.execute::<xla::Literal>(&args)?;
        let mut lit = rt_out[0][0].to_literal_sync()?;
        let parts = lit.decompose_tuple()?;
        anyhow::ensure!(parts.len() == 2, "eval_step returned {} outputs", parts.len());
        let loss = literal_scalar_f32(&parts[0])?;
        let preds = literal_to_vec_i32(&parts[1])?;
        Ok((loss, preds))
    }

    /// Next-token distribution at `pos` for a single prompt row (Fig 2b).
    pub fn probe(&self, rt: &Runtime, params: &[Tensor], tokens: &[i32], pos: usize) -> Result<Vec<f32>> {
        let exe = {
            let mut probe = self.probe.lock().expect("probe lock poisoned");
            if probe.is_none() {
                *probe = Some(rt.load_artifact(
                    self.preset
                        .executables
                        .get("logits_probe")
                        .context("manifest missing logits_probe")?,
                )?);
            }
            probe.as_ref().unwrap().clone()
        };
        self.check_params(params)?;
        anyhow::ensure!(tokens.len() == self.preset.seq, "probe prompt must be seq-padded");
        let mut args = Vec::with_capacity(params.len() + 2);
        for t in params {
            args.push(tensor_to_literal(t)?);
        }
        args.push(i32_matrix_to_literal(1, self.preset.seq, tokens)?);
        args.push(scalar_i32(pos as i32));
        let rt_out = exe.execute::<xla::Literal>(&args)?;
        let mut lit = rt_out[0][0].to_literal_sync()?;
        let parts = lit.decompose_tuple()?;
        literal_to_vec_f32(&parts[0])
    }
}

//! PJRT runtime: load AOT artifacts, compile rust-built graphs, execute.
//!
//! The pattern follows /opt/xla-example/load_hlo: HLO *text* in,
//! `HloModuleProto::from_text_file` -> `XlaComputation` -> `client.compile`
//! -> `execute`. Python is never on this path — artifacts were produced
//! once by `make artifacts`; everything else (the linalg toolkit) is built
//! in-process with `XlaBuilder`.

pub mod linalg;
pub mod literal;
pub mod manifest;
pub mod model_exec;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

pub use linalg::Linalg;
pub use manifest::Manifest;

/// Shared PJRT CPU client + executable caches.
pub struct Runtime {
    pub client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    /// artifact-name -> compiled executable
    artifact_cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        // silence the TfrtCpuClient banner unless TF logging is configured
        if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json")).with_context(
            || format!("loading manifest from {artifacts_dir:?} — run `make artifacts`"),
        )?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            artifact_cache: RefCell::new(HashMap::new()),
            manifest,
        })
    }

    /// Locate the artifacts dir: $LIFT_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("LIFT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn from_default() -> Result<Runtime> {
        Runtime::new(&Self::default_dir())
    }

    /// Load + compile an artifact HLO file (cached).
    pub fn load_artifact(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.artifact_cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.artifacts_dir.join(file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {file}"))?,
        );
        log::debug!("compiled artifact {file} in {:.2}s", t0.elapsed().as_secs_f64());
        self.artifact_cache
            .borrow_mut()
            .insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an executable whose root is a tuple; returns the flattened
    /// tuple elements as host literals.
    pub fn run_tuple(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<xla::Literal>(args)?;
        let mut lit = out[0][0].to_literal_sync()?;
        Ok(lit.decompose_tuple()?)
    }

    /// Execute with a single (non-tuple) output.
    pub fn run_one(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<xla::Literal> {
        let out = exe.execute::<xla::Literal>(args)?;
        Ok(out[0][0].to_literal_sync()?)
    }
}

//! PJRT runtime: load AOT artifacts, compile rust-built graphs, execute.
//!
//! The pattern follows /opt/xla-example/load_hlo: HLO *text* in,
//! `HloModuleProto::from_text_file` -> `XlaComputation` -> `client.compile`
//! -> `execute`. Python is never on this path — artifacts were produced
//! once by `make artifacts`; everything else (the linalg toolkit) is built
//! in-process with `XlaBuilder`.

pub mod cache;
pub mod linalg;
pub mod literal;
pub mod manifest;
pub mod model_exec;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

pub use cache::ShardedCache;
pub use linalg::Linalg;
pub use manifest::Manifest;

/// Artifact availability for surface-level callers (integration tests,
/// bench, quickstart). Produced by [`Runtime::artifact_status`], which
/// owns the skip-vs-fail policy so every caller classifies identically:
/// broken artifacts are a loud `Err`, never a skip.
pub enum ArtifactStatus {
    /// Runtime constructed and artifacts execute on this build.
    Ready(Runtime),
    /// Artifacts exist but this build links the host-interpreter `xla`
    /// stub, which cannot execute AOT HLO — skip artifact-backed work
    /// with an explanation.
    StubOnly,
    /// Artifacts were never generated (no manifest) — skip and point at
    /// `make artifacts`. Carries the original lookup error.
    Missing(anyhow::Error),
}

/// Shared PJRT CPU client + executable caches. Thread-safe: artifacts are
/// cached behind sharded locks and handed out as `Arc`, so engine worker
/// threads can share one `Runtime`.
pub struct Runtime {
    pub client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    /// artifact-name -> compiled executable
    artifact_cache: ShardedCache<xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        // silence the TfrtCpuClient banner unless TF logging is configured
        if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json")).with_context(
            || format!("loading manifest from {artifacts_dir:?} — run `make artifacts`"),
        )?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            artifact_cache: ShardedCache::new(),
            manifest,
        })
    }

    /// Locate the artifacts dir: $LIFT_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("LIFT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn from_default() -> Result<Runtime> {
        Runtime::new(&Self::default_dir())
    }

    /// Load + compile an artifact HLO file (cached, thread-safe).
    pub fn load_artifact(&self, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.artifact_cache.get_or_try_insert(file, || {
            let path = self.artifacts_dir.join(file);
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {file}"))?;
            log::debug!("compiled artifact {file} in {:.2}s", t0.elapsed().as_secs_f64());
            Ok(exe)
        })
    }

    /// Probe whether this build can actually execute the manifest's AOT
    /// artifacts: one executable goes through the full parse-and-compile
    /// path (cached on success). Errors either because the vendored
    /// host-interpreter `xla` stub is linked — a build-capability gap,
    /// classified by [`is_stub_refusal`] so callers can skip with an
    /// explanation — or because the artifacts themselves are broken,
    /// which callers must surface loudly, not mask as a skip.
    pub fn probe_artifacts(&self) -> Result<()> {
        let probe = self
            .manifest
            .kernels
            .values()
            .next()
            .cloned()
            .or_else(|| {
                self.manifest
                    .presets
                    .values()
                    .next()
                    .and_then(|p| p.executables.values().next().cloned())
            });
        match probe {
            Some(file) => self.load_artifact(&file).map(|_| ()),
            None => anyhow::bail!("manifest lists no artifacts"),
        }
    }

    /// True when `err` (from [`Runtime::probe_artifacts`] or
    /// `load_artifact`) is the vendored host-interpreter `xla` stub
    /// refusing AOT HLO — i.e. the build lacks the native runtime, the
    /// artifacts themselves are fine. Matches on the `{:#}` rendering,
    /// which includes the full cause chain under both the vendored
    /// anyhow stand-in and the crates.io anyhow (whose plain `Display`
    /// shows only the outermost context).
    pub fn is_stub_refusal(err: &anyhow::Error) -> bool {
        format!("{err:#}").contains("host-interpreter stub cannot execute")
    }

    /// Classify artifact availability with one shared policy (see
    /// [`ArtifactStatus`]): `Ready` / `StubOnly` / `Missing` are the
    /// expected states; a present-but-broken artifacts dir is an `Err`
    /// that callers must surface, never convert into a skip.
    pub fn artifact_status() -> Result<ArtifactStatus> {
        let broken =
            |e: anyhow::Error| e.context("artifacts present but broken — regenerate with `make artifacts`");
        match Runtime::from_default() {
            Ok(rt) => match rt.probe_artifacts() {
                Ok(()) => Ok(ArtifactStatus::Ready(rt)),
                Err(e) if Self::is_stub_refusal(&e) => Ok(ArtifactStatus::StubOnly),
                Err(e) => Err(broken(e)),
            },
            Err(e) if !Self::default_dir().join("manifest.json").exists() => {
                Ok(ArtifactStatus::Missing(e))
            }
            Err(e) => Err(broken(e)),
        }
    }

    /// Execute an executable whose root is a tuple; returns the flattened
    /// tuple elements as host literals.
    pub fn run_tuple(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<xla::Literal>(args)?;
        let mut lit = out[0][0].to_literal_sync()?;
        Ok(lit.decompose_tuple()?)
    }

    /// Execute with a single (non-tuple) output.
    pub fn run_one(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<xla::Literal> {
        let out = exe.execute::<xla::Literal>(args)?;
        Ok(out[0][0].to_literal_sync()?)
    }
}

//! Sharded, thread-safe executable cache.
//!
//! The mask engine fans selection across worker threads that all hit the
//! compile caches; the old `Rc<RefCell<HashMap>>` caches were
//! single-threaded by construction. `ShardedCache` replaces them with
//! two levels: mutex-guarded shards that only protect the key → cell
//! map (held for microseconds), and a per-key cell that serializes the
//! build. A compile-on-miss therefore blocks *only* other requests for
//! the same key — never a different key that happens to share the shard
//! — while still guaranteeing each key is built exactly once.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

const N_SHARDS: usize = 8;

type Cell<V> = Arc<Mutex<Option<Arc<V>>>>;

pub struct ShardedCache<V> {
    shards: [Mutex<HashMap<String, Cell<V>>>; N_SHARDS],
}

impl<V> Default for ShardedCache<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> ShardedCache<V> {
    pub fn new() -> ShardedCache<V> {
        ShardedCache {
            shards: std::array::from_fn(|_| Mutex::new(HashMap::new())),
        }
    }

    fn shard(&self, key: &str) -> &Mutex<HashMap<String, Cell<V>>> {
        &self.shards[fxhash(key) as usize % N_SHARDS]
    }

    /// Fetch `key`, building and inserting it on a miss. The shard lock
    /// covers only the map probe; the build itself runs under the key's
    /// own cell lock, so concurrent misses on *different* keys compile
    /// in parallel while a given key is still compiled exactly once.
    /// A failed build leaves the cell empty, so the next caller retries.
    pub fn get_or_try_insert(
        &self,
        key: &str,
        build: impl FnOnce() -> Result<V>,
    ) -> Result<Arc<V>> {
        let cell = {
            let mut shard = self.shard(key).lock().expect("cache shard poisoned");
            shard
                .entry(key.to_string())
                .or_insert_with(|| Arc::new(Mutex::new(None)))
                .clone()
        };
        let mut slot = cell.lock().expect("cache cell poisoned");
        if let Some(v) = slot.as_ref() {
            return Ok(v.clone());
        }
        let v = Arc::new(build()?);
        *slot = Some(v.clone());
        Ok(v)
    }

    /// Number of *built* entries (cells whose build has succeeded).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .flat_map(|s| {
                s.lock()
                    .expect("cache shard poisoned")
                    .values()
                    .map(|c| c.lock().expect("cache cell poisoned").is_some() as usize)
                    .collect::<Vec<_>>()
            })
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_once_per_key() {
        let cache: ShardedCache<usize> = ShardedCache::new();
        let a = cache.get_or_try_insert("k", || Ok(1)).unwrap();
        let b = cache
            .get_or_try_insert("k", || panic!("must not rebuild"))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn build_errors_do_not_poison() {
        let cache: ShardedCache<usize> = ShardedCache::new();
        assert!(cache.get_or_try_insert("k", || anyhow::bail!("nope")).is_err());
        assert_eq!(cache.len(), 0, "failed build leaves no entry");
        assert_eq!(*cache.get_or_try_insert("k", || Ok(2)).unwrap(), 2);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let cache: Arc<ShardedCache<String>> = Arc::new(ShardedCache::new());
        std::thread::scope(|s| {
            for t in 0..4 {
                let cache = cache.clone();
                s.spawn(move || {
                    for i in 0..50 {
                        let key = format!("k{}", i % 10);
                        let v = cache
                            .get_or_try_insert(&key, || Ok(key.clone()))
                            .unwrap();
                        assert_eq!(*v, key, "thread {t}");
                    }
                });
            }
        });
        assert_eq!(cache.len(), 10);
    }

    #[test]
    fn slow_build_does_not_block_other_keys() {
        // a build in progress on one key must not prevent a lookup that
        // lands in the same shard from completing
        let cache: Arc<ShardedCache<usize>> = Arc::new(ShardedCache::new());
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        std::thread::scope(|s| {
            let c1 = cache.clone();
            s.spawn(move || {
                let _ = c1.get_or_try_insert("slow", || {
                    // hold the "slow" cell until the other thread finishes
                    rx.recv().ok();
                    Ok(1)
                });
            });
            // probe every other key; one of them shares "slow"'s shard.
            // if builds held the shard lock this would deadlock with the
            // sender below never being reached
            for i in 0..32 {
                let _ = cache.get_or_try_insert(&format!("fast{i}"), || Ok(i)).unwrap();
            }
            tx.send(()).unwrap();
        });
        assert_eq!(cache.len(), 33);
    }
}

//! Host tensor <-> xla::Literal conversions.

use anyhow::Result;

use crate::tensor::Tensor;

/// f32 tensor -> literal with the tensor's shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// i32 matrix (e.g. token ids) -> literal.
pub fn i32_matrix_to_literal(rows: usize, cols: usize, data: &[i32]) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "i32 literal shape mismatch");
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(&[rows as i64, cols as i64])?)
}

pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// literal (any rank, f32) -> host tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::from_vec(&dims, data))
}

pub fn literal_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn literal_to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

//! XlaBuilder-built linear-algebra toolkit (rust-side, python-free).
//!
//! The mask engine needs truncated SVDs and matmuls for *arbitrary* shapes
//! and ranks (the paper sweeps LRA rank 8..256 — Fig. 16), which fixed AOT
//! artifacts cannot cover. Graphs here are constructed in-process with
//! `XlaBuilder`, compiled once per shape and cached; numerically they
//! mirror `python/compile/kernels/subspace_iter.py` exactly (same
//! Newton–Schulz orthonormalization, same power-iteration count), and
//! rust/tests cross-check the two paths on the canonical artifact shapes.
//!
//! `Linalg` is `Send + Sync`: the compile cache is sharded-locked
//! (`runtime::cache`) and executables are shared as `Arc`, so the
//! layer-parallel mask engine (`lift::engine`) can drive one `Linalg`
//! from all of its worker threads. Graph *construction* still happens on
//! whichever thread misses the cache; the built executable is immutable
//! afterwards.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::cache::ShardedCache;
use super::literal::{literal_to_tensor, tensor_to_literal};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

const NEWTON_ITERS: usize = 24;
// trace-relative ridge: keeps Newton-Schulz inside its convergence domain
// even when Y is rank-deficient (true rank < rank + oversample).
const EPS_REL: f32 = 1e-6;

pub struct Linalg {
    client: xla::PjRtClient,
    cache: ShardedCache<xla::PjRtLoadedExecutable>,
}

impl Linalg {
    pub fn new(client: &xla::PjRtClient) -> Linalg {
        Linalg {
            client: client.clone(),
            cache: ShardedCache::new(),
        }
    }

    fn cached(
        &self,
        key: &str,
        build: impl FnOnce() -> Result<xla::XlaComputation>,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.cache.get_or_try_insert(key, || {
            let comp = build()?;
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))
        })
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// a (m,k) @ b (k,n), f32, via XLA (Eigen-backed on CPU).
    pub fn matmul(&self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        let (m, k) = a.dims2();
        let (k2, n) = b.dims2();
        anyhow::ensure!(k == k2, "matmul {:?} x {:?}", a.shape, b.shape);
        let exe = self.cached(&format!("mm_{m}_{k}_{n}"), || {
            let bld = xla::XlaBuilder::new("mm");
            let x = bld.parameter(0, xla::ElementType::F32, &[m as i64, k as i64], "a")?;
            let y = bld.parameter(1, xla::ElementType::F32, &[k as i64, n as i64], "b")?;
            Ok(x.dot_general(&y, &[1], &[0], &[], &[])?.build()?)
        })?;
        let out = exe.execute::<xla::Literal>(&[tensor_to_literal(a)?, tensor_to_literal(b)?])?;
        literal_to_tensor(&out[0][0].to_literal_sync()?)
    }

    /// a^T (k,m) @ b (k,n) without materializing the transpose.
    pub fn matmul_tn(&self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        let (k, m) = a.dims2();
        let (k2, n) = b.dims2();
        anyhow::ensure!(k == k2, "matmul_tn {:?} x {:?}", a.shape, b.shape);
        let exe = self.cached(&format!("mmtn_{k}_{m}_{n}"), || {
            let bld = xla::XlaBuilder::new("mmtn");
            let x = bld.parameter(0, xla::ElementType::F32, &[k as i64, m as i64], "a")?;
            let y = bld.parameter(1, xla::ElementType::F32, &[k as i64, n as i64], "b")?;
            Ok(x.dot_general(&y, &[0], &[0], &[], &[])?.build()?)
        })?;
        let out = exe.execute::<xla::Literal>(&[tensor_to_literal(a)?, tensor_to_literal(b)?])?;
        literal_to_tensor(&out[0][0].to_literal_sync()?)
    }

    /// a (m,k) @ b^T (n,k) without materializing the transpose.
    pub fn matmul_nt(&self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        let (m, k) = a.dims2();
        let (n, k2) = b.dims2();
        anyhow::ensure!(k == k2, "matmul_nt {:?} x {:?}", a.shape, b.shape);
        let exe = self.cached(&format!("mmnt_{m}_{k}_{n}"), || {
            let bld = xla::XlaBuilder::new("mmnt");
            let x = bld.parameter(0, xla::ElementType::F32, &[m as i64, k as i64], "a")?;
            let y = bld.parameter(1, xla::ElementType::F32, &[n as i64, k as i64], "b")?;
            Ok(x.dot_general(&y, &[1], &[1], &[], &[])?.build()?)
        })?;
        let out = exe.execute::<xla::Literal>(&[tensor_to_literal(a)?, tensor_to_literal(b)?])?;
        literal_to_tensor(&out[0][0].to_literal_sync()?)
    }

    /// Truncated SVD factors by subspace iteration: w ~= q @ b with
    /// q (m, rp) orthonormal, b (rp, n). `rp` = rank + oversample.
    /// One fused XLA graph per (m, n, rp, power_iters), cached.
    pub fn svd_lowrank(
        &self,
        w: &Tensor,
        rp: usize,
        power_iters: usize,
        rng: &mut Rng,
    ) -> Result<(Tensor, Tensor)> {
        let (m, n) = w.dims2();
        let rp = rp.min(m).min(n);
        let g0 = Tensor::randn(&[n, rp], 1.0, rng);
        self.svd_lowrank_with(w, &g0, power_iters)
    }

    /// Same as `svd_lowrank` but with a caller-supplied test matrix
    /// (deterministic cross-checks against the AOT kernel artifacts).
    pub fn svd_lowrank_with(
        &self,
        w: &Tensor,
        g0: &Tensor,
        power_iters: usize,
    ) -> Result<(Tensor, Tensor)> {
        let (m, n) = w.dims2();
        let (_, rp) = g0.dims2();
        let exe = self.cached(&format!("svd_{m}x{n}_r{rp}_q{power_iters}"), || {
            build_svd_graph(m, n, rp, power_iters)
        })?;
        let out = exe.execute::<xla::Literal>(&[tensor_to_literal(w)?, tensor_to_literal(g0)?])?;
        let mut lit = out[0][0].to_literal_sync()?;
        let parts = lit.decompose_tuple()?;
        anyhow::ensure!(parts.len() == 2, "svd graph returned {} outputs", parts.len());
        Ok((literal_to_tensor(&parts[0])?, literal_to_tensor(&parts[1])?))
    }

    /// Rank-r approximation W' = Q B (materialized, for host top-k).
    /// Cold-scratch wrapper over [`Linalg::lowrank_approx_with`].
    pub fn lowrank_approx(
        &self,
        w: &Tensor,
        rank: usize,
        power_iters: usize,
        oversample: usize,
        rng: &mut Rng,
    ) -> Result<Tensor> {
        self.lowrank_approx_with(
            w,
            rank,
            power_iters,
            oversample,
            rng,
            &mut crate::util::eigh::EighScratch::new(),
        )
    }

    /// [`Linalg::lowrank_approx`] with a caller-owned scratch arena: the
    /// host-side factor rotation's decomposition intermediates come from
    /// `scratch`, so an engine worker running many rank reductions
    /// allocates them once.
    pub fn lowrank_approx_with(
        &self,
        w: &Tensor,
        rank: usize,
        power_iters: usize,
        oversample: usize,
        rng: &mut Rng,
        scratch: &mut crate::util::eigh::EighScratch,
    ) -> Result<Tensor> {
        let (m, n) = w.dims2();
        let rp = (rank + oversample).min(m).min(n);
        let (q, b) = self.svd_lowrank(w, rp, power_iters, rng)?;
        if rp > rank {
            // drop the oversampled tail: rotate so columns of Q align with
            // singular directions, then truncate to `rank`.
            let (qr, br) = truncate_factors_with(&q, &b, rank, scratch);
            self.matmul(&qr, &br)
        } else {
            self.matmul(&q, &b)
        }
    }
}

/// Rotate (q, b) into singular order via the exact host decomposition of
/// the small factor b (rp x n) and truncate to `rank` columns. Only the
/// top `rank` triplets are requested (`eigh::svd_topr`); at the default
/// oversample the solver falls back to the full Jacobi oracle, but
/// callers sweeping larger blocks (Fig. 16 rank sweeps) stop paying for
/// components the truncation would discard. The `q @ ub` rotation runs
/// through the cache-tiled kernel in `util::gemm` (shared with the
/// exact decomposition path), f64-accumulated as before.
pub fn truncate_factors(q: &Tensor, b: &Tensor, rank: usize) -> (Tensor, Tensor) {
    truncate_factors_with(q, b, rank, &mut crate::util::eigh::EighScratch::new())
}

/// [`truncate_factors`] with a caller-owned scratch arena for the
/// small-factor decomposition's intermediates.
pub fn truncate_factors_with(
    q: &Tensor,
    b: &Tensor,
    rank: usize,
    scratch: &mut crate::util::eigh::EighScratch,
) -> (Tensor, Tensor) {
    let (m, rp) = q.dims2();
    let (rp2, n) = b.dims2();
    assert_eq!(rp, rp2);
    // clamp to min(rp, n): b has only min(rp, n) singular triplets, and
    // the loops below index ub/sb with exactly `rank` of them
    let rank = rank.min(rp).min(n);
    let (ub, sb, vtb, _) =
        crate::util::eigh::svd_topr_warm(&b.data, rp, n, rank, None, scratch);
    // q' = q @ ub[:, :rank] (m, rank); b' = diag(s) vtb [:rank] (rank, n).
    // The rotation inherits the arena's intra-matrix worker budget and
    // row-accumulator scratch (serial + allocating only for the cold
    // `truncate_factors` wrapper's fresh arena).
    let ub64: Vec<f64> = ub.iter().map(|&x| x as f64).collect();
    let mut qr = vec![0.0f32; m * rank];
    let wk = scratch.par_workers();
    crate::util::gemm::matmul_f32xf64_par(&q.data, &ub64, m, rp, rank, &mut qr, wk, &mut scratch.mm_acc);
    let mut br = vec![0.0f32; rank * n];
    for c in 0..rank {
        for j in 0..n {
            br[c * n + j] = sb[c] * vtb[c * n + j];
        }
    }
    (
        Tensor::from_vec(&[m, rank], qr),
        Tensor::from_vec(&[rank, n], br),
    )
}

/// Build the fused subspace-iteration graph (mirrors subspace_iter.py).
fn build_svd_graph(m: usize, n: usize, rp: usize, power_iters: usize) -> Result<xla::XlaComputation> {
    let bld = xla::XlaBuilder::new("svd_lowrank");
    let w = bld.parameter(0, xla::ElementType::F32, &[m as i64, n as i64], "w")?;
    let g0 = bld.parameter(1, xla::ElementType::F32, &[n as i64, rp as i64], "g0")?;

    let orth1 = |y: &xla::XlaOp| -> Result<xla::XlaOp> {
        // gram = y^T y (rp x rp)
        let gram = y.dot_general(y, &[0], &[0], &[], &[])?;
        let inv = invsqrt_psd(&bld, &gram, rp)?;
        Ok(y.dot_general(&inv, &[1], &[0], &[], &[])?)
    };
    // two passes: the second repairs residual non-orthogonality left by the
    // ridge when Y is rank-deficient (standard randomized-SVD trick).
    let orth = |y: &xla::XlaOp| -> Result<xla::XlaOp> { orth1(&orth1(y)?) };

    // range finder
    let y = w.dot_general(&g0, &[1], &[0], &[], &[])?;
    let mut q = orth(&y)?;
    for _ in 0..power_iters {
        let z = orth(&w.dot_general(&q, &[0], &[0], &[], &[])?)?; // (n, rp)
        q = orth(&w.dot_general(&z, &[1], &[0], &[], &[])?)?; // (m, rp)
    }
    let b = q.dot_general(&w, &[0], &[0], &[], &[])?; // (rp, n)
    Ok(bld.tuple(&[q, b])?.build()?)
}

/// (A + eps I)^{-1/2} for a small PSD matrix, coupled Newton–Schulz,
/// unrolled (mirrors subspace_iter.invsqrt_psd).
fn invsqrt_psd(bld: &xla::XlaBuilder, a: &xla::XlaOp, r: usize) -> Result<xla::XlaOp> {
    let r64 = r as i64;
    let rows = bld.iota(xla::ElementType::S32, &[r64, r64], 0)?;
    let cols = bld.iota(xla::ElementType::S32, &[r64, r64], 1)?;
    let eye = rows.eq(&cols)?.convert(xla::PrimitiveType::F32)?;
    // trace-relative ridge (plus a floor for the all-zero corner case)
    let tr = (a * &eye)?.reduce_sum(&[0, 1], false)?;
    let eps = ((&tr * bld.c0(EPS_REL)?)? + bld.c0(1e-30f32)?)?;
    let a = (a + (&eye * eps)?)?;
    // c = trace(A)  (scalar); ||A||_2 <= tr(A) for PSD
    let c = (&a * &eye)?.reduce_sum(&[0, 1], false)?;
    let mut y = (&a / &c)?;
    let mut z = eye.clone();
    let three = bld.c0(3.0f32)?;
    let half = bld.c0(0.5f32)?;
    for _ in 0..NEWTON_ITERS {
        // t = 0.5 * (3 I - z y)
        let zy = z.dot_general(&y, &[1], &[0], &[], &[])?;
        let t = (((&eye * &three)? - zy)? * &half)?;
        y = y.dot_general(&t, &[1], &[0], &[], &[])?;
        z = t.dot_general(&z, &[1], &[0], &[], &[])?;
    }
    Ok((z / c.sqrt()?)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linalg() -> (Linalg, xla::PjRtClient) {
        let client = xla::PjRtClient::cpu().unwrap();
        (Linalg::new(&client), client)
    }

    #[test]
    fn matmul_matches_host() {
        let (la, _c) = linalg();
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[17, 23], 1.0, &mut rng);
        let b = Tensor::randn(&[23, 9], 1.0, &mut rng);
        let xla_c = la.matmul(&a, &b).unwrap();
        let host_c = a.matmul(&b);
        let diff = crate::util::stats::frobenius_diff(&xla_c.data, &host_c.data);
        assert!(diff < 1e-3, "diff={diff}");
        // transposed variants
        let at = a.transpose();
        let tn = la.matmul_tn(&at, &b).unwrap();
        assert!(crate::util::stats::frobenius_diff(&tn.data, &host_c.data) < 1e-3);
        let bt = b.transpose();
        let nt = la.matmul_nt(&a, &bt).unwrap();
        assert!(crate::util::stats::frobenius_diff(&nt.data, &host_c.data) < 1e-3);
    }

    #[test]
    fn svd_recovers_lowrank_matrix() {
        let (la, _c) = linalg();
        let mut rng = Rng::new(2);
        let (m, n, r) = (48, 36, 4);
        let u = Tensor::randn(&[m, r], 1.0, &mut rng);
        let v = Tensor::randn(&[r, n], 1.0, &mut rng);
        let mut w = u.matmul(&v);
        // small full-rank tail: exact rank deficiency would make rp
        // orthonormal columns impossible (rank(Y) = 4 < rp)
        w.add_scaled(&Tensor::randn(&[m, n], 1.0, &mut rng), 1e-3);
        let (q, b) = la.svd_lowrank(&w, r + 4, 2, &mut rng).unwrap();
        let rec = la.matmul(&q, &b).unwrap();
        let rel = crate::util::stats::frobenius_diff(&rec.data, &w.data) / w.frobenius();
        assert!(rel < 1e-2, "rel={rel}");
        // q columns orthonormal
        let qtq = la.matmul_tn(&q, &q).unwrap();
        for i in 0..r + 4 {
            for j in 0..r + 4 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (qtq.at2(i, j) - expect).abs() < 1e-2,
                    "qtq[{i},{j}]={}",
                    qtq.at2(i, j)
                );
            }
        }
    }

    #[test]
    fn lowrank_approx_close_to_exact() {
        let (la, _c) = linalg();
        let mut rng = Rng::new(3);
        let (m, n, rank) = (40, 32, 6);
        let w = Tensor::randn(&[m, n], 1.0, &mut rng);
        let approx = la.lowrank_approx(&w, rank, 3, 8, &mut rng).unwrap();
        let exact = crate::util::eigh::lowrank_approx(&w.data, m, n, rank);
        // randomized vs exact: compare approximation errors, not entries
        let err_rand = crate::util::stats::frobenius_diff(&approx.data, &w.data);
        let err_exact = crate::util::stats::frobenius_diff(&exact, &w.data);
        assert!(
            err_rand <= err_exact * 1.05 + 1e-4,
            "rand {err_rand} vs exact {err_exact}"
        );
    }

    #[test]
    fn truncate_factors_clamps_rank_to_small_side() {
        let (la, _c) = linalg();
        let mut rng = Rng::new(6);
        let q = Tensor::randn(&[10, 6], 1.0, &mut rng);
        let b = Tensor::randn(&[6, 4], 1.0, &mut rng);
        // rank > n: b has only n singular triplets — must clamp, not
        // panic / read out of bounds
        let (qr, br) = truncate_factors(&q, &b, 5);
        assert_eq!(qr.shape, vec![10, 4]);
        assert_eq!(br.shape, vec![4, 4]);
        // at b's full rank the "truncation" must reproduce q @ b
        let rec = la.matmul(&qr, &br).unwrap();
        let full = la.matmul(&q, &b).unwrap();
        let diff = crate::util::stats::frobenius_diff(&rec.data, &full.data);
        assert!(diff < 1e-3, "diff={diff}");
    }

    #[test]
    fn linalg_is_shareable_across_threads() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Linalg>();
        // same Linalg driven from several threads, same numeric results
        let (la, _c) = linalg();
        let mut rng = Rng::new(9);
        let a = Tensor::randn(&[12, 10], 1.0, &mut rng);
        let b = Tensor::randn(&[10, 7], 1.0, &mut rng);
        let want = la.matmul(&a, &b).unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let got = la.matmul(&a, &b).unwrap();
                    assert_eq!(got.data, want.data);
                });
            }
        });
    }

    #[test]
    fn executables_are_cached() {
        let (la, _c) = linalg();
        let mut rng = Rng::new(4);
        let a = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let _ = la.matmul(&a, &a).unwrap();
        let n1 = la.cache_len();
        let _ = la.matmul(&a, &a).unwrap();
        assert_eq!(la.cache_len(), n1);
    }
}

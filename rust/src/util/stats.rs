//! Small statistics helpers shared by eval, analysis and the bench harness.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile (nearest-rank on a sorted copy), p in [0, 100].
/// NaN entries sort last (`total_cmp`), so low/mid percentiles of a
/// partially-poisoned series stay finite instead of panicking.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
    s[idx.min(s.len() - 1)]
}

/// Exact k-th largest magnitude threshold: |x| >= t holds for >= k entries.
/// O(n) average (quickselect via select_nth_unstable).
///
/// NaN entries rank *below every finite magnitude* (not `total_cmp`'s
/// above-infinity slot): a diverged weight must never become the
/// threshold, or `|x| >= NaN` would silently select nothing. With at
/// least `k` non-NaN entries the returned threshold is always non-NaN.
pub fn topk_abs_threshold(xs: &[f32], k: usize) -> f32 {
    assert!(k > 0 && k <= xs.len(), "k={} n={}", k, xs.len());
    let mut mags: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    let idx = xs.len() - k;
    let (_, kth, _) = mags.select_nth_unstable_by(idx, |a, b| {
        match (a.is_nan(), b.is_nan()) {
            (true, true) => std::cmp::Ordering::Equal,
            (true, false) => std::cmp::Ordering::Less,
            (false, true) => std::cmp::Ordering::Greater,
            (false, false) => a.total_cmp(b),
        }
    });
    *kth
}

/// Histogram with fixed bin count over [lo, hi]; out-of-range clamps.
/// A degenerate range (`hi <= lo`, or a non-finite width) has bin
/// width 0 — every sample clamps into bin 0 instead of dividing by zero.
pub fn histogram(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if bins == 0 {
        return h;
    }
    let w = (hi - lo) / bins as f32;
    if !(w > 0.0 && w.is_finite()) {
        h[0] = xs.len();
        return h;
    }
    for &x in xs {
        let b = (((x - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        h[b] += 1;
    }
    h
}

/// Dot product (f64 accumulate).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

pub fn l2_norm(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

pub fn frobenius_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn topk_threshold_exact() {
        let xs = [0.1f32, -5.0, 2.0, -0.3, 4.0, 1.0];
        let t = topk_abs_threshold(&xs, 2);
        assert_eq!(t, 4.0);
        let kept = xs.iter().filter(|x| x.abs() >= t).count();
        assert_eq!(kept, 2);
        // k = n keeps everything
        assert!(topk_abs_threshold(&xs, 6) <= 0.1);
    }

    #[test]
    fn histogram_clamps() {
        let h = histogram(&[-10.0, 0.0, 0.5, 10.0], -1.0, 1.0, 4);
        assert_eq!(h.iter().sum::<usize>(), 4);
        assert_eq!(h[0], 1); // -10 clamped into first bin
        assert_eq!(h[3], 2); // 0.5 and 10 in the last bin
    }

    #[test]
    fn percentile_survives_nan() {
        // regression (ISSUE 10): the NaN-panicking comparator lived here
        let xs = [5.0, f64::NAN, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 33.0), 3.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        // NaN sorts last, so p100 of a poisoned series is NaN — loud
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn topk_threshold_ranks_nan_below_finite() {
        // regression (ISSUE 10): one NaN weight panicked the selection
        // hot path; now NaN is the smallest magnitude
        let xs = [1.0f32, f32::NAN, 3.0, -2.0];
        assert_eq!(topk_abs_threshold(&xs, 2), 2.0);
        assert_eq!(topk_abs_threshold(&xs, 3), 1.0);
        // only when k exceeds the finite count can the threshold be NaN
        assert!(topk_abs_threshold(&xs, 4).is_nan());
    }

    #[test]
    fn histogram_degenerate_range() {
        // regression (ISSUE 10): hi == lo made the bin width 0 and
        // routed every sample through a NaN/inf cast
        let h = histogram(&[1.0, 5.0, 5.0], 5.0, 5.0, 4);
        assert_eq!(h, vec![3, 0, 0, 0]);
        let h = histogram(&[1.0], 2.0, -2.0, 3); // inverted range
        assert_eq!(h, vec![1, 0, 0]);
        assert!(histogram(&[1.0], 0.0, 1.0, 0).is_empty());
    }
}

//! Property-testing mini-framework (`proptest` is unavailable offline).
//!
//! A property is a closure over a seeded `Rng`; the runner executes it for
//! `cases` independent seeds and reports the first failing seed so a
//! failure reproduces with `check_seeded(name, BAD_SEED, prop)`. No
//! shrinking — generators are kept small-biased instead (sizes drawn
//! log-uniformly), which in practice keeps counterexamples readable.

use crate::util::rng::Rng;

pub const DEFAULT_CASES: usize = 32;

/// Run `prop` for `cases` derived seeds; panic with the failing seed.
pub fn check_cases<F: FnMut(&mut Rng) -> Result<(), String>>(
    name: &str,
    cases: usize,
    base_seed: u64,
    mut prop: F,
) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with check_seeded(\"{name}\", {seed:#x}, ...)"
            );
        }
    }
}

pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, prop: F) {
    check_cases(name, DEFAULT_CASES, 0xC0FFEE, prop);
}

pub fn check_seeded<F: FnMut(&mut Rng) -> Result<(), String>>(
    name: &str,
    seed: u64,
    mut prop: F,
) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed on seed {seed:#x}: {msg}");
    }
}

/// Log-uniform size in [lo, hi] — biases toward small counterexamples.
pub fn gen_size(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    assert!(lo >= 1 && hi >= lo);
    let llo = (lo as f64).ln();
    let lhi = (hi as f64).ln();
    let x = llo + rng.next_f64() * (lhi - llo);
    (x.exp().round() as usize).clamp(lo, hi)
}

/// Assertion helpers returning Result for use inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("abs is nonneg", |rng| {
            let x = rng.normal();
            ensure(x.abs() >= 0.0, format!("abs({x}) < 0 ?!"))
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        check("always fails", |_| Err("nope".into()));
    }

    #[test]
    fn gen_size_bounds() {
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let s = gen_size(&mut rng, 2, 64);
            assert!((2..=64).contains(&s));
        }
    }
}

//! Tiny argv parser (`clap` is unavailable offline).
//!
//! Grammar: `lift <subcommand> [positional...] [--key value | --flag]...`.
//! Typed getters with defaults; unknown-flag detection via `finish()`.

use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub cmd: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    used: std::cell::RefCell<BTreeSet<String>>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut a = Args::default();
        let mut seen_cmd = false;
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let (k, v) = match name.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => {
                        // value is the next token unless it looks like a flag
                        let takes_val =
                            matches!(it.peek(), Some(n) if !n.starts_with("--"));
                        let v = if takes_val { it.next().unwrap() } else { "true".into() };
                        (name.to_string(), v)
                    }
                };
                a.flags.insert(k, v);
            } else if !seen_cmd {
                a.cmd = tok;
                seen_cmd = true;
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.used.borrow_mut().insert(key.to_string());
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| matches!(v.as_str(), "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// Comma-separated list flag.
    pub fn list(&self, key: &str, default: &str) -> Vec<String> {
        self.str(key, default)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }

    /// Error on any flag that no getter consumed (typo guard).
    pub fn finish(&self) -> anyhow::Result<()> {
        let used = self.used.borrow();
        let unknown: Vec<_> = self.flags.keys().filter(|k| !used.contains(*k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown flags: {unknown:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --preset tiny --steps 500 --fast --lr 1e-4");
        assert_eq!(a.cmd, "train");
        assert_eq!(a.str("preset", "x"), "tiny");
        assert_eq!(a.usize("steps", 0), 500);
        assert!(a.bool("fast", false));
        assert!((a.f32("lr", 0.0) - 1e-4).abs() < 1e-10);
        a.finish().unwrap();
    }

    #[test]
    fn eq_form_and_positional() {
        let a = parse("exp table2 --seeds=4");
        assert_eq!(a.cmd, "exp");
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.usize("seeds", 1), 4);
    }

    #[test]
    fn defaults() {
        let a = parse("train");
        assert_eq!(a.str("preset", "tiny"), "tiny");
        assert!(!a.bool("fast", false));
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse("train --bogus 3");
        let _ = a.str("preset", "tiny");
        assert!(a.finish().is_err());
    }

    #[test]
    fn list_flag() {
        let a = parse("exp --methods full,lift,lora");
        assert_eq!(a.list("methods", ""), vec!["full", "lift", "lora"]);
    }
}

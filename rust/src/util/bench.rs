//! Micro-benchmark harness (`criterion` is unavailable offline).
//!
//! Warmup + timed iterations, reports mean / p50 / p95 / min, and writes a
//! machine-readable line so `rust/benches/bench_main.rs` output can be
//! diffed across the perf-pass iterations recorded in EXPERIMENTS.md §Perf.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<5} mean={:>12} p50={:>12} p95={:>12} min={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Order statistics over raw samples. Sorts with `f64::total_cmp` — a
/// NaN sample (clock step, derived-value callers) sorts last instead of
/// panicking the run and losing the trajectory append; it then surfaces
/// in the affected percentile where a reader can see it.
fn summarize(name: &str, mut samples: Vec<f64>) -> BenchResult {
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        p50_ns: samples[n / 2],
        p95_ns: samples[(n as f64 * 0.95) as usize..][0],
        min_ns: samples[0],
    }
}

pub struct Bencher {
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_secs: f64,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            min_iters: 5,
            max_iters: 200,
            target_secs: 2.0,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn fast() -> Self {
        Bencher {
            min_iters: 3,
            max_iters: 20,
            target_secs: 0.5,
            results: Vec::new(),
        }
    }

    /// Time `f` adaptively: warm up once, then iterate until target_secs
    /// or max_iters.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        f(); // warmup (compile caches, allocators)
        let mut samples = Vec::new();
        let t_start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters
                && t_start.elapsed().as_secs_f64() < self.target_secs)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let res = summarize(name, samples);
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Throughput variant: report items/sec alongside latency.
    pub fn bench_throughput<F: FnMut()>(&mut self, name: &str, items: usize, f: F) {
        let mean_ns = self.bench(name, f).mean_ns;
        let per_sec = items as f64 / (mean_ns / 1e9);
        println!("{:<44} {:.1} items/s", format!("{name} [throughput]"), per_sec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders() {
        let mut b = Bencher {
            min_iters: 3,
            max_iters: 5,
            target_secs: 0.01,
            results: vec![],
        };
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = acc.wrapping_add(1);
            std::hint::black_box(acc);
        });
        let r = &b.results[0];
        assert!(r.iters >= 3);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn summarize_survives_nan_samples() {
        // regression (ISSUE 7): the NaN-panicking comparator lived here
        let r = summarize("nan-proof", vec![2.0, f64::NAN, 1.0]);
        assert_eq!(r.iters, 3);
        assert_eq!(r.min_ns, 1.0); // total_cmp sorts NaN last
        assert_eq!(r.p50_ns, 2.0);
        assert!(r.p95_ns.is_nan());
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.1e9), "3.100s");
    }
}

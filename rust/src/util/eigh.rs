//! Dense symmetric eigensolver (cyclic Jacobi) + exact small-matrix SVD
//! + the top-r subspace path the exact oracle actually runs on.
//!
//! The HLO interchange cannot carry LAPACK custom-calls, and the runtime
//! path uses randomized subspace iteration (runtime/linalg.rs). This module
//! is the *exact* host-side oracle used for (a) cross-checking the
//! randomized factors in tests, (b) Fig. 13-style rank counting of update
//! matrices, and (c) the small-side rotation of subspace factors. O(n^3)
//! per sweep — fine for the n <= ~2k matrices it sees.
//!
//! Two tiers live here:
//!   * [`eigh64`] / [`svd`] — the full-spectrum Jacobi oracle, retained
//!     for the tail-component ablation strategies, Fig. 13 rank counting,
//!     and as the reference the property suite checks against;
//!   * [`svd_topr`] — a deterministic blocked subspace iteration that
//!     computes only the top-r singular triplets. [`lowrank_approx`]
//!     (the paper's Eq. 1 oracle) routes through it, so a rank-32
//!     reconstruction of a 2k-side matrix no longer pays for the other
//!     ~2k components; accuracy vs the Jacobi oracle is bounded by
//!     [`TOPR_SV_TOL`] / [`TOPR_RECON_SLACK`] (asserted in
//!     `rust/tests/properties.rs`).

/// Jacobi eigendecomposition of a symmetric matrix (row-major, n x n).
/// Returns (eigenvalues desc, eigenvectors as columns, row-major n x n).
pub fn eigh(a: &[f32], n: usize) -> (Vec<f32>, Vec<f32>) {
    let a64: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    let (w, v) = eigh64(&a64, n);
    (
        w.iter().map(|&x| x as f32).collect(),
        v.iter().map(|&x| x as f32).collect(),
    )
}

/// f64 Jacobi core — the Gram matrix must stay in f64 end-to-end or the
/// sqrt amplifies rounding into a ~1e-4-relative singular-value noise
/// floor (breaks Fig. 13 rank counting).
pub fn eigh64(a: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), n * n);
    let mut a: Vec<f64> = a.to_vec();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for p in 0..n {
            for q in (p + 1)..n {
                off += a[p * n + q] * a[p * n + q];
            }
        }
        if off.sqrt() < 1e-11 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // A <- J^T A J on rows/cols p, q
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    // sort by eigenvalue descending
    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    order.sort_by(|&i, &j| evals[j].partial_cmp(&evals[i]).unwrap());
    let mut w = Vec::with_capacity(n);
    let mut vecs = vec![0.0f64; n * n];
    for (new, &old) in order.iter().enumerate() {
        w.push(evals[old]);
        for k in 0..n {
            vecs[k * n + new] = v[k * n + old];
        }
    }
    (w, vecs)
}

/// Exact thin SVD of an m x n matrix (row-major) via eigh of the Gram
/// matrix on the smaller side. Returns (u m x r, s r, vt r x n), r = min(m, n).
pub fn svd(a: &[f32], m: usize, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(a.len(), m * n);
    let r = m.min(n);
    if n <= m {
        // G = A^T A (n x n); A = U S V^T, G = V S^2 V^T
        let mut g = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i..n {
                let mut acc = 0.0f64;
                for k in 0..m {
                    acc += a[k * n + i] as f64 * a[k * n + j] as f64;
                }
                g[i * n + j] = acc;
                g[j * n + i] = acc;
            }
        }
        let (w, vfull) = eigh64(&g, n);
        let mut s = vec![0.0f32; r];
        let mut u = vec![0.0f32; m * r];
        let mut vt = vec![0.0f32; r * n];
        for c in 0..r {
            let sc = w[c].max(0.0).sqrt();
            s[c] = sc as f32;
            for k in 0..n {
                vt[c * n + k] = vfull[k * n + c] as f32;
            }
            // u_c = A v_c / s_c
            if sc > 1e-12 {
                for row in 0..m {
                    let mut acc = 0.0f64;
                    for k in 0..n {
                        acc += a[row * n + k] as f64 * vfull[k * n + c];
                    }
                    u[row * r + c] = (acc / sc) as f32;
                }
            }
        }
        (u, s, vt)
    } else {
        // transpose route: svd(A^T) then swap
        let mut at = vec![0.0f32; n * m];
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = a[i * n + j];
            }
        }
        let (ut, s, vtt) = svd(&at, n, m);
        // A = (V_t)^T S U_t^T  =>  U = vtt^T (m x r), V^T = ut^T (r x n)
        let mut u = vec![0.0f32; m * r];
        let mut vt = vec![0.0f32; r * n];
        for i in 0..m {
            for c in 0..r {
                u[i * r + c] = vtt[c * m + i];
            }
        }
        for c in 0..r {
            for j in 0..n {
                vt[c * n + j] = ut[j * r + c];
            }
        }
        (u, s, vt)
    }
}

/// Accuracy contract of [`svd_topr`] against the full-spectrum [`svd`]
/// oracle (asserted by `rust/tests/properties.rs`):
/// every returned singular value is within `TOPR_SV_TOL * s_max` of the
/// oracle's value at the same position. The worst case is an adversarial
/// near-flat spectrum (`s_r ~ s_{p+1}`, p = r + oversample), where
/// subspace iteration converges slowly; observed error there is ~2e-3,
/// while decaying spectra land near f64 round-off (~1e-15).
pub const TOPR_SV_TOL: f32 = 1e-2;

/// Companion bound: the top-r reconstruction's Frobenius error exceeds
/// the oracle's best-rank-r error by at most `TOPR_RECON_SLACK * |A|_F`.
/// Near-flat spectra are again the worst case (~3e-4 observed), and there
/// any rank-r subspace is near-optimal, which is what keeps the slack
/// small even when individual vectors have not converged.
pub const TOPR_RECON_SLACK: f32 = 1e-3;

/// Oversampling columns of the iteration block (p = r + this).
const TOPR_OVERSAMPLE: usize = 8;
/// Iteration cap; each pass multiplies the error by (s_{p+1}/s_r)^2.
const TOPR_MAX_ITERS: usize = 60;
/// Early exit when trace(X^T G X) is relatively stable between passes.
const TOPR_TRACE_TOL: f64 = 1e-12;

/// Top-r thin SVD of an m x n matrix (row-major) by blocked subspace
/// iteration on the smaller-side Gram matrix, entirely in f64 on the
/// host. Returns (u m x r, s r, vt r x n), r clamped to min(m, n).
///
/// Deterministic: the start block comes from a fixed-seed [`Rng`], so
/// the result is a pure function of `(a, m, n, r)` — the layer-parallel
/// engine can run one decomposition per worker without the worker count
/// or scheduling order leaking into the factors. Small problems
/// (2(r + oversample) >= min(m, n)) fall back to the full Jacobi
/// oracle, where iteration would save nothing.
pub fn svd_topr(a: &[f32], m: usize, n: usize, r: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(a.len(), m * n);
    let minmn = m.min(n);
    let r = r.min(minmn);
    if r == 0 {
        return (Vec::new(), Vec::new(), Vec::new());
    }
    let p = (r + TOPR_OVERSAMPLE).min(minmn);
    if 2 * p >= minmn {
        let (uf, sf, vtf) = svd(a, m, n);
        let mut u = vec![0.0f32; m * r];
        for i in 0..m {
            u[i * r..(i + 1) * r].copy_from_slice(&uf[i * minmn..i * minmn + r]);
        }
        return (u, sf[..r].to_vec(), vtf[..r * n].to_vec());
    }
    if n > m {
        // transpose route: svd_topr(A^T) then swap factors
        let mut at = vec![0.0f32; n * m];
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = a[i * n + j];
            }
        }
        let (ut, s, vtt) = svd_topr(&at, n, m, r);
        // A = (V_t)^T S U_t^T  =>  U = vtt^T (m x r), V^T = ut^T (r x n)
        let mut u = vec![0.0f32; m * r];
        let mut vt = vec![0.0f32; r * n];
        for i in 0..m {
            for c in 0..r {
                u[i * r + c] = vtt[c * m + i];
            }
        }
        for c in 0..r {
            for j in 0..n {
                vt[c * n + j] = ut[j * r + c];
            }
        }
        return (u, s, vt);
    }
    // n <= m: iterate on G = A^T A (n x n, f64). Basis vectors are rows
    // of xt (p x n) so Gram-Schmidt and the G-apply stay contiguous.
    let mut g = vec![0.0f64; n * n];
    for i in 0..n {
        for j in i..n {
            let mut acc = 0.0f64;
            for k in 0..m {
                acc += a[k * n + i] as f64 * a[k * n + j] as f64;
            }
            g[i * n + j] = acc;
            g[j * n + i] = acc;
        }
    }
    let apply_g = |xt: &[f64]| -> Vec<f64> {
        let mut yt = vec![0.0f64; p * n];
        for j in 0..p {
            let xrow = &xt[j * n..(j + 1) * n];
            let yrow = &mut yt[j * n..(j + 1) * n];
            for (k, &x) in xrow.iter().enumerate() {
                if x == 0.0 {
                    continue;
                }
                let grow = &g[k * n..(k + 1) * n];
                for i in 0..n {
                    yrow[i] += x * grow[i];
                }
            }
        }
        yt
    };
    // fixed-seed start block: determinism is part of the contract
    let mut rng = crate::util::rng::Rng::new(0x70b5_eed0_5bd7_0b5e);
    let mut xt: Vec<f64> = (0..p * n).map(|_| rng.normal() as f64).collect();
    orthonormalize_rows(&mut xt, p, n);
    let mut prev_tr = f64::NEG_INFINITY;
    for _ in 0..TOPR_MAX_ITERS {
        let yt = apply_g(&xt);
        let mut tr = 0.0f64;
        for j in 0..p {
            for i in 0..n {
                tr += xt[j * n + i] * yt[j * n + i];
            }
        }
        let done = prev_tr.is_finite()
            && (tr - prev_tr).abs() <= TOPR_TRACE_TOL * tr.abs().max(1e-300);
        prev_tr = tr;
        xt = yt;
        orthonormalize_rows(&mut xt, p, n);
        if done {
            break;
        }
    }
    // Rayleigh-Ritz: rotate the converged block into singular order
    let yt = apply_g(&xt);
    let mut t = vec![0.0f64; p * p];
    for b in 0..p {
        for c in b..p {
            let mut acc = 0.0f64;
            for i in 0..n {
                acc += xt[b * n + i] * yt[c * n + i];
            }
            t[b * p + c] = acc;
            t[c * p + b] = acc;
        }
    }
    let (w, z) = eigh64(&t, p);
    let mut s = vec![0.0f32; r];
    let mut u = vec![0.0f32; m * r];
    let mut vt = vec![0.0f32; r * n];
    let mut vc = vec![0.0f64; n];
    for c in 0..r {
        let sc = w[c].max(0.0).sqrt();
        s[c] = sc as f32;
        // v_c = sum_b z[b][c] * xt_b
        for x in vc.iter_mut() {
            *x = 0.0;
        }
        for b in 0..p {
            let zb = z[b * p + c];
            if zb == 0.0 {
                continue;
            }
            for i in 0..n {
                vc[i] += zb * xt[b * n + i];
            }
        }
        for j in 0..n {
            vt[c * n + j] = vc[j] as f32;
        }
        // u_c = A v_c / s_c
        if sc > 1e-12 {
            for row in 0..m {
                let mut acc = 0.0f64;
                for j in 0..n {
                    acc += a[row * n + j] as f64 * vc[j];
                }
                u[row * r + c] = (acc / sc) as f32;
            }
        }
    }
    (u, s, vt)
}

/// Orthonormalize the rows of `xt` (p x n, row-major) by modified
/// Gram-Schmidt with two projection passes per row ("twice is enough"):
/// one pass leaves cancellation junk correlated with the earlier rows
/// when the block is numerically rank-deficient, which inflates the
/// Ritz values. Rows that collapse entirely are replaced by a cycling
/// unit basis vector (deterministic), keeping the block full rank for
/// rank-deficient inputs.
fn orthonormalize_rows(xt: &mut [f64], p: usize, n: usize) {
    // project row j against the already-orthonormal rows 0..j, twice
    fn project_out(head: &[f64], row: &mut [f64], j: usize, n: usize) {
        for _pass in 0..2 {
            for i in 0..j {
                let prev = &head[i * n..(i + 1) * n];
                let mut dot = 0.0f64;
                for k in 0..n {
                    dot += prev[k] * row[k];
                }
                for k in 0..n {
                    row[k] -= dot * prev[k];
                }
            }
        }
    }
    for j in 0..p {
        let (head, tail) = xt.split_at_mut(j * n);
        let row = &mut tail[..n];
        project_out(head, row, j, n);
        let nrm = row.iter().map(|x| x * x).sum::<f64>().sqrt();
        if nrm < 1e-30 {
            // dead row (rank-deficient block): deterministic rescue with
            // a cycling basis vector, re-orthogonalized the same way
            for (k, x) in row.iter_mut().enumerate() {
                *x = if k == j % n { 1.0 } else { 0.0 };
            }
            project_out(head, row, j, n);
            let nrm2 = row.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
            for x in row.iter_mut() {
                *x /= nrm2;
            }
        } else {
            for x in row.iter_mut() {
                *x /= nrm;
            }
        }
    }
}

/// Rank-r reconstruction (the paper's Eq. 1 oracle), now through the
/// top-r subspace path — only the requested components are computed.
pub fn lowrank_approx(a: &[f32], m: usize, n: usize, rank: usize) -> Vec<f32> {
    let rank = rank.min(m.min(n));
    let (u, s, vt) = svd_topr(a, m, n, rank);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for c in 0..rank {
            let uis = u[i * rank + c] * s[c];
            if uis == 0.0 {
                continue;
            }
            let row = &vt[c * n..(c + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += uis * row[j];
            }
        }
    }
    out
}

/// Count of singular values above `tau` (Fig. 13 rank metric).
pub fn rank_above(a: &[f32], m: usize, n: usize, tau_mult: f32) -> usize {
    let (_, s, _) = svd(a, m, n);
    let smax = s.first().copied().unwrap_or(0.0);
    // paper: tau = 10 x default = 10 * max(m,n) * smax * eps_f32
    let tau = tau_mult * m.max(n) as f32 * smax * f32::EPSILON;
    s.iter().filter(|&&x| x > tau).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for l in 0..k {
                let ail = a[i * k + l];
                for j in 0..n {
                    c[i * n + j] += ail * b[l * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn eigh_diagonal() {
        let a = vec![3.0, 0.0, 0.0, 1.0];
        let (w, v) = eigh(&a, 2);
        assert!((w[0] - 3.0).abs() < 1e-5 && (w[1] - 1.0).abs() < 1e-5);
        // columns orthonormal
        let dot = v[0] * v[1] + v[2] * v[3];
        assert!(dot.abs() < 1e-5);
    }

    #[test]
    fn eigh_reconstructs() {
        let mut rng = Rng::new(42);
        let n = 16;
        let b = rng.normal_vec(n * n, 1.0);
        // symmetrize
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = 0.5 * (b[i * n + j] + b[j * n + i]);
            }
        }
        let (w, v) = eigh(&a, n);
        // A v_c = w_c v_c
        for c in 0..n {
            for i in 0..n {
                let mut av = 0.0;
                for k in 0..n {
                    av += a[i * n + k] * v[k * n + c];
                }
                assert!(
                    (av - w[c] * v[i * n + c]).abs() < 1e-3,
                    "c={c} i={i}: {av} vs {}",
                    w[c] * v[i * n + c]
                );
            }
        }
    }

    #[test]
    fn svd_reconstructs_tall_and_wide() {
        let mut rng = Rng::new(7);
        for (m, n) in [(20usize, 8usize), (8, 20), (12, 12)] {
            let a = rng.normal_vec(m * n, 1.0);
            let (u, s, vt) = svd(&a, m, n);
            let r = m.min(n);
            let mut us = vec![0.0f32; m * r];
            for i in 0..m {
                for c in 0..r {
                    us[i * r + c] = u[i * r + c] * s[c];
                }
            }
            let rec = matmul(&us, &vt, m, r, n);
            for i in 0..m * n {
                assert!((rec[i] - a[i]).abs() < 1e-3, "({m},{n}) idx {i}");
            }
            // singular values sorted desc, nonnegative
            for c in 1..r {
                assert!(s[c - 1] >= s[c] - 1e-5);
                assert!(s[c] >= -1e-6);
            }
        }
    }

    #[test]
    fn lowrank_is_best_approx() {
        // rank-2 matrix + noise: rank-2 approx error must be ~ noise level
        let mut rng = Rng::new(3);
        let (m, n, r) = (24, 16, 2);
        let u = rng.normal_vec(m * r, 1.0);
        let v = rng.normal_vec(r * n, 1.0);
        let mut a = matmul(&u, &v, m, r, n);
        for x in a.iter_mut() {
            *x += rng.normal() * 1e-3;
        }
        let ar = lowrank_approx(&a, m, n, 2);
        let err: f32 = a.iter().zip(&ar).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(err.sqrt() < 0.1, "err={}", err.sqrt());
    }

    #[test]
    fn topr_matches_full_svd_on_leading_triplets() {
        let mut rng = Rng::new(21);
        // large enough that 2(r + oversample) < min(m, n): subspace path
        for (m, n, r) in [(60usize, 50usize, 5usize), (44, 72, 3)] {
            let a = rng.normal_vec(m * n, 1.0);
            let (uf, sf, vtf) = svd(&a, m, n);
            let (u, s, vt) = svd_topr(&a, m, n, r);
            assert_eq!(u.len(), m * r);
            assert_eq!(vt.len(), r * n);
            for c in 0..r {
                assert!(
                    (s[c] - sf[c]).abs() <= TOPR_SV_TOL * sf[0],
                    "({m},{n}) s[{c}]: topr {} vs oracle {}",
                    s[c],
                    sf[c]
                );
            }
            // returned factors actually reconstruct: U diag(s) V^T has the
            // oracle's rank-r error up to the documented slack
            let mut rec = vec![0.0f32; m * n];
            for i in 0..m {
                for c in 0..r {
                    let x = u[i * r + c] * s[c];
                    for j in 0..n {
                        rec[i * n + j] += x * vt[c * n + j];
                    }
                }
            }
            let oracle = {
                let rr = m.min(n);
                let mut o = vec![0.0f32; m * n];
                for i in 0..m {
                    for c in 0..r {
                        let x = uf[i * rr + c] * sf[c];
                        for j in 0..n {
                            o[i * n + j] += x * vtf[c * n + j];
                        }
                    }
                }
                o
            };
            let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let err = |rec: &[f32]| -> f32 {
                a.iter()
                    .zip(rec)
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum::<f32>()
                    .sqrt()
            };
            assert!(
                err(&rec) <= err(&oracle) + TOPR_RECON_SLACK * norm,
                "({m},{n}) recon {} vs oracle {}",
                err(&rec),
                err(&oracle)
            );
        }
    }

    #[test]
    fn topr_rank_deficient_input_is_exact() {
        // rank-1 all-ones matrix: the iteration block collapses and the
        // Gram-Schmidt rescue must keep the factors orthonormal — a
        // single-pass MGS inflates s[0] by sqrt(2) here
        let (m, n) = (50usize, 40usize);
        let a = vec![1.0f32; m * n];
        let (_, s, vt) = svd_topr(&a, m, n, 4);
        let s1 = ((m * n) as f32).sqrt();
        assert!((s[0] - s1).abs() < 1e-3 * s1, "s[0]={} want {s1}", s[0]);
        for c in 1..4 {
            assert!(s[c].abs() < 1e-3 * s1, "s[{c}]={} should vanish", s[c]);
        }
        let row0: f32 = vt[..n].iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((row0 - 1.0).abs() < 1e-4, "v_0 not unit: {row0}");
    }

    #[test]
    fn topr_degenerate_shapes() {
        // m=1 / n=1 / rank 0 / rank = min(m, n) all route through the
        // full-oracle fallback and must keep the documented shapes
        let mut rng = Rng::new(23);
        let row = rng.normal_vec(9, 1.0);
        let (u, s, vt) = svd_topr(&row, 1, 9, 1);
        assert_eq!((u.len(), s.len(), vt.len()), (1, 1, 9));
        let (u, s, vt) = svd_topr(&row, 9, 1, 3);
        assert_eq!((u.len(), s.len(), vt.len()), (9, 1, 1));
        let (u, s, vt) = svd_topr(&row, 3, 3, 0);
        assert!(u.is_empty() && s.is_empty() && vt.is_empty());
        let sq = rng.normal_vec(36, 1.0);
        let (_, s, _) = svd_topr(&sq, 6, 6, 6);
        let (_, sf, _) = svd(&sq, 6, 6);
        for (a, b) in s.iter().zip(&sf) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn topr_is_deterministic() {
        let mut rng = Rng::new(29);
        let (m, n, r) = (56usize, 48usize, 4usize);
        let a = rng.normal_vec(m * n, 1.0);
        let (u1, s1, v1) = svd_topr(&a, m, n, r);
        let (u2, s2, v2) = svd_topr(&a, m, n, r);
        assert_eq!(u1, u2);
        assert_eq!(s1, s2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn rank_counting() {
        let mut rng = Rng::new(5);
        let (m, n, r) = (30usize, 30usize, 5usize);
        let u = rng.normal_vec(m * r, 1.0);
        let v = rng.normal_vec(r * n, 1.0);
        let a = matmul(&u, &v, m, r, n);
        assert_eq!(rank_above(&a, m, n, 10.0), r);
    }
}

//! Dense symmetric eigensolver (cyclic Jacobi) + exact small-matrix SVD
//! + the top-r subspace path the exact oracle actually runs on.
//!
//! The HLO interchange cannot carry LAPACK custom-calls, and the runtime
//! path uses randomized subspace iteration (runtime/linalg.rs). This module
//! is the *exact* host-side oracle used for (a) cross-checking the
//! randomized factors in tests, (b) Fig. 13-style rank counting of update
//! matrices, and (c) the small-side rotation of subspace factors. O(n^3)
//! per sweep — fine for the n <= ~2k matrices it sees.
//!
//! Two tiers live here:
//!   * [`eigh64`] / [`svd`] — the full-spectrum Jacobi oracle, retained
//!     for the tail-component ablation strategies, Fig. 13 rank counting,
//!     and as the reference the property suite checks against;
//!   * [`svd_topr`] — a deterministic blocked subspace iteration that
//!     computes only the top-r singular triplets. [`lowrank_approx`]
//!     (the paper's Eq. 1 oracle) routes through it, so a rank-32
//!     reconstruction of a 2k-side matrix no longer pays for the other
//!     ~2k components; accuracy vs the Jacobi oracle is bounded by
//!     [`TOPR_SV_TOL`] / [`TOPR_RECON_SLACK`] (asserted in
//!     `rust/tests/properties.rs`).
//!
//! # Steady-state performance (the hot-loop overhaul)
//!
//! Training refreshes the decomposition of every weight matrix each
//! `interval` steps, and the paper's own observation — the principal
//! subspace is stable across refreshes — makes the previous refresh an
//! excellent starting guess for the next one. [`svd_topr_warm`] exploits
//! that:
//!
//! * **warm start** — the converged iteration block of a refresh is
//!   returned as a [`SubspaceWarm`] carrier; seeding the next refresh
//!   from it typically converges in 1–3 passes instead of a cold start's
//!   tens. Carriers are bit-exact serializable (the method families
//!   persist them through `crate::ckpt`), so crash-resume replays warm
//!   refreshes identically.
//! * **invalidation rules** — a carrier is used only when its `(p, n)`
//!   block shape matches the current problem, and a warm start is
//!   accepted only when the drift guard passes: over the (at most)
//!   [`TOPR_WARM_MAX_ITERS`] warm passes the block's Rayleigh trace may
//!   grow by at most [`TOPR_WARM_DRIFT_TOL`] — a stale carrier (the
//!   subspace rotated, e.g. after an LR spike) overshoots that and
//!   deterministically restarts cold. A bad carrier can cost
//!   iterations, never accuracy.
//!   The full-Jacobi small-problem fallback carries nothing (`None`).
//! * **scratch arenas** — every O(n²) intermediate (Gram matrix,
//!   iteration blocks, packing buffers) lives in a caller-owned
//!   [`EighScratch`], so the layer-parallel engine's workers reuse one
//!   arena across all the matrices they process instead of re-allocating
//!   per job.
//! * **blocked GEMM** — the Gram build and the projection matmuls go
//!   through the cache-tiled, transpose-packed kernels in
//!   [`crate::util::gemm`], shared with `runtime::linalg`. Those
//!   kernels carry the raw-speed tier: AVX2 microkernels behind runtime
//!   detection (bit-identical to the scalar fallback by a documented
//!   summation order), and — when [`EighScratch::with_par_workers`]
//!   grants a budget — intra-matrix parallel row tiles fanned over the
//!   `lift::engine` pool, so one large matrix no longer serializes
//!   behind a single worker (bit-identical to serial by the disjoint
//!   tile-ownership contract; see the `gemm` module doc).
//! * **quantized scan** (`LiftCfg.qscan` / `LIFT_QSCAN=1`) — when the
//!   arena's [`EighScratch::qscan`] toggle is on, the Gram build and
//!   the subspace iteration's G-applies route through the int8
//!   blockwise kernels (`gemm::gram_q8_par` / `gemm::matmul_q8_par`),
//!   moving ~8x less memory per pass. Rayleigh–Ritz, the small
//!   eigensolve, the V/U projections, and the small-problem Jacobi
//!   fallback all stay f64 — only the iteration operand is lossy.
//!   Selection tolerates this because it consumes the *ordering* of
//!   |W'| magnitudes, not the values; the contract is the
//!   [`LIFT_QSCAN_TOL`] mask-overlap gate instead of bit-identity.
//!   Training deltas never flow through this tier (the trainers apply
//!   updates to the f32 weights directly), which is why quantization is
//!   safe here and would not be there.
//!
//! All of it preserves the engine's determinism contract: every result
//! is a pure function of `(a, m, n, r, warm)` — plus the qscan toggle —
//! never of the worker count, scheduling order, or allocation reuse.

use crate::util::gemm;

/// Descending float order with NaN pinned *last*, regardless of NaN
/// sign. A NaN eigenvalue carries no ordering information — pinning it
/// after every finite value keeps a diverged matrix's leading
/// components the meaningful ones (and keeps the sort total, where
/// `partial_cmp` would have panicked).
fn nan_last_desc(a: f64, b: f64) -> std::cmp::Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => std::cmp::Ordering::Equal,
        (true, false) => std::cmp::Ordering::Greater,
        (false, true) => std::cmp::Ordering::Less,
        (false, false) => b.total_cmp(&a),
    }
}

/// Jacobi eigendecomposition of a symmetric matrix (row-major, n x n).
/// Returns (eigenvalues desc, eigenvectors as columns, row-major n x n).
pub fn eigh(a: &[f32], n: usize) -> (Vec<f32>, Vec<f32>) {
    let a64: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    let (w, v) = eigh64(&a64, n);
    (
        w.iter().map(|&x| x as f32).collect(),
        v.iter().map(|&x| x as f32).collect(),
    )
}

/// f64 Jacobi core — the Gram matrix must stay in f64 end-to-end or the
/// sqrt amplifies rounding into a ~1e-4-relative singular-value noise
/// floor (breaks Fig. 13 rank counting).
pub fn eigh64(a: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), n * n);
    let mut a: Vec<f64> = a.to_vec();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for p in 0..n {
            for q in (p + 1)..n {
                off += a[p * n + q] * a[p * n + q];
            }
        }
        if off.sqrt() < 1e-11 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // A <- J^T A J on rows/cols p, q
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    // sort by eigenvalue descending; a NaN diagonal (diverged input)
    // must order deterministically instead of panicking (ISSUE 10)
    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    order.sort_by(|&i, &j| nan_last_desc(evals[i], evals[j]));
    let mut w = Vec::with_capacity(n);
    let mut vecs = vec![0.0f64; n * n];
    for (new, &old) in order.iter().enumerate() {
        w.push(evals[old]);
        for k in 0..n {
            vecs[k * n + new] = v[k * n + old];
        }
    }
    (w, vecs)
}

/// Exact thin SVD of an m x n matrix (row-major) via eigh of the Gram
/// matrix on the smaller side. Returns (u m x r, s r, vt r x n), r = min(m, n).
pub fn svd(a: &[f32], m: usize, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(a.len(), m * n);
    let r = m.min(n);
    if n <= m {
        // G = A^T A (n x n); A = U S V^T, G = V S^2 V^T
        let mut g = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i..n {
                let mut acc = 0.0f64;
                for k in 0..m {
                    acc += a[k * n + i] as f64 * a[k * n + j] as f64;
                }
                g[i * n + j] = acc;
                g[j * n + i] = acc;
            }
        }
        let (w, vfull) = eigh64(&g, n);
        let mut s = vec![0.0f32; r];
        let mut u = vec![0.0f32; m * r];
        let mut vt = vec![0.0f32; r * n];
        for c in 0..r {
            // NaN Ritz values (diverged input) must stay NaN: max(0.0)
            // would flush them to a silent zero singular value and the
            // caller would reconstruct an innocent-looking zero matrix
            // instead of a loud NaN one (ISSUE 10)
            let sc = if w[c].is_nan() { f64::NAN } else { w[c].max(0.0).sqrt() };
            s[c] = sc as f32;
            for k in 0..n {
                vt[c * n + k] = vfull[k * n + c] as f32;
            }
            // u_c = A v_c / s_c
            if sc > 1e-12 {
                for row in 0..m {
                    let mut acc = 0.0f64;
                    for k in 0..n {
                        acc += a[row * n + k] as f64 * vfull[k * n + c];
                    }
                    u[row * r + c] = (acc / sc) as f32;
                }
            }
        }
        (u, s, vt)
    } else {
        // transpose route: svd(A^T) then swap
        let mut at = vec![0.0f32; n * m];
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = a[i * n + j];
            }
        }
        let (ut, s, vtt) = svd(&at, n, m);
        // A = (V_t)^T S U_t^T  =>  U = vtt^T (m x r), V^T = ut^T (r x n)
        let mut u = vec![0.0f32; m * r];
        let mut vt = vec![0.0f32; r * n];
        for i in 0..m {
            for c in 0..r {
                u[i * r + c] = vtt[c * m + i];
            }
        }
        for c in 0..r {
            for j in 0..n {
                vt[c * n + j] = ut[j * r + c];
            }
        }
        (u, s, vt)
    }
}

/// Accuracy contract of [`svd_topr`] against the full-spectrum [`svd`]
/// oracle (asserted by `rust/tests/properties.rs`):
/// every returned singular value is within `TOPR_SV_TOL * s_max` of the
/// oracle's value at the same position. The worst case is an adversarial
/// near-flat spectrum (`s_r ~ s_{p+1}`, p = r + oversample), where
/// subspace iteration converges slowly; observed error there is ~2e-3,
/// while decaying spectra land near f64 round-off (~1e-15).
pub const TOPR_SV_TOL: f32 = 1e-2;

/// Companion bound: the top-r reconstruction's Frobenius error exceeds
/// the oracle's best-rank-r error by at most `TOPR_RECON_SLACK * |A|_F`.
/// Near-flat spectra are again the worst case (~3e-4 observed), and there
/// any rank-r subspace is near-optimal, which is what keeps the slack
/// small even when individual vectors have not converged.
///
/// Warm-started refreshes ([`svd_topr_warm`]) live under the same two
/// bounds: a warm start either converges to the same tolerance or the
/// drift guard restarts it cold, so the contract is start-independent
/// (asserted warm-vs-cold in `rust/tests/properties.rs`).
pub const TOPR_RECON_SLACK: f32 = 1e-3;

/// Oversampling columns of the iteration block (p = r + this).
const TOPR_OVERSAMPLE: usize = 8;
/// Iteration cap; each pass multiplies the error by (s_{p+1}/s_r)^2.
const TOPR_MAX_ITERS: usize = 60;
/// Warm-start iteration budget — a fixed, small number of corrective
/// passes (early-exited by the trace test when it fires sooner). Still
/// ~6x fewer G-applies than a cold start that runs to its cap, which is
/// where the steady-state refresh saving comes from.
pub const TOPR_WARM_MAX_ITERS: usize = 10;
/// Drift guard for warm starts: the carrier is accepted only when the
/// block's Rayleigh trace grew by at most this fraction over the warm
/// passes. A carrier near the current top subspace barely moves the
/// trace (drift enters at second order); a stale or junk carrier on any
/// spectrum with real decay is pulled sharply toward the dominant
/// subspace, overshooting this bound within a pass or two, and triggers
/// the deterministic cold restart. (On a near-flat spectrum a junk
/// carrier can slip under the bound — and there every rank-r subspace
/// is near-optimal, which is exactly the argument behind
/// [`TOPR_RECON_SLACK`], so accuracy still holds.) The *strict* trace
/// tolerance deliberately plays no role here: on flat spectra it may
/// not fire within any small budget, and gating on it would turn every
/// warm start into a cold restart plus overhead.
pub const TOPR_WARM_DRIFT_TOL: f64 = 0.05;
/// Early exit when trace(X^T G X) is relatively stable between passes.
const TOPR_TRACE_TOL: f64 = 1e-12;

/// Selection-tolerance contract of the quantized scan (ISSUE 10, in the
/// spirit of [`TOPR_SV_TOL`]): on the standard selection fixtures
/// (low-rank-plus-noise and plain Gaussian matrices across shapes and
/// spectra), the mask selected from a quantized rank reduction overlaps
/// the f32-scan mask by at least this fraction (property-tested in
/// `rust/tests/properties.rs`; `LIFT_QSCAN_TOL` in the environment
/// overrides the floor there for exploratory runs).
///
/// Why a mask-overlap gate and not a value tolerance: the quantized
/// tier perturbs every Gram entry by up to ~2 quantization steps
/// (`util::gemm` blockwise bound), which perturbs |W'| magnitudes by
/// O(0.5%) — enough to swap entries *at the top-k boundary*, where
/// magnitudes are near-tied and either choice is equally principled,
/// but not enough to move the selected set materially. Selection
/// consumes only the ordering; training (which would integrate the
/// error step after step) never touches this path.
pub const LIFT_QSCAN_TOL: f64 = 0.99;

/// Warm-start carrier: the converged subspace-iteration block of a
/// previous [`svd_topr_warm`] call on (a drifted version of) the same
/// matrix. `xt` is the row-major `p × n` orthonormal basis of the
/// small-side iteration space, kept in f64 so serializing it through
/// `crate::ckpt` round-trips bit-exactly (crash-resume replays warm
/// refreshes identically — `rust/tests/ckpt.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct SubspaceWarm {
    /// Block width at capture time (`r + oversample`, clamped).
    pub p: usize,
    /// Small-side dimension the block spans.
    pub n: usize,
    /// Row-major `p × n` orthonormal block.
    pub xt: Vec<f64>,
}

impl SubspaceWarm {
    /// Shape check against the current problem — a mismatched carrier
    /// (rank or matrix shape changed) is ignored, never misused.
    fn matches(&self, p: usize, n: usize) -> bool {
        self.p == p && self.n == n && self.xt.len() == p * n
    }
}

/// Reusable scratch arena for the exact decomposition path: every O(n²)
/// intermediate of [`svd_topr_warm`] / [`lowrank_approx_warm`] lives
/// here, so a worker that processes many matrices allocates these
/// buffers once. Buffers are resized (and re-zeroed only where the
/// algorithm actually reads zeros) per call; reuse cannot leak state
/// between jobs, so results are identical whether an arena is shared or
/// fresh. The arena also carries the intra-matrix parallelism budget
/// ([`EighScratch::with_par_workers`]) — a different budget changes
/// only wall-clock, never bits (the gemm tile-ownership contract).
#[derive(Default)]
pub struct EighScratch {
    /// Gram matrix (n × n, f64).
    g: Vec<f64>,
    /// Transpose-pack buffer for the Gram build (`gemm::gram_f64`).
    pack: Vec<f64>,
    /// Subspace-iteration block (p × n, f64).
    xt: Vec<f64>,
    /// G-applied block (p × n, f64); doubles as the scaled-basis buffer
    /// of the final U projection.
    yt: Vec<f64>,
    /// Rayleigh–Ritz matrix (p × p, f64).
    t: Vec<f64>,
    /// Rotated small-side basis V (n × r, f64).
    v: Vec<f64>,
    /// Leading r columns of the Ritz rotation (p × r, f64).
    zr: Vec<f64>,
    /// Transpose buffer for the wide (n > m) route, f32.
    at: Vec<f32>,
    /// Row accumulator arena for the mixed-precision products
    /// (`gemm::matmul_f32xf64_with` / `_par`) — also reused by
    /// `runtime::linalg::truncate_factors_with`.
    pub(crate) mm_acc: Vec<f64>,
    /// Intra-matrix parallelism budget for the GEMM calls issued through
    /// this arena (0 and 1 both mean serial). Set by the engine when
    /// pool capacity exceeds the number of in-flight matrices.
    par_workers: usize,
    /// Quantized-scan toggle: when set, the Gram build and the subspace
    /// iteration's G-applies run on the int8 tier (module doc). Set by
    /// `lift::rank_reduce_warm` from `LiftCfg.qscan` / `LIFT_QSCAN`.
    qscan: bool,
    /// Quantized transpose pack for the q8 Gram build.
    qpack: gemm::QuantMat,
    /// Quantized Gram operand (rows of G), built once per refresh.
    qg: gemm::QuantMat,
    /// Quantized iteration block, rebuilt each pass.
    qx: gemm::QuantMat,
}

impl EighScratch {
    pub fn new() -> EighScratch {
        EighScratch::default()
    }

    /// Arena whose GEMM calls may fan row tiles across up to `workers`
    /// pool threads (bit-identical to serial for any count — the gemm
    /// tile-ownership contract).
    pub fn with_par_workers(workers: usize) -> EighScratch {
        EighScratch {
            par_workers: workers,
            ..EighScratch::default()
        }
    }

    /// The effective worker budget (>= 1) for GEMMs through this arena.
    pub fn par_workers(&self) -> usize {
        self.par_workers.max(1)
    }

    /// Toggle the quantized scan for subsequent calls through this
    /// arena. Changing it changes which documented contract applies
    /// (bit-exactness of the f64 tier vs the [`LIFT_QSCAN_TOL`]
    /// overlap gate) — never worker-count or scratch-reuse behavior.
    pub fn set_qscan(&mut self, on: bool) {
        self.qscan = on;
    }

    /// Whether this arena routes the scan through the quantized tier.
    pub fn qscan(&self) -> bool {
        self.qscan
    }
}

/// Clear-and-zero a scratch buffer to `len` (capacity is reused). Only
/// for buffers whose consumer actually reads zeros (e.g. the scaled
/// basis, where vanishing singular values must leave zero columns).
fn zeroed(buf: &mut Vec<f64>, len: usize) {
    buf.clear();
    buf.resize(len, 0.0);
}

/// Size a scratch buffer to `len` without the redundant zero pass — for
/// buffers whose every element is overwritten before being read. A
/// shrinking call truncates in place; capacity is always reused
/// (the arena contract, see `util::gemm`).
fn sized(buf: &mut Vec<f64>, len: usize) {
    buf.resize(len, 0.0);
}

/// Top-r thin SVD of an m x n matrix (row-major) by blocked subspace
/// iteration on the smaller-side Gram matrix, entirely in f64 on the
/// host. Returns (u m x r, s r, vt r x n), r clamped to min(m, n).
///
/// Deterministic: the start block comes from a fixed-seed [`Rng`], so
/// the result is a pure function of `(a, m, n, r)` — the layer-parallel
/// engine can run one decomposition per worker without the worker count
/// or scheduling order leaking into the factors. Small problems
/// (2(r + oversample) >= min(m, n)) fall back to the full Jacobi
/// oracle, where iteration would save nothing.
///
/// This is the cold-start convenience wrapper over [`svd_topr_warm`]
/// (fresh scratch, no carrier).
///
/// [`Rng`]: crate::util::rng::Rng
pub fn svd_topr(a: &[f32], m: usize, n: usize, r: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut scratch = EighScratch::default();
    let (u, s, vt, _) = svd_topr_warm(a, m, n, r, None, &mut scratch);
    (u, s, vt)
}

/// [`svd_topr`] with a warm-start carrier and a caller-owned scratch
/// arena — the steady-state refresh path. Returns the factors plus the
/// carrier for the *next* refresh (`None` when the problem routed
/// through the full-Jacobi fallback, which has no iteration block).
///
/// The result is a pure function of `(a, m, n, r, warm)`: a matching
/// carrier seeds the iteration (capped at [`TOPR_WARM_MAX_ITERS`]
/// passes, falling back to the fixed-seed cold start on drift), a
/// mismatched or absent one runs the cold path — both deterministic,
/// both inside the [`TOPR_SV_TOL`] / [`TOPR_RECON_SLACK`] contract.
pub fn svd_topr_warm(
    a: &[f32],
    m: usize,
    n: usize,
    r: usize,
    warm: Option<&SubspaceWarm>,
    scratch: &mut EighScratch,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Option<SubspaceWarm>) {
    assert_eq!(a.len(), m * n);
    let minmn = m.min(n);
    let r = r.min(minmn);
    if r == 0 {
        return (Vec::new(), Vec::new(), Vec::new(), None);
    }
    let p = (r + TOPR_OVERSAMPLE).min(minmn);
    if 2 * p >= minmn {
        let (uf, sf, vtf) = svd(a, m, n);
        let mut u = vec![0.0f32; m * r];
        for i in 0..m {
            u[i * r..(i + 1) * r].copy_from_slice(&uf[i * minmn..i * minmn + r]);
        }
        return (u, sf[..r].to_vec(), vtf[..r * n].to_vec(), None);
    }
    if n > m {
        // transpose route: svd_topr(A^T) then swap factors. The `at`
        // buffer is taken out of the arena so the recursive call (which
        // runs the n <= m branch and never touches `at`) can borrow the
        // rest of the scratch. No clear(): every element is written by
        // the transpose loop, so a bare resize skips the zero pass.
        let mut at = std::mem::take(&mut scratch.at);
        at.resize(n * m, 0.0);
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = a[i * n + j];
            }
        }
        let (ut, s, vtt, carrier) = svd_topr_warm(&at, n, m, r, warm, scratch);
        scratch.at = at;
        // A = (V_t)^T S U_t^T  =>  U = vtt^T (m x r), V^T = ut^T (r x n)
        let mut u = vec![0.0f32; m * r];
        let mut vt = vec![0.0f32; r * n];
        for i in 0..m {
            for c in 0..r {
                u[i * r + c] = vtt[c * m + i];
            }
        }
        for c in 0..r {
            for j in 0..n {
                vt[c * n + j] = ut[j * r + c];
            }
        }
        return (u, s, vt, carrier);
    }
    // n <= m: iterate on G = A^T A (n x n, f64), built by the
    // transpose-packed blocked kernel (fanned across the pool when the
    // arena carries an intra-matrix budget). Basis vectors are rows of
    // xt (p x n) so Gram-Schmidt and the G-apply stay contiguous.
    let wk = scratch.par_workers();
    let qscan = scratch.qscan;
    sized(&mut scratch.g, n * n);
    if qscan {
        // int8 Gram + a quantized copy of G for the iteration's
        // G-applies; RR and the projections below still read the f64 `g`
        gemm::gram_q8_par(a, m, n, &mut scratch.pack, &mut scratch.qpack, &mut scratch.g, wk);
        gemm::quantize_rows(&scratch.g, n, n, &mut scratch.qg);
    } else {
        gemm::gram_f64_par(a, m, n, &mut scratch.pack, &mut scratch.g, wk);
    }
    let g = &scratch.g;
    let qg = if qscan { Some(&scratch.qg) } else { None };

    // start block: the carrier when it fits, else the fixed-seed cold
    // start (determinism is part of the contract either way)
    sized(&mut scratch.xt, p * n);
    sized(&mut scratch.yt, p * n);
    let warm_started = match warm {
        Some(w) if w.matches(p, n) => {
            scratch.xt.copy_from_slice(&w.xt);
            true
        }
        _ => false,
    };
    if !warm_started {
        cold_start_block(&mut scratch.xt);
    }
    orthonormalize_rows(&mut scratch.xt, p, n);
    let budget = if warm_started { TOPR_WARM_MAX_ITERS } else { TOPR_MAX_ITERS };
    let (_, tr_first, tr_last) = iterate_block(
        g,
        qg,
        &mut scratch.qx,
        &mut scratch.xt,
        &mut scratch.yt,
        p,
        n,
        budget,
        wk,
    );
    let drifted = warm_started
        && (tr_last - tr_first).abs() > TOPR_WARM_DRIFT_TOL * tr_last.abs().max(1e-300);
    if drifted {
        // drift guard (see TOPR_WARM_DRIFT_TOL): the carried subspace no
        // longer tracks the top-p space — restart cold so accuracy never
        // depends on carrier age. The cold restart re-seeds from the
        // fixed Rng, so the result is bit-identical to a cold svd_topr
        // of the same matrix.
        cold_start_block(&mut scratch.xt);
        orthonormalize_rows(&mut scratch.xt, p, n);
        iterate_block(
            g,
            qg,
            &mut scratch.qx,
            &mut scratch.xt,
            &mut scratch.yt,
            p,
            n,
            TOPR_MAX_ITERS,
            wk,
        );
    }
    if qscan {
        // one f64 polish pass: the int8 passes steer the block cheaply,
        // then a single full-precision apply collapses the residual
        // quantization angle before Rayleigh-Ritz reads the block —
        // this is what keeps the LIFT_QSCAN_TOL overlap contract robust
        // across spectra instead of marginal
        gemm::matmul_f64_par(&scratch.xt, g, p, n, n, &mut scratch.yt, wk);
        std::mem::swap(&mut scratch.xt, &mut scratch.yt);
        orthonormalize_rows(&mut scratch.xt, p, n);
    }
    let xt = &scratch.xt;

    // Rayleigh-Ritz: rotate the converged block into singular order
    // (yt kept its p × n size through the iteration's ping-pong swaps)
    gemm::matmul_f64_par(xt, g, p, n, n, &mut scratch.yt, wk);
    let yt = &scratch.yt;
    sized(&mut scratch.t, p * p);
    for b in 0..p {
        for c in b..p {
            let xrow = &xt[b * n..(b + 1) * n];
            let yrow = &yt[c * n..(c + 1) * n];
            let mut acc = 0.0f64;
            for i in 0..n {
                acc += xrow[i] * yrow[i];
            }
            scratch.t[b * p + c] = acc;
            scratch.t[c * p + b] = acc;
        }
    }
    let (w, z) = eigh64(&scratch.t, p);
    // V = Xt^T · Z[:, :r]  (n × r) via the shared transpose-product kernel
    sized(&mut scratch.zr, p * r);
    for b in 0..p {
        for c in 0..r {
            scratch.zr[b * r + c] = z[b * p + c];
        }
    }
    sized(&mut scratch.v, n * r);
    gemm::matmul_tn_f64_par(xt, &scratch.zr, p, n, r, &mut scratch.v, wk);
    let mut s = vec![0.0f32; r];
    let mut vt = vec![0.0f32; r * n];
    for c in 0..r {
        // NaN Ritz values propagate (see `svd`): a diverged matrix must
        // reduce to a loud NaN reconstruction, not a silent zero one
        s[c] = if w[c].is_nan() { f32::NAN } else { w[c].max(0.0).sqrt() as f32 };
        for j in 0..n {
            vt[c * n + j] = scratch.v[j * r + c] as f32;
        }
    }
    // U = A · (V diag(1/s)) in one blocked mixed-precision product;
    // columns with vanishing singular values stay zero (as before) —
    // this buffer genuinely needs the zero fill, so `zeroed` stays.
    // yt is free again — reuse it for the scaled basis (n × r <= p × n).
    zeroed(&mut scratch.yt, n * r);
    for c in 0..r {
        let sc = w[c].max(0.0).sqrt();
        if sc > 1e-12 {
            let inv = 1.0 / sc;
            for j in 0..n {
                scratch.yt[j * r + c] = scratch.v[j * r + c] * inv;
            }
        }
    }
    let mut u = vec![0.0f32; m * r];
    gemm::matmul_f32xf64_par(a, &scratch.yt, m, n, r, &mut u, wk, &mut scratch.mm_acc);
    let carrier = SubspaceWarm {
        p,
        n,
        xt: scratch.xt.clone(),
    };
    (u, s, vt, Some(carrier))
}

/// Fill the iteration block from the fixed-seed generator (the cold
/// start [`svd_topr`] documents — determinism is part of the contract).
fn cold_start_block(xt: &mut [f64]) {
    let mut rng = crate::util::rng::Rng::new(0x70b5_eed0_5bd7_0b5e);
    for x in xt.iter_mut() {
        *x = rng.normal() as f64;
    }
}

/// Run up to `max_iters` subspace-iteration passes of `xt` against `g`
/// (both row-major; `yt` is the ping-pong buffer). The G-apply fans row
/// tiles over up to `workers` pool threads (bit-identical to serial).
/// When `qg` carries the quantized Gram operand, each pass quantizes
/// the block into `qx` and applies `Y = X·G` on the int8 tier (G is
/// symmetric, so its quantized rows serve as its columns); the trace
/// test and orthonormalization stay f64 either way. Returns whether the
/// trace-convergence test fired inside the budget, plus the first and
/// last pass's Rayleigh traces — the warm path's drift guard reads
/// their growth ([`TOPR_WARM_DRIFT_TOL`]).
#[allow(clippy::too_many_arguments)]
fn iterate_block(
    g: &[f64],
    qg: Option<&gemm::QuantMat>,
    qx: &mut gemm::QuantMat,
    xt: &mut Vec<f64>,
    yt: &mut Vec<f64>,
    p: usize,
    n: usize,
    max_iters: usize,
    workers: usize,
) -> (bool, f64, f64) {
    let mut prev_tr = f64::NEG_INFINITY;
    let mut tr_first = f64::NAN;
    let mut tr_last = f64::NAN;
    for it in 0..max_iters {
        match qg {
            Some(qg) => {
                gemm::quantize_rows(xt, p, n, qx);
                gemm::matmul_q8_par(qx, qg, yt, workers);
            }
            None => gemm::matmul_f64_par(xt, g, p, n, n, yt, workers),
        }
        let mut tr = 0.0f64;
        for (x, y) in xt.iter().zip(yt.iter()) {
            tr += x * y;
        }
        if it == 0 {
            tr_first = tr;
        }
        tr_last = tr;
        let done =
            prev_tr.is_finite() && (tr - prev_tr).abs() <= TOPR_TRACE_TOL * tr.abs().max(1e-300);
        prev_tr = tr;
        std::mem::swap(xt, yt);
        orthonormalize_rows(xt, p, n);
        if done {
            return (true, tr_first, tr_last);
        }
    }
    (false, tr_first, tr_last)
}

/// Orthonormalize the rows of `xt` (p x n, row-major) by modified
/// Gram-Schmidt with two projection passes per row ("twice is enough"):
/// one pass leaves cancellation junk correlated with the earlier rows
/// when the block is numerically rank-deficient, which inflates the
/// Ritz values. Rows that collapse entirely are replaced by a cycling
/// unit basis vector (deterministic), keeping the block full rank for
/// rank-deficient inputs.
fn orthonormalize_rows(xt: &mut [f64], p: usize, n: usize) {
    // project row j against the already-orthonormal rows 0..j, twice
    fn project_out(head: &[f64], row: &mut [f64], j: usize, n: usize) {
        for _pass in 0..2 {
            for i in 0..j {
                let prev = &head[i * n..(i + 1) * n];
                let mut dot = 0.0f64;
                for k in 0..n {
                    dot += prev[k] * row[k];
                }
                for k in 0..n {
                    row[k] -= dot * prev[k];
                }
            }
        }
    }
    for j in 0..p {
        let (head, tail) = xt.split_at_mut(j * n);
        let row = &mut tail[..n];
        project_out(head, row, j, n);
        let nrm = row.iter().map(|x| x * x).sum::<f64>().sqrt();
        if nrm < 1e-30 {
            // dead row (rank-deficient block): deterministic rescue with
            // a cycling basis vector, re-orthogonalized the same way
            for (k, x) in row.iter_mut().enumerate() {
                *x = if k == j % n { 1.0 } else { 0.0 };
            }
            project_out(head, row, j, n);
            let nrm2 = row.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
            for x in row.iter_mut() {
                *x /= nrm2;
            }
        } else {
            for x in row.iter_mut() {
                *x /= nrm;
            }
        }
    }
}

/// Rank-r reconstruction (the paper's Eq. 1 oracle), now through the
/// top-r subspace path — only the requested components are computed.
/// Cold-start wrapper over [`lowrank_approx_warm`].
pub fn lowrank_approx(a: &[f32], m: usize, n: usize, rank: usize) -> Vec<f32> {
    let mut scratch = EighScratch::default();
    lowrank_approx_warm(a, m, n, rank, None, &mut scratch).0
}

/// [`lowrank_approx`] with warm start + scratch arena (the per-refresh
/// path the mask engine drives). Returns the reconstruction and the
/// carrier for the next refresh of the same matrix.
pub fn lowrank_approx_warm(
    a: &[f32],
    m: usize,
    n: usize,
    rank: usize,
    warm: Option<&SubspaceWarm>,
    scratch: &mut EighScratch,
) -> (Vec<f32>, Option<SubspaceWarm>) {
    let rank = rank.min(m.min(n));
    let (u, s, vt, carrier) = svd_topr_warm(a, m, n, rank, warm, scratch);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for c in 0..rank {
            let uis = u[i * rank + c] * s[c];
            if uis == 0.0 {
                continue;
            }
            let row = &vt[c * n..(c + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += uis * row[j];
            }
        }
    }
    (out, carrier)
}

/// Count of singular values above `tau` (Fig. 13 rank metric).
pub fn rank_above(a: &[f32], m: usize, n: usize, tau_mult: f32) -> usize {
    let (_, s, _) = svd(a, m, n);
    let smax = s.first().copied().unwrap_or(0.0);
    // paper: tau = 10 x default = 10 * max(m,n) * smax * eps_f32
    let tau = tau_mult * m.max(n) as f32 * smax * f32::EPSILON;
    s.iter().filter(|&&x| x > tau).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for l in 0..k {
                let ail = a[i * k + l];
                for j in 0..n {
                    c[i * n + j] += ail * b[l * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn eigh_diagonal() {
        let a = vec![3.0, 0.0, 0.0, 1.0];
        let (w, v) = eigh(&a, 2);
        assert!((w[0] - 3.0).abs() < 1e-5 && (w[1] - 1.0).abs() < 1e-5);
        // columns orthonormal
        let dot = v[0] * v[1] + v[2] * v[3];
        assert!(dot.abs() < 1e-5);
    }

    #[test]
    fn eigh_reconstructs() {
        let mut rng = Rng::new(42);
        let n = 16;
        let b = rng.normal_vec(n * n, 1.0);
        // symmetrize
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = 0.5 * (b[i * n + j] + b[j * n + i]);
            }
        }
        let (w, v) = eigh(&a, n);
        // A v_c = w_c v_c
        for c in 0..n {
            for i in 0..n {
                let mut av = 0.0;
                for k in 0..n {
                    av += a[i * n + k] * v[k * n + c];
                }
                assert!(
                    (av - w[c] * v[i * n + c]).abs() < 1e-3,
                    "c={c} i={i}: {av} vs {}",
                    w[c] * v[i * n + c]
                );
            }
        }
    }

    #[test]
    fn svd_reconstructs_tall_and_wide() {
        let mut rng = Rng::new(7);
        for (m, n) in [(20usize, 8usize), (8, 20), (12, 12)] {
            let a = rng.normal_vec(m * n, 1.0);
            let (u, s, vt) = svd(&a, m, n);
            let r = m.min(n);
            let mut us = vec![0.0f32; m * r];
            for i in 0..m {
                for c in 0..r {
                    us[i * r + c] = u[i * r + c] * s[c];
                }
            }
            let rec = matmul(&us, &vt, m, r, n);
            for i in 0..m * n {
                assert!((rec[i] - a[i]).abs() < 1e-3, "({m},{n}) idx {i}");
            }
            // singular values sorted desc, nonnegative
            for c in 1..r {
                assert!(s[c - 1] >= s[c] - 1e-5);
                assert!(s[c] >= -1e-6);
            }
        }
    }

    #[test]
    fn lowrank_is_best_approx() {
        // rank-2 matrix + noise: rank-2 approx error must be ~ noise level
        let mut rng = Rng::new(3);
        let (m, n, r) = (24, 16, 2);
        let u = rng.normal_vec(m * r, 1.0);
        let v = rng.normal_vec(r * n, 1.0);
        let mut a = matmul(&u, &v, m, r, n);
        for x in a.iter_mut() {
            *x += rng.normal() * 1e-3;
        }
        let ar = lowrank_approx(&a, m, n, 2);
        let err: f32 = a.iter().zip(&ar).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(err.sqrt() < 0.1, "err={}", err.sqrt());
    }

    #[test]
    fn topr_matches_full_svd_on_leading_triplets() {
        let mut rng = Rng::new(21);
        // large enough that 2(r + oversample) < min(m, n): subspace path
        for (m, n, r) in [(60usize, 50usize, 5usize), (44, 72, 3)] {
            let a = rng.normal_vec(m * n, 1.0);
            let (uf, sf, vtf) = svd(&a, m, n);
            let (u, s, vt) = svd_topr(&a, m, n, r);
            assert_eq!(u.len(), m * r);
            assert_eq!(vt.len(), r * n);
            for c in 0..r {
                assert!(
                    (s[c] - sf[c]).abs() <= TOPR_SV_TOL * sf[0],
                    "({m},{n}) s[{c}]: topr {} vs oracle {}",
                    s[c],
                    sf[c]
                );
            }
            // returned factors actually reconstruct: U diag(s) V^T has the
            // oracle's rank-r error up to the documented slack
            let mut rec = vec![0.0f32; m * n];
            for i in 0..m {
                for c in 0..r {
                    let x = u[i * r + c] * s[c];
                    for j in 0..n {
                        rec[i * n + j] += x * vt[c * n + j];
                    }
                }
            }
            let oracle = {
                let rr = m.min(n);
                let mut o = vec![0.0f32; m * n];
                for i in 0..m {
                    for c in 0..r {
                        let x = uf[i * rr + c] * sf[c];
                        for j in 0..n {
                            o[i * n + j] += x * vtf[c * n + j];
                        }
                    }
                }
                o
            };
            let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let err = |rec: &[f32]| -> f32 {
                a.iter()
                    .zip(rec)
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum::<f32>()
                    .sqrt()
            };
            assert!(
                err(&rec) <= err(&oracle) + TOPR_RECON_SLACK * norm,
                "({m},{n}) recon {} vs oracle {}",
                err(&rec),
                err(&oracle)
            );
        }
    }

    #[test]
    fn topr_rank_deficient_input_is_exact() {
        // rank-1 all-ones matrix: the iteration block collapses and the
        // Gram-Schmidt rescue must keep the factors orthonormal — a
        // single-pass MGS inflates s[0] by sqrt(2) here
        let (m, n) = (50usize, 40usize);
        let a = vec![1.0f32; m * n];
        let (_, s, vt) = svd_topr(&a, m, n, 4);
        let s1 = ((m * n) as f32).sqrt();
        assert!((s[0] - s1).abs() < 1e-3 * s1, "s[0]={} want {s1}", s[0]);
        for c in 1..4 {
            assert!(s[c].abs() < 1e-3 * s1, "s[{c}]={} should vanish", s[c]);
        }
        let row0: f32 = vt[..n].iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((row0 - 1.0).abs() < 1e-4, "v_0 not unit: {row0}");
    }

    #[test]
    fn topr_degenerate_shapes() {
        // m=1 / n=1 / rank 0 / rank = min(m, n) all route through the
        // full-oracle fallback and must keep the documented shapes
        let mut rng = Rng::new(23);
        let row = rng.normal_vec(9, 1.0);
        let (u, s, vt) = svd_topr(&row, 1, 9, 1);
        assert_eq!((u.len(), s.len(), vt.len()), (1, 1, 9));
        let (u, s, vt) = svd_topr(&row, 9, 1, 3);
        assert_eq!((u.len(), s.len(), vt.len()), (9, 1, 1));
        let (u, s, vt) = svd_topr(&row, 3, 3, 0);
        assert!(u.is_empty() && s.is_empty() && vt.is_empty());
        let sq = rng.normal_vec(36, 1.0);
        let (_, s, _) = svd_topr(&sq, 6, 6, 6);
        let (_, sf, _) = svd(&sq, 6, 6);
        for (a, b) in s.iter().zip(&sf) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn topr_is_deterministic() {
        let mut rng = Rng::new(29);
        let (m, n, r) = (56usize, 48usize, 4usize);
        let a = rng.normal_vec(m * n, 1.0);
        let (u1, s1, v1) = svd_topr(&a, m, n, r);
        let (u2, s2, v2) = svd_topr(&a, m, n, r);
        assert_eq!(u1, u2);
        assert_eq!(s1, s2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn warm_start_tracks_a_drifting_matrix_within_tolerance() {
        // the training steady state: W drifts a little between
        // refreshes; a warm-started refresh must land inside the same
        // accuracy contract as a cold one
        let mut rng = Rng::new(31);
        let (m, n, r) = (64usize, 48usize, 5usize);
        let mut a = rng.normal_vec(m * n, 1.0);
        let mut scratch = EighScratch::new();
        let (_, _, _, mut carrier) = svd_topr_warm(&a, m, n, r, None, &mut scratch);
        assert!(carrier.is_some(), "subspace path must emit a carrier");
        for _refresh in 0..3 {
            for x in a.iter_mut() {
                *x += rng.normal() * 0.02; // small drift, like an optimizer step
            }
            let (uw, sw, vtw, next) =
                svd_topr_warm(&a, m, n, r, carrier.as_ref(), &mut scratch);
            let (_, sf, _) = svd(&a, m, n);
            for c in 0..r {
                assert!(
                    (sw[c] - sf[c]).abs() <= TOPR_SV_TOL * sf[0],
                    "warm s[{c}]: {} vs oracle {}",
                    sw[c],
                    sf[c]
                );
            }
            // warm factors reconstruct as well as the cold path's bound
            let mut rec = vec![0.0f32; m * n];
            for i in 0..m {
                for c in 0..r {
                    let x = uw[i * r + c] * sw[c];
                    for j in 0..n {
                        rec[i * n + j] += x * vtw[c * n + j];
                    }
                }
            }
            let (uc, sc, vtc) = svd_topr(&a, m, n, r);
            let mut rec_cold = vec![0.0f32; m * n];
            for i in 0..m {
                for c in 0..r {
                    let x = uc[i * r + c] * sc[c];
                    for j in 0..n {
                        rec_cold[i * n + j] += x * vtc[c * n + j];
                    }
                }
            }
            let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let err = |rec: &[f32]| -> f32 {
                a.iter()
                    .zip(rec)
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum::<f32>()
                    .sqrt()
            };
            assert!(
                err(&rec) <= err(&rec_cold) + TOPR_RECON_SLACK * norm,
                "warm recon {} vs cold {}",
                err(&rec),
                err(&rec_cold)
            );
            carrier = next;
        }
    }

    #[test]
    fn mismatched_or_drifted_carrier_falls_back_to_cold_bitwise() {
        let mut rng = Rng::new(37);
        let (m, n, r) = (60usize, 44usize, 4usize);
        let a = rng.normal_vec(m * n, 1.0);
        let cold = svd_topr(&a, m, n, r);
        let mut scratch = EighScratch::new();
        // wrong-shape carrier: ignored, result == cold bit-for-bit
        let bad_shape = SubspaceWarm {
            p: 3,
            n: 7,
            xt: vec![0.5; 21],
        };
        let (u, s, vt, _) = svd_topr_warm(&a, m, n, r, Some(&bad_shape), &mut scratch);
        assert_eq!((u, s, vt), cold.clone(), "mismatched carrier must act cold");
        // right-shape, wrong-subspace carrier: power iteration pulls a
        // random orthonormal block sharply toward the dominant subspace,
        // so its trace growth overshoots TOPR_WARM_DRIFT_TOL and the
        // guard restarts cold
        let p = r + 8;
        let mut junk = vec![0.0f64; p * n];
        let mut jrng = Rng::new(99);
        for x in junk.iter_mut() {
            *x = jrng.normal() as f64;
        }
        orthonormalize_rows(&mut junk, p, n);
        let drifted = SubspaceWarm { p, n, xt: junk };
        let (u2, s2, vt2, _) = svd_topr_warm(&a, m, n, r, Some(&drifted), &mut scratch);
        // either the guard accepted the block (possible only when the
        // spectrum is flat enough that any subspace is near-optimal) or
        // it restarted cold — in both cases values must sit within the
        // documented tolerance of the oracle
        let (_, sf, _) = svd(&a, m, n);
        for c in 0..r {
            assert!(
                (s2[c] - sf[c]).abs() <= TOPR_SV_TOL * sf[0],
                "drifted-carrier s[{c}] out of contract: {} vs {}",
                s2[c],
                sf[c]
            );
        }
        assert_eq!(u2.len(), m * r);
        assert_eq!(vt2.len(), r * n);
    }

    #[test]
    fn warm_refresh_is_deterministic_and_scratch_independent() {
        let mut rng = Rng::new(41);
        let (m, n, r) = (56usize, 48usize, 4usize);
        let a = rng.normal_vec(m * n, 1.0);
        let mut s1 = EighScratch::new();
        let mut s2 = EighScratch::new();
        let (_, _, _, c1) = svd_topr_warm(&a, m, n, r, None, &mut s1);
        // dirty s2 with an unrelated problem first: reuse must not leak
        let other = rng.normal_vec(40 * 30, 1.0);
        let _ = svd_topr_warm(&other, 40, 30, 3, None, &mut s2);
        let (_, _, _, c2) = svd_topr_warm(&a, m, n, r, None, &mut s2);
        assert_eq!(c1, c2, "carrier must not depend on scratch history");
        let w1 = svd_topr_warm(&a, m, n, r, c1.as_ref(), &mut s1);
        let w2 = svd_topr_warm(&a, m, n, r, c2.as_ref(), &mut s2);
        assert_eq!(w1.0, w2.0);
        assert_eq!(w1.1, w2.1);
        assert_eq!(w1.2, w2.2);
        assert_eq!(w1.3, w2.3);
    }

    #[test]
    fn rank_counting() {
        let mut rng = Rng::new(5);
        let (m, n, r) = (30usize, 30usize, 5usize);
        let u = rng.normal_vec(m * r, 1.0);
        let v = rng.normal_vec(r * n, 1.0);
        let a = matmul(&u, &v, m, r, n);
        assert_eq!(rank_above(&a, m, n, 10.0), r);
    }

    /// ISSUE-10 regression: a NaN on the diagonal (diverged input) used
    /// to panic the descending eigenvalue sort via `partial_cmp`. The
    /// pinned order now puts NaN last, keeping the leading components
    /// the meaningful ones.
    #[test]
    fn eigh_orders_nan_eigenvalues_last() {
        let n = 3;
        let mut a = vec![0.0f64; n * n];
        a[0] = 1.0;
        a[1 * n + 1] = f64::NAN;
        a[2 * n + 2] = 3.0;
        let (w, _) = eigh64(&a, n);
        assert_eq!(w[0], 3.0);
        assert_eq!(w[1], 1.0);
        assert!(w[2].is_nan(), "NaN eigenvalue must sort last: {w:?}");
        // and the pinned order is sign-agnostic for NaN
        use std::cmp::Ordering::*;
        assert_eq!(nan_last_desc(f64::NAN, f64::NEG_INFINITY), Greater);
        assert_eq!(nan_last_desc(-f64::NAN, f64::NEG_INFINITY), Greater);
        assert_eq!(nan_last_desc(2.0, f64::NAN), Less);
        assert_eq!(nan_last_desc(f64::NAN, f64::NAN), Equal);
        assert_eq!(nan_last_desc(1.0, 2.0), Greater);
    }

    /// The quantized scan stays inside a loose value tolerance of the
    /// f64 scan (the *selection* contract — LIFT_QSCAN_TOL mask overlap
    /// — is property-tested in rust/tests/properties.rs), is
    /// deterministic, and is worker-count invariant bitwise.
    #[test]
    fn qscan_subspace_tracks_f64_and_is_worker_invariant() {
        let mut rng = Rng::new(43);
        let (m, n, r) = (64usize, 48usize, 4usize);
        let u = rng.normal_vec(m * r, 1.0);
        let v = rng.normal_vec(r * n, 1.0);
        let mut a = matmul(&u, &v, m, r, n);
        for x in a.iter_mut() {
            *x += rng.normal() * 0.05;
        }
        let (_, s64, _) = svd_topr(&a, m, n, r);
        let run = |workers: usize| {
            let mut scratch = EighScratch::with_par_workers(workers);
            scratch.set_qscan(true);
            svd_topr_warm(&a, m, n, r, None, &mut scratch)
        };
        let (uq, sq, vq, cq) = run(1);
        for c in 0..r {
            assert!(
                (sq[c] - s64[c]).abs() <= 0.05 * s64[0],
                "qscan s[{c}] drifted: {} vs {}",
                sq[c],
                s64[c]
            );
        }
        let (uq4, sq4, vq4, cq4) = run(4);
        assert_eq!(uq, uq4, "qscan U diverged across worker counts");
        assert_eq!(sq, sq4, "qscan s diverged across worker counts");
        assert_eq!(vq, vq4, "qscan V diverged across worker counts");
        assert_eq!(cq, cq4, "qscan carrier diverged across worker counts");
        // warm restart through the same quantized arena stays in contract
        let mut scratch = EighScratch::new();
        scratch.set_qscan(true);
        let (_, sw, _, _) = svd_topr_warm(&a, m, n, r, cq.as_ref(), &mut scratch);
        for c in 0..r {
            assert!((sw[c] - s64[c]).abs() <= 0.05 * s64[0]);
        }
    }
}

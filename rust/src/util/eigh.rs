//! Dense symmetric eigensolver (cyclic Jacobi) + exact small-matrix SVD.
//!
//! The HLO interchange cannot carry LAPACK custom-calls, and the runtime
//! path uses randomized subspace iteration (runtime/linalg.rs). This module
//! is the *exact* host-side oracle used for (a) cross-checking the
//! randomized factors in tests, (b) Fig. 13-style rank counting of update
//! matrices, and (c) the small-side rotation of subspace factors. O(n^3)
//! per sweep — fine for the n <= ~2k matrices it sees.

/// Jacobi eigendecomposition of a symmetric matrix (row-major, n x n).
/// Returns (eigenvalues desc, eigenvectors as columns, row-major n x n).
pub fn eigh(a: &[f32], n: usize) -> (Vec<f32>, Vec<f32>) {
    let a64: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    let (w, v) = eigh64(&a64, n);
    (
        w.iter().map(|&x| x as f32).collect(),
        v.iter().map(|&x| x as f32).collect(),
    )
}

/// f64 Jacobi core — the Gram matrix must stay in f64 end-to-end or the
/// sqrt amplifies rounding into a ~1e-4-relative singular-value noise
/// floor (breaks Fig. 13 rank counting).
pub fn eigh64(a: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), n * n);
    let mut a: Vec<f64> = a.to_vec();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for p in 0..n {
            for q in (p + 1)..n {
                off += a[p * n + q] * a[p * n + q];
            }
        }
        if off.sqrt() < 1e-11 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // A <- J^T A J on rows/cols p, q
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    // sort by eigenvalue descending
    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    order.sort_by(|&i, &j| evals[j].partial_cmp(&evals[i]).unwrap());
    let mut w = Vec::with_capacity(n);
    let mut vecs = vec![0.0f64; n * n];
    for (new, &old) in order.iter().enumerate() {
        w.push(evals[old]);
        for k in 0..n {
            vecs[k * n + new] = v[k * n + old];
        }
    }
    (w, vecs)
}

/// Exact thin SVD of an m x n matrix (row-major) via eigh of the Gram
/// matrix on the smaller side. Returns (u m x r, s r, vt r x n), r = min(m, n).
pub fn svd(a: &[f32], m: usize, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(a.len(), m * n);
    let r = m.min(n);
    if n <= m {
        // G = A^T A (n x n); A = U S V^T, G = V S^2 V^T
        let mut g = vec![0.0f64; n * n];
        for i in 0..n {
            for j in i..n {
                let mut acc = 0.0f64;
                for k in 0..m {
                    acc += a[k * n + i] as f64 * a[k * n + j] as f64;
                }
                g[i * n + j] = acc;
                g[j * n + i] = acc;
            }
        }
        let (w, vfull) = eigh64(&g, n);
        let mut s = vec![0.0f32; r];
        let mut u = vec![0.0f32; m * r];
        let mut vt = vec![0.0f32; r * n];
        for c in 0..r {
            let sc = w[c].max(0.0).sqrt();
            s[c] = sc as f32;
            for k in 0..n {
                vt[c * n + k] = vfull[k * n + c] as f32;
            }
            // u_c = A v_c / s_c
            if sc > 1e-12 {
                for row in 0..m {
                    let mut acc = 0.0f64;
                    for k in 0..n {
                        acc += a[row * n + k] as f64 * vfull[k * n + c];
                    }
                    u[row * r + c] = (acc / sc) as f32;
                }
            }
        }
        (u, s, vt)
    } else {
        // transpose route: svd(A^T) then swap
        let mut at = vec![0.0f32; n * m];
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = a[i * n + j];
            }
        }
        let (ut, s, vtt) = svd(&at, n, m);
        // A = (V_t)^T S U_t^T  =>  U = vtt^T (m x r), V^T = ut^T (r x n)
        let mut u = vec![0.0f32; m * r];
        let mut vt = vec![0.0f32; r * n];
        for i in 0..m {
            for c in 0..r {
                u[i * r + c] = vtt[c * m + i];
            }
        }
        for c in 0..r {
            for j in 0..n {
                vt[c * n + j] = ut[j * r + c];
            }
        }
        (u, s, vt)
    }
}

/// Rank-r reconstruction from exact SVD (the paper's Eq. 1 oracle).
pub fn lowrank_approx(a: &[f32], m: usize, n: usize, rank: usize) -> Vec<f32> {
    let (u, s, vt) = svd(a, m, n);
    let r = m.min(n);
    let rank = rank.min(r);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for c in 0..rank {
            let uis = u[i * r + c] * s[c];
            if uis == 0.0 {
                continue;
            }
            let row = &vt[c * n..(c + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += uis * row[j];
            }
        }
    }
    out
}

/// Count of singular values above `tau` (Fig. 13 rank metric).
pub fn rank_above(a: &[f32], m: usize, n: usize, tau_mult: f32) -> usize {
    let (_, s, _) = svd(a, m, n);
    let smax = s.first().copied().unwrap_or(0.0);
    // paper: tau = 10 x default = 10 * max(m,n) * smax * eps_f32
    let tau = tau_mult * m.max(n) as f32 * smax * f32::EPSILON;
    s.iter().filter(|&&x| x > tau).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for l in 0..k {
                let ail = a[i * k + l];
                for j in 0..n {
                    c[i * n + j] += ail * b[l * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn eigh_diagonal() {
        let a = vec![3.0, 0.0, 0.0, 1.0];
        let (w, v) = eigh(&a, 2);
        assert!((w[0] - 3.0).abs() < 1e-5 && (w[1] - 1.0).abs() < 1e-5);
        // columns orthonormal
        let dot = v[0] * v[1] + v[2] * v[3];
        assert!(dot.abs() < 1e-5);
    }

    #[test]
    fn eigh_reconstructs() {
        let mut rng = Rng::new(42);
        let n = 16;
        let b = rng.normal_vec(n * n, 1.0);
        // symmetrize
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = 0.5 * (b[i * n + j] + b[j * n + i]);
            }
        }
        let (w, v) = eigh(&a, n);
        // A v_c = w_c v_c
        for c in 0..n {
            for i in 0..n {
                let mut av = 0.0;
                for k in 0..n {
                    av += a[i * n + k] * v[k * n + c];
                }
                assert!(
                    (av - w[c] * v[i * n + c]).abs() < 1e-3,
                    "c={c} i={i}: {av} vs {}",
                    w[c] * v[i * n + c]
                );
            }
        }
    }

    #[test]
    fn svd_reconstructs_tall_and_wide() {
        let mut rng = Rng::new(7);
        for (m, n) in [(20usize, 8usize), (8, 20), (12, 12)] {
            let a = rng.normal_vec(m * n, 1.0);
            let (u, s, vt) = svd(&a, m, n);
            let r = m.min(n);
            let mut us = vec![0.0f32; m * r];
            for i in 0..m {
                for c in 0..r {
                    us[i * r + c] = u[i * r + c] * s[c];
                }
            }
            let rec = matmul(&us, &vt, m, r, n);
            for i in 0..m * n {
                assert!((rec[i] - a[i]).abs() < 1e-3, "({m},{n}) idx {i}");
            }
            // singular values sorted desc, nonnegative
            for c in 1..r {
                assert!(s[c - 1] >= s[c] - 1e-5);
                assert!(s[c] >= -1e-6);
            }
        }
    }

    #[test]
    fn lowrank_is_best_approx() {
        // rank-2 matrix + noise: rank-2 approx error must be ~ noise level
        let mut rng = Rng::new(3);
        let (m, n, r) = (24, 16, 2);
        let u = rng.normal_vec(m * r, 1.0);
        let v = rng.normal_vec(r * n, 1.0);
        let mut a = matmul(&u, &v, m, r, n);
        for x in a.iter_mut() {
            *x += rng.normal() * 1e-3;
        }
        let ar = lowrank_approx(&a, m, n, 2);
        let err: f32 = a.iter().zip(&ar).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(err.sqrt() < 0.1, "err={}", err.sqrt());
    }

    #[test]
    fn rank_counting() {
        let mut rng = Rng::new(5);
        let (m, n, r) = (30usize, 30usize, 5usize);
        let u = rng.normal_vec(m * r, 1.0);
        let v = rng.normal_vec(r * n, 1.0);
        let a = matmul(&u, &v, m, r, n);
        assert_eq!(rank_above(&a, m, n, 10.0), r);
    }
}

//! Deterministic PRNG (SplitMix64 core) — `rand` is unavailable offline.
//!
//! Every stochastic component in the system (init, data generation, noise
//! perturbation, subspace-iteration test matrices) takes an explicit `Rng`
//! so experiments are reproducible from a single seed recorded in the
//! results CSV.

/// SplitMix64: tiny state, passes BigCrush, splittable by construction.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed ^ 0x9e37_79b9_7f4a_7c15,
        }
    }

    /// Derive an independent stream (for per-matrix / per-task generators).
    pub fn split(&mut self, tag: u64) -> Rng {
        let s = self.next_u64();
        Rng::new(s ^ tag.wrapping_mul(0xbf58_476d_1ce4_e5b9))
    }

    /// Raw generator state for checkpointing; [`Rng::from_state`] rebuilds
    /// the stream at exactly this position.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a generator at a previously captured [`Rng::state`]
    /// position. NOT a seed — `Rng::new` applies a seed scramble, this
    /// restores the internal word verbatim.
    pub fn from_state(state: u64) -> Rng {
        Rng { state }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free is overkill here; modulo
        // bias is < 2^-40 for our n (< 2^24).
        (self.next_u64() % n as u64) as usize
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    pub fn chance(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Standard normal via Box–Muller (cached second variate dropped to
    /// stay allocation-free and branch-simple).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-7 {
                let u2 = self.next_f32();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fill with N(0, sigma^2).
    pub fn fill_normal(&mut self, buf: &mut [f32], sigma: f32) {
        for x in buf.iter_mut() {
            *x = self.normal() * sigma;
        }
    }

    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v, sigma);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_the_stream() {
        let mut a = Rng::new(13);
        for _ in 0..10 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // from_state is position-restore, not seeding
        assert_ne!(Rng::from_state(13).next_u64(), Rng::new(13).next_u64());
    }

    #[test]
    fn split_streams_differ() {
        let mut r = Rng::new(1);
        let mut s1 = r.split(1);
        let mut s2 = r.split(2);
        assert_ne!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            let n = r.below(17);
            assert!(n < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let idx = r.sample_indices(100, 30);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}

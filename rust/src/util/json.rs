//! Minimal JSON — parser + writer for the artifact manifest, run configs
//! and result files (`serde_json` is unavailable offline).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP (not needed: all our payloads are ASCII identifiers and numbers).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Builder helpers for result writing.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected char")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(c) if c < 0x80 => {
                    s.push(c as char);
                    self.i += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8: copy the whole scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write(self, &mut s);
        f.write_str(&s)
    }
}

fn write(j: &Json, out: &mut String) {
    match j {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(v) => {
            out.push('[');
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"presets": {"tiny": {"d": 128, "params": [{"name": "embed", "shape": [512, 128]}]}}, "ok": true, "x": null, "f": -1.5e3}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(
            j.get("presets")
                .and_then(|p| p.get("tiny"))
                .and_then(|t| t.get("d"))
                .and_then(|d| d.as_usize()),
            Some(128)
        );
        assert_eq!(j.get("f").and_then(|x| x.as_f64()), Some(-1500.0));
        // reparse what we print
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn strings_and_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(
            j.idx(1).and_then(|x| x.idx(1)).and_then(|x| x.idx(0)).and_then(|x| x.as_f64()),
            Some(4.0)
        );
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo — ≥""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo — ≥"));
    }
}

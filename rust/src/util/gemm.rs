//! Cache-tiled host GEMM kernels shared by the exact decomposition path
//! (`util::eigh::svd_topr`) and the factor-rotation matmuls in
//! `runtime::linalg::truncate_factors`.
//!
//! These are not a BLAS replacement: the matrices here top out around a
//! couple thousand on a side, f32 in / f64 accumulate, and the callers
//! need *deterministic* summation order (the engine's 1-worker ≡
//! N-workers contract hashes results bit-for-bit). The two tricks that
//! matter at this scale:
//!
//! * **k-blocking** — the inner product dimension is walked in
//!   [`KC`]-sized panels so the streamed rows of `b` stay in L1/L2
//!   across the whole `a`-row sweep instead of being evicted between
//!   rows;
//! * **transpose packing** — Gram builds (`A^T A`) and `A^T B` products
//!   read their left operand column-wise; packing the transpose once
//!   into a contiguous scratch buffer turns every inner loop into a
//!   unit-stride dot product the autovectorizer handles.
//!
//! Summation order is fixed by the loop structure alone (no
//! data-dependent skipping), so every kernel is a pure function of its
//! inputs — results are bit-identical run-to-run and worker-to-worker.

/// Panel width of the inner-product dimension. 64 f64 columns = 512 B
/// per `b`-row panel — comfortably L1-resident alongside the `c` row.
const KC: usize = 64;

/// C (m×n, f64) = A (m×k, f64) · B (k×n, f64), k-blocked. `c` is
/// overwritten, not accumulated into.
pub fn matmul_f64(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f64]) {
    assert_eq!(a.len(), m * k, "gemm: a is not m×k");
    assert_eq!(b.len(), k * n, "gemm: b is not k×n");
    assert_eq!(c.len(), m * n, "gemm: c is not m×n");
    c.fill(0.0);
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for l in kk..kend {
                let ail = arow[l];
                let brow = &b[l * n..(l + 1) * n];
                for j in 0..n {
                    crow[j] += ail * brow[j];
                }
            }
        }
        kk = kend;
    }
}

/// C (m×n, f64) = Aᵀ · B where A is k×m and B is k×n (both f64, row
/// major) — the projection shape (`V = Xᵀ Z` in the Rayleigh–Ritz
/// rotation). Walking `l` (the shared leading dimension) outermost keeps
/// every read and write unit-stride without materializing Aᵀ.
pub fn matmul_tn_f64(a: &[f64], b: &[f64], k: usize, m: usize, n: usize, c: &mut [f64]) {
    assert_eq!(a.len(), k * m, "gemm_tn: a is not k×m");
    assert_eq!(b.len(), k * n, "gemm_tn: b is not k×n");
    assert_eq!(c.len(), m * n, "gemm_tn: c is not m×n");
    c.fill(0.0);
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        for l in kk..kend {
            let arow = &a[l * m..(l + 1) * m];
            let brow = &b[l * n..(l + 1) * n];
            for (i, &ail) in arow.iter().enumerate() {
                let crow = &mut c[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += ail * brow[j];
                }
            }
        }
        kk = kend;
    }
}

/// C (m×n, f32) = A (m×k, f32) · B (k×n, f64), f64 accumulation —
/// the `U = A V` projection and the `q @ ub` factor rotation. k-blocked
/// like [`matmul_f64`]; the f64 accumulator matches the precision the
/// previous per-element loops used, so tolerances are unchanged.
pub fn matmul_f32xf64(a: &[f32], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "gemm_32x64: a is not m×k");
    assert_eq!(b.len(), k * n, "gemm_32x64: b is not k×n");
    assert_eq!(c.len(), m * n, "gemm_32x64: c is not m×n");
    // f64 row accumulator: KC-blocking alone would round each panel's
    // partial sum through f32
    let mut acc = vec![0.0f64; n];
    for i in 0..m {
        acc.fill(0.0);
        let arow = &a[i * k..(i + 1) * k];
        let mut kk = 0;
        while kk < k {
            let kend = (kk + KC).min(k);
            for l in kk..kend {
                let ail = arow[l] as f64;
                let brow = &b[l * n..(l + 1) * n];
                for j in 0..n {
                    acc[j] += ail * brow[j];
                }
            }
            kk = kend;
        }
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] = acc[j] as f32;
        }
    }
}

/// G (n×n, f64) = Aᵀ A for A m×n (f32), transpose-packed: A is packed
/// column-major (as f64) into `pack` once, turning every Gram entry into
/// a unit-stride dot product; only the upper triangle is computed and
/// mirrored. `pack` is caller-owned scratch (resized here) so the
/// per-refresh allocation disappears when an arena is threaded through.
pub fn gram_f64(a: &[f32], m: usize, n: usize, pack: &mut Vec<f64>, g: &mut [f64]) {
    assert_eq!(a.len(), m * n, "gram: a is not m×n");
    assert_eq!(g.len(), n * n, "gram: g is not n×n");
    pack.clear();
    pack.resize(n * m, 0.0);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for (j, &x) in arow.iter().enumerate() {
            pack[j * m + i] = x as f64;
        }
    }
    for i in 0..n {
        let ci = &pack[i * m..(i + 1) * m];
        for j in i..n {
            let cj = &pack[j * m..(j + 1) * m];
            let mut acc = 0.0f64;
            for l in 0..m {
                acc += ci[l] * cj[l];
            }
            g[i * n + j] = acc;
            g[j * n + i] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for l in 0..k {
                    acc += a[i * k + l] * b[l * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn blocked_matches_naive_across_panel_boundaries() {
        let mut rng = Rng::new(3);
        // sizes straddling the KC panel boundary, incl. degenerate dims
        for (m, k, n) in [(7usize, 130usize, 9usize), (1, 64, 5), (5, 63, 1), (3, 65, 4)] {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal() as f64).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
            let mut c = vec![1.0f64; m * n]; // nonzero: kernel must overwrite
            matmul_f64(&a, &b, m, k, n, &mut c);
            let want = naive(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-9, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn tn_variant_matches_explicit_transpose() {
        let mut rng = Rng::new(5);
        let (k, m, n) = (70usize, 6usize, 11usize);
        let a: Vec<f64> = (0..k * m).map(|_| rng.normal() as f64).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
        let mut at = vec![0.0f64; m * k];
        for l in 0..k {
            for i in 0..m {
                at[i * k + l] = a[l * m + i];
            }
        }
        let want = naive(&at, &b, m, k, n);
        let mut c = vec![0.0f64; m * n];
        matmul_tn_f64(&a, &b, k, m, n, &mut c);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn mixed_precision_matches_f64_reference() {
        let mut rng = Rng::new(7);
        let (m, k, n) = (9usize, 129usize, 8usize);
        let a32: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
        let a64: Vec<f64> = a32.iter().map(|&x| x as f64).collect();
        let want = naive(&a64, &b, m, k, n);
        let mut c = vec![0.0f32; m * n];
        matmul_f32xf64(&a32, &b, m, k, n, &mut c);
        for (x, y) in c.iter().zip(&want) {
            assert!((*x as f64 - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn gram_is_symmetric_and_exact() {
        let mut rng = Rng::new(9);
        let (m, n) = (37usize, 12usize);
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut pack = Vec::new();
        let mut g = vec![0.0f64; n * n];
        gram_f64(&a, m, n, &mut pack, &mut g);
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0f64;
                for l in 0..m {
                    acc += a[l * n + i] as f64 * a[l * n + j] as f64;
                }
                assert!((g[i * n + j] - acc).abs() < 1e-9);
                assert_eq!(g[i * n + j].to_bits(), g[j * n + i].to_bits(), "not symmetric");
            }
        }
        // pack scratch is reusable: second call over a different shape
        let (m2, n2) = (5usize, 4usize);
        let a2: Vec<f32> = (0..m2 * n2).map(|_| rng.normal()).collect();
        let mut g2 = vec![0.0f64; n2 * n2];
        gram_f64(&a2, m2, n2, &mut pack, &mut g2);
        assert!((g2[0] - (0..m2).map(|l| (a2[l * n2] as f64).powi(2)).sum::<f64>()).abs() < 1e-9);
    }

    #[test]
    fn deterministic_bitwise() {
        let mut rng = Rng::new(11);
        let (m, k, n) = (8usize, 100usize, 7usize);
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal() as f64).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
        let mut c1 = vec![0.0f64; m * n];
        let mut c2 = vec![0.0f64; m * n];
        matmul_f64(&a, &b, m, k, n, &mut c1);
        matmul_f64(&a, &b, m, k, n, &mut c2);
        assert!(c1.iter().zip(&c2).all(|(x, y)| x.to_bits() == y.to_bits()));
    }
}

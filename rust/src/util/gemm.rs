//! Cache-tiled host GEMM kernels shared by the exact decomposition path
//! (`util::eigh::svd_topr`) and the factor-rotation matmuls in
//! `runtime::linalg::truncate_factors`, with a SIMD microkernel tier and
//! an intra-matrix parallel tile tier on top (the ISSUE-7 raw-speed
//! layer).
//!
//! These are not a BLAS replacement: the matrices here top out around a
//! couple thousand on a side, f32 in / f64 accumulate, and the callers
//! need *deterministic* summation order (the engine's 1-worker ≡
//! N-workers contract hashes results bit-for-bit). The tricks that
//! matter at this scale:
//!
//! * **k-blocking** — the inner product dimension is walked in
//!   [`KC`]-sized panels so the streamed rows of `b` stay in L1/L2
//!   across the whole `a`-row sweep instead of being evicted between
//!   rows;
//! * **transpose packing** — Gram builds (`A^T A`) read their operand
//!   column-wise; packing the transpose once into a contiguous scratch
//!   buffer turns every inner loop into a unit-stride dot product;
//! * **SIMD microkernels** — the unit-stride inner loops dispatch to
//!   AVX2 f64x4 kernels when the CPU has them (see below), with a
//!   portable scalar fallback that computes bit-identical results;
//! * **intra-matrix parallelism** — the `*_par` entry points split one
//!   large product's output-row grid across the `lift::engine` pool
//!   (see below), so a big matrix no longer serializes behind a single
//!   worker while the rest of the pool idles.
//!
//! # SIMD determinism rules
//!
//! Runtime detection ([`simd_enabled`]) picks AVX2 when the CPU supports
//! it; `LIFT_NO_SIMD=1` forces the scalar fallback (CI runs the suite
//! both ways). Scalar and SIMD results are **bit-identical** by
//! construction, under two rules the kernels must never violate:
//!
//! 1. **axpy kernels** (`c[j] += a * b[j]`, the matmul inner loop):
//!    vectorizing across `j` keeps every output element's summation
//!    chain exactly the scalar one — one multiply then one add per
//!    `(l, j)`, each individually rounded. FMA (`_mm256_fmadd_pd`) is
//!    FORBIDDEN here: its single rounding diverges from the scalar
//!    chain at the last bit.
//! 2. **dot kernels** (the Gram build): the summation order is the
//!    documented quad-accumulator order — four partial sums `s_q`
//!    accumulate elements `4t + q` over the 4-aligned prefix, combined
//!    as `(s0 + s2) + (s1 + s3)` (exactly the AVX2 128-bit lane
//!    reduction: low+high halves, then unpackhi + add), followed by a
//!    sequential tail. The scalar fallback mirrors that order
//!    element-for-element.
//!
//! # Parallel tile-ownership contract
//!
//! The `*_par` kernels split the output into contiguous, disjoint
//! row-tiles; tile index → output rows is a pure function of the shape
//! and worker count, and every tile's arithmetic is the serial kernel on
//! its own rows. Since no partial sums ever cross a tile boundary, the
//! result is bit-identical to the serial kernel for ANY worker count —
//! the 1w ≡ Nw contract holds by construction, not by tolerance.
//! Products below [`PAR_MIN_MULADDS`] multiply-adds run serially (the
//! fan-out overhead would dominate).
//!
//! # Scratch-arena contract
//!
//! `pack` (Gram transpose pack) and `acc` (mixed-precision row
//! accumulator) are caller-owned arenas: they are sized here *without* a
//! redundant zero pass (every element is overwritten before being read),
//! and a shrinking resize deliberately leaves the previous capacity
//! untrimmed so a worker cycling through many shapes allocates once for
//! the largest. [`QuantMat`] buffers follow the same rule.
//!
//! # Quantized scan tier (int8 blockwise, f32 scale-out)
//!
//! The `*_q8` kernels are the ISSUE-10 quantized selection path
//! (ROADMAP kernel-tier (c)): the operand is quantized **along its
//! reduction dimension** into per-row, per-[`QBLOCK`]-element int8
//! blocks with an f32 absmax scale each (`q = round(x / s)`, `s =
//! absmax / 127`), so a dot product decomposes into exact int8×int8→i32
//! block dots scaled out in f32. This moves ~8x less memory per operand
//! than the f64 tier — and selection only needs the *ordering* of
//! |W'| magnitudes to survive, not the values, so the loss is gated by
//! a documented tolerance contract instead of bit-identity
//! (`util::eigh::LIFT_QSCAN_TOL`: quantized-vs-f32 mask overlap).
//!
//! Determinism still holds *within* the tier, by construction:
//!
//! * a block dot never exceeds `64 · 127 · 127 < 2^23`, so the i32
//!   accumulation is exact and the AVX2 `madd_epi16` path is equal to
//!   the scalar loop as integers, not just to rounding;
//! * the f32 scale-out walks blocks in index order with one f32
//!   accumulator (`acc += (dot as f32 * s_a) * s_b`), shared verbatim
//!   by the scalar and SIMD dispatch — so `LIFT_NO_SIMD` flips cost,
//!   never results;
//! * non-finite inputs (NaN/±inf) quantize to 0: a NaN weight cannot
//!   poison a whole Gram row here (the selection-level NaN policy in
//!   `lift::topk_indices` still warns about it);
//! * the `*_par` variants reuse the tile-ownership contract above —
//!   1w ≡ Nw bitwise for any worker count.

use std::sync::OnceLock;

/// Panel width of the inner-product dimension. 64 f64 columns = 512 B
/// per `b`-row panel — comfortably L1-resident alongside the `c` row.
const KC: usize = 64;

/// Minimum multiply-adds before a `*_par` kernel fans its row tiles out
/// across the pool (~4.2M — below this, thread handoff costs more than
/// it saves on the matrices this module sees).
const PAR_MIN_MULADDS: usize = 1 << 22;

/// Raw CPU capability (ignores `LIFT_NO_SIMD`). The explicit
/// `*_with_simd` entry points clamp against this, so a forced-on
/// request on non-AVX2 hardware degrades to scalar instead of faulting.
fn simd_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    let yes = is_x86_feature_detected!("avx2");
    #[cfg(not(target_arch = "x86_64"))]
    let yes = false;
    yes
}

/// Whether the kernels in this module dispatch to the AVX2 microkernels:
/// runtime feature detection, overridden off by `LIFT_NO_SIMD` (any
/// non-empty value other than `"0"`). Cached once per process — the
/// bench gate reads this to decide whether the `[gemm-simd]` absolute
/// speedup floor applies on this host.
pub fn simd_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| {
        let forced_off = std::env::var("LIFT_NO_SIMD")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        !forced_off && simd_supported()
    })
}

// ---------------------------------------------------------------------------
// microkernels: axpy (matmul inner loop) and quad-order dot (Gram build)
// ---------------------------------------------------------------------------

/// `crow[j] += ail * brow[j]` — the scalar reference the SIMD kernel is
/// bit-identical to (one multiply, one add, per element).
#[inline(always)]
fn axpy_scalar(ail: f64, brow: &[f64], crow: &mut [f64]) {
    for j in 0..crow.len() {
        crow[j] += ail * brow[j];
    }
}

/// AVX2 axpy: 4-wide multiply then add (NEVER fmadd — see the module
/// doc's determinism rule 1), scalar tail.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(ail: f64, brow: &[f64], crow: &mut [f64]) {
    use std::arch::x86_64::*;
    let n = crow.len();
    let m4 = n & !3;
    let va = _mm256_set1_pd(ail);
    let bp = brow.as_ptr();
    let cp = crow.as_mut_ptr();
    let mut j = 0;
    while j < m4 {
        let vb = _mm256_loadu_pd(bp.add(j));
        let vc = _mm256_loadu_pd(cp.add(j));
        // separate mul + add: each lane rounds exactly like the scalar
        // statement `c += a * b`, keeping scalar ≡ SIMD bitwise
        let vc = _mm256_add_pd(vc, _mm256_mul_pd(va, vb));
        _mm256_storeu_pd(cp.add(j), vc);
        j += 4;
    }
    while j < n {
        crow[j] += ail * brow[j];
        j += 1;
    }
}

/// Dispatching axpy. `use_simd` must only be true when AVX2 was
/// actually detected ([`simd_enabled`] / [`simd_supported`]).
#[inline(always)]
fn axpy(use_simd: bool, ail: f64, brow: &[f64], crow: &mut [f64]) {
    debug_assert_eq!(brow.len(), crow.len());
    #[cfg(target_arch = "x86_64")]
    {
        if use_simd {
            // SAFETY: callers pass use_simd = true only behind runtime
            // AVX2 detection, so the target-feature fn is safe to call.
            unsafe { axpy_avx2(ail, brow, crow) };
            return;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = use_simd;
    axpy_scalar(ail, brow, crow);
}

/// Dot product in the documented quad-accumulator order (module doc,
/// determinism rule 2): partials `s_q` over elements `4t + q`, combined
/// as `(s0 + s2) + (s1 + s3)`, then a sequential tail — exactly the
/// order the AVX2 lane reduction produces.
#[inline(always)]
fn dot_quad_scalar(x: &[f64], y: &[f64]) -> f64 {
    let len = x.len();
    let m4 = len & !3;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut l = 0;
    while l < m4 {
        s0 += x[l] * y[l];
        s1 += x[l + 1] * y[l + 1];
        s2 += x[l + 2] * y[l + 2];
        s3 += x[l + 3] * y[l + 3];
        l += 4;
    }
    let mut acc = (s0 + s2) + (s1 + s3);
    for l in m4..len {
        acc += x[l] * y[l];
    }
    acc
}

/// AVX2 quad-order dot: one 4-lane accumulator (mul + add, no fmadd),
/// reduced low+high then unpackhi+add — bit-identical to
/// [`dot_quad_scalar`] by construction.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_quad_avx2(x: &[f64], y: &[f64]) -> f64 {
    use std::arch::x86_64::*;
    let len = x.len();
    let m4 = len & !3;
    let xp = x.as_ptr();
    let yp = y.as_ptr();
    let mut vs = _mm256_setzero_pd();
    let mut l = 0;
    while l < m4 {
        let vx = _mm256_loadu_pd(xp.add(l));
        let vy = _mm256_loadu_pd(yp.add(l));
        vs = _mm256_add_pd(vs, _mm256_mul_pd(vx, vy));
        l += 4;
    }
    // lane reduce: [s0,s1] + [s2,s3] = [s0+s2, s1+s3], then
    // (s0+s2) + (s1+s3) — the order dot_quad_scalar mirrors
    let lo = _mm256_castpd256_pd128(vs);
    let hi = _mm256_extractf128_pd::<1>(vs);
    let pair = _mm_add_pd(lo, hi);
    let swapped = _mm_unpackhi_pd(pair, pair);
    let mut acc = _mm_cvtsd_f64(_mm_add_sd(pair, swapped));
    for l in m4..len {
        acc += x[l] * y[l];
    }
    acc
}

/// Dispatching quad-order dot (same `use_simd` contract as [`axpy`]).
#[inline(always)]
fn dot_quad(use_simd: bool, x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    {
        if use_simd {
            // SAFETY: use_simd is true only behind runtime AVX2 detection.
            return unsafe { dot_quad_avx2(x, y) };
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = use_simd;
    dot_quad_scalar(x, y)
}

// ---------------------------------------------------------------------------
// serial kernels (row cores shared with the parallel tile tier)
// ---------------------------------------------------------------------------

/// C (m×n, f64) = A (m×k, f64) · B (k×n, f64), k-blocked. `c` is
/// overwritten, not accumulated into.
pub fn matmul_f64(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f64]) {
    matmul_f64_with_simd(a, b, m, k, n, c, simd_enabled());
}

/// [`matmul_f64`] with the SIMD dispatch pinned by the caller — the
/// bench harness times scalar-vs-SIMD through this. A forced-on request
/// is clamped to the CPU's actual capability.
pub(crate) fn matmul_f64_with_simd(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f64],
    use_simd: bool,
) {
    assert_eq!(a.len(), m * k, "gemm: a is not m×k");
    assert_eq!(b.len(), k * n, "gemm: b is not k×n");
    assert_eq!(c.len(), m * n, "gemm: c is not m×n");
    matmul_f64_rows(a, b, m, k, n, c, use_simd && simd_supported());
}

/// Row core of [`matmul_f64`]: `a`/`c` hold `m` contiguous rows (a tile
/// of the full problem or all of it).
fn matmul_f64_rows(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f64], use_simd: bool) {
    c.fill(0.0);
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for l in kk..kend {
                axpy(use_simd, arow[l], &b[l * n..(l + 1) * n], crow);
            }
        }
        kk = kend;
    }
}

/// C (m×n, f64) = Aᵀ · B where A is k×m and B is k×n (both f64, row
/// major) — the projection shape (`V = Xᵀ Z` in the Rayleigh–Ritz
/// rotation). Walking `l` (the shared leading dimension) outermost keeps
/// every read and write unit-stride without materializing Aᵀ.
pub fn matmul_tn_f64(a: &[f64], b: &[f64], k: usize, m: usize, n: usize, c: &mut [f64]) {
    assert_eq!(a.len(), k * m, "gemm_tn: a is not k×m");
    assert_eq!(b.len(), k * n, "gemm_tn: b is not k×n");
    assert_eq!(c.len(), m * n, "gemm_tn: c is not m×n");
    matmul_tn_rows(a, b, k, m, n, 0, m, c, simd_enabled());
}

/// Row core of [`matmul_tn_f64`]: computes output rows `i0..i0+rows`
/// into `c` (rows×n). Output row `i` reads column `i0+i` of A, so a
/// tile is NOT a contiguous slice of `a` — the full `a` is passed and
/// the column window selected here.
fn matmul_tn_rows(
    a: &[f64],
    b: &[f64],
    k: usize,
    m: usize,
    n: usize,
    i0: usize,
    rows: usize,
    c: &mut [f64],
    use_simd: bool,
) {
    debug_assert_eq!(c.len(), rows * n);
    c.fill(0.0);
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        for l in kk..kend {
            let arow = &a[l * m..(l + 1) * m];
            let brow = &b[l * n..(l + 1) * n];
            for i in 0..rows {
                let crow = &mut c[i * n..(i + 1) * n];
                axpy(use_simd, arow[i0 + i], brow, crow);
            }
        }
        kk = kend;
    }
}

/// C (m×n, f32) = A (m×k, f32) · B (k×n, f64), f64 accumulation —
/// the `U = A V` projection and the `q @ ub` factor rotation. Thin
/// allocating wrapper over [`matmul_f32xf64_with`]; hot-loop callers
/// thread a scratch accumulator through instead (the per-call
/// `vec![0.0; n]` here was the ISSUE-7 allocation bug).
pub fn matmul_f32xf64(a: &[f32], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f32]) {
    let mut acc = Vec::new();
    matmul_f32xf64_with(a, b, m, k, n, c, &mut acc);
}

/// [`matmul_f32xf64`] with a caller-owned f64 row accumulator (`acc`
/// is sized here; see the module doc's scratch-arena contract). The f64
/// accumulator matches the precision the per-element loops used before
/// blocking, so tolerances are unchanged.
pub fn matmul_f32xf64_with(
    a: &[f32],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    acc: &mut Vec<f64>,
) {
    assert_eq!(a.len(), m * k, "gemm_32x64: a is not m×k");
    assert_eq!(b.len(), k * n, "gemm_32x64: b is not k×n");
    assert_eq!(c.len(), m * n, "gemm_32x64: c is not m×n");
    // grow-or-truncate only: the accumulator is fill(0.0)-ed per row by
    // the core, so no up-front zero pass over reused capacity
    acc.resize(n, 0.0);
    matmul_f32xf64_rows(a, b, m, k, n, c, &mut acc[..], simd_enabled());
}

/// Row core of the mixed-precision product: `acc` is one n-wide f64
/// accumulator row, re-zeroed per output row. KC-blocking alone would
/// round each panel's partial sum through f32 — hence the f64 row.
fn matmul_f32xf64_rows(
    a: &[f32],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    acc: &mut [f64],
    use_simd: bool,
) {
    debug_assert_eq!(acc.len(), n);
    for i in 0..m {
        acc.fill(0.0);
        let arow = &a[i * k..(i + 1) * k];
        let mut kk = 0;
        while kk < k {
            let kend = (kk + KC).min(k);
            for l in kk..kend {
                axpy(use_simd, arow[l] as f64, &b[l * n..(l + 1) * n], acc);
            }
            kk = kend;
        }
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] = acc[j] as f32;
        }
    }
}

/// G (n×n, f64) = Aᵀ A for A m×n (f32), transpose-packed: A is packed
/// column-major (as f64) into `pack` once, turning every Gram entry into
/// a unit-stride quad-order dot; only the upper triangle is computed,
/// then mirrored (bitwise-symmetric by construction). `pack` is a
/// caller-owned arena sized without a redundant zero pass (every element
/// is written by the packing loop).
pub fn gram_f64(a: &[f32], m: usize, n: usize, pack: &mut Vec<f64>, g: &mut [f64]) {
    assert_eq!(a.len(), m * n, "gram: a is not m×n");
    assert_eq!(g.len(), n * n, "gram: g is not n×n");
    let use_simd = simd_enabled();
    pack_transpose(a, m, n, pack);
    gram_rows(pack, m, n, 0, n, g, use_simd);
    mirror_lower(g, n);
}

/// Pack A (m×n, f32) column-major into `pack` (n×m, f64) with a single
/// write per element: the previous `clear()` + `resize(n*m, 0.0)` paid
/// a full zero pass over the largest buffer in the scan on every call,
/// only to overwrite every element immediately (the ISSUE-7 double-write
/// bug). A shrinking call keeps the arena's capacity (module doc).
fn pack_transpose(a: &[f32], m: usize, n: usize, pack: &mut Vec<f64>) {
    let len = n * m;
    pack.clear();
    pack.reserve(len);
    let spare = &mut pack.spare_capacity_mut()[..len];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for (j, &x) in arow.iter().enumerate() {
            spare[j * m + i].write(x as f64);
        }
    }
    // SAFETY: every index j*m + i with i < m, j < n is written exactly
    // once above, so all `len` elements are initialized.
    unsafe { pack.set_len(len) };
}

/// Upper-triangle rows `i0..i0+rows` of the Gram matrix into `g`
/// (rows×n): entry (i, j) for j >= i only — the lower triangle of the
/// tile is left untouched and filled by [`mirror_lower`] afterwards.
fn gram_rows(pack: &[f64], m: usize, n: usize, i0: usize, rows: usize, g: &mut [f64], use_simd: bool) {
    debug_assert_eq!(g.len(), rows * n);
    for i in 0..rows {
        let ci = &pack[(i0 + i) * m..(i0 + i + 1) * m];
        for j in (i0 + i)..n {
            let cj = &pack[j * m..(j + 1) * m];
            g[i * n + j] = dot_quad(use_simd, ci, cj);
        }
    }
}

/// Copy the computed upper triangle onto the lower one — a bit-exact
/// copy, so `g[i,j].to_bits() == g[j,i].to_bits()` always holds.
fn mirror_lower(g: &mut [f64], n: usize) {
    for i in 1..n {
        for j in 0..i {
            g[i * n + j] = g[j * n + i];
        }
    }
}

// ---------------------------------------------------------------------------
// intra-matrix parallel tier: disjoint output-row tiles over the pool
// ---------------------------------------------------------------------------

/// [`matmul_f64`] with intra-matrix parallelism: output rows are split
/// into `workers` contiguous disjoint tiles fanned over the
/// `lift::engine` pool. Bit-identical to the serial kernel for any
/// worker count (tile-ownership contract, module doc); products below
/// [`PAR_MIN_MULADDS`] run serially.
pub fn matmul_f64_par(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f64], workers: usize) {
    matmul_f64_tiled(a, b, m, k, n, c, workers, PAR_MIN_MULADDS);
}

/// Tiling core with an explicit threshold so tests can force the
/// parallel path on small matrices (`min_muladds = 0`).
pub(crate) fn matmul_f64_tiled(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f64],
    workers: usize,
    min_muladds: usize,
) {
    if workers <= 1 || m < 2 || m * k * n < min_muladds {
        matmul_f64(a, b, m, k, n, c);
        return;
    }
    assert_eq!(a.len(), m * k, "gemm: a is not m×k");
    assert_eq!(b.len(), k * n, "gemm: b is not k×n");
    assert_eq!(c.len(), m * n, "gemm: c is not m×n");
    let use_simd = simd_enabled();
    let rows_per = m.div_ceil(workers.min(m));
    let mut jobs = Vec::new();
    let mut a_rest = a;
    let mut c_rest = c;
    let mut i0 = 0;
    while i0 < m {
        let rows = rows_per.min(m - i0);
        let (a_t, ar) = a_rest.split_at(rows * k);
        let (c_t, cr) = std::mem::take(&mut c_rest).split_at_mut(rows * n);
        a_rest = ar;
        c_rest = cr;
        jobs.push((a_t, c_t, rows));
        i0 += rows;
    }
    crate::lift::engine::par_map(workers, jobs, |_, (a_t, c_t, rows)| {
        matmul_f64_rows(a_t, b, rows, k, n, c_t, use_simd);
    });
}

/// [`matmul_tn_f64`] with intra-matrix parallelism (same contract as
/// [`matmul_f64_par`]): each tile owns output rows `i0..i0+rows`, i.e.
/// a disjoint column window of A.
pub fn matmul_tn_f64_par(a: &[f64], b: &[f64], k: usize, m: usize, n: usize, c: &mut [f64], workers: usize) {
    matmul_tn_f64_tiled(a, b, k, m, n, c, workers, PAR_MIN_MULADDS);
}

pub(crate) fn matmul_tn_f64_tiled(
    a: &[f64],
    b: &[f64],
    k: usize,
    m: usize,
    n: usize,
    c: &mut [f64],
    workers: usize,
    min_muladds: usize,
) {
    if workers <= 1 || m < 2 || k * m * n < min_muladds {
        matmul_tn_f64(a, b, k, m, n, c);
        return;
    }
    assert_eq!(a.len(), k * m, "gemm_tn: a is not k×m");
    assert_eq!(b.len(), k * n, "gemm_tn: b is not k×n");
    assert_eq!(c.len(), m * n, "gemm_tn: c is not m×n");
    let use_simd = simd_enabled();
    let rows_per = m.div_ceil(workers.min(m));
    let mut jobs = Vec::new();
    let mut c_rest = c;
    let mut i0 = 0;
    while i0 < m {
        let rows = rows_per.min(m - i0);
        let (c_t, cr) = std::mem::take(&mut c_rest).split_at_mut(rows * n);
        c_rest = cr;
        jobs.push((i0, c_t, rows));
        i0 += rows;
    }
    crate::lift::engine::par_map(workers, jobs, |_, (i0, c_t, rows)| {
        matmul_tn_rows(a, b, k, m, n, i0, rows, c_t, use_simd);
    });
}

/// [`matmul_f32xf64_with`] with intra-matrix parallelism: `acc` is
/// resized to one f64 row per tile, and each tile gets a disjoint
/// accumulator slice alongside its disjoint output rows.
pub fn matmul_f32xf64_par(
    a: &[f32],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    workers: usize,
    acc: &mut Vec<f64>,
) {
    matmul_f32xf64_tiled(a, b, m, k, n, c, workers, PAR_MIN_MULADDS, acc);
}

pub(crate) fn matmul_f32xf64_tiled(
    a: &[f32],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    workers: usize,
    min_muladds: usize,
    acc: &mut Vec<f64>,
) {
    if workers <= 1 || m < 2 || m * k * n < min_muladds {
        matmul_f32xf64_with(a, b, m, k, n, c, acc);
        return;
    }
    assert_eq!(a.len(), m * k, "gemm_32x64: a is not m×k");
    assert_eq!(b.len(), k * n, "gemm_32x64: b is not k×n");
    assert_eq!(c.len(), m * n, "gemm_32x64: c is not m×n");
    let use_simd = simd_enabled();
    let rows_per = m.div_ceil(workers.min(m));
    let n_tiles = m.div_ceil(rows_per);
    acc.resize(n_tiles * n, 0.0);
    let mut jobs = Vec::new();
    let mut a_rest = a;
    let mut c_rest = c;
    let mut acc_rest = &mut acc[..];
    let mut i0 = 0;
    while i0 < m {
        let rows = rows_per.min(m - i0);
        let (a_t, ar) = a_rest.split_at(rows * k);
        let (c_t, cr) = std::mem::take(&mut c_rest).split_at_mut(rows * n);
        let (acc_t, accr) = std::mem::take(&mut acc_rest).split_at_mut(n);
        a_rest = ar;
        c_rest = cr;
        acc_rest = accr;
        jobs.push((a_t, c_t, acc_t, rows));
        i0 += rows;
    }
    crate::lift::engine::par_map(workers, jobs, |_, (a_t, c_t, acc_t, rows)| {
        matmul_f32xf64_rows(a_t, b, rows, k, n, c_t, acc_t, use_simd);
    });
}

/// [`gram_f64`] with intra-matrix parallelism: the packing pass stays
/// serial (it is a bandwidth-bound transpose), then the upper-triangle
/// rows fan out in small tiles (~4 per worker — upper-triangle rows
/// shrink with `i`, so finer tiles plus the pool's stealing cursor
/// level the load), and the mirror pass runs serially after.
pub fn gram_f64_par(a: &[f32], m: usize, n: usize, pack: &mut Vec<f64>, g: &mut [f64], workers: usize) {
    gram_f64_tiled(a, m, n, pack, g, workers, PAR_MIN_MULADDS);
}

pub(crate) fn gram_f64_tiled(
    a: &[f32],
    m: usize,
    n: usize,
    pack: &mut Vec<f64>,
    g: &mut [f64],
    workers: usize,
    min_muladds: usize,
) {
    if workers <= 1 || n < 2 || n * (n + 1) / 2 * m < min_muladds {
        gram_f64(a, m, n, pack, g);
        return;
    }
    assert_eq!(a.len(), m * n, "gram: a is not m×n");
    assert_eq!(g.len(), n * n, "gram: g is not n×n");
    let use_simd = simd_enabled();
    pack_transpose(a, m, n, pack);
    let pack_ro: &[f64] = pack;
    let rows_per = n.div_ceil(4 * workers).max(1);
    let mut jobs = Vec::new();
    let mut g_rest = &mut g[..];
    let mut i0 = 0;
    while i0 < n {
        let rows = rows_per.min(n - i0);
        let (g_t, gr) = std::mem::take(&mut g_rest).split_at_mut(rows * n);
        g_rest = gr;
        jobs.push((i0, g_t, rows));
        i0 += rows;
    }
    crate::lift::engine::par_map(workers, jobs, |_, (i0, g_t, rows)| {
        gram_rows(pack_ro, m, n, i0, rows, g_t, use_simd);
    });
    mirror_lower(g, n);
}

// ---------------------------------------------------------------------------
// quantized scan tier: int8 blockwise operands, i32 dots, f32 scale-out
// ---------------------------------------------------------------------------

/// Quantization block width along the reduction dimension. Matches [`KC`]
/// so a quantized panel and an f64 panel cover the same cache footprint
/// shape; 64 int8 values = one cache line.
pub const QBLOCK: usize = 64;

/// A row-major matrix quantized blockwise to int8: row `i`'s elements
/// `[b·QBLOCK, (b+1)·QBLOCK)` share one f32 absmax scale `s` with
/// `x ≈ q · s`, `q ∈ [-127, 127]`. Buffers follow the scratch-arena
/// contract (grow-only capacity across requantizations).
#[derive(Default)]
pub struct QuantMat {
    rows: usize,
    cols: usize,
    q: Vec<i8>,
    scales: Vec<f32>,
}

impl QuantMat {
    pub fn new() -> QuantMat {
        QuantMat::default()
    }

    /// Blocks per row (0 for an empty matrix).
    fn nblocks(&self) -> usize {
        self.cols.div_ceil(QBLOCK)
    }

    fn row_q(&self, i: usize) -> &[i8] {
        &self.q[i * self.cols..(i + 1) * self.cols]
    }

    fn row_scales(&self, i: usize) -> &[f32] {
        let nb = self.nblocks();
        &self.scales[i * nb..(i + 1) * nb]
    }
}

/// Quantize `src` (rows×cols, f64, row major) into `out`. Per block:
/// scale = absmax / 127 (0 for an all-zero block), `q = round(x / s)`
/// clamped to ±127. Non-finite blocks — any block whose absmax is not
/// finite — quantize entirely to zero: NaN cannot be ordered and ±inf
/// would turn the scale-out into NaN, so both degrade to "no signal"
/// deterministically instead of poisoning the product.
pub fn quantize_rows(src: &[f64], rows: usize, cols: usize, out: &mut QuantMat) {
    assert_eq!(src.len(), rows * cols, "quantize: src is not rows×cols");
    out.rows = rows;
    out.cols = cols;
    let nb = cols.div_ceil(QBLOCK);
    out.q.resize(rows * cols, 0);
    out.scales.resize(rows * nb, 0.0);
    for i in 0..rows {
        let srow = &src[i * cols..(i + 1) * cols];
        let qrow = &mut out.q[i * cols..(i + 1) * cols];
        let sc = &mut out.scales[i * nb..(i + 1) * nb];
        for b in 0..nb {
            let lo = b * QBLOCK;
            let hi = (lo + QBLOCK).min(cols);
            // f64::max drops a NaN operand, so NaN entries are ignored
            // here (they still quantize to 0 below, via the saturating
            // float→int cast)
            let amax = srow[lo..hi].iter().fold(0.0f64, |m, &x| m.max(x.abs()));
            if amax == 0.0 || !amax.is_finite() {
                sc[b] = 0.0;
                qrow[lo..hi].fill(0);
                continue;
            }
            let scale = (amax / 127.0) as f32;
            sc[b] = scale;
            let inv = 127.0 / amax;
            for l in lo..hi {
                // `as i32` saturates and maps NaN to 0 — both are the
                // deterministic behavior the contract wants
                qrow[l] = (srow[l] * inv).round() as i32 as i8;
            }
        }
    }
}

/// Scalar int8 block dot — exact in i32 (max |block dot| is
/// 64·127·127 = 1 032 256).
#[inline(always)]
fn q8_block_dot_scalar(x: &[i8], y: &[i8]) -> i32 {
    let mut acc = 0i32;
    for l in 0..x.len() {
        acc += x[l] as i32 * y[l] as i32;
    }
    acc
}

/// AVX2 int8 block dot: 16 i8 lanes widened to i16, `madd_epi16` pairs
/// into i32, lane-reduced at the end. Integer addition is associative,
/// so this equals [`q8_block_dot_scalar`] exactly — no rounding-order
/// rule needed in this tier.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn q8_block_dot_avx2(x: &[i8], y: &[i8]) -> i32 {
    use std::arch::x86_64::*;
    let n = x.len();
    let m16 = n & !15;
    let xp = x.as_ptr();
    let yp = y.as_ptr();
    let mut vs = _mm256_setzero_si256();
    let mut l = 0;
    while l < m16 {
        let vx = _mm256_cvtepi8_epi16(_mm_loadu_si128(xp.add(l) as *const __m128i));
        let vy = _mm256_cvtepi8_epi16(_mm_loadu_si128(yp.add(l) as *const __m128i));
        // i16×i16 products of adjacent lanes summed into 8 i32 lanes;
        // ≤ 2·127² per madd and ≤ 4 madds per block — far from overflow
        vs = _mm256_add_epi32(vs, _mm256_madd_epi16(vx, vy));
        l += 16;
    }
    let lo = _mm256_castsi256_si128(vs);
    let hi = _mm256_extracti128_si256::<1>(vs);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b0100_1110>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b1011_0001>(s));
    let mut acc = _mm_cvtsi128_si32(s);
    while l < n {
        acc += x[l] as i32 * y[l] as i32;
        l += 1;
    }
    acc
}

/// Dispatching int8 block dot (same `use_simd` contract as [`axpy`]).
#[inline(always)]
fn q8_block_dot(use_simd: bool, x: &[i8], y: &[i8]) -> i32 {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    {
        if use_simd {
            // SAFETY: use_simd is true only behind runtime AVX2 detection.
            return unsafe { q8_block_dot_avx2(x, y) };
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = use_simd;
    q8_block_dot_scalar(x, y)
}

/// Dot of row `i` of `qa` with row `j` of `qb`: exact i32 block dots,
/// scaled out in f32 in fixed block order — `acc += (dot · s_a) · s_b`
/// — shared by the scalar and SIMD dispatch, so the two are
/// bit-identical by construction.
fn q8_dot_rows(qa: &QuantMat, i: usize, qb: &QuantMat, j: usize, use_simd: bool) -> f64 {
    debug_assert_eq!(qa.cols, qb.cols, "q8 dot: reduction dims differ");
    let nb = qa.nblocks();
    let xa = qa.row_q(i);
    let xb = qb.row_q(j);
    let sa = qa.row_scales(i);
    let sb = qb.row_scales(j);
    let mut acc = 0.0f32;
    for b in 0..nb {
        let lo = b * QBLOCK;
        let hi = (lo + QBLOCK).min(qa.cols);
        let d = q8_block_dot(use_simd, &xa[lo..hi], &xb[lo..hi]);
        acc += (d as f32 * sa[b]) * sb[b];
    }
    acc as f64
}

/// Quantized Gram: G (n×n, f64) ≈ Aᵀ A for A m×n (f32). The transpose
/// pack is reused from the f64 tier, then quantized per-row (reduction
/// dimension m), and every Gram entry becomes a quantized row dot —
/// upper triangle only, mirrored after. `pack`/`qpack` are caller-owned
/// arenas.
pub fn gram_q8(
    a: &[f32],
    m: usize,
    n: usize,
    pack: &mut Vec<f64>,
    qpack: &mut QuantMat,
    g: &mut [f64],
) {
    gram_q8_tiled(a, m, n, pack, qpack, g, 1, usize::MAX);
}

/// [`gram_q8`] with intra-matrix parallelism (same tile contract as
/// [`gram_f64_par`]: packing + quantization serial, upper-triangle row
/// tiles fanned out, mirror after).
pub fn gram_q8_par(
    a: &[f32],
    m: usize,
    n: usize,
    pack: &mut Vec<f64>,
    qpack: &mut QuantMat,
    g: &mut [f64],
    workers: usize,
) {
    gram_q8_tiled(a, m, n, pack, qpack, g, workers, PAR_MIN_MULADDS);
}

pub(crate) fn gram_q8_tiled(
    a: &[f32],
    m: usize,
    n: usize,
    pack: &mut Vec<f64>,
    qpack: &mut QuantMat,
    g: &mut [f64],
    workers: usize,
    min_muladds: usize,
) {
    assert_eq!(a.len(), m * n, "gram_q8: a is not m×n");
    assert_eq!(g.len(), n * n, "gram_q8: g is not n×n");
    let use_simd = simd_enabled();
    pack_transpose(a, m, n, pack);
    quantize_rows(pack, n, m, qpack);
    let qp: &QuantMat = qpack;
    if workers <= 1 || n < 2 || n * (n + 1) / 2 * m < min_muladds {
        gram_q8_rows(qp, n, 0, n, g, use_simd);
    } else {
        let rows_per = n.div_ceil(4 * workers).max(1);
        let mut jobs = Vec::new();
        let mut g_rest = &mut g[..];
        let mut i0 = 0;
        while i0 < n {
            let rows = rows_per.min(n - i0);
            let (g_t, gr) = std::mem::take(&mut g_rest).split_at_mut(rows * n);
            g_rest = gr;
            jobs.push((i0, g_t, rows));
            i0 += rows;
        }
        crate::lift::engine::par_map(workers, jobs, |_, (i0, g_t, rows)| {
            gram_q8_rows(qp, n, i0, rows, g_t, use_simd);
        });
    }
    mirror_lower(g, n);
}

/// Upper-triangle rows `i0..i0+rows` of the quantized Gram into `g`.
fn gram_q8_rows(qp: &QuantMat, n: usize, i0: usize, rows: usize, g: &mut [f64], use_simd: bool) {
    debug_assert_eq!(g.len(), rows * n);
    for i in 0..rows {
        for j in (i0 + i)..n {
            g[i * n + j] = q8_dot_rows(qp, i0 + i, qp, j, use_simd);
        }
    }
}

/// Quantized product against a transposed right operand:
/// C (ma×mb, f64) ≈ A · Bᵀ where `qa` holds A's rows and `qb` holds B's
/// rows (both quantized along the shared reduction dimension). The
/// subspace iteration uses this as `Y = Xᵀ · G` with G symmetric, so
/// "Bᵀ" costs nothing. `c` is overwritten.
pub fn matmul_q8(qa: &QuantMat, qb: &QuantMat, c: &mut [f64]) {
    matmul_q8_tiled(qa, qb, c, 1, usize::MAX);
}

/// [`matmul_q8`] with intra-matrix parallelism over A's row tiles.
pub fn matmul_q8_par(qa: &QuantMat, qb: &QuantMat, c: &mut [f64], workers: usize) {
    matmul_q8_tiled(qa, qb, c, workers, PAR_MIN_MULADDS);
}

pub(crate) fn matmul_q8_tiled(
    qa: &QuantMat,
    qb: &QuantMat,
    c: &mut [f64],
    workers: usize,
    min_muladds: usize,
) {
    let (ma, mb, k) = (qa.rows, qb.rows, qa.cols);
    assert_eq!(qa.cols, qb.cols, "matmul_q8: reduction dims differ");
    assert_eq!(c.len(), ma * mb, "matmul_q8: c is not ma×mb");
    let use_simd = simd_enabled();
    if workers <= 1 || ma < 2 || ma * k * mb < min_muladds {
        matmul_q8_rows(qa, 0, ma, qb, c, use_simd);
        return;
    }
    let rows_per = ma.div_ceil(workers.min(ma));
    let mut jobs = Vec::new();
    let mut c_rest = c;
    let mut i0 = 0;
    while i0 < ma {
        let rows = rows_per.min(ma - i0);
        let (c_t, cr) = std::mem::take(&mut c_rest).split_at_mut(rows * mb);
        c_rest = cr;
        jobs.push((i0, c_t, rows));
        i0 += rows;
    }
    crate::lift::engine::par_map(workers, jobs, |_, (i0, c_t, rows)| {
        matmul_q8_rows(qa, i0, rows, qb, c_t, use_simd);
    });
}

/// Output rows `i0..i0+rows` of the quantized A·Bᵀ product.
fn matmul_q8_rows(
    qa: &QuantMat,
    i0: usize,
    rows: usize,
    qb: &QuantMat,
    c: &mut [f64],
    use_simd: bool,
) {
    let mb = qb.rows;
    debug_assert_eq!(c.len(), rows * mb);
    for i in 0..rows {
        for j in 0..mb {
            c[i * mb + j] = q8_dot_rows(qa, i0 + i, qb, j, use_simd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for l in 0..k {
                    acc += a[i * k + l] * b[l * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn bits_eq(x: &[f64], y: &[f64]) -> bool {
        x.len() == y.len() && x.iter().zip(y).all(|(a, b)| a.to_bits() == b.to_bits())
    }

    #[test]
    fn blocked_matches_naive_across_panel_boundaries() {
        let mut rng = Rng::new(3);
        // sizes straddling the KC panel boundary, incl. degenerate dims
        for (m, k, n) in [(7usize, 130usize, 9usize), (1, 64, 5), (5, 63, 1), (3, 65, 4)] {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal() as f64).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
            let mut c = vec![1.0f64; m * n]; // nonzero: kernel must overwrite
            matmul_f64(&a, &b, m, k, n, &mut c);
            let want = naive(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-9, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn tn_variant_matches_explicit_transpose() {
        let mut rng = Rng::new(5);
        let (k, m, n) = (70usize, 6usize, 11usize);
        let a: Vec<f64> = (0..k * m).map(|_| rng.normal() as f64).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
        let mut at = vec![0.0f64; m * k];
        for l in 0..k {
            for i in 0..m {
                at[i * k + l] = a[l * m + i];
            }
        }
        let want = naive(&at, &b, m, k, n);
        let mut c = vec![0.0f64; m * n];
        matmul_tn_f64(&a, &b, k, m, n, &mut c);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn mixed_precision_matches_f64_reference() {
        let mut rng = Rng::new(7);
        let (m, k, n) = (9usize, 129usize, 8usize);
        let a32: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
        let a64: Vec<f64> = a32.iter().map(|&x| x as f64).collect();
        let want = naive(&a64, &b, m, k, n);
        let mut c = vec![0.0f32; m * n];
        matmul_f32xf64(&a32, &b, m, k, n, &mut c);
        for (x, y) in c.iter().zip(&want) {
            assert!((*x as f64 - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn gram_is_symmetric_and_exact() {
        let mut rng = Rng::new(9);
        let (m, n) = (37usize, 12usize);
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut pack = Vec::new();
        let mut g = vec![0.0f64; n * n];
        gram_f64(&a, m, n, &mut pack, &mut g);
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0f64;
                for l in 0..m {
                    acc += a[l * n + i] as f64 * a[l * n + j] as f64;
                }
                assert!((g[i * n + j] - acc).abs() < 1e-9);
                assert_eq!(g[i * n + j].to_bits(), g[j * n + i].to_bits(), "not symmetric");
            }
        }
        // pack scratch is an arena: a second, smaller-shape call reuses
        // it — and the shrinking resize keeps the larger capacity
        let (m2, n2) = (5usize, 4usize);
        let a2: Vec<f32> = (0..m2 * n2).map(|_| rng.normal()).collect();
        let mut g2 = vec![0.0f64; n2 * n2];
        gram_f64(&a2, m2, n2, &mut pack, &mut g2);
        assert!((g2[0] - (0..m2).map(|l| (a2[l * n2] as f64).powi(2)).sum::<f64>()).abs() < 1e-9);
        assert_eq!(pack.len(), n2 * m2);
        assert!(pack.capacity() >= m * n, "arena capacity must survive a shrinking call");
    }

    #[test]
    fn deterministic_bitwise() {
        let mut rng = Rng::new(11);
        let (m, k, n) = (8usize, 100usize, 7usize);
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal() as f64).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
        let mut c1 = vec![0.0f64; m * n];
        let mut c2 = vec![0.0f64; m * n];
        matmul_f64(&a, &b, m, k, n, &mut c1);
        matmul_f64(&a, &b, m, k, n, &mut c2);
        assert!(bits_eq(&c1, &c2));
    }

    /// Scalar and SIMD kernels must agree BITWISE across KC panel
    /// boundaries and degenerate shapes (m=1 / n=1 / k < KC). On hosts
    /// without AVX2 the SIMD side clamps to scalar and the test passes
    /// vacuously; CI's x86-64 runners exercise the real comparison.
    #[test]
    fn simd_matches_scalar_bitwise() {
        let simd = simd_supported();
        let mut rng = Rng::new(13);
        // axpy-family kernels: matmul, tn, mixed precision
        for (m, k, n) in [
            (7usize, 130usize, 9usize),
            (1, 64, 5),
            (5, 63, 1),
            (3, 65, 4),
            (4, 30, 17),
            (2, 129, 8),
        ] {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal() as f64).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
            let mut cs = vec![0.0f64; m * n];
            let mut cv = vec![1.0f64; m * n];
            matmul_f64_rows(&a, &b, m, k, n, &mut cs, false);
            matmul_f64_rows(&a, &b, m, k, n, &mut cv, simd);
            assert!(bits_eq(&cs, &cv), "matmul parity broke at ({m},{k},{n})");

            // reuse (m, k, n) as the tn shape (a is k×m there)
            let at: Vec<f64> = (0..k * m).map(|_| rng.normal() as f64).collect();
            let mut ts = vec![0.0f64; m * n];
            let mut tv = vec![1.0f64; m * n];
            matmul_tn_rows(&at, &b, k, m, n, 0, m, &mut ts, false);
            matmul_tn_rows(&at, &b, k, m, n, 0, m, &mut tv, simd);
            assert!(bits_eq(&ts, &tv), "tn parity broke at ({k},{m},{n})");

            let a32: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let mut ms = vec![0.0f32; m * n];
            let mut mv = vec![1.0f32; m * n];
            let mut acc = vec![0.0f64; n];
            matmul_f32xf64_rows(&a32, &b, m, k, n, &mut ms, &mut acc, false);
            matmul_f32xf64_rows(&a32, &b, m, k, n, &mut mv, &mut acc, simd);
            assert!(
                ms.iter().zip(&mv).all(|(x, y)| x.to_bits() == y.to_bits()),
                "mixed-precision parity broke at ({m},{k},{n})"
            );
        }
        // dot-family kernel (Gram): column length m hits every tail
        // residue of the quad-accumulator order
        for (m, n) in [(37usize, 12usize), (64, 3), (1, 7), (7, 1), (130, 9), (5, 4)] {
            let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let mut pack = Vec::new();
            pack_transpose(&a, m, n, &mut pack);
            let mut gs = vec![0.0f64; n * n];
            let mut gv = vec![1.0f64; n * n];
            gram_rows(&pack, m, n, 0, n, &mut gs, false);
            mirror_lower(&mut gs, n);
            gram_rows(&pack, m, n, 0, n, &mut gv, simd);
            mirror_lower(&mut gv, n);
            assert!(bits_eq(&gs, &gv), "gram parity broke at ({m},{n})");
        }
    }

    /// The parallel tile tier must be bit-identical to the serial kernel
    /// for any worker count, including more workers than rows
    /// (threshold forced to 0 so tiny shapes take the parallel path).
    #[test]
    fn tiled_matches_serial_bitwise_for_any_worker_count() {
        let mut rng = Rng::new(17);
        let (m, k, n) = (13usize, 70usize, 11usize);
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal() as f64).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
        let mut want = vec![0.0f64; m * n];
        matmul_f64(&a, &b, m, k, n, &mut want);
        for w in [1usize, 2, 3, 8, 32] {
            let mut c = vec![1.0f64; m * n];
            matmul_f64_tiled(&a, &b, m, k, n, &mut c, w, 0);
            assert!(bits_eq(&c, &want), "matmul tiling diverged at {w} workers");
        }

        let (k2, m2, n2) = (66usize, 9usize, 8usize);
        let a2: Vec<f64> = (0..k2 * m2).map(|_| rng.normal() as f64).collect();
        let b2: Vec<f64> = (0..k2 * n2).map(|_| rng.normal() as f64).collect();
        let mut want_tn = vec![0.0f64; m2 * n2];
        matmul_tn_f64(&a2, &b2, k2, m2, n2, &mut want_tn);
        for w in [2usize, 5, 16] {
            let mut c = vec![1.0f64; m2 * n2];
            matmul_tn_f64_tiled(&a2, &b2, k2, m2, n2, &mut c, w, 0);
            assert!(bits_eq(&c, &want_tn), "tn tiling diverged at {w} workers");
        }

        let a32: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let mut want_mx = vec![0.0f32; m * n];
        matmul_f32xf64(&a32, &b, m, k, n, &mut want_mx);
        let mut acc = Vec::new(); // one arena reused across worker counts
        for w in [2usize, 4, 9] {
            let mut c = vec![1.0f32; m * n];
            matmul_f32xf64_tiled(&a32, &b, m, k, n, &mut c, w, 0, &mut acc);
            assert!(
                c.iter().zip(&want_mx).all(|(x, y)| x.to_bits() == y.to_bits()),
                "mixed-precision tiling diverged at {w} workers"
            );
        }

        let (gm, gn) = (41usize, 14usize);
        let ga: Vec<f32> = (0..gm * gn).map(|_| rng.normal()).collect();
        let mut pack = Vec::new();
        let mut want_g = vec![0.0f64; gn * gn];
        gram_f64(&ga, gm, gn, &mut pack, &mut want_g);
        for w in [2usize, 3, 16] {
            let mut g = vec![1.0f64; gn * gn];
            gram_f64_tiled(&ga, gm, gn, &mut pack, &mut g, w, 0);
            assert!(bits_eq(&g, &want_g), "gram tiling diverged at {w} workers");
        }
    }

    /// Satellite-1 regression: the `_with` variant must match the
    /// allocating wrapper bitwise while reusing one accumulator arena
    /// across different shapes.
    #[test]
    fn with_scratch_matches_allocating_wrapper_across_shapes() {
        let mut rng = Rng::new(19);
        let mut acc = Vec::new();
        for (m, k, n) in [(9usize, 129usize, 8usize), (3, 10, 5), (6, 64, 12), (1, 7, 1)] {
            let a32: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
            let mut c1 = vec![0.0f32; m * n];
            let mut c2 = vec![1.0f32; m * n];
            matmul_f32xf64(&a32, &b, m, k, n, &mut c1);
            matmul_f32xf64_with(&a32, &b, m, k, n, &mut c2, &mut acc);
            assert!(
                c1.iter().zip(&c2).all(|(x, y)| x.to_bits() == y.to_bits()),
                "scratch variant diverged at ({m},{k},{n})"
            );
            assert_eq!(acc.len(), n);
        }
        assert!(acc.capacity() >= 12, "accumulator arena must be retained");
    }

    // ---- quantized scan tier (ISSUE 10) ----

    /// Per-entry dequantization error is bounded by half a quantization
    /// step (scale/2 = absmax/254 per block) — the contract every
    /// downstream tolerance builds on.
    #[test]
    fn quantize_roundtrip_error_is_bounded_per_block() {
        let mut rng = Rng::new(23);
        for (rows, cols) in [(3usize, 130usize), (1, 64), (5, 63), (4, 1), (2, 200)] {
            let src: Vec<f64> = (0..rows * cols).map(|_| rng.normal() as f64 * 3.0).collect();
            let mut q = QuantMat::new();
            quantize_rows(&src, rows, cols, &mut q);
            for i in 0..rows {
                let sc = q.row_scales(i);
                let qr = q.row_q(i);
                for l in 0..cols {
                    let s = sc[l / QBLOCK] as f64;
                    let deq = qr[l] as f64 * s;
                    assert!(
                        (deq - src[i * cols + l]).abs() <= 0.5 * s + 1e-12,
                        "({rows},{cols}) entry ({i},{l}): {deq} vs {}",
                        src[i * cols + l]
                    );
                }
            }
        }
    }

    #[test]
    fn quantize_zeroes_nonfinite_blocks_and_entries() {
        // block 0 holds a NaN entry among finite ones (entry-level zero),
        // block 1 is all-zero (scale 0), block 2 holds an inf (whole
        // block zeroed because its absmax is non-finite)
        let cols = 3 * QBLOCK;
        let mut src = vec![0.0f64; cols];
        src[0] = 2.0;
        src[1] = f64::NAN;
        src[2 * QBLOCK] = f64::INFINITY;
        src[2 * QBLOCK + 1] = 5.0;
        let mut q = QuantMat::new();
        quantize_rows(&src, 1, cols, &mut q);
        let qr = q.row_q(0);
        let sc = q.row_scales(0);
        assert_eq!(qr[0], 127, "finite absmax entry quantizes to ±127");
        assert_eq!(qr[1], 0, "NaN entry must quantize to 0");
        assert!(sc[0] > 0.0);
        assert_eq!(sc[1], 0.0, "all-zero block gets scale 0");
        assert_eq!(sc[2], 0.0, "non-finite block gets scale 0");
        assert!(qr[2 * QBLOCK..].iter().all(|&x| x == 0));
        // and the products stay finite: dot of the row with itself
        let d = q8_dot_rows(&q, 0, &q, 0, false);
        assert!(d.is_finite(), "q8 dot leaked a non-finite value: {d}");
    }

    /// The int8 dots are exact integers, so scalar and SIMD must agree
    /// BITWISE (not just to tolerance) across block-tail residues.
    #[test]
    fn q8_simd_matches_scalar_bitwise() {
        let simd = simd_supported();
        let mut rng = Rng::new(29);
        for (m, n) in [(37usize, 12usize), (64, 3), (1, 7), (7, 1), (130, 9), (79, 5), (200, 6)] {
            let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let mut pack = Vec::new();
            let mut qp = QuantMat::new();
            pack_transpose(&a, m, n, &mut pack);
            quantize_rows(&pack, n, m, &mut qp);
            let mut gs = vec![0.0f64; n * n];
            let mut gv = vec![1.0f64; n * n];
            gram_q8_rows(&qp, n, 0, n, &mut gs, false);
            mirror_lower(&mut gs, n);
            gram_q8_rows(&qp, n, 0, n, &mut gv, simd);
            mirror_lower(&mut gv, n);
            assert!(bits_eq(&gs, &gv), "q8 gram parity broke at ({m},{n})");
        }
        // the A·Bᵀ kernel too, with a reduction dim that leaves both a
        // 16-lane tail and a QBLOCK tail
        let (ma, mb, k) = (5usize, 4usize, 77usize);
        let a: Vec<f64> = (0..ma * k).map(|_| rng.normal() as f64).collect();
        let b: Vec<f64> = (0..mb * k).map(|_| rng.normal() as f64).collect();
        let (mut qa, mut qb) = (QuantMat::new(), QuantMat::new());
        quantize_rows(&a, ma, k, &mut qa);
        quantize_rows(&b, mb, k, &mut qb);
        let mut cs = vec![0.0f64; ma * mb];
        let mut cv = vec![1.0f64; ma * mb];
        matmul_q8_rows(&qa, 0, ma, &qb, &mut cs, false);
        matmul_q8_rows(&qa, 0, ma, &qb, &mut cv, simd);
        assert!(bits_eq(&cs, &cv), "q8 matmul parity broke");
    }

    /// Same tile-ownership contract as the f64 tier: any worker count is
    /// bit-identical to serial (threshold forced to 0).
    #[test]
    fn q8_tiled_matches_serial_bitwise_for_any_worker_count() {
        let mut rng = Rng::new(31);
        let (m, n) = (41usize, 14usize);
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut pack = Vec::new();
        let mut qp = QuantMat::new();
        let mut want = vec![0.0f64; n * n];
        gram_q8(&a, m, n, &mut pack, &mut qp, &mut want);
        for w in [1usize, 2, 3, 16] {
            let mut g = vec![1.0f64; n * n];
            gram_q8_tiled(&a, m, n, &mut pack, &mut qp, &mut g, w, 0);
            assert!(bits_eq(&g, &want), "q8 gram tiling diverged at {w} workers");
        }
        let (ma, mb, k) = (13usize, 11usize, 70usize);
        let av: Vec<f64> = (0..ma * k).map(|_| rng.normal() as f64).collect();
        let bv: Vec<f64> = (0..mb * k).map(|_| rng.normal() as f64).collect();
        let (mut qa, mut qb) = (QuantMat::new(), QuantMat::new());
        quantize_rows(&av, ma, k, &mut qa);
        quantize_rows(&bv, mb, k, &mut qb);
        let mut want_c = vec![0.0f64; ma * mb];
        matmul_q8(&qa, &qb, &mut want_c);
        for w in [2usize, 5, 32] {
            let mut c = vec![1.0f64; ma * mb];
            matmul_q8_tiled(&qa, &qb, &mut c, w, 0);
            assert!(bits_eq(&c, &want_c), "q8 matmul tiling diverged at {w} workers");
        }
    }

    /// The quantized Gram tracks the f64 Gram to the blockwise error
    /// bound — the numeric basis of the LIFT_QSCAN_TOL selection gate.
    #[test]
    fn q8_gram_tracks_f64_gram() {
        let mut rng = Rng::new(37);
        let (m, n) = (130usize, 9usize);
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut pack = Vec::new();
        let mut g64 = vec![0.0f64; n * n];
        gram_f64(&a, m, n, &mut pack, &mut g64);
        let mut qp = QuantMat::new();
        let mut gq = vec![0.0f64; n * n];
        gram_q8(&a, m, n, &mut pack, &mut qp, &mut gq);
        let scale = g64.iter().fold(0.0f64, |s, x| s.max(x.abs()));
        for (x, y) in gq.iter().zip(&g64) {
            assert!(
                (x - y).abs() <= 0.02 * scale,
                "quantized Gram drifted: {x} vs {y} (scale {scale})"
            );
        }
    }
}

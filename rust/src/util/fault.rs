//! Deterministic, seeded fault injection over every durable-state IO
//! call site — the failpoint seam the crash/fault torture harness
//! (`exp::torture`, `lift torture`) replays schedules through.
//!
//! Every module that persists state the system must survive losing —
//! snapshots (`ckpt::write_atomic` / `prune_snapshots` /
//! `Snapshot::read_from`, and the `AsyncSnapshotWriter` thread on top),
//! the curve sidecar prefix-rewrite (`ckpt::curve`), the tenant delta
//! store (`serve::DeltaStore`), cell leases (`exp::lease`) and the
//! outcome ledger (`exp::matrix`) — routes its filesystem calls through
//! the free functions here ([`write`], [`create_new`], [`rename`],
//! [`read`], [`read_to_string`], [`remove_file`], [`create_dir_all`],
//! [`sync_file_at`], [`sync_dir`]) instead of `std::fs` directly.
//!
//! # Passthrough by default
//!
//! Nothing is injected unless a [`FaultPlan`] is [`arm`]ed: the seam's
//! fast path is one relaxed atomic load and then the verbatim `std::fs`
//! call, so release hot paths pay nothing measurable. Arming is
//! process-global (the `AsyncSnapshotWriter` thread and pool workers
//! must see the same schedule), so armed phases belong in dedicated,
//! serialized test binaries — never in concurrent unit tests.
//!
//! # Schedules
//!
//! A plan maps `(op class, per-class call index)` to a [`FaultKind`]:
//! the Nth call of a class fails with the planned fault, all other
//! calls pass through. Plans come from [`FaultPlan::seeded`] (a seeded
//! RNG draw — same seed, same schedule, forever) or [`FaultPlan::parse`]
//! (`"write:enospc@3,rename:crash-before@0"` or `"auto:N[:horizon]"`,
//! the `LIFT_FAULT_SCHEDULE` syntax; [`arm_from_env`] wires it to the
//! CLI together with `LIFT_FAULT_SEED`).
//!
//! # Error classification — transient vs permanent
//!
//! Injected (and real) errors of kind `Interrupted`/`WouldBlock` are
//! EINTR/EAGAIN-style *transient*: the seam retries them in place with
//! bounded backoff ([`MAX_RETRIES`], 2/4/8/16 ms) and counts the
//! retries. Everything else — ENOSPC, EIO, EACCES, short writes, crash
//! faults — is *permanent* and propagates to the caller untouched, per
//! the repo's "Unreadable ≠ Corrupt" doctrine: an IO failure proves
//! nothing about the bytes, so the caller must surface it loudly, never
//! fold it into "missing" or "claimable". Every injected error's
//! message carries the [`INJECTED_MARK`] marker plus the fault's name,
//! class, index, and path, so torture assertions can tell a planned
//! fault from an environmental one.
//!
//! # Crash faults
//!
//! `crash-before` / `crash-after` (rename class only) simulate dying in
//! the atomic-commit window *in process*: `crash-before` skips the
//! rename (the temp file is left behind, the destination untouched) and
//! `crash-after` performs the rename and THEN reports failure (the
//! commit landed but the caller believes it did not — recovery must be
//! idempotent). Both then surface as permanent errors; a real `kill -9`
//! differs only in that no error unwinds, which the torture harness's
//! recovery-rerun covers the same way.
//!
//! # Fsync gate
//!
//! [`sync_file_at`]/[`sync_dir`] implement the durability half of
//! `ckpt::write_atomic` (fsync file + parent dir around the rename).
//! `LIFT_NO_FSYNC=1` turns both into no-ops for tests and tmpfs smoke
//! runs; the default is fsync ON.

use std::collections::BTreeMap;
use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use anyhow::Result;

use crate::util::rng::Rng;

/// Marker every injected error message carries (the torture harness's
/// loud-failure assertion greps observed errors for it).
pub const INJECTED_MARK: &str = "injected fault";

/// Bounded-backoff retry cap for transient (EINTR/EAGAIN-class) errors.
pub const MAX_RETRIES: u32 = 4;

/// The seam's operation classes; a plan addresses faults per class by
/// the class's own call counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OpClass {
    /// `write` / `create_new` payload writes.
    Write,
    /// `rename` commits (the atomic-write rename).
    Rename,
    /// `read` / `read_to_string`.
    Read,
    /// `remove_file` (retention pruning, lease release, delta delete).
    Remove,
    /// `sync_file_at` / `sync_dir` fsyncs.
    Sync,
    /// `create_dir_all`.
    Dir,
}

impl OpClass {
    pub const ALL: [OpClass; 6] = [
        OpClass::Write,
        OpClass::Rename,
        OpClass::Read,
        OpClass::Remove,
        OpClass::Sync,
        OpClass::Dir,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OpClass::Write => "write",
            OpClass::Rename => "rename",
            OpClass::Read => "read",
            OpClass::Remove => "remove",
            OpClass::Sync => "sync",
            OpClass::Dir => "dir",
        }
    }

    fn parse(s: &str) -> Option<OpClass> {
        OpClass::ALL.iter().copied().find(|c| c.name() == s)
    }

    /// Fault kinds that can physically occur on this class (a seeded
    /// plan only draws compatible kinds; `parse` rejects the rest).
    pub fn kinds(self) -> &'static [FaultKind] {
        use FaultKind::*;
        match self {
            OpClass::Write => &[Enospc, Eio, Eacces, Eintr, ShortWrite],
            OpClass::Rename => &[Eio, Eacces, Eintr, CrashBeforeRename, CrashAfterRename],
            OpClass::Read => &[Eio, Eacces, Eintr, Eagain],
            OpClass::Remove => &[Eio, Eacces, Eintr],
            OpClass::Sync => &[Enospc, Eio, Eintr],
            OpClass::Dir => &[Enospc, Eacces, Eintr],
        }
    }
}

/// What an armed call site fails with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Permanent: disk full.
    Enospc,
    /// Permanent: device-level IO error.
    Eio,
    /// Permanent: permission denied.
    Eacces,
    /// Transient: interrupted syscall — the seam retries it.
    Eintr,
    /// Transient: would-block — the seam retries it.
    Eagain,
    /// Permanent: half the payload reaches the file, then failure (a
    /// torn temp is left on disk).
    ShortWrite,
    /// Permanent, rename only: die before the rename — temp left
    /// behind, destination untouched.
    CrashBeforeRename,
    /// Permanent, rename only: the rename LANDS, then failure is
    /// reported — recovery must tolerate "it committed after all".
    CrashAfterRename,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Enospc => "enospc",
            FaultKind::Eio => "eio",
            FaultKind::Eacces => "eacces",
            FaultKind::Eintr => "eintr",
            FaultKind::Eagain => "eagain",
            FaultKind::ShortWrite => "short",
            FaultKind::CrashBeforeRename => "crash-before",
            FaultKind::CrashAfterRename => "crash-after",
        }
    }

    fn parse(s: &str) -> Option<FaultKind> {
        use FaultKind::*;
        [Enospc, Eio, Eacces, Eintr, Eagain, ShortWrite, CrashBeforeRename, CrashAfterRename]
            .into_iter()
            .find(|k| k.name() == s)
    }

    /// EINTR/EAGAIN-class faults are retried in place; everything else
    /// propagates loudly.
    pub fn is_transient(self) -> bool {
        matches!(self, FaultKind::Eintr | FaultKind::Eagain)
    }

    fn io_kind(self) -> io::ErrorKind {
        match self {
            // stable-ErrorKind stand-ins: ENOSPC/EIO/short/crash map to
            // Other (the message names the precise fault)
            FaultKind::Enospc
            | FaultKind::Eio
            | FaultKind::ShortWrite
            | FaultKind::CrashBeforeRename
            | FaultKind::CrashAfterRename => io::ErrorKind::Other,
            FaultKind::Eacces => io::ErrorKind::PermissionDenied,
            FaultKind::Eintr => io::ErrorKind::Interrupted,
            FaultKind::Eagain => io::ErrorKind::WouldBlock,
        }
    }
}

/// A deterministic fault schedule: the `(class, index)`-th call of each
/// op class fails with the mapped kind.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: BTreeMap<(OpClass, u64), FaultKind>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Draw `n` distinct `(class, idx < horizon)` sites with
    /// class-compatible kinds from a seeded RNG. Same `(seed, n,
    /// horizon)` → byte-identical plan, forever — the torture
    /// determinism contract starts here.
    pub fn seeded(seed: u64, n: usize, horizon: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA_0175EED);
        let mut faults = BTreeMap::new();
        let mut attempts = 0usize;
        while faults.len() < n && attempts < n * 32 + 64 {
            attempts += 1;
            let class = OpClass::ALL[rng.below(OpClass::ALL.len())];
            let idx = rng.below(horizon.max(1) as usize) as u64;
            let kinds = class.kinds();
            let kind = kinds[rng.below(kinds.len())];
            faults.entry((class, idx)).or_insert(kind);
        }
        FaultPlan { faults }
    }

    /// Parse the `LIFT_FAULT_SCHEDULE` syntax: either a comma list of
    /// `class:kind@idx` entries (`"write:enospc@3,rename:crash-before@0"`)
    /// or `"auto:N[:horizon]"` — N seeded faults over the first
    /// `horizon` (default 64) calls per class, drawn from `seed`.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let spec = spec.trim();
        if let Some(rest) = spec.strip_prefix("auto:") {
            let mut parts = rest.splitn(2, ':');
            let n: usize = parts
                .next()
                .unwrap_or("")
                .parse()
                .map_err(|_| anyhow::anyhow!("bad fault schedule '{spec}': auto:N expects a count"))?;
            let horizon: u64 = match parts.next() {
                Some(h) => h
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad fault schedule '{spec}': horizon must be an integer"))?,
                None => 64,
            };
            return Ok(FaultPlan::seeded(seed, n, horizon));
        }
        let mut faults = BTreeMap::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (class_kind, idx) = entry.split_once('@').ok_or_else(|| {
                anyhow::anyhow!("bad fault entry '{entry}': expected class:kind@idx")
            })?;
            let (class_s, kind_s) = class_kind.split_once(':').ok_or_else(|| {
                anyhow::anyhow!("bad fault entry '{entry}': expected class:kind@idx")
            })?;
            let class = OpClass::parse(class_s).ok_or_else(|| {
                anyhow::anyhow!(
                    "bad fault entry '{entry}': unknown class '{class_s}' (one of write, \
                     rename, read, remove, sync, dir)"
                )
            })?;
            let kind = FaultKind::parse(kind_s)
                .ok_or_else(|| anyhow::anyhow!("bad fault entry '{entry}': unknown kind '{kind_s}'"))?;
            anyhow::ensure!(
                class.kinds().contains(&kind),
                "bad fault entry '{entry}': kind '{kind_s}' cannot occur on class '{class_s}'"
            );
            let idx: u64 = idx
                .parse()
                .map_err(|_| anyhow::anyhow!("bad fault entry '{entry}': index must be an integer"))?;
            faults.insert((class, idx), kind);
        }
        Ok(FaultPlan { faults })
    }

    /// Render the plan back in `parse` syntax (sorted — deterministic),
    /// for reports and logs.
    pub fn spec(&self) -> String {
        self.faults
            .iter()
            .map(|(&(class, idx), kind)| format!("{}:{}@{idx}", class.name(), kind.name()))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// What an armed phase did, returned by [`disarm`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Planned faults that actually fired (a plan site past the op
    /// stream's end never fires).
    pub injected: usize,
    /// Transient errors absorbed by the bounded-backoff retry loop.
    pub retried: usize,
}

struct Armed {
    plan: FaultPlan,
    counters: BTreeMap<OpClass, u64>,
    stats: FaultStats,
}

// Fast-path gate: a single relaxed load keeps the disarmed seam at
// passthrough cost; the mutex is only touched while a plan is armed.
static ACTIVE: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<Armed>> = Mutex::new(None);

fn state_lock() -> std::sync::MutexGuard<'static, Option<Armed>> {
    STATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arm a fault plan process-wide; call counters and stats start at
/// zero. Arming replaces any previously armed plan.
pub fn arm(plan: FaultPlan) {
    let mut st = state_lock();
    *st = Some(Armed {
        plan,
        counters: BTreeMap::new(),
        stats: FaultStats::default(),
    });
    ACTIVE.store(true, Ordering::SeqCst);
}

/// Disarm and return what the armed phase injected/retried; a no-op
/// (default stats) when nothing was armed.
pub fn disarm() -> FaultStats {
    let mut st = state_lock();
    ACTIVE.store(false, Ordering::SeqCst);
    st.take().map(|a| a.stats).unwrap_or_default()
}

pub fn is_armed() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Arm from `LIFT_FAULT_SCHEDULE` (+ `LIFT_FAULT_SEED`, default 0) if
/// set; returns whether a plan was armed. The CLI calls this once at
/// startup so any subcommand can run under an injected schedule.
pub fn arm_from_env() -> Result<bool> {
    let Ok(spec) = std::env::var("LIFT_FAULT_SCHEDULE") else {
        return Ok(false);
    };
    if spec.trim().is_empty() {
        return Ok(false);
    }
    let seed = std::env::var("LIFT_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let plan = FaultPlan::parse(&spec, seed)?;
    log::info!("fault injection armed from env: {}", plan.spec());
    arm(plan);
    Ok(true)
}

/// Whether the durability fsyncs are live (`LIFT_NO_FSYNC=1` disables
/// them for tests/smoke runs; read once per process).
pub fn fsync_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| std::env::var("LIFT_NO_FSYNC").map(|v| v != "1").unwrap_or(true))
}

/// Consume this class's next call slot; `Some(kind)` if the plan
/// scheduled a fault there. Each retry attempt consumes its own slot,
/// so a schedule can hit a retry too — still deterministically.
fn take_fault(class: OpClass) -> Option<FaultKind> {
    if !ACTIVE.load(Ordering::Relaxed) {
        return None;
    }
    let mut st = state_lock();
    let armed = st.as_mut()?;
    let ctr = armed.counters.entry(class).or_insert(0);
    let idx = *ctr;
    *ctr += 1;
    let hit = armed.plan.faults.get(&(class, idx)).copied();
    if hit.is_some() {
        armed.stats.injected += 1;
    }
    hit
}

fn note_retry() {
    if !ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    if let Some(armed) = state_lock().as_mut() {
        armed.stats.retried += 1;
    }
}

fn injected(kind: FaultKind, class: OpClass, path: &Path) -> io::Error {
    io::Error::new(
        kind.io_kind(),
        format!(
            "{INJECTED_MARK}: {} during {} on {}",
            kind.name(),
            class.name(),
            path.display()
        ),
    )
}

fn is_transient_err(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::Interrupted | io::ErrorKind::WouldBlock)
}

/// The retry loop every seam op runs inside: consult the plan, run the
/// op, absorb transient errors with bounded backoff, propagate the
/// rest.
fn run_op<T>(class: OpClass, mut op: impl FnMut(Option<FaultKind>) -> io::Result<T>) -> io::Result<T> {
    let mut attempt: u32 = 0;
    loop {
        match op(take_fault(class)) {
            Ok(v) => return Ok(v),
            Err(e) if is_transient_err(&e) && attempt < MAX_RETRIES => {
                note_retry();
                std::thread::sleep(std::time::Duration::from_millis(2u64 << attempt));
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

/// `std::fs::write` through the seam ([`OpClass::Write`]). A planned
/// `short` fault writes half the payload, then fails — the torn temp
/// the atomic-commit pattern must make harmless.
pub fn write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    run_op(OpClass::Write, |fault| match fault {
        None => std::fs::write(path, bytes),
        Some(FaultKind::ShortWrite) => {
            let _ = std::fs::write(path, &bytes[..bytes.len() / 2]);
            Err(injected(FaultKind::ShortWrite, OpClass::Write, path))
        }
        Some(k) => Err(injected(k, OpClass::Write, path)),
    })
}

/// `O_CREAT|O_EXCL` create + full payload write ([`OpClass::Write`]) —
/// the lease claim's winner-picking primitive. A `short` fault creates
/// the file but tears the payload.
pub fn create_new(path: &Path, bytes: &[u8]) -> io::Result<()> {
    run_op(OpClass::Write, |fault| {
        let short = match fault {
            None => false,
            Some(FaultKind::ShortWrite) => true,
            Some(k) => return Err(injected(k, OpClass::Write, path)),
        };
        let mut f = std::fs::OpenOptions::new().write(true).create_new(true).open(path)?;
        use std::io::Write as _;
        if short {
            let _ = f.write_all(&bytes[..bytes.len() / 2]);
            return Err(injected(FaultKind::ShortWrite, OpClass::Write, path));
        }
        f.write_all(bytes)
    })
}

/// `std::fs::rename` through the seam ([`OpClass::Rename`]); the only
/// class where the crash faults live (see the module doc).
pub fn rename(from: &Path, to: &Path) -> io::Result<()> {
    run_op(OpClass::Rename, |fault| match fault {
        None => std::fs::rename(from, to),
        Some(FaultKind::CrashBeforeRename) => {
            Err(injected(FaultKind::CrashBeforeRename, OpClass::Rename, to))
        }
        Some(FaultKind::CrashAfterRename) => {
            std::fs::rename(from, to)?;
            Err(injected(FaultKind::CrashAfterRename, OpClass::Rename, to))
        }
        Some(k) => Err(injected(k, OpClass::Rename, to)),
    })
}

/// `std::fs::read` through the seam ([`OpClass::Read`]).
pub fn read(path: &Path) -> io::Result<Vec<u8>> {
    run_op(OpClass::Read, |fault| match fault {
        None => std::fs::read(path),
        Some(k) => Err(injected(k, OpClass::Read, path)),
    })
}

/// `std::fs::read_to_string` through the seam ([`OpClass::Read`]).
pub fn read_to_string(path: &Path) -> io::Result<String> {
    run_op(OpClass::Read, |fault| match fault {
        None => std::fs::read_to_string(path),
        Some(k) => Err(injected(k, OpClass::Read, path)),
    })
}

/// `std::fs::remove_file` through the seam ([`OpClass::Remove`]).
pub fn remove_file(path: &Path) -> io::Result<()> {
    run_op(OpClass::Remove, |fault| match fault {
        None => std::fs::remove_file(path),
        Some(k) => Err(injected(k, OpClass::Remove, path)),
    })
}

/// `std::fs::create_dir_all` through the seam ([`OpClass::Dir`]).
pub fn create_dir_all(path: &Path) -> io::Result<()> {
    run_op(OpClass::Dir, |fault| match fault {
        None => std::fs::create_dir_all(path),
        Some(k) => Err(injected(k, OpClass::Dir, path)),
    })
}

/// Reopen `path` and fsync its data + metadata ([`OpClass::Sync`]);
/// no-op under `LIFT_NO_FSYNC=1`.
pub fn sync_file_at(path: &Path) -> io::Result<()> {
    if !fsync_enabled() {
        return Ok(());
    }
    run_op(OpClass::Sync, |fault| match fault {
        None => std::fs::File::open(path)?.sync_all(),
        Some(k) => Err(injected(k, OpClass::Sync, path)),
    })
}

/// Fsync a directory so a just-renamed entry survives power loss
/// ([`OpClass::Sync`]); no-op under `LIFT_NO_FSYNC=1` and on platforms
/// where directories cannot be opened for sync.
pub fn sync_dir(dir: &Path) -> io::Result<()> {
    if !fsync_enabled() {
        return Ok(());
    }
    run_op(OpClass::Sync, |fault| {
        if let Some(k) = fault {
            return Err(injected(k, OpClass::Sync, dir));
        }
        if cfg!(unix) {
            std::fs::File::open(dir)?.sync_all()
        } else {
            Ok(())
        }
    })
}

// NOTE: unit tests here stay PURE (plan construction only). Arming is
// process-global, and the lib test binary runs ckpt/lease/serve unit
// tests concurrently — an armed plan would inject into them. Armed
// coverage lives in the dedicated, serialized `rust/tests/torture.rs`.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_deterministic_and_class_compatible() {
        let a = FaultPlan::seeded(7, 5, 40);
        let b = FaultPlan::seeded(7, 5, 40);
        assert_eq!(a, b, "same seed must give the same plan");
        assert_eq!(a.faults.len(), 5);
        for (&(class, idx), kind) in &a.faults {
            assert!(idx < 40);
            assert!(class.kinds().contains(kind), "{}: {}", class.name(), kind.name());
        }
        let c = FaultPlan::seeded(8, 5, 40);
        assert_ne!(a, c, "different seed, different plan");
        // render/parse closes the loop
        let back = FaultPlan::parse(&a.spec(), 0).unwrap();
        assert_eq!(a, back, "spec() must round-trip through parse()");
    }

    #[test]
    fn parse_accepts_lists_and_auto_and_rejects_nonsense() {
        let p = FaultPlan::parse("write:enospc@3, rename:crash-before@0", 0).unwrap();
        assert_eq!(p.faults.len(), 2);
        assert_eq!(p.faults[&(OpClass::Write, 3)], FaultKind::Enospc);
        assert_eq!(p.faults[&(OpClass::Rename, 0)], FaultKind::CrashBeforeRename);
        let auto = FaultPlan::parse("auto:4:32", 9).unwrap();
        assert_eq!(auto, FaultPlan::seeded(9, 4, 32));
        for bad in [
            "write:enospc",        // no index
            "warp:eio@1",          // unknown class
            "write:frobnicate@1",  // unknown kind
            "read:crash-before@1", // kind incompatible with class
            "auto:x",              // bad count
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "accepted '{bad}'");
        }
        assert!(FaultPlan::parse("", 0).unwrap().is_empty());
    }

    #[test]
    fn transience_classification_matches_the_doctrine() {
        assert!(FaultKind::Eintr.is_transient());
        assert!(FaultKind::Eagain.is_transient());
        for k in [
            FaultKind::Enospc,
            FaultKind::Eio,
            FaultKind::Eacces,
            FaultKind::ShortWrite,
            FaultKind::CrashBeforeRename,
            FaultKind::CrashAfterRename,
        ] {
            assert!(!k.is_transient(), "{} must be permanent", k.name());
        }
        // the io kinds the retry loop keys on
        assert!(is_transient_err(&injected(FaultKind::Eintr, OpClass::Read, Path::new("x"))));
        assert!(!is_transient_err(&injected(FaultKind::Eio, OpClass::Read, Path::new("x"))));
    }

    #[test]
    fn injected_errors_are_loudly_named() {
        let e = injected(FaultKind::Enospc, OpClass::Write, Path::new("/tmp/x.snap"));
        let msg = e.to_string();
        assert!(msg.contains(INJECTED_MARK), "{msg}");
        assert!(msg.contains("enospc"), "{msg}");
        assert!(msg.contains("write"), "{msg}");
        assert!(msg.contains("/tmp/x.snap"), "{msg}");
        assert_eq!(
            injected(FaultKind::Eacces, OpClass::Read, Path::new("y")).kind(),
            io::ErrorKind::PermissionDenied
        );
    }
}

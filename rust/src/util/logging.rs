//! Leveled stderr logger wired to the `log` facade crate.

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::OnceLock;
use std::time::Instant;

static START: OnceLock<Instant> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = start().elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        };
        eprintln!("[{t:9.3} {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger once; level from `LIFT_LOG` (error..trace), default info.
pub fn init() {
    let _ = start(); // pin the log epoch to process start
    let level = match std::env::var("LIFT_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}

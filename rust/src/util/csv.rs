//! CSV writer for experiment results (`results/*.csv`).
//!
//! Every exp runner appends rows through this so the paper tables can be
//! regenerated/diffed; quoting is applied only when needed.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

pub struct CsvWriter {
    path: PathBuf,
    file: fs::File,
    cols: usize,
}

impl CsvWriter {
    /// Create (truncate) `results/<name>.csv` with a header row.
    pub fn create(dir: &Path, name: &str, header: &[&str]) -> anyhow::Result<CsvWriter> {
        fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut file = fs::File::create(&path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter {
            path,
            file,
            cols: header.len(),
        })
    }

    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(
            fields.len() == self.cols,
            "csv row width {} != header {}",
            fields.len(),
            self.cols
        );
        let line: Vec<String> = fields.iter().map(|f| quote(f)).collect();
        writeln!(self.file, "{}", line.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, fields: &[&dyn std::fmt::Display]) -> anyhow::Result<()> {
        self.row(&fields.iter().map(|f| f.to_string()).collect::<Vec<_>>())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

fn quote(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let dir = std::env::temp_dir().join("lift_csv_test");
        let mut w = CsvWriter::create(&dir, "t", &["a", "b"]).unwrap();
        w.row(&["1".into(), "he,llo \"x\"".into()]).unwrap();
        assert!(w.row(&["only-one".into()]).is_err());
        let body = std::fs::read_to_string(w.path()).unwrap();
        assert_eq!(body, "a,b\n1,\"he,llo \"\"x\"\"\"\n");
    }
}

//! Offline-environment substrates.
//!
//! This box has no crates.io access beyond the vendored set (see
//! `.cargo/config.toml`), so the usual suspects — `serde_json`, `clap`,
//! `rand`, `criterion`, `proptest` — are hand-rolled here with exactly the
//! surface the rest of the system needs. Each submodule carries its own
//! unit tests.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod eigh;
pub mod fault;
pub mod gemm;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;

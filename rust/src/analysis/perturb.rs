//! Noise-perturbation harness (§4, Fig. 2; Appendix C, Figs. 8-9).
//!
//! Select parameters with a criterion, add N(0, scale^2) noise to exactly
//! those entries, and measure what breaks: held-out perplexity, fact
//! recall, task accuracy, and per-matrix spectral/Frobenius norm deltas.

use anyhow::Result;

use crate::lift::{select_indices, LiftCfg, Selector};
use crate::runtime::manifest::PresetInfo;
use crate::runtime::Linalg;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Copy `params` and perturb `n_total` entries (split across trainable
/// matrices proportionally to their size) chosen by `sel`.
#[allow(clippy::too_many_arguments)]
pub fn perturb(
    la: &Linalg,
    preset: &PresetInfo,
    params: &[Tensor],
    sel: Selector,
    cfg: &LiftCfg,
    n_total: usize,
    scale: f32,
    rng: &mut Rng,
) -> Result<Vec<Tensor>> {
    let matrices = crate::model::trainable_matrices(preset, false);
    let total_elems: usize = matrices.iter().map(|&i| params[i].len()).sum();
    let mut out = params.to_vec();
    for &pi in &matrices {
        let w = &params[pi];
        let k = ((n_total as f64) * (w.len() as f64) / (total_elems as f64)).round() as usize;
        if k == 0 {
            continue;
        }
        let k = k.min(w.len());
        let idx = select_indices(sel, la, w, None, None, k, cfg, rng)?;
        for &i in &idx {
            out[pi].data[i as usize] += rng.normal() * scale;
        }
    }
    Ok(out)
}

/// Spectral + Frobenius norm change per perturbed matrix (Figs. 8-9).
pub struct NormDelta {
    pub name: String,
    pub spectral_before: f32,
    pub spectral_after: f32,
    pub frob_before: f64,
    pub frob_after: f64,
}

pub fn norm_deltas(
    preset: &PresetInfo,
    before: &[Tensor],
    after: &[Tensor],
    rng: &mut Rng,
) -> Vec<NormDelta> {
    crate::model::trainable_matrices(preset, false)
        .into_iter()
        .map(|pi| NormDelta {
            name: preset.params[pi].name.clone(),
            spectral_before: before[pi].spectral_norm(30, rng),
            spectral_after: after[pi].spectral_norm(30, rng),
            frob_before: before[pi].frobenius(),
            frob_after: after[pi].frobenius(),
        })
        .collect()
}

/// Random-matrix variant of the spectral-norm study (Fig. 8): returns
/// (spectral delta, frobenius delta) after noising `k` selected entries.
pub fn random_matrix_norms(
    la: &Linalg,
    dim: usize,
    sel: Selector,
    cfg: &LiftCfg,
    frac: f64,
    scale: f32,
    rng: &mut Rng,
) -> Result<(f64, f64)> {
    let w = Tensor::randn(&[dim, dim], 1.0 / (dim as f32).sqrt(), rng);
    let k = ((dim * dim) as f64 * frac).round().max(1.0) as usize;
    let idx = select_indices(sel, la, &w, None, None, k, cfg, rng)?;
    let mut w2 = w.clone();
    for &i in &idx {
        w2.data[i as usize] += rng.normal() * scale;
    }
    let s_before = w.spectral_norm(40, rng) as f64;
    let s_after = w2.spectral_norm(40, rng) as f64;
    Ok((s_after - s_before, w2.frobenius() - w.frobenius()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linalg() -> Linalg {
        Linalg::new(&xla::PjRtClient::cpu().unwrap())
    }

    #[test]
    fn lift_noise_moves_spectral_norm_more_than_random() {
        // Appendix C.1: noise on principal weights inflates sigma_max far
        // more than noise on random entries
        let la = linalg();
        let mut rng = Rng::new(5);
        let cfg = LiftCfg {
            rank: 4,
            ..Default::default()
        };
        let mut d_lift = 0.0;
        let mut d_rand = 0.0;
        for _ in 0..3 {
            d_lift += random_matrix_norms(&la, 96, Selector::Lift, &cfg, 0.05, 0.1, &mut rng)
                .unwrap()
                .0;
            d_rand += random_matrix_norms(&la, 96, Selector::Random, &cfg, 0.05, 0.1, &mut rng)
                .unwrap()
                .0;
        }
        assert!(
            d_lift > d_rand,
            "lift delta {d_lift} should exceed random {d_rand}"
        );
    }
}

//! Eigenspace alignment score (paper Appendix H.1, Fig. 12).
//!
//! For the top-n right singular vectors V (before) and V' (after
//! fine-tuning): d_i = sum_j (v'_i . v_j)^2 = ||V^T v'_i||^2, and the
//! score is mean_i d_i in [0, 1]. 1 = the fine-tuned top eigenspace lies
//! inside the pretrained one; 0 = orthogonal.

use crate::tensor::Tensor;
use crate::util::eigh;

/// Top-`k` right singular vectors as rows (k x n).
pub fn top_right_vectors(w: &Tensor, k: usize) -> Vec<f32> {
    let (m, n) = w.dims2();
    let (_, _, vt) = eigh::svd(&w.data, m, n);
    let k = k.min(m.min(n));
    vt[..k * n].to_vec()
}

/// Alignment between two top-k right-singular subspaces.
pub fn alignment_score(w_before: &Tensor, w_after: &Tensor, k: usize) -> f64 {
    let (_, n) = w_before.dims2();
    let vb = top_right_vectors(w_before, k);
    let va = top_right_vectors(w_after, k);
    let k = va.len() / n;
    let kb = vb.len() / n;
    // d_i = sum_j ( va_i . vb_j )^2
    let mut total = 0.0f64;
    for i in 0..k {
        let vi = &va[i * n..(i + 1) * n];
        let mut di = 0.0f64;
        for j in 0..kb {
            let vj = &vb[j * n..(j + 1) * n];
            let dot: f64 = vi.iter().zip(vj).map(|(a, b)| *a as f64 * *b as f64).sum();
            di += dot * dot;
        }
        total += di;
    }
    total / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_matrices_align_to_one() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[24, 16], 1.0, &mut rng);
        let s = alignment_score(&w, &w, 8);
        assert!((s - 1.0).abs() < 1e-3, "s={s}");
    }

    #[test]
    fn unrelated_matrices_align_partially() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[40, 30], 1.0, &mut rng);
        let b = Tensor::randn(&[40, 30], 1.0, &mut rng);
        // top-8 of 30 dims: random subspaces overlap ~ k/n
        let s = alignment_score(&a, &b, 8);
        assert!(s < 0.7, "s={s}");
        assert!(s > 0.05, "s={s}");
    }

    #[test]
    fn small_perturbation_keeps_alignment_high() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[24, 16], 1.0, &mut rng);
        let mut b = a.clone();
        b.add_scaled(&Tensor::randn(&[24, 16], 1.0, &mut rng), 1e-3);
        let s = alignment_score(&a, &b, 6);
        assert!(s > 0.99, "s={s}");
    }

    #[test]
    fn score_bounded() {
        let mut rng = Rng::new(4);
        for _ in 0..5 {
            let a = Tensor::randn(&[12, 10], 1.0, &mut rng);
            let b = Tensor::randn(&[12, 10], 1.0, &mut rng);
            let s = alignment_score(&a, &b, 5);
            assert!((0.0..=1.0 + 1e-6).contains(&s));
        }
    }
}

//! Weight-update analyses: ΔW magnitude histograms (Fig. 5) and ΔW rank
//! (Fig. 13, singular values above 10x the torch default threshold).

use crate::tensor::Tensor;
use crate::util::eigh;
use crate::util::stats;

/// Histogram of ΔW entries over [-lim, lim] (Fig. 5 panels).
pub fn update_histogram(before: &Tensor, after: &Tensor, lim: f32, bins: usize) -> Vec<usize> {
    let delta: Vec<f32> = after
        .data
        .iter()
        .zip(&before.data)
        .map(|(a, b)| a - b)
        .collect();
    stats::histogram(&delta, -lim, lim, bins)
}

/// Max |ΔW| entry and fraction of exactly-unchanged entries.
pub fn update_stats(before: &Tensor, after: &Tensor) -> (f32, f64) {
    let mut maxabs = 0.0f32;
    let mut unchanged = 0usize;
    for (a, b) in after.data.iter().zip(&before.data) {
        let d = (a - b).abs();
        if d == 0.0 {
            unchanged += 1;
        }
        maxabs = maxabs.max(d);
    }
    (maxabs, unchanged as f64 / before.len() as f64)
}

/// Rank of ΔW: #singular values > tau, tau = mult * max(m,n) * smax * eps
/// (paper Appendix G.3 uses mult = 10).
pub fn update_rank(before: &Tensor, after: &Tensor, mult: f32) -> usize {
    let delta = after.sub(before);
    let (m, n) = delta.dims2();
    eigh::rank_above(&delta.data, m, n, mult)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn histogram_centers_on_zero_for_no_update() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[10, 10], 1.0, &mut rng);
        let h = update_histogram(&w, &w, 0.1, 5);
        assert_eq!(h[2], 100); // all mass in the middle bin
    }

    #[test]
    fn sparse_update_leaves_spike() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[20, 20], 1.0, &mut rng);
        let mut w2 = w.clone();
        for i in 0..20 {
            w2.data[i * 7 % 400] += 0.5;
        }
        let (maxabs, unchanged) = update_stats(&w, &w2);
        assert!(maxabs >= 0.5);
        assert!(unchanged > 0.9);
    }

    #[test]
    fn lora_style_update_has_low_rank() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[32, 24], 1.0, &mut rng);
        let a = Tensor::randn(&[32, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[4, 24], 1.0, &mut rng);
        let mut w2 = w.clone();
        w2.add_scaled(&a.matmul(&b), 0.1);
        assert_eq!(update_rank(&w, &w2, 10.0), 4);
    }

    #[test]
    fn dense_update_has_full_rank() {
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&[16, 12], 1.0, &mut rng);
        let mut w2 = w.clone();
        w2.add_scaled(&Tensor::randn(&[16, 12], 1.0, &mut rng), 0.1);
        assert_eq!(update_rank(&w, &w2, 10.0), 12);
    }
}

//! Analysis toolkit behind the paper's §4 and §7 studies.

pub mod align;
pub mod memory;
pub mod perturb;
pub mod update;

pub use align::alignment_score;
pub use memory::{ArchSpec, MemoryBreakdown};
pub use update::{update_histogram, update_rank};

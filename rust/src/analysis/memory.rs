//! Analytic fine-tuning memory model (Fig. 6).
//!
//! Fig. 6 in the paper is arithmetic over tensor shapes x dtypes measured
//! on real GPUs; we compute the same breakdown exactly for the *real*
//! LLaMA-2-7B / LLaMA-3-8B architectures (shapes public), so this panel
//! reproduces at full scale despite the simulator substrate.
//!
//! Conventions (matching the paper's training setup): bf16 weights and
//! gradients (2 B), fp32 Adam moments (8 B/param), activations estimated
//! for batch x seq tokens with standard checkpointing (per-layer boundary
//! activations + one layer's working set).

/// A transformer architecture's shape inventory.
#[derive(Clone, Debug)]
pub struct ArchSpec {
    pub name: &'static str,
    pub vocab: usize,
    pub d: usize,
    pub layers: usize,
    pub ffn: usize,
    /// kv projection width (GQA: < d)
    pub kv_dim: usize,
}

pub const LLAMA2_7B: ArchSpec = ArchSpec {
    name: "LLaMA-2-7B",
    vocab: 32000,
    d: 4096,
    layers: 32,
    ffn: 11008,
    kv_dim: 4096,
};

pub const LLAMA3_8B: ArchSpec = ArchSpec {
    name: "LLaMA-3-8B",
    vocab: 128256,
    d: 4096,
    layers: 32,
    ffn: 14336,
    kv_dim: 1024,
};

impl ArchSpec {
    /// (m, n) of every trainable projection matrix.
    pub fn matrices(&self) -> Vec<(usize, usize, &'static str)> {
        let mut v = Vec::new();
        for _ in 0..self.layers {
            v.push((self.d, self.d, "wq"));
            v.push((self.d, self.kv_dim, "wk"));
            v.push((self.d, self.kv_dim, "wv"));
            v.push((self.d, self.d, "wo"));
            v.push((self.d, self.ffn, "wgate"));
            v.push((self.d, self.ffn, "wup"));
            v.push((self.ffn, self.d, "wdown"));
        }
        v
    }

    pub fn matrix_params(&self) -> usize {
        self.matrices().iter().map(|(m, n, _)| m * n).sum()
    }

    pub fn mlp_params(&self) -> usize {
        self.matrices()
            .iter()
            .filter(|(_, _, k)| matches!(*k, "wgate" | "wup" | "wdown"))
            .map(|(m, n, _)| m * n)
            .sum()
    }

    pub fn total_params(&self) -> usize {
        // embedding (tied) + norms + matrices
        self.vocab * self.d + (2 * self.layers + 1) * self.d + self.matrix_params()
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryBreakdown {
    pub weights_gb: f64,
    pub grads_gb: f64,
    pub optimizer_gb: f64,
    pub activations_gb: f64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.weights_gb + self.grads_gb + self.optimizer_gb + self.activations_gb
    }
}

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

fn activations_gb(arch: &ArchSpec, batch: usize, seq: usize) -> f64 {
    // gradient checkpointing: boundary activations per layer + one layer's
    // working set (attn scores flash-style, so O(b s d) not O(b s^2))
    let tokens = batch * seq;
    let boundary = arch.layers * tokens * arch.d;
    let working = tokens * (4 * arch.d + 2 * arch.ffn);
    ((boundary + working) as f64) * 2.0 / GB
}

/// Full fine-tuning: dense everything.
pub fn full_ft(arch: &ArchSpec, batch: usize, seq: usize) -> MemoryBreakdown {
    let n = arch.total_params() as f64;
    MemoryBreakdown {
        weights_gb: n * 2.0 / GB,
        grads_gb: n * 2.0 / GB,
        optimizer_gb: n * 8.0 / GB,
        activations_gb: activations_gb(arch, batch, seq),
    }
}

/// LoRA at rank r on all projection matrices.
pub fn lora(arch: &ArchSpec, rank: usize, batch: usize, seq: usize) -> MemoryBreakdown {
    let n = arch.total_params() as f64;
    let adapter: usize = arch.matrices().iter().map(|(m, nn, _)| rank * (m + nn)).sum();
    MemoryBreakdown {
        weights_gb: (n + adapter as f64) * 2.0 / GB,
        grads_gb: adapter as f64 * 2.0 / GB,
        optimizer_gb: adapter as f64 * 8.0 / GB,
        activations_gb: activations_gb(arch, batch, seq),
    }
}

/// LIFT at LoRA-rank-equivalent budget (Algorithm 1): Adam moments are
/// packed fp32 vectors of length k plus a bitmask per matrix; gradients
/// are gathered layer-by-layer during the backward pass (Eq. 3), so the
/// dense gradient buffer is transient — only one matrix's dense grad plus
/// the packed masked gradient are live at a time.
pub fn lift(arch: &ArchSpec, rank: usize, batch: usize, seq: usize, mlp_only: bool) -> MemoryBreakdown {
    let n = arch.total_params() as f64;
    let mats = arch.matrices();
    let scoped = mats
        .iter()
        .filter(|(_, _, kind)| !mlp_only || matches!(*kind, "wgate" | "wup" | "wdown"));
    let mut k = 0usize;
    let mut mask_bits = 0usize;
    let mut largest = 0usize;
    for (m, nn, _) in scoped {
        k += rank * (m + nn);
        mask_bits += m * nn;
        largest = largest.max(m * nn);
    }
    MemoryBreakdown {
        weights_gb: n * 2.0 / GB,
        grads_gb: (k as f64 * 2.0 + largest as f64 * 2.0) / GB,
        optimizer_gb: (k as f64 * 8.0 + mask_bits as f64 / 8.0) / GB,
        activations_gb: activations_gb(arch, batch, seq),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_public_numbers() {
        let n7 = LLAMA2_7B.total_params() as f64 / 1e9;
        assert!((6.0..7.5).contains(&n7), "llama-2-7b params {n7}B");
        let n8 = LLAMA3_8B.total_params() as f64 / 1e9;
        assert!((7.0..8.5).contains(&n8), "llama-3-8b params {n8}B");
    }

    #[test]
    fn full_ft_optimizer_dominates() {
        let m = full_ft(&LLAMA2_7B, 8, 1024);
        assert!(m.optimizer_gb > m.weights_gb);
        // ~27 GB half-precision-trainables * 8B... paper reports 27GB for
        // the 7B optimizer; ours counts all params: should land 20..60
        assert!((20.0..60.0).contains(&m.optimizer_gb), "{}", m.optimizer_gb);
    }

    #[test]
    fn lift_optimizer_under_5_percent_of_full() {
        let f = full_ft(&LLAMA2_7B, 8, 1024);
        let l = lift(&LLAMA2_7B, 128, 8, 1024, false);
        // paper: ~5% (27 GB -> 1.3 GB); our accounting adds the bitmask
        let ratio = l.optimizer_gb / f.optimizer_gb;
        assert!(ratio < 0.08, "optimizer ratio {ratio}");
        assert!(l.total() < f.total() * 0.5);
    }

    #[test]
    fn lift_close_to_lora_and_mlp_variant_smaller() {
        let lo = lora(&LLAMA2_7B, 128, 8, 1024);
        let li = lift(&LLAMA2_7B, 128, 8, 1024, false);
        let li_mlp = lift(&LLAMA2_7B, 128, 8, 1024, true);
        assert!(li.total() < lo.total() * 1.4, "{} vs {}", li.total(), lo.total());
        assert!(li_mlp.total() < li.total());
    }
}

//! Little-endian binary codec for snapshot payloads.
//!
//! Every multi-byte integer and float is little-endian; vectors are
//! length-prefixed with a `u64` count. [`Dec`] is hardened against
//! corrupted input: every read is bounds-checked, vector lengths are
//! capped by the remaining payload before any allocation, and
//! [`Dec::finish`] rejects trailing bytes — so a flipped length byte
//! yields a clean error, never an OOM or a silent short read. (Whole-file
//! integrity is the container's job: `ckpt::Snapshot` CRC32-checks each
//! section before a `Dec` ever sees it.)

use anyhow::Result;

use crate::optim::{AdamCfg, DenseAdam, SparseAdam};
use crate::tensor::Tensor;

/// Append-only encoder; [`Enc::into_bytes`] yields the payload.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    pub fn bool(&mut self, x: bool) {
        self.buf.push(x as u8);
    }

    pub fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }

    pub fn f32(&mut self, x: f32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn f64(&mut self, x: f64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn opt_usize(&mut self, x: Option<usize>) {
        match x {
            Some(v) => {
                self.bool(true);
                self.usize(v);
            }
            None => self.bool(false),
        }
    }

    pub fn f32s(&mut self, xs: &[f32]) {
        self.usize(xs.len());
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn f64s(&mut self, xs: &[f64]) {
        self.usize(xs.len());
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn u32s(&mut self, xs: &[u32]) {
        self.usize(xs.len());
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn usizes(&mut self, xs: &[usize]) {
        self.usize(xs.len());
        for &x in xs {
            self.u64(x as u64);
        }
    }

    pub fn tensor(&mut self, t: &Tensor) {
        self.usize(t.shape.len());
        for &d in &t.shape {
            self.u64(d as u64);
        }
        self.f32s(&t.data);
    }

    pub fn adam_cfg(&mut self, c: &AdamCfg) {
        self.f32(c.beta1);
        self.f32(c.beta2);
        self.f32(c.eps);
        self.f32(c.weight_decay);
    }

    pub fn dense_adam(&mut self, o: &DenseAdam) {
        self.adam_cfg(&o.cfg);
        self.usize(o.t);
        self.f32s(&o.m);
        self.f32s(&o.v);
    }

    pub fn sparse_adam(&mut self, o: &SparseAdam) {
        self.adam_cfg(&o.cfg);
        self.usize(o.t);
        self.u32s(&o.idx);
        self.f32s(&o.m);
        self.f32s(&o.v);
    }
}

/// Bounds-checked decoder over a payload slice.
pub struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    pub fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, i: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "snapshot payload truncated: wanted {n} bytes at offset {}, {} left",
                    self.i,
                    self.b.len() - self.i
                )
            })?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    /// Vector length prefix, capped by the remaining payload (given
    /// `elem` bytes per element) before any allocation happens.
    fn len(&mut self, elem: usize) -> Result<usize> {
        let n = self.usize()?;
        anyhow::ensure!(
            n.checked_mul(elem).is_some_and(|bytes| bytes <= self.remaining()),
            "snapshot payload corrupted: implausible vector length {n}"
        );
        Ok(n)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// `usize` travels as u64 on the wire; on a 32-bit target a corrupt
    /// (or genuinely huge) value above `usize::MAX` must error, not
    /// truncate — `as usize` would silently fold e.g. `0x1_0000_0001`
    /// down to 1 and misparse everything after it.
    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| {
            anyhow::anyhow!(
                "snapshot length {v} (0x{v:x}) does not fit this target's \
                 {}-bit usize — corrupt payload or a container from a larger host",
                usize::BITS
            )
        })
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.len(1)?;
        let s = std::str::from_utf8(self.take(n)?)
            .map_err(|_| anyhow::anyhow!("snapshot string is not UTF-8"))?;
        Ok(s.to_string())
    }

    pub fn opt_usize(&mut self) -> Result<Option<usize>> {
        if self.bool()? {
            Ok(Some(self.usize()?))
        } else {
            Ok(None)
        }
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.len(8)?;
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.len(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.len(8)?;
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect())
    }

    pub fn tensor(&mut self) -> Result<Tensor> {
        let ndim = self.len(8)?;
        anyhow::ensure!(ndim <= 8, "snapshot tensor has implausible ndim {ndim}");
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.usize()?);
        }
        let data = self.f32s()?;
        let numel = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .ok_or_else(|| anyhow::anyhow!("snapshot tensor shape overflows"))?;
        anyhow::ensure!(
            numel == data.len(),
            "snapshot tensor shape {shape:?} does not match its {} data values",
            data.len()
        );
        Ok(Tensor::from_vec(&shape, data))
    }

    pub fn adam_cfg(&mut self) -> Result<AdamCfg> {
        Ok(AdamCfg {
            beta1: self.f32()?,
            beta2: self.f32()?,
            eps: self.f32()?,
            weight_decay: self.f32()?,
        })
    }

    pub fn dense_adam(&mut self) -> Result<DenseAdam> {
        let cfg = self.adam_cfg()?;
        let t = self.usize()?;
        let m = self.f32s()?;
        let v = self.f32s()?;
        anyhow::ensure!(m.len() == v.len(), "dense-adam moment lengths differ");
        Ok(DenseAdam { cfg, m, v, t })
    }

    pub fn sparse_adam(&mut self) -> Result<SparseAdam> {
        let cfg = self.adam_cfg()?;
        let t = self.usize()?;
        let idx = self.u32s()?;
        let m = self.f32s()?;
        let v = self.f32s()?;
        anyhow::ensure!(
            idx.len() == m.len() && m.len() == v.len(),
            "sparse-adam index/moment lengths differ ({}/{}/{})",
            idx.len(),
            m.len(),
            v.len()
        );
        Ok(SparseAdam { cfg, idx, m, v, t })
    }

    /// Assert the whole payload was consumed — catches encoder/decoder
    /// drift and truncated-then-padded corruption.
    pub fn finish(self) -> Result<()> {
        anyhow::ensure!(
            self.i == self.b.len(),
            "snapshot payload has {} trailing bytes",
            self.b.len() - self.i
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn scalars_roundtrip() {
        let mut e = Enc::new();
        e.u8(7);
        e.bool(true);
        e.bool(false);
        e.u32(0xDEAD_BEEF);
        e.u64(u64::MAX);
        e.usize(42);
        e.f32(-0.0);
        e.f64(std::f64::consts::PI);
        e.str("héllo");
        e.opt_usize(Some(9));
        e.opt_usize(None);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.u8().unwrap(), 7);
        assert!(d.bool().unwrap());
        assert!(!d.bool().unwrap());
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX);
        assert_eq!(d.usize().unwrap(), 42);
        assert_eq!(d.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(d.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(d.str().unwrap(), "héllo");
        assert_eq!(d.opt_usize().unwrap(), Some(9));
        assert_eq!(d.opt_usize().unwrap(), None);
        d.finish().unwrap();
    }

    #[test]
    fn vectors_and_degenerate_tensors_roundtrip() {
        let mut rng = Rng::new(3);
        let tensors = [
            Tensor::randn(&[1, 1], 1.0, &mut rng),
            Tensor::randn(&[1, 5], 1.0, &mut rng),
            Tensor::randn(&[5, 1], 1.0, &mut rng),
            Tensor::zeros(&[3]),
            Tensor::randn(&[2, 3], 1.0, &mut rng),
        ];
        let mut e = Enc::new();
        e.f32s(&[]);
        e.u32s(&[]);
        e.usizes(&[0, usize::MAX]);
        for t in &tensors {
            e.tensor(t);
        }
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert!(d.f32s().unwrap().is_empty());
        assert!(d.u32s().unwrap().is_empty());
        assert_eq!(d.usizes().unwrap(), vec![0, usize::MAX]);
        for t in &tensors {
            assert_eq!(&d.tensor().unwrap(), t);
        }
        d.finish().unwrap();
    }

    #[test]
    fn optimizer_states_roundtrip_incl_empty_mask() {
        let mut sp = SparseAdam::new(vec![3, 1, 7], AdamCfg::default());
        let mut w = vec![0.5f32; 10];
        sp.step(&mut w, &[1.0; 10], 0.1);
        let empty = SparseAdam::new(vec![], AdamCfg::default());
        let mut dn = DenseAdam::new(4, AdamCfg { weight_decay: 0.1, ..Default::default() });
        dn.step(&mut vec![1.0; 4], &[0.3; 4], 0.01);
        let mut e = Enc::new();
        e.sparse_adam(&sp);
        e.sparse_adam(&empty);
        e.dense_adam(&dn);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        let sp2 = d.sparse_adam().unwrap();
        assert_eq!(sp2.idx, sp.idx);
        assert_eq!(sp2.m, sp.m);
        assert_eq!(sp2.v, sp.v);
        assert_eq!(sp2.t, sp.t);
        let e2 = d.sparse_adam().unwrap();
        assert!(e2.idx.is_empty() && e2.m.is_empty());
        let dn2 = d.dense_adam().unwrap();
        assert_eq!(dn2.m, dn.m);
        assert_eq!(dn2.v, dn.v);
        assert_eq!(dn2.t, dn.t);
        assert_eq!(dn2.cfg.weight_decay, 0.1);
        d.finish().unwrap();
    }

    #[test]
    fn usize_above_u32_max_never_silently_truncates() {
        // the regression: `self.u64()? as usize` on a 32-bit target
        // folded 0x1_0000_0001 down to 1 — a corrupt >4 GiB length
        // parsed as a tiny one and everything after it misparsed.
        let v: u64 = u32::MAX as u64 + 1; // just above u32::MAX
        let mut e = Enc::new();
        e.u64(v);
        let bytes = e.into_bytes();
        let got = Dec::new(&bytes).usize();
        #[cfg(target_pointer_width = "64")]
        {
            // on a 64-bit host the value FITS and must decode exactly —
            // any truncation would surface here as a small number
            assert_eq!(got.unwrap(), 0x1_0000_0000usize);
        }
        #[cfg(target_pointer_width = "32")]
        {
            let err = got.unwrap_err().to_string();
            assert!(err.contains("4294967296"), "error must name the length: {err}");
            assert!(err.contains("32-bit"), "{err}");
        }
    }

    #[test]
    fn corrupted_lengths_error_instead_of_allocating() {
        // a length prefix far beyond the payload must be rejected before
        // any allocation
        let mut e = Enc::new();
        e.u64(u64::MAX / 2);
        let bytes = e.into_bytes();
        assert!(Dec::new(&bytes).f32s().is_err());
        assert!(Dec::new(&bytes).tensor().is_err());
        // truncation mid-value
        assert!(Dec::new(&[1, 2]).u32().is_err());
        // trailing garbage flagged by finish()
        let mut e = Enc::new();
        e.u8(1);
        e.u8(2);
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        d.u8().unwrap();
        assert!(d.finish().is_err());
    }
}

//! Versioned checkpoint/restore: bit-exact snapshots of a training run.
//!
//! LIFT's trainable state is tiny (per matrix: `k` mask indices + `2k`
//! packed Adam moments — the Fig. 6 memory argument), which makes
//! frequent, cheap, bit-exact checkpoints feasible where Full FT's would
//! be prohibitive. This module is the persistence layer behind
//! `train::train_with`'s checkpoint cadence, the `lift train --resume`
//! CLI, and the resumable scenario-matrix runner (`exp::matrix`).
//!
//! # On-disk layout (all integers little-endian)
//!
//! ```text
//! offset 0   magic           8 bytes   b"LIFTSNAP"
//!        8   format version  u32       FORMAT_VERSION
//!       12   section count   u32
//! then, per section:
//!            name length     u32
//!            name            UTF-8 bytes
//!            payload length  u64
//!            payload CRC32   u32       ISO-HDLC polynomial (zlib's)
//!            payload         bytes
//! ```
//!
//! Sections are opaque length-delimited payloads encoded with
//! [`codec::Enc`]; the reader validates every section's CRC32 before any
//! payload is parsed, so truncation, bit-flips, and half-written files
//! are rejected with a specific error instead of misparsing. Writes go
//! through a same-directory temp file + rename, so a crash mid-save
//! leaves the previous complete snapshot in place, never a torn one.
//!
//! # Versioning policy
//!
//! `FORMAT_VERSION` is bumped on ANY layout change — container or
//! section payloads. A reader only accepts its own version and fails
//! loudly otherwise ("refusing to guess at the layout"): snapshots are
//! cheap to regenerate from the run that wrote them, so there is no
//! migration machinery, only honest rejection. New optional data must
//! therefore go in a new section *and* bump the version.
//!
//! # What a trainer snapshot contains
//!
//! * `meta`    — method name, completed-step counter, both RNG stream
//!   positions (the trainer's data RNG and `Ctx::rng`), accumulated
//!   wall seconds, and the schedule-relevant `TrainCfg` (lr / warmup
//!   fraction / total steps). The loss curve and per-step latencies are
//!   NOT here: they stream to the append-only `curve.sidecar` next to
//!   the snapshots ([`curve`]), which is what keeps snapshot bytes
//!   O(model) — flat in step count — instead of O(model + steps);
//! * `params`  — every model tensor, bit-exact f32;
//! * `method`  — the active [`Method`]'s full internal state via
//!   `Method::save_state` (SparseAdam idx/m/v/t, DenseAdamSet moments,
//!   LoRA/Spectral factors and frozen bases, SpIEL grow/drop snapshots,
//!   S2FT column packs, warm-start subspace carriers, lazy-init and
//!   last-maintained-step guards).
//!
//! # Off-loop writes and retention
//!
//! The trainer serializes snapshots on the hot loop (it needs the live
//! state) but hands the bytes to a double-buffered background
//! [`writer::AsyncSnapshotWriter`]; disk latency overlaps the next
//! training steps, and [`prune_snapshots`] enforces a keep-last-N
//! policy (`TrainCfg::ckpt_keep`) after every write so long campaigns
//! don't accrete one snapshot per cadence tick.
//!
//! # Determinism
//!
//! Restoring a snapshot and continuing reproduces the uninterrupted run
//! bit-for-bit (weights AND optimizer moments, any worker count) — the
//! crash-resume suite in `rust/tests/ckpt.rs` asserts this for every
//! method. Per-matrix selection RNG streams need no persisting: they are
//! pure functions of `(refresh seed, param index)` (see
//! `lift::engine::stream_rng`), and the refresh seeds are drawn from
//! `Ctx::rng`, whose position IS captured — so mask refresh scheduling
//! and sampling replay exactly. Mismatched resume configs are rejected
//! on two levels: `Method::load_state` refuses a different `make_method`
//! spec, and `train_with` refuses a different schedule-relevant
//! `TrainCfg` (lr / warmup / total steps). The *gradient source* is the
//! one thing outside the snapshot: the data RNG position replays the
//! stream, but the caller must reconstruct the same source (task suite,
//! sample counts) — the scenario matrix guarantees this by keying every
//! cell's snapshots on the full `CellSpec`.
//!
//! Scaling note (closed by the hot-loop overhaul): the curve streams
//! to `curve.sidecar` at 12 bytes/step, snapshots stay flat in step
//! count (asserted by `rust/tests/ckpt.rs`), and keep-last-N retention
//! bounds the directory over million-step campaigns.
//!
//! # Durability contract
//!
//! What this layer promises, by failure mode:
//!
//! * **`kill -9` / process crash**: writes are temp-file + rename in
//!   the same directory, so at every instant `path` holds either the
//!   previous complete snapshot or the new complete snapshot — never a
//!   torn one. A leftover `*.tmp` is inert debris: readers never open
//!   it, the next write of the same path reuses (and commits or
//!   replaces) it.
//! * **Power loss**: [`write_atomic`] additionally fsyncs the temp file
//!   *before* the rename (so the bytes the rename publishes are on
//!   stable storage, not just in page cache) and fsyncs the parent
//!   directory *after* it (so the rename itself survives). Set
//!   `LIFT_NO_FSYNC=1` to trade this (and only this) away for speed in
//!   tests and tmpfs smoke runs.
//! * **Transient IO errors** (EINTR/EAGAIN-class): retried in place
//!   with bounded backoff by the `util::fault` seam every filesystem
//!   call here routes through.
//! * **Permanent IO errors** (ENOSPC, EIO, EACCES, short writes): fail
//!   loudly with the path and operation named — never folded into
//!   "missing". Bad *bytes* (CRC mismatch, truncation) are a separate,
//!   equally loud refusal at parse time; an unreadable file proves
//!   nothing about its content ("Unreadable ≠ Corrupt").
//!
//! Every one of these paths is replayed under seeded fault schedules by
//! `lift torture` / `rust/tests/torture.rs`, which assert that recovery
//! reproduces an uninterrupted run bit-identically.

pub mod codec;
pub mod curve;
pub mod writer;

pub use writer::AsyncSnapshotWriter;

use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{Context, Result};

use crate::methods::Method;
use crate::tensor::Tensor;
use crate::train::{TrainCfg, TrainLog};
use crate::util::fault;
use crate::util::rng::Rng;
use codec::{Dec, Enc};

pub const MAGIC: &[u8; 8] = b"LIFTSNAP";
/// v2: the loss/latency curve moved out of `meta` into the append-only
/// sidecar ([`curve`]), and sparse methods persist warm-start subspace
/// carriers. Per the versioning policy, v1 snapshots are rejected
/// loudly, not migrated.
pub const FORMAT_VERSION: u32 = 2;

/// Section names of a trainer snapshot.
pub const SEC_META: &str = "meta";
pub const SEC_PARAMS: &str = "params";
pub const SEC_METHOD: &str = "method";

/// CRC-32 (ISO-HDLC, polynomial 0xEDB88320 reflected — the zlib/PNG
/// checksum), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// A snapshot: ordered named sections, each CRC32-validated on read.
#[derive(Default)]
pub struct Snapshot {
    pub sections: Vec<(String, Vec<u8>)>,
}

impl Snapshot {
    pub fn new() -> Snapshot {
        Snapshot::default()
    }

    /// Append a section. Section names are the container's only lookup
    /// key ([`Snapshot::get`] finds the FIRST match), so a duplicate
    /// would silently shadow its later payload — that is a writer bug,
    /// and it panics here rather than round-tripping into a file every
    /// reader then misreads.
    pub fn add(&mut self, name: &str, payload: Vec<u8>) {
        assert!(
            !self.sections.iter().any(|(n, _)| n == name),
            "snapshot section '{name}' added twice — later payload would be shadowed"
        );
        self.sections.push((name.to_string(), payload));
    }

    pub fn get(&self, name: &str) -> Result<&[u8]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p.as_slice())
            .ok_or_else(|| anyhow::anyhow!("snapshot has no '{name}' section"))
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (name, payload) in &self.sections {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(&crc32(payload).to_le_bytes());
            out.extend_from_slice(payload);
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Snapshot> {
        // the container parses through the same hardened reader as the
        // payloads — one bounds-checking code path to maintain
        let mut d = Dec::new(b);
        anyhow::ensure!(
            d.take(8).map(|m| m == MAGIC).unwrap_or(false),
            "bad snapshot magic — not a LIFT snapshot file (or truncated before the header)"
        );
        let version = d.u32()?;
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "unsupported snapshot format version {version} (this build reads version \
             {FORMAT_VERSION}); refusing to guess at the layout"
        );
        let n_sections = d.u32()? as usize;
        anyhow::ensure!(n_sections <= 1024, "implausible section count {n_sections}");
        let mut sections = Vec::with_capacity(n_sections);
        for _ in 0..n_sections {
            let name_len = d.u32()? as usize;
            anyhow::ensure!(name_len <= 256, "implausible section-name length {name_len}");
            let name = std::str::from_utf8(d.take(name_len)?)
                .map_err(|_| anyhow::anyhow!("section name is not UTF-8"))?
                .to_string();
            let payload_len = d.u64()? as usize;
            let stored = d.u32()?;
            let payload = d
                .take(payload_len)
                .with_context(|| format!("section '{name}'"))?
                .to_vec();
            let got = crc32(&payload);
            anyhow::ensure!(
                got == stored,
                "snapshot section '{name}' failed its CRC32 check (stored {stored:08x}, \
                 computed {got:08x}) — the file is corrupted"
            );
            // a duplicate name means a foreign/corrupt writer: `get`
            // would silently shadow the later payload, so refuse the
            // whole container instead of misreading half of it
            anyhow::ensure!(
                !sections.iter().any(|(n, _): &(String, Vec<u8>)| n == &name),
                "snapshot contains duplicate section '{name}' — refusing a container \
                 whose later payload would be silently shadowed"
            );
            sections.push((name, payload));
        }
        anyhow::ensure!(
            d.remaining() == 0,
            "snapshot has {} trailing bytes",
            d.remaining()
        );
        Ok(Snapshot { sections })
    }

    /// Atomic write: temp file in the same directory, then rename — a
    /// crash mid-save never leaves a torn snapshot at `path`.
    pub fn write_to(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.to_bytes())
    }

    pub fn read_from(path: &Path) -> Result<Snapshot> {
        let bytes =
            fault::read(path).with_context(|| format!("reading snapshot {path:?}"))?;
        Snapshot::from_bytes(&bytes).with_context(|| format!("parsing snapshot {path:?}"))
    }
}

/// Atomic byte write shared by the synchronous path and the background
/// writer: temp file in the same directory, then rename — a crash
/// mid-save never leaves a torn file at `path`.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    write_atomic_as(path, &path.with_extension("tmp"), bytes)
}

/// [`write_atomic`] with an explicit temp path, for writers that must
/// not share a temp name — the matrix ledger tags temps with the
/// runner's identity so concurrent runners finishing the same cell
/// never interleave bytes into one temp file. `tmp` must live on the
/// same filesystem as `path` (same directory in practice) for the
/// rename to stay atomic.
///
/// Durability: the temp file is fsynced before the rename and the
/// parent directory after it (see the module doc's durability
/// contract; `LIFT_NO_FSYNC=1` disables both syncs). All IO goes
/// through the `util::fault` seam, so transient errors are retried in
/// place and the torture harness can inject faults at every stage.
pub fn write_atomic_as(path: &Path, tmp: &Path, bytes: &[u8]) -> Result<()> {
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    if let Some(dir) = dir {
        fault::create_dir_all(dir).with_context(|| format!("creating snapshot dir {dir:?}"))?;
    }
    fault::write(tmp, bytes).with_context(|| format!("writing snapshot temp {tmp:?}"))?;
    // the rename publishes whatever is on stable storage at crash time;
    // sync the payload first so that is the full file, not a torn one
    fault::sync_file_at(tmp).with_context(|| format!("fsyncing snapshot temp {tmp:?}"))?;
    fault::rename(tmp, path).with_context(|| format!("committing snapshot {path:?}"))?;
    if let Some(dir) = dir {
        // the rename lives in the directory's metadata; without this a
        // power cut can resurrect the pre-rename directory state
        fault::sync_dir(dir).with_context(|| format!("fsyncing snapshot dir {dir:?}"))?;
    }
    Ok(())
}

/// Keep-last-N retention: delete all but the newest `keep` `step_*.snap`
/// files under `dir` (by step number). `keep == 0` disables pruning.
/// Everything that is not a step snapshot — the curve sidecar, cell
/// outcome JSONs, stray files — is never touched.
pub fn prune_snapshots(dir: &Path, keep: usize) -> Result<()> {
    if keep == 0 || !dir.exists() {
        return Ok(());
    }
    let mut snaps: Vec<(usize, PathBuf)> = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        if let Some(step) = snapshot_step(&entry.path()) {
            snaps.push((step, entry.path()));
        }
    }
    snaps.sort_by_key(|(step, _)| std::cmp::Reverse(*step));
    for (_, path) in snaps.into_iter().skip(keep) {
        fault::remove_file(&path)
            .with_context(|| format!("pruning old snapshot {path:?}"))?;
    }
    Ok(())
}

/// Everything `train::train_with` needs to continue a run bit-exactly.
pub struct TrainerState {
    /// Completed steps (the resumed loop starts here).
    pub step: usize,
    pub method_name: String,
    /// `Ctx::rng` stream position (feeds mask-refresh seeds).
    pub ctx_rng: u64,
    /// Trainer data-RNG stream position (feeds batch sampling).
    pub data_rng: u64,
    /// Accumulated wall seconds of the completed prefix. The loss curve
    /// and per-step latencies are NOT in the snapshot (that would make
    /// snapshot bytes grow with step count): `train_with` reconstructs
    /// them from the `curve.sidecar` next to the snapshot, so a resumed
    /// run's `TrainLog` still covers the entire campaign.
    pub seconds: f64,
    /// The writing run's schedule-relevant `TrainCfg` (lr, warmup
    /// fraction, total steps). `train_with` refuses to resume under a
    /// different one — the LR schedule would silently diverge from the
    /// uninterrupted run.
    pub lr: f32,
    pub warmup_frac: f32,
    pub cfg_steps: usize,
    pub params: Vec<Tensor>,
    pub method_state: Vec<u8>,
}

/// Serialize one trainer snapshot to bytes (see the module doc for the
/// layout) without touching disk — the form the hot loop hands to the
/// background [`AsyncSnapshotWriter`]. `seconds` is the accumulated
/// wall time up to this snapshot; the curve itself lives in the sidecar.
pub fn trainer_snapshot_bytes(
    step: usize,
    method: &dyn Method,
    params: &[Tensor],
    ctx_rng: &Rng,
    data_rng: &Rng,
    seconds: f64,
    cfg: &TrainCfg,
) -> Result<Vec<u8>> {
    let mut meta = Enc::new();
    meta.str(&method.name());
    meta.usize(step);
    meta.u64(ctx_rng.state());
    meta.u64(data_rng.state());
    meta.f64(seconds);
    meta.f32(cfg.lr);
    meta.f32(cfg.warmup_frac);
    meta.usize(cfg.steps);
    let mut ps = Enc::new();
    ps.usize(params.len());
    for t in params {
        ps.tensor(t);
    }
    let mut snap = Snapshot::new();
    snap.add(SEC_META, meta.into_bytes());
    snap.add(SEC_PARAMS, ps.into_bytes());
    snap.add(SEC_METHOD, method.save_state()?);
    Ok(snap.to_bytes())
}

/// Synchronous snapshot write — serialization + atomic write in one
/// call. Only `log.seconds` is persisted from the log (the curve lives
/// in the sidecar); the trainer's hot loop uses
/// [`trainer_snapshot_bytes`] + [`AsyncSnapshotWriter`] instead.
#[allow(clippy::too_many_arguments)]
pub fn save_trainer(
    path: &Path,
    step: usize,
    method: &dyn Method,
    params: &[Tensor],
    ctx_rng: &Rng,
    data_rng: &Rng,
    log: &TrainLog,
    cfg: &TrainCfg,
) -> Result<()> {
    let bytes =
        trainer_snapshot_bytes(step, method, params, ctx_rng, data_rng, log.seconds, cfg)?;
    write_atomic(path, &bytes)
}

pub fn load_trainer(path: &Path) -> Result<TrainerState> {
    let snap = Snapshot::read_from(path)?;
    let mut meta = Dec::new(snap.get(SEC_META)?);
    let method_name = meta.str()?;
    let step = meta.usize()?;
    let ctx_rng = meta.u64()?;
    let data_rng = meta.u64()?;
    let seconds = meta.f64()?;
    let lr = meta.f32()?;
    let warmup_frac = meta.f32()?;
    let cfg_steps = meta.usize()?;
    meta.finish()?;
    let mut ps = Dec::new(snap.get(SEC_PARAMS)?);
    let n = ps.usize()?;
    let mut params = Vec::new();
    for _ in 0..n {
        params.push(ps.tensor()?);
    }
    ps.finish()?;
    let method_state = snap.get(SEC_METHOD)?.to_vec();
    Ok(TrainerState {
        step,
        method_name,
        ctx_rng,
        data_rng,
        seconds,
        lr,
        warmup_frac,
        cfg_steps,
        params,
        method_state,
    })
}

impl TrainerState {
    /// Apply a loaded snapshot to freshly-constructed trainer pieces:
    /// overwrite `params`, rebuild `method`'s internal state (instead of
    /// `init`), and reposition both RNG streams. Returns
    /// `(completed_steps, accumulated wall seconds)`; the caller
    /// reconstructs the loss/latency curve from the sidecar
    /// ([`curve::read_curve`]). The method *name* is checked here; the
    /// finer construction spec (rank, refresh interval, selector,
    /// adapter kind, LRA config) is embedded in the method payload and
    /// validated by each `Method::load_state`, so a resume with
    /// mismatched `make_method` arguments fails loudly instead of
    /// continuing as a hybrid run.
    pub fn restore(
        self,
        method: &mut dyn Method,
        params: &mut [Tensor],
        ctx_rng: &mut Rng,
        data_rng: &mut Rng,
    ) -> Result<(usize, f64)> {
        anyhow::ensure!(
            method.name() == self.method_name,
            "snapshot was written by method '{}' but the resuming run constructed '{}' — \
             the method spec must match the original run",
            self.method_name,
            method.name()
        );
        anyhow::ensure!(
            params.len() == self.params.len(),
            "snapshot holds {} parameter tensors, the model has {}",
            self.params.len(),
            params.len()
        );
        for (i, (dst, src)) in params.iter_mut().zip(self.params).enumerate() {
            anyhow::ensure!(
                dst.shape == src.shape,
                "parameter {i} shape mismatch: snapshot {:?} vs model {:?}",
                src.shape,
                dst.shape
            );
            *dst = src;
        }
        method.load_state(&self.method_state)?;
        *ctx_rng = Rng::from_state(self.ctx_rng);
        *data_rng = Rng::from_state(self.data_rng);
        Ok((self.step, self.seconds))
    }
}

/// Canonical snapshot path for a step: `<dir>/step_XXXXXXXX.snap`.
pub fn snapshot_path(dir: &Path, step: usize) -> PathBuf {
    dir.join(format!("step_{step:08}.snap"))
}

/// Step number encoded in a `step_XXXXXXXX.snap` file name, if it is one.
pub fn snapshot_step(path: &Path) -> Option<usize> {
    path.file_name()?
        .to_string_lossy()
        .strip_prefix("step_")?
        .strip_suffix(".snap")?
        .parse()
        .ok()
}

/// Newest `step_*.snap` under `dir` (by step number), if any.
pub fn latest_snapshot(dir: &Path) -> Result<Option<PathBuf>> {
    if !dir.exists() {
        return Ok(None);
    }
    let mut best: Option<(usize, PathBuf)> = None;
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        if let Some(step) = snapshot_step(&entry.path()) {
            if best.as_ref().is_none_or(|(b, _)| step > *b) {
                best = Some((step, entry.path()));
            }
        }
    }
    Ok(best.map(|(_, p)| p))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // the standard check value for CRC-32/ISO-HDLC
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn container_roundtrip() {
        let mut snap = Snapshot::new();
        snap.add("alpha", vec![1, 2, 3]);
        snap.add("empty", vec![]);
        snap.add("beta", (0..255u8).collect());
        let bytes = snap.to_bytes();
        let back = Snapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.sections, snap.sections);
        assert_eq!(back.get("alpha").unwrap(), &[1, 2, 3]);
        assert!(back.get("missing").is_err());
    }

    #[test]
    fn container_rejects_corruption() {
        let mut snap = Snapshot::new();
        snap.add("data", vec![9u8; 64]);
        let good = snap.to_bytes();
        // truncation
        let err = Snapshot::from_bytes(&good[..good.len() - 5]).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err}");
        // bit flip in the payload -> CRC failure
        let mut flipped = good.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x40;
        let err = Snapshot::from_bytes(&flipped).unwrap_err();
        assert!(format!("{err:#}").contains("CRC32"), "{err}");
        // bumped format version -> loud refusal
        let mut vbump = good.clone();
        vbump[8..12].copy_from_slice(&99u32.to_le_bytes());
        let err = Snapshot::from_bytes(&vbump).unwrap_err();
        assert!(format!("{err:#}").contains("version 99"), "{err}");
        // bad magic
        let mut bad = good;
        bad[0] = b'X';
        assert!(Snapshot::from_bytes(&bad).is_err());
    }

    #[test]
    #[should_panic(expected = "added twice")]
    fn add_panics_on_duplicate_section_name() {
        let mut snap = Snapshot::new();
        snap.add("meta", vec![1]);
        snap.add("meta", vec![2]);
    }

    #[test]
    fn parse_rejects_duplicate_sections_in_hand_built_bytes() {
        // hand-build a container that `to_bytes` can no longer produce:
        // two sections named "meta" with DIFFERENT payloads, both CRCs
        // valid — the old parser accepted it and `get` served the first
        // payload while the second silently vanished.
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes()); // section count
        for payload in [&[1u8, 2, 3][..], &[9u8, 9][..]] {
            b.extend_from_slice(&4u32.to_le_bytes()); // name length
            b.extend_from_slice(b"meta");
            b.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            b.extend_from_slice(&crc32(payload).to_le_bytes());
            b.extend_from_slice(payload);
        }
        let err = Snapshot::from_bytes(&b).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("duplicate section 'meta'"), "{msg}");
        // same bytes with the second section renamed parse fine — the
        // rejection is specifically about the duplicate name
        let pos = b.len() - (4 + 8 + 4 + 2); // start of second header
        b[pos..pos + 4].copy_from_slice(&4u32.to_le_bytes());
        let name_at = pos + 4;
        b[name_at..name_at + 4].copy_from_slice(b"mate");
        let ok = Snapshot::from_bytes(&b).unwrap();
        assert_eq!(ok.get("meta").unwrap(), &[1, 2, 3]);
        assert_eq!(ok.get("mate").unwrap(), &[9, 9]);
    }

    #[test]
    fn atomic_write_and_latest() {
        let dir = std::env::temp_dir().join(format!("lift_ckpt_mod_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(latest_snapshot(&dir).unwrap().is_none());
        for step in [2usize, 10, 6] {
            let mut snap = Snapshot::new();
            snap.add("meta", vec![step as u8]);
            snap.write_to(&snapshot_path(&dir, step)).unwrap();
        }
        let latest = latest_snapshot(&dir).unwrap().unwrap();
        assert_eq!(latest, snapshot_path(&dir, 10));
        // files that don't match the pattern are ignored
        std::fs::write(dir.join("notes.txt"), b"x").unwrap();
        assert_eq!(latest_snapshot(&dir).unwrap().unwrap(), snapshot_path(&dir, 10));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

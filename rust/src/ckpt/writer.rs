//! Double-buffered background snapshot writer: moves checkpoint I/O off
//! the training hot loop.
//!
//! The trainer serializes a snapshot to bytes (O(model) memcpy — the
//! part that needs `&method`/`&params`) and hands the buffer to a
//! dedicated writer thread, which performs the atomic temp-file +
//! rename write and then applies the keep-last-N retention policy. The
//! channel is bounded at depth 1, so at most one buffer is being
//! written while one more is queued — "double buffered": a burst of
//! snapshots backpressures the trainer instead of growing memory
//! without bound.
//!
//! Correctness properties the crash-resume suite leans on:
//!
//! * writes stay atomic (same tmp+rename as the synchronous path), so a
//!   kill mid-write still leaves only complete snapshots on disk;
//! * [`AsyncSnapshotWriter::finish`] — and `Drop`, for error-path
//!   unwinds — drains the queue and joins the thread, so by the time
//!   `train_with` returns (normally OR with an error), every submitted
//!   snapshot is durable and `ckpt::latest_snapshot` sees it;
//! * retention runs on the writer thread after each write, so the
//!   directory never exceeds `keep` snapshots (+ the curve sidecar) at
//!   any quiescent point.
//!
//! Write errors are reported at the next [`AsyncSnapshotWriter::submit`]
//! or at [`AsyncSnapshotWriter::finish`], whichever comes first.

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, SyncSender};

use anyhow::Result;

use super::{prune_snapshots, write_atomic};

struct Job {
    path: PathBuf,
    bytes: Vec<u8>,
    /// keep-last-N policy applied to `path`'s directory after the write
    /// (0 = keep everything).
    keep: usize,
}

/// Background snapshot writer; one per training run with checkpointing
/// enabled. See the module doc for the buffering and error contract.
pub struct AsyncSnapshotWriter {
    tx: Option<SyncSender<Job>>,
    handle: Option<std::thread::JoinHandle<Result<usize>>>,
}

impl AsyncSnapshotWriter {
    pub fn new() -> AsyncSnapshotWriter {
        // depth 1 + the job being written = two buffers in flight
        let (tx, rx) = sync_channel::<Job>(1);
        let handle = std::thread::spawn(move || -> Result<usize> {
            let mut written = 0usize;
            for job in rx {
                write_atomic(&job.path, &job.bytes)?;
                if job.keep > 0 {
                    if let Some(dir) = job.path.parent() {
                        prune_snapshots(dir, job.keep)?;
                    }
                }
                written += 1;
            }
            Ok(written)
        });
        AsyncSnapshotWriter {
            tx: Some(tx),
            handle: Some(handle),
        }
    }

    /// Enqueue one serialized snapshot. Blocks only when both buffers
    /// are in flight (backpressure). A send failure means the writer
    /// thread died on a prior write — the thread is joined here so the
    /// caller gets the underlying I/O error (path + cause), not a
    /// generic "thread stopped".
    pub fn submit(&mut self, path: PathBuf, bytes: Vec<u8>, keep: usize) -> Result<()> {
        let sent = self
            .tx
            .as_ref()
            .expect("submit after finish")
            .send(Job { path, bytes, keep });
        if sent.is_err() {
            return Err(match self.finish_inner() {
                Err(e) => e.context("snapshot writer thread stopped"),
                Ok(n) => anyhow::anyhow!(
                    "snapshot writer thread stopped unexpectedly after {n} clean writes"
                ),
            });
        }
        Ok(())
    }

    /// Close the queue, wait for every pending write, and return how
    /// many snapshots this writer committed — or the first write error.
    pub fn finish(mut self) -> Result<usize> {
        self.finish_inner()
    }

    fn finish_inner(&mut self) -> Result<usize> {
        drop(self.tx.take()); // close the channel so the thread drains and exits
        match self.handle.take() {
            Some(h) => h
                .join()
                .map_err(|_| anyhow::anyhow!("snapshot writer thread panicked"))?,
            None => Ok(0),
        }
    }
}

impl Default for AsyncSnapshotWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for AsyncSnapshotWriter {
    /// Error-path safety net: a `?`-unwind in the trainer still drains
    /// pending writes before the run returns, so crash-resume finds the
    /// newest snapshot. Errors here are swallowed — call
    /// [`AsyncSnapshotWriter::finish`] on the happy path to observe them.
    fn drop(&mut self) {
        let _ = self.finish_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::{snapshot_path, Snapshot};

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lift_writer_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn snap_bytes(step: usize) -> Vec<u8> {
        let mut s = Snapshot::new();
        s.add("meta", vec![step as u8; 64]);
        s.to_bytes()
    }

    #[test]
    fn writes_everything_before_finish_returns() {
        let dir = tmp("drain");
        let mut w = AsyncSnapshotWriter::new();
        for step in 1..=5 {
            w.submit(snapshot_path(&dir, step), snap_bytes(step), 0).unwrap();
        }
        let n = w.finish().unwrap();
        assert_eq!(n, 5);
        for step in 1..=5 {
            let snap = Snapshot::read_from(&snapshot_path(&dir, step)).unwrap();
            assert_eq!(snap.get("meta").unwrap()[0], step as u8, "content intact");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn retention_caps_the_directory() {
        let dir = tmp("retain");
        // an unrelated file must survive pruning
        std::fs::write(dir.join("curve.sidecar"), b"LIFTCRV1").unwrap();
        let mut w = AsyncSnapshotWriter::new();
        for step in 1..=7 {
            w.submit(snapshot_path(&dir, step), snap_bytes(step), 3).unwrap();
        }
        w.finish().unwrap();
        let mut snaps: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".snap"))
            .collect();
        snaps.sort();
        assert_eq!(
            snaps,
            vec!["step_00000005.snap", "step_00000006.snap", "step_00000007.snap"],
            "keep-last-3 must hold at quiescence"
        );
        assert!(dir.join("curve.sidecar").exists(), "sidecar untouched");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn drop_drains_like_finish() {
        let dir = tmp("drop");
        {
            let mut w = AsyncSnapshotWriter::new();
            w.submit(snapshot_path(&dir, 9), snap_bytes(9), 0).unwrap();
            // no finish(): simulates the trainer's error-path unwind
        }
        assert!(
            snapshot_path(&dir, 9).exists(),
            "drop must drain pending writes"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn write_failure_surfaces_the_underlying_error() {
        // target's parent is a FILE, so create_dir_all inside
        // write_atomic fails on the writer thread; the failure must
        // reach the caller with the real cause attached, via a later
        // submit (channel disconnected -> join) or via finish
        let dir = tmp("fail");
        let blocker = dir.join("not_a_dir");
        std::fs::write(&blocker, b"x").unwrap();
        let bad = blocker.join("step_00000001.snap");
        let mut w = AsyncSnapshotWriter::new();
        let mut err = None;
        for _ in 0..16 {
            if let Err(e) = w.submit(bad.clone(), snap_bytes(1), 0) {
                err = Some(format!("{e:#}"));
                break;
            }
        }
        let msg = match err {
            Some(m) => m,
            None => format!("{:#}", w.finish().unwrap_err()),
        };
        assert!(
            msg.contains("not_a_dir") || msg.contains("snapshot"),
            "error lost its cause: {msg}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! Append-only loss/latency sidecar: the training curve stream that
//! keeps snapshots O(model).
//!
//! Snapshots used to embed the full loss + step-latency history, which
//! made snapshot bytes grow linearly with step count (quadratic total
//! I/O over a long campaign — the ROADMAP scaling item). The curve now
//! streams to one `curve.sidecar` file per checkpoint directory:
//!
//! ```text
//! offset 0   magic    8 bytes   b"LIFTCRV1"
//! then, per completed step (12 bytes):
//!            loss     f32 LE
//!            seconds  f64 LE   (step wall latency)
//! ```
//!
//! Consistency contract with the snapshots next to it: a snapshot at
//! step `k` requires the sidecar's first `k` records (the trainer
//! flushes the sidecar before enqueueing the snapshot). Records past the
//! newest snapshot are a crash tail; [`CurveWriter::open`] truncates to
//! the restored prefix on resume, so duplicates can never accumulate.
//! Torn final records are handled the same way — truncation on the next
//! open, never a parse error for the prefix a snapshot vouches for.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Sidecar file name inside a checkpoint directory.
pub const CURVE_FILE: &str = "curve.sidecar";

const CURVE_MAGIC: &[u8; 8] = b"LIFTCRV1";
/// Bytes per record: f32 loss + f64 step seconds.
const REC_BYTES: usize = 12;

pub fn curve_path(dir: &Path) -> PathBuf {
    dir.join(CURVE_FILE)
}

/// Buffered appender over the sidecar. Opening rewrites the file as
/// `magic + prefix` (the restored curve on resume, empty on a fresh
/// run), which is both the truncation of crash tails and the migration
/// of a restored prefix into a new checkpoint directory. The rewrite is
/// atomic — temp file + rename, like the snapshots — so a crash during
/// a resume's prefix install never destroys the only copy of the curve
/// the directory's snapshots depend on; appends after that go straight
/// to the committed file (a torn appended tail is truncated by the next
/// open, never parsed).
pub struct CurveWriter {
    file: std::io::BufWriter<std::fs::File>,
}

impl CurveWriter {
    pub fn open(dir: &Path, prefix: &[(f32, f64)]) -> Result<CurveWriter> {
        let path = curve_path(dir);
        let mut bytes = Vec::with_capacity(CURVE_MAGIC.len() + prefix.len() * REC_BYTES);
        bytes.extend_from_slice(CURVE_MAGIC);
        for &(loss, secs) in prefix {
            bytes.extend_from_slice(&loss.to_le_bytes());
            bytes.extend_from_slice(&secs.to_le_bytes());
        }
        // same tmp+rename (and dir creation) as the snapshots — one
        // atomic-write implementation to harden
        super::write_atomic(&path, &bytes)
            .with_context(|| format!("installing curve sidecar prefix {path:?}"))?;
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(&path)
            .with_context(|| format!("opening curve sidecar {path:?} for append"))?;
        Ok(CurveWriter {
            file: std::io::BufWriter::new(file),
        })
    }

    /// One completed step's record. Buffered — call [`CurveWriter::flush`]
    /// before a snapshot of that step is enqueued.
    pub fn append(&mut self, loss: f32, secs: f64) -> Result<()> {
        self.file.write_all(&loss.to_le_bytes())?;
        self.file.write_all(&secs.to_le_bytes())?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// Read the first `steps` records — the curve prefix a snapshot at
/// `steps` vouches for. Fails loudly when the sidecar is missing,
/// mis-tagged, or shorter than the snapshot claims (the snapshot and
/// its sidecar are a pair; one without the other is corruption).
pub fn read_curve(dir: &Path, steps: usize) -> Result<(Vec<f32>, Vec<f64>)> {
    let path = curve_path(dir);
    let bytes = crate::util::fault::read(&path).with_context(|| {
        format!(
            "reading curve sidecar {path:?} (snapshots store only O(model) state; \
             the loss curve lives in the sidecar next to them)"
        )
    })?;
    anyhow::ensure!(
        bytes.len() >= CURVE_MAGIC.len() && &bytes[..CURVE_MAGIC.len()] == CURVE_MAGIC,
        "{path:?} is not a LIFT curve sidecar"
    );
    let body = &bytes[CURVE_MAGIC.len()..];
    anyhow::ensure!(
        body.len() / REC_BYTES >= steps,
        "curve sidecar {path:?} holds {} complete records but the snapshot is at step {steps}",
        body.len() / REC_BYTES
    );
    let mut losses = Vec::with_capacity(steps);
    let mut times = Vec::with_capacity(steps);
    for rec in body.chunks_exact(REC_BYTES).take(steps) {
        losses.push(f32::from_le_bytes(rec[..4].try_into().unwrap()));
        times.push(f64::from_le_bytes(rec[4..].try_into().unwrap()));
    }
    Ok((losses, times))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lift_curve_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let dir = tmp("roundtrip");
        let mut w = CurveWriter::open(&dir, &[]).unwrap();
        let recs = [(0.5f32, 0.001f64), (-0.0, 2.5), (f32::MIN_POSITIVE, 1e-9)];
        for &(l, t) in &recs {
            w.append(l, t).unwrap();
        }
        w.flush().unwrap();
        let (ls, ts) = read_curve(&dir, 3).unwrap();
        for (i, &(l, t)) in recs.iter().enumerate() {
            assert_eq!(ls[i].to_bits(), l.to_bits());
            assert_eq!(ts[i].to_bits(), t.to_bits());
        }
        // shorter prefixes read fine; longer ones fail loudly
        assert_eq!(read_curve(&dir, 1).unwrap().0.len(), 1);
        assert!(read_curve(&dir, 4).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopen_truncates_to_the_prefix() {
        let dir = tmp("truncate");
        let mut w = CurveWriter::open(&dir, &[]).unwrap();
        for i in 0..5 {
            w.append(i as f32, 0.1).unwrap();
        }
        w.flush().unwrap();
        drop(w);
        // resume at step 2: crash tail (records 2..5) must vanish
        let prefix: Vec<(f32, f64)> = vec![(0.0, 0.1), (1.0, 0.1)];
        let mut w = CurveWriter::open(&dir, &prefix).unwrap();
        w.append(9.0, 0.2).unwrap();
        w.flush().unwrap();
        let (ls, _) = read_curve(&dir, 3).unwrap();
        assert_eq!(ls, vec![0.0, 1.0, 9.0]);
        assert!(read_curve(&dir, 4).is_err(), "tail records must be gone");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_or_mistagged_sidecar_errors() {
        let dir = tmp("missing");
        assert!(read_curve(&dir, 0).is_err(), "missing file");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(curve_path(&dir), b"garbage!x").unwrap();
        assert!(read_curve(&dir, 0).is_err(), "bad magic");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! LIFT-as-a-service: per-tenant sparse-delta serving over one shared base.
//!
//! The paper's economic argument is that a LIFT fine-tune is a *tiny sparse
//! delta* — the top-5% principal weights — over a frozen base model, so a
//! server can keep ONE base resident and overlay per-tenant deltas at
//! request time instead of holding thousands of model copies. This module
//! is that serving layer, generalized over any sparse-FT method that emits
//! `(mask indices, values)` pairs (LIFT, weight_mag, SIFT, ...).
//!
//! # Delta format ([`delta`])
//!
//! A [`TenantDelta`] is `{ tenant, base_digest, entries }` where each entry
//! holds one parameter's sorted flat mask indices (`u32`) and replacement
//! values (`f32`). On disk it is a LIFTSNAP container (`ckpt::Snapshot`,
//! magic + version + per-section CRC32) with two sections, serialized via
//! the existing `ckpt::codec` Enc/Dec — so deltas inherit the snapshot
//! suite's corruption detection and atomic tmp+rename writes. `base_digest`
//! is an FNV-1a digest of the base parameters ([`base_digest`]); a delta
//! whose digest does not match the resident base is refused LOUDLY at load,
//! the same policy as the LIFTSNAP format-version refusal — serving a delta
//! against the wrong base silently personalizes with garbage.
//!
//! # LRU / eviction contract ([`lru`])
//!
//! The base is immutable and shared; overlay-apply never writes into it.
//! Materializing a tenant builds a [`TenantView`]: a row-granular
//! copy-on-materialize overlay holding ONLY the base rows the delta
//! touches, with the delta values scattered in. Eviction is therefore a
//! scatter-undo by construction — dropping the view releases exactly the
//! touched-row copies and the base needs no restoration, O(touched rows)
//! rather than a full base copy. [`TenantLru`] bounds total view bytes by a
//! budget and evicts least-recently-used tenants (logical-tick recency, so
//! eviction order is a pure function of the request stream — deterministic
//! at any worker count).
//!
//! # Hot-swap atomicity
//!
//! Updating a live tenant is build-then-swap: the new view is fully
//! materialized *before* the LRU entry's `Arc` is replaced, and unrelated
//! tenants are untouched (no eviction sweep unless the replacement is
//! larger and the budget demands it). In-flight requests hold the old
//! `Arc` and keep reading the complete old version; a torn half-old
//! half-new delta is unrepresentable.
//!
//! # Batched multi-tenant inference ([`batch`])
//!
//! [`Server::handle_batch`] groups requests by tenant so one overlay
//! resolution amortizes across the tenant's whole group, then fans the
//! groups over `lift::engine::par_map` with the PR-7 intra-matrix budget
//! (`intra = (workers / n_groups).max(1)` chunks per group). Each request
//! is a pure function of `(base, delta, seed)`, so 1-worker and N-worker
//! runs are bit-identical per the repo's standing determinism contract.
//!
//! # Durability contract
//!
//! The [`DeltaStore`] inherits the checkpoint suite's atomic-write path
//! (`ckpt::write_atomic`: temp + fsync + rename + dir fsync — see the
//! `ckpt` module doc), so per tenant the store only ever holds either the
//! previous complete delta or the new complete delta:
//!
//! - A crash mid-`register` leaves an orphaned `<tenant>.tmp` next to the
//!   (untouched) committed delta. [`DeltaStore::list`] skips such
//!   droppings with a warning naming the file; `load` of the tenant still
//!   returns the pre-crash version. The store never needs repair to stay
//!   usable.
//! - Transient read/write errors (`EINTR`/`EAGAIN`) are retried with
//!   bounded backoff by the `util::fault` IO seam; permanent errors
//!   (`ENOSPC`, `EIO`, `EACCES`) surface loudly with the tenant and path
//!   named — a delta that cannot be read is an error, never treated as
//!   "not registered" unless the file is genuinely absent.
//! - Corrupt bytes (CRC/magic/digest failures) are refused at load with
//!   the reason named; the file is left in place for inspection.
//!
//! `lift torture` replays seeded fault schedules over exactly this
//! register/swap/evict mix to keep the contract honest.

pub mod batch;
pub mod delta;
pub mod lru;

pub use batch::{forward_one, BaseModel, ForwardPlan, ModelRows, OverlayModel, Request, Server};
pub use delta::{synth_delta, DeltaStore, ParamDelta, TenantDelta};
pub use lru::{TenantLru, TenantView};

use crate::tensor::Tensor;

/// Digest of a base parameter set: shapes and exact f32 bit patterns, via
/// the same FNV-1a word digest the method-state checkpoints use. Two bases
/// agree on this iff every parameter is bitwise identical — the spec key a
/// [`TenantDelta`] is pinned to.
pub fn base_digest(params: &[Tensor]) -> u64 {
    crate::methods::digest_words(
        std::iter::once(params.len() as u64).chain(params.iter().flat_map(|t| {
            std::iter::once(t.shape.len() as u64)
                .chain(t.shape.iter().map(|&d| d as u64))
                .chain(t.data.iter().map(|x| x.to_bits() as u64))
        })),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_digest_is_stable_and_bit_sensitive() {
        let a = crate::exp::matrix::toy_params(7);
        let b = crate::exp::matrix::toy_params(7);
        assert_eq!(base_digest(&a), base_digest(&b), "same seed, same digest");
        let c = crate::exp::matrix::toy_params(8);
        assert_ne!(base_digest(&a), base_digest(&c), "different base, different digest");
        // a single-ULP flip changes the digest
        let mut d = crate::exp::matrix::toy_params(7);
        d[0].data[0] = f32::from_bits(d[0].data[0].to_bits() ^ 1);
        assert_ne!(base_digest(&a), base_digest(&d));
    }
}

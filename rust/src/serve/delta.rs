//! Tenant delta format + on-disk store.
//!
//! A [`TenantDelta`] is the unit a fine-tune hands to the server: per
//! parameter, the sorted flat indices its method masked plus the trained
//! replacement values, pinned to the exact base it was trained against by
//! [`super::base_digest`]. The [`DeltaStore`] persists one LIFTSNAP
//! container per tenant under a directory (`<dir>/<tenant>.delta`), written
//! with the checkpoint suite's atomic tmp+rename, and refuses loudly on
//! digest mismatch at both register and load.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::ckpt::codec::{Dec, Enc};
use crate::ckpt::{self, Snapshot};
use crate::lift::budget_for;
use crate::lift::engine::stream_rng;
use crate::tensor::Tensor;

/// Snapshot section holding `{tenant, base_digest, entry count}`.
pub const SEC_TENANT_META: &str = "tenant_meta";
/// Snapshot section holding the per-parameter index/value arrays.
pub const SEC_TENANT_ENTRIES: &str = "tenant_entries";

/// One parameter's sparse update: `idx` are flat (row-major) positions,
/// strictly increasing; `vals[i]` replaces the base value at `idx[i]`.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamDelta {
    pub param: usize,
    pub idx: Vec<u32>,
    pub vals: Vec<f32>,
}

/// A tenant's full sparse fine-tune over one specific base.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantDelta {
    pub tenant: String,
    pub base_digest: u64,
    /// Sorted by `param`, strictly increasing.
    pub entries: Vec<ParamDelta>,
}

impl TenantDelta {
    /// Total number of overridden weights.
    pub fn nnz(&self) -> usize {
        self.entries.iter().map(|e| e.idx.len()).sum()
    }

    /// Serialize as a LIFTSNAP container (magic, version, per-section CRC).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut meta = Enc::new();
        meta.str(&self.tenant);
        meta.u64(self.base_digest);
        meta.usize(self.entries.len());
        let mut body = Enc::new();
        for e in &self.entries {
            body.usize(e.param);
            body.u32s(&e.idx);
            body.f32s(&e.vals);
        }
        let mut snap = Snapshot::new();
        snap.add(SEC_TENANT_META, meta.into_bytes());
        snap.add(SEC_TENANT_ENTRIES, body.into_bytes());
        snap.to_bytes()
    }

    /// Parse and validate canonical form. The digest check runs BEFORE the
    /// entry arrays are trusted: a delta built against a different base is
    /// refused with both digests named (LIFTSNAP version-refusal policy —
    /// overlaying it would silently personalize with garbage).
    pub fn from_bytes(b: &[u8], expect_digest: u64) -> Result<TenantDelta> {
        let snap = Snapshot::from_bytes(b)?;
        let mut meta = Dec::new(snap.get(SEC_TENANT_META)?);
        let tenant = meta.str()?;
        let base_digest = meta.u64()?;
        let n_entries = meta.usize()?;
        meta.finish()?;
        anyhow::ensure!(
            base_digest == expect_digest,
            "tenant '{tenant}' delta was trained against base {base_digest:016x} but this \
             server runs base {expect_digest:016x} — refusing to overlay a mismatched \
             spec (re-fine-tune the tenant against the resident base)"
        );
        let mut body = Dec::new(snap.get(SEC_TENANT_ENTRIES)?);
        let mut entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let param = body.usize()?;
            let idx = body.u32s()?;
            let vals = body.f32s()?;
            anyhow::ensure!(
                idx.len() == vals.len(),
                "tenant '{tenant}' param {param}: {} indices but {} values",
                idx.len(),
                vals.len()
            );
            anyhow::ensure!(
                idx.windows(2).all(|w| w[0] < w[1]),
                "tenant '{tenant}' param {param}: mask indices not strictly increasing"
            );
            entries.push(ParamDelta { param, idx, vals });
        }
        body.finish()?;
        anyhow::ensure!(
            entries.windows(2).all(|w| w[0].param < w[1].param),
            "tenant '{tenant}': entries not sorted by parameter index"
        );
        Ok(TenantDelta { tenant, base_digest, entries })
    }

    /// Bounds-check every entry against a concrete base parameter set.
    pub fn validate_against(&self, base: &[Tensor]) -> Result<()> {
        for e in &self.entries {
            anyhow::ensure!(
                e.param < base.len(),
                "tenant '{}': delta names param {} but the base has only {}",
                self.tenant,
                e.param,
                base.len()
            );
            let numel = base[e.param].len();
            if let Some(&last) = e.idx.last() {
                anyhow::ensure!(
                    (last as usize) < numel,
                    "tenant '{}' param {}: mask index {} out of bounds ({} elements)",
                    self.tenant,
                    e.param,
                    last,
                    numel
                );
            }
        }
        Ok(())
    }
}

/// Tenant names become file stems; keep them shell- and NFS-safe.
pub fn check_tenant_name(name: &str) -> Result<()> {
    anyhow::ensure!(
        !name.is_empty() && name.len() <= 64,
        "tenant name must be 1..=64 chars, got {} ('{name}')",
        name.len()
    );
    anyhow::ensure!(
        !name.starts_with('.')
            && name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')),
        "tenant name '{name}' has characters outside [A-Za-z0-9._-] (or a leading dot)"
    );
    Ok(())
}

/// On-disk registry of tenant deltas, pinned to one base digest.
pub struct DeltaStore {
    dir: PathBuf,
    base_digest: u64,
}

impl DeltaStore {
    /// Open (creating the directory); every later register/load checks
    /// against `base_digest`.
    pub fn open(dir: &Path, base_digest: u64) -> Result<DeltaStore> {
        crate::util::fault::create_dir_all(dir)
            .with_context(|| format!("creating delta store dir {}", dir.display()))?;
        Ok(DeltaStore { dir: dir.to_path_buf(), base_digest })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn base_digest(&self) -> u64 {
        self.base_digest
    }

    pub fn delta_path(&self, tenant: &str) -> Result<PathBuf> {
        check_tenant_name(tenant)?;
        Ok(self.dir.join(format!("{tenant}.delta")))
    }

    /// Register a new tenant or update an existing one (same call — the
    /// atomic rename makes the update an all-or-nothing replacement).
    pub fn register(&self, delta: &TenantDelta) -> Result<()> {
        anyhow::ensure!(
            delta.base_digest == self.base_digest,
            "tenant '{}' delta targets base {:016x} but this store is pinned to {:016x} — \
             refusing to register a delta no resident base can serve",
            delta.tenant,
            delta.base_digest,
            self.base_digest
        );
        let path = self.delta_path(&delta.tenant)?;
        ckpt::write_atomic(&path, &delta.to_bytes())
            .with_context(|| format!("registering tenant '{}'", delta.tenant))
    }

    pub fn load(&self, tenant: &str) -> Result<TenantDelta> {
        let path = self.delta_path(tenant)?;
        let bytes = crate::util::fault::read(&path).with_context(|| {
            format!("no delta registered for tenant '{tenant}' ({})", path.display())
        })?;
        let delta = TenantDelta::from_bytes(&bytes, self.base_digest)
            .with_context(|| format!("loading {}", path.display()))?;
        anyhow::ensure!(
            delta.tenant == tenant,
            "{} holds a delta for tenant '{}' — file renamed after registration?",
            path.display(),
            delta.tenant
        );
        Ok(delta)
    }

    /// Remove a tenant's delta; `Ok(false)` if it was never registered.
    pub fn delete(&self, tenant: &str) -> Result<bool> {
        let path = self.delta_path(tenant)?;
        match crate::util::fault::remove_file(&path) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e).with_context(|| format!("deleting {}", path.display())),
        }
    }

    /// Registered tenant names, sorted. Non-`.delta` droppings — most
    /// importantly the orphaned `<tenant>.tmp` a crash mid-`register`
    /// leaves behind (the rename never happened, so the committed delta
    /// is whatever was there before) — are skipped WITH a warning
    /// naming the file, never silently and never fatally: one crashed
    /// registration must not take the store down.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("listing {}", self.dir.display()))?
        {
            let path = entry?.path();
            if path.is_dir() {
                log::warn!("delta store: ignoring subdirectory {}", path.display());
                continue;
            }
            let ext = path.extension().and_then(|e| e.to_str());
            if ext == Some("delta") {
                if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                    out.push(stem.to_string());
                }
            } else if ext == Some("tmp") {
                log::warn!(
                    "delta store: ignoring orphaned temp file {} (crashed register; the \
                     committed delta, if any, is unaffected — delete the .tmp to silence this)",
                    path.display()
                );
            } else {
                log::warn!("delta store: ignoring non-delta file {}", path.display());
            }
        }
        out.sort();
        Ok(out)
    }
}

/// Seeded synthetic fine-tune for demos/benches: a row-clustered sparse
/// delta over every 2-D base parameter (1-D norms are skipped — real LIFT
/// masks matrices).
///
/// Indices are ROW-CLUSTERED, not uniform: LIFT's principal-weight masks
/// and the row-structured sparse-FT baselines concentrate updates in few
/// rows, and row clustering is what makes the row-granular [`super::lru::
/// TenantView`] copy a small fraction of the base instead of every row.
/// Budget per matrix is the repo-standard `budget_for(m, n, rank_equiv)`,
/// spread over ~2x the minimum rows that could hold it.
pub fn synth_delta(
    base: &[Tensor],
    tenant: &str,
    base_digest: u64,
    rank_equiv: usize,
    seed: u64,
) -> TenantDelta {
    let mut entries = Vec::new();
    for (pi, t) in base.iter().enumerate() {
        if t.shape.len() != 2 {
            continue;
        }
        let (m, n) = t.dims2();
        let k = budget_for(m, n, rank_equiv);
        let mut rng = stream_rng(seed, 0x5e77e ^ pi as u64);
        let rows_min = k.div_ceil(n).max(1);
        let rows = (rows_min * 2).min(m);
        let mut row_ids = rng.sample_indices(m, rows);
        row_ids.sort_unstable();
        let per_row = k.div_ceil(rows).min(n);
        let mut idx = Vec::with_capacity(k);
        let mut remaining = k;
        for &r in &row_ids {
            let take = per_row.min(remaining);
            if take == 0 {
                break;
            }
            let mut cols = rng.sample_indices(n, take);
            cols.sort_unstable();
            idx.extend(cols.iter().map(|&c| (r * n + c) as u32));
            remaining -= take;
        }
        let vals = idx
            .iter()
            .map(|&i| t.data[i as usize] + 0.05 * rng.normal())
            .collect();
        entries.push(ParamDelta { param: pi, idx, vals });
    }
    TenantDelta { tenant: tenant.to_string(), base_digest, entries }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::matrix::toy_params;
    use crate::serve::base_digest;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lift_delta_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn synth_delta_is_canonical_and_seeded() {
        let base = toy_params(3);
        let dg = base_digest(&base);
        let a = synth_delta(&base, "t0", dg, 2, 11);
        let b = synth_delta(&base, "t0", dg, 2, 11);
        assert_eq!(a, b, "same seed, same delta");
        let c = synth_delta(&base, "t0", dg, 2, 12);
        assert_ne!(a, c, "different seed, different delta");
        assert!(a.nnz() > 0);
        a.validate_against(&base).unwrap();
        for e in &a.entries {
            assert!(e.idx.windows(2).all(|w| w[0] < w[1]), "param {} unsorted", e.param);
            assert_eq!(e.idx.len(), e.vals.len());
        }
        assert!(a.entries.windows(2).all(|w| w[0].param < w[1].param));
    }

    #[test]
    fn roundtrip_and_digest_refusal() {
        let base = toy_params(3);
        let dg = base_digest(&base);
        let d = synth_delta(&base, "alice", dg, 2, 5);
        let bytes = d.to_bytes();
        let back = TenantDelta::from_bytes(&bytes, dg).unwrap();
        assert_eq!(d, back);
        let err = TenantDelta::from_bytes(&bytes, dg ^ 1).unwrap_err().to_string();
        assert!(err.contains("refusing to overlay"), "got: {err}");
        assert!(err.contains("alice"), "names the tenant: {err}");
    }

    #[test]
    fn store_register_load_update_delete_list() {
        let base = toy_params(3);
        let dg = base_digest(&base);
        let dir = tmpdir("store");
        let store = DeltaStore::open(&dir, dg).unwrap();
        let a = synth_delta(&base, "a", dg, 2, 1);
        let b = synth_delta(&base, "b", dg, 2, 2);
        store.register(&a).unwrap();
        store.register(&b).unwrap();
        assert_eq!(store.list().unwrap(), vec!["a", "b"]);
        assert_eq!(store.load("a").unwrap(), a);
        // register is also update
        let a2 = synth_delta(&base, "a", dg, 2, 99);
        store.register(&a2).unwrap();
        assert_eq!(store.load("a").unwrap(), a2);
        // wrong-digest register refused
        let alien = synth_delta(&base, "evil", dg ^ 7, 2, 1);
        assert!(store.register(&alien).unwrap_err().to_string().contains("pinned"));
        assert!(store.delete("a").unwrap());
        assert!(!store.delete("a").unwrap());
        assert_eq!(store.list().unwrap(), vec!["b"]);
        let missing = store.load("a").unwrap_err().to_string();
        assert!(missing.contains("no delta registered"), "got: {missing}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tenant_names_are_validated() {
        for bad in ["", "../up", "a b", ".hidden", &"x".repeat(65)] {
            assert!(check_tenant_name(bad).is_err(), "accepted '{bad}'");
        }
        for good in ["t0001", "alice-v2", "A.B_c"] {
            check_tenant_name(good).unwrap();
        }
    }
}

//! Materialized tenant views + the byte-budgeted LRU that caches them.
//!
//! A [`TenantView`] is the copy-on-materialize overlay: for every base row
//! a delta touches, one copied row with the delta values scattered in.
//! The base itself is immutable and shared, so eviction is a scatter-undo
//! by construction — dropping the view releases exactly the touched-row
//! copies (see the module doc in [`super`]). [`TenantLru`] keys recency on
//! a logical tick, not wall time, so admit/evict order is a pure function
//! of the request stream and identical at any worker count.

use std::sync::Arc;

use anyhow::Result;

use crate::tensor::Tensor;

use super::delta::TenantDelta;

/// Accounting overhead charged per copied row (Vec header + row key).
pub const ROW_OVERHEAD_BYTES: usize = 32;
/// Accounting overhead charged per touched parameter.
pub const PARAM_OVERHEAD_BYTES: usize = 48;
/// Accounting overhead charged per view (tenant string, vec headers).
pub const VIEW_OVERHEAD_BYTES: usize = 96;

/// Row-granular overlay for one tenant: per touched parameter (ascending),
/// the touched rows (ascending) as full copied rows with delta values
/// scattered in. Lookup is two binary searches; untouched rows fall
/// through to the base.
pub struct TenantView {
    tenant: String,
    /// `(param index, [(row index, copied row)])`, both levels sorted.
    params: Vec<(usize, Vec<(usize, Vec<f32>)>)>,
    bytes: usize,
}

impl TenantView {
    /// Build the overlay from a delta: group each parameter's flat indices
    /// by row (`ncols` = last dim; 1-D tensors are one row), copy each
    /// touched base row once, scatter the values in.
    pub fn materialize(base: &[Tensor], delta: &TenantDelta) -> Result<TenantView> {
        delta.validate_against(base)?;
        let mut params = Vec::with_capacity(delta.entries.len());
        let mut bytes = VIEW_OVERHEAD_BYTES + delta.tenant.len();
        for e in &delta.entries {
            let t = &base[e.param];
            let ncols = *t.shape.last().unwrap_or(&1);
            let mut rows: Vec<(usize, Vec<f32>)> = Vec::new();
            for (&i, &v) in e.idx.iter().zip(&e.vals) {
                let (r, c) = (i as usize / ncols, i as usize % ncols);
                // idx is sorted, so a row's indices arrive contiguously
                match rows.last_mut() {
                    Some((last_r, row)) if *last_r == r => row[c] = v,
                    _ => {
                        let mut row = t.data[r * ncols..(r + 1) * ncols].to_vec();
                        row[c] = v;
                        rows.push((r, row));
                    }
                }
            }
            bytes += PARAM_OVERHEAD_BYTES + rows.len() * (ncols * 4 + ROW_OVERHEAD_BYTES);
            params.push((e.param, rows));
        }
        Ok(TenantView { tenant: delta.tenant.clone(), params, bytes })
    }

    /// The slow path the view replaces: a full dense copy of the base with
    /// the delta scattered in. Used by the bit-identity tests and the
    /// `[serve]` bench as the comparison baseline.
    pub fn full_materialize(base: &[Tensor], delta: &TenantDelta) -> Result<Vec<Tensor>> {
        delta.validate_against(base)?;
        let mut dense: Vec<Tensor> = base.to_vec();
        for e in &delta.entries {
            let data = &mut dense[e.param].data;
            for (&i, &v) in e.idx.iter().zip(&e.vals) {
                data[i as usize] = v;
            }
        }
        Ok(dense)
    }

    /// The overlaid row, if this view touches `(param, row)`.
    pub fn row(&self, param: usize, row: usize) -> Option<&[f32]> {
        let p = self.params.binary_search_by_key(&param, |e| e.0).ok()?;
        let rows = &self.params[p].1;
        let r = rows.binary_search_by_key(&row, |e| e.0).ok()?;
        Some(&rows[r].1)
    }

    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Accounted resident size (row copies + bookkeeping overhead).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Number of base rows this view copies.
    pub fn touched_rows(&self) -> usize {
        self.params.iter().map(|(_, rows)| rows.len()).sum()
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LruStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub swaps: u64,
    /// Views larger than the whole budget: served, never cached.
    pub uncacheable: u64,
}

/// Byte-budgeted LRU of materialized tenants. Recency is a logical tick
/// bumped on every get/admit/swap — strictly increasing, so the eviction
/// victim (min last-used) is always unique and deterministic.
pub struct TenantLru {
    budget: usize,
    tick: u64,
    /// `(tenant, view, last_used_tick)` — unordered; linear scans are fine
    /// at the tenant counts a byte budget admits.
    entries: Vec<(String, Arc<TenantView>, u64)>,
    pub stats: LruStats,
}

impl TenantLru {
    pub fn new(budget_bytes: usize) -> TenantLru {
        TenantLru { budget: budget_bytes, tick: 0, entries: Vec::new(), stats: LruStats::default() }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    fn bump(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Cached view for `tenant`, bumping its recency. Records hit/miss.
    pub fn get(&mut self, tenant: &str) -> Option<Arc<TenantView>> {
        let tick = self.bump();
        match self.entries.iter_mut().find(|(t, _, _)| t == tenant) {
            Some((_, view, last)) => {
                *last = tick;
                self.stats.hits += 1;
                Some(Arc::clone(view))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// `true` without bumping recency (inspection only).
    pub fn contains(&self, tenant: &str) -> bool {
        self.entries.iter().any(|(t, _, _)| t == tenant)
    }

    /// Evict least-recently-used entries (never `keep`) until `extra` more
    /// bytes fit under the budget.
    fn evict_until_fits(&mut self, extra: usize, keep: Option<&str>) {
        while self.resident_bytes() + extra > self.budget {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter(|(_, (t, _, _))| Some(t.as_str()) != keep)
                .min_by_key(|(_, (_, _, last))| *last)
                .map(|(i, _)| i);
            match victim {
                Some(i) => {
                    self.entries.remove(i);
                    self.stats.evictions += 1;
                }
                None => break, // nothing evictable left
            }
        }
    }

    /// Cache a freshly materialized view, evicting LRU entries to fit. A
    /// view bigger than the entire budget is returned WITHOUT caching
    /// (`stats.uncacheable`) — the server still serves it, it just pays
    /// materialization per batch. If the tenant is already resident this
    /// degenerates to [`TenantLru::swap`].
    pub fn admit(&mut self, view: TenantView) -> Arc<TenantView> {
        if self.contains(view.tenant()) {
            return self.swap(view);
        }
        if view.bytes() > self.budget {
            self.stats.uncacheable += 1;
            return Arc::new(view);
        }
        self.evict_until_fits(view.bytes(), None);
        let tick = self.bump();
        let arc = Arc::new(view);
        self.entries.push((arc.tenant().to_string(), Arc::clone(&arc), tick));
        arc
    }

    /// Hot-swap: replace a resident tenant's view in place. The new view
    /// is already fully built (build-then-swap), in-flight holders of the
    /// old `Arc` keep a complete old version, and unrelated tenants are
    /// evicted only if the replacement is larger and the budget demands
    /// it. Absent or over-budget tenants fall back to [`TenantLru::admit`]
    /// semantics.
    pub fn swap(&mut self, view: TenantView) -> Arc<TenantView> {
        let Some(pos) = self.entries.iter().position(|(t, _, _)| t == view.tenant()) else {
            return self.admit(view);
        };
        if view.bytes() > self.budget {
            self.entries.remove(pos);
            self.stats.uncacheable += 1;
            return Arc::new(view);
        }
        let old_bytes = self.entries[pos].1.bytes();
        if view.bytes() > old_bytes {
            let keep = view.tenant().to_string();
            self.evict_until_fits(view.bytes() - old_bytes, Some(&keep));
        }
        let tick = self.bump();
        let arc = Arc::new(view);
        // position may have shifted if eviction removed earlier entries
        if let Some((_, slot_view, slot_tick)) =
            self.entries.iter_mut().find(|(t, _, _)| t == arc.tenant())
        {
            *slot_view = Arc::clone(&arc);
            *slot_tick = tick;
        }
        self.stats.swaps += 1;
        arc
    }

    /// Drop one tenant's view; `true` if it was resident.
    pub fn evict(&mut self, tenant: &str) -> bool {
        let before = self.entries.len();
        self.entries.retain(|(t, _, _)| t != tenant);
        before != self.entries.len()
    }

    pub fn resident(&self) -> usize {
        self.entries.len()
    }

    pub fn resident_bytes(&self) -> usize {
        self.entries.iter().map(|(_, v, _)| v.bytes()).sum()
    }

    /// Resident tenant names, sorted (inspection only, no recency bump).
    pub fn resident_tenants(&self) -> Vec<String> {
        let mut out: Vec<String> = self.entries.iter().map(|(t, _, _)| t.clone()).collect();
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::matrix::toy_params;
    use crate::serve::delta::synth_delta;
    use crate::serve::base_digest;

    fn view(base: &[Tensor], name: &str, seed: u64) -> TenantView {
        let dg = base_digest(base);
        TenantView::materialize(base, &synth_delta(base, name, dg, 2, seed)).unwrap()
    }

    #[test]
    fn view_matches_full_materialization_row_for_row() {
        let base = toy_params(5);
        let dg = base_digest(&base);
        let delta = synth_delta(&base, "t", dg, 2, 42);
        let v = TenantView::materialize(&base, &delta).unwrap();
        let dense = TenantView::full_materialize(&base, &delta).unwrap();
        for (pi, t) in base.iter().enumerate() {
            let ncols = *t.shape.last().unwrap_or(&1);
            let nrows = t.len() / ncols;
            for r in 0..nrows {
                let expect = &dense[pi].data[r * ncols..(r + 1) * ncols];
                match v.row(pi, r) {
                    Some(row) => assert_eq!(row, expect, "param {pi} row {r}"),
                    None => assert_eq!(
                        &t.data[r * ncols..(r + 1) * ncols],
                        expect,
                        "untouched param {pi} row {r} must equal base"
                    ),
                }
            }
        }
        // row-clustered deltas must not touch every row (the tenants/GB claim)
        let total_rows: usize = base
            .iter()
            .map(|t| t.len() / *t.shape.last().unwrap_or(&1))
            .sum();
        assert!(
            v.touched_rows() < total_rows,
            "view copies {} of {} rows — no byte savings",
            v.touched_rows(),
            total_rows
        );
        assert!(v.bytes() > 0);
    }

    #[test]
    fn lru_evicts_least_recently_used_deterministically() {
        let base = toy_params(5);
        let a = view(&base, "a", 1);
        let one = a.bytes();
        // budget fits exactly two toy views
        let mut lru = TenantLru::new(2 * one + 2);
        lru.admit(view(&base, "a", 1));
        lru.admit(view(&base, "b", 2));
        assert_eq!(lru.resident_tenants(), vec!["a", "b"]);
        // touch a, then admit c → b is the LRU victim
        assert!(lru.get("a").is_some());
        lru.admit(view(&base, "c", 3));
        assert_eq!(lru.resident_tenants(), vec!["a", "c"]);
        assert_eq!(lru.stats.evictions, 1);
        assert!(lru.get("b").is_none());
        assert_eq!(lru.stats.hits, 1);
        assert_eq!(lru.stats.misses, 1);
        // readmit b → a (older tick than c) goes
        lru.admit(view(&base, "b", 2));
        assert_eq!(lru.resident_tenants(), vec!["b", "c"]);
    }

    #[test]
    fn oversized_view_is_served_uncached() {
        let base = toy_params(5);
        let mut lru = TenantLru::new(8); // smaller than any view
        let arc = lru.admit(view(&base, "big", 1));
        assert_eq!(arc.tenant(), "big");
        assert_eq!(lru.resident(), 0);
        assert_eq!(lru.stats.uncacheable, 1);
    }

    #[test]
    fn hot_swap_replaces_in_place_without_evicting_others() {
        let base = toy_params(5);
        let one = view(&base, "a", 1).bytes();
        let mut lru = TenantLru::new(3 * one + 3);
        lru.admit(view(&base, "a", 1));
        let held = lru.get("a").unwrap(); // in-flight request holds v1
        lru.admit(view(&base, "b", 2));
        lru.admit(view(&base, "c", 3));
        let v1_probe = held.row(1, 0).map(<[f32]>::to_vec);
        lru.swap(view(&base, "a", 99));
        assert_eq!(lru.resident_tenants(), vec!["a", "b", "c"], "no unrelated eviction");
        assert_eq!(lru.stats.swaps, 1);
        assert_eq!(lru.stats.evictions, 0);
        // the held Arc still reads the complete old version
        assert_eq!(held.row(1, 0).map(<[f32]>::to_vec), v1_probe);
        // a fresh get sees the new version
        let fresh = lru.get("a").unwrap();
        let new_direct = view(&base, "a", 99);
        for pi in 0..base.len() {
            let ncols = *base[pi].shape.last().unwrap_or(&1);
            for r in 0..base[pi].len() / ncols {
                assert_eq!(
                    fresh.row(pi, r).map(<[f32]>::to_vec),
                    new_direct.row(pi, r).map(<[f32]>::to_vec)
                );
            }
        }
    }

    #[test]
    fn explicit_evict() {
        let base = toy_params(5);
        let mut lru = TenantLru::new(usize::MAX);
        lru.admit(view(&base, "a", 1));
        assert!(lru.evict("a"));
        assert!(!lru.evict("a"));
        assert_eq!(lru.resident(), 0);
    }
}

//! Batched multi-tenant inference over the shared base + overlay views.
//!
//! Requests are grouped by tenant so one overlay resolution amortizes
//! across the group, then fanned over `lift::engine::par_map` with the
//! PR-7 intra-matrix budget (`intra = (workers / n_groups).max(1)` chunks
//! per group). The forward pass is a pure function of `(model rows,
//! seed)`, evaluated per request with no cross-request state, so any
//! chunking of the batch — 1 worker or N — produces bit-identical outputs.
//!
//! The forward itself is the repo's synthetic serving workload: a
//! residual tanh-MLP walk over the preset's transformer matrices (wq →
//! wk → wv → wo, then wup/wdown, then final_norm). It touches every row
//! the deltas can touch — which is what the overlay bit-identity
//! acceptance needs — without pretending to be the trainer's full model.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::lift::engine::par_map;
use crate::runtime::manifest::PresetInfo;
use crate::tensor::Tensor;

use super::delta::{DeltaStore, TenantDelta};
use super::lru::{TenantLru, TenantView};

/// Row access over a (possibly overlaid) parameter set. `row` returns the
/// `row`-th length-`ncols` slice of parameter `param`; 1-D tensors are a
/// single row 0.
pub trait ModelRows: Sync {
    fn row(&self, param: usize, row: usize) -> &[f32];
}

/// The frozen base, no overlay.
pub struct BaseModel<'a> {
    pub base: &'a [Tensor],
}

impl ModelRows for BaseModel<'_> {
    fn row(&self, param: usize, row: usize) -> &[f32] {
        let t = &self.base[param];
        let ncols = *t.shape.last().unwrap_or(&1);
        &t.data[row * ncols..(row + 1) * ncols]
    }
}

/// Base + one tenant's row-granular overlay: touched rows come from the
/// view, everything else falls through to the base.
pub struct OverlayModel<'a> {
    pub base: &'a [Tensor],
    pub view: &'a TenantView,
}

impl ModelRows for OverlayModel<'_> {
    fn row(&self, param: usize, row: usize) -> &[f32] {
        self.view
            .row(param, row)
            .unwrap_or_else(|| BaseModel { base: self.base }.row(param, row))
    }
}

/// Parameter indices for the forward walk, resolved once from a preset's
/// `ParamInfo` names ("embed", "l{l}.{kind}", "final_norm").
pub struct ForwardPlan {
    pub embed: usize,
    /// Per layer: `[wq, wk, wv, wo, wup, wdown]` parameter indices.
    pub layers: Vec<[usize; 6]>,
    pub final_norm: Option<usize>,
    pub d: usize,
    pub ffn: usize,
    pub vocab: usize,
}

impl ForwardPlan {
    pub fn from_preset(preset: &PresetInfo) -> Result<ForwardPlan> {
        let by_name: BTreeMap<&str, usize> =
            preset.params.iter().enumerate().map(|(i, p)| (p.name.as_str(), i)).collect();
        let embed = *by_name
            .get("embed")
            .with_context(|| format!("preset '{}' has no 'embed' parameter", preset.name))?;
        anyhow::ensure!(
            preset.params[embed].shape.len() == 2,
            "preset '{}': embed must be 2-D",
            preset.name
        );
        let (vocab, d) = (preset.params[embed].shape[0], preset.params[embed].shape[1]);
        let mut layers = Vec::new();
        let mut ffn = preset.ffn;
        for l in 0.. {
            if !by_name.contains_key(format!("l{l}.wq").as_str()) {
                break;
            }
            let mut ids = [0usize; 6];
            for (slot, kind) in ["wq", "wk", "wv", "wo", "wup", "wdown"].iter().enumerate() {
                let name = format!("l{l}.{kind}");
                ids[slot] = *by_name.get(name.as_str()).with_context(|| {
                    format!("preset '{}': layer {l} has wq but no '{name}'", preset.name)
                })?;
            }
            let up_shape = &preset.params[ids[4]].shape;
            anyhow::ensure!(
                up_shape.len() == 2 && up_shape[0] == d,
                "preset '{}': l{l}.wup shape {:?} does not start at d={d}",
                preset.name,
                up_shape
            );
            ffn = up_shape[1];
            layers.push(ids);
        }
        anyhow::ensure!(
            !layers.is_empty(),
            "preset '{}' has no 'l0.wq' — nothing to serve",
            preset.name
        );
        let final_norm = by_name.get("final_norm").copied();
        Ok(ForwardPlan { embed, layers, final_norm, d, ffn, vocab })
    }
}

/// One request's pure forward: embed the seed-chosen token, walk every
/// layer's matrices with residual tanh mixes, scale by final_norm.
/// Deterministic per `(model, seed)`; allocation-light (two scratch
/// buffers).
pub fn forward_one<M: ModelRows + ?Sized>(model: &M, plan: &ForwardPlan, seed: u64) -> Vec<f32> {
    let token = (seed % plan.vocab as u64) as usize;
    let mut h: Vec<f32> = model.row(plan.embed, token).to_vec();
    let mut y = vec![0.0f32; plan.d];
    let mut u = vec![0.0f32; plan.ffn];
    for ids in &plan.layers {
        for &w in &ids[..4] {
            y.iter_mut().for_each(|v| *v = 0.0);
            for (i, &hi) in h.iter().enumerate() {
                let r = model.row(w, i);
                for j in 0..plan.d {
                    y[j] += hi * r[j];
                }
            }
            for j in 0..plan.d {
                h[j] += y[j].tanh();
            }
        }
        u.iter_mut().for_each(|v| *v = 0.0);
        for (i, &hi) in h.iter().enumerate() {
            let r = model.row(ids[4], i);
            for j in 0..plan.ffn {
                u[j] += hi * r[j];
            }
        }
        y.iter_mut().for_each(|v| *v = 0.0);
        for (i, &ui) in u.iter().enumerate() {
            let r = model.row(ids[5], i);
            let ut = ui.tanh();
            for j in 0..plan.d {
                y[j] += ut * r[j];
            }
        }
        for j in 0..plan.d {
            h[j] += y[j];
        }
    }
    if let Some(fnorm) = plan.final_norm {
        let r = model.row(fnorm, 0);
        for j in 0..plan.d {
            h[j] *= r[j];
        }
    }
    h
}

/// One synthetic inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub tenant: String,
    pub seed: u64,
}

/// The serving daemon's core: one resident base, a delta store, a
/// byte-budgeted LRU of materialized tenants, and a worker pool.
pub struct Server<'a> {
    base: &'a [Tensor],
    plan: ForwardPlan,
    store: DeltaStore,
    lru: TenantLru,
    workers: usize,
}

impl<'a> Server<'a> {
    /// Open (or create) the delta store at `dir`, pinned to this base's
    /// digest, with `budget_bytes` of overlay cache.
    pub fn new(
        base: &'a [Tensor],
        preset: &PresetInfo,
        dir: &Path,
        budget_bytes: usize,
        workers: usize,
    ) -> Result<Server<'a>> {
        let plan = ForwardPlan::from_preset(preset)?;
        let store = DeltaStore::open(dir, super::base_digest(base))?;
        Ok(Server {
            base,
            plan,
            store,
            lru: TenantLru::new(budget_bytes),
            workers: workers.max(1),
        })
    }

    pub fn store(&self) -> &DeltaStore {
        &self.store
    }

    pub fn lru(&self) -> &TenantLru {
        &self.lru
    }

    pub fn plan(&self) -> &ForwardPlan {
        &self.plan
    }

    /// The base's answer for a seed — what a tenant's output must differ
    /// from once its delta overlays anything the forward touches.
    pub fn base_forward(&self, seed: u64) -> Vec<f32> {
        forward_one(&BaseModel { base: self.base }, &self.plan, seed)
    }

    /// Resolve a tenant's view: LRU hit, else load-materialize-admit.
    fn view_for(&mut self, tenant: &str) -> Result<Arc<TenantView>> {
        if let Some(v) = self.lru.get(tenant) {
            return Ok(v);
        }
        let delta = self.store.load(tenant)?;
        let view = TenantView::materialize(self.base, &delta)?;
        Ok(self.lru.admit(view))
    }

    /// Serve a batch: group by tenant, resolve each group's overlay once
    /// (sequentially in sorted tenant order, so LRU mutation is a pure
    /// function of the batch), then fan request chunks over the pool.
    /// Outputs come back in request order, bit-identical at any worker
    /// count.
    pub fn handle_batch(&mut self, reqs: &[Request]) -> Result<Vec<Vec<f32>>> {
        let mut groups: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (i, r) in reqs.iter().enumerate() {
            groups.entry(r.tenant.as_str()).or_default().push(i);
        }
        let n_groups = groups.len().max(1);
        let intra = (self.workers / n_groups).max(1);
        let mut jobs: Vec<(Arc<TenantView>, Vec<usize>)> = Vec::new();
        for (tenant, idxs) in groups {
            let view = self.view_for(tenant)?;
            let per = idxs.len().div_ceil(intra);
            for chunk in idxs.chunks(per.max(1)) {
                jobs.push((Arc::clone(&view), chunk.to_vec()));
            }
        }
        let base = self.base;
        let plan = &self.plan;
        let done = par_map(self.workers, jobs, |_, (view, idxs)| {
            let model = OverlayModel { base, view: &view };
            idxs.iter()
                .map(|&i| (i, forward_one(&model, plan, reqs[i].seed)))
                .collect::<Vec<_>>()
        });
        let mut out = vec![Vec::new(); reqs.len()];
        for pair in done.into_iter().flatten() {
            out[pair.0] = pair.1;
        }
        Ok(out)
    }

    /// Register-or-update a tenant and, if it is resident, hot-swap its
    /// view: durable write first, new view fully built BEFORE the LRU
    /// `Arc` is replaced. In-flight batches keep the old `Arc`; unrelated
    /// tenants stay resident.
    pub fn hot_swap(&mut self, delta: &TenantDelta) -> Result<()> {
        self.store.register(delta)?;
        if self.lru.contains(&delta.tenant) {
            let view = TenantView::materialize(self.base, delta)?;
            self.lru.swap(view);
        }
        Ok(())
    }

    /// Drop a tenant entirely: delta file and any resident view.
    pub fn delete_tenant(&mut self, tenant: &str) -> Result<bool> {
        let existed = self.store.delete(tenant)?;
        self.lru.evict(tenant);
        Ok(existed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::matrix::{toy_params, toy_preset};
    use crate::serve::base_digest;
    use crate::serve::delta::synth_delta;

    #[test]
    fn plan_resolves_toy_preset() {
        let plan = ForwardPlan::from_preset(&toy_preset()).unwrap();
        assert_eq!(plan.layers.len(), 2);
        assert_eq!((plan.d, plan.ffn, plan.vocab), (16, 24, 32));
        assert!(plan.final_norm.is_some());
    }

    #[test]
    fn overlay_forward_differs_from_base_and_matches_dense() {
        let base = toy_params(9);
        let plan = ForwardPlan::from_preset(&toy_preset()).unwrap();
        let dg = base_digest(&base);
        let delta = synth_delta(&base, "t", dg, 2, 21);
        let view = TenantView::materialize(&base, &delta).unwrap();
        let dense = TenantView::full_materialize(&base, &delta).unwrap();
        for seed in [0u64, 7, 31] {
            let over = forward_one(&OverlayModel { base: &base, view: &view }, &plan, seed);
            let full = forward_one(&BaseModel { base: &dense }, &plan, seed);
            let plain = forward_one(&BaseModel { base: &base }, &plan, seed);
            assert_eq!(over, full, "overlay ≡ dense materialization, seed {seed}");
            assert_ne!(over, plain, "delta must change the output, seed {seed}");
        }
    }
}

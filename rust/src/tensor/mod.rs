//! Dense host tensor (f32, row-major) — the coordinator's working type.
//!
//! Heavy compute goes through XLA executables (runtime/); these host ops
//! exist for glue, masking, optimizer state manipulation, analyses on
//! small matrices, and as independent oracles in tests.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn randn(shape: &[usize], sigma: f32, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: rng.normal_vec(n, sigma),
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// (rows, cols) of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected 2-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        let (_, n) = self.dims2();
        self.data[i * n + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let (_, n) = self.dims2();
        self.data[i * n + j] = v;
    }

    /// Host matmul (naive ikj) — for small matrices and test oracles only;
    /// hot-path matmuls go through runtime::linalg.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, k) = self.dims2();
        let (k2, n) = other.dims2();
        assert_eq!(k, k2, "matmul {:?} x {:?}", self.shape, other.shape);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for l in 0..k {
                let a = self.data[i * k + l];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[l * n..(l + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Tensor::from_vec(&[m, n], out)
    }

    pub fn transpose(&self) -> Tensor {
        let (m, n) = self.dims2();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::from_vec(&[n, m], out)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// self += alpha * other
    pub fn add_scaled(&mut self, other: &Tensor, alpha: f32) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    pub fn frobenius(&self) -> f64 {
        crate::util::stats::l2_norm(&self.data)
    }

    /// Largest singular value via power iteration on W^T W (host).
    pub fn spectral_norm(&self, iters: usize, rng: &mut Rng) -> f32 {
        let (m, n) = self.dims2();
        let mut v = rng.normal_vec(n, 1.0);
        let mut tmp = vec![0.0f32; m];
        let mut sigma = 0.0f64;
        for _ in 0..iters {
            // tmp = W v
            for i in 0..m {
                let row = &self.data[i * n..(i + 1) * n];
                tmp[i] = row.iter().zip(&v).map(|(a, b)| a * b).sum();
            }
            // v = W^T tmp
            for x in v.iter_mut() {
                *x = 0.0;
            }
            for i in 0..m {
                let t = tmp[i];
                if t == 0.0 {
                    continue;
                }
                let row = &self.data[i * n..(i + 1) * n];
                for j in 0..n {
                    v[j] += row[j] * t;
                }
            }
            let norm = crate::util::stats::l2_norm(&v);
            sigma = norm.sqrt();
            if norm > 0.0 {
                let inv = (1.0 / norm) as f32;
                for x in v.iter_mut() {
                    *x *= inv;
                }
            }
        }
        sigma as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let eye = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn spectral_norm_of_diag() {
        let mut rng = Rng::new(2);
        let a = Tensor::from_vec(&[3, 3], vec![5.0, 0., 0., 0., 2.0, 0., 0., 0., 1.0]);
        let s = a.spectral_norm(50, &mut rng);
        assert!((s - 5.0).abs() < 1e-3, "s={s}");
    }

    #[test]
    fn add_scaled_and_sub() {
        let mut a = Tensor::full(&[4], 1.0);
        let b = Tensor::full(&[4], 2.0);
        a.add_scaled(&b, 0.5);
        assert_eq!(a.data, vec![2.0; 4]);
        assert_eq!(a.sub(&b).data, vec![0.0; 4]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        let _ = a.matmul(&b);
    }
}

//! LIFT: Low-rank Informed Sparse Fine-Tuning — full-system reproduction.
#![allow(clippy::needless_range_loop)]

pub mod analysis;
pub mod ckpt;
pub mod data;
pub mod exp;
pub mod lift;
pub mod model;
pub mod methods;
pub mod optim;
pub mod train;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

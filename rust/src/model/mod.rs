//! Model parameter store: init, checkpoints, layer taxonomy helpers.
//!
//! The actual compute graphs live in AOT artifacts (L2); this module owns
//! the host-side truth of the parameters between steps.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::manifest::PresetInfo;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Initialize parameters for a preset (LLaMA-style scaled init):
/// matrices N(0, 0.02), residual-output projections (wo, wdown) scaled by
/// 1/sqrt(2 * layers), norms = 1, embeddings N(0, 0.02).
pub fn init_params(preset: &PresetInfo, rng: &mut Rng) -> Vec<Tensor> {
    let resid_scale = 1.0 / ((2 * preset.layers) as f32).sqrt();
    preset
        .params
        .iter()
        .map(|p| {
            let mut r = rng.split(fxhash(&p.name));
            match p.kind() {
                "attn_norm" | "mlp_norm" | "final_norm" => Tensor::full(&p.shape, 1.0),
                "wo" | "wdown" => Tensor::randn(&p.shape, 0.02 * resid_scale, &mut r),
                _ => Tensor::randn(&p.shape, 0.02, &mut r),
            }
        })
        .collect()
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Indices of the PEFT-trainable matrices (wq..wdown), optionally filtered.
pub fn trainable_matrices(preset: &PresetInfo, mlp_only: bool) -> Vec<usize> {
    preset
        .params
        .iter()
        .enumerate()
        .filter(|(_, p)| p.is_matrix() && (!mlp_only || p.is_mlp()))
        .map(|(i, _)| i)
        .collect()
}

/// Matrices restricted to one layer-type kind (Fig. 11 component study).
pub fn matrices_of_kind(preset: &PresetInfo, kind: &str) -> Vec<usize> {
    preset
        .params
        .iter()
        .enumerate()
        .filter(|(_, p)| p.is_matrix() && p.kind() == kind)
        .map(|(i, _)| i)
        .collect()
}

const CKPT_MAGIC: &[u8; 8] = b"LIFTCKP1";

/// Save parameters as a simple binary checkpoint:
/// magic | n_tensors u32 | per tensor: ndim u32, dims u32..., f32 data (LE).
pub fn save_checkpoint(path: &Path, params: &[Tensor]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(CKPT_MAGIC)?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for t in params {
        f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        // f32 slice -> bytes
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
        };
        f.write_all(bytes)?;
    }
    Ok(())
}

pub fn load_checkpoint(path: &Path) -> Result<Vec<Tensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening checkpoint {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == CKPT_MAGIC, "bad checkpoint magic");
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    let n = u32::from_le_bytes(u32buf) as usize;
    anyhow::ensure!(n < 100_000, "implausible tensor count {n}");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        f.read_exact(&mut u32buf)?;
        let ndim = u32::from_le_bytes(u32buf) as usize;
        anyhow::ensure!(ndim <= 4, "implausible ndim {ndim}");
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            f.read_exact(&mut u32buf)?;
            shape.push(u32::from_le_bytes(u32buf) as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0.0f32; numel];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
        };
        f.read_exact(bytes)?;
        out.push(Tensor::from_vec(&shape, data));
    }
    Ok(out)
}

/// Verify loaded params against a preset's manifest spec.
pub fn check_params(preset: &PresetInfo, params: &[Tensor]) -> Result<()> {
    anyhow::ensure!(
        params.len() == preset.params.len(),
        "checkpoint has {} tensors, preset {} expects {}",
        params.len(),
        preset.name,
        preset.params.len()
    );
    for (t, info) in params.iter().zip(&preset.params) {
        anyhow::ensure!(
            t.shape == info.shape,
            "tensor {}: shape {:?} != {:?}",
            info.name,
            t.shape,
            info.shape
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn tiny_preset() -> PresetInfo {
        let j = r#"{"presets": {"t": {"d": 8, "layers": 2, "ffn": 16, "vocab": 32,
          "seq": 8, "batch": 2, "heads": 1, "params": [
            {"name": "embed", "shape": [32, 8]},
            {"name": "l0.attn_norm", "shape": [8]},
            {"name": "l0.wq", "shape": [8, 8]},
            {"name": "l0.wdown", "shape": [16, 8]},
            {"name": "l1.wup", "shape": [8, 16]},
            {"name": "final_norm", "shape": [8]}], "executables": {}}}}"#;
        Manifest::parse(j).unwrap().preset("t").unwrap().clone()
    }

    #[test]
    fn init_respects_kinds() {
        let p = tiny_preset();
        let mut rng = Rng::new(1);
        let params = init_params(&p, &mut rng);
        assert_eq!(params.len(), 6);
        // norms are ones
        assert!(params[1].data.iter().all(|&x| x == 1.0));
        assert!(params[5].data.iter().all(|&x| x == 1.0));
        // wdown has smaller scale than wq
        let std = |t: &Tensor| (t.data.iter().map(|x| x * x).sum::<f32>() / t.len() as f32).sqrt();
        assert!(std(&params[3]) < std(&params[2]));
        // deterministic given the same seed
        let params2 = init_params(&p, &mut Rng::new(1));
        assert_eq!(params[2], params2[2]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let p = tiny_preset();
        let mut rng = Rng::new(2);
        let params = init_params(&p, &mut rng);
        let path = std::env::temp_dir().join("lift_ckpt_test.bin");
        save_checkpoint(&path, &params).unwrap();
        let loaded = load_checkpoint(&path).unwrap();
        assert_eq!(params, loaded);
        check_params(&p, &loaded).unwrap();
    }

    #[test]
    fn trainable_sets() {
        let p = tiny_preset();
        let all = trainable_matrices(&p, false);
        assert_eq!(all, vec![2, 3, 4]);
        let mlp = trainable_matrices(&p, true);
        assert_eq!(mlp, vec![3, 4]);
        assert_eq!(matrices_of_kind(&p, "wq"), vec![2]);
    }

    #[test]
    fn checkpoint_rejects_garbage() {
        let path = std::env::temp_dir().join("lift_ckpt_garbage.bin");
        std::fs::write(&path, b"NOTACKPTxxxxxxxx").unwrap();
        assert!(load_checkpoint(&path).is_err());
    }
}

//! Layer-parallel engine: the worker pool behind every batched
//! per-matrix stage — mask selection (`select_all`), the exact top-r
//! decompositions of a refresh, and the batched optimizer step
//! (`optim::sparse::step_all` / `DenseAdamSet::step_all`), all of which
//! fan out through [`par_map`].
//!
//! # Threading model
//!
//! [`par_map`] runs one job per matrix across a pool of
//! `std::thread::scope` workers. Work is distributed by an atomic
//! cursor over the job list, so threads steal the next matrix as
//! they finish — no static partitioning, no idle tail when matrix sizes
//! are skewed. Jobs are consumed by value, which lets callers hand each
//! worker exclusive `&mut` access to disjoint state (the batched
//! optimizer step moves `&mut` parameter slices in; selection moves
//! shared references plus an exclusive warm-carrier slot per matrix).
//! Each worker owns one scratch arena ([`par_map_scratch`]) reused
//! across every job it steals — the steady-state loop allocates no
//! per-job O(n²) intermediates. `select_all`'s workers share one [`Linalg`]: its
//! compile cache is sharded-locked and executables are immutable `Arc`s,
//! so concurrent rank reductions only contend for the few microseconds
//! of a cache probe. Worker count comes from `LIFT_WORKERS` (or the
//! older `LIFT_MASK_WORKERS` alias), else `available_parallelism`, and
//! can be pinned per engine with [`MaskEngine::with_workers`].
//!
//! # Determinism contract
//!
//! Every batched stage is a pure function of its per-job inputs — never
//! of the worker count, the scheduling order, or which thread ran the
//! job. Running with 1 worker and with N workers is **bit-identical**
//! (asserted by `rust/tests/engine.rs`: masks for every `Selector` ×
//! `RankStrategy` including the exact top-r path, and weights + Adam
//! moments after multi-step `refresh_all`/`step_all` runs for every
//! `Method`). The ingredients:
//!
//! * **RNG-stream derivation**: each selection request gets its own
//!   generator, `stream_rng(seed, tag)` = `Rng::new(seed).split(tag)`, a
//!   pure function of the refresh seed and the request's stable tag
//!   (callers use the parameter index).
//!   No RNG state is shared across requests, so execution order cannot
//!   leak into the sampled values. The caller draws `seed` from its own
//!   RNG once per refresh, keeping successive refreshes decorrelated.
//! * **Deterministic kernels**: rank reduction runs through compiled
//!   executables whose results depend only on their inputs; the exact
//!   path's host `eigh::svd_topr` seeds its iteration block from a fixed
//!   constant (accuracy vs the full-spectrum oracle is bounded by
//!   `eigh::TOPR_SV_TOL` / `eigh::TOPR_RECON_SLACK`); and the host-side
//!   top-k resolves ties by index order.
//! * **Independent updates**: `step_all` jobs touch disjoint
//!   `(state, param, grad)` triples, so the fan-out is the sequential
//!   loop reordered — bit-identical for any worker count.
//! * **Intra-matrix tiles**: when the pool has more workers than
//!   in-flight matrices, the exact path's GEMMs split one matrix's
//!   output rows into disjoint tiles across the spare capacity
//!   (`util::gemm::*_par`). Tile boundaries never cross a summation
//!   chain, so any tiling — including none — produces the same bits.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::{select_indices_warm, LiftCfg, Selector};
use crate::runtime::Linalg;
use crate::tensor::Tensor;
use crate::util::eigh::{EighScratch, SubspaceWarm};
use crate::util::rng::Rng;

/// One matrix's selection job.
pub struct MaskRequest<'a> {
    /// Stable stream tag (callers use the parameter index). The mask for
    /// a request depends on its tag, never on its position in the batch.
    pub tag: u64,
    pub w: &'a Tensor,
    /// Needed by `Selector::GradMag` (and ignored otherwise).
    pub grad: Option<&'a Tensor>,
    /// Needed by `Selector::Movement` (and ignored otherwise).
    pub score: Option<&'a [f32]>,
    /// Trainable-parameter budget (top-k size).
    pub k: usize,
}

/// Thread-pool scheduler for batched principal-weight selection.
pub struct MaskEngine {
    la: Arc<Linalg>,
    workers: usize,
}

/// Worker count: `LIFT_WORKERS` if set (`LIFT_MASK_WORKERS` is honored
/// as a back-compat alias), else the machine's available parallelism,
/// else 1. An unparseable value is rejected WITH a warning naming it —
/// a typo'd `LIFT_WORKERS=all` must not silently fall through to full
/// machine parallelism. CI runs the test suite under both
/// `LIFT_WORKERS=1` and the default to catch any violation of the
/// determinism contract.
pub fn default_workers() -> usize {
    env_workers(|key| std::env::var(key).ok()).unwrap_or_else(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// The env-derived worker count, if any. Takes the lookup as a closure
/// so the parse/warn policy is unit-testable without racing on the
/// process environment.
fn env_workers(get: impl Fn(&str) -> Option<String>) -> Option<usize> {
    for key in ["LIFT_WORKERS", "LIFT_MASK_WORKERS"] {
        if let Some(v) = get(key) {
            match v.parse::<usize>() {
                Ok(n) => return Some(n.max(1)),
                Err(_) => log::warn!(
                    "ignoring {key}={v:?}: not a worker count (expected a positive integer)"
                ),
            }
        }
    }
    None
}

/// Deterministic parallel map: apply `f` to every job and return the
/// results in job order. `f(i, job)` must be a pure function of its
/// arguments; the atomic-cursor work stealing then guarantees the output
/// is bit-identical for any worker count. Jobs are consumed by value so
/// callers can move exclusive `&mut` borrows of disjoint state into the
/// pool (see `optim::sparse::step_all`).
pub fn par_map<T, R, F>(workers: usize, jobs: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    par_map_scratch(workers, jobs, || (), |i, job, _: &mut ()| f(i, job))
}

/// [`par_map`] with a per-worker scratch arena: each worker thread calls
/// `mk_scratch` ONCE and reuses the arena across every job it steals, so
/// per-job allocation churn (Gram matrices, iteration blocks, packing
/// buffers — see `util::eigh::EighScratch`) disappears from the steady
/// state. `f(i, job, scratch)` must treat the arena as uninitialized
/// workspace — results must be a pure function of `(i, job)` alone,
/// never of which jobs previously used the arena; under that contract
/// the output is bit-identical for any worker count (the determinism
/// suite runs every batched stage at 1 and N workers).
pub fn par_map_scratch<T, R, S, F>(
    workers: usize,
    jobs: Vec<T>,
    mk_scratch: impl Fn() -> S + Sync,
    f: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T, &mut S) -> R + Sync,
{
    let n_workers = workers.min(jobs.len()).max(1);
    if n_workers == 1 {
        let mut scratch = mk_scratch();
        return jobs
            .into_iter()
            .enumerate()
            .map(|(i, j)| f(i, j, &mut scratch))
            .collect();
    }
    // slot i holds the pending job, then its result; the cursor hands
    // each index to exactly one worker
    let slots: Vec<Mutex<(Option<T>, Option<R>)>> = jobs
        .into_iter()
        .map(|j| Mutex::new((Some(j), None)))
        .collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..n_workers {
            s.spawn(|| {
                // one arena per worker, reused across all stolen jobs
                let mut scratch = mk_scratch();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= slots.len() {
                        break;
                    }
                    let job = slots[i]
                        .lock()
                        .expect("par_map slot poisoned")
                        .0
                        .take()
                        .expect("par_map job taken twice");
                    let res = f(i, job, &mut scratch);
                    slots[i].lock().expect("par_map slot poisoned").1 = Some(res);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("par_map slot poisoned")
                .1
                .expect("worker left a slot unfilled")
        })
        .collect()
}

/// Fan a per-matrix update over the pool: each state (keyed by its
/// parameter index) gets exclusive `&mut` access to its tensor and a
/// shared view of its gradient. The single walk over `params` carves
/// disjoint mutable borrows, so the jobs can run on any worker without
/// aliasing; panics on duplicate or out-of-range parameter indices —
/// either would mean two jobs racing on one tensor (or a silently
/// dropped state). Backs `optim::sparse::step_all` and the S2FT
/// column-pack step.
pub fn par_over_params<S: Send>(
    states: Vec<(usize, S)>,
    params: &mut [crate::tensor::Tensor],
    grads: &[crate::tensor::Tensor],
    workers: usize,
    f: impl Fn(S, &mut crate::tensor::Tensor, &crate::tensor::Tensor) + Sync,
) {
    let n_states = states.len();
    assert_eq!(
        grads.len(),
        params.len(),
        "par_over_params: {} grads for {} params — gradient and parameter \
         slices must be parallel",
        grads.len(),
        params.len()
    );
    let mut by_param: std::collections::HashMap<usize, S> = states.into_iter().collect();
    assert_eq!(
        by_param.len(),
        n_states,
        "par_over_params: duplicate parameter index"
    );
    let jobs: Vec<(S, &mut Tensor, &Tensor)> = params
        .iter_mut()
        .enumerate()
        .filter_map(|(pi, p)| by_param.remove(&pi).map(|st| (st, p, &grads[pi])))
        .collect();
    assert!(
        by_param.is_empty(),
        "par_over_params: state references a parameter index out of range"
    );
    par_map(workers, jobs, |_, (st, p, g)| f(st, p, g));
}

/// Derive the independent RNG stream for `(seed, tag)`. Pure function
/// of its inputs; delegates to [`Rng::split`] so the codebase has one
/// canonical stream-derivation scheme.
pub fn stream_rng(seed: u64, tag: u64) -> Rng {
    Rng::new(seed).split(tag)
}

impl MaskEngine {
    pub fn new(la: Arc<Linalg>) -> MaskEngine {
        Self::with_workers(la, default_workers())
    }

    pub fn with_workers(la: Arc<Linalg>, workers: usize) -> MaskEngine {
        MaskEngine {
            la,
            workers: workers.max(1),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Compute the mask for every request. Identical output for any
    /// worker count (see the determinism contract above); errors are
    /// reported for the lowest-index failing request. One-shot callers'
    /// entry point — warm carriers are neither consumed nor produced
    /// (the first refresh of a run is always cold anyway).
    pub fn select_all(
        &self,
        sel: Selector,
        cfg: &LiftCfg,
        reqs: &[MaskRequest],
        seed: u64,
    ) -> Result<Vec<Vec<u32>>> {
        let mut warms: Vec<Option<SubspaceWarm>> = (0..reqs.len()).map(|_| None).collect();
        self.select_all_warm(sel, cfg, reqs, seed, &mut warms)
    }

    /// [`MaskEngine::select_all`] with per-matrix warm-start carriers —
    /// the steady-state refresh path. `warms[i]` seeds request `i`'s
    /// exact decomposition (when the selector/config route through the
    /// exact top-r path) and is overwritten with the carrier for the
    /// next refresh; carriers for other paths pass through untouched.
    /// Each job owns its carrier slot exclusively and every worker
    /// reuses one [`EighScratch`] arena across the jobs it steals, so
    /// the masks AND the updated carriers are bit-identical for any
    /// worker count — the carrier is part of the determinism contract
    /// (it is checkpointed and replayed by crash-resume).
    pub fn select_all_warm(
        &self,
        sel: Selector,
        cfg: &LiftCfg,
        reqs: &[MaskRequest],
        seed: u64,
        warms: &mut [Option<SubspaceWarm>],
    ) -> Result<Vec<Vec<u32>>> {
        assert_eq!(
            reqs.len(),
            warms.len(),
            "select_all_warm: {} requests vs {} warm slots",
            reqs.len(),
            warms.len()
        );
        let jobs: Vec<(&MaskRequest, &mut Option<SubspaceWarm>)> =
            reqs.iter().zip(warms.iter_mut()).collect();
        // leftover pool capacity fans INTO matrices: when there are more
        // workers than requests, each worker's arena carries an
        // intra-matrix budget and the exact path's GEMMs split their
        // output-row tiles across it. Bit-identical for any split by the
        // tile-ownership contract (util::gemm), so the 1w ≡ Nw promise
        // below is untouched.
        let intra = (self.workers / reqs.len().max(1)).max(1);
        let mk_scratch = || EighScratch::with_par_workers(intra);
        par_map_scratch(self.workers, jobs, mk_scratch, |_, (req, warm), scratch| {
            let mut rng = stream_rng(seed, req.tag);
            select_indices_warm(
                sel, &self.la, req.w, req.grad, req.score, req.k, cfg, &mut rng, warm, scratch,
            )
        })
        .into_iter()
        .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(workers: usize) -> MaskEngine {
        let la = Arc::new(Linalg::new(&xla::PjRtClient::cpu().unwrap()));
        MaskEngine::with_workers(la, workers)
    }

    fn requests(ws: &[Tensor], k: usize) -> Vec<MaskRequest<'_>> {
        ws.iter()
            .enumerate()
            .map(|(i, w)| MaskRequest {
                tag: i as u64,
                w,
                grad: None,
                score: None,
                k,
            })
            .collect()
    }

    #[test]
    fn par_map_preserves_order_and_moves_mut_jobs() {
        let mut data: Vec<Vec<u64>> = (0..16u64).map(|i| vec![i]).collect();
        let jobs: Vec<&mut Vec<u64>> = data.iter_mut().collect();
        let out = par_map(4, jobs, |i, v| {
            v.push(i as u64 * 10);
            v[0] * 100 + i as u64
        });
        let want: Vec<u64> = (0..16).map(|i| i * 100 + i).collect();
        assert_eq!(out, want, "results must be in job order");
        for (i, v) in data.iter().enumerate() {
            assert_eq!(v, &vec![i as u64, i as u64 * 10], "job {i} mutated once");
        }
        // single worker takes the sequential path, same results
        let mut data2: Vec<Vec<u64>> = (0..16u64).map(|i| vec![i]).collect();
        let jobs2: Vec<&mut Vec<u64>> = data2.iter_mut().collect();
        let out2 = par_map(1, jobs2, |i, v| {
            v.push(i as u64 * 10);
            v[0] * 100 + i as u64
        });
        assert_eq!(out2, want);
        assert_eq!(data2, data);
    }

    #[test]
    fn stream_rng_is_tag_keyed() {
        let a: Vec<u64> = (0..4).map(|_| stream_rng(7, 1).next_u64()).collect();
        assert!(a.windows(2).all(|w| w[0] == w[1]), "same (seed, tag) repeats");
        assert_ne!(stream_rng(7, 1).next_u64(), stream_rng(7, 2).next_u64());
        assert_ne!(stream_rng(7, 1).next_u64(), stream_rng(8, 1).next_u64());
    }

    #[test]
    fn parallel_equals_sequential_smoke() {
        let mut rng = Rng::new(3);
        let ws: Vec<Tensor> = (0..6)
            .map(|_| Tensor::randn(&[24, 18], 1.0, &mut rng))
            .collect();
        let cfg = LiftCfg {
            rank: 4,
            ..Default::default()
        };
        let seq = engine(1)
            .select_all(Selector::Lift, &cfg, &requests(&ws, 60), 99)
            .unwrap();
        let par = engine(4)
            .select_all(Selector::Lift, &cfg, &requests(&ws, 60), 99)
            .unwrap();
        assert_eq!(seq, par);
        assert!(seq.iter().all(|m| m.len() == 60));
    }

    #[test]
    fn masks_do_not_depend_on_batch_order() {
        let mut rng = Rng::new(5);
        let ws: Vec<Tensor> = (0..4)
            .map(|_| Tensor::randn(&[16, 12], 1.0, &mut rng))
            .collect();
        let cfg = LiftCfg {
            rank: 3,
            ..Default::default()
        };
        let eng = engine(2);
        let fwd = eng
            .select_all(Selector::Lift, &cfg, &requests(&ws, 30), 1)
            .unwrap();
        // same requests, reversed batch order, same tags
        let mut rev_reqs = requests(&ws, 30);
        rev_reqs.reverse();
        let mut rev = eng.select_all(Selector::Lift, &cfg, &rev_reqs, 1).unwrap();
        rev.reverse();
        assert_eq!(fwd, rev);
    }

    #[test]
    fn errors_surface_from_parallel_path() {
        let mut rng = Rng::new(7);
        let ws: Vec<Tensor> = (0..3)
            .map(|_| Tensor::randn(&[8, 8], 1.0, &mut rng))
            .collect();
        // GradMag without gradients must error, not hang or panic
        let cfg = LiftCfg::default();
        let err = engine(4).select_all(Selector::GradMag, &cfg, &requests(&ws, 10), 1);
        assert!(err.is_err());
    }

    #[test]
    fn env_workers_parses_warns_and_falls_through() {
        // closure-injected environment: no racing on the real process env
        let env = |pairs: &'static [(&'static str, &'static str)]| {
            move |key: &str| {
                pairs
                    .iter()
                    .find(|(k, _)| *k == key)
                    .map(|(_, v)| v.to_string())
            }
        };
        assert_eq!(env_workers(env(&[("LIFT_WORKERS", "3")])), Some(3));
        // back-compat alias, and primary key wins over it
        assert_eq!(env_workers(env(&[("LIFT_MASK_WORKERS", "5")])), Some(5));
        assert_eq!(
            env_workers(env(&[("LIFT_WORKERS", "2"), ("LIFT_MASK_WORKERS", "5")])),
            Some(2)
        );
        // zero clamps to one worker, never a zero-width pool
        assert_eq!(env_workers(env(&[("LIFT_WORKERS", "0")])), Some(1));
        // the parse-failure path: a typo'd value is rejected (warned),
        // not treated as unset-and-silently-full-parallelism...
        assert_eq!(env_workers(env(&[("LIFT_WORKERS", "all")])), None);
        // ...and falls through to the alias when that one parses
        assert_eq!(
            env_workers(env(&[("LIFT_WORKERS", "all"), ("LIFT_MASK_WORKERS", "4")])),
            Some(4)
        );
        assert_eq!(env_workers(env(&[])), None);
    }
}

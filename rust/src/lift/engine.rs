//! Layer-parallel mask engine: one batched, multi-threaded pass that
//! selects principal weights for every matrix of the model.
//!
//! # Threading model
//!
//! `select_all` fans the per-matrix pipeline (rank reduction → top-k →
//! optional block structuring; see `lift::select_indices`) across a pool
//! of `std::thread::scope` workers. Work is distributed by an atomic
//! cursor over the request list, so threads steal the next matrix as
//! they finish — no static partitioning, no idle tail when matrix sizes
//! are skewed. All workers share one [`Linalg`]: its compile cache is
//! sharded-locked and executables are immutable `Arc`s, so concurrent
//! rank reductions only contend for the few microseconds of a cache
//! probe. Worker count comes from `LIFT_MASK_WORKERS`, else
//! `available_parallelism`, and can be pinned per engine with
//! [`MaskEngine::with_workers`].
//!
//! # Determinism contract
//!
//! Masks are a pure function of `(seed, request.tag, request inputs,
//! selector, cfg)` — never of the worker count, the scheduling order, or
//! which thread ran the request. Selection with 1 worker and with N
//! workers is **bit-identical** (asserted by `rust/tests/engine.rs` for
//! every `Selector` × `RankStrategy`). Two ingredients make this hold:
//!
//! * **RNG-stream derivation**: each request gets its own generator,
//!   `stream_rng(seed, tag)` = `Rng::new(seed).split(tag)`, a pure
//!   function of the refresh seed and the request's stable tag (callers
//!   use the parameter index).
//!   No RNG state is shared across requests, so execution order cannot
//!   leak into the sampled values. The caller draws `seed` from its own
//!   RNG once per refresh, keeping successive refreshes decorrelated.
//! * **Deterministic kernels**: rank reduction runs through compiled
//!   executables whose results depend only on their inputs, and the
//!   host-side top-k resolves ties by index order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::{select_indices, LiftCfg, Selector};
use crate::runtime::Linalg;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One matrix's selection job.
pub struct MaskRequest<'a> {
    /// Stable stream tag (callers use the parameter index). The mask for
    /// a request depends on its tag, never on its position in the batch.
    pub tag: u64,
    pub w: &'a Tensor,
    /// Needed by `Selector::GradMag` (and ignored otherwise).
    pub grad: Option<&'a Tensor>,
    /// Needed by `Selector::Movement` (and ignored otherwise).
    pub score: Option<&'a [f32]>,
    /// Trainable-parameter budget (top-k size).
    pub k: usize,
}

/// Thread-pool scheduler for batched principal-weight selection.
pub struct MaskEngine {
    la: Arc<Linalg>,
    workers: usize,
}

/// Worker count: `LIFT_MASK_WORKERS` if set, else the machine's available
/// parallelism, else 1.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("LIFT_MASK_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Derive the independent RNG stream for `(seed, tag)`. Pure function
/// of its inputs; delegates to [`Rng::split`] so the codebase has one
/// canonical stream-derivation scheme.
pub fn stream_rng(seed: u64, tag: u64) -> Rng {
    Rng::new(seed).split(tag)
}

impl MaskEngine {
    pub fn new(la: Arc<Linalg>) -> MaskEngine {
        Self::with_workers(la, default_workers())
    }

    pub fn with_workers(la: Arc<Linalg>, workers: usize) -> MaskEngine {
        MaskEngine {
            la,
            workers: workers.max(1),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    fn select_one(
        &self,
        sel: Selector,
        cfg: &LiftCfg,
        req: &MaskRequest,
        seed: u64,
    ) -> Result<Vec<u32>> {
        let mut rng = stream_rng(seed, req.tag);
        select_indices(sel, &self.la, req.w, req.grad, req.score, req.k, cfg, &mut rng)
    }

    /// Compute the mask for every request. Identical output for any
    /// worker count (see the determinism contract above); errors are
    /// reported for the lowest-index failing request.
    pub fn select_all(
        &self,
        sel: Selector,
        cfg: &LiftCfg,
        reqs: &[MaskRequest],
        seed: u64,
    ) -> Result<Vec<Vec<u32>>> {
        let n_workers = self.workers.min(reqs.len()).max(1);
        if n_workers == 1 {
            return reqs
                .iter()
                .map(|r| self.select_one(sel, cfg, r, seed))
                .collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<Result<Vec<u32>>>>> =
            reqs.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..n_workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= reqs.len() {
                        break;
                    }
                    let res = self.select_one(sel, cfg, &reqs[i], seed);
                    *slots[i].lock().expect("mask slot poisoned") = Some(res);
                });
            }
        });
        let mut out = Vec::with_capacity(reqs.len());
        for slot in slots {
            let res = slot
                .into_inner()
                .expect("mask slot poisoned")
                .expect("worker left a slot unfilled");
            out.push(res?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(workers: usize) -> MaskEngine {
        let la = Arc::new(Linalg::new(&xla::PjRtClient::cpu().unwrap()));
        MaskEngine::with_workers(la, workers)
    }

    fn requests(ws: &[Tensor], k: usize) -> Vec<MaskRequest<'_>> {
        ws.iter()
            .enumerate()
            .map(|(i, w)| MaskRequest {
                tag: i as u64,
                w,
                grad: None,
                score: None,
                k,
            })
            .collect()
    }

    #[test]
    fn stream_rng_is_tag_keyed() {
        let a: Vec<u64> = (0..4).map(|_| stream_rng(7, 1).next_u64()).collect();
        assert!(a.windows(2).all(|w| w[0] == w[1]), "same (seed, tag) repeats");
        assert_ne!(stream_rng(7, 1).next_u64(), stream_rng(7, 2).next_u64());
        assert_ne!(stream_rng(7, 1).next_u64(), stream_rng(8, 1).next_u64());
    }

    #[test]
    fn parallel_equals_sequential_smoke() {
        let mut rng = Rng::new(3);
        let ws: Vec<Tensor> = (0..6)
            .map(|_| Tensor::randn(&[24, 18], 1.0, &mut rng))
            .collect();
        let cfg = LiftCfg {
            rank: 4,
            ..Default::default()
        };
        let seq = engine(1)
            .select_all(Selector::Lift, &cfg, &requests(&ws, 60), 99)
            .unwrap();
        let par = engine(4)
            .select_all(Selector::Lift, &cfg, &requests(&ws, 60), 99)
            .unwrap();
        assert_eq!(seq, par);
        assert!(seq.iter().all(|m| m.len() == 60));
    }

    #[test]
    fn masks_do_not_depend_on_batch_order() {
        let mut rng = Rng::new(5);
        let ws: Vec<Tensor> = (0..4)
            .map(|_| Tensor::randn(&[16, 12], 1.0, &mut rng))
            .collect();
        let cfg = LiftCfg {
            rank: 3,
            ..Default::default()
        };
        let eng = engine(2);
        let fwd = eng
            .select_all(Selector::Lift, &cfg, &requests(&ws, 30), 1)
            .unwrap();
        // same requests, reversed batch order, same tags
        let mut rev_reqs = requests(&ws, 30);
        rev_reqs.reverse();
        let mut rev = eng.select_all(Selector::Lift, &cfg, &rev_reqs, 1).unwrap();
        rev.reverse();
        assert_eq!(fwd, rev);
    }

    #[test]
    fn errors_surface_from_parallel_path() {
        let mut rng = Rng::new(7);
        let ws: Vec<Tensor> = (0..3)
            .map(|_| Tensor::randn(&[8, 8], 1.0, &mut rng))
            .collect();
        // GradMag without gradients must error, not hang or panic
        let cfg = LiftCfg::default();
        let err = engine(4).select_all(Selector::GradMag, &cfg, &requests(&ws, 10), 1);
        assert!(err.is_err());
    }
}

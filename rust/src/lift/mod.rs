//! The LIFT mask engine: principal-weight selection (the paper's §3.2).
//!
//! Pipeline per weight matrix W:
//!   1. rank-r approximation W' (randomized subspace iteration through XLA
//!      on the fast path; exact host top-r subspace iteration for the
//!      oracle, full Jacobi SVD only for the tail-component ablations),
//!   2. exact top-k on |W'| (quickselect threshold), giving flat indices,
//!   3. optional 4x4-block structuring (Table 17).
//!
//! Every alternative selection criterion the paper compares against
//! (weight magnitude, gradient magnitude, movement score, random) lives
//! here too, behind the same `Selector` interface, so Fig. 2/3 and the
//! ablations are one code path — including the layer-parallel batched
//! path in [`engine`], which fans selection across worker threads with a
//! bit-identical-to-sequential determinism contract. On the exact path,
//! spare pool capacity additionally fans *into* a matrix: the Gram /
//! apply / Rayleigh–Ritz products split their output rows into disjoint
//! tiles across idle workers (`util::gemm::*_par`, SIMD microkernels
//! underneath), without disturbing that contract — tile ownership is
//! deterministic and no summation chain crosses a tile.

pub mod engine;

pub use engine::{MaskEngine, MaskRequest};

use anyhow::Result;

use crate::runtime::Linalg;
use crate::tensor::Tensor;
use crate::util::eigh::{EighScratch, SubspaceWarm};
use crate::util::rng::Rng;
use crate::util::stats::topk_abs_threshold;

/// Which singular components the rank reduction keeps (Fig. 7b ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankStrategy {
    Largest,
    Smallest,
    Random,
    Hybrid,
}

/// Parameter-selection criteria compared in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selector {
    /// LIFT: top-|.| of the rank-r approximation.
    Lift,
    /// top-|W| on the raw weights
    WeightMag,
    /// top-|g| on the current gradient
    GradMag,
    /// movement score S = -sum w*g accumulated over steps
    Movement,
    /// uniform random
    Random,
}

#[derive(Clone, Copy, Debug)]
pub struct LiftCfg {
    /// LRA rank r of the approximation (paper's "LRA rank").
    pub rank: usize,
    /// power iterations for the randomized path
    pub power_iters: usize,
    /// oversampling columns
    pub oversample: usize,
    pub strategy: RankStrategy,
    /// use exact host SVD instead of randomized (ablations/oracle)
    pub exact: bool,
    /// structured selection in bxb blocks (Table 17: b = 4)
    pub block: usize,
    /// route the rank-reduce scan through the int8 quantized kernel
    /// tier (ISSUE 10; `LIFT_QSCAN=1` forces it on for a whole run).
    /// Lossy, under the `eigh::LIFT_QSCAN_TOL` mask-overlap contract —
    /// selection-only, training never reads quantized values.
    pub qscan: bool,
}

impl Default for LiftCfg {
    fn default() -> Self {
        LiftCfg {
            rank: 32,
            power_iters: 2,
            oversample: 8,
            strategy: RankStrategy::Largest,
            exact: false,
            block: 1,
            qscan: false,
        }
    }
}

/// Whether `LIFT_QSCAN` in the environment forces the quantized scan on
/// for every selection in the process (any non-empty value other than
/// `"0"` — same convention as `LIFT_NO_SIMD`). Cached once per process;
/// CI runs the whole suite once under `LIFT_QSCAN=1`.
pub fn qscan_forced() -> bool {
    static FORCED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FORCED.get_or_init(|| {
        std::env::var("LIFT_QSCAN")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    })
}

/// Process-wide count of NaN-poisoned-matrix warnings fired by
/// [`topk_indices`] — monotonic, so tests assert on deltas (e.g. the
/// engine's NaN-torture test proves the warning fires exactly once per
/// poisoned matrix per refresh, at any worker count).
pub fn nan_warning_count() -> u64 {
    NAN_WARNINGS.load(std::sync::atomic::Ordering::Relaxed)
}

static NAN_WARNINGS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Trainable-parameter budget for one (m, n) matrix at LoRA-rank
/// equivalence: k = r (m + n), capped at half the matrix (small presets).
pub fn budget_for(m: usize, n: usize, rank_equiv: usize) -> usize {
    (rank_equiv * (m + n)).min(m * n / 2).max(1)
}

/// Exact top-k flat indices of |values| (ties trimmed deterministically).
///
/// NaN policy (ISSUE 10): NaN entries rank *below every finite
/// magnitude* — a NaN-poisoned matrix logs one loud warning (counted in
/// [`nan_warning_count`]) and still returns exactly `k` indices, filled
/// from the finite entries first; NaN positions are appended (in index
/// order) only when fewer than `k` finite entries exist. The silent
/// `>= thr` under-selection the old filter allowed is gone.
pub fn topk_indices(values: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(values.len());
    if k == 0 {
        return vec![];
    }
    let n_nan = values.iter().filter(|v| v.is_nan()).count();
    if n_nan == 0 {
        let thr = topk_abs_threshold(values, k);
        let mut idx: Vec<u32> = (0..values.len() as u32)
            .filter(|&i| values[i as usize].abs() >= thr)
            .collect();
        if idx.len() > k {
            // trim ties at the threshold, keeping the largest magnitudes
            // (|v| of a finite value is finite, so total_cmp == numeric order)
            idx.sort_by(|&a, &b| {
                values[b as usize]
                    .abs()
                    .total_cmp(&values[a as usize].abs())
            });
            idx.truncate(k);
            idx.sort_unstable();
        }
        debug_assert_eq!(idx.len(), k);
        return idx;
    }
    NAN_WARNINGS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    log::warn!(
        "topk_indices: matrix is NaN-poisoned ({n_nan} NaN of {} entries, k = {k}); \
         NaN entries rank last — selection quality is degraded",
        values.len(),
    );
    let mut idx: Vec<u32> = (0..values.len() as u32).collect();
    // descending |v| with NaN (any sign) pinned last; ties and NaN runs
    // break by index, so the order is fully deterministic
    idx.sort_by(|&a, &b| {
        let (x, y) = (values[a as usize].abs(), values[b as usize].abs());
        match (x.is_nan(), y.is_nan()) {
            (true, true) => a.cmp(&b),
            (true, false) => std::cmp::Ordering::Greater,
            (false, true) => std::cmp::Ordering::Less,
            (false, false) => y.total_cmp(&x).then(a.cmp(&b)),
        }
    });
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// The rank-r approximation W' per the configured strategy. Cold-start
/// wrapper over [`rank_reduce_warm`] (fresh scratch, no carrier).
pub fn rank_reduce(
    la: &Linalg,
    w: &Tensor,
    cfg: &LiftCfg,
    rng: &mut Rng,
) -> Result<Tensor> {
    rank_reduce_warm(la, w, cfg, rng, &mut None, &mut EighScratch::new())
}

/// [`rank_reduce`] with a warm-start carrier slot and a reusable scratch
/// arena — the steady-state refresh path the layer-parallel engine
/// drives. On the exact `Largest` route the carrier seeds (and is
/// replaced by) the top-r subspace iteration (`eigh::svd_topr_warm`);
/// on the randomized and full-spectrum ablation routes it passes
/// through untouched (those paths have no persistent iteration block).
pub fn rank_reduce_warm(
    la: &Linalg,
    w: &Tensor,
    cfg: &LiftCfg,
    rng: &mut Rng,
    warm: &mut Option<SubspaceWarm>,
    scratch: &mut EighScratch,
) -> Result<Tensor> {
    let (m, n) = w.dims2();
    let minmn = m.min(n);
    let rank = cfg.rank.min(minmn);
    // Quantized scan tier: selection-only, so the flag lives on the
    // scratch arena and every svd_topr_warm this call reaches (exact
    // Largest here, or the randomized route's factor rotation inside
    // `Linalg::lowrank_approx_with`) sees the same setting.
    scratch.set_qscan(cfg.qscan || qscan_forced());
    if cfg.exact || cfg.strategy != RankStrategy::Largest {
        if cfg.strategy == RankStrategy::Largest {
            // the exact oracle only needs the leading subspace — top-r
            // subspace iteration instead of the full-spectrum Jacobi,
            // warm-started from the previous refresh of this matrix
            let (out, carrier) = crate::util::eigh::lowrank_approx_warm(
                &w.data,
                m,
                n,
                rank,
                warm.as_ref(),
                scratch,
            );
            *warm = carrier;
            return Ok(Tensor::from_vec(&[m, n], out));
        }
        // tail/random ablation strategies need the full spectrum
        let (u, s, vt) = crate::util::eigh::svd(&w.data, m, n);
        let comps: Vec<usize> = match cfg.strategy {
            RankStrategy::Largest => unreachable!("exact Largest returns via svd_topr above"),
            RankStrategy::Smallest => (minmn - rank..minmn).collect(),
            RankStrategy::Random => rng.sample_indices(minmn, rank),
            RankStrategy::Hybrid => {
                let half = rank / 2;
                let mut c: Vec<usize> = (0..half).collect();
                c.extend(minmn - (rank - half)..minmn);
                c
            }
        };
        let mut out = vec![0.0f32; m * n];
        for &c in &comps {
            for i in 0..m {
                let uis = u[i * minmn + c] * s[c];
                if uis == 0.0 {
                    continue;
                }
                let row = &vt[c * n..(c + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += uis * row[j];
                }
            }
        }
        Ok(Tensor::from_vec(&[m, n], out))
    } else {
        la.lowrank_approx_with(w, rank, cfg.power_iters, cfg.oversample, rng, scratch)
    }
}

/// LIFT principal-weight indices: rank-reduce, then top-k magnitude.
/// Cold-start wrapper over [`principal_indices_warm`].
pub fn principal_indices(
    la: &Linalg,
    w: &Tensor,
    k: usize,
    cfg: &LiftCfg,
    rng: &mut Rng,
) -> Result<Vec<u32>> {
    principal_indices_warm(la, w, k, cfg, rng, &mut None, &mut EighScratch::new())
}

/// [`principal_indices`] with warm carrier + scratch arena (the
/// engine's per-request path).
pub fn principal_indices_warm(
    la: &Linalg,
    w: &Tensor,
    k: usize,
    cfg: &LiftCfg,
    rng: &mut Rng,
    warm: &mut Option<SubspaceWarm>,
    scratch: &mut EighScratch,
) -> Result<Vec<u32>> {
    let wr = rank_reduce_warm(la, w, cfg, rng, warm, scratch)?;
    if cfg.block > 1 {
        Ok(block_topk(&wr, k, cfg.block))
    } else {
        Ok(topk_indices(&wr.data, k))
    }
}

/// Generic selection across all criteria (Fig. 2 / Fig. 3 comparisons).
/// `grad` is needed for GradMag, `score` for Movement. Cold-start
/// wrapper over [`select_indices_warm`].
#[allow(clippy::too_many_arguments)]
pub fn select_indices(
    sel: Selector,
    la: &Linalg,
    w: &Tensor,
    grad: Option<&Tensor>,
    score: Option<&[f32]>,
    k: usize,
    cfg: &LiftCfg,
    rng: &mut Rng,
) -> Result<Vec<u32>> {
    select_indices_warm(
        sel,
        la,
        w,
        grad,
        score,
        k,
        cfg,
        rng,
        &mut None,
        &mut EighScratch::new(),
    )
}

/// [`select_indices`] with warm carrier + scratch arena. Only the LIFT
/// selector's exact path consumes/produces carriers; every other
/// selector ignores both and behaves exactly as before.
#[allow(clippy::too_many_arguments)]
pub fn select_indices_warm(
    sel: Selector,
    la: &Linalg,
    w: &Tensor,
    grad: Option<&Tensor>,
    score: Option<&[f32]>,
    k: usize,
    cfg: &LiftCfg,
    rng: &mut Rng,
    warm: &mut Option<SubspaceWarm>,
    scratch: &mut EighScratch,
) -> Result<Vec<u32>> {
    match sel {
        Selector::Lift => principal_indices_warm(la, w, k, cfg, rng, warm, scratch),
        Selector::WeightMag => Ok(if cfg.block > 1 {
            block_topk(w, k, cfg.block)
        } else {
            topk_indices(&w.data, k)
        }),
        Selector::GradMag => {
            let g = grad.ok_or_else(|| anyhow::anyhow!("GradMag needs a gradient"))?;
            Ok(if cfg.block > 1 {
                block_topk(g, k, cfg.block)
            } else {
                topk_indices(&g.data, k)
            })
        }
        Selector::Movement => {
            let s = score.ok_or_else(|| anyhow::anyhow!("Movement needs scores"))?;
            Ok(topk_indices(s, k))
        }
        Selector::Random => {
            let mut idx: Vec<u32> = rng
                .sample_indices(w.len(), k.min(w.len()))
                .into_iter()
                .map(|i| i as u32)
                .collect();
            idx.sort_unstable();
            Ok(idx)
        }
    }
}

/// Structured top-k: score bxb blocks by sum |.|, take whole blocks until
/// the budget is filled (Table 17, LIFT_Structured).
pub fn block_topk(w: &Tensor, k: usize, b: usize) -> Vec<u32> {
    let (m, n) = w.dims2();
    let gm = m.div_ceil(b);
    let gn = n.div_ceil(b);
    let mut scores = vec![0.0f32; gm * gn];
    for i in 0..m {
        for j in 0..n {
            scores[(i / b) * gn + (j / b)] += w.data[i * n + j].abs();
        }
    }
    let n_blocks = k.div_ceil(b * b).min(gm * gn);
    let blocks = topk_indices(&scores, n_blocks);
    let mut idx = Vec::with_capacity(n_blocks * b * b);
    for &bi in &blocks {
        let (gi, gj) = ((bi as usize) / gn, (bi as usize) % gn);
        for i in gi * b..((gi + 1) * b).min(m) {
            for j in gj * b..((gj + 1) * b).min(n) {
                idx.push((i * n + j) as u32);
            }
        }
    }
    idx.sort_unstable();
    idx.truncate(k);
    idx
}

/// Overlap |a ∩ b| / |b| between two index sets (Fig. 17).
pub fn mask_overlap(a: &[u32], b: &[u32]) -> f64 {
    if b.is_empty() {
        return 0.0;
    }
    let set: std::collections::HashSet<u32> = a.iter().copied().collect();
    b.iter().filter(|i| set.contains(i)).count() as f64 / b.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linalg() -> Linalg {
        Linalg::new(&xla::PjRtClient::cpu().unwrap())
    }

    #[test]
    fn budget_caps() {
        assert_eq!(budget_for(128, 128, 16), 16 * 256);
        // capped at half the matrix
        assert_eq!(budget_for(16, 16, 128), 128);
        assert!(budget_for(1, 1, 1) >= 1);
    }

    #[test]
    fn topk_exact_count_with_ties() {
        let vals = vec![1.0f32, -1.0, 1.0, 0.5, 2.0, -2.0];
        let idx = topk_indices(&vals, 3);
        assert_eq!(idx.len(), 3);
        // the two 2.0-magnitude entries must be in
        assert!(idx.contains(&4) && idx.contains(&5));
    }

    #[test]
    fn topk_nan_policy_ranks_nan_last_and_warns_once() {
        // regression (ISSUE 10): the old `>= thr` filter silently
        // dropped NaN entries, returning fewer than k indices
        let vals = vec![1.0f32, f32::NAN, 3.0, -2.0, f32::NAN, 0.5];
        let before = nan_warning_count();
        let idx = topk_indices(&vals, 3);
        assert_eq!(nan_warning_count(), before + 1, "one warning per call");
        // 4 finite entries exist, so exactly k come back, all finite
        assert_eq!(idx, vec![0, 2, 3]);
        // asking for more than the finite count still yields k indices:
        // NaN positions fill the tail in index order
        let idx = topk_indices(&vals, 5);
        assert_eq!(idx, vec![0, 1, 2, 3, 5]);
        // -NaN ranks last too, and a clean matrix fires no warning
        let clean_before = nan_warning_count();
        let neg = vec![2.0f32, -f32::NAN, 1.0];
        assert_eq!(topk_indices(&neg, 2), vec![0, 2]);
        assert_eq!(nan_warning_count(), clean_before + 1);
        let fin = vec![2.0f32, -1.0, 1.0];
        assert_eq!(topk_indices(&fin, 2), vec![0, 1]);
        assert_eq!(nan_warning_count(), clean_before + 1);
    }

    #[test]
    fn topk_nan_order_is_deterministic() {
        // the NaN path sorts the whole matrix — pin that two runs (and
        // an all-NaN matrix) produce identical, index-ordered output
        let vals = vec![f32::NAN; 6];
        assert_eq!(topk_indices(&vals, 4), vec![0, 1, 2, 3]);
        let mixed = vec![1.0f32, f32::NAN, 1.0, f32::NAN];
        assert_eq!(topk_indices(&mixed, 3), topk_indices(&mixed, 3));
        assert_eq!(topk_indices(&mixed, 3), vec![0, 1, 2]);
    }

    #[test]
    fn qscan_selection_overlaps_f64_scan() {
        // LIFT_QSCAN_TOL contract at the selection level: the int8
        // scan's mask matches the f64 scan's on a low-rank fixture
        let la = linalg();
        let mut rng = Rng::new(29);
        let (m, n, r) = (48, 40, 4);
        let u = Tensor::randn(&[m, r], 1.0, &mut rng);
        let v = Tensor::randn(&[r, n], 1.0, &mut rng);
        let mut w = u.matmul(&v);
        w.add_scaled(&Tensor::randn(&[m, n], 1.0, &mut rng), 0.05);
        let k = budget_for(m, n, 4);
        let cfg = LiftCfg {
            rank: r,
            exact: true,
            ..Default::default()
        };
        let f64_mask = principal_indices(&la, &w, k, &cfg, &mut rng).unwrap();
        let qcfg = LiftCfg { qscan: true, ..cfg };
        let q_mask = principal_indices(&la, &w, k, &qcfg, &mut rng).unwrap();
        assert_eq!(q_mask.len(), k);
        let ov = mask_overlap(&f64_mask, &q_mask);
        assert!(
            ov >= crate::util::eigh::LIFT_QSCAN_TOL,
            "quantized-vs-f64 mask overlap {ov} below contract"
        );
    }

    #[test]
    fn principal_indices_match_exact_oracle() {
        let la = linalg();
        let mut rng = Rng::new(11);
        // matrix with a strong low-rank component
        let (m, n, r) = (64, 48, 4);
        let u = Tensor::randn(&[m, r], 1.0, &mut rng);
        let v = Tensor::randn(&[r, n], 1.0, &mut rng);
        let mut w = u.matmul(&v);
        w.add_scaled(&Tensor::randn(&[m, n], 1.0, &mut rng), 0.05);
        let k = 300;
        let cfg = LiftCfg {
            rank: r,
            ..Default::default()
        };
        let fast = principal_indices(&la, &w, k, &cfg, &mut rng).unwrap();
        let exact_cfg = LiftCfg {
            exact: true,
            ..cfg
        };
        let exact = principal_indices(&la, &w, k, &exact_cfg, &mut rng).unwrap();
        let ov = mask_overlap(&fast, &exact);
        assert!(ov > 0.9, "randomized vs exact overlap {ov}");
    }

    #[test]
    fn lift_mask_differs_from_weight_magnitude() {
        // the paper's core observation: principal weights != largest weights
        let la = linalg();
        let mut rng = Rng::new(13);
        let (m, n) = (64, 64);
        let mut w = Tensor::randn(&[m, n], 1.0, &mut rng);
        // spike a few individual entries (largest |W| but not low-rank)
        for _ in 0..50 {
            let i = rng.below(m * n);
            w.data[i] = 8.0;
        }
        let k = 200;
        let cfg = LiftCfg {
            rank: 4,
            ..Default::default()
        };
        let lift = principal_indices(&la, &w, k, &cfg, &mut rng).unwrap();
        let wm = topk_indices(&w.data, k);
        let ov = mask_overlap(&wm, &lift);
        assert!(ov < 0.9, "LIFT should not equal weight-mag (overlap {ov})");
    }

    #[test]
    fn strategies_differ() {
        let la = linalg();
        let mut rng = Rng::new(17);
        let w = Tensor::randn(&[32, 24], 1.0, &mut rng);
        let k = 100;
        let mut mk = |strategy| {
            let cfg = LiftCfg {
                rank: 6,
                strategy,
                exact: true,
                ..Default::default()
            };
            principal_indices(&la, &w, k, &cfg, &mut rng).unwrap()
        };
        let largest = mk(RankStrategy::Largest);
        let smallest = mk(RankStrategy::Smallest);
        assert!(mask_overlap(&largest, &smallest) < 0.8);
    }

    #[test]
    fn block_structured_selection() {
        let mut rng = Rng::new(19);
        let w = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let idx = block_topk(&w, 64, 4);
        assert_eq!(idx.len(), 64);
        // indices come in full 4x4 blocks: every index's block must have
        // all 16 members present
        let set: std::collections::HashSet<u32> = idx.iter().copied().collect();
        for &i in &idx {
            let (r, c) = ((i / 16) as usize, (i % 16) as usize);
            let (br, bc) = (r / 4 * 4, c / 4 * 4);
            for dr in 0..4 {
                for dc in 0..4 {
                    let j = ((br + dr) * 16 + bc + dc) as u32;
                    assert!(set.contains(&j), "block of {i} missing {j}");
                }
            }
        }
    }

    #[test]
    fn selectors_respect_budget() {
        let la = linalg();
        let mut rng = Rng::new(23);
        let w = Tensor::randn(&[20, 30], 1.0, &mut rng);
        let g = Tensor::randn(&[20, 30], 1.0, &mut rng);
        let score: Vec<f32> = (0..600).map(|i| i as f32).collect();
        let cfg = LiftCfg::default();
        for sel in [
            Selector::Lift,
            Selector::WeightMag,
            Selector::GradMag,
            Selector::Movement,
            Selector::Random,
        ] {
            let idx =
                select_indices(sel, &la, &w, Some(&g), Some(&score), 64, &cfg, &mut rng).unwrap();
            assert_eq!(idx.len(), 64, "{sel:?}");
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "{sel:?} sorted+unique");
        }
        // movement picks the top-scoring tail
        let idx = select_indices(
            Selector::Movement,
            &la,
            &w,
            None,
            Some(&score),
            4,
            &cfg,
            &mut rng,
        )
        .unwrap();
        assert_eq!(idx, vec![596, 597, 598, 599]);
    }
}

//! Shared experiment harness: pretrain-once, fine-tune-many machinery,
//! plus the sequential-vs-parallel speedup measurements (the ISSUE-1
//! mask-refresh row and the ISSUE-2 exact-refresh / step-all rows).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::Result;

use crate::data::tasks::{TaskMixSource, TaskSet};
use crate::data::{CorpusGen, TaskFamily};
use crate::lift::engine::MaskEngine;
use crate::lift::{budget_for, LiftCfg, MaskRequest, Selector};
use crate::methods::{make_method, Scope};
use crate::runtime::model_exec::ModelExec;
use crate::runtime::{Linalg, Runtime};
use crate::tensor::Tensor;
use crate::train::{eval, pretrain, train, TrainCfg, TrainLog};
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;

pub fn default_pretrain_steps(preset: &str) -> usize {
    // sized so each preset sees enough tokens to memorize its KG tier
    // (fact-recall >> chance); see EXPERIMENTS.md §Setup
    match preset {
        "tiny" => 1500,
        "small" => 2500,
        "base" => 1200,
        _ => 300,
    }
}

/// Per-method default learning rates (searched once; see EXPERIMENTS.md).
pub fn default_lr(method: &str) -> f32 {
    match method {
        "full" => 3e-4,
        "lora" | "dora" | "pissa" | "spectral" => 1e-3,
        "s2ft" => 5e-4,
        _ => 1e-3, // sparse family
    }
}

/// Shared state across runs inside one experiment invocation.
pub struct ExpEnv {
    pub rt: Runtime,
    pub fast: bool,
    pub results_dir: PathBuf,
    execs: BTreeMap<String, Rc<ModelExec>>,
    pretrained: BTreeMap<String, Vec<Tensor>>,
}

impl ExpEnv {
    pub fn new(args: &Args) -> Result<ExpEnv> {
        Ok(ExpEnv {
            rt: Runtime::from_default()?,
            fast: args.bool("fast", false),
            results_dir: PathBuf::from(args.str("results-dir", "results")),
            execs: BTreeMap::new(),
            pretrained: BTreeMap::new(),
        })
    }

    pub fn exec(&mut self, preset: &str) -> Result<Rc<ModelExec>> {
        if let Some(e) = self.execs.get(preset) {
            return Ok(e.clone());
        }
        let e = Rc::new(ModelExec::load(&self.rt, preset)?);
        self.execs.insert(preset.to_string(), e.clone());
        Ok(e)
    }

    /// Pretrained base parameters for a preset (cached in runs/ on disk
    /// and in memory for this invocation).
    pub fn pretrained(&mut self, preset: &str) -> Result<Vec<Tensor>> {
        if let Some(p) = self.pretrained.get(preset) {
            return Ok(p.clone());
        }
        let exec = self.exec(preset)?;
        // --fast shrinks fine-tunes, not the base model: reuse the cached
        // full pretrain if present, otherwise fall back to a short one
        let full_steps = default_pretrain_steps(preset);
        let full_path = pretrain::runs_dir().join(format!(
            "{preset}_pretrain_s{full_steps}_seed1.ckpt"
        ));
        let steps = if self.fast && !full_path.exists() {
            full_steps / 3
        } else {
            full_steps
        };
        let params = pretrain::ensure_pretrained(&self.rt, &exec, steps, 1)?;
        self.pretrained.insert(preset.to_string(), params.clone());
        Ok(params)
    }

    pub fn world(&mut self, preset: &str) -> Result<CorpusGen> {
        Ok(pretrain::world(self.exec(preset)?.as_ref()))
    }

    pub fn csv(&self, name: &str, header: &[&str]) -> Result<CsvWriter> {
        CsvWriter::create(&self.results_dir, name, header)
    }
}

/// One fine-tuning configuration.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub preset: String,
    pub families: Vec<TaskFamily>,
    pub steps: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub seed: u64,
}

impl RunSpec {
    pub fn new(preset: &str, families: &[TaskFamily], fast: bool) -> RunSpec {
        RunSpec {
            preset: preset.to_string(),
            families: families.to_vec(),
            steps: if fast { 100 } else { 400 },
            n_train: if fast { 500 } else { 2000 },
            n_test: if fast { 60 } else { 120 },
            seed: 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct MethodSpec {
    pub name: String,
    pub rank: usize,
    pub lra_rank: usize,
    pub interval: usize,
    pub lr: f32,
    pub scope: Scope,
}

impl MethodSpec {
    pub fn new(name: &str, rank: usize) -> MethodSpec {
        MethodSpec {
            name: name.to_string(),
            rank,
            lra_rank: rank,
            interval: 100,
            lr: default_lr(name),
            scope: Scope::default(),
        }
    }
}

/// Outcome of one fine-tune + eval run.
pub struct FtOutcome {
    pub label: String,
    /// accuracy per family, in `families` order
    pub accs: Vec<f64>,
    pub avg: f64,
    pub log: TrainLog,
    pub trainable: usize,
    pub opt_bytes: usize,
    /// (before, after) parameters when requested (analysis experiments)
    pub params: Option<(Vec<Tensor>, Vec<Tensor>)>,
}

/// Fine-tune `method` from the preset's pretrained base on a mixture of
/// `families`, then evaluate each family's test split.
pub fn run_ft(
    env: &mut ExpEnv,
    spec: &RunSpec,
    method_spec: &MethodSpec,
    keep_params: bool,
) -> Result<FtOutcome> {
    let base = env.pretrained(&spec.preset)?;
    let mut out = run_ft_from(env, spec, method_spec, base.clone())?;
    if !keep_params {
        out.params = None;
    } else if let Some(p) = out.params.as_mut() {
        p.0 = base;
    }
    Ok(out)
}

/// Like `run_ft` but starting from caller-supplied parameters (e.g. an
/// instruction-capable intermediate checkpoint, Fig. 4). Always keeps
/// (start, end) params in the outcome.
pub fn run_ft_from(
    env: &mut ExpEnv,
    spec: &RunSpec,
    method_spec: &MethodSpec,
    base: Vec<Tensor>,
) -> Result<FtOutcome> {
    let exec = env.exec(&spec.preset)?;
    let corpus = env.world(&spec.preset)?;
    let sets: Vec<TaskSet> = spec
        .families
        .iter()
        .map(|&f| {
            TaskSet::generate(
                f,
                &corpus.vocab,
                &corpus.kg,
                spec.n_train,
                spec.n_test,
                spec.seed,
            )
        })
        .collect();
    let mut src = TaskMixSource {
        sets: sets.clone(),
        batch: exec.preset.batch,
        seq: exec.preset.seq,
    };
    let mut params = base.clone();
    let mut ctx = pretrain::make_ctx(&env.rt, &exec, spec.seed ^ 0xabcd);
    let lift_cfg = LiftCfg {
        rank: method_spec.lra_rank,
        ..Default::default()
    };
    let mut method = make_method(
        &method_spec.name,
        method_spec.rank,
        lift_cfg,
        method_spec.interval,
        method_spec.scope.clone(),
    )?;
    let cfg = TrainCfg {
        steps: spec.steps,
        lr: method_spec.lr,
        warmup_frac: 0.03,
        log_every: 0,
        seed: spec.seed,
        ..Default::default()
    };
    let log = train(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg)?;
    let mut accs = Vec::with_capacity(sets.len());
    for set in &sets {
        accs.push(eval::accuracy(&exec, &params, &set.test)?);
    }
    let avg = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
    log::info!(
        "[{}] {} r={} avg={:.2} ({:.0}s)",
        spec.preset,
        method.name(),
        method_spec.rank,
        avg,
        log.seconds
    );
    Ok(FtOutcome {
        label: method.name(),
        accs,
        avg,
        log,
        trainable: method.trainable(),
        opt_bytes: method.opt_bytes(),
        params: Some((base, params)),
    })
}

/// One tiny-preset layer's trainable-matrix shapes (wq/wk/wv/wo `d x d`,
/// wup `d x ffn`, wdown `ffn x d`). Shared by the bench, the quickstart
/// selftest, and the speedup measurement so a preset change is edited in
/// one place.
pub fn tiny_layer_shapes() -> [(usize, usize); 6] {
    let (d, ffn) = (128, 352);
    [(d, d), (d, d), (d, d), (d, d), (d, ffn), (ffn, d)]
}

/// Weight-only mask requests over caller-owned tensors: `tag` = index,
/// `k` = the LoRA-rank-equivalent budget.
pub fn mask_requests(ws: &[Tensor], rank_equiv: usize) -> Vec<MaskRequest<'_>> {
    ws.iter()
        .enumerate()
        .map(|(i, w)| {
            let (m, n) = w.dims2();
            MaskRequest {
                tag: i as u64,
                w,
                grad: None,
                score: None,
                k: budget_for(m, n, rank_equiv),
            }
        })
        .collect()
}

/// Measured sequential-vs-parallel wall clock of one batched stage
/// (mask refresh, exact refresh, or the batched optimizer step).
#[derive(Clone, Debug)]
pub struct Speedup {
    pub label: &'static str,
    pub workers: usize,
    pub matrices: usize,
    pub seq_s: f64,
    pub par_s: f64,
    pub speedup: f64,
}

impl Speedup {
    /// One printable results row (the "measured, not asserted" line).
    pub fn row(&self) -> String {
        format!(
            "{} {:>2} matrices | seq {:>8.3}s | {}w {:>8.3}s | speedup {:.2}x",
            self.label, self.matrices, self.seq_s, self.workers, self.par_s, self.speedup
        )
    }
}

/// Time a full LIFT mask refresh (randomized rank reduction) — the
/// ISSUE-1 acceptance row.
pub fn measure_mask_refresh(
    la: &Arc<Linalg>,
    shapes: &[(usize, usize)],
    lra_rank: usize,
    rank_equiv: usize,
    workers: usize,
    reps: usize,
) -> Result<Speedup> {
    let cfg = LiftCfg {
        rank: lra_rank,
        ..Default::default()
    };
    measure_refresh("mask_refresh", la, shapes, &cfg, rank_equiv, workers, reps)
}

/// Time a full *exact-path* refresh (host top-r subspace decompositions
/// fanned across the pool) — the ISSUE-2 `[exact-svd]` acceptance row.
pub fn measure_exact_refresh(
    la: &Arc<Linalg>,
    shapes: &[(usize, usize)],
    lra_rank: usize,
    rank_equiv: usize,
    workers: usize,
    reps: usize,
) -> Result<Speedup> {
    let cfg = LiftCfg {
        rank: lra_rank,
        exact: true,
        ..Default::default()
    };
    measure_refresh("exact_refresh", la, shapes, &cfg, rank_equiv, workers, reps)
}

/// Shared refresh timing over synthetic preset-shaped matrices,
/// sequential (1 worker) vs layer-parallel (`workers`). Best-of-`reps`
/// per side to damp scheduler noise; both sides produce bit-identical
/// masks (the determinism tests assert this; here it is debug-checked).
fn measure_refresh(
    label: &'static str,
    la: &Arc<Linalg>,
    shapes: &[(usize, usize)],
    cfg: &LiftCfg,
    rank_equiv: usize,
    workers: usize,
    reps: usize,
) -> Result<Speedup> {
    let mut rng = Rng::new(0x5eed_11f7);
    let ws: Vec<Tensor> = shapes
        .iter()
        .map(|&(m, n)| Tensor::randn(&[m, n], 0.05, &mut rng))
        .collect();
    let reqs = mask_requests(&ws, rank_equiv);
    let seed = 0xa5ce_17u64;
    let time_side = |n_workers: usize| -> Result<(f64, Vec<Vec<u32>>)> {
        let engine = MaskEngine::with_workers(la.clone(), n_workers);
        // warm the compile caches so both sides time execution, not builds
        let mut masks = engine.select_all(Selector::Lift, cfg, &reqs, seed)?;
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = std::time::Instant::now();
            masks = engine.select_all(Selector::Lift, cfg, &reqs, seed)?;
            best = best.min(t0.elapsed().as_secs_f64());
        }
        Ok((best, masks))
    };
    let (seq_s, seq_masks) = time_side(1)?;
    let (par_s, par_masks) = time_side(workers.max(1))?;
    debug_assert_eq!(seq_masks, par_masks, "parallel masks diverged");
    Ok(Speedup {
        label,
        workers: workers.max(1),
        matrices: shapes.len(),
        seq_s,
        par_s,
        speedup: seq_s / par_s.max(1e-12),
    })
}

/// Time the batched sparse-Adam step (`optim::sparse::step_all`) over
/// synthetic preset-shaped matrices, sequential (1 worker) vs
/// layer-parallel — the ISSUE-2 `[step-all]` acceptance row. Each timed
/// rep runs `inner_steps` consecutive batched steps (each spawns its own
/// scoped pool, as the trainer does); best-of-`reps` per side. Both
/// sides must produce bit-identical weights (debug-checked here,
/// asserted by the determinism suite).
pub fn measure_step_all(
    shapes: &[(usize, usize)],
    rank_equiv: usize,
    workers: usize,
    reps: usize,
    inner_steps: usize,
) -> Result<Speedup> {
    use crate::optim::{sparse, AdamCfg, SparseAdam};
    let mut rng = Rng::new(0x57e9_0a11);
    let params: Vec<Tensor> = shapes
        .iter()
        .map(|&(m, n)| Tensor::randn(&[m, n], 0.05, &mut rng))
        .collect();
    let grads: Vec<Tensor> = shapes
        .iter()
        .map(|&(m, n)| Tensor::randn(&[m, n], 0.02, &mut rng))
        .collect();
    let states: Vec<(usize, SparseAdam)> = shapes
        .iter()
        .enumerate()
        .map(|(i, &(m, n))| {
            let k = budget_for(m, n, rank_equiv);
            let mut idx: Vec<u32> = rng
                .sample_indices(m * n, k)
                .into_iter()
                .map(|x| x as u32)
                .collect();
            idx.sort_unstable();
            (i, SparseAdam::new(idx, AdamCfg::default()))
        })
        .collect();
    let time_side = |n_workers: usize| -> (f64, Vec<Tensor>) {
        let mut best = f64::INFINITY;
        let mut out = params.clone();
        for _ in 0..reps.max(1) {
            let mut st = states.clone();
            let mut ps = params.clone();
            let t0 = std::time::Instant::now();
            for _ in 0..inner_steps.max(1) {
                sparse::step_all(&mut st, &mut ps, &grads, 1e-3, n_workers);
            }
            let dt = t0.elapsed().as_secs_f64();
            if dt < best {
                best = dt;
                out = ps;
            }
        }
        (best, out)
    };
    let (seq_s, seq_params) = time_side(1);
    let (par_s, par_params) = time_side(workers.max(1));
    debug_assert_eq!(seq_params, par_params, "parallel step diverged");
    Ok(Speedup {
        label: "step_all",
        workers: workers.max(1),
        matrices: shapes.len(),
        seq_s,
        par_s,
        speedup: seq_s / par_s.max(1e-12),
    })
}

/// Time cold vs warm-started exact refreshes over a drifting steady
/// state — the `[warm-refresh]` acceptance row of the hot-loop
/// overhaul. Each matrix first runs a cold refresh (producing its
/// carrier), then drifts slightly (like `interval` optimizer steps
/// between refreshes); the timed comparison is a full cold re-refresh
/// of the drifted model vs a carrier-seeded warm one, both through one
/// reusable scratch arena. `seq_s` holds the cold time and `par_s` the
/// warm time, so `speedup` reads as cold/warm.
pub fn measure_warm_refresh(
    shapes: &[(usize, usize)],
    lra_rank: usize,
    reps: usize,
) -> Result<Speedup> {
    use crate::util::eigh::{lowrank_approx_warm, EighScratch, SubspaceWarm};
    let mut rng = Rng::new(0x3a9d_cafe);
    let ws: Vec<Tensor> = shapes
        .iter()
        .map(|&(m, n)| Tensor::randn(&[m, n], 0.05, &mut rng))
        .collect();
    let mut scratch = EighScratch::new();
    // the "previous refresh": cold decompositions yielding the carriers
    let carriers: Vec<Option<SubspaceWarm>> = ws
        .iter()
        .map(|w| {
            let (m, n) = w.dims2();
            lowrank_approx_warm(&w.data, m, n, lra_rank, None, &mut scratch).1
        })
        .collect();
    // drift every matrix a little, as interval optimizer steps would
    let drifted: Vec<Tensor> = ws
        .iter()
        .map(|w| {
            let mut d = w.clone();
            d.add_scaled(&Tensor::randn(&w.shape, 0.001, &mut rng), 1.0);
            d
        })
        .collect();
    let mut time_side = |warm: bool| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = std::time::Instant::now();
            for (i, w) in drifted.iter().enumerate() {
                let (m, n) = w.dims2();
                let seed = if warm { carriers[i].as_ref() } else { None };
                let _ = lowrank_approx_warm(&w.data, m, n, lra_rank, seed, &mut scratch);
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let cold_s = time_side(false);
    let warm_s = time_side(true);
    Ok(Speedup {
        label: "warm_refresh",
        workers: 1,
        matrices: shapes.len(),
        seq_s: cold_s,
        par_s: warm_s,
        speedup: cold_s / warm_s.max(1e-12),
    })
}

/// Time one fixed-shape f64 GEMM with the SIMD microkernels pinned off
/// vs the runtime-detected dispatch — the `[gemm-simd]` row. `seq_s`
/// holds the scalar time and `par_s` the SIMD time, so `speedup` reads
/// scalar/simd. The row is ALWAYS emitted: on a host without AVX2 (or
/// under `LIFT_NO_SIMD=1`) both sides run the scalar kernel and the
/// ratio sits near 1.0x — keeping the label in `BENCH_trajectory.json`
/// so the `--check` gate's vanished-row detection never trips on
/// heterogeneous runners. The absolute >=1.5x floor is applied by the
/// bench only when `gemm::simd_enabled()` reports the SIMD path live.
pub fn measure_gemm_simd(reps: usize) -> Speedup {
    use crate::util::gemm;
    let (m, k, n) = (256usize, 320usize, 256usize);
    let mut rng = Rng::new(0x51_3d_ca11);
    let a: Vec<f64> = (0..m * k).map(|_| rng.normal() as f64).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.normal() as f64).collect();
    let mut c_scalar = vec![0.0f64; m * n];
    let mut c_simd = vec![0.0f64; m * n];
    let time = |use_simd: bool, c: &mut [f64]| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = std::time::Instant::now();
            gemm::matmul_f64_with_simd(&a, &b, m, k, n, c, use_simd);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let scalar_s = time(false, &mut c_scalar);
    // simd_enabled() (not raw `true`) so LIFT_NO_SIMD pins both sides
    // scalar and the row honestly reads ~1.0x
    let simd_s = time(gemm::simd_enabled(), &mut c_simd);
    // the determinism contract, spot-checked where it is being timed
    debug_assert!(
        c_scalar.iter().zip(&c_simd).all(|(x, y)| x.to_bits() == y.to_bits()),
        "scalar and SIMD kernels diverged"
    );
    Speedup {
        label: "gemm_simd",
        workers: 1,
        matrices: 1,
        seq_s: scalar_s,
        par_s: simd_s,
        speedup: scalar_s / simd_s.max(1e-12),
    }
}

/// Time one large f64 GEMM serial vs intra-matrix-parallel (output-row
/// tiles over the engine pool) — the `[gemm-par]` row. The shape sits
/// above the kernels' fan-out threshold so the parallel side actually
/// tiles; like `[gemm-simd]`, the row is always emitted (a 1-worker
/// host reads ~1.0x) so the trajectory label stays present everywhere.
pub fn measure_gemm_par(workers: usize, reps: usize) -> Speedup {
    use crate::util::gemm;
    let nsz = 512usize; // 512^3 = 134M muladds, well past PAR_MIN_MULADDS
    let mut rng = Rng::new(0x9a27_111e);
    let a: Vec<f64> = (0..nsz * nsz).map(|_| rng.normal() as f64).collect();
    let b: Vec<f64> = (0..nsz * nsz).map(|_| rng.normal() as f64).collect();
    let mut c_seq = vec![0.0f64; nsz * nsz];
    let mut c_par = vec![0.0f64; nsz * nsz];
    let time = |w: usize, c: &mut [f64]| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = std::time::Instant::now();
            gemm::matmul_f64_par(&a, &b, nsz, nsz, nsz, c, w);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let seq_s = time(1, &mut c_seq);
    let par_s = time(workers.max(1), &mut c_par);
    debug_assert!(
        c_seq.iter().zip(&c_par).all(|(x, y)| x.to_bits() == y.to_bits()),
        "tiled GEMM diverged from serial"
    );
    Speedup {
        label: "gemm_par",
        workers: workers.max(1),
        matrices: 1,
        seq_s,
        par_s,
        speedup: seq_s / par_s.max(1e-12),
    }
}

/// Time one fixed-shape Gram build in f64 vs the int8 blockwise
/// quantized tier (`gram_q8`) — the `[gemm-q]` row backing the qscan
/// feature (ISSUE 10). `seq_s` holds the f64 time and `par_s` the
/// quantized time, so `speedup` reads f64/q8. Like `[gemm-simd]`, the
/// row is ALWAYS emitted so the trajectory label stays present on every
/// runner (under LIFT_NO_SIMD both tiers run their scalar kernels and
/// the ratio is whatever the autovectorizer makes of 8x narrower
/// operands); the bench applies the absolute `--check` floor only where
/// the SIMD path is live. Before timing, the quantized Gram is checked
/// against
/// the f64 Gram entrywise (the LIFT_QSCAN_TOL overlap contract's
/// numerical root), so the bench cannot report a speedup from a kernel
/// that drifted.
pub fn measure_gemm_q(reps: usize) -> Speedup {
    use crate::util::gemm;
    let (m, n) = (320usize, 256usize);
    let mut rng = Rng::new(0x9c_a11_0b5);
    let a: Vec<f32> = (0..m * n).map(|_| rng.normal() * 0.05).collect();
    let mut pack: Vec<f64> = Vec::new();
    let mut qpack = gemm::QuantMat::default();
    let mut g_f64 = vec![0.0f64; n * n];
    let mut g_q8 = vec![0.0f64; n * n];
    gemm::gram_f64(&a, m, n, &mut pack, &mut g_f64);
    gemm::gram_q8(&a, m, n, &mut pack, &mut qpack, &mut g_q8);
    // blockwise int8 keeps every Gram entry within a small relative
    // error of f64 — catch kernel drift where it is being timed
    let scale = g_f64.iter().fold(0.0f64, |s, x| s.max(x.abs())).max(1e-30);
    let worst = g_f64
        .iter()
        .zip(&g_q8)
        .fold(0.0f64, |w, (x, y)| w.max((x - y).abs() / scale));
    debug_assert!(worst < 0.05, "quantized Gram drifted: rel err {worst:.4}");
    let time = |quant: bool| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = std::time::Instant::now();
            if quant {
                gemm::gram_q8(&a, m, n, &mut pack, &mut qpack, &mut g_q8);
            } else {
                gemm::gram_f64(&a, m, n, &mut pack, &mut g_f64);
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let f64_s = time(false);
    let q8_s = time(true);
    Speedup {
        label: "gemm_q",
        workers: 1,
        matrices: 1,
        seq_s: f64_s,
        par_s: q8_s,
        speedup: f64_s / q8_s.max(1e-12),
    }
}

/// Time per-tenant overlay-apply (row-granular `serve::TenantView`
/// materialization) vs full tenant materialization (dense base clone +
/// scatter) — the `[serve]` acceptance row. `seq_s` holds the full-copy
/// time and `par_s` the overlay time, so `speedup` reads full/overlay.
/// The ratio is an algorithmic invariant (row-clustered deltas touch a
/// small fraction of base rows, and the view copies only those), not a
/// host feature, so like `[gemm-simd]` the row is ALWAYS emitted and the
/// trajectory label stays present on every runner. Also returns
/// `(view_bytes, dense_bytes)` per tenant so callers can report
/// tenants/GB honestly from the same measurement.
pub fn measure_serve_overlay(reps: usize) -> Result<(Speedup, usize, usize)> {
    use crate::serve::{base_digest, synth_delta, TenantView};
    let mut rng = Rng::new(0x7e4a_9001);
    // two tiny-preset layers' worth of matrices — the same shapes every
    // other bench row uses, so rows are comparable across sections
    let base: Vec<Tensor> = tiny_layer_shapes()
        .iter()
        .chain(tiny_layer_shapes().iter())
        .map(|&(m, n)| Tensor::randn(&[m, n], 0.05, &mut rng))
        .collect();
    let delta = synth_delta(&base, "bench", base_digest(&base), 8, 0xbe7c);
    // correctness before timing: the view must agree with the dense copy
    // on every touched row and fall through to base elsewhere
    let view = TenantView::materialize(&base, &delta)?;
    let dense = TenantView::full_materialize(&base, &delta)?;
    for (pi, t) in base.iter().enumerate() {
        let ncols = *t.shape.last().unwrap_or(&1);
        for r in 0..t.len() / ncols {
            let expect = &dense[pi].data[r * ncols..(r + 1) * ncols];
            match view.row(pi, r) {
                Some(row) => anyhow::ensure!(row == expect, "overlay row {pi}/{r} diverged"),
                None => anyhow::ensure!(
                    &t.data[r * ncols..(r + 1) * ncols] == expect,
                    "untouched row {pi}/{r} modified by full materialization"
                ),
            }
        }
    }
    let view_bytes = view.bytes();
    let dense_bytes = base.iter().map(|t| t.len() * 4).sum::<usize>();
    let time = |full: bool| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = std::time::Instant::now();
            if full {
                let _ = std::hint::black_box(TenantView::full_materialize(&base, &delta));
            } else {
                let _ = std::hint::black_box(TenantView::materialize(&base, &delta));
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let full_s = time(true);
    let overlay_s = time(false);
    Ok((
        Speedup {
            label: "serve_overlay",
            workers: 1,
            matrices: base.len(),
            seq_s: full_s,
            par_s: overlay_s,
            speedup: full_s / overlay_s.max(1e-12),
        },
        view_bytes,
        dense_bytes,
    ))
}

/// Evaluate a family suite on given params (e.g. source-domain retention).
pub fn eval_suite(
    env: &mut ExpEnv,
    preset: &str,
    families: &[TaskFamily],
    params: &[Tensor],
    n_test: usize,
    seed: u64,
) -> Result<Vec<f64>> {
    let exec = env.exec(preset)?;
    let corpus = env.world(preset)?;
    families
        .iter()
        .map(|&f| {
            let set = TaskSet::generate(f, &corpus.vocab, &corpus.kg, 1, n_test, seed);
            eval::accuracy(&exec, params, &set.test)
        })
        .collect()
}

//! Experiment runners — one per paper table/figure (DESIGN.md §5 index).
//!
//! Every runner prints paper-shaped rows and writes `results/<id>.csv`.
//! `--fast` shrinks steps/samples/seeds for smoke runs; full settings are
//! what EXPERIMENTS.md records.

pub mod ablations;
pub mod figures;
pub mod grid;
pub mod harness;
pub mod lease;
pub mod matrix;
pub mod memory_fig;
pub mod perturb_fig;
pub mod retention;
pub mod tables;
pub mod torture;
pub mod toy;

use anyhow::Result;

use crate::util::cli::Args;

pub use harness::{default_pretrain_steps, ExpEnv, MethodSpec, RunSpec};

pub type Runner = fn(&mut harness::ExpEnv, &Args) -> Result<()>;

/// (id, description) — the regeneration index for the paper's evaluation.
pub const REGISTRY: &[(&str, &str)] = &[
    ("table1", "commonsense reasoning, 8 tasks x methods (Tab. 1)"),
    ("table2", "arithmetic reasoning, 7 tasks x methods (Tab. 2)"),
    ("table3", "GLUE-analog NLU, 8 tasks x methods (Tab. 3)"),
    ("table4", "GPQA-analog: LIFT vs Full FT, 2 presets (Tab. 4)"),
    ("table8", "rank search, commonsense (Tab. 8)"),
    ("table9", "rank search, arithmetic (Tab. 9)"),
    ("table10", "rank search, NLU (Tab. 10)"),
    ("table11", "arithmetic on the small preset (Tab. 11)"),
    ("table12", "code-gen analog: pass@1 / pass@10 (Tab. 12)"),
    ("table13", "StrategyQA-analog (Tab. 13)"),
    ("table14", "LIFT vs SpIEL vs Full FT on GSM8K-analog (Tab. 14)"),
    ("table15", "LIFT vs SIFT vs Full FT on NLU (Tab. 15)"),
    ("table16", "LIFT_MLP memory-saving variant (Tab. 16)"),
    ("table17", "structured 4x4 LIFT vs baselines (Tab. 17)"),
    ("fig2", "noise on selected params: ppl / recall / accuracy (Fig. 2)"),
    ("fig3", "selection-metric shootout on GSM8K-analog (Fig. 3)"),
    ("fig4", "learning vs forgetting: target + source domains (Fig. 4/10)"),
    ("fig5", "weight-update magnitude distributions (Fig. 5)"),
    ("fig6", "memory breakdown on real 7B/8B shapes (Fig. 6)"),
    ("fig7a", "mask update-interval ablation (Fig. 7a)"),
    ("fig7b", "rank-reduction strategy ablation (Fig. 7b)"),
    ("fig8", "random-matrix spectral/frobenius deltas (Fig. 8)"),
    ("fig9", "per-layer spectral-norm delta after noise (Fig. 9)"),
    ("fig11", "single-layer-type fine-tuning (Fig. 11)"),
    ("fig12", "eigenspace alignment per layer type (Fig. 12)"),
    ("fig13", "rank of the update matrix per layer type (Fig. 13)"),
    ("fig14", "two-layer toy regression study (Fig. 14, §G.5)"),
    ("fig15", "training-loss curves of all methods (Fig. 15)"),
    ("fig16", "LRA-rank x selected-rank heatmap (Fig. 16)"),
    ("fig17", "LIFT vs weight-magnitude mask overlap (Fig. 17)"),
];

pub fn run(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| args.str("id", ""));
    anyhow::ensure!(
        REGISTRY.iter().any(|(r, _)| *r == id),
        "unknown experiment '{id}' — see `lift list-exp`"
    );
    let mut env = harness::ExpEnv::new(args)?;
    let t0 = std::time::Instant::now();
    let result = match id.as_str() {
        "table1" => tables::table1(&mut env, args),
        "table2" => tables::table2(&mut env, args),
        "table3" => tables::table3(&mut env, args),
        "table4" => tables::table4(&mut env, args),
        "table8" => tables::rank_search(&mut env, args, "table8"),
        "table9" => tables::rank_search(&mut env, args, "table9"),
        "table10" => tables::rank_search(&mut env, args, "table10"),
        "table11" => tables::table11(&mut env, args),
        "table12" => tables::table12(&mut env, args),
        "table13" => tables::table13(&mut env, args),
        "table14" => tables::table14(&mut env, args),
        "table15" => tables::table15(&mut env, args),
        "table16" => tables::table16(&mut env, args),
        "table17" => tables::table17(&mut env, args),
        "fig2" => perturb_fig::fig2(&mut env, args),
        "fig3" => figures::fig3(&mut env, args),
        "fig4" => figures::fig4(&mut env, args),
        "fig5" => figures::fig5(&mut env, args),
        "fig6" => memory_fig::fig6(&mut env, args),
        "fig7a" => ablations::fig7a(&mut env, args),
        "fig7b" => ablations::fig7b(&mut env, args),
        "fig8" => perturb_fig::fig8(&mut env, args),
        "fig9" => perturb_fig::fig9(&mut env, args),
        "fig11" => ablations::fig11(&mut env, args),
        "fig12" => figures::fig12_13(&mut env, args, true),
        "fig13" => figures::fig12_13(&mut env, args, false),
        "fig14" => toy::fig14(&mut env, args),
        "fig15" => figures::fig15(&mut env, args),
        "fig16" => ablations::fig16(&mut env, args),
        "fig17" => ablations::fig17(&mut env, args),
        _ => unreachable!(),
    };
    log::info!("exp {id} finished in {:.1}s", t0.elapsed().as_secs_f64());
    result
}

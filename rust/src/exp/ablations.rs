//! Ablations: update interval (Fig. 7a), rank-reduction strategy (Fig. 7b),
//! layer-type restriction (Fig. 11), LRA-rank heatmap (Fig. 16), mask
//! overlap vs weight magnitude (Fig. 17).

use anyhow::Result;

use super::harness::*;
use crate::data::tasks::ARITH;
use crate::data::TaskFamily;
use crate::lift::{self, LiftCfg, RankStrategy};
use crate::methods::Scope;
use crate::util::cli::Args;

pub fn fig7a(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let intervals: Vec<usize> = if env.fast {
        vec![25, 100, 0]
    } else {
        vec![25, 50, 100, 200, 0] // 0 = never refresh
    };
    let mut csv = env.csv("fig7a", &["interval", "acc"])?;
    println!("\n== Fig 7a: mask update interval (GSM8K-analog) ==");
    println!("{:<10} {:>8}", "interval", "acc");
    // Full FT baseline for the dashed line in the paper
    let spec = RunSpec::new(&preset, &[TaskFamily::GsmHard], env.fast);
    let base = run_ft(env, &spec, &MethodSpec::new("full", 32), false)?;
    println!("{:<10} {:>8.2}", "full-ft", base.avg);
    csv.row(&["full".into(), format!("{:.3}", base.avg)])?;
    for &iv in &intervals {
        let mut ms = MethodSpec::new("lift", 32);
        ms.interval = iv;
        let out = run_ft(env, &spec, &ms, false)?;
        let name = if iv == 0 { "never".to_string() } else { iv.to_string() };
        println!("{:<10} {:>8.2}", name, out.avg);
        csv.row(&[name, format!("{:.3}", out.avg)])?;
    }
    println!("(expected: medium interval best; all above the baseline)");
    Ok(())
}

pub fn fig7b(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let strategies = [
        ("largest", RankStrategy::Largest),
        ("random", RankStrategy::Random),
        ("smallest", RankStrategy::Smallest),
        ("hybrid", RankStrategy::Hybrid),
    ];
    let mut csv = env.csv("fig7b", &["strategy", "avg"])?;
    println!("\n== Fig 7b: rank-reduction strategies (7 arithmetic tasks) ==");
    println!("{:<10} {:>8}", "strategy", "avg");
    for (name, strat) in strategies {
        let spec = RunSpec::new(&preset, &ARITH, env.fast);
        let exec = env.exec(&preset)?;
        let base = env.pretrained(&preset)?;
        let corpus = env.world(&preset)?;
        // run via a custom SparseFt with the given strategy
        let mut sets = Vec::new();
        for &f in &ARITH {
            sets.push(crate::data::tasks::TaskSet::generate(
                f,
                &corpus.vocab,
                &corpus.kg,
                spec.n_train,
                spec.n_test,
                spec.seed,
            ));
        }
        let mut src = crate::data::tasks::TaskMixSource {
            sets: sets.clone(),
            batch: exec.preset.batch,
            seq: exec.preset.seq,
        };
        let mut params = base.clone();
        let mut ctx = crate::train::pretrain::make_ctx(&env.rt, &exec, spec.seed);
        let cfg_l = LiftCfg {
            rank: 32,
            strategy: strat,
            ..Default::default()
        };
        let mut method = crate::methods::sparse_ft::SparseFt::new(
            &format!("LIFT[{name}]"),
            lift::Selector::Lift,
            32,
            cfg_l,
            100,
            Scope::default(),
        );
        let tcfg = crate::train::TrainCfg {
            steps: spec.steps,
            lr: default_lr("lift"),
            warmup_frac: 0.03,
            log_every: 0,
            seed: spec.seed,
            ..Default::default()
        };
        crate::train::train(&exec, &mut src, &mut method, &mut ctx, &mut params, &tcfg)?;
        let mut accs = Vec::new();
        for set in &sets {
            accs.push(crate::train::eval::accuracy(&exec, &params, &set.test)?);
        }
        let avg = crate::util::stats::mean(&accs);
        println!("{name:<10} {avg:>8.2}");
        csv.row(&[name.to_string(), format!("{avg:.3}")])?;
    }
    println!("(expected: largest >> random/hybrid > smallest)");
    Ok(())
}

pub fn fig11(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let kinds = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];
    let mut csv = env.csv("fig11", &["kind", "avg"])?;
    println!("\n== Fig 11: LIFT restricted to one layer type (arithmetic avg) ==");
    println!("{:<8} {:>8}", "kind", "avg");
    for kind in kinds {
        let spec = RunSpec::new(&preset, &ARITH, env.fast);
        let mut ms = MethodSpec::new("lift", 64);
        ms.scope = Scope {
            mlp_only: false,
            kind: Some(kind.to_string()),
        };
        let out = run_ft(env, &spec, &ms, false)?;
        println!("{kind:<8} {:>8.2}", out.avg);
        csv.row(&[kind.to_string(), format!("{:.3}", out.avg)])?;
    }
    println!("(expected: value/up/down >> query/key)");
    Ok(())
}

pub fn fig16(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let ranks: Vec<usize> = if env.fast {
        vec![8, 32]
    } else {
        vec![8, 16, 32, 64]
    };
    let mut csv = env.csv("fig16", &["lra_rank", "selected_rank", "avg"])?;
    println!("\n== Fig 16: LRA rank x selected rank heatmap (arith avg) ==");
    print!("{:<10}", "lra\\sel");
    for r in &ranks {
        print!("{r:>8}");
    }
    println!();
    for &lra in &ranks {
        print!("{lra:<10}");
        for &sel in &ranks {
            let spec = RunSpec::new(&preset, &ARITH, env.fast);
            let mut ms = MethodSpec::new("lift", sel);
            ms.lra_rank = lra;
            let out = run_ft(env, &spec, &ms, false)?;
            print!("{:>8.2}", out.avg);
            csv.row(&[
                lra.to_string(),
                sel.to_string(),
                format!("{:.3}", out.avg),
            ])?;
        }
        println!();
    }
    println!("(expected: best cells near the diagonal lra ~ selected)");
    Ok(())
}

pub fn fig17(env: &mut ExpEnv, args: &Args) -> Result<()> {
    // no training: masks on the pretrained model
    let preset = args.str("preset", "tiny");
    let base = env.pretrained(&preset)?;
    let exec = env.exec(&preset)?;
    let la = crate::runtime::Linalg::new(&env.rt.client);
    let mut rng = crate::util::rng::Rng::new(3);
    let lra_ranks = [8usize, 32, 128];
    let kinds = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];
    let mut csv = env.csv("fig17", &["lra_rank", "kind", "overlap"])?;
    println!("\n== Fig 17: overlap of LIFT vs weight-magnitude masks ==");
    print!("{:<10}", "lra");
    for k in kinds {
        print!("{k:>9}");
    }
    println!();
    for &lra in &lra_ranks {
        print!("{lra:<10}");
        for kind in kinds {
            let idxs = crate::model::matrices_of_kind(&exec.preset, kind);
            let mut overlaps = Vec::new();
            for &pi in &idxs {
                let w = &base[pi];
                let (m, n) = w.dims2();
                let k = lift::budget_for(m, n, 32);
                let cfg = LiftCfg {
                    rank: lra,
                    ..Default::default()
                };
                let lift_idx = lift::principal_indices(&la, w, k, &cfg, &mut rng)?;
                let wm_idx = lift::topk_indices(&w.data, k);
                overlaps.push(lift::mask_overlap(&wm_idx, &lift_idx));
            }
            let v = crate::util::stats::mean(&overlaps);
            print!("{v:>9.3}");
            csv.row(&[lra.to_string(), kind.to_string(), format!("{v:.4}")])?;
        }
        println!();
    }
    println!("(expected: low overlap overall, rising with LRA rank)");
    Ok(())
}

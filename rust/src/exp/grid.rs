//! N-dimensional scenario grid (ISSUE 5): the generalized axis system
//! behind `lift matrix`.
//!
//! The v1 runner hard-coded a method × selector × rank triple. This
//! module turns every swept dimension into a first-class [`Axis`] —
//! preset, method (selectors ride this axis, see
//! [`crate::exp::matrix::CellSpec`]), task suite, sparsity budget
//! (`rank`), mask refresh interval, and seed — and a [`Grid`] that
//! expands any subset of them into [`CellSpec`] cells.
//!
//! # Identity contract
//!
//! Cell identity must be a pure function of the cell's *field values*,
//! never of how the grid was described:
//!
//! * axes are normalized into one **canonical order** (preset → method →
//!   suite → rank → interval → seed → qscan) before expansion, so building the
//!   same grid with axes added in any order yields the identical cell
//!   vector (golden-file-locked by `rust/tests/grid.rs`);
//! * values within an axis are deduplicated preserving first occurrence,
//!   and merging two same-kind axes appends + dedups;
//! * any spec-field change yields a new id (property-tested in
//!   `rust/tests/properties.rs`), so a changed interval/suite/… can
//!   never reuse a stale ledger entry.
//!
//! Axes absent from a grid take single-value defaults
//! ([`Axis::default_for`]), so a grid over `{interval, seed}` alone is
//! still a complete cell description.

use anyhow::Result;

use super::matrix::CellSpec;

/// The seven sweepable dimensions, in canonical expansion order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum AxisKind {
    Preset,
    Method,
    Suite,
    Rank,
    Interval,
    Seed,
    /// Quantized rank-reduce scan on/off (ISSUE 10) — measures the int8
    /// tier's retention cost per method via the selector-zoo summary.
    Qscan,
}

pub const AXIS_KINDS: [AxisKind; 7] = [
    AxisKind::Preset,
    AxisKind::Method,
    AxisKind::Suite,
    AxisKind::Rank,
    AxisKind::Interval,
    AxisKind::Seed,
    AxisKind::Qscan,
];

impl AxisKind {
    pub fn key(&self) -> &'static str {
        match self {
            AxisKind::Preset => "preset",
            AxisKind::Method => "method",
            AxisKind::Suite => "suite",
            AxisKind::Rank => "rank",
            AxisKind::Interval => "interval",
            AxisKind::Seed => "seed",
            AxisKind::Qscan => "qscan",
        }
    }
}

/// One grid dimension with its value list.
#[derive(Clone, Debug, PartialEq)]
pub enum Axis {
    Preset(Vec<String>),
    /// Selector names are method names (`make_method`), so the selector
    /// axis of the v1 CLI merges into this one.
    Method(Vec<String>),
    /// Named eval suite (`data::tasks::suite_families`).
    Suite(Vec<String>),
    /// LoRA-rank-equivalent sparsity budget (`lift::budget_for`).
    Rank(Vec<usize>),
    /// Mask refresh interval handed to `make_method`.
    Interval(Vec<usize>),
    Seed(Vec<u64>),
    /// Quantized rank-reduce scan on/off (`LiftCfg.qscan`).
    Qscan(Vec<bool>),
}

impl Axis {
    pub fn kind(&self) -> AxisKind {
        match self {
            Axis::Preset(_) => AxisKind::Preset,
            Axis::Method(_) => AxisKind::Method,
            Axis::Suite(_) => AxisKind::Suite,
            Axis::Rank(_) => AxisKind::Rank,
            Axis::Interval(_) => AxisKind::Interval,
            Axis::Seed(_) => AxisKind::Seed,
            Axis::Qscan(_) => AxisKind::Qscan,
        }
    }

    pub fn key(&self) -> &'static str {
        self.kind().key()
    }

    pub fn len(&self) -> usize {
        match self {
            Axis::Preset(v) | Axis::Method(v) | Axis::Suite(v) => v.len(),
            Axis::Rank(v) | Axis::Interval(v) => v.len(),
            Axis::Seed(v) => v.len(),
            Axis::Qscan(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The single-value axis an absent dimension defaults to.
    pub fn default_for(kind: AxisKind) -> Axis {
        match kind {
            AxisKind::Preset => Axis::Preset(vec!["tiny".to_string()]),
            AxisKind::Method => Axis::Method(vec!["lift".to_string()]),
            AxisKind::Suite => Axis::Suite(vec!["arith".to_string()]),
            AxisKind::Rank => Axis::Rank(vec![32]),
            AxisKind::Interval => Axis::Interval(vec![100]),
            AxisKind::Seed => Axis::Seed(vec![1]),
            // defaults off: existing campaigns keep their golden cell ids
            AxisKind::Qscan => Axis::Qscan(vec![false]),
        }
    }

    /// Parse one `key=v1,v2,…` axis description (the CLI `--axis` form).
    pub fn parse(key: &str, values: &str) -> Result<Axis> {
        let vals: Vec<&str> = values
            .split(',')
            .map(|v| v.trim())
            .filter(|v| !v.is_empty())
            .collect();
        anyhow::ensure!(!vals.is_empty(), "axis '{key}' has no values");
        let ints = |what: &str| -> Result<Vec<usize>> {
            vals.iter()
                .map(|v| {
                    v.parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("axis '{what}' expects integers, got '{v}'"))
                })
                .collect()
        };
        Ok(match key {
            "preset" => Axis::Preset(vals.iter().map(|v| v.to_string()).collect()),
            "method" | "selector" => Axis::Method(vals.iter().map(|v| v.to_string()).collect()),
            "suite" => Axis::Suite(vals.iter().map(|v| v.to_string()).collect()),
            "rank" | "sparsity" => Axis::Rank(ints(key)?),
            "interval" => Axis::Interval(ints(key)?),
            "seed" => Axis::Seed(
                vals.iter()
                    .map(|v| {
                        v.parse::<u64>()
                            .map_err(|_| anyhow::anyhow!("axis 'seed' expects integers, got '{v}'"))
                    })
                    .collect::<Result<Vec<u64>>>()?,
            ),
            "qscan" => Axis::Qscan(
                vals.iter()
                    .map(|v| match *v {
                        "0" | "false" | "off" => Ok(false),
                        "1" | "true" | "on" => Ok(true),
                        _ => Err(anyhow::anyhow!(
                            "axis 'qscan' expects 0/1/true/false/on/off, got '{v}'"
                        )),
                    })
                    .collect::<Result<Vec<bool>>>()?,
            ),
            other => anyhow::bail!(
                "unknown axis '{other}' (known: preset, method, suite, rank, interval, seed, qscan)"
            ),
        })
    }

    /// Append `other`'s values (same kind only), deduplicating while
    /// preserving first occurrence.
    fn merge(&mut self, other: Axis) {
        fn extend_dedup<T: PartialEq>(dst: &mut Vec<T>, src: Vec<T>) {
            for v in src {
                if !dst.contains(&v) {
                    dst.push(v);
                }
            }
        }
        match (self, other) {
            (Axis::Preset(a), Axis::Preset(b)) => extend_dedup(a, b),
            (Axis::Method(a), Axis::Method(b)) => extend_dedup(a, b),
            (Axis::Suite(a), Axis::Suite(b)) => extend_dedup(a, b),
            (Axis::Rank(a), Axis::Rank(b)) => extend_dedup(a, b),
            (Axis::Interval(a), Axis::Interval(b)) => extend_dedup(a, b),
            (Axis::Seed(a), Axis::Seed(b)) => extend_dedup(a, b),
            (Axis::Qscan(a), Axis::Qscan(b)) => extend_dedup(a, b),
            (a, b) => unreachable!("merge of mismatched axes {:?} / {:?}", a.kind(), b.kind()),
        }
    }

    /// Drop duplicate values in place (first occurrence wins).
    fn dedup_values(&mut self) {
        fn dd<T: PartialEq + Clone>(v: &mut Vec<T>) {
            let mut out: Vec<T> = Vec::with_capacity(v.len());
            for x in v.iter() {
                if !out.contains(x) {
                    out.push(x.clone());
                }
            }
            *v = out;
        }
        match self {
            Axis::Preset(v) | Axis::Method(v) | Axis::Suite(v) => dd(v),
            Axis::Rank(v) | Axis::Interval(v) => dd(v),
            Axis::Seed(v) => dd(v),
            Axis::Qscan(v) => dd(v),
        }
    }
}

/// Parse a whole `--axis` flag value: `key=v1,v2[;key2=v3,…]`.
pub fn parse_axes(spec: &str) -> Result<Vec<Axis>> {
    let mut axes = Vec::new();
    for part in spec.split(';').map(|p| p.trim()).filter(|p| !p.is_empty()) {
        let (key, values) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("axis spec '{part}' is not key=v1,v2,…"))?;
        axes.push(Axis::parse(key.trim(), values)?);
    }
    Ok(axes)
}

/// An N-dimensional scenario grid: a set of axes plus the per-cell step
/// count (steps is campaign config, not a swept dimension — every cell
/// of one campaign trains the same number of steps).
#[derive(Clone, Debug)]
pub struct Grid {
    axes: Vec<Axis>,
    pub steps: usize,
}

impl Grid {
    pub fn new(steps: usize) -> Grid {
        Grid {
            axes: Vec::new(),
            steps,
        }
    }

    /// Add an axis; a same-kind axis already present merges (append +
    /// dedup) instead of duplicating the dimension. Empty axes are
    /// ignored — an absent dimension takes its default at expansion.
    pub fn with_axis(mut self, mut axis: Axis) -> Grid {
        if axis.is_empty() {
            return self;
        }
        axis.dedup_values();
        match self.axes.iter().position(|a| a.kind() == axis.kind()) {
            Some(i) => self.axes[i].merge(axis),
            None => self.axes.push(axis),
        }
        self
    }

    /// Replace a dimension wholesale (e.g. `--toy` pinning the preset
    /// axis to `toy` regardless of what the flags described).
    pub fn set_axis(mut self, mut axis: Axis) -> Grid {
        axis.dedup_values();
        self.axes.retain(|a| a.kind() != axis.kind());
        if !axis.is_empty() {
            self.axes.push(axis);
        }
        self
    }

    /// Whether a dimension was explicitly given (vs. default-filled at
    /// expansion) — lets the CLI distinguish "absent" from "swept".
    pub fn has_axis(&self, kind: AxisKind) -> bool {
        self.axes.iter().any(|a| a.kind() == kind)
    }

    /// The values of one dimension, defaulted if absent (string form,
    /// for reporting).
    pub fn axis(&self, kind: AxisKind) -> Axis {
        self.axes
            .iter()
            .find(|a| a.kind() == kind)
            .cloned()
            .unwrap_or_else(|| Axis::default_for(kind))
    }

    /// Expand into the full cell list. Axes are walked in canonical
    /// order (preset → method → suite → rank → interval → seed → qscan)
    /// no matter the order they were added, so both the expansion order
    /// and every cell id are stable under axis reordering.
    pub fn expand(&self) -> Vec<CellSpec> {
        let presets = match self.axis(AxisKind::Preset) {
            Axis::Preset(v) => v,
            _ => unreachable!(),
        };
        let methods = match self.axis(AxisKind::Method) {
            Axis::Method(v) => v,
            _ => unreachable!(),
        };
        let suites = match self.axis(AxisKind::Suite) {
            Axis::Suite(v) => v,
            _ => unreachable!(),
        };
        let ranks = match self.axis(AxisKind::Rank) {
            Axis::Rank(v) => v,
            _ => unreachable!(),
        };
        let intervals = match self.axis(AxisKind::Interval) {
            Axis::Interval(v) => v,
            _ => unreachable!(),
        };
        let seeds = match self.axis(AxisKind::Seed) {
            Axis::Seed(v) => v,
            _ => unreachable!(),
        };
        let qscans = match self.axis(AxisKind::Qscan) {
            Axis::Qscan(v) => v,
            _ => unreachable!(),
        };
        let mut cells =
            Vec::with_capacity(presets.len() * methods.len() * suites.len() * ranks.len());
        for preset in &presets {
            for method in &methods {
                for suite in &suites {
                    for &rank in &ranks {
                        for &interval in &intervals {
                            for &seed in &seeds {
                                for &qscan in &qscans {
                                    cells.push(CellSpec {
                                        preset: preset.clone(),
                                        method: method.clone(),
                                        suite: suite.clone(),
                                        rank,
                                        seed,
                                        steps: self.steps,
                                        interval,
                                        qscan,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        cells
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axes_merge_and_default() {
        let g = Grid::new(10)
            .with_axis(Axis::Method(vec!["lift".into(), "full".into()]))
            .with_axis(Axis::Method(vec!["full".into(), "weight_mag".into()]))
            .with_axis(Axis::Seed(vec![1, 2, 1]));
        let cells = g.expand();
        // 3 methods (full deduped) x 2 seeds (1 deduped), defaults elsewhere
        assert_eq!(cells.len(), 6);
        assert!(cells.iter().all(|c| c.preset == "tiny" && c.suite == "arith"));
        assert!(cells.iter().all(|c| c.rank == 32 && c.interval == 100));
        let ids: std::collections::HashSet<String> = cells.iter().map(|c| c.id()).collect();
        assert_eq!(ids.len(), 6);
    }

    #[test]
    fn expansion_is_axis_order_invariant() {
        let a = Grid::new(5)
            .with_axis(Axis::Seed(vec![1, 2]))
            .with_axis(Axis::Interval(vec![2, 4]))
            .with_axis(Axis::Method(vec!["lift".into(), "full".into()]));
        let b = Grid::new(5)
            .with_axis(Axis::Method(vec!["lift".into(), "full".into()]))
            .with_axis(Axis::Interval(vec![2, 4]))
            .with_axis(Axis::Seed(vec![1, 2]));
        assert_eq!(a.expand(), b.expand());
    }

    #[test]
    fn set_axis_replaces() {
        let g = Grid::new(5)
            .with_axis(Axis::Preset(vec!["tiny".into(), "small".into()]))
            .set_axis(Axis::Preset(vec!["toy".into()]));
        assert!(g.expand().iter().all(|c| c.preset == "toy"));
    }

    #[test]
    fn qscan_axis_parses_and_expands() {
        let axes = parse_axes("qscan=0,1").unwrap();
        assert_eq!(axes, vec![Axis::Qscan(vec![false, true])]);
        assert_eq!(
            parse_axes("qscan=off,on").unwrap(),
            vec![Axis::Qscan(vec![false, true])]
        );
        assert!(parse_axes("qscan=maybe").is_err());
        let cells = Grid::new(5)
            .with_axis(Axis::Qscan(vec![false, true]))
            .expand();
        assert_eq!(cells.len(), 2);
        assert!(!cells[0].qscan && cells[1].qscan);
        assert_ne!(cells[0].id(), cells[1].id());
        // absent axis defaults off
        assert!(Grid::new(5).expand().iter().all(|c| !c.qscan));
    }

    #[test]
    fn parse_axes_specs() {
        let axes = parse_axes("interval=2,4; seed=1,2,3 ;suite=arith,nlu").unwrap();
        assert_eq!(
            axes,
            vec![
                Axis::Interval(vec![2, 4]),
                Axis::Seed(vec![1, 2, 3]),
                Axis::Suite(vec!["arith".into(), "nlu".into()]),
            ]
        );
        assert!(parse_axes("bogus=1").is_err());
        assert!(parse_axes("interval=abc").is_err());
        assert!(parse_axes("interval").is_err());
        assert!(parse_axes("interval=").is_err());
        assert!(parse_axes("").unwrap().is_empty());
        // sparsity is an alias for the rank axis
        assert_eq!(parse_axes("sparsity=8,16").unwrap(), vec![Axis::Rank(vec![8, 16])]);
    }
}

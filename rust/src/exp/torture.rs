//! Crash/fault torture harness: replay seeded fault schedules across
//! the durable-state surfaces and prove recovery.
//!
//! Each schedule drives three scenarios against the `util::fault` seam,
//! every one with a disarmed *straight* baseline to compare against:
//!
//! 1. **train-resume** — a toy fine-tune cell with checkpointing every
//!    2 steps. Under faults the run either completes (transients were
//!    retried) or fails loudly; a disarmed rerun over the same
//!    checkpoint dir must then resume and land the exact outcome the
//!    straight run produced.
//! 2. **2-runner lease campaign** — two sequential leased
//!    `run_matrix_with` passes over a 2-cell toy grid under faults,
//!    then a disarmed recovery sweep per runner. Every cell must end
//!    `Done` with the straight (lease-free) outcome, and no `.lease`
//!    file may survive.
//! 3. **serve register/swap/evict mix** — register three tenants, warm
//!    the LRU, hot-swap one, delete one, probe. The disarmed recovery
//!    drive over the crashed store must produce bit-identical outputs
//!    to the straight store, with the orphaned `.tmp` droppings of
//!    crashed registrations skipped (warned) rather than fatal.
//!
//! After recovery the schedule's directory is scanned: every committed
//! artifact must parse (`.snap`/`.delta` LIFTSNAP containers, `.json`
//! ledger entries, `curve.sidecar` magic), `.tmp` debris is swept and
//! counted, and leftover `.lease` files are failures. Any failure under
//! faults whose message does not name its injected fault
//! ([`fault::INJECTED_MARK`]) fails the schedule by name — fault
//! injection must never manifest as a quiet wrong answer.
//!
//! The report is counts-only (no wall-clock, no timestamps), so two
//! same-seed invocations are byte-identical — the `torture-smoke`
//! Makefile target diffs exactly that.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::ckpt::{curve, Snapshot};
use crate::exp::lease::LeaseCfg;
use crate::exp::matrix::{
    expand_grid, read_outcome, run_matrix_with, run_toy_cell_in, toy_params, toy_preset,
    CellOutcome, CellSpec,
};
use crate::runtime::manifest::PresetInfo;
use crate::serve::{base_digest, synth_delta, Request, Server, TenantDelta};
use crate::tensor::Tensor;
use crate::util::fault::{self, FaultPlan, FaultStats};
use crate::util::json::Json;

/// Knobs for one torture run (`lift torture`).
#[derive(Clone, Debug)]
pub struct TortureCfg {
    /// Independent seeded schedules to replay.
    pub schedules: usize,
    /// Master seed; schedule `s` derives its three scenario plans from it.
    pub seed: u64,
    /// Scratch directory — wiped at the start of every run.
    pub out: PathBuf,
    /// Faults drawn per scenario plan.
    pub faults: usize,
    /// Per-class call horizon the fault sites are drawn from.
    pub horizon: u64,
}

/// What a torture run found, plus the deterministic report text.
#[derive(Clone, Debug)]
pub struct TortureReport {
    /// The full report, also written to `<out>/torture_report.txt`.
    pub text: String,
    /// Schedules that did not recover cleanly (empty = success).
    pub failed: Vec<String>,
    /// Total faults that actually fired across all schedules.
    pub injected: usize,
    /// Total transient faults absorbed by the retry loop.
    pub retried: usize,
    /// `.tmp` debris files swept after recovery.
    pub debris: usize,
}

/// Replay `cfg.schedules` seeded fault schedules. Completes every
/// schedule before reporting; per-schedule failures land in
/// `TortureReport::failed`, not in an early `Err` (a harness `Err`
/// means the straight baseline or the disarmed recovery plumbing broke,
/// which is a bug in the repo, not a torture finding).
pub fn run_torture(cfg: &TortureCfg) -> Result<TortureReport> {
    anyhow::ensure!(
        !fault::is_armed(),
        "torture cannot start while a fault plan is already armed (LIFT_FAULT_SCHEDULE?)"
    );
    anyhow::ensure!(cfg.schedules > 0, "need at least one schedule");
    if cfg.out.exists() {
        std::fs::remove_dir_all(&cfg.out)
            .with_context(|| format!("wiping torture dir {:?}", cfg.out))?;
    }
    std::fs::create_dir_all(&cfg.out)?;
    let mut lines = vec![format!(
        "lift torture: {} schedule(s), seed {}, {} fault(s)/scenario, horizon {}",
        cfg.schedules, cfg.seed, cfg.faults, cfg.horizon
    )];
    let mut failed = Vec::new();
    let (mut injected, mut retried, mut debris_total) = (0usize, 0usize, 0usize);
    for s in 0..cfg.schedules {
        let sdir = cfg.out.join(format!("s{s:03}"));
        let sseed = cfg.seed ^ (s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut notes: Vec<String> = Vec::new();
        let mut stats = FaultStats::default();
        for (scenario, tag) in [
            (scenario_train as ScenarioFn, 0x0721u64),
            (scenario_lease, 0x1ea5e),
            (scenario_serve, 0x5e17e),
        ] {
            let plan = FaultPlan::seeded(sseed ^ tag, cfg.faults, cfg.horizon);
            let st = scenario(&sdir, sseed, plan, &mut notes)?;
            stats.injected += st.injected;
            stats.retried += st.retried;
        }
        let mut debris = 0usize;
        scan_artifacts(&sdir, &mut notes, &mut debris)?;
        injected += stats.injected;
        retried += stats.retried;
        debris_total += debris;
        let status = if notes.is_empty() { "recovered" } else { "FAILED" };
        lines.push(format!(
            "schedule {s:03} [{status}] injected {} retried {} debris {}",
            stats.injected, stats.retried, debris
        ));
        for n in &notes {
            lines.push(format!("  - {n}"));
        }
        if !notes.is_empty() {
            failed.push(format!("s{s:03}"));
        }
    }
    lines.push(format!(
        "total: {} schedule(s), {} recovered, {} failed; {injected} fault(s) injected, \
         {retried} retried, {debris_total} temp file(s) swept",
        cfg.schedules,
        cfg.schedules - failed.len(),
        failed.len()
    ));
    let text = lines.join("\n") + "\n";
    std::fs::write(cfg.out.join("torture_report.txt"), &text)
        .with_context(|| format!("writing torture report under {:?}", cfg.out))?;
    Ok(TortureReport { text, failed, injected, retried, debris: debris_total })
}

type ScenarioFn = fn(&Path, u64, FaultPlan, &mut Vec<String>) -> Result<FaultStats>;

/// Strip the one field that legitimately differs between two runs of
/// the same cell (wall-clock seconds) before comparing outcomes.
fn norm(mut o: CellOutcome) -> CellOutcome {
    o.seconds = 0.0;
    o
}

/// A failure under an armed plan must name its injection — anything
/// else is the seam leaking a quiet wrong answer.
fn check_loud(notes: &mut Vec<String>, what: &str, e: &anyhow::Error) {
    let msg = format!("{e:#}");
    if !msg.contains(fault::INJECTED_MARK) {
        notes.push(format!("{what}: failure under faults does not name its injection: {msg}"));
    }
}

fn toy_cells(seeds: &[u64], steps: usize) -> Vec<CellSpec> {
    expand_grid("toy", &["lift".to_string()], &[], &[2], seeds, steps, 2)
}

// ---- scenario 1: train-resume ------------------------------------------

fn scenario_train(
    dir: &Path,
    seed: u64,
    plan: FaultPlan,
    notes: &mut Vec<String>,
) -> Result<FaultStats> {
    let dir = dir.join("train");
    let spec = toy_cells(&[seed % 5 + 1], 6).remove(0);
    let straight = norm(
        run_toy_cell_in(&spec, &dir.join("straight"), 2, 2, 1)
            .context("train scenario: straight baseline")?,
    );
    let fdir = dir.join("faulted");
    fault::arm(plan);
    let attempt = run_toy_cell_in(&spec, &fdir, 2, 2, 1);
    let stats = fault::disarm();
    let recovered = match attempt {
        Ok(o) => o,
        Err(e) => {
            check_loud(notes, "train", &e);
            // disarmed rerun over the same dir: resume from whatever
            // committed snapshots survived the faults
            match run_toy_cell_in(&spec, &fdir, 2, 2, 1) {
                Ok(o) => o,
                Err(e2) => {
                    notes.push(format!("train: disarmed recovery rerun failed: {e2:#}"));
                    return Ok(stats);
                }
            }
        }
    };
    if norm(recovered) != straight {
        notes.push("train: recovered outcome differs from the straight run".into());
    }
    Ok(stats)
}

// ---- scenario 2: 2-runner lease campaign -------------------------------

fn scenario_lease(
    dir: &Path,
    seed: u64,
    plan: FaultPlan,
    notes: &mut Vec<String>,
) -> Result<FaultStats> {
    let dir = dir.join("lease");
    let cells = toy_cells(&[seed % 5 + 1, seed % 5 + 2], 4);
    let run = |spec: &CellSpec, ckpt_dir: &Path| run_toy_cell_in(spec, ckpt_dir, 2, 2, 1);
    let sdir = dir.join("straight");
    let rep = run_matrix_with(&sdir, &cells, 1, None, run)
        .context("lease scenario: straight baseline")?;
    anyhow::ensure!(rep.failed.is_empty(), "lease straight baseline failed: {:?}", rep.failed);
    let mut baseline = Vec::new();
    for c in &cells {
        let id = c.id();
        match read_outcome(&sdir, &id) {
            Some(o) => baseline.push(norm(o)),
            None => anyhow::bail!("lease scenario: straight outcome for {id} missing"),
        }
    }
    let fdir = dir.join("faulted");
    fault::arm(plan);
    for runner in ["tort-a", "tort-b"] {
        let cfg = LeaseCfg::new(runner, 60);
        match run_matrix_with(&fdir, &cells, 1, Some(&cfg), run) {
            Ok(rep) => {
                for (id, why) in &rep.failed {
                    if !why.contains(fault::INJECTED_MARK) {
                        notes.push(format!("lease: cell {id} failed quietly under faults: {why}"));
                    }
                }
            }
            Err(e) => check_loud(notes, "lease", &e),
        }
    }
    let stats = fault::disarm();
    // recovery: each runner sweeps once; a cell deferred to the other
    // runner's still-live crashed lease is reclaimed by that runner's
    // own pass (same runner id -> reclaim, no TTL wait)
    for runner in ["tort-a", "tort-b"] {
        let cfg = LeaseCfg::new(runner, 60);
        let rep = run_matrix_with(&fdir, &cells, 1, Some(&cfg), run)
            .context("lease scenario: disarmed recovery pass")?;
        if !rep.failed.is_empty() {
            notes.push(format!("lease: recovery pass failed cells: {:?}", rep.failed));
        }
    }
    for (c, want) in cells.iter().zip(&baseline) {
        let id = c.id();
        match read_outcome(&fdir, &id) {
            Some(got) if &norm(got) == want => {}
            Some(_) => notes.push(format!("lease: cell {id} recovered with a different outcome")),
            None => notes.push(format!("lease: cell {id} never completed after recovery")),
        }
    }
    for entry in std::fs::read_dir(&fdir)? {
        let p = entry?.path();
        if p.extension().and_then(|e| e.to_str()) == Some("lease") {
            notes.push(format!("lease: leftover lease file {}", p.display()));
        }
    }
    Ok(stats)
}

// ---- scenario 3: serve register/swap/evict mix -------------------------

fn scenario_serve(
    dir: &Path,
    seed: u64,
    plan: FaultPlan,
    notes: &mut Vec<String>,
) -> Result<FaultStats> {
    let dir = dir.join("serve");
    let base = toy_params(0xBA5E ^ seed);
    let preset = toy_preset();
    let dg = base_digest(&base);
    let deltas: Vec<TenantDelta> = (0..3u64)
        .map(|i| synth_delta(&base, &format!("t{i}"), dg, 2, seed.wrapping_add(10 + i)))
        .collect();
    let swap1 = synth_delta(&base, "t1", dg, 2, seed.wrapping_add(21));
    let straight = drive_serve(&base, &preset, &dir.join("straight"), &deltas, &swap1, false, notes)
        .context("serve scenario: straight drive")?
        .expect("a disarmed serve drive always returns outputs");
    let fdir = dir.join("faulted");
    fault::arm(plan);
    let armed = drive_serve(&base, &preset, &fdir, &deltas, &swap1, true, notes);
    let stats = fault::disarm();
    let _ = armed?; // armed drives swallow op errors; a real Err is harness plumbing
    let recovered = drive_serve(&base, &preset, &fdir, &deltas, &swap1, false, notes)
        .context("serve scenario: disarmed recovery drive")?
        .expect("a disarmed serve drive always returns outputs");
    if bits(&recovered.0) != bits(&straight.0) {
        notes.push("serve: recovered probe outputs differ from the straight store".into());
    }
    if recovered.1 != straight.1 {
        notes.push(format!(
            "serve: recovered listing {:?} != straight {:?}",
            recovered.1, straight.1
        ));
    }
    Ok(stats)
}

fn bits(outs: &[Vec<f32>]) -> Vec<Vec<u32>> {
    outs.iter().map(|row| row.iter().map(|x| x.to_bits()).collect()).collect()
}

/// One deterministic pass over a serve store: register t0..t2, warm the
/// LRU, hot-swap t1, delete t2, probe the survivors, list. When `armed`,
/// per-op failures are expected — each is loudness-checked and the op
/// stream continues (tenants whose registration failed are dropped from
/// later batches so their absence is not mistaken for a quiet fault).
/// Returns `None` only from an armed drive that could not finish.
#[allow(clippy::type_complexity)]
fn drive_serve(
    base: &[Tensor],
    preset: &PresetInfo,
    store_dir: &Path,
    deltas: &[TenantDelta],
    swap1: &TenantDelta,
    armed: bool,
    notes: &mut Vec<String>,
) -> Result<Option<(Vec<Vec<f32>>, Vec<String>)>> {
    let mut server = match Server::new(base, preset, store_dir, 1 << 20, 1) {
        Ok(s) => s,
        Err(e) if armed => {
            check_loud(notes, "serve open", &e);
            return Ok(None);
        }
        Err(e) => return Err(e.context("opening serve store")),
    };
    let mut live: BTreeSet<String> = BTreeSet::new();
    for d in deltas {
        match server.hot_swap(d) {
            Ok(()) => {
                live.insert(d.tenant.clone());
            }
            Err(e) if armed => check_loud(notes, "serve register", &e),
            Err(e) => return Err(e.context(format!("registering tenant '{}'", d.tenant))),
        }
    }
    let warm: Vec<Request> = live
        .iter()
        .enumerate()
        .map(|(i, t)| Request { tenant: t.clone(), seed: 1 + i as u64 })
        .collect();
    if !warm.is_empty() {
        match server.handle_batch(&warm) {
            Ok(_) => {}
            Err(e) if armed => check_loud(notes, "serve warm batch", &e),
            Err(e) => return Err(e.context("serve warm batch")),
        }
    }
    match server.hot_swap(swap1) {
        Ok(()) => {
            live.insert(swap1.tenant.clone());
        }
        Err(e) if armed => check_loud(notes, "serve hot-swap", &e),
        Err(e) => return Err(e.context("hot-swapping tenant 't1'")),
    }
    match server.delete_tenant("t2") {
        Ok(_) => {
            live.remove("t2");
        }
        Err(e) if armed => check_loud(notes, "serve delete", &e),
        Err(e) => return Err(e.context("deleting tenant 't2'")),
    }
    let probe: Vec<Request> = live
        .iter()
        .enumerate()
        .map(|(i, t)| Request { tenant: t.clone(), seed: 4 + i as u64 })
        .collect();
    let outs = match server.handle_batch(&probe) {
        Ok(o) => o,
        Err(e) if armed => {
            check_loud(notes, "serve probe batch", &e);
            return Ok(None);
        }
        Err(e) => return Err(e.context("serve probe batch")),
    };
    let listing = match server.store().list() {
        Ok(l) => l,
        Err(e) if armed => {
            check_loud(notes, "serve list", &e);
            return Ok(None);
        }
        Err(e) => return Err(e.context("listing the serve store")),
    };
    Ok(Some((outs, listing)))
}

// ---- post-recovery artifact scan ---------------------------------------

/// Walk a schedule's directory after recovery: committed artifacts must
/// parse, `.tmp` debris is swept (counted, then removed — the atomic
/// writers guarantee temps are never load-bearing), and a surviving
/// `.lease` is a failure.
fn scan_artifacts(dir: &Path, notes: &mut Vec<String>, debris: &mut usize) -> Result<()> {
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("scanning torture dir {dir:?}"))?
    {
        entries.push(entry?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            scan_artifacts(&p, notes, debris)?;
            continue;
        }
        let name = p.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
        match p.extension().and_then(|e| e.to_str()).unwrap_or("") {
            "tmp" => {
                std::fs::remove_file(&p)
                    .with_context(|| format!("sweeping temp debris {}", p.display()))?;
                *debris += 1;
            }
            "lease" => notes.push(format!("torn: lease survived recovery: {}", p.display())),
            "snap" | "delta" => {
                if let Err(e) = Snapshot::read_from(&p) {
                    notes.push(format!("torn: {} does not parse: {e:#}", p.display()));
                }
            }
            "json" => match std::fs::read_to_string(&p) {
                Ok(s) => {
                    if Json::parse(&s).is_err() {
                        notes.push(format!("torn: {} is not valid JSON", p.display()));
                    }
                }
                Err(e) => notes.push(format!("torn: {} unreadable: {e}", p.display())),
            },
            _ if name == curve::CURVE_FILE => match std::fs::read(&p) {
                Ok(b) => {
                    if b.len() < 8 || &b[..8] != b"LIFTCRV1" {
                        notes.push(format!("torn: {} lost its magic", p.display()));
                    }
                }
                Err(e) => notes.push(format!("torn: {} unreadable: {e}", p.display())),
            },
            _ => {}
        }
    }
    Ok(())
}

//! Lease-based cell claiming for distributed matrix campaigns.
//!
//! N independent `lift matrix` processes — on one machine or on many
//! hosts sharing a filesystem (NFS) — shard one campaign with **zero
//! coordination service**: before computing a cell, a runner atomically
//! claims it by creating `<out>/<cell-id>.lease` next to the outcome
//! file, using create-new (`O_CREAT|O_EXCL`) semantics so exactly one
//! creator wins. The lease records three things:
//!
//! * **runner id** — who holds the cell (`--runner-id`; default
//!   `<hostname>-<pid>`);
//! * **fencing token** — a monotonically increasing claim counter. A
//!   fresh claim writes token 1; every takeover of an expired lease
//!   writes `old + 1`. Commits are fenced on it (below);
//! * **expiry deadline** — `now + TTL` in unix seconds (`--lease-ttl`).
//!
//! # Protocol
//!
//! * **Claim** ([`claim`]): create-new the lease file. If it already
//!   exists, read it: a lease held by *our own* runner id is reclaimed
//!   (same token, fresh deadline — a restarted runner picks its cells
//!   back up immediately; reuse `--runner-id` across restarts to get
//!   this); a **live** foreign lease defers the cell ([`Claim::Busy`] —
//!   the holder is computing it); an **expired or corrupt** lease is
//!   taken over by atomically renaming a higher-token lease over it and
//!   reading back to confirm the takeover race was won. An
//!   **unreadable** lease — an IO failure, NOT bad bytes — defers the
//!   cell loudly instead ([`Claim::Unreadable`]): corrupt bytes prove a
//!   claim died mid-write (claimable), but a failed read proves nothing
//!   about who holds the cell, and claiming over a live holder we
//!   merely could not see would compute the cell twice
//!   ("Unreadable ≠ Corrupt", as in the outcome ledger's
//!   `LedgerEntry::Unreadable`).
//! * **Renew** ([`LeaseGuard::renew`]): rewrite the same (runner, token)
//!   with a fresh deadline; refuses if the lease was lost. `run_matrix`
//!   renews once right before the cell computes — size the TTL to
//!   comfortably exceed the slowest cell.
//! * **Fenced commit** ([`LeaseGuard::still_held`]): `write_outcome`
//!   commits only while the on-disk lease still carries exactly our
//!   (runner id, token). A runner that stalled past its TTL and was
//!   taken over reads the usurper's higher token and *refuses* to
//!   commit — its cell is recomputed by the takeover runner instead of
//!   two runners racing renames onto one outcome file.
//! * **Release** ([`LeaseGuard::release`]): delete the lease after the
//!   outcome lands (or after a failure, so the cell is reclaimable
//!   immediately). Only a lease we still hold is deleted.
//!
//! A crashed runner never blocks a campaign forever: its leases expire
//! by TTL and the cells are recovered by takeover. Checkpoint dirs are
//! keyed by the claim's fencing token
//! (`exp::matrix::cell_ckpt_dir_fenced`), so a takeover runner never
//! shares a snapshot directory with the zombie it displaced.
//!
//! # Honest limits
//!
//! The deadline uses wall-clock unix seconds — the only clock hosts on a
//! shared filesystem have in common — so the TTL must also absorb
//! cross-host clock skew. And between the fencing check and the final
//! rename there remains a syscall-wide window in which a takeover can
//! land; cells are pure functions of their spec and outcome writes are
//! atomic, so the loser of that window renames identical bytes, never a
//! torn or wrong outcome. Both are the standard price of lease files
//! without a coordination service; the fencing token bounds the damage
//! to (at worst) one redundantly computed cell.
//!
//! # Durability contract
//!
//! Leases are *coordination* state, not *result* state — they are
//! written atomically (unique-per-runner temp + rename) but never
//! fsynced: losing a lease file to power loss only costs a TTL wait or
//! an immediate reclaim, never computed work. By failure mode:
//!
//! * **`kill -9` mid-claim**: either the lease landed (the crashed
//!   holder's cells are recovered by TTL takeover, or reclaimed
//!   immediately under the same `--runner-id`) or only a torn temp /
//!   half-written lease exists (corrupt → claimable at the next token).
//! * **Transient IO errors**: retried in place by the `util::fault`
//!   seam all lease IO routes through.
//! * **Permanent read errors** (EACCES/EIO): the cell is *deferred
//!   loudly* ([`Claim::Unreadable`]), commits are refused
//!   ([`LeaseGuard::still_held`] treats unprovable as lost), and GC
//!   leaves the file alone — an IO error must never be mistaken for
//!   "no one holds this cell".
//!
//! `lift torture` replays seeded fault schedules over a 2-runner
//! campaign to hold this contract (see `exp::torture`).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::fault;
use crate::util::json::Json;

/// Campaign-wide lease knobs: this runner's identity and the TTL every
/// claim/renewal stamps.
#[derive(Clone, Debug)]
pub struct LeaseCfg {
    pub runner: String,
    pub ttl_secs: u64,
}

impl LeaseCfg {
    pub fn new(runner: &str, ttl_secs: u64) -> LeaseCfg {
        LeaseCfg {
            runner: sanitize(runner),
            ttl_secs: ttl_secs.max(1),
        }
    }

    /// `<hostname>-<pid>`: unique per process, so uncoordinated runners
    /// never collide by default. A runner that should RECLAIM its cells
    /// after a restart must pass an explicit stable `--runner-id`
    /// instead (otherwise its old leases wait out the TTL).
    pub fn default_runner_id() -> String {
        let host = std::env::var("HOSTNAME").unwrap_or_else(|_| "host".to_string());
        sanitize(&format!("{host}-{}", std::process::id()))
    }
}

/// Runner ids become filename components (lease tmp names, outcome tmp
/// names), so anything outside `[A-Za-z0-9._-]` maps to `-`.
pub fn sanitize(s: &str) -> String {
    let cleaned: String = s
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') {
                c
            } else {
                '-'
            }
        })
        .collect();
    if cleaned.is_empty() {
        "runner".to_string()
    } else {
        cleaned
    }
}

/// Current wall clock in unix seconds — the shared-filesystem common
/// denominator the expiry deadline lives in.
///
/// A pre-epoch (or otherwise broken) clock is a **campaign-aborting
/// error**, not a value: a runner that silently saw `now = 0` would
/// treat every foreign lease as unexpired forever while stamping its
/// own deadlines as `0 + ttl` — which healthy peers read as expired
/// decades ago and instantly usurp, so the broken-clock runner's live
/// work is stolen out from under it. Better to refuse to participate.
pub fn now_unix() -> Result<u64> {
    now_unix_from(std::time::SystemTime::now())
}

/// Testable seam behind [`now_unix`]: convert an injected clock reading
/// to unix seconds, refusing pre-epoch times loudly.
pub fn now_unix_from(t: std::time::SystemTime) -> Result<u64> {
    t.duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .map_err(|e| {
            anyhow::anyhow!(
                "system clock reads {:.1}s BEFORE the unix epoch — lease deadlines \
                 would be nonsense (own leases instantly usurpable, foreign leases \
                 never expired); fix the clock and restart the campaign",
                e.duration().as_secs_f64()
            )
        })
}

pub fn lease_path(out_dir: &Path, id: &str) -> PathBuf {
    out_dir.join(format!("{id}.lease"))
}

/// The persisted lease record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Lease {
    pub runner: String,
    pub token: u64,
    pub expires_unix: u64,
}

impl Lease {
    pub fn is_expired(&self, now: u64) -> bool {
        now >= self.expires_unix
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("runner", Json::str(&self.runner)),
            ("token", Json::from(self.token as usize)),
            ("expires_unix", Json::from(self.expires_unix as usize)),
        ])
    }

    fn from_json(j: &Json) -> Option<Lease> {
        Some(Lease {
            runner: j.get("runner")?.as_str()?.to_string(),
            token: j.get("token")?.as_f64()? as u64,
            expires_unix: j.get("expires_unix")?.as_f64()? as u64,
        })
    }
}

/// The lease currently on disk for a cell, with missing and unreadable
/// kept apart:
///
/// * `Ok(None)` — no lease file, or one holding unparseable bytes. Both
///   are CLAIMABLE: a corrupt lease is a half-written claim whose
///   writer died, and fencing on (runner, token) keeps a surviving
///   writer from committing over a takeover.
/// * `Err(_)` — the file exists but could not be READ (EACCES, EIO,
///   ...). This proves nothing about who holds the cell; callers must
///   defer or refuse, never claim over it.
pub fn read_lease_checked(out_dir: &Path, id: &str) -> Result<Option<Lease>> {
    let path = lease_path(out_dir, id);
    let s = match fault::read_to_string(&path) {
        Ok(s) => s,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(e).with_context(|| format!("reading lease {}", path.display()));
        }
    };
    Ok(Json::parse(&s).ok().and_then(|j| Lease::from_json(&j)))
}

/// Permissive view of [`read_lease_checked`] for display/tests: `None`
/// for missing, corrupt, AND unreadable. Decision-making paths (claim,
/// GC) use the checked variant — folding an unreadable lease into "no
/// lease" is exactly the bug that let a second runner claim a live
/// cell.
pub fn read_lease(out_dir: &Path, id: &str) -> Option<Lease> {
    read_lease_checked(out_dir, id).ok().flatten()
}

/// Result of a claim attempt.
#[derive(Debug)]
pub enum Claim {
    /// This runner holds the cell; compute it, commit through the
    /// guard's fence, then release.
    Held(LeaseGuard),
    /// A live lease belongs to another runner — skip the cell (it will
    /// be in the report's `deferred` column).
    Busy { holder: String, expires_unix: u64 },
    /// The lease file exists but could not be read (EACCES/EIO-class
    /// failure — NOT corrupt bytes). The holder may be live, so the
    /// cell is deferred loudly instead of claimed or taken over.
    Unreadable { why: String },
}

/// Proof of a claim: the (runner, token) pair every subsequent renew /
/// fenced commit / release is checked against.
#[derive(Debug)]
pub struct LeaseGuard {
    out_dir: PathBuf,
    id: String,
    runner: String,
    token: u64,
    ttl_secs: u64,
}

impl LeaseGuard {
    pub fn token(&self) -> u64 {
        self.token
    }

    pub fn runner(&self) -> &str {
        &self.runner
    }

    /// Whether the on-disk lease still carries exactly our
    /// (runner, token) — the fencing check a commit is gated on. A
    /// missing or unreadable lease also reads as lost: we can no longer
    /// PROVE ownership, so the commit is refused and the cell falls to
    /// whoever holds (or next claims) it. For unreadable this is the
    /// safe direction — refusing a commit we were entitled to costs one
    /// recompute; committing over a takeover we could not see corrupts
    /// the ledger.
    pub fn still_held(&self) -> bool {
        matches!(
            read_lease(&self.out_dir, &self.id),
            Some(l) if l.runner == self.runner && l.token == self.token
        )
    }

    fn body(&self) -> Result<Lease> {
        Ok(Lease {
            runner: self.runner.clone(),
            token: self.token,
            expires_unix: now_unix()? + self.ttl_secs,
        })
    }

    /// Extend the deadline by a fresh TTL (same runner, same token).
    /// Fails if the lease was lost — the caller must not start (or keep)
    /// computing a cell it no longer holds.
    pub fn renew(&self) -> Result<()> {
        anyhow::ensure!(
            self.still_held(),
            "lease on cell {} was lost (taken over or released) — refusing to renew",
            self.id
        );
        write_lease_atomic(&self.out_dir, &self.id, &self.runner, &self.body()?)
            .with_context(|| format!("renewing lease on cell {}", self.id))
    }

    /// Delete the lease if (and only if) we still hold it; a lease lost
    /// to a takeover is left alone — it is the usurper's to release.
    pub fn release(self) -> Result<()> {
        if !self.still_held() {
            log::debug!(
                "lease on cell {} no longer held by {} — leaving it in place",
                self.id,
                self.runner
            );
            return Ok(());
        }
        match fault::remove_file(&lease_path(&self.out_dir, &self.id)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e).with_context(|| format!("releasing lease on cell {}", self.id)),
        }
    }
}

/// Atomically install a lease body: unique per-runner temp name (two
/// runners racing a takeover never share a temp file), then rename.
fn write_lease_atomic(out_dir: &Path, id: &str, runner: &str, lease: &Lease) -> Result<()> {
    let tmp = out_dir.join(format!("{id}.lease.{runner}.tmp"));
    fault::write(&tmp, lease.to_json().to_string().as_bytes())
        .with_context(|| format!("writing lease temp {tmp:?}"))?;
    fault::rename(&tmp, &lease_path(out_dir, id))
        .with_context(|| format!("installing lease for cell {id}"))?;
    Ok(())
}

/// Try to claim cell `id` for `cfg.runner`. See the module doc for the
/// full protocol; in short — create-new wins a fresh claim (token 1), a
/// lease of our own runner id is reclaimed at its existing token, a live
/// foreign lease is `Busy`, an unreadable lease defers loudly
/// (`Unreadable`), and an expired/corrupt lease is taken over at
/// `token + 1` with a read-back to confirm the rename race was won.
pub fn claim(out_dir: &Path, id: &str, cfg: &LeaseCfg) -> Result<Claim> {
    let path = lease_path(out_dir, id);
    let fresh = Lease {
        runner: cfg.runner.clone(),
        token: 1,
        expires_unix: now_unix()? + cfg.ttl_secs,
    };
    match fault::create_new(&path, fresh.to_json().to_string().as_bytes()) {
        Ok(()) => {
            return Ok(Claim::Held(LeaseGuard {
                out_dir: out_dir.to_path_buf(),
                id: id.to_string(),
                runner: cfg.runner.clone(),
                token: 1,
                ttl_secs: cfg.ttl_secs,
            }));
        }
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {}
        Err(e) => {
            return Err(e).with_context(|| format!("creating lease {path:?}"));
        }
    }
    // someone claimed this cell before us — inspect the lease. An
    // UNREADABLE one (IO failure, not bad bytes) defers: the holder may
    // be live and mid-compute, and claiming blind would run the cell
    // twice — the exact bug the old `.ok()?` fold had.
    let current = match read_lease_checked(out_dir, id) {
        Ok(c) => c,
        Err(e) => {
            log::warn!("cell {id}: lease exists but cannot be read — deferring ({e:#})");
            return Ok(Claim::Unreadable { why: format!("{e:#}") });
        }
    };
    if let Some(l) = &current {
        if l.runner == cfg.runner {
            // our own lease (this runner restarted, or a prior claim of
            // this run): reclaim at the SAME token so snapshots written
            // under it keep resuming, and push the deadline out
            let guard = LeaseGuard {
                out_dir: out_dir.to_path_buf(),
                id: id.to_string(),
                runner: cfg.runner.clone(),
                token: l.token,
                ttl_secs: cfg.ttl_secs,
            };
            write_lease_atomic(out_dir, id, &cfg.runner, &guard.body()?)
                .with_context(|| format!("reclaiming lease on cell {id}"))?;
            return Ok(Claim::Held(guard));
        }
        if !l.is_expired(now_unix()?) {
            return Ok(Claim::Busy {
                holder: l.runner.clone(),
                expires_unix: l.expires_unix,
            });
        }
    }
    // expired (or unreadable — a claim whose writer died mid-write):
    // take over with a strictly higher fencing token, then read back to
    // learn whether our rename won the takeover race
    let takeover = Lease {
        runner: cfg.runner.clone(),
        token: current.as_ref().map(|l| l.token + 1).unwrap_or(1),
        expires_unix: now_unix()? + cfg.ttl_secs,
    };
    write_lease_atomic(out_dir, id, &cfg.runner, &takeover)
        .with_context(|| format!("taking over expired lease on cell {id}"))?;
    match read_lease(out_dir, id) {
        Some(l) if l.runner == takeover.runner && l.token == takeover.token => {
            log::info!(
                "cell {id}: took over expired lease at fencing token {} (runner {})",
                takeover.token,
                cfg.runner
            );
            Ok(Claim::Held(LeaseGuard {
                out_dir: out_dir.to_path_buf(),
                id: id.to_string(),
                runner: cfg.runner.clone(),
                token: takeover.token,
                ttl_secs: cfg.ttl_secs,
            }))
        }
        Some(l) => Ok(Claim::Busy {
            holder: l.runner,
            expires_unix: l.expires_unix,
        }),
        // our just-renamed lease vanished: the winner already released
        // (computed the cell faster than our read-back) — defer
        None => Ok(Claim::Busy {
            holder: "unknown (lease released mid-takeover)".to_string(),
            expires_unix: 0,
        }),
    }
}

/// Garbage-collect the lease of a cell whose outcome already exists —
/// the state a crash between outcome-commit and release leaves behind.
/// Only a lease that is ours or expired is removed; a live foreign
/// lease is left to its holder's own release, and an UNREADABLE one is
/// left in place with a loud warning (ownership and expiry cannot be
/// judged from an IO error). Errors only on a broken clock (see
/// [`now_unix`]) — expiry cannot be judged without one either.
pub fn gc_finished(out_dir: &Path, id: &str, cfg: &LeaseCfg) -> Result<()> {
    let l = match read_lease_checked(out_dir, id) {
        Ok(Some(l)) => l,
        Ok(None) => return Ok(()),
        Err(e) => {
            log::warn!("cell {id}: leftover lease cannot be read — leaving it in place ({e:#})");
            return Ok(());
        }
    };
    if l.runner == cfg.runner || l.is_expired(now_unix()?) {
        if fault::remove_file(&lease_path(out_dir, id)).is_ok() {
            log::debug!("cell {id}: removed leftover lease (outcome already committed)");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tests run on a healthy host clock; a failure here IS the
    /// broken-clock condition `now_unix` exists to refuse.
    fn now() -> u64 {
        now_unix().unwrap()
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lift_lease_unit_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn put_lease(dir: &Path, id: &str, runner: &str, token: u64, expires_unix: u64) {
        let l = Lease {
            runner: runner.into(),
            token,
            expires_unix,
        };
        std::fs::write(lease_path(dir, id), l.to_json().to_string()).unwrap();
    }

    #[test]
    fn sanitize_keeps_safe_chars_and_replaces_the_rest() {
        assert_eq!(sanitize("host-1.example_A"), "host-1.example_A");
        assert_eq!(sanitize("a/b c:d"), "a-b-c-d");
        assert_eq!(sanitize(""), "runner");
        // default ids are already filename-safe
        let d = LeaseCfg::default_runner_id();
        assert_eq!(d, sanitize(&d));
    }

    #[test]
    fn lease_json_roundtrip() {
        let l = Lease {
            runner: "r1".into(),
            token: 7,
            expires_unix: 1_999_999_999,
        };
        let back = Lease::from_json(&Json::parse(&l.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, l);
    }

    #[test]
    fn fresh_claim_wins_and_lands_token_one() {
        let dir = tmpdir("fresh");
        let cfg = LeaseCfg::new("r1", 60);
        let Claim::Held(g) = claim(&dir, "cell", &cfg).unwrap() else {
            panic!("fresh claim must be held");
        };
        assert_eq!(g.token(), 1);
        assert!(g.still_held());
        let on_disk = read_lease(&dir, "cell").unwrap();
        assert_eq!(on_disk.runner, "r1");
        assert_eq!(on_disk.token, 1);
        assert!(on_disk.expires_unix >= now());
        g.release().unwrap();
        assert!(read_lease(&dir, "cell").is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn live_foreign_lease_is_busy() {
        let dir = tmpdir("busy");
        put_lease(&dir, "cell", "other", 3, now() + 600);
        match claim(&dir, "cell", &LeaseCfg::new("me", 60)).unwrap() {
            Claim::Busy { holder, .. } => assert_eq!(holder, "other"),
            Claim::Held(_) => panic!("must defer to a live lease"),
            Claim::Unreadable { why } => panic!("readable lease classified unreadable: {why}"),
        }
        // the live lease is untouched
        assert_eq!(read_lease(&dir, "cell").unwrap().token, 3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn expired_lease_is_taken_over_with_a_higher_token() {
        let dir = tmpdir("takeover");
        put_lease(&dir, "cell", "dead", 5, now().saturating_sub(10));
        let Claim::Held(g) = claim(&dir, "cell", &LeaseCfg::new("me", 60)).unwrap() else {
            panic!("expired lease must be takeover-able");
        };
        assert_eq!(g.token(), 6, "takeover must fence with old token + 1");
        let on_disk = read_lease(&dir, "cell").unwrap();
        assert_eq!((on_disk.runner.as_str(), on_disk.token), ("me", 6));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_lease_is_takeover_able() {
        let dir = tmpdir("corrupt");
        std::fs::write(lease_path(&dir, "cell"), "{half a lea").unwrap();
        // corrupt is NOT unreadable: the bytes came back fine, they just
        // don't parse — a half-written claim whose writer died
        assert!(matches!(read_lease_checked(&dir, "cell"), Ok(None)));
        let Claim::Held(g) = claim(&dir, "cell", &LeaseCfg::new("me", 60)).unwrap() else {
            panic!("corrupt lease must be claimable");
        };
        assert_eq!(g.token(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unreadable_lease_defers_instead_of_claiming() {
        // a DIRECTORY at the lease path makes reads fail with EISDIR —
        // a non-NotFound IO error standing in for EACCES/EIO (which a
        // root test process cannot provoke via permissions). The old
        // `.ok()?` fold read this as "no lease" and claimed the cell.
        let dir = tmpdir("unreadable");
        std::fs::create_dir_all(lease_path(&dir, "cell")).unwrap();
        assert!(read_lease_checked(&dir, "cell").is_err(), "checked read must surface the IO error");
        assert!(read_lease(&dir, "cell").is_none(), "permissive view folds to None");
        match claim(&dir, "cell", &LeaseCfg::new("me", 60)).unwrap() {
            Claim::Unreadable { why } => assert!(why.contains("cell"), "{why}"),
            Claim::Held(_) => panic!("claimed over an unreadable lease — live holder races"),
            Claim::Busy { .. } => panic!("unreadable must be distinguished from busy"),
        }
        // GC must leave it in place too: ownership cannot be judged
        gc_finished(&dir, "cell", &LeaseCfg::new("me", 60)).unwrap();
        assert!(lease_path(&dir, "cell").exists(), "gc removed a lease it could not read");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn own_lease_is_reclaimed_at_the_same_token() {
        let dir = tmpdir("reclaim");
        // even an EXPIRED own lease reclaims (not takes over): same
        // token means the restarted runner resumes its own fenced
        // checkpoint dir
        put_lease(&dir, "cell", "me", 4, now().saturating_sub(10));
        let Claim::Held(g) = claim(&dir, "cell", &LeaseCfg::new("me", 60)).unwrap() else {
            panic!("own lease must reclaim");
        };
        assert_eq!(g.token(), 4);
        let on_disk = read_lease(&dir, "cell").unwrap();
        assert!(on_disk.expires_unix >= now() + 50, "deadline must be pushed out");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stale_guard_fails_fencing_and_refuses_renew_and_release() {
        let dir = tmpdir("stale");
        let Claim::Held(g) = claim(&dir, "cell", &LeaseCfg::new("me", 60)).unwrap() else {
            panic!();
        };
        // simulate a takeover landing while we compute
        put_lease(&dir, "cell", "usurper", g.token() + 1, now() + 600);
        assert!(!g.still_held(), "fencing must see the higher token");
        assert!(g.renew().is_err(), "renew of a lost lease must refuse");
        g.release().unwrap();
        let left = read_lease(&dir, "cell").unwrap();
        assert_eq!(left.runner, "usurper", "release must not delete the usurper's lease");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pre_epoch_clock_is_a_loud_error_not_zero() {
        use std::time::{Duration, UNIX_EPOCH};
        // injected clock: 5 s before the epoch. The old code mapped this
        // to 0, which poisoned every deadline in the campaign.
        let broken = UNIX_EPOCH - Duration::from_secs(5);
        let err = now_unix_from(broken).unwrap_err().to_string();
        assert!(err.contains("BEFORE the unix epoch"), "{err}");
        assert!(err.contains("fix the clock"), "{err}");
        // a healthy clock still converts
        let ok = now_unix_from(UNIX_EPOCH + Duration::from_secs(1_700_000_000)).unwrap();
        assert_eq!(ok, 1_700_000_000);
        // exactly-epoch is fine (duration 0), not an error
        assert_eq!(now_unix_from(UNIX_EPOCH).unwrap(), 0);
    }

    #[test]
    fn claim_race_has_exactly_one_winner() {
        let dir = tmpdir("race");
        fn cfg_for(i: usize) -> LeaseCfg {
            LeaseCfg::new(&format!("racer{i}"), 300)
        }
        let wins: Vec<bool> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let dir = dir.clone();
                    s.spawn(move || {
                        matches!(claim(&dir, "cell", &cfg_for(i)).unwrap(), Claim::Held(_))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(
            wins.iter().filter(|&&w| w).count(),
            1,
            "create-new must admit exactly one claimant: {wins:?}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_finished_spares_live_foreign_leases() {
        let dir = tmpdir("gc");
        let me = LeaseCfg::new("me", 60);
        // ours: collected
        put_lease(&dir, "a", "me", 1, now() + 600);
        gc_finished(&dir, "a", &me).unwrap();
        assert!(read_lease(&dir, "a").is_none());
        // expired foreign: collected
        put_lease(&dir, "b", "dead", 2, now().saturating_sub(5));
        gc_finished(&dir, "b", &me).unwrap();
        assert!(read_lease(&dir, "b").is_none());
        // live foreign: spared
        put_lease(&dir, "c", "other", 3, now() + 600);
        gc_finished(&dir, "c", &me).unwrap();
        assert_eq!(read_lease(&dir, "c").unwrap().runner, "other");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! Resumable scenario-matrix runner (ISSUE 3): a grid of
//! method × selector × sparsity cells, each persisted independently so a
//! preempted campaign reruns only its unfinished cells.
//!
//! Layout under the output directory:
//!
//! ```text
//! <out>/<cell-id>.json    the cell's outcome (written atomically on
//!                         completion; existing + parseable == done)
//! <out>/<cell-id>.ckpt/   the cell's trainer snapshots
//!                         (`step_XXXXXXXX.snap`, see `crate::ckpt`)
//! ```
//!
//! [`run_matrix`] partitions the grid into done/todo by reading outcome
//! files, then fans the todo cells over the shared
//! `lift::engine::par_map` worker pool. A cell that crashed mid-train
//! resumes from its newest snapshot on the next campaign run; a
//! half-written or corrupted outcome file counts as *not done* and is
//! recomputed (the atomic temp-file + rename write makes that window
//! tiny). Cell failures are collected per cell — one broken configuration
//! never aborts the rest of the campaign.
//!
//! Two cell executors share the machinery:
//! * [`run_toy_cell`] — artifact-free: the toy preset + a synthetic
//!   gradient stream through the *real* trainer loop
//!   (`train::train_with`), so checkpoint cadence, resume and the
//!   skip/recompute ledger are exercisable (and CI-tested,
//!   `rust/tests/ckpt.rs`) without AOT artifacts;
//! * [`run_real_cell`] — the full fine-tune + eval path, requiring
//!   `make artifacts`.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::ckpt;
use crate::data::tasks::{TaskMixSource, TaskSet};
use crate::data::TaskFamily;
use crate::lift::engine::par_map;
use crate::lift::LiftCfg;
use crate::methods::{make_method, Ctx, Method, Scope};
use crate::optim::AdamCfg;
use crate::runtime::manifest::{ParamInfo, PresetInfo};
use crate::runtime::model_exec::ModelExec;
use crate::runtime::{Linalg, Runtime};
use crate::tensor::Tensor;
use crate::train::{self, pretrain, TrainCfg};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One cell of the scenario grid. The selector axis rides the method
/// axis: sparse selectors ARE `make_method` names (lift, weight_mag,
/// grad_mag, movement, random, sift), so a grid over
/// `methods ∪ selectors × ranks × seeds` covers method × selector ×
/// sparsity without a redundant third constructor path.
#[derive(Clone, Debug)]
pub struct CellSpec {
    pub preset: String,
    pub method: String,
    /// LoRA-rank-equivalent sparsity budget (`lift::budget_for`).
    pub rank: usize,
    pub seed: u64,
    pub steps: usize,
    /// mask refresh interval handed to `make_method`
    pub interval: usize,
}

impl CellSpec {
    /// Stable cell identity over EVERY spec field — outcome file and
    /// checkpoint dir both key on it, so changing the spec (including
    /// the refresh interval) is a new cell, never a stale reuse.
    pub fn id(&self) -> String {
        format!(
            "{}_{}_r{}_s{}_t{}_i{}",
            self.preset, self.method, self.rank, self.seed, self.steps, self.interval
        )
    }

    /// Construct the cell's method with an explicit LRA rank (the toy
    /// preset's matrices are too small for large ranks).
    pub fn method_with_lra(&self, lra_rank: usize) -> Result<Box<dyn Method>> {
        make_method(
            &self.method,
            self.rank,
            LiftCfg {
                rank: lra_rank,
                ..Default::default()
            },
            self.interval,
            Scope::default(),
        )
    }

    pub fn method(&self) -> Result<Box<dyn Method>> {
        self.method_with_lra(self.rank)
    }
}

/// Persisted result of one finished cell.
#[derive(Clone, Debug, PartialEq)]
pub struct CellOutcome {
    pub label: String,
    /// accuracy per task family (empty for toy cells)
    pub accs: Vec<f64>,
    pub avg: f64,
    pub tail_loss: f32,
    pub trainable: usize,
    pub opt_bytes: usize,
    pub seconds: f64,
    pub steps: usize,
}

impl CellOutcome {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(&self.label)),
            ("accs", Json::arr(self.accs.iter().map(|&a| Json::num(a)))),
            ("avg", Json::num(self.avg)),
            ("tail_loss", Json::num(self.tail_loss as f64)),
            ("trainable", Json::from(self.trainable)),
            ("opt_bytes", Json::from(self.opt_bytes)),
            ("seconds", Json::num(self.seconds)),
            ("steps", Json::from(self.steps)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<CellOutcome> {
        Some(CellOutcome {
            label: j.get("label")?.as_str()?.to_string(),
            accs: j
                .get("accs")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Option<Vec<_>>>()?,
            avg: j.get("avg")?.as_f64()?,
            tail_loss: j.get("tail_loss")?.as_f64()? as f32,
            trainable: j.get("trainable")?.as_usize()?,
            opt_bytes: j.get("opt_bytes")?.as_usize()?,
            seconds: j.get("seconds")?.as_f64()?,
            steps: j.get("steps")?.as_usize()?,
        })
    }
}

/// Expand the method × selector × sparsity × seed grid; the selector
/// axis is deduplicated into the method axis (see [`CellSpec`]).
pub fn expand_grid(
    preset: &str,
    methods: &[String],
    selectors: &[String],
    ranks: &[usize],
    seeds: &[u64],
    steps: usize,
    interval: usize,
) -> Vec<CellSpec> {
    let mut names: Vec<String> = Vec::new();
    for n in methods.iter().chain(selectors) {
        if !names.contains(n) {
            names.push(n.clone());
        }
    }
    let mut cells = Vec::new();
    for name in &names {
        for &rank in ranks {
            for &seed in seeds {
                cells.push(CellSpec {
                    preset: preset.to_string(),
                    method: name.clone(),
                    rank,
                    seed,
                    steps,
                    interval,
                });
            }
        }
    }
    cells
}

pub fn outcome_path(out_dir: &Path, id: &str) -> PathBuf {
    out_dir.join(format!("{id}.json"))
}

pub fn cell_ckpt_dir(out_dir: &Path, id: &str) -> PathBuf {
    out_dir.join(format!("{id}.ckpt"))
}

/// A cell's persisted outcome, if it exists AND parses — corruption or a
/// torn write reads as "not done", so reruns recompute it.
pub fn read_outcome(out_dir: &Path, id: &str) -> Option<CellOutcome> {
    let s = std::fs::read_to_string(outcome_path(out_dir, id)).ok()?;
    CellOutcome::from_json(&Json::parse(&s).ok()?)
}

fn write_outcome(out_dir: &Path, id: &str, out: &CellOutcome) -> Result<()> {
    let path = outcome_path(out_dir, id);
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, out.to_json().to_string())?;
    std::fs::rename(&tmp, &path)?;
    Ok(())
}

#[derive(Debug, Default)]
pub struct MatrixReport {
    /// cells executed this run (outcome written)
    pub ran: Vec<String>,
    /// cells whose outcome already existed — not recomputed
    pub skipped: Vec<String>,
    /// (cell id, error) — the rest of the campaign still completes
    pub failed: Vec<(String, String)>,
}

/// Run every unfinished cell of the grid, fanned over
/// `lift::engine::par_map`. `run_cell` must be a pure function of the
/// spec (cells execute on any worker in any order); it should route
/// through the cell's checkpoint dir so an interrupted cell resumes
/// instead of restarting.
pub fn run_matrix<F>(
    out_dir: &Path,
    cells: &[CellSpec],
    workers: usize,
    run_cell: F,
) -> Result<MatrixReport>
where
    F: Fn(&CellSpec) -> Result<CellOutcome> + Sync,
{
    std::fs::create_dir_all(out_dir)?;
    let mut report = MatrixReport::default();
    let mut todo: Vec<&CellSpec> = Vec::new();
    for c in cells {
        if read_outcome(out_dir, &c.id()).is_some() {
            report.skipped.push(c.id());
        } else {
            todo.push(c);
        }
    }
    log::info!(
        "matrix: {} cells, {} done, {} to run ({} workers)",
        cells.len(),
        report.skipped.len(),
        todo.len(),
        workers.max(1)
    );
    let results = par_map(workers.max(1), todo, |_, spec| {
        let id = spec.id();
        let res = run_cell(spec).and_then(|out| {
            write_outcome(out_dir, &id, &out)?;
            Ok(out)
        });
        (id, res.map_err(|e| format!("{e:#}")))
    });
    for (id, res) in results {
        match res {
            Ok(_) => report.ran.push(id),
            Err(e) => report.failed.push((id, e)),
        }
    }
    Ok(report)
}

// ---- campaign summary ---------------------------------------------------

/// Paper-style method × rank summary over the persisted cell outcomes:
/// rows are methods, columns are sparsity budgets (ranks), each cell the
/// mean over seeds of the outcome metric — average task accuracy for
/// real cells, tail loss for `--toy` cells (which have no eval). Cells
/// without a finished outcome render as `-`, so a partially-run
/// campaign still summarizes cleanly.
pub fn summary_table(out_dir: &Path, cells: &[CellSpec]) -> String {
    let mut methods: Vec<String> = Vec::new();
    let mut ranks: Vec<usize> = Vec::new();
    for c in cells {
        if !methods.contains(&c.method) {
            methods.push(c.method.clone());
        }
        if !ranks.contains(&c.rank) {
            ranks.push(c.rank);
        }
    }
    ranks.sort_unstable();
    // (method, rank) -> (sum avg, sum tail loss, count, label)
    let mut agg: std::collections::BTreeMap<(String, usize), (f64, f64, usize, String)> =
        std::collections::BTreeMap::new();
    let mut done = 0usize;
    let mut any_acc = false;
    for c in cells {
        if let Some(o) = read_outcome(out_dir, &c.id()) {
            done += 1;
            any_acc |= !o.accs.is_empty();
            let e = agg
                .entry((c.method.clone(), c.rank))
                .or_insert((0.0, 0.0, 0, o.label.clone()));
            e.0 += o.avg;
            e.1 += o.tail_loss as f64;
            e.2 += 1;
        }
    }
    let metric = if any_acc { "mean avg accuracy" } else { "mean tail loss" };
    let mut out = format!(
        "scenario matrix: {done}/{} cells finished | cell = {metric} over seeds\n\n",
        cells.len()
    );
    out.push_str(&format!("{:<18}", "method"));
    for &r in &ranks {
        out.push_str(&format!("{:>12}", format!("r={r}")));
    }
    out.push('\n');
    for m in &methods {
        // prefer the method's self-reported label when any cell finished
        let label = ranks
            .iter()
            .find_map(|r| agg.get(&(m.clone(), *r)).map(|e| e.3.clone()))
            .unwrap_or_else(|| m.clone());
        out.push_str(&format!("{label:<18}"));
        for &r in &ranks {
            match agg.get(&(m.clone(), r)) {
                Some(&(sum_avg, sum_tail, n, _)) if n > 0 => {
                    let sum = if any_acc { sum_avg } else { sum_tail };
                    let v = sum / n as f64;
                    out.push_str(&format!("{:>12}", format!("{v:.4} ({n}s)")));
                }
                _ => out.push_str(&format!("{:>12}", "-")),
            }
        }
        out.push('\n');
    }
    out
}

/// Render [`summary_table`] and persist it as `summary.txt` in the
/// campaign directory — the readable artifact a matrix run ends with.
pub fn write_summary(out_dir: &Path, cells: &[CellSpec]) -> Result<(PathBuf, String)> {
    let table = summary_table(out_dir, cells);
    let path = out_dir.join("summary.txt");
    std::fs::write(&path, &table)?;
    Ok((path, table))
}

// ---- artifact-free toy cells -------------------------------------------

/// The artifact-free toy preset shared by the crash-resume suite and
/// `--toy` matrix cells: two transformer layers' worth of trainable
/// matrices plus an embedding and a norm, small enough that every method
/// trains in milliseconds yet wide enough for real layer fan-out.
pub fn toy_preset() -> PresetInfo {
    let mut params = vec![ParamInfo {
        name: "embed".into(),
        shape: vec![32, 16],
    }];
    for l in 0..2 {
        for (kind, shape) in [
            ("wq", vec![16usize, 16usize]),
            ("wk", vec![16, 16]),
            ("wv", vec![16, 16]),
            ("wo", vec![16, 16]),
            ("wup", vec![16, 24]),
            ("wdown", vec![24, 16]),
        ] {
            params.push(ParamInfo {
                name: format!("l{l}.{kind}"),
                shape,
            });
        }
    }
    params.push(ParamInfo {
        name: "final_norm".into(),
        shape: vec![16],
    });
    PresetInfo {
        name: "toy".into(),
        d: 16,
        layers: 2,
        ffn: 24,
        vocab: 32,
        seq: 8,
        batch: 2,
        heads: 2,
        params,
        executables: std::collections::BTreeMap::new(),
    }
}

/// A `Ctx` over the toy preset (host-interpreter linalg, no artifacts).
pub fn toy_ctx(workers: usize, seed: u64) -> Result<Ctx> {
    Ok(Ctx {
        la: Arc::new(Linalg::new(&xla::PjRtClient::cpu()?)),
        preset: toy_preset(),
        rng: Rng::new(seed),
        adam: AdamCfg::default(),
        workers,
    })
}

pub fn toy_params(seed: u64) -> Vec<Tensor> {
    crate::model::init_params(&toy_preset(), &mut Rng::new(seed))
}

/// Synthetic gradient source for `train::train_with`: one N(0, 0.1²)
/// tensor per parameter drawn from the trainer's data RNG — a pure
/// function of the stream position, so a resumed run replays the exact
/// gradients an uninterrupted run would have seen. Loss is the mean |g|
/// of the first tensor (deterministic, finite, replayable).
pub fn synth_step(params: &[Tensor], rng: &mut Rng) -> Result<(f32, Vec<Tensor>)> {
    let grads: Vec<Tensor> = params
        .iter()
        .map(|p| Tensor::randn(&p.shape, 0.1, rng))
        .collect();
    let loss = grads[0].data.iter().map(|x| x.abs()).sum::<f32>() / grads[0].len().max(1) as f32;
    Ok((loss, grads))
}

/// One artifact-free cell: the real trainer loop over the toy preset
/// with synthetic gradients, checkpointing every `ckpt_every` steps
/// (keep-last-`ckpt_keep` retention; 0 = keep all) and resuming from
/// the cell's newest snapshot when one exists. `inner_workers` is the
/// per-cell engine pool — keep it 1 when cells themselves fan over
/// `par_map` (the outer pool already saturates the machine, and
/// determinism holds for any split either way).
pub fn run_toy_cell(
    spec: &CellSpec,
    out_dir: &Path,
    ckpt_every: usize,
    ckpt_keep: usize,
    inner_workers: usize,
) -> Result<CellOutcome> {
    let mut ctx = toy_ctx(inner_workers, 0xC311 ^ spec.seed)?;
    let mut params = toy_params(0x1717 ^ spec.seed);
    // toy matrices are 16-wide: clamp the LRA rank, not the budget
    let mut method = spec.method_with_lra(spec.rank.clamp(1, 8))?;
    let ckpt_dir = cell_ckpt_dir(out_dir, &spec.id());
    let cfg = TrainCfg {
        steps: spec.steps,
        lr: 1e-3,
        warmup_frac: 0.03,
        log_every: 0,
        seed: spec.seed,
        ckpt_every,
        ckpt_dir: Some(ckpt_dir.clone()),
        ckpt_keep,
    };
    let resume_from = ckpt::latest_snapshot(&ckpt_dir)?;
    let log = train::train_with(
        &mut synth_step,
        &mut *method,
        &mut ctx,
        &mut params,
        &cfg,
        resume_from.as_deref(),
    )?;
    Ok(CellOutcome {
        label: method.name(),
        accs: Vec::new(),
        avg: 0.0,
        tail_loss: log.tail_loss(20),
        trainable: method.trainable(),
        opt_bytes: method.opt_bytes(),
        seconds: log.seconds,
        steps: spec.steps,
    })
}

// ---- artifact-backed real cells ----------------------------------------

/// Shared knobs for [`run_real_cell`].
#[derive(Clone, Debug)]
pub struct RealCellCfg {
    pub families: Vec<TaskFamily>,
    pub pt_steps: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub ckpt_every: usize,
    /// keep-last-N snapshot retention per cell (0 = keep all)
    pub ckpt_keep: usize,
    /// per-cell engine pool; keep 1 when cells fan over `par_map`
    pub inner_workers: usize,
}

/// One real fine-tune + eval cell. Builds its own `Runtime`/`ModelExec`
/// so cells are pure functions of their spec and can execute on any
/// matrix worker; the pretrained base must be pre-warmed sequentially
/// first (the CLI does) so parallel cells hit the `runs/` cache
/// read-only. Resumes from the cell's newest snapshot when one exists.
pub fn run_real_cell(spec: &CellSpec, out_dir: &Path, rc: &RealCellCfg) -> Result<CellOutcome> {
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &spec.preset)?;
    let mut params = pretrain::ensure_pretrained(&rt, &exec, rc.pt_steps, 1)?;
    let corpus = pretrain::world(&exec);
    let sets: Vec<TaskSet> = rc
        .families
        .iter()
        .map(|&f| {
            TaskSet::generate(f, &corpus.vocab, &corpus.kg, rc.n_train, rc.n_test, spec.seed)
        })
        .collect();
    let mut src = TaskMixSource {
        sets: sets.clone(),
        batch: exec.preset.batch,
        seq: exec.preset.seq,
    };
    let mut ctx = pretrain::make_ctx(&rt, &exec, spec.seed ^ 0xabcd);
    ctx.workers = rc.inner_workers.max(1);
    let mut method = spec.method()?;
    let ckpt_dir = cell_ckpt_dir(out_dir, &spec.id());
    let cfg = TrainCfg {
        steps: spec.steps,
        lr: crate::exp::harness::default_lr(&spec.method),
        warmup_frac: 0.03,
        log_every: 0,
        seed: spec.seed,
        ckpt_every: rc.ckpt_every,
        ckpt_dir: Some(ckpt_dir.clone()),
        ckpt_keep: rc.ckpt_keep,
    };
    let log = match ckpt::latest_snapshot(&ckpt_dir)? {
        Some(snap) => train::resume(
            &exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg, &snap,
        )?,
        None => train::train(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg)?,
    };
    let mut accs = Vec::with_capacity(sets.len());
    for set in &sets {
        accs.push(crate::train::eval::accuracy(&exec, &params, &set.test)?);
    }
    let avg = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
    Ok(CellOutcome {
        label: method.name(),
        accs,
        avg,
        tail_loss: log.tail_loss(20),
        trainable: method.trainable(),
        opt_bytes: method.opt_bytes(),
        seconds: log.seconds,
        steps: spec.steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_expansion_dedupes_selector_axis() {
        let cells = expand_grid(
            "toy",
            &["lift".into(), "full".into()],
            &["lift".into(), "weight_mag".into()],
            &[4, 8],
            &[1, 2],
            10,
            5,
        );
        // 3 distinct names (lift deduped) x 2 ranks x 2 seeds
        assert_eq!(cells.len(), 12);
        let ids: std::collections::HashSet<String> = cells.iter().map(|c| c.id()).collect();
        assert_eq!(ids.len(), 12, "cell ids must be unique");
        assert!(ids.contains("toy_weight_mag_r8_s2_t10_i5"));
        // every spec field is part of the identity (a changed interval
        // must not reuse another cell's ledger entry)
        let a = CellSpec {
            preset: "toy".into(),
            method: "lift".into(),
            rank: 4,
            seed: 1,
            steps: 10,
            interval: 5,
        };
        let b = CellSpec { interval: 7, ..a.clone() };
        assert_ne!(a.id(), b.id());
    }

    #[test]
    fn summary_table_aggregates_seeds_and_marks_missing_cells() {
        let dir = std::env::temp_dir().join(format!("lift_matrix_summary_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cells = expand_grid("toy", &["lift".into(), "full".into()], &[], &[2, 4], &[1, 2], 4, 2);
        assert_eq!(cells.len(), 8);
        // finish both seeds of (lift, r=2) and one seed of (full, r=4)
        let finish = |method: &str, rank: usize, seed: u64, tail: f32| {
            let c = cells
                .iter()
                .find(|c| c.method == method && c.rank == rank && c.seed == seed)
                .unwrap();
            let out = CellOutcome {
                label: method.to_uppercase(),
                accs: Vec::new(),
                avg: 0.0,
                tail_loss: tail,
                trainable: 1,
                opt_bytes: 12,
                seconds: 0.1,
                steps: 4,
            };
            write_outcome(&dir, &c.id(), &out).unwrap();
        };
        finish("lift", 2, 1, 0.5);
        finish("lift", 2, 2, 0.7);
        finish("full", 4, 1, 0.25);
        let table = summary_table(&dir, &cells);
        assert!(table.contains("3/8 cells finished"), "{table}");
        assert!(table.contains("mean tail loss"), "toy cells report loss: {table}");
        // (lift, r=2): mean of 0.5 and 0.7 over 2 seeds
        assert!(table.contains("0.6000 (2s)"), "{table}");
        assert!(table.contains("0.2500 (1s)"), "{table}");
        // unfinished cells render as '-', and both rank columns appear
        assert!(table.contains("r=2") && table.contains("r=4"), "{table}");
        assert!(table.contains('-'), "{table}");
        let (path, persisted) = write_summary(&dir, &cells).unwrap();
        assert_eq!(persisted, table);
        assert_eq!(std::fs::read_to_string(path).unwrap(), table);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn outcome_json_roundtrip() {
        let out = CellOutcome {
            label: "LIFT".into(),
            accs: vec![0.5, 0.75],
            avg: 0.625,
            tail_loss: 0.125,
            trainable: 640,
            opt_bytes: 7680,
            seconds: 1.5,
            steps: 10,
        };
        let j = out.to_json().to_string();
        let back = CellOutcome::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, out);
        // missing fields read as not-done, not as a panic
        assert!(CellOutcome::from_json(&Json::parse("{\"label\":\"x\"}").unwrap()).is_none());
    }
}

//! Resumable scenario-matrix runner (ISSUE 3, generalized by ISSUE 5):
//! an N-dimensional grid of cells (`exp::grid` — preset × method ×
//! suite × rank × interval × seed), each persisted independently so a
//! preempted campaign reruns only its unfinished cells.
//!
//! Layout under the output directory:
//!
//! ```text
//! <out>/<cell-id>.json    the cell's outcome (written atomically on
//!                         completion; existing + parseable v2 == done)
//! <out>/<cell-id>.ckpt/   the cell's trainer snapshots
//!                         (`step_XXXXXXXX.snap`, see `crate::ckpt`)
//! <out>/summary.txt       paper-style target-vs-retention table
//! ```
//!
//! # Outcome ledger v2
//!
//! Outcome files are versioned (`"v": 2`, [`LEDGER_VERSION`]) and carry
//! the per-cell evaluation pass of `exp::retention`: target-suite scores
//! plus held-out source-domain scores and the headline `retention`
//! ratio. The versioning policy mirrors the `LIFTSNAP` snapshot
//! container:
//!
//! * a **corrupt / torn** file reads as *not done* and is recomputed —
//!   loudly, logging what was discarded (the atomic temp-file + rename
//!   write makes that window tiny);
//! * a **v1** (pre-versioning) file is finished work: [`run_matrix`]
//!   refuses to run until it is explicitly migrated ([`migrate_v1`],
//!   CLI `--migrate-v1`) or moved aside — it is **never** silently
//!   recomputed;
//! * a **future-version** file aborts the campaign (an older binary
//!   must not destroy a newer one's ledger).
//!
//! [`run_matrix`] partitions the grid into done/todo by classifying
//! outcome files, then fans the todo cells over the shared
//! `lift::engine::par_map` worker pool — resume-mid-axis: a campaign
//! interrupted anywhere in the grid skips every finished cell on rerun,
//! and a cell that crashed mid-train resumes from its newest snapshot.
//! Cell failures are collected per cell — one broken configuration
//! never aborts the rest of the campaign.
//!
//! Two cell executors share the machinery:
//! * [`run_toy_cell`] — artifact-free: the toy preset + a synthetic
//!   gradient stream through the *real* trainer loop
//!   (`train::train_with`), so checkpoint cadence, resume, the
//!   skip/recompute ledger and the retention columns are exercisable
//!   (and CI-tested, `rust/tests/{ckpt,grid}.rs`) without AOT artifacts;
//! * [`run_real_cell`] — the full fine-tune + eval path, requiring
//!   `make artifacts`.
//!
//! # Multi-runner campaigns (leases)
//!
//! [`run_matrix_with`] shards one campaign across N **uncoordinated**
//! `lift matrix` processes pointed at the same `--out` directory — on
//! one machine or many hosts over a shared filesystem. Before computing
//! a cell, a runner atomically claims it through `exp::lease`
//! (`<cell-id>.lease` created with `O_CREAT|O_EXCL` create-new
//! semantics, carrying runner id + monotonic **fencing token** + TTL
//! deadline):
//!
//! * a cell under a **live foreign lease** is *deferred* — reported in
//!   [`MatrixReport::deferred`], never recomputed while its holder
//!   lives;
//! * an **expired** lease (crashed runner) is **taken over** at a
//!   strictly higher fencing token; the takeover's checkpoint dir is
//!   keyed by that token ([`cell_ckpt_dir_fenced`],
//!   `<cell-id>.t<token>.ckpt`), so a displaced zombie's late snapshot
//!   writes land in a dir nobody reads;
//! * the outcome **commit is fenced**: [`write_outcome`] goes through a
//!   per-(runner, token) temp name and only commits while the on-disk
//!   lease still carries exactly this runner's winning token — a zombie
//!   that stalls past its TTL refuses its own commit instead of racing
//!   the usurper;
//! * after the outcome lands the lease is released; a crash between
//!   commit and release is garbage-collected on the next classify pass.
//!
//! Campaign-level merge correctness: cells are pure functions of their
//! spec, so N runners' merged ledger is bit-identical (modulo the
//! wall-clock `seconds` field) to a single-runner run — CI races two
//! runners over one campaign and diffs exactly that
//! (`make matrix-race`). Single-process use is unchanged:
//! [`run_matrix`] runs lease-free (`--no-lease` at the CLI).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::ckpt;
use crate::data::tasks::{suite_families, TaskMixSource, TaskSet};
use crate::exp::grid::{Axis, Grid};
use crate::exp::lease::{self, Claim, LeaseCfg, LeaseGuard};
use crate::exp::retention::{self, RetentionCfg, SuiteScores};
use crate::lift::engine::par_map;
use crate::lift::LiftCfg;
use crate::methods::{make_method, Ctx, Method, Scope};
use crate::optim::AdamCfg;
use crate::runtime::manifest::{ParamInfo, PresetInfo};
use crate::runtime::model_exec::ModelExec;
use crate::runtime::{Linalg, Runtime};
use crate::tensor::Tensor;
use crate::train::{self, pretrain, TrainCfg};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One cell of the scenario grid. The selector axis rides the method
/// axis: sparse selectors ARE `make_method` names (lift, weight_mag,
/// grad_mag, movement, random, sift), so a grid over
/// `methods ∪ selectors × …` covers method × selector × sparsity
/// without a redundant third constructor path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CellSpec {
    pub preset: String,
    pub method: String,
    /// named target suite (`data::tasks::suite_families`)
    pub suite: String,
    /// LoRA-rank-equivalent sparsity budget (`lift::budget_for`).
    pub rank: usize,
    pub seed: u64,
    pub steps: usize,
    /// mask refresh interval handed to `make_method`
    pub interval: usize,
    /// quantized rank-reduce scan (`LiftCfg.qscan`, ISSUE 10)
    pub qscan: bool,
}

impl CellSpec {
    /// Stable cell identity over EVERY spec field — outcome file and
    /// checkpoint dir both key on it, so changing the spec (including
    /// the suite or refresh interval) is a new cell, never a stale
    /// reuse. Pure function of the field values: axis order, CLI
    /// spelling, etc. cannot move a cell (golden-locked by
    /// `rust/tests/grid.rs`).
    pub fn id(&self) -> String {
        // qscan=false must stay byte-identical to the pre-qscan id so
        // every existing ledger outcome and checkpoint dir still keys
        // correctly; only the opt-in variant gains a marker.
        let q = if self.qscan { "_q1" } else { "" };
        format!(
            "{}_{}_{}_r{}_s{}_t{}_i{}{}",
            self.preset, self.method, self.suite, self.rank, self.seed, self.steps, self.interval, q
        )
    }

    /// The id this cell had under the pre-suite v1 ledger — where
    /// [`migrate_v1`] looks for finished v1 outcomes and orphaned v1
    /// checkpoint dirs.
    pub fn v1_id(&self) -> String {
        format!(
            "{}_{}_r{}_s{}_t{}_i{}",
            self.preset, self.method, self.rank, self.seed, self.steps, self.interval
        )
    }

    /// Construct the cell's method with an explicit LRA rank (the toy
    /// preset's matrices are too small for large ranks).
    pub fn method_with_lra(&self, lra_rank: usize) -> Result<Box<dyn Method>> {
        make_method(
            &self.method,
            self.rank,
            LiftCfg {
                rank: lra_rank,
                qscan: self.qscan,
                ..Default::default()
            },
            self.interval,
            Scope::default(),
        )
    }

    pub fn method(&self) -> Result<Box<dyn Method>> {
        self.method_with_lra(self.rank)
    }
}

/// Version of the on-disk outcome schema this binary reads and writes.
pub const LEDGER_VERSION: u64 = 2;

/// Why an outcome file did not read as a finished v2 cell.
#[derive(Clone, Debug, PartialEq)]
pub enum LedgerError {
    /// pre-versioning (PR 3/4) outcome — finished work, needs migration
    V1,
    /// written by a newer lift than this binary
    Future(u64),
    /// unparseable / missing fields — carries what was discarded
    Corrupt(String),
}

impl std::fmt::Display for LedgerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LedgerError::V1 => write!(f, "v1 (pre-versioning) outcome"),
            LedgerError::Future(v) => {
                write!(f, "ledger version {v} is newer than this binary's v{LEDGER_VERSION}")
            }
            LedgerError::Corrupt(why) => write!(f, "corrupt outcome: {why}"),
        }
    }
}

impl std::error::Error for LedgerError {}

/// Persisted result of one finished cell (ledger v2).
#[derive(Clone, Debug, PartialEq)]
pub struct CellOutcome {
    pub label: String,
    /// accuracy per target family (empty for toy cells)
    pub accs: Vec<f64>,
    pub avg: f64,
    pub tail_loss: f32,
    pub trainable: usize,
    pub opt_bytes: usize,
    pub seconds: f64,
    pub steps: usize,
    /// target-suite scores (`None` only on migrated v1 entries)
    pub target: Option<SuiteScores>,
    /// held-out source-domain scores (`None` for toy / migrated cells)
    pub source: Option<SuiteScores>,
    /// headline source retention (`exp::retention`): real cells the
    /// post/pre fact-recall ratio, toy cells the untouched-weight
    /// fraction; `None` where unmeasurable
    pub retention: Option<f64>,
}

impl CellOutcome {
    pub fn to_json(&self) -> Json {
        let scores = |s: &Option<SuiteScores>| match s {
            Some(s) => s.to_json(),
            None => Json::Null,
        };
        Json::obj(vec![
            ("v", Json::from(LEDGER_VERSION as usize)),
            ("label", Json::str(&self.label)),
            ("accs", Json::arr(self.accs.iter().map(|&a| Json::num(a)))),
            ("avg", Json::num(self.avg)),
            ("tail_loss", Json::num(self.tail_loss as f64)),
            ("trainable", Json::from(self.trainable)),
            ("opt_bytes", Json::from(self.opt_bytes)),
            ("seconds", Json::num(self.seconds)),
            ("steps", Json::from(self.steps)),
            ("target", scores(&self.target)),
            ("source", scores(&self.source)),
            ("retention", retention::opt_json(self.retention)),
        ])
    }

    /// Version-aware parse. A v1 file (or an unknown/future version) is
    /// a typed error, never a silent `None` — the caller decides whether
    /// that means refuse, migrate, or recompute-with-logging; see the
    /// module policy.
    pub fn from_json(j: &Json) -> Result<CellOutcome, LedgerError> {
        let v = match j.get("v").and_then(|v| v.as_f64()) {
            Some(v) => v as u64,
            None => {
                return Err(if v1_fields(j).is_some() {
                    LedgerError::V1
                } else {
                    LedgerError::Corrupt(
                        "no ledger version field and not a recognizable v1 outcome".into(),
                    )
                });
            }
        };
        if v == 1 {
            return Err(LedgerError::V1);
        }
        if v > LEDGER_VERSION {
            return Err(LedgerError::Future(v));
        }
        if v != LEDGER_VERSION {
            return Err(LedgerError::Corrupt(format!("unknown ledger version {v}")));
        }
        v2_fields(j).ok_or_else(|| {
            LedgerError::Corrupt("v2 outcome is missing fields or has mistyped ones".into())
        })
    }
}

/// The fields shared by the v1 and v2 schemas, with the v2-only columns
/// left empty.
fn base_fields(j: &Json) -> Option<CellOutcome> {
    Some(CellOutcome {
        label: j.get("label")?.as_str()?.to_string(),
        accs: j
            .get("accs")?
            .as_arr()?
            .iter()
            .map(|x| x.as_f64())
            .collect::<Option<Vec<_>>>()?,
        avg: j.get("avg")?.as_f64()?,
        tail_loss: j.get("tail_loss")?.as_f64()? as f32,
        trainable: j.get("trainable")?.as_usize()?,
        opt_bytes: j.get("opt_bytes")?.as_usize()?,
        seconds: j.get("seconds")?.as_f64()?,
        steps: j.get("steps")?.as_usize()?,
        target: None,
        source: None,
        retention: None,
    })
}

/// A pre-versioning outcome: all v1 fields present, no version marker.
/// Migration maps it onto v2 with empty retention columns.
fn v1_fields(j: &Json) -> Option<CellOutcome> {
    if j.get("v").is_some() {
        return None;
    }
    base_fields(j)
}

fn v2_fields(j: &Json) -> Option<CellOutcome> {
    let scores = |key: &str| -> Option<Option<SuiteScores>> {
        match j.get(key)? {
            Json::Null => Some(None),
            v => Some(Some(SuiteScores::from_json(v)?)),
        }
    };
    let mut out = base_fields(j)?;
    out.target = scores("target")?;
    out.source = scores("source")?;
    out.retention = match j.get("retention")? {
        Json::Null => None,
        v => Some(v.as_f64()?),
    };
    Some(out)
}

/// Expand the method × selector × sparsity × seed grid of the v1 CLI;
/// the selector axis is deduplicated into the method axis (see
/// [`CellSpec`]). Kept as the simple-flags entry point — richer grids go
/// through `exp::grid::Grid` directly. The suite axis takes its default
/// (`arith`).
pub fn expand_grid(
    preset: &str,
    methods: &[String],
    selectors: &[String],
    ranks: &[usize],
    seeds: &[u64],
    steps: usize,
    interval: usize,
) -> Vec<CellSpec> {
    Grid::new(steps)
        .with_axis(Axis::Preset(vec![preset.to_string()]))
        .with_axis(Axis::Method(methods.to_vec()))
        .with_axis(Axis::Method(selectors.to_vec()))
        .with_axis(Axis::Rank(ranks.to_vec()))
        .with_axis(Axis::Seed(seeds.to_vec()))
        .with_axis(Axis::Interval(vec![interval]))
        .expand()
}

pub fn outcome_path(out_dir: &Path, id: &str) -> PathBuf {
    out_dir.join(format!("{id}.json"))
}

pub fn cell_ckpt_dir(out_dir: &Path, id: &str) -> PathBuf {
    out_dir.join(format!("{id}.ckpt"))
}

/// The cell's checkpoint dir under a lease: keyed by the claim's fencing
/// token (`<id>.t<token>.ckpt`), so a runner that takes over an expired
/// lease (token + 1) NEVER shares a snapshot dir with the zombie it
/// displaced — a stalled writer's late snapshots land in a dir nobody
/// resumes from. Lease-free runs (`token = None`) keep the plain
/// `<id>.ckpt`.
pub fn cell_ckpt_dir_fenced(out_dir: &Path, id: &str, token: Option<u64>) -> PathBuf {
    match token {
        Some(t) => out_dir.join(format!("{id}.t{t}.ckpt")),
        None => cell_ckpt_dir(out_dir, id),
    }
}

/// What the ledger holds for one cell id.
#[derive(Clone, Debug)]
pub enum LedgerEntry {
    Missing,
    Done(Box<CellOutcome>),
    V1,
    Future(u64),
    Corrupt(String),
    /// The file exists but could not be READ (`EACCES`, `EIO`, an NFS
    /// hiccup…). Distinct from `Corrupt` — bad bytes prove the cell
    /// unfinished, a failed read proves nothing — so the campaign
    /// aborts instead of recomputing over possibly-finished work.
    Unreadable(String),
}

/// Classify a cell's outcome file without committing to a policy.
pub fn classify_outcome(out_dir: &Path, id: &str) -> LedgerEntry {
    let path = outcome_path(out_dir, id);
    let s = match crate::util::fault::read_to_string(&path) {
        Ok(s) => s,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return LedgerEntry::Missing,
        Err(e) => return LedgerEntry::Unreadable(format!("{} reading {}", e, path.display())),
    };
    let j = match Json::parse(&s) {
        Ok(j) => j,
        Err(e) => {
            let head: String = s.chars().take(48).collect();
            return LedgerEntry::Corrupt(format!("unparseable ({e}); starts {head:?}"));
        }
    };
    match CellOutcome::from_json(&j) {
        Ok(o) => LedgerEntry::Done(Box::new(o)),
        Err(LedgerError::V1) => LedgerEntry::V1,
        Err(LedgerError::Future(v)) => LedgerEntry::Future(v),
        Err(LedgerError::Corrupt(why)) => LedgerEntry::Corrupt(why),
    }
}

/// A cell's persisted outcome, if it exists AND parses as the current
/// ledger version. Anything else reads as `None` *with a log line
/// naming what was discarded* — and [`run_matrix`] additionally refuses
/// to recompute over v1/future entries rather than wasting their
/// finished work (this function is the render-side convenience; the
/// policy gate lives in `run_matrix`).
pub fn read_outcome(out_dir: &Path, id: &str) -> Option<CellOutcome> {
    match classify_outcome(out_dir, id) {
        LedgerEntry::Done(o) => Some(*o),
        LedgerEntry::Missing => None,
        LedgerEntry::V1 => {
            log::warn!(
                "outcome {id} is a v1 ledger entry — not readable as v{LEDGER_VERSION}; \
                 migrate with `lift matrix --migrate-v1`"
            );
            None
        }
        LedgerEntry::Future(v) => {
            log::warn!(
                "outcome {id} was written by ledger v{v} (> v{LEDGER_VERSION}); refusing to read"
            );
            None
        }
        LedgerEntry::Corrupt(why) => {
            log::warn!("discarding corrupt outcome {id}: {why}");
            None
        }
        LedgerEntry::Unreadable(why) => {
            log::warn!("outcome {id} could not be read ({why}); treating as unfinished for rendering only");
            None
        }
    }
}

/// A finished v1 outcome at the given (v1) id, if present.
fn read_v1(out_dir: &Path, id: &str) -> Option<CellOutcome> {
    let s = std::fs::read_to_string(outcome_path(out_dir, id)).ok()?;
    v1_fields(&Json::parse(&s).ok()?)
}

/// Atomically commit a cell outcome through the hardened same-dir
/// writer (`ckpt::write_atomic_as`): temp file next to the destination,
/// then rename, with error context naming the cell. `tmp_tag`
/// distinguishes concurrent writers — the lease path tags with
/// `(runner id, fencing token)` so two runners finishing the same cell
/// can never interleave bytes into one temp file and rename a torn
/// outcome into place. The lease-free single-process tag is `"tmp"`,
/// reproducing the historical `<id>.json.tmp` name.
pub fn write_outcome_tagged(
    out_dir: &Path,
    id: &str,
    out: &CellOutcome,
    tmp_tag: &str,
) -> Result<()> {
    let path = outcome_path(out_dir, id);
    let tmp = out_dir.join(format!("{id}.json.{tmp_tag}"));
    ckpt::write_atomic_as(&path, &tmp, out.to_json().to_string().as_bytes())
        .with_context(|| format!("committing outcome for cell {id}"))
}

pub fn write_outcome(out_dir: &Path, id: &str, out: &CellOutcome) -> Result<()> {
    write_outcome_tagged(out_dir, id, out, "tmp")
}

/// Explicitly migrate a campaign directory's v1 ledger onto the given
/// cells: finished v1 outcomes are rewritten as v2 under the cell's v2
/// id (every v1 field preserved; the retention columns start empty and
/// render `-`), and orphaned v1 checkpoint dirs are renamed so
/// interrupted v1 cells resume instead of restarting. Every move is
/// logged. Returns the ids whose outcome was migrated.
///
/// A v1 id records no suite, so a v1 artifact can only be migrated when
/// the grid maps it onto exactly ONE v2 cell — if the grid sweeps
/// several suites, the migration would have to guess which suite the v1
/// campaign trained, and a wrong guess silently mislabels finished
/// work. That case is refused: rerun with the single original suite.
pub fn migrate_v1(out_dir: &Path, cells: &[CellSpec]) -> Result<Vec<String>> {
    let mut by_v1: std::collections::BTreeMap<String, Vec<&CellSpec>> =
        std::collections::BTreeMap::new();
    for c in cells {
        by_v1.entry(c.v1_id()).or_default().push(c);
    }
    let mut migrated = Vec::new();
    for (v1, candidates) in &by_v1 {
        // a v1-FORMAT file already sitting at a v2 path (hand-renamed)
        // names its suite in the filename — always unambiguous, rewrite
        // in place
        for c in candidates {
            let id = c.id();
            if matches!(classify_outcome(out_dir, &id), LedgerEntry::V1) {
                if let Some(out) = read_v1(out_dir, &id) {
                    write_outcome(out_dir, &id, &out)?;
                    log::info!(
                        "migrated v1-format outcome at {id} in place \
                         (retention columns start empty)"
                    );
                    migrated.push(id);
                }
            }
        }
        // artifacts under the suite-less v1 id need the unambiguity check
        let v1_outcome = read_v1(out_dir, v1);
        let v1_ckpt = cell_ckpt_dir(out_dir, v1);
        if v1_outcome.is_none() && !v1_ckpt.is_dir() {
            continue;
        }
        if candidates.len() > 1 {
            let suites: Vec<&str> = candidates.iter().map(|c| c.suite.as_str()).collect();
            anyhow::bail!(
                "cannot migrate v1 cell {v1}: the grid maps it onto {} v2 cells \
                 (suites {}) and a v1 ledger records no suite — rerun --migrate-v1 \
                 with only the suite the v1 campaign actually trained",
                candidates.len(),
                suites.join(", ")
            );
        }
        let c = candidates[0];
        let id = c.id();
        if let Some(out) = v1_outcome {
            if !matches!(classify_outcome(out_dir, &id), LedgerEntry::Done(_)) {
                write_outcome(out_dir, &id, &out)?;
                std::fs::remove_file(outcome_path(out_dir, v1))?;
                log::info!("migrated v1 outcome {v1} -> {id} (retention columns start empty)");
                migrated.push(id.clone());
            }
        }
        // snapshots: an interrupted v1 cell has a ckpt dir but no outcome
        let new_ckpt = cell_ckpt_dir(out_dir, &id);
        if v1_ckpt.is_dir() && !new_ckpt.exists() {
            std::fs::rename(&v1_ckpt, &new_ckpt)?;
            log::info!(
                "migrated v1 checkpoint dir {} -> {}",
                v1_ckpt.display(),
                new_ckpt.display()
            );
        }
    }
    Ok(migrated)
}

#[derive(Debug, Default)]
pub struct MatrixReport {
    /// cells executed this run (outcome written)
    pub ran: Vec<String>,
    /// cells whose outcome already existed — not recomputed
    pub skipped: Vec<String>,
    /// (cell id, error) — the rest of the campaign still completes
    pub failed: Vec<(String, String)>,
    /// (cell id, reason) — cells under another runner's live lease
    /// (or finished by it mid-claim): not ours to compute, not a
    /// failure. A co-runner lands them; rerun to pick up stragglers.
    pub deferred: Vec<(String, String)>,
}

/// How one todo cell resolved inside the worker pool.
enum CellRun {
    Ran,
    Skipped(String),
    Deferred(String),
    Failed(String),
}

/// Lease-free [`run_matrix_with`]: the single-process entry point the
/// in-repo suites use. `run_cell` gets only the spec and routes through
/// the plain `<id>.ckpt` checkpoint dir.
pub fn run_matrix<F>(
    out_dir: &Path,
    cells: &[CellSpec],
    workers: usize,
    run_cell: F,
) -> Result<MatrixReport>
where
    F: Fn(&CellSpec) -> Result<CellOutcome> + Sync,
{
    run_matrix_with(out_dir, cells, workers, None, |spec, _ckpt_dir| run_cell(spec))
}

/// Run every unfinished cell of the grid, fanned over
/// `lift::engine::par_map`. `run_cell(spec, ckpt_dir)` must be a pure
/// function of the spec (cells execute on any worker in any order, and
/// under leases on any RUNNER) and must persist snapshots under the
/// `ckpt_dir` it is handed — under a lease that dir is fenced by the
/// claim's token ([`cell_ckpt_dir_fenced`]).
///
/// Ledger policy (see the module doc): finished v2 cells are skipped,
/// corrupt files are recomputed loudly, an UNREADABLE outcome aborts
/// the campaign (an IO error proves nothing about the cell — aborting
/// beats destroying finished work), and the campaign **refuses to
/// start** while v1 or future-version entries are present.
///
/// With `lease: Some(cfg)` the multi-runner protocol is active (module
/// doc): claim → renew → compute → fenced commit → release, deferring
/// cells other runners hold.
pub fn run_matrix_with<F>(
    out_dir: &Path,
    cells: &[CellSpec],
    workers: usize,
    lease: Option<&LeaseCfg>,
    run_cell: F,
) -> Result<MatrixReport>
where
    F: Fn(&CellSpec, &Path) -> Result<CellOutcome> + Sync,
{
    std::fs::create_dir_all(out_dir)?;
    let mut report = MatrixReport::default();
    let mut todo: Vec<&CellSpec> = Vec::new();
    let mut v1_pending: Vec<String> = Vec::new();
    for c in cells {
        let id = c.id();
        match classify_outcome(out_dir, &id) {
            LedgerEntry::Done(_) => {
                // a crash between outcome-commit and lease-release
                // leaves a lease on a finished cell; collect it (ours
                // or expired only) so the id stops looking busy
                if let Some(cfg) = lease {
                    lease::gc_finished(out_dir, &id, cfg)?;
                }
                report.skipped.push(id);
            }
            LedgerEntry::V1 => v1_pending.push(format!("{id} (v1 format at the v2 path)")),
            LedgerEntry::Future(v) => anyhow::bail!(
                "outcome {id} under {out_dir:?} was written by ledger v{v}, newer than this \
                 binary's v{LEDGER_VERSION} — refusing to run over it; upgrade lift or point \
                 --out at a fresh directory"
            ),
            LedgerEntry::Corrupt(why) => {
                log::warn!("outcome {id} is corrupt ({why}); recomputing the cell");
                todo.push(c);
            }
            LedgerEntry::Unreadable(why) => anyhow::bail!(
                "outcome {id} under {out_dir:?} exists but could not be read: {why}\na read \
                 error does not prove the cell unfinished — refusing to recompute over \
                 possibly-finished work; fix the IO problem (permissions, NFS) and rerun"
            ),
            LedgerEntry::Missing => {
                let v1 = c.v1_id();
                if read_v1(out_dir, &v1).is_some() {
                    v1_pending.push(format!("{v1} (finished v1 cell)"));
                } else {
                    // a v1-era file that does not even parse as v1 is
                    // corrupt: recompute, but say what is being ignored
                    // (the loud-recompute policy applies to v1 too)
                    let v1_path = outcome_path(out_dir, &v1);
                    if v1_path.exists() {
                        log::warn!(
                            "ignoring unreadable v1-era outcome file {} (recomputing cell {id})",
                            v1_path.display()
                        );
                    }
                    todo.push(c);
                }
            }
        }
    }
    if !v1_pending.is_empty() {
        anyhow::bail!(
            "{} v1 ledger file(s) under {out_dir:?}:\n  {}\nthese hold finished work this \
             binary would otherwise recompute — migrate them with `lift matrix --migrate-v1` \
             (or `exp::matrix::migrate_v1`), or point --out at a fresh directory",
            v1_pending.len(),
            v1_pending.join("\n  ")
        );
    }
    log::info!(
        "matrix: {} cells, {} done, {} to run ({} workers{})",
        cells.len(),
        report.skipped.len(),
        todo.len(),
        workers.max(1),
        match lease {
            Some(cfg) => format!(", runner {} ttl {}s", cfg.runner, cfg.ttl_secs),
            None => String::new(),
        }
    );
    // Test hook for the CI kill/resume smoke: LIFT_MATRIX_KILL_AFTER=N
    // hard-exits the process (code 41) once N cell outcomes have LANDED
    // on disk this run — after write_outcome but BEFORE lease release,
    // so exactly N finished cells are skippable on resume while other
    // workers die mid-cell (a faithful `kill -9` mid-campaign, leases
    // and all — the killed runner's leases are reclaimed by runner id
    // or recovered by TTL).
    let kill_after: Option<usize> = std::env::var("LIFT_MATRIX_KILL_AFTER")
        .ok()
        .and_then(|v| v.parse().ok());
    let landed = std::sync::atomic::AtomicUsize::new(0);
    let results = par_map(workers.max(1), todo, |_, spec| {
        let id = spec.id();
        (
            id.clone(),
            run_claimed_cell(out_dir, spec, &id, lease, &run_cell, kill_after, &landed),
        )
    });
    for (id, res) in results {
        match res {
            CellRun::Ran => report.ran.push(id),
            CellRun::Skipped(_) => report.skipped.push(id),
            CellRun::Deferred(why) => report.deferred.push((id, why)),
            CellRun::Failed(e) => report.failed.push((id, e)),
        }
    }
    Ok(report)
}

/// [`run_matrix_with`] plus a bounded re-poll over `Deferred` cells:
/// after the main pass, cells another runner held (or whose lease was
/// unreadable) are retried up to `defer_retries` times, restricted to
/// the still-deferred subset each round. The first re-poll is immediate
/// (the common case — a co-runner released between classify and
/// re-poll); later rounds sleep half the lease TTL, clamped to 1..=10
/// seconds so a long TTL cannot stall a CI smoke. Deferrals that
/// survive every round stay in `report.deferred` — the report never
/// hides them.
pub fn run_matrix_retry<F>(
    out_dir: &Path,
    cells: &[CellSpec],
    workers: usize,
    lease: Option<&LeaseCfg>,
    defer_retries: usize,
    run_cell: F,
) -> Result<MatrixReport>
where
    F: Fn(&CellSpec, &Path) -> Result<CellOutcome> + Sync,
{
    let mut report = run_matrix_with(out_dir, cells, workers, lease, &run_cell)?;
    for round in 0..defer_retries {
        if report.deferred.is_empty() {
            break;
        }
        if round > 0 {
            let ttl = lease.map(|c| c.ttl_secs).unwrap_or(0);
            let secs = ((ttl + 1) / 2).clamp(1, 10);
            log::info!(
                "matrix: {} deferral(s) after re-poll {round}; sleeping {secs}s before the next",
                report.deferred.len()
            );
            std::thread::sleep(std::time::Duration::from_secs(secs));
        }
        let pending: Vec<CellSpec> = {
            let ids: std::collections::BTreeSet<&str> =
                report.deferred.iter().map(|(id, _)| id.as_str()).collect();
            cells.iter().filter(|c| ids.contains(c.id().as_str())).cloned().collect()
        };
        log::info!(
            "matrix: re-polling {} deferred cell(s) (round {}/{defer_retries})",
            pending.len(),
            round + 1
        );
        let sub = run_matrix_with(out_dir, &pending, workers, lease, &run_cell)?;
        report.deferred = sub.deferred;
        report.ran.extend(sub.ran);
        report.skipped.extend(sub.skipped);
        report.failed.extend(sub.failed);
    }
    Ok(report)
}

/// One worker's handling of one todo cell: claim (when leases are on),
/// recheck the ledger under the claim, compute into the fenced
/// checkpoint dir, commit through the fence, release.
fn run_claimed_cell<F>(
    out_dir: &Path,
    spec: &CellSpec,
    id: &str,
    lease: Option<&LeaseCfg>,
    run_cell: &F,
    kill_after: Option<usize>,
    landed: &std::sync::atomic::AtomicUsize,
) -> CellRun
where
    F: Fn(&CellSpec, &Path) -> Result<CellOutcome> + Sync,
{
    let guard: Option<LeaseGuard> = match lease {
        None => None,
        Some(cfg) => match lease::claim(out_dir, id, cfg) {
            Ok(Claim::Held(g)) => Some(g),
            Ok(Claim::Busy { holder, expires_unix }) => {
                return CellRun::Deferred(format!(
                    "held by runner {holder} (lease expires at unix {expires_unix})"
                ));
            }
            // Unreadable ≠ corrupt ≠ missing: the lease file exists but
            // its bytes never came back, so a live holder cannot be
            // ruled out. Defer (retryable) instead of claiming over a
            // possibly-live runner or failing the whole campaign.
            Ok(Claim::Unreadable { why }) => {
                return CellRun::Deferred(format!("lease unreadable: {why}"));
            }
            Err(e) => return CellRun::Failed(format!("lease claim: {e:#}")),
        },
    };
    if guard.is_some() {
        // the ledger was classified before the claim; a co-runner may
        // have finished this cell in between — recheck under the claim
        // so a finished cell is never recomputed
        if matches!(classify_outcome(out_dir, id), LedgerEntry::Done(_)) {
            if let Err(e) = guard.expect("guard checked above").release() {
                log::warn!("cell {id}: releasing lease on already-done cell: {e:#}");
            }
            return CellRun::Skipped("finished by another runner between classify and claim".into());
        }
        // one renewal right before compute: the TTL countdown starts at
        // the work, not at however long the cell sat in the queue
        if let Err(e) = guard.as_ref().expect("guard checked above").renew() {
            return CellRun::Deferred(format!("lease lost before compute: {e:#}"));
        }
    }
    let ckpt_dir = cell_ckpt_dir_fenced(out_dir, id, guard.as_ref().map(|g| g.token()));
    let computed = run_cell(spec, &ckpt_dir);
    let run = match computed {
        Ok(out) => {
            // fenced commit: only while the on-disk lease still carries
            // exactly our (runner, token). Losing the fence is a defer,
            // not a failure — the usurper recomputes and commits.
            if let Some(g) = &guard {
                if !g.still_held() {
                    return CellRun::Deferred(
                        "lease lost before commit (taken over after TTL expiry) — \
                         refusing to write over the takeover runner's cell"
                            .into(),
                    );
                }
            }
            let tag = match &guard {
                Some(g) => format!("{}.t{}.tmp", g.runner(), g.token()),
                None => "tmp".to_string(),
            };
            match write_outcome_tagged(out_dir, id, &out, &tag) {
                Ok(()) => {
                    if let Some(n) = kill_after {
                        if landed.fetch_add(1, std::sync::atomic::Ordering::SeqCst) + 1 >= n {
                            eprintln!(
                                "LIFT_MATRIX_KILL_AFTER={n}: killing the campaign after cell {id}"
                            );
                            std::process::exit(41);
                        }
                    }
                    CellRun::Ran
                }
                Err(e) => CellRun::Failed(format!("{e:#}")),
            }
        }
        Err(e) => CellRun::Failed(format!("{e:#}")),
    };
    if let Some(g) = guard {
        if let Err(e) = g.release() {
            log::warn!("cell {id}: lease release failed: {e:#}");
        }
    }
    run
}

// ---- campaign summary ---------------------------------------------------

/// Paper-style summary over the persisted cell outcomes: rows are
/// methods, and each sparsity budget (rank) contributes a `tgt` column
/// (mean over seeds — and any other swept axes — of the target metric:
/// average task accuracy for real cells, tail loss for `--toy` cells)
/// and a `ret` column (mean source retention, `exp::retention` — the
/// paper's "LIFT forgets less" claim as a table). Cells without a
/// finished v2 outcome render as `-`, so an empty, partially-run,
/// all-failed or partially-corrupt campaign still summarizes cleanly
/// (regression-tested by `rust/tests/grid.rs`).
pub fn summary_table(out_dir: &Path, cells: &[CellSpec]) -> String {
    let mut methods: Vec<String> = Vec::new();
    let mut ranks: Vec<usize> = Vec::new();
    for c in cells {
        if !methods.contains(&c.method) {
            methods.push(c.method.clone());
        }
        if !ranks.contains(&c.rank) {
            ranks.push(c.rank);
        }
    }
    ranks.sort_unstable();
    #[derive(Default)]
    struct Agg {
        avg: f64,
        tail: f64,
        n: usize,
        ret: f64,
        n_ret: usize,
        label: String,
    }
    let mut agg: std::collections::BTreeMap<(String, usize), Agg> =
        std::collections::BTreeMap::new();
    let mut done = 0usize;
    let mut any_acc = false;
    for c in cells {
        if let Some(o) = read_outcome(out_dir, &c.id()) {
            done += 1;
            any_acc |= !o.accs.is_empty();
            let e = agg.entry((c.method.clone(), c.rank)).or_default();
            if e.label.is_empty() {
                e.label = o.label.clone();
            }
            e.avg += o.avg;
            e.tail += o.tail_loss as f64;
            e.n += 1;
            if let Some(r) = o.retention {
                e.ret += r;
                e.n_ret += 1;
            }
        }
    }
    let metric = if any_acc { "mean avg accuracy" } else { "mean tail loss" };
    let mut out = format!(
        "scenario matrix: {done}/{} cells finished | tgt = {metric} over seeds | \
         ret = mean source retention (1.0 = nothing forgotten)\n\n",
        cells.len()
    );
    out.push_str(&format!("{:<18}", "method"));
    for &r in &ranks {
        out.push_str(&format!("{:>14}{:>10}", format!("r={r} tgt"), format!("r={r} ret")));
    }
    out.push('\n');
    for m in &methods {
        // prefer the method's self-reported label when any cell finished
        let label = ranks
            .iter()
            .find_map(|r| {
                agg.get(&(m.clone(), *r))
                    .map(|e| e.label.clone())
                    .filter(|l| !l.is_empty())
            })
            .unwrap_or_else(|| m.clone());
        out.push_str(&format!("{label:<18}"));
        for &r in &ranks {
            match agg.get(&(m.clone(), r)) {
                Some(e) if e.n > 0 => {
                    let sum = if any_acc { e.avg } else { e.tail };
                    let v = sum / e.n as f64;
                    out.push_str(&format!("{:>14}", format!("{v:.4} ({}s)", e.n)));
                    if e.n_ret > 0 {
                        out.push_str(&format!("{:>10}", format!("{:.4}", e.ret / e.n_ret as f64)));
                    } else {
                        out.push_str(&format!("{:>10}", "-"));
                    }
                }
                _ => out.push_str(&format!("{:>14}{:>10}", "-", "-")),
            }
        }
        out.push('\n');
    }
    out
}

/// Render [`summary_table`] and persist it as `summary.txt` in the
/// campaign directory — the readable artifact a matrix run ends with.
pub fn write_summary(out_dir: &Path, cells: &[CellSpec]) -> Result<(PathBuf, String)> {
    let table = summary_table(out_dir, cells);
    let path = out_dir.join("summary.txt");
    std::fs::write(&path, &table)?;
    Ok((path, table))
}

// ---- artifact-free toy cells -------------------------------------------

/// The artifact-free toy preset shared by the crash-resume suite and
/// `--toy` matrix cells: two transformer layers' worth of trainable
/// matrices plus an embedding and a norm, small enough that every method
/// trains in milliseconds yet wide enough for real layer fan-out.
pub fn toy_preset() -> PresetInfo {
    let mut params = vec![ParamInfo {
        name: "embed".into(),
        shape: vec![32, 16],
    }];
    for l in 0..2 {
        for (kind, shape) in [
            ("wq", vec![16usize, 16usize]),
            ("wk", vec![16, 16]),
            ("wv", vec![16, 16]),
            ("wo", vec![16, 16]),
            ("wup", vec![16, 24]),
            ("wdown", vec![24, 16]),
        ] {
            params.push(ParamInfo {
                name: format!("l{l}.{kind}"),
                shape,
            });
        }
    }
    params.push(ParamInfo {
        name: "final_norm".into(),
        shape: vec![16],
    });
    PresetInfo {
        name: "toy".into(),
        d: 16,
        layers: 2,
        ffn: 24,
        vocab: 32,
        seq: 8,
        batch: 2,
        heads: 2,
        params,
        executables: std::collections::BTreeMap::new(),
    }
}

/// A `Ctx` over the toy preset (host-interpreter linalg, no artifacts).
pub fn toy_ctx(workers: usize, seed: u64) -> Result<Ctx> {
    Ok(Ctx {
        la: Arc::new(Linalg::new(&xla::PjRtClient::cpu()?)),
        preset: toy_preset(),
        rng: Rng::new(seed),
        adam: AdamCfg::default(),
        workers,
    })
}

pub fn toy_params(seed: u64) -> Vec<Tensor> {
    crate::model::init_params(&toy_preset(), &mut Rng::new(seed))
}

/// Synthetic gradient source for `train::train_with`: one N(0, 0.1²)
/// tensor per parameter drawn from the trainer's data RNG — a pure
/// function of the stream position, so a resumed run replays the exact
/// gradients an uninterrupted run would have seen. Loss is the mean |g|
/// of the first tensor (deterministic, finite, replayable).
pub fn synth_step(params: &[Tensor], rng: &mut Rng) -> Result<(f32, Vec<Tensor>)> {
    let grads: Vec<Tensor> = params
        .iter()
        .map(|p| Tensor::randn(&p.shape, 0.1, rng))
        .collect();
    let loss = grads[0].data.iter().map(|x| x.abs()).sum::<f32>() / grads[0].len().max(1) as f32;
    Ok((loss, grads))
}

/// One artifact-free cell: the real trainer loop over the toy preset
/// with synthetic gradients, checkpointing every `ckpt_every` steps
/// (keep-last-`ckpt_keep` retention; 0 = keep all) and resuming from
/// the cell's newest snapshot when one exists. `inner_workers` is the
/// per-cell engine pool — keep it 1 when cells themselves fan over
/// `par_map` (the outer pool already saturates the machine, and
/// determinism holds for any split either way).
///
/// Toy cells have no executable model, so their ledger entry carries
/// the artifact-free retention proxy: `target.perplexity` is the tail
/// training perplexity and `retention` the untouched-weight fraction
/// (`exp::retention::toy_retention`) — both bit-deterministic for any
/// worker count and across crash-resume.
pub fn run_toy_cell(
    spec: &CellSpec,
    out_dir: &Path,
    ckpt_every: usize,
    ckpt_keep: usize,
    inner_workers: usize,
) -> Result<CellOutcome> {
    run_toy_cell_in(spec, &cell_ckpt_dir(out_dir, &spec.id()), ckpt_every, ckpt_keep, inner_workers)
}

/// [`run_toy_cell`] with an explicit checkpoint dir — the form
/// [`run_matrix_with`] calls, so a leased cell snapshots under its
/// claim's token-fenced dir instead of the plain `<id>.ckpt`.
pub fn run_toy_cell_in(
    spec: &CellSpec,
    ckpt_dir: &Path,
    ckpt_every: usize,
    ckpt_keep: usize,
    inner_workers: usize,
) -> Result<CellOutcome> {
    let mut ctx = toy_ctx(inner_workers, 0xC311 ^ spec.seed)?;
    let mut params = toy_params(0x1717 ^ spec.seed);
    // toy matrices are 16-wide: clamp the LRA rank, not the budget
    let mut method = spec.method_with_lra(spec.rank.clamp(1, 8))?;
    let ckpt_dir = ckpt_dir.to_path_buf();
    let cfg = TrainCfg {
        steps: spec.steps,
        lr: 1e-3,
        warmup_frac: 0.03,
        log_every: 0,
        seed: spec.seed,
        ckpt_every,
        ckpt_dir: Some(ckpt_dir.clone()),
        ckpt_keep,
    };
    let resume_from = ckpt::latest_snapshot(&ckpt_dir)?;
    let log = train::train_with(
        &mut synth_step,
        &mut *method,
        &mut ctx,
        &mut params,
        &cfg,
        resume_from.as_deref(),
    )?;
    // retention proxy vs the (regenerated, deterministic) init weights
    let init = toy_params(0x1717 ^ spec.seed);
    let kept = retention::toy_retention(&init, &params);
    Ok(CellOutcome {
        label: method.name(),
        accs: Vec::new(),
        avg: 0.0,
        tail_loss: log.tail_loss(20),
        trainable: method.trainable(),
        opt_bytes: method.opt_bytes(),
        seconds: log.seconds,
        steps: spec.steps,
        target: Some(SuiteScores {
            accuracy: None,
            perplexity: retention::fin(log.tail_ppl(20)),
            fact_recall: None,
        }),
        source: None,
        retention: retention::fin(kept),
    })
}

// ---- artifact-backed real cells ----------------------------------------

/// Shared knobs for [`run_real_cell`]. The target suite is per-cell
/// (`CellSpec::suite`); this carries everything suite-independent.
#[derive(Clone, Debug)]
pub struct RealCellCfg {
    /// pretrain steps for the base model; `None` = the per-preset
    /// default (`exp::harness::default_pretrain_steps`), so multi-preset
    /// grids don't inherit one preset's step count
    pub pt_steps: Option<usize>,
    pub n_train: usize,
    pub n_test: usize,
    pub ckpt_every: usize,
    /// keep-last-N snapshot retention per cell (0 = keep all)
    pub ckpt_keep: usize,
    /// per-cell engine pool; keep 1 when cells fan over `par_map`
    pub inner_workers: usize,
    /// source-domain scoring knobs (held-out probe suite, corpus ppl,
    /// fact recall) — see `exp::retention`
    pub retention: RetentionCfg,
    /// pre-computed base-model source scores per preset (the retention
    /// ratio's denominator — identical for every cell of a preset, so
    /// the CLI scores each base once; a missing entry is computed
    /// in-cell as a fallback)
    pub base_source: std::collections::BTreeMap<String, SuiteScores>,
}

/// One real fine-tune + eval cell. Builds its own `Runtime`/`ModelExec`
/// so cells are pure functions of their spec and can execute on any
/// matrix worker; the pretrained base must be pre-warmed sequentially
/// first (the CLI does) so parallel cells hit the `runs/` cache
/// read-only. Resumes from the cell's newest snapshot when one exists.
/// Ends with the per-cell evaluation pass: target-suite scores plus
/// held-out source-domain scores against the pretrained base
/// (`exp::retention`).
pub fn run_real_cell(spec: &CellSpec, out_dir: &Path, rc: &RealCellCfg) -> Result<CellOutcome> {
    run_real_cell_in(spec, &cell_ckpt_dir(out_dir, &spec.id()), rc)
}

/// [`run_real_cell`] with an explicit checkpoint dir — the form
/// [`run_matrix_with`] calls, so a leased cell snapshots under its
/// claim's token-fenced dir instead of the plain `<id>.ckpt`.
pub fn run_real_cell_in(spec: &CellSpec, ckpt_dir: &Path, rc: &RealCellCfg) -> Result<CellOutcome> {
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &spec.preset)?;
    let pt_steps = rc
        .pt_steps
        .unwrap_or_else(|| crate::exp::harness::default_pretrain_steps(&spec.preset));
    let mut params = pretrain::ensure_pretrained(&rt, &exec, pt_steps, 1)?;
    let corpus = pretrain::world(&exec);
    let families = suite_families(&spec.suite)?;
    let sets: Vec<TaskSet> = families
        .iter()
        .map(|&f| {
            TaskSet::generate(f, &corpus.vocab, &corpus.kg, rc.n_train, rc.n_test, spec.seed)
        })
        .collect();
    let mut src = TaskMixSource {
        sets: sets.clone(),
        batch: exec.preset.batch,
        seq: exec.preset.seq,
    };
    let mut ctx = pretrain::make_ctx(&rt, &exec, spec.seed ^ 0xabcd);
    ctx.workers = rc.inner_workers.max(1);
    let mut method = spec.method()?;
    let ckpt_dir = ckpt_dir.to_path_buf();
    let cfg = TrainCfg {
        steps: spec.steps,
        lr: crate::exp::harness::default_lr(&spec.method),
        warmup_frac: 0.03,
        log_every: 0,
        seed: spec.seed,
        ckpt_every: rc.ckpt_every,
        ckpt_dir: Some(ckpt_dir.clone()),
        ckpt_keep: rc.ckpt_keep,
    };
    let log = match ckpt::latest_snapshot(&ckpt_dir)? {
        Some(snap) => train::resume(
            &exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg, &snap,
        )?,
        None => train::train(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg)?,
    };
    // per-cell evaluation pass: target suite, then the held-out source
    // domain for the tuned weights AND the base (the retention ratio's
    // denominator)
    let (accs, target) = retention::score_target(&exec, &params, &sets)?;
    let source = retention::score_source(&rt, &exec, &params, &corpus, &rc.retention)?;
    let base_src = match rc.base_source.get(&spec.preset) {
        Some(s) => *s,
        None => {
            // fallback for direct callers: re-obtain the base from the
            // runs/ disk cache (cheap) instead of keeping a full clone
            // of it resident through the whole fine-tune
            let base = pretrain::ensure_pretrained(&rt, &exec, pt_steps, 1)?;
            retention::score_source(&rt, &exec, &base, &corpus, &rc.retention)?
        }
    };
    let ret = match (base_src.fact_recall, source.fact_recall) {
        (Some(b), Some(a)) => retention::retention_ratio(b, a),
        _ => None,
    };
    let avg = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
    Ok(CellOutcome {
        label: method.name(),
        accs,
        avg,
        tail_loss: log.tail_loss(20),
        trainable: method.trainable(),
        opt_bytes: method.opt_bytes(),
        seconds: log.seconds,
        steps: spec.steps,
        target: Some(target),
        source: Some(source),
        retention: ret,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_expansion_dedupes_selector_axis() {
        let cells = expand_grid(
            "toy",
            &["lift".into(), "full".into()],
            &["lift".into(), "weight_mag".into()],
            &[4, 8],
            &[1, 2],
            10,
            5,
        );
        // 3 distinct names (lift deduped) x 2 ranks x 2 seeds
        assert_eq!(cells.len(), 12);
        let ids: std::collections::HashSet<String> = cells.iter().map(|c| c.id()).collect();
        assert_eq!(ids.len(), 12, "cell ids must be unique");
        assert!(ids.contains("toy_weight_mag_arith_r8_s2_t10_i5"));
        // every spec field is part of the identity (a changed interval
        // or suite must not reuse another cell's ledger entry)
        let a = CellSpec {
            preset: "toy".into(),
            method: "lift".into(),
            suite: "arith".into(),
            rank: 4,
            seed: 1,
            steps: 10,
            interval: 5,
            qscan: false,
        };
        let b = CellSpec { interval: 7, ..a.clone() };
        assert_ne!(a.id(), b.id());
        let c = CellSpec { suite: "nlu".into(), ..a.clone() };
        assert_ne!(a.id(), c.id());
        // qscan=false keeps the legacy id byte-for-byte; qscan=true is
        // a distinct cell with an explicit marker
        assert_eq!(a.id(), "toy_lift_arith_r4_s1_t10_i5");
        let q = CellSpec { qscan: true, ..a.clone() };
        assert_eq!(q.id(), "toy_lift_arith_r4_s1_t10_i5_q1");
        // and the v1 id is the pre-suite form
        assert_eq!(a.v1_id(), "toy_lift_r4_s1_t10_i5");
    }

    #[test]
    fn summary_table_aggregates_seeds_and_marks_missing_cells() {
        let dir = std::env::temp_dir().join(format!("lift_matrix_summary_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cells = expand_grid("toy", &["lift".into(), "full".into()], &[], &[2, 4], &[1, 2], 4, 2);
        assert_eq!(cells.len(), 8);
        // finish both seeds of (lift, r=2) and one seed of (full, r=4)
        let finish = |method: &str, rank: usize, seed: u64, tail: f32, ret: Option<f64>| {
            let c = cells
                .iter()
                .find(|c| c.method == method && c.rank == rank && c.seed == seed)
                .unwrap();
            let out = CellOutcome {
                label: method.to_uppercase(),
                accs: Vec::new(),
                avg: 0.0,
                tail_loss: tail,
                trainable: 1,
                opt_bytes: 12,
                seconds: 0.1,
                steps: 4,
                target: None,
                source: None,
                retention: ret,
            };
            write_outcome(&dir, &c.id(), &out).unwrap();
        };
        finish("lift", 2, 1, 0.5, Some(0.9));
        finish("lift", 2, 2, 0.7, Some(0.7));
        finish("full", 4, 1, 0.25, None);
        let table = summary_table(&dir, &cells);
        assert!(table.contains("3/8 cells finished"), "{table}");
        assert!(table.contains("mean tail loss"), "toy cells report loss: {table}");
        // (lift, r=2): mean of 0.5 and 0.7 over 2 seeds; retention 0.8
        assert!(table.contains("0.6000 (2s)"), "{table}");
        assert!(table.contains("0.8000"), "{table}");
        assert!(table.contains("0.2500 (1s)"), "{table}");
        // unfinished cells render as '-', and both column kinds appear
        assert!(table.contains("r=2 tgt") && table.contains("r=4 ret"), "{table}");
        assert!(table.contains('-'), "{table}");
        let (path, persisted) = write_summary(&dir, &cells).unwrap();
        assert_eq!(persisted, table);
        assert_eq!(std::fs::read_to_string(path).unwrap(), table);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn outcome_json_roundtrip_and_version_gate() {
        let out = CellOutcome {
            label: "LIFT".into(),
            accs: vec![0.5, 0.75],
            avg: 0.625,
            tail_loss: 0.125,
            trainable: 640,
            opt_bytes: 7680,
            seconds: 1.5,
            steps: 10,
            target: Some(SuiteScores {
                accuracy: Some(62.5),
                perplexity: Some(1.25),
                fact_recall: None,
            }),
            source: Some(SuiteScores {
                accuracy: Some(40.0),
                perplexity: Some(3.5),
                fact_recall: Some(0.2),
            }),
            retention: Some(0.8),
        };
        let j = out.to_json().to_string();
        assert!(j.contains("\"v\":2"), "{j}");
        let back = CellOutcome::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, out);
        // missing fields read as corrupt (not-done), not as a panic
        assert_eq!(
            CellOutcome::from_json(&Json::parse("{\"label\":\"x\",\"v\":2}").unwrap()),
            Err(LedgerError::Corrupt(
                "v2 outcome is missing fields or has mistyped ones".into()
            ))
        );
        // a v1-shaped file is a typed V1 error, never corrupt
        let v1 = "{\"label\":\"x\",\"accs\":[],\"avg\":0,\"tail_loss\":0.5,\"trainable\":1,\
                  \"opt_bytes\":8,\"seconds\":0.1,\"steps\":4}";
        assert_eq!(
            CellOutcome::from_json(&Json::parse(v1).unwrap()),
            Err(LedgerError::V1)
        );
        // a future version is a typed rejection
        let v9 = "{\"v\":9,\"label\":\"x\"}";
        assert_eq!(
            CellOutcome::from_json(&Json::parse(v9).unwrap()),
            Err(LedgerError::Future(9))
        );
    }
}

//! Fig. 6: fine-tuning memory breakdown on the real LLaMA-2-7B and
//! LLaMA-3-8B architectures (analytic model, analysis/memory.rs), plus a
//! measured cross-check of optimizer-state bytes from an actual run on
//! the simulator preset.

use anyhow::Result;

use super::harness::*;
use crate::analysis::memory::{self, LLAMA2_7B, LLAMA3_8B};
use crate::data::tasks::ARITH;
use crate::util::cli::Args;

pub fn fig6(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let rank = args.usize("rank", 128);
    let (batch, seq) = (8usize, 1024usize);
    let mut csv = env.csv(
        "fig6",
        &["arch", "method", "weights_gb", "grads_gb", "optimizer_gb", "activations_gb", "total_gb"],
    )?;
    println!("\n== Fig 6: memory breakdown (batch {batch} x seq {seq}, rank {rank}) ==");
    println!(
        "{:<12} {:<10} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "arch", "method", "weights", "grads", "optim", "activ", "total"
    );
    for arch in [&LLAMA2_7B, &LLAMA3_8B] {
        let rows = [
            ("FullFT", memory::full_ft(arch, batch, seq)),
            ("LoRA", memory::lora(arch, rank, batch, seq)),
            ("LIFT", memory::lift(arch, rank, batch, seq, false)),
            ("LIFT_MLP", memory::lift(arch, rank, batch, seq, true)),
        ];
        for (m, b) in rows {
            println!(
                "{:<12} {:<10} {:>8.1}G {:>8.1}G {:>8.1}G {:>8.1}G {:>8.1}G",
                arch.name,
                m,
                b.weights_gb,
                b.grads_gb,
                b.optimizer_gb,
                b.activations_gb,
                b.total()
            );
            csv.row(&[
                arch.name.into(),
                m.into(),
                format!("{:.2}", b.weights_gb),
                format!("{:.2}", b.grads_gb),
                format!("{:.2}", b.optimizer_gb),
                format!("{:.2}", b.activations_gb),
                format!("{:.2}", b.total()),
            ])?;
        }
        let f = memory::full_ft(arch, batch, seq);
        let l = memory::lift(arch, rank, batch, seq, false);
        println!(
            "  -> LIFT optimizer = {:.1}% of Full FT optimizer",
            100.0 * l.optimizer_gb / f.optimizer_gb
        );
    }

    // measured cross-check on the simulator preset (skipped with --fast)
    if !env.fast {
        println!("\nmeasured optimizer-state bytes on the `tiny` preset:");
        for m in ["full", "lora", "lift", "lift_mlp"] {
            let mut spec = RunSpec::new("tiny", &ARITH, true);
            spec.steps = 5;
            let out = run_ft(env, &spec, &MethodSpec::new(m, 32), false)?;
            println!(
                "  {:<16} trainable={:>9} opt_bytes={:>10}",
                out.label, out.trainable, out.opt_bytes
            );
        }
    }
    Ok(())
}

//! Per-cell evaluation pass: target-suite scores plus **source-domain
//! retention** (ISSUE 5).
//!
//! The paper's second headline claim is that LIFT retains up to 20% more
//! source-domain knowledge than Full FT / LoRA. To make that claim a
//! reproducible table, every finished matrix cell is scored on two
//! suites:
//!
//! * **target** — the suite the cell fine-tuned on: exact-match accuracy
//!   per family plus teacher-forced perplexity over the held-out test
//!   split;
//! * **source** — the *pretraining world* the cell never fine-tuned on:
//!   accuracy on a held-out relational-QA probe suite (generated at a
//!   reserved seed, disjoint from every fine-tune set by the prompt-hash
//!   split), held-out corpus perplexity (the Wikitext analog, Fig. 2a)
//!   and KG fact recall (Fig. 2b).
//!
//! The headline `retention` number is the ratio of post-fine-tune to
//! pre-fine-tune source fact recall ([`retention_ratio`]): 1.0 = nothing
//! forgotten, 0.5 = half the base model's factual probability mass lost.
//! `--toy` cells have no executable model, so their retention proxy is
//! the untouched-weight fraction ([`toy_retention`]) — sparse methods
//! leave non-principal weights bit-identical while Full FT moves all of
//! them, which reproduces the paper's qualitative ordering in the
//! artifact-free world (asserted by `rust/tests/grid.rs`).
//!
//! All scores are persisted in the v2 outcome ledger
//! (`exp::matrix::CellOutcome`) and surfaced as the `ret` columns of
//! `summary.txt`.

use anyhow::Result;

use crate::data::tasks::TaskSet;
use crate::data::{CorpusGen, TaskFamily};
use crate::runtime::model_exec::ModelExec;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::train::eval;
use crate::util::json::Json;

/// The three suite-level metrics of one evaluation pass. `None` means
/// "not applicable / not measured" (e.g. fact recall on a target suite,
/// or everything on a migrated v1 ledger entry) and renders as `-`.
/// Non-finite values are stored as `None` ([`fin`]) — the JSON ledger
/// cannot hold NaN/inf.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct SuiteScores {
    /// exact-match accuracy in percent (mean over the suite's families)
    pub accuracy: Option<f64>,
    /// teacher-forced perplexity over the suite's held-out split
    pub perplexity: Option<f64>,
    /// mean P(ground-truth entity | "e r") over probed KG facts
    pub fact_recall: Option<f64>,
}

/// Clamp a metric for the JSON ledger: finite values pass through,
/// NaN/inf become `None` (rendered `-`), never invalid JSON.
pub fn fin(x: f64) -> Option<f64> {
    x.is_finite().then_some(x)
}

/// `Option<f64>` → JSON with the ledger's None encoding (`null`).
/// Shared with `exp::matrix`'s outcome writer so the rule lives once.
pub(crate) fn opt_json(x: Option<f64>) -> Json {
    match x {
        Some(v) => Json::num(v),
        None => Json::Null,
    }
}

fn opt_f64(j: &Json, key: &str) -> Option<Option<f64>> {
    match j.get(key)? {
        Json::Null => Some(None),
        v => Some(Some(v.as_f64()?)),
    }
}

impl SuiteScores {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("accuracy", opt_json(self.accuracy)),
            ("perplexity", opt_json(self.perplexity)),
            ("fact_recall", opt_json(self.fact_recall)),
        ])
    }

    /// Strict parse: all three keys must be present (`null` = None).
    pub fn from_json(j: &Json) -> Option<SuiteScores> {
        Some(SuiteScores {
            accuracy: opt_f64(j, "accuracy")?,
            perplexity: opt_f64(j, "perplexity")?,
            fact_recall: opt_f64(j, "fact_recall")?,
        })
    }
}

/// Knobs for the source-domain scoring pass.
#[derive(Clone, Debug)]
pub struct RetentionCfg {
    /// held-out source probe suite: relational-QA families whose samples
    /// query the pretraining KG directly
    pub source_families: Vec<TaskFamily>,
    /// test samples per source family
    pub n_test: usize,
    /// held-out corpus batches for source perplexity
    pub ppl_batches: usize,
    /// KG facts probed for fact recall
    pub n_facts: usize,
    /// reserved seed for the probe suite + corpus batches — fixed so
    /// every cell (and the base model) is scored on the same probes
    pub probe_seed: u64,
}

impl Default for RetentionCfg {
    fn default() -> Self {
        RetentionCfg {
            source_families: vec![TaskFamily::BoolQ, TaskFamily::ArcE],
            n_test: 60,
            ppl_batches: 8,
            n_facts: 50,
            probe_seed: 0x5EED_0F,
        }
    }
}

/// Score the target suite: per-family exact-match accuracies plus the
/// suite-level [`SuiteScores`] (mean accuracy + teacher-forced test-split
/// perplexity; fact recall is a source-domain probe, so `None` here).
pub fn score_target(
    exec: &ModelExec,
    params: &[Tensor],
    sets: &[TaskSet],
) -> Result<(Vec<f64>, SuiteScores)> {
    let mut accs = Vec::with_capacity(sets.len());
    let mut test: Vec<_> = Vec::new();
    for set in sets {
        accs.push(eval::accuracy(exec, params, &set.test)?);
        test.extend(set.test.iter().cloned());
    }
    let avg = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
    let ppl = eval::sample_perplexity(exec, params, &test)?;
    Ok((
        accs,
        SuiteScores {
            accuracy: fin(avg),
            perplexity: fin(ppl),
            fact_recall: None,
        },
    ))
}

/// Score the held-out source domain: probe-suite accuracy, corpus
/// perplexity and KG fact recall, all at the reserved probe seed.
pub fn score_source(
    rt: &Runtime,
    exec: &ModelExec,
    params: &[Tensor],
    corpus: &CorpusGen,
    rc: &RetentionCfg,
) -> Result<SuiteScores> {
    let mut accs = Vec::with_capacity(rc.source_families.len());
    for &f in &rc.source_families {
        let set = TaskSet::generate(f, &corpus.vocab, &corpus.kg, 1, rc.n_test, rc.probe_seed);
        accs.push(eval::accuracy(exec, params, &set.test)?);
    }
    let acc = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
    let ppl = eval::perplexity(exec, params, corpus, rc.ppl_batches, rc.probe_seed)?;
    let recall = eval::fact_recall(rt, exec, params, corpus, rc.n_facts, rc.probe_seed)?;
    Ok(SuiteScores {
        accuracy: fin(acc),
        perplexity: fin(ppl),
        fact_recall: fin(recall),
    })
}

/// The headline retention number: post-fine-tune source fact recall as a
/// fraction of the base model's. `None` when the base recall is too
/// small to ratio against (an unpretrained base knows nothing to
/// forget).
pub fn retention_ratio(base_recall: f64, after_recall: f64) -> Option<f64> {
    if !base_recall.is_finite() || !after_recall.is_finite() || base_recall <= 1e-9 {
        return None;
    }
    fin(after_recall / base_recall)
}

/// Artifact-free retention proxy for `--toy` cells: the fraction of
/// weights left **bit-identical** by fine-tuning. Deterministic for any
/// worker count (the engine's weights are), so resumed cells reproduce
/// it exactly. Two empty parameter lists retain everything (1.0).
pub fn toy_retention(init: &[Tensor], tuned: &[Tensor]) -> f64 {
    assert_eq!(init.len(), tuned.len(), "param list mismatch");
    let mut total = 0usize;
    let mut kept = 0usize;
    for (a, b) in init.iter().zip(tuned) {
        assert_eq!(a.shape, b.shape, "param shape mismatch");
        total += a.data.len();
        kept += a
            .data
            .iter()
            .zip(&b.data)
            .filter(|(x, y)| x.to_bits() == y.to_bits())
            .count();
    }
    if total == 0 {
        return 1.0;
    }
    kept as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_scores_json_roundtrip_with_nulls() {
        let s = SuiteScores {
            accuracy: Some(62.5),
            perplexity: None,
            fact_recall: Some(0.25),
        };
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(SuiteScores::from_json(&j), Some(s));
        // a missing key is a parse failure, not a silent None
        assert_eq!(SuiteScores::from_json(&Json::parse("{\"accuracy\":1}").unwrap()), None);
    }

    #[test]
    fn fin_guards_the_ledger_against_non_finite_metrics() {
        assert_eq!(fin(2.0), Some(2.0));
        assert_eq!(fin(f64::NAN), None);
        assert_eq!(fin(f64::INFINITY), None);
        let s = SuiteScores {
            accuracy: fin(f64::NAN),
            perplexity: fin(f64::INFINITY),
            fact_recall: fin(0.5),
        };
        // the serialized form must reparse (NaN/inf would be invalid JSON)
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(SuiteScores::from_json(&j), Some(s));
    }

    #[test]
    fn retention_ratio_edges() {
        assert_eq!(retention_ratio(0.5, 0.4), Some(0.8));
        assert_eq!(retention_ratio(0.0, 0.4), None);
        assert_eq!(retention_ratio(f64::NAN, 0.4), None);
        assert_eq!(retention_ratio(0.5, f64::NAN), None);
    }
}

//! Perturbation experiments: Fig. 2 (what breaks when principal weights
//! are noised), Fig. 8 (random-matrix norms), Fig. 9 (per-layer spectral
//! deltas on the pretrained model).

use anyhow::Result;

use super::harness::*;
use crate::analysis::perturb;
use crate::data::tasks::ARITH;
use crate::lift::{LiftCfg, Selector};
use crate::train::eval;
use crate::util::cli::Args;
use crate::util::stats;

const SELECTORS: [(&str, Selector); 3] = [
    ("lift", Selector::Lift),
    ("weight_mag", Selector::WeightMag),
    ("random", Selector::Random),
];

pub fn fig2(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let scale = args.f32("scale", 0.02);
    let fracs: Vec<f64> = if env.fast {
        vec![0.01, 0.05]
    } else {
        vec![0.002, 0.01, 0.05, 0.1]
    };
    let exec = env.exec(&preset)?;
    let base = env.pretrained(&preset)?;
    let corpus = env.world(&preset)?;
    let la = crate::runtime::Linalg::new(&env.rt.client);
    let total: usize = crate::model::trainable_matrices(&exec.preset, false)
        .iter()
        .map(|&i| base[i].len())
        .sum();

    // (c) needs a fine-tuned model: Full FT on arithmetic once
    let spec = RunSpec::new(&preset, &ARITH, env.fast);
    let ft = run_ft(env, &spec, &MethodSpec::new("full", 32), true)?;
    let (_, ft_params) = ft.params.as_ref().unwrap();
    let arith_sets: Vec<_> = ARITH
        .iter()
        .map(|&f| {
            crate::data::tasks::TaskSet::generate(
                f,
                &corpus.vocab,
                &corpus.kg,
                1,
                if env.fast { 30 } else { 60 },
                1,
            )
        })
        .collect();

    let mut csv = env.csv(
        "fig2",
        &["selector", "frac", "ppl", "fact_recall", "arith_acc"],
    )?;
    println!("\n== Fig 2: noise on selected parameters (scale {scale}) ==");
    println!(
        "{:<12} {:>8} {:>10} {:>12} {:>10}",
        "selector", "frac", "ppl", "P(answer)", "arith-acc"
    );
    // unperturbed reference row
    let ppl0 = eval::perplexity(&exec, &base, &corpus, 4, 99)?;
    let rec0 = eval::fact_recall(&env.rt, &exec, &base, &corpus, 40, 7)?;
    let acc0: f64 = {
        let mut a = Vec::new();
        for s in &arith_sets {
            a.push(eval::accuracy(&exec, ft_params, &s.test)?);
        }
        stats::mean(&a)
    };
    println!(
        "{:<12} {:>8} {:>10.3} {:>12.4} {:>10.2}",
        "none", "0", ppl0, rec0, acc0
    );
    csv.row(&[
        "none".into(),
        "0".into(),
        format!("{ppl0:.4}"),
        format!("{rec0:.5}"),
        format!("{acc0:.2}"),
    ])?;

    for (name, sel) in SELECTORS {
        for &frac in &fracs {
            let n = (total as f64 * frac) as usize;
            let cfg = LiftCfg {
                rank: 32,
                ..Default::default()
            };
            let mut rng = crate::util::rng::Rng::new(7);
            let noisy = perturb::perturb(
                &la, &exec.preset, &base, sel, &cfg, n, scale, &mut rng,
            )?;
            let ppl = eval::perplexity(&exec, &noisy, &corpus, 4, 99)?;
            let rec = eval::fact_recall(&env.rt, &exec, &noisy, &corpus, 40, 7)?;
            // (c): perturb the fine-tuned model with the same selector
            let mut rng2 = crate::util::rng::Rng::new(7);
            let noisy_ft = perturb::perturb(
                &la, &exec.preset, ft_params, sel, &cfg, n, scale, &mut rng2,
            )?;
            let mut accs = Vec::new();
            for s in &arith_sets {
                accs.push(eval::accuracy(&exec, &noisy_ft, &s.test)?);
            }
            let acc = stats::mean(&accs);
            println!(
                "{name:<12} {frac:>8.3} {ppl:>10.3} {rec:>12.4} {acc:>10.2}"
            );
            csv.row(&[
                name.into(),
                format!("{frac}"),
                format!("{ppl:.4}"),
                format!("{rec:.5}"),
                format!("{acc:.2}"),
            ])?;
        }
    }
    println!("(expected: LIFT rows degrade far more than weight-mag/random)");
    Ok(())
}

pub fn fig8(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let dims: Vec<usize> = if env.fast {
        vec![64, 128]
    } else {
        vec![64, 128, 256, 512]
    };
    let scale = args.f32("scale", 0.1);
    let la = crate::runtime::Linalg::new(&env.rt.client);
    let mut rng = crate::util::rng::Rng::new(5);
    let mut csv = env.csv("fig8", &["selector", "dim", "spectral_delta", "frob_delta"])?;
    println!("\n== Fig 8: random-matrix norm deltas after selective noise ==");
    println!(
        "{:<12} {:>6} {:>16} {:>12}",
        "selector", "dim", "spectral-delta", "frob-delta"
    );
    for (name, sel) in SELECTORS {
        for &d in &dims {
            let cfg = LiftCfg {
                rank: 8,
                ..Default::default()
            };
            let mut sd = 0.0;
            let mut fd = 0.0;
            let reps = 3;
            for _ in 0..reps {
                let (s, f) =
                    perturb::random_matrix_norms(&la, d, sel, &cfg, 0.05, scale, &mut rng)?;
                sd += s / reps as f64;
                fd += f / reps as f64;
            }
            println!("{name:<12} {d:>6} {sd:>16.4} {fd:>12.4}");
            csv.row(&[
                name.into(),
                d.to_string(),
                format!("{sd:.5}"),
                format!("{fd:.5}"),
            ])?;
        }
    }
    println!("(expected: frobenius ~equal across selectors; spectral grows only for LIFT)");
    Ok(())
}

pub fn fig9(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let scale = args.f32("scale", 0.1);
    let exec = env.exec(&preset)?;
    let base = env.pretrained(&preset)?;
    let la = crate::runtime::Linalg::new(&env.rt.client);
    let total: usize = crate::model::trainable_matrices(&exec.preset, false)
        .iter()
        .map(|&i| base[i].len())
        .sum();
    let n = total / 20; // 5% of parameters
    let mut csv = env.csv("fig9", &["selector", "layer", "spectral_delta"])?;
    println!("\n== Fig 9: per-layer spectral-norm delta on the pretrained model ==");
    println!("{:<12} {:>16} {:>16}", "selector", "mean-delta", "max-delta");
    for (name, sel) in SELECTORS {
        let cfg = LiftCfg {
            rank: 32,
            ..Default::default()
        };
        let mut rng = crate::util::rng::Rng::new(11);
        let noisy = perturb::perturb(&la, &exec.preset, &base, sel, &cfg, n, scale, &mut rng)?;
        let deltas = perturb::norm_deltas(&exec.preset, &base, &noisy, &mut rng);
        let ds: Vec<f64> = deltas
            .iter()
            .map(|d| (d.spectral_after - d.spectral_before) as f64)
            .collect();
        for d in &deltas {
            csv.row(&[
                name.into(),
                d.name.clone(),
                format!("{:.5}", d.spectral_after - d.spectral_before),
            ])?;
        }
        println!(
            "{name:<12} {:>16.4} {:>16.4}",
            stats::mean(&ds),
            ds.iter().cloned().fold(f64::MIN, f64::max)
        );
    }
    println!("(expected: LIFT >> weight-mag ~ random)");
    Ok(())
}

//! Table runners (Tables 1-4 and 8-17).
//!
//! The shootout tables additionally report **source-domain retention**
//! (ISSUE 5): alongside each method's target accuracies, the held-out
//! pretraining-world perplexity and KG fact recall of the fine-tuned
//! weights (`exp::retention::score_source`) — the paper's "LIFT forgets
//! less than Full FT / LoRA" claim surfaced in the same row.

use anyhow::Result;

use super::harness::*;
use crate::data::tasks::{ARITH, COMMONSENSE, NLU};
use crate::data::TaskFamily;
use crate::exp::retention::{self, RetentionCfg};
use crate::train::eval;
use crate::util::cli::Args;

fn print_header(title: &str, families: &[TaskFamily]) {
    println!("\n== {title} ==");
    print!("{:<8} {:<18}", "preset", "method");
    for f in families {
        print!("{:>10}", f.name());
    }
    println!("{:>10}{:>10}{:>10}", "Avg.", "src-ppl", "recall");
}

fn fmt_opt(v: Option<f64>, prec: usize) -> String {
    match v {
        Some(x) => format!("{x:.prec$}"),
        None => "-".to_string(),
    }
}

fn print_row(preset: &str, out: &FtOutcome, src_ppl: Option<f64>, recall: Option<f64>) {
    print!("{:<8} {:<18}", preset, out.label);
    for a in &out.accs {
        print!("{a:>10.2}");
    }
    println!(
        "{:>10.2}{:>10}{:>10}",
        out.avg,
        fmt_opt(src_ppl, 2),
        fmt_opt(recall, 3)
    );
}

/// Generic "methods x families" table on one or more presets, with the
/// per-run source-retention columns averaged over seeds.
fn shootout(
    env: &mut ExpEnv,
    args: &Args,
    id: &str,
    title: &str,
    presets: &[&str],
    methods: &[&str],
    families: &[TaskFamily],
    rank: usize,
) -> Result<()> {
    let seeds = args.usize("seeds", 1);
    let rcfg = RetentionCfg {
        n_test: if env.fast { 30 } else { 60 },
        ppl_batches: if env.fast { 4 } else { 8 },
        n_facts: if env.fast { 30 } else { 50 },
        ..Default::default()
    };
    let mut csv = env.csv(
        id,
        &["preset", "method", "rank", "seed", "task", "acc", "src_ppl", "src_recall"],
    )?;
    print_header(title, families);
    for preset in presets {
        // loop-invariant per preset: the executable handle and the
        // synthetic pretraining world the retention probes query
        let exec = env.exec(preset)?;
        let corpus = env.world(preset)?;
        for m in methods {
            let mut sum = vec![0.0f64; families.len()];
            let mut label = String::new();
            let mut avg_over_seeds = 0.0;
            let mut ppl_sum = 0.0f64;
            let mut recall_sum = 0.0f64;
            let mut n_src = 0usize;
            for seed in 0..seeds {
                let mut spec = RunSpec::new(preset, families, env.fast);
                spec.seed = 1 + seed as u64;
                let ms = MethodSpec::new(m, rank);
                let out = run_ft(env, &spec, &ms, true)?;
                // source-domain retention of the tuned weights
                let (_, after) = out.params.as_ref().expect("keep_params requested");
                let src = retention::score_source(&env.rt, &exec, after, &corpus, &rcfg)?;
                if let (Some(p), Some(r)) = (src.perplexity, src.fact_recall) {
                    ppl_sum += p;
                    recall_sum += r;
                    n_src += 1;
                }
                for (i, a) in out.accs.iter().enumerate() {
                    sum[i] += a;
                    csv.row(&[
                        preset.to_string(),
                        out.label.clone(),
                        rank.to_string(),
                        spec.seed.to_string(),
                        families[i].name().to_string(),
                        format!("{a:.3}"),
                        fmt_opt(src.perplexity, 3),
                        fmt_opt(src.fact_recall, 4),
                    ])?;
                }
                label = out.label;
                avg_over_seeds += out.avg;
            }
            let accs: Vec<f64> = sum.iter().map(|s| s / seeds as f64).collect();
            let out = FtOutcome {
                label,
                avg: avg_over_seeds / seeds as f64,
                accs,
                log: Default::default(),
                trainable: 0,
                opt_bytes: 0,
                params: None,
            };
            let (ppl, rec) = if n_src > 0 {
                (Some(ppl_sum / n_src as f64), Some(recall_sum / n_src as f64))
            } else {
                (None, None)
            };
            print_row(preset, &out, ppl, rec);
        }
    }
    println!("(csv: {})", csv.path().display());
    Ok(())
}

pub fn table1(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let presets: Vec<String> = args.list("presets", "tiny,small");
    let p: Vec<&str> = presets.iter().map(|s| s.as_str()).collect();
    shootout(
        env,
        args,
        "table1",
        "Table 1: commonsense reasoning (Commonsense-170K analog)",
        &p,
        &["full", "lora", "dora", "pissa", "s2ft", "lift"],
        &COMMONSENSE,
        args.usize("rank", 32),
    )
}

pub fn table2(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let presets: Vec<String> = args.list("presets", "tiny,small");
    let p: Vec<&str> = presets.iter().map(|s| s.as_str()).collect();
    shootout(
        env,
        args,
        "table2",
        "Table 2: arithmetic reasoning (MATH-10K analog)",
        &p,
        &["full", "lora", "dora", "pissa", "s2ft", "lift"],
        &ARITH,
        args.usize("rank", 32),
    )
}

pub fn table3(env: &mut ExpEnv, args: &Args) -> Result<()> {
    shootout(
        env,
        args,
        "table3",
        "Table 3: NLU (GLUE analog; mixture fine-tune, see DESIGN.md)",
        &[&args.str("preset", "tiny")],
        &["full", "lora", "dora", "spectral", "pissa", "lift"],
        &NLU,
        args.usize("rank", 32),
    )
}

pub fn table4(env: &mut ExpEnv, args: &Args) -> Result<()> {
    // s1K-style: tiny SFT set, hardest family
    let presets: Vec<String> = args.list("presets", "tiny,small");
    let mut csv = env.csv("table4", &["preset", "method", "acc"])?;
    println!("\n== Table 4: GPQA-analog (s1K-style SFT) ==");
    println!("{:<8} {:<10} {:>8}", "preset", "method", "acc");
    for preset in &presets {
        for m in ["full", "lift"] {
            let mut spec = RunSpec::new(preset, &[TaskFamily::Gpqa], env.fast);
            spec.n_train = if env.fast { 300 } else { 1000 }; // "s1K"
            let out = run_ft(env, &spec, &MethodSpec::new(m, 32), false)?;
            println!("{:<8} {:<10} {:>8.2}", preset, out.label, out.avg);
            csv.row(&[preset.clone(), out.label, format!("{:.3}", out.avg)])?;
        }
    }
    Ok(())
}

/// Tables 8/9/10: best-of-rank search per method.
pub fn rank_search(env: &mut ExpEnv, args: &Args, id: &str) -> Result<()> {
    let (title, families, methods): (&str, &[TaskFamily], Vec<&str>) = match id {
        "table8" => (
            "Table 8: rank search, commonsense",
            &COMMONSENSE,
            vec!["full", "lora", "s2ft", "lift"],
        ),
        "table9" => (
            "Table 9: rank search, arithmetic",
            &ARITH,
            vec!["full", "s2ft", "pissa", "dora", "lora", "lift"],
        ),
        _ => (
            "Table 10: rank search, NLU",
            &NLU,
            vec!["full", "lora", "dora", "pissa", "spectral", "lift"],
        ),
    };
    let preset = args.str("preset", "tiny");
    let ranks: Vec<usize> = if env.fast {
        vec![16, 64]
    } else {
        vec![8, 16, 32, 64, 128]
    };
    let mut csv = env.csv(id, &["method", "rank", "avg"])?;
    println!("\n== {title} (preset {preset}) ==");
    print!("{:<18}", "method");
    for r in &ranks {
        print!("{r:>9}");
    }
    println!("{:>9}", "best");
    for m in methods {
        let mut row = Vec::new();
        for &r in &ranks {
            // full FT ignores rank: run once
            if m == "full" && !row.is_empty() {
                let prev: f64 = row[0];
                row.push(prev);
                continue;
            }
            let spec = RunSpec::new(&preset, families, env.fast);
            let out = run_ft(env, &spec, &MethodSpec::new(m, r), false)?;
            csv.row(&[m.to_string(), r.to_string(), format!("{:.3}", out.avg)])?;
            row.push(out.avg);
        }
        print!("{m:<18}");
        for v in &row {
            print!("{v:>9.2}");
        }
        println!("{:>9.2}", row.iter().cloned().fold(f64::MIN, f64::max));
    }
    Ok(())
}

pub fn table11(env: &mut ExpEnv, args: &Args) -> Result<()> {
    shootout(
        env,
        args,
        "table11",
        "Table 11: arithmetic on the extra preset (LLaMA-7B analog)",
        &[&args.str("preset", "small")],
        &["full", "s2ft", "pissa", "lora", "dora", "lift"],
        &ARITH,
        args.usize("rank", 32),
    )
}

pub fn table12(env: &mut ExpEnv, args: &Args) -> Result<()> {
    // instruct-tune on the code-gen analog, report pass@1/pass@10
    let preset = args.str("preset", "tiny");
    let mut csv = env.csv("table12", &["method", "pass1", "pass10"])?;
    println!("\n== Table 12: code generation (Humaneval analog) ==");
    println!("{:<12} {:>8} {:>8}", "method", "pass@1", "pass@10");
    let corpus = env.world(&preset)?;
    let set = crate::data::tasks::TaskSet::generate(
        TaskFamily::CodeGen,
        &corpus.vocab,
        &corpus.kg,
        if env.fast { 300 } else { 1000 },
        60,
        1,
    );
    let max_eval = if env.fast { 20 } else { 50 };
    for m in ["lift", "full", "sift", "lora", "dora"] {
        let spec = RunSpec::new(&preset, &[TaskFamily::CodeGen], env.fast);
        let out = run_ft(env, &spec, &MethodSpec::new(m, 32), true)?;
        let (_, params) = out.params.as_ref().unwrap();
        let exec = env.exec(&preset)?;
        let p1 = eval::pass_at_k(&env.rt, &exec, params, &set.test, 1, 0.7, 1, max_eval)?;
        let p10 = eval::pass_at_k(&env.rt, &exec, params, &set.test, 10, 0.7, 1, max_eval)?;
        println!("{:<12} {p1:>8.2} {p10:>8.2}", out.label);
        csv.row(&[out.label, format!("{p1:.2}"), format!("{p10:.2}")])?;
    }
    Ok(())
}

pub fn table13(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let presets: Vec<String> = args.list("presets", "tiny,small");
    let mut csv = env.csv("table13", &["preset", "method", "acc"])?;
    println!("\n== Table 13: StrategyQA analog ==");
    println!("{:<8} {:<12} {:>8}", "preset", "method", "acc");
    for preset in &presets {
        for m in ["lift", "full", "lora", "dora", "pissa"] {
            let spec = RunSpec::new(preset, &[TaskFamily::StrategyQa], env.fast);
            let out = run_ft(env, &spec, &MethodSpec::new(m, 32), false)?;
            println!("{:<8} {:<12} {:>8.2}", preset, out.label, out.avg);
            csv.row(&[preset.clone(), out.label, format!("{:.3}", out.avg)])?;
        }
    }
    Ok(())
}

pub fn table14(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let presets: Vec<String> = args.list("presets", "tiny,small");
    let mut csv = env.csv("table14", &["preset", "method", "acc"])?;
    println!("\n== Table 14: LIFT vs SpIEL vs Full FT (GSM8K analog) ==");
    println!("{:<8} {:<12} {:>8}", "preset", "method", "acc");
    for preset in &presets {
        for m in ["lift", "spiel", "full"] {
            let spec = RunSpec::new(preset, &[TaskFamily::GsmHard], env.fast);
            let out = run_ft(env, &spec, &MethodSpec::new(m, 32), false)?;
            println!("{:<8} {:<12} {:>8.2}", preset, out.label, out.avg);
            csv.row(&[preset.clone(), out.label, format!("{:.3}", out.avg)])?;
        }
    }
    Ok(())
}

pub fn table15(env: &mut ExpEnv, args: &Args) -> Result<()> {
    shootout(
        env,
        args,
        "table15",
        "Table 15: LIFT vs SIFT vs Full FT (GLUE analog, 5% budget)",
        &[&args.str("preset", "tiny")],
        &["full", "sift", "lift"],
        &NLU,
        args.usize("rank", 32),
    )
}

pub fn table16(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let mut csv = env.csv("table16", &["method", "avg", "opt_bytes"])?;
    println!("\n== Table 16: LIFT_MLP (MLP-only fine-tuning) ==");
    print_header("arithmetic suite", &ARITH);
    let preset = args.str("preset", "tiny");
    for m in ["lift", "lift_mlp", "full", "lora"] {
        let spec = RunSpec::new(&preset, &ARITH, env.fast);
        let out = run_ft(env, &spec, &MethodSpec::new(m, 32), false)?;
        print_row(&preset, &out, None, None);
        csv.row(&[
            out.label.clone(),
            format!("{:.3}", out.avg),
            out.opt_bytes.to_string(),
        ])?;
    }
    Ok(())
}

pub fn table17(env: &mut ExpEnv, args: &Args) -> Result<()> {
    shootout(
        env,
        args,
        "table17",
        "Table 17: structured 4x4 LIFT vs selection baselines",
        &[&args.str("preset", "tiny")],
        &["lift_structured", "lift", "full", "weight_mag", "grad_mag"],
        &ARITH,
        args.usize("rank", 32),
    )
}

//! Figure runners: Fig. 3 (selection shootout), Fig. 4/10 (learning vs
//! forgetting), Fig. 5 (update histograms), Fig. 12/13 (eigenspace +
//! rank), Fig. 15 (loss curves).

use anyhow::Result;

use super::harness::*;
use crate::analysis;
use crate::data::tasks::{ARITH, COMMONSENSE};
use crate::data::TaskFamily;
use crate::util::cli::Args;
use crate::util::stats;

pub fn fig3(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let presets: Vec<String> = args.list("presets", "tiny,small");
    let seeds = args.usize("seeds", if env.fast { 2 } else { 4 });
    let methods = ["lift", "weight_mag", "movement", "grad_mag", "random", "full"];
    let mut csv = env.csv("fig3", &["preset", "method", "seed", "acc"])?;
    println!("\n== Fig 3: sparse selection metrics on GSM8K-analog ==");
    println!(
        "{:<8} {:<12} {:>8} {:>8} ({} seeds)",
        "preset", "method", "mean", "std", seeds
    );
    for preset in &presets {
        for m in methods {
            let mut accs = Vec::new();
            for seed in 0..seeds {
                let mut spec = RunSpec::new(preset, &[TaskFamily::GsmHard], env.fast);
                spec.seed = 1 + seed as u64;
                let out = run_ft(env, &spec, &MethodSpec::new(m, 32), false)?;
                csv.row(&[
                    preset.clone(),
                    out.label.clone(),
                    spec.seed.to_string(),
                    format!("{:.3}", out.avg),
                ])?;
                accs.push(out.avg);
            }
            println!(
                "{:<8} {:<12} {:>8.2} {:>8.2}",
                preset,
                m,
                stats::mean(&accs),
                stats::stddev(&accs)
            );
        }
    }
    Ok(())
}

pub fn fig4(env: &mut ExpEnv, args: &Args) -> Result<()> {
    // The paper fine-tunes an instruction-capable LLM on MATH-10K and
    // measures commonsense (source) retention. Our pretrained base has
    // never seen the answer-marker task format, so "source capability"
    // is created explicitly: a commonsense SFT pass first (the source
    // skill), then each method fine-tunes arithmetic on top of it and we
    // measure how much source skill survives.
    let preset = args.str("preset", "tiny");
    let mut csv = env.csv(
        "fig4",
        &["method", "target_easy", "target_hard", "source_avg", "source_base"],
    )?;
    println!("\n== Fig 4/10: learning vs forgetting (preset {preset}) ==");
    let n_test = if env.fast { 40 } else { 100 };
    // source-capable base: full-FT SFT on the commonsense mixture
    let src_spec = RunSpec::new(&preset, &COMMONSENSE, env.fast);
    let src_out = run_ft(env, &src_spec, &MethodSpec::new("full", 32), true)?;
    let (_, instructed) = src_out.params.unwrap();
    let base_src = eval_suite(env, &preset, &COMMONSENSE, &instructed, n_test, 7)?;
    let base_avg = stats::mean(&base_src);
    println!("source-capable base: commonsense avg {base_avg:.2}");
    println!(
        "{:<12} {:>12} {:>12} {:>12}",
        "method", "target-easy", "target-hard", "source"
    );
    let rank = args.usize("rank", 8);
    for m in ["lift", "full", "lora"] {
        let mut spec = RunSpec::new(&preset, &ARITH, env.fast);
        spec.steps = spec.steps * 3 / 4; // shorter target SFT: the paper's
                                         // forgetting regime, not saturation
        let out = run_ft_from(env, &spec, &MethodSpec::new(m, rank), instructed.clone())?;
        let after = &out.params.as_ref().unwrap().1;
        let mut easy = Vec::new();
        let mut hard = Vec::new();
        for (i, f) in ARITH.iter().enumerate() {
            if f.is_hard() {
                hard.push(out.accs[i]);
            } else {
                easy.push(out.accs[i]);
            }
        }
        let src = eval_suite(env, &preset, &COMMONSENSE, after, n_test, 7)?;
        let (e, h, s) = (stats::mean(&easy), stats::mean(&hard), stats::mean(&src));
        println!("{:<12} {e:>12.2} {h:>12.2} {s:>12.2}", out.label);
        csv.row(&[
            out.label,
            format!("{e:.2}"),
            format!("{h:.2}"),
            format!("{s:.2}"),
            format!("{base_avg:.2}"),
        ])?;
    }
    Ok(())
}

pub fn fig5(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let bins = 61;
    let lim = 0.02f32;
    let mut csv = env.csv("fig5", &["method", "layer", "bin_center", "count"])?;
    println!("\n== Fig 5: |ΔW| distribution after fine-tuning ==");
    println!(
        "{:<12} {:>12} {:>14} {:>12}",
        "method", "max|ΔW|", "%unchanged", "ΔW-frob"
    );
    for m in ["lift", "full", "lora"] {
        let spec = RunSpec::new(&preset, &ARITH, env.fast);
        let out = run_ft(env, &spec, &MethodSpec::new(m, 32), true)?;
        let (before, after) = out.params.as_ref().unwrap();
        let exec = env.exec(&preset)?;
        let matrices = crate::model::trainable_matrices(&exec.preset, false);
        let mut maxd = 0.0f32;
        let mut unchanged = 0.0f64;
        let mut frob = 0.0f64;
        for (mi, &pi) in matrices.iter().enumerate() {
            let h = analysis::update_histogram(&before[pi], &after[pi], lim, bins);
            let (mx, un) = analysis::update::update_stats(&before[pi], &after[pi]);
            maxd = maxd.max(mx);
            unchanged += un;
            frob += stats::frobenius_diff(&before[pi].data, &after[pi].data).powi(2);
            if mi < 4 {
                for (b, &c) in h.iter().enumerate() {
                    let center = -lim + (b as f32 + 0.5) * (2.0 * lim / bins as f32);
                    csv.row(&[
                        out.label.clone(),
                        exec.preset.params[pi].name.clone(),
                        format!("{center:.5}"),
                        c.to_string(),
                    ])?;
                }
            }
        }
        println!(
            "{:<12} {:>12.5} {:>13.1}% {:>12.4}",
            out.label,
            maxd,
            100.0 * unchanged / matrices.len() as f64,
            frob.sqrt()
        );
    }
    println!("(expected shape: LIFT max update >> LoRA/Full, with a large unchanged spike)");
    Ok(())
}

/// Fig. 12 (alignment=true) and Fig. 13 (alignment=false, ΔW rank).
pub fn fig12_13(env: &mut ExpEnv, args: &Args, alignment: bool) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let id = if alignment { "fig12" } else { "fig13" };
    let kinds = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];
    let mut csv = env.csv(id, &["method", "kind", "value"])?;
    println!(
        "\n== {} per layer type ==",
        if alignment {
            "Fig 12: eigenspace alignment (lower = larger rotation)"
        } else {
            "Fig 13: rank of ΔW"
        }
    );
    print!("{:<12}", "method");
    for k in kinds {
        print!("{k:>9}");
    }
    println!();
    for m in ["lift", "full", "lora"] {
        let spec = RunSpec::new(&preset, &ARITH, env.fast);
        let out = run_ft(env, &spec, &MethodSpec::new(m, 32), true)?;
        let (before, after) = out.params.as_ref().unwrap();
        let exec = env.exec(&preset)?;
        print!("{:<12}", out.label);
        for kind in kinds {
            let idxs = crate::model::matrices_of_kind(&exec.preset, kind);
            let vals: Vec<f64> = idxs
                .iter()
                .map(|&pi| {
                    if alignment {
                        analysis::alignment_score(&before[pi], &after[pi], 32)
                    } else {
                        analysis::update_rank(&before[pi], &after[pi], 10.0) as f64
                    }
                })
                .collect();
            let v = stats::mean(&vals);
            print!("{v:>9.3}");
            csv.row(&[out.label.clone(), kind.to_string(), format!("{v:.4}")])?;
        }
        println!();
    }
    Ok(())
}

pub fn fig15(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let methods = ["full", "lift", "lora", "dora", "pissa", "s2ft"];
    let mut curves = Vec::new();
    for m in methods {
        let spec = RunSpec::new(&preset, &ARITH, env.fast);
        let out = run_ft(env, &spec, &MethodSpec::new(m, 32), false)?;
        curves.push((out.label.clone(), out.log.losses.clone()));
    }
    let mut csv = env.csv("fig15", &["step", "method", "loss"])?;
    let n = curves.iter().map(|(_, l)| l.len()).max().unwrap_or(0);
    for step in 0..n {
        for (label, losses) in &curves {
            if let Some(l) = losses.get(step) {
                csv.row(&[step.to_string(), label.clone(), format!("{l:.5}")])?;
            }
        }
    }
    println!("\n== Fig 15: training loss (smoothed tail means) ==");
    println!("{:<14} {:>10} {:>10} {:>10}", "method", "25%", "50%", "final");
    for (label, losses) in &curves {
        let at = |frac: f64| {
            let i = ((losses.len() as f64 * frac) as usize).min(losses.len() - 1);
            let lo = i.saturating_sub(5);
            losses[lo..=i].iter().sum::<f32>() / (i - lo + 1) as f32
        };
        println!(
            "{label:<14} {:>10.4} {:>10.4} {:>10.4}",
            at(0.25),
            at(0.5),
            at(1.0)
        );
    }
    println!("(expected: LIFT converges on par with Full FT, faster than PEFT)");
    Ok(())
}

//! Fig. 14 (§G.5): two-layer toy regression — a self-contained replica of
//! the paper's toy study, with manual backprop through f(X) = relu(XW) a.
//! Pretrain on one rule, fine-tune 100 samples of another, and compare
//! Full FT vs sparse fine-tuning (LIFT / weight-mag / grad-mag masks).

use anyhow::Result;

use super::harness::ExpEnv;
use crate::lift::{self, LiftCfg, Selector};
use crate::optim::{AdamCfg, DenseAdam, SparseAdam};
use crate::runtime::Linalg;
use crate::tensor::Tensor;
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats;

const D: usize = 512;
const H: usize = 128;

fn labels_pretrain(x: &Tensor) -> Vec<f32> {
    let (n, d) = x.dims2();
    (0..n)
        .map(|i| {
            let row = &x.data[i * d..(i + 1) * d];
            let s1: f32 = row[..32].iter().sum();
            let s2: f32 = row[32..64].iter().map(|v| v.sin()).sum();
            s1 + 0.1 * s2
        })
        .collect()
}

fn labels_finetune(x: &Tensor) -> Vec<f32> {
    let (n, d) = x.dims2();
    (0..n)
        .map(|i| {
            let row = &x.data[i * d..(i + 1) * d];
            0.2 * row[64] * row[65] * row[66] + 0.1 * (row[67] * row[68]).sin()
        })
        .collect()
}

struct Toy {
    w: Tensor, // (D, H)
    a: Vec<f32>,
}

impl Toy {
    fn forward(&self, la: &Linalg, x: &Tensor) -> Result<(Tensor, Vec<f32>)> {
        let mut h = la.matmul(x, &self.w)?; // (n, H)
        for v in h.data.iter_mut() {
            *v = v.max(0.0);
        }
        let (n, hh) = h.dims2();
        let preds = (0..n)
            .map(|i| {
                h.data[i * hh..(i + 1) * hh]
                    .iter()
                    .zip(&self.a)
                    .map(|(x, a)| x * a)
                    .sum()
            })
            .collect();
        Ok((h, preds))
    }

    /// MSE loss + grads (dW, da).
    fn backward(
        &self,
        la: &Linalg,
        x: &Tensor,
        y: &[f32],
    ) -> Result<(f32, Tensor, Vec<f32>)> {
        let (h, preds) = self.forward(la, x)?;
        let (n, hh) = h.dims2();
        let resid: Vec<f32> = preds
            .iter()
            .zip(y)
            .map(|(p, t)| 2.0 * (p - t) / n as f32)
            .collect();
        let loss = preds
            .iter()
            .zip(y)
            .map(|(p, t)| (p - t) * (p - t))
            .sum::<f32>()
            / n as f32;
        // da = H^T r
        let mut da = vec![0.0f32; hh];
        for i in 0..n {
            for j in 0..hh {
                da[j] += h.data[i * hh + j] * resid[i];
            }
        }
        // dH = r a^T masked by relu'; dW = X^T dH
        let mut dh = Tensor::zeros(&[n, hh]);
        for i in 0..n {
            for j in 0..hh {
                if h.data[i * hh + j] > 0.0 {
                    dh.data[i * hh + j] = resid[i] * self.a[j];
                }
            }
        }
        let dw = la.matmul_tn(x, &dh)?; // (D, H)
        Ok((loss, dw, da))
    }
}

pub fn fig14(env: &mut ExpEnv, args: &Args) -> Result<()> {
    let la = Linalg::new(&env.rt.client);
    let mut rng = Rng::new(args.u64("seed", 1));
    let n_pre = if env.fast { 2000 } else { 5000 };
    let pre_steps = if env.fast { 150 } else { 400 };
    let ft_steps = if env.fast { 150 } else { 400 };

    // pretrain
    let x_pre = Tensor::randn(&[n_pre, D], 1.0, &mut rng);
    let y_pre = labels_pretrain(&x_pre);
    let mut net = Toy {
        w: Tensor::randn(&[D, H], (1.0 / D as f32).sqrt(), &mut rng),
        a: rng.normal_vec(H, (1.0 / H as f32).sqrt()),
    };
    let mut opt_w = DenseAdam::new(D * H, AdamCfg::default());
    let mut opt_a = DenseAdam::new(H, AdamCfg::default());
    for step in 0..pre_steps {
        let (loss, dw, da) = net.backward(&la, &x_pre, &y_pre)?;
        opt_w.step(&mut net.w.data, &dw.data, 3e-3);
        opt_a.step(&mut net.a, &da, 3e-3);
        if step % 100 == 0 {
            log::info!("toy pretrain step {step} loss {loss:.4}");
        }
    }

    // fine-tune datasets
    let x_ft = Tensor::randn(&[100, D], 1.0, &mut rng);
    let y_ft = labels_finetune(&x_ft);
    let x_val = Tensor::randn(&[500, D], 1.0, &mut rng);
    let y_val = labels_finetune(&x_val);

    let mut csv = env.csv(
        "fig14",
        &["method", "step", "train_loss", "val_loss", "grad_norm", "spectral_norm"],
    )?;
    println!("\n== Fig 14: toy two-layer regression ==");
    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>12}",
        "method", "train-loss", "val-loss", "grad-norm", "spec-norm"
    );
    let k = (D * H) / 20; // 5% of W
    for method in ["full", "lift", "weight_mag", "grad_mag"] {
        let mut n2 = Toy {
            w: net.w.clone(),
            a: net.a.clone(),
        };
        let mut opt_a = DenseAdam::new(H, AdamCfg::default());
        // mask selection on the pretrained W
        let (_, dw0, _) = n2.backward(&la, &x_ft, &y_ft)?;
        let cfg = LiftCfg {
            rank: 8,
            ..Default::default()
        };
        let sel = match method {
            "lift" => Some(Selector::Lift),
            "weight_mag" => Some(Selector::WeightMag),
            "grad_mag" => Some(Selector::GradMag),
            _ => None,
        };
        let mut opt: Box<dyn FnMut(&mut Toy, &Tensor, f32)> = match sel {
            None => {
                let mut o = DenseAdam::new(D * H, AdamCfg::default());
                Box::new(move |t: &mut Toy, dw: &Tensor, lr: f32| {
                    o.step(&mut t.w.data, &dw.data, lr)
                })
            }
            Some(s) => {
                let idx =
                    lift::select_indices(s, &la, &n2.w, Some(&dw0), None, k, &cfg, &mut rng)?;
                let mut o = SparseAdam::new(idx, AdamCfg::default());
                Box::new(move |t: &mut Toy, dw: &Tensor, lr: f32| {
                    o.step(&mut t.w.data, &dw.data, lr)
                })
            }
        };
        let (mut fin_tr, mut fin_val, mut fin_g, mut fin_s) = (0.0, 0.0, 0.0, 0.0);
        for step in 0..ft_steps {
            let (loss, dw, da) = n2.backward(&la, &x_ft, &y_ft)?;
            opt(&mut n2, &dw, 1e-3);
            opt_a.step(&mut n2.a, &da, 1e-3);
            if step % 20 == 0 || step == ft_steps - 1 {
                let (_, vp) = n2.forward(&la, &x_val)?;
                let vloss = vp
                    .iter()
                    .zip(&y_val)
                    .map(|(p, t)| (p - t) * (p - t))
                    .sum::<f32>()
                    / y_val.len() as f32;
                let gnorm = stats::l2_norm(&dw.data);
                let snorm = n2.w.spectral_norm(30, &mut rng);
                csv.row(&[
                    method.into(),
                    step.to_string(),
                    format!("{loss:.5}"),
                    format!("{vloss:.5}"),
                    format!("{gnorm:.5}"),
                    format!("{snorm:.5}"),
                ])?;
                (fin_tr, fin_val, fin_g, fin_s) =
                    (loss, vloss, gnorm as f32, snorm);
            }
        }
        println!(
            "{method:<12} {fin_tr:>12.4} {fin_val:>12.4} {fin_g:>12.4} {fin_s:>12.4}"
        );
    }
    println!("(expected: sparse < full on val loss; LIFT best among sparse)");
    Ok(())
}

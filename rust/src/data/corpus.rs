//! Pretraining corpus generator (the "source domain").
//!
//! A mixture of (a) KG fact sentences — frequent facts oversampled ~5x,
//! plus occasional 2-hop compositions so multi-hop tasks are learnable,
//! (b) arithmetic equations — the numeracy the arithmetic tasks build on,
//! and (c) Zipf-ish filler sentences for generic language statistics.
//! Sentences are packed back-to-back into rows (standard LM packing).

use super::vocab::*;
use super::{BatchSource, Kg, Vocab};
use crate::runtime::model_exec::Batch;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct CorpusGen {
    pub vocab: Vocab,
    pub kg: Kg,
    pub batch: usize,
    pub seq: usize,
    /// mixture weights in percent: facts / arithmetic / filler
    pub mix: [u64; 3],
}

impl CorpusGen {
    pub fn new(vocab: Vocab, kg: Kg, batch: usize, seq: usize) -> CorpusGen {
        CorpusGen {
            vocab,
            kg,
            batch,
            seq,
            mix: [50, 30, 20],
        }
    }

    /// One sentence, BOS..EOS.
    pub fn sentence(&self, rng: &mut Rng) -> Vec<i32> {
        let roll = rng.next_u64() % 100;
        if roll < self.mix[0] {
            self.fact_sentence(rng)
        } else if roll < self.mix[0] + self.mix[1] {
            self.arith_sentence(rng)
        } else {
            self.filler_sentence(rng)
        }
    }

    fn fact_sentence(&self, rng: &mut Rng) -> Vec<i32> {
        // frequent facts are oversampled: 70% of fact sentences draw from
        // the frequent tier (~25% of facts)
        let frequent = rng.chance(0.7);
        if rng.chance(0.1) {
            // 2-hop composition sentence: e r1 r2 -> t
            let (e, r1, _m, r2, t) = self.kg.sample_2hop(rng);
            vec![
                BOS,
                self.vocab.entity(e),
                self.vocab.relation(r1),
                self.vocab.relation(r2),
                self.vocab.entity(t),
                EOS,
            ]
        } else {
            let (e, r, t) = self.kg.sample_fact_tier(rng, frequent);
            vec![
                BOS,
                self.vocab.entity(e),
                self.vocab.relation(r),
                self.vocab.entity(t),
                EOS,
            ]
        }
    }

    fn arith_sentence(&self, rng: &mut Rng) -> Vec<i32> {
        // ranges matched to the task suites (data/tasks.rs) so fine-tuning
        // builds on pretrained numeracy rather than fighting it
        let a = rng.range(0, 30);
        let b = rng.range(0, 30);
        let (op, c) = match rng.below(3) {
            0 => (PLUS, a + b),
            1 => (SUB, a - b),
            _ => {
                let a = a % 10;
                let b = b % 10;
                return self.equation(a, MUL, b, a * b);
            }
        };
        self.equation(a, op, b, c)
    }

    fn equation(&self, a: i64, op: i32, b: i64, c: i64) -> Vec<i32> {
        let mut s = vec![BOS];
        s.extend(self.vocab.number(a));
        s.push(op);
        s.extend(self.vocab.number(b));
        s.push(EQ);
        s.extend(self.vocab.number(c));
        s.push(EOS);
        s
    }

    fn filler_sentence(&self, rng: &mut Rng) -> Vec<i32> {
        let len = 3 + rng.below(8);
        let mut s = vec![BOS];
        for _ in 0..len {
            // Zipf-ish: squash uniform to favor low filler ids
            let u = rng.next_f64();
            let idx = ((u * u) * self.vocab.n_filler as f64) as usize;
            s.push(self.vocab.filler(idx.min(self.vocab.n_filler - 1)));
        }
        s.push(EOS);
        s
    }

    /// Held-out evaluation batches (fixed seed stream disjoint from train).
    pub fn eval_batches(&self, n: usize, seed: u64) -> Vec<Batch> {
        let mut rng = Rng::new(seed ^ 0x5eed_e7a1);
        (0..n).map(|_| self.pack_batch(&mut rng)).collect()
    }

    fn pack_batch(&self, rng: &mut Rng) -> Batch {
        let (b, s) = (self.batch, self.seq);
        let mut batch = Batch::empty(b, s);
        for row in 0..b {
            let mut buf: Vec<i32> = Vec::with_capacity(s + 16);
            while buf.len() < s + 1 {
                buf.extend(self.sentence(rng));
            }
            let toks = &buf[..s + 1];
            for i in 0..s {
                batch.tokens[row * s + i] = toks[i];
                batch.targets[row * s + i] = toks[i + 1];
                batch.loss_mask[row * s + i] = 1.0;
            }
        }
        batch
    }
}

impl BatchSource for CorpusGen {
    fn next_batch(&mut self, rng: &mut Rng) -> Batch {
        self.pack_batch(rng)
    }

    fn shape(&self) -> (usize, usize) {
        (self.batch, self.seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> CorpusGen {
        let v = Vocab::new(512);
        let kg = Kg::new(7, v.n_entities, v.n_relations);
        CorpusGen::new(v, kg, 4, 32)
    }

    #[test]
    fn sentences_are_well_formed() {
        let g = gen();
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let s = g.sentence(&mut rng);
            assert_eq!(s[0], BOS);
            assert_eq!(*s.last().unwrap(), EOS);
            assert!(s.len() >= 3);
            for &t in &s {
                assert!((t as usize) < g.vocab.size, "token {t} out of vocab");
            }
        }
    }

    #[test]
    fn batches_have_shifted_targets() {
        let mut g = gen();
        let mut rng = Rng::new(2);
        let b = g.next_batch(&mut rng);
        assert_eq!(b.tokens.len(), 4 * 32);
        for row in 0..4 {
            for i in 0..31 {
                assert_eq!(b.targets[row * 32 + i], b.tokens[row * 32 + i + 1]);
            }
        }
        assert!(b.loss_mask.iter().all(|&m| m == 1.0));
    }

    #[test]
    fn fact_sentences_respect_kg() {
        let g = gen();
        let mut rng = Rng::new(3);
        let mut checked = 0;
        for _ in 0..500 {
            let s = g.sentence(&mut rng);
            if s.len() == 5 && g.vocab.is_entity(s[1]) {
                let e = g.vocab.entity_index(s[1]).unwrap();
                let r = (s[2] - REL0) as usize;
                let t = g.vocab.entity_index(s[3]).unwrap();
                assert_eq!(g.kg.lookup(e, r), Some(t));
                checked += 1;
            }
        }
        assert!(checked > 50, "only {checked} fact sentences seen");
    }

    #[test]
    fn eval_stream_is_deterministic() {
        let g = gen();
        let a = g.eval_batches(2, 9);
        let b = g.eval_batches(2, 9);
        assert_eq!(a[0].tokens, b[0].tokens);
        assert_eq!(a[1].tokens, b[1].tokens);
    }
}

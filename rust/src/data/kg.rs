//! Deterministic knowledge graph — the "world" the model pretrains on.
//!
//! A functional KG: each (entity, relation) pair maps to at most one target
//! entity, decided by a seeded hash, with a coverage knob (not every pair
//! holds a fact) and a frequency tier (a minority of facts are "frequent"
//! in the corpus; the rare tier feeds the OBQA-analog task and matches the
//! paper's framing — Sharma et al.'s rank-reduction recovers *infrequent*
//! knowledge, which is exactly what LIFT's principal weights should carry).

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Kg {
    pub seed: u64,
    pub n_entities: usize,
    pub n_relations: usize,
    /// fraction of (e, r) pairs that hold a fact, in percent
    pub coverage_pct: u64,
}

fn mix(seed: u64, a: u64, b: u64) -> u64 {
    let mut x = seed ^ a.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ b.wrapping_mul(0xc2b2_ae3d_27d4_eb4f);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl Kg {
    pub fn new(seed: u64, n_entities: usize, n_relations: usize) -> Kg {
        Kg {
            seed,
            n_entities,
            n_relations,
            coverage_pct: 60,
        }
    }

    /// The unique target of (e, r), if the fact exists.
    pub fn lookup(&self, e: usize, r: usize) -> Option<usize> {
        let h = mix(self.seed, e as u64, r as u64);
        if h % 100 < self.coverage_pct {
            Some((mix(self.seed ^ 0xfac7, e as u64, r as u64) % self.n_entities as u64) as usize)
        } else {
            None
        }
    }

    /// Frequent tier: ~25% of existing facts appear often in the corpus.
    pub fn is_frequent(&self, e: usize, r: usize) -> bool {
        mix(self.seed ^ 0xf4e9, e as u64, r as u64) % 100 < 25
    }

    /// Sample a uniformly random existing fact.
    pub fn sample_fact(&self, rng: &mut Rng) -> (usize, usize, usize) {
        loop {
            let e = rng.below(self.n_entities);
            let r = rng.below(self.n_relations);
            if let Some(t) = self.lookup(e, r) {
                return (e, r, t);
            }
        }
    }

    /// Sample a fact whose frequency tier matches `frequent`.
    pub fn sample_fact_tier(&self, rng: &mut Rng, frequent: bool) -> (usize, usize, usize) {
        loop {
            let (e, r, t) = self.sample_fact(rng);
            if self.is_frequent(e, r) == frequent {
                return (e, r, t);
            }
        }
    }

    /// Sample a 2-hop composition e -r1-> m -r2-> t.
    pub fn sample_2hop(&self, rng: &mut Rng) -> (usize, usize, usize, usize, usize) {
        loop {
            let (e, r1, m) = self.sample_fact(rng);
            let r2 = rng.below(self.n_relations);
            if let Some(t) = self.lookup(m, r2) {
                return (e, r1, m, r2, t);
            }
        }
    }

    /// Sample a 3-hop composition (GPQA-analog difficulty).
    #[allow(clippy::type_complexity)]
    pub fn sample_3hop(&self, rng: &mut Rng) -> (usize, usize, usize, usize, usize, usize, usize) {
        loop {
            let (e, r1, m1, r2, m2) = self.sample_2hop(rng);
            let r3 = rng.below(self.n_relations);
            if let Some(t) = self.lookup(m2, r3) {
                return (e, r1, m1, r2, m2, r3, t);
            }
        }
    }

    /// A wrong-answer entity distinct from `correct` (for choices/negatives).
    pub fn distractor(&self, rng: &mut Rng, correct: usize) -> usize {
        loop {
            let d = rng.below(self.n_entities);
            if d != correct {
                return d;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_is_deterministic_and_functional() {
        let kg = Kg::new(7, 200, 24);
        for e in 0..50 {
            for r in 0..24 {
                assert_eq!(kg.lookup(e, r), kg.lookup(e, r));
                if let Some(t) = kg.lookup(e, r) {
                    assert!(t < 200);
                }
            }
        }
    }

    #[test]
    fn coverage_close_to_knob() {
        let kg = Kg::new(3, 256, 24);
        let total = 256 * 24;
        let hits = (0..256)
            .flat_map(|e| (0..24).map(move |r| (e, r)))
            .filter(|&(e, r)| kg.lookup(e, r).is_some())
            .count();
        let pct = 100.0 * hits as f64 / total as f64;
        assert!((52.0..68.0).contains(&pct), "coverage {pct}%");
    }

    #[test]
    fn different_seeds_differ() {
        let a = Kg::new(1, 200, 24);
        let b = Kg::new(2, 200, 24);
        let diff = (0..200)
            .flat_map(|e| (0..24).map(move |r| (e, r)))
            .filter(|&(e, r)| a.lookup(e, r) != b.lookup(e, r))
            .count();
        assert!(diff > 1000);
    }

    #[test]
    fn multihop_chains_are_consistent() {
        let kg = Kg::new(5, 300, 24);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let (e, r1, m, r2, t) = kg.sample_2hop(&mut rng);
            assert_eq!(kg.lookup(e, r1), Some(m));
            assert_eq!(kg.lookup(m, r2), Some(t));
        }
        let (e, r1, m1, r2, m2, r3, t) = kg.sample_3hop(&mut rng);
        assert_eq!(kg.lookup(e, r1), Some(m1));
        assert_eq!(kg.lookup(m1, r2), Some(m2));
        assert_eq!(kg.lookup(m2, r3), Some(t));
    }

    #[test]
    fn tiers_partition_facts() {
        let kg = Kg::new(9, 200, 24);
        let mut rng = Rng::new(2);
        let (e, r, _) = kg.sample_fact_tier(&mut rng, true);
        assert!(kg.is_frequent(e, r));
        let (e, r, _) = kg.sample_fact_tier(&mut rng, false);
        assert!(!kg.is_frequent(e, r));
    }
}

//! Synthetic-language substrate.
//!
//! The paper fine-tunes LLaMA/Qwen/DeBERTa on public corpora; none of that
//! fits this box (DESIGN.md §3). This module builds the closest synthetic
//! equivalent that exercises identical code paths:
//!
//!   * a deterministic world (knowledge graph + arithmetic grammar) that a
//!     model *pretrains* on — this is the "source domain" whose retention
//!     Fig. 4 measures and whose facts the Fig. 2b probe queries;
//!   * task families mirroring each benchmark suite: 7 arithmetic
//!     (MATH-10K analogs), 8 relational-QA (Commonsense-170K analogs),
//!     8 sequence-classification (GLUE analogs), plus GPQA / code-gen /
//!     StrategyQA analogs — each with disjoint train/test splits.

pub mod corpus;
pub mod kg;
pub mod tasks;
pub mod vocab;

pub use corpus::CorpusGen;
pub use kg::Kg;
pub use tasks::{Sample, TaskFamily, TaskSet};
pub use vocab::Vocab;

use crate::runtime::model_exec::Batch;
use crate::util::rng::Rng;

/// Anything the trainer can pull batches from.
pub trait BatchSource {
    fn next_batch(&mut self, rng: &mut Rng) -> Batch;
    /// rows are (batch, seq) — must match the preset.
    fn shape(&self) -> (usize, usize);
}

//! Task families — synthetic analogs of every benchmark suite the paper
//! evaluates on (DESIGN.md §3 maps each analog to its original).
//!
//! Uniform sample format: `prompt … ANS answer-tokens`; fine-tuning masks
//! the loss to the answer span; eval counts a sample correct iff *every*
//! answer token is greedy-predicted (teacher-forced exact match).
//! Train/test splits are disjoint by construction: a hash of the prompt
//! decides the split, and duplicates are filtered.

use std::collections::HashSet;

use super::vocab::*;
use super::{BatchSource, Kg, Vocab};
use crate::runtime::model_exec::Batch;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskFamily {
    // arithmetic — MATH-10K analogs (Table 2)
    MultiArith,
    GsmHard,
    AddSub,
    AQuA,
    SingleEq,
    Svamp,
    Mawps,
    // relational QA — Commonsense-170K analogs (Table 1)
    BoolQ,
    Piqa,
    Siqa,
    HellaSwag,
    Winogrande,
    ArcE,
    ArcC,
    Obqa,
    // sequence classification — GLUE analogs (Table 3)
    Mnli,
    Sst2,
    Mrpc,
    Cola,
    Qnli,
    Qqp,
    Rte,
    Stsb,
    // extras
    Gpqa,       // 3-hop, 4-choice (Table 4)
    CodeGen,    // transformation programs (Table 12)
    StrategyQa, // 2-hop yes/no (Table 13)
}

pub const ARITH: [TaskFamily; 7] = [
    TaskFamily::MultiArith,
    TaskFamily::GsmHard,
    TaskFamily::AddSub,
    TaskFamily::AQuA,
    TaskFamily::SingleEq,
    TaskFamily::Svamp,
    TaskFamily::Mawps,
];

pub const COMMONSENSE: [TaskFamily; 8] = [
    TaskFamily::BoolQ,
    TaskFamily::Piqa,
    TaskFamily::Siqa,
    TaskFamily::HellaSwag,
    TaskFamily::Winogrande,
    TaskFamily::ArcE,
    TaskFamily::ArcC,
    TaskFamily::Obqa,
];

pub const NLU: [TaskFamily; 8] = [
    TaskFamily::Mnli,
    TaskFamily::Sst2,
    TaskFamily::Mrpc,
    TaskFamily::Cola,
    TaskFamily::Qnli,
    TaskFamily::Qqp,
    TaskFamily::Rte,
    TaskFamily::Stsb,
];

/// The named eval suites — `--suite` CLI values and the scenario grid's
/// suite-axis vocabulary.
pub const SUITES: [&str; 4] = ["arith", "commonsense", "nlu", "gpqa"];

/// Resolve a named suite to its task families (shared by the CLI and
/// the scenario-matrix cells, so both reject unknown names identically).
pub fn suite_families(suite: &str) -> anyhow::Result<Vec<TaskFamily>> {
    Ok(match suite {
        "arith" => ARITH.to_vec(),
        "commonsense" => COMMONSENSE.to_vec(),
        "nlu" => NLU.to_vec(),
        "gpqa" => vec![TaskFamily::Gpqa],
        other => anyhow::bail!("unknown suite '{other}' (known: {})", SUITES.join(", ")),
    })
}

impl TaskFamily {
    pub fn name(&self) -> &'static str {
        match self {
            TaskFamily::MultiArith => "MultiArith",
            TaskFamily::GsmHard => "GSM8K",
            TaskFamily::AddSub => "AddSub",
            TaskFamily::AQuA => "AQuA",
            TaskFamily::SingleEq => "SingleEQ",
            TaskFamily::Svamp => "SVAMP",
            TaskFamily::Mawps => "MAWPS",
            TaskFamily::BoolQ => "BoolQ",
            TaskFamily::Piqa => "PIQA",
            TaskFamily::Siqa => "SIQA",
            TaskFamily::HellaSwag => "HellaSwag",
            TaskFamily::Winogrande => "Wino",
            TaskFamily::ArcE => "ARC-e",
            TaskFamily::ArcC => "ARC-c",
            TaskFamily::Obqa => "OBQA",
            TaskFamily::Mnli => "MNLI",
            TaskFamily::Sst2 => "SST-2",
            TaskFamily::Mrpc => "MRPC",
            TaskFamily::Cola => "CoLA",
            TaskFamily::Qnli => "QNLI",
            TaskFamily::Qqp => "QQP",
            TaskFamily::Rte => "RTE",
            TaskFamily::Stsb => "STSB",
            TaskFamily::Gpqa => "GPQA",
            TaskFamily::CodeGen => "Humaneval",
            TaskFamily::StrategyQa => "StrategyQA",
        }
    }

    /// "hard" target-domain tasks (Fig. 4 grouping).
    pub fn is_hard(&self) -> bool {
        matches!(
            self,
            TaskFamily::GsmHard | TaskFamily::AQuA | TaskFamily::Svamp | TaskFamily::ArcC | TaskFamily::Gpqa
        )
    }
}

/// One task sample: `tokens[answer_start..answer_start+answer_len]` is the
/// answer span (always preceded by the ANS marker).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Sample {
    pub tokens: Vec<i32>,
    pub answer_start: usize,
    pub answer_len: usize,
}

impl Sample {
    fn close(mut prompt: Vec<i32>, answer: Vec<i32>) -> Sample {
        prompt.push(ANS);
        let answer_start = prompt.len();
        let answer_len = answer.len();
        prompt.extend(answer);
        Sample {
            tokens: prompt,
            answer_start,
            answer_len,
        }
    }

    pub fn prompt(&self) -> &[i32] {
        &self.tokens[..self.answer_start]
    }

    pub fn answer(&self) -> &[i32] {
        &self.tokens[self.answer_start..self.answer_start + self.answer_len]
    }
}

/// A generated family with disjoint splits.
#[derive(Clone, Debug)]
pub struct TaskSet {
    pub family: TaskFamily,
    pub train: Vec<Sample>,
    pub test: Vec<Sample>,
}

fn split_hash(prompt: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in prompt {
        h ^= t as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl TaskSet {
    /// Generate `n_train`/`n_test` deduplicated samples; ~80/20 split by
    /// prompt hash so the two sides can never share a question.
    pub fn generate(
        family: TaskFamily,
        vocab: &Vocab,
        kg: &Kg,
        n_train: usize,
        n_test: usize,
        seed: u64,
    ) -> TaskSet {
        let mut rng = Rng::new(seed ^ split_hash(&[family as i32]));
        let mut train = Vec::with_capacity(n_train);
        let mut test = Vec::with_capacity(n_test);
        let mut seen: HashSet<u64> = HashSet::new();
        let mut attempts = 0usize;
        let budget = (n_train + n_test) * 400;
        while (train.len() < n_train || test.len() < n_test) && attempts < budget {
            attempts += 1;
            let s = gen_sample(family, vocab, kg, &mut rng);
            let h = split_hash(s.prompt());
            let is_test = h % 10 >= 8;
            // dedupe across both splits (identical prompts carry identical
            // answers by construction, but keep sets clean anyway)
            if !seen.insert(h) {
                continue;
            }
            if is_test {
                if test.len() < n_test {
                    test.push(s);
                }
            } else if train.len() < n_train {
                train.push(s);
            }
        }
        TaskSet {
            family,
            train,
            test,
        }
    }
}

/// Generate one sample of the family.
pub fn gen_sample(family: TaskFamily, vocab: &Vocab, kg: &Kg, rng: &mut Rng) -> Sample {
    use TaskFamily::*;
    match family {
        // ---------- arithmetic ----------
        MultiArith => {
            let (a, b, c) = (rng.range(0, 7), rng.range(0, 7), rng.range(0, 7));
            let mut p = vec![BOS];
            p.extend(vocab.number(a));
            p.push(PLUS);
            p.extend(vocab.number(b));
            p.push(PLUS);
            p.extend(vocab.number(c));
            p.push(EQ);
            Sample::close(p, vocab.number(a + b + c))
        }
        GsmHard => {
            let (a, b) = (rng.range(0, 7), rng.range(0, 7));
            let c = rng.range(2, 5);
            let d = rng.range(0, 10);
            let mut p = vec![BOS, LPAR];
            p.extend(vocab.number(a));
            p.push(PLUS);
            p.extend(vocab.number(b));
            p.push(RPAR);
            p.push(MUL);
            p.extend(vocab.number(c));
            p.push(SUB);
            p.extend(vocab.number(d));
            p.push(EQ);
            Sample::close(p, vocab.number((a + b) * c - d))
        }
        AddSub => {
            let (a, b) = (rng.range(0, 25), rng.range(0, 25));
            let mut p = vec![BOS];
            p.extend(vocab.number(a));
            p.push(SUB);
            p.extend(vocab.number(b));
            p.push(EQ);
            Sample::close(p, vocab.number(a - b))
        }
        AQuA => {
            let (a, b) = (rng.range(0, 25), rng.range(0, 25));
            let ans = a + b;
            let correct = rng.below(5);
            let mut p = vec![BOS];
            p.extend(vocab.number(a));
            p.push(PLUS);
            p.extend(vocab.number(b));
            p.push(QMARK);
            for (i, &label) in CHOICE.iter().enumerate() {
                p.push(label);
                let v = if i == correct {
                    ans
                } else {
                    // distinct distractor near the answer
                    let mut v = ans + rng.range(1, 10) * if rng.chance(0.5) { 1 } else { -1 };
                    if v == ans {
                        v += 1;
                    }
                    v
                };
                p.extend(vocab.number(v));
            }
            Sample::close(p, vec![CHOICE[correct]])
        }
        SingleEq => {
            let (a, x) = (rng.range(0, 15), rng.range(0, 15));
            let c = a + x;
            let mut p = vec![BOS];
            p.extend(vocab.number(a));
            p.push(PLUS);
            p.push(VAR_X);
            p.push(EQ);
            p.extend(vocab.number(c));
            p.push(QMARK);
            Sample::close(p, vocab.number(x))
        }
        Svamp => {
            let (a, b) = (rng.range(0, 8), rng.range(0, 8));
            let c = rng.range(0, 12);
            let mut p = vec![BOS];
            p.extend(vocab.number(a));
            p.push(MUL);
            p.extend(vocab.number(b));
            p.push(PLUS);
            p.extend(vocab.number(c));
            p.push(EQ);
            Sample::close(p, vocab.number(a * b + c))
        }
        Mawps => {
            // word-problem surface: filler context around two numbers
            let (a, b) = (rng.range(0, 15), rng.range(0, 15));
            let f = |rng: &mut Rng| vocab.filler(rng.below(40));
            let mut p = vec![BOS, f(rng), f(rng)];
            p.extend(vocab.number(a));
            p.push(f(rng));
            p.extend(vocab.number(b));
            p.push(f(rng));
            p.push(QMARK);
            Sample::close(p, vocab.number(a + b))
        }
        // ---------- relational QA ----------
        BoolQ => {
            let (e, r, t) = kg.sample_fact(rng);
            let truthy = rng.chance(0.5);
            let shown = if truthy { t } else { kg.distractor(rng, t) };
            let p = vec![
                BOS,
                QMARK,
                vocab.entity(e),
                vocab.relation(r),
                vocab.entity(shown),
            ];
            Sample::close(p, vec![if truthy { YES } else { NO }])
        }
        Piqa => {
            let (e, r, t) = kg.sample_fact(rng);
            let d = kg.distractor(rng, t);
            let correct = rng.below(2);
            let (ca, cb) = if correct == 0 { (t, d) } else { (d, t) };
            let p = vec![
                BOS,
                vocab.entity(e),
                vocab.relation(r),
                SEP,
                CHOICE[0],
                vocab.entity(ca),
                CHOICE[1],
                vocab.entity(cb),
            ];
            Sample::close(p, vec![CHOICE[correct]])
        }
        Siqa => {
            // which relation connects e to t?
            let (e, r, t) = kg.sample_fact(rng);
            let correct = rng.below(3);
            let mut rels = Vec::new();
            for i in 0..3 {
                if i == correct {
                    rels.push(r);
                } else {
                    loop {
                        let rr = rng.below(kg.n_relations);
                        if rr != r && kg.lookup(e, rr) != Some(t) {
                            rels.push(rr);
                            break;
                        }
                    }
                }
            }
            let mut p = vec![BOS, vocab.entity(e), QMARK, vocab.entity(t), SEP];
            for (i, &rr) in rels.iter().enumerate() {
                p.push(CHOICE[i]);
                p.push(vocab.relation(rr));
            }
            Sample::close(p, vec![CHOICE[correct]])
        }
        HellaSwag => {
            // chain continuation: e -r1-> m; which entity does m -r2-> ?
            let (e, r1, m, r2, t) = kg.sample_2hop(rng);
            let correct = rng.below(4);
            let mut p = vec![
                BOS,
                vocab.entity(e),
                vocab.relation(r1),
                vocab.entity(m),
                vocab.relation(r2),
                SEP,
            ];
            for (i, &label) in CHOICE[..4].iter().enumerate() {
                p.push(label);
                let shown = if i == correct { t } else { kg.distractor(rng, t) };
                p.push(vocab.entity(shown));
            }
            Sample::close(p, vec![CHOICE[correct]])
        }
        Winogrande => {
            // which of e1, e2 satisfies r -> t? answer is the entity itself
            let (e1, r, t) = kg.sample_fact(rng);
            let e2 = loop {
                let cand = rng.below(kg.n_entities);
                if cand != e1 && kg.lookup(cand, r) != Some(t) {
                    break cand;
                }
            };
            let first = rng.chance(0.5);
            let (sa, sb) = if first { (e1, e2) } else { (e2, e1) };
            let p = vec![
                BOS,
                vocab.entity(sa),
                COMMA,
                vocab.entity(sb),
                COLON,
                vocab.relation(r),
                vocab.entity(t),
                QMARK,
            ];
            Sample::close(p, vec![vocab.entity(e1)])
        }
        ArcE | Obqa => {
            // 1-hop 4-choice; OBQA draws from the rare tier
            let (e, r, t) = if family == Obqa {
                kg.sample_fact_tier(rng, false)
            } else {
                kg.sample_fact(rng)
            };
            let correct = rng.below(4);
            let mut p = vec![BOS, QMARK, vocab.entity(e), vocab.relation(r), SEP];
            for (i, &label) in CHOICE[..4].iter().enumerate() {
                p.push(label);
                let shown = if i == correct { t } else { kg.distractor(rng, t) };
                p.push(vocab.entity(shown));
            }
            Sample::close(p, vec![CHOICE[correct]])
        }
        ArcC => {
            // 2-hop 4-choice (hard)
            let (e, r1, _m, r2, t) = kg.sample_2hop(rng);
            let correct = rng.below(4);
            let mut p = vec![
                BOS,
                QMARK,
                vocab.entity(e),
                vocab.relation(r1),
                vocab.relation(r2),
                SEP,
            ];
            for (i, &label) in CHOICE[..4].iter().enumerate() {
                p.push(label);
                let shown = if i == correct { t } else { kg.distractor(rng, t) };
                p.push(vocab.entity(shown));
            }
            Sample::close(p, vec![CHOICE[correct]])
        }
        // ---------- sequence classification (GLUE analogs) ----------
        Sst2 => {
            // "sentiment": majority of tokens from the positive half
            let len = 7 + rng.below(4);
            let n_pos = rng.below(len + 1);
            let half = vocab.n_filler / 2;
            let mut toks: Vec<i32> = (0..len)
                .map(|i| {
                    if i < n_pos {
                        vocab.filler(rng.below(half))
                    } else {
                        vocab.filler(half + rng.below(vocab.n_filler - half))
                    }
                })
                .collect();
            rng.shuffle(&mut toks);
            let mut p = vec![BOS];
            p.extend(&toks);
            let positive = 2 * n_pos > len;
            Sample::close(p, vec![if positive { YES } else { NO }])
        }
        Mnli => {
            // entail = hypothesis ⊆ premise; contradict = disjoint; else neutral
            let plen = 6 + rng.below(3);
            let prem: Vec<i32> = (0..plen).map(|_| vocab.filler(rng.below(60))).collect();
            let hlen = 3;
            let mode = rng.below(3);
            let hyp: Vec<i32> = match mode {
                0 => (0..hlen).map(|_| prem[rng.below(plen)]).collect(),
                1 => (0..hlen)
                    .map(|_| loop {
                        let t = vocab.filler(rng.below(60));
                        if !prem.contains(&t) {
                            break t;
                        }
                    })
                    .collect(),
                _ => vec![
                    prem[rng.below(plen)],
                    loop {
                        let t = vocab.filler(rng.below(60));
                        if !prem.contains(&t) {
                            break t;
                        }
                    },
                    prem[rng.below(plen)],
                ],
            };
            let mut p = vec![BOS];
            p.extend(&prem);
            p.push(SEP);
            p.extend(&hyp);
            let label = match mode {
                0 => YES,
                1 => NO,
                _ => MAYBE,
            };
            Sample::close(p, vec![label])
        }
        Mrpc | Qqp => {
            // paraphrase = same multiset; negative differs in 1 (MRPC) or
            // is a near-miss with 1 swap + 1 replace (QQP, harder)
            let len = 6 + rng.below(3);
            let a: Vec<i32> = (0..len).map(|_| vocab.filler(rng.below(80))).collect();
            let mut b = a.clone();
            rng.shuffle(&mut b);
            let same = rng.chance(0.5);
            if !same {
                let idx = rng.below(len);
                b[idx] = loop {
                    let t = vocab.filler(rng.below(80));
                    if !a.contains(&t) {
                        break t;
                    }
                };
                if family == TaskFamily::Qqp {
                    b.swap(0, len - 1);
                }
            }
            let mut p = vec![BOS];
            p.extend(&a);
            p.push(SEP);
            p.extend(&b);
            Sample::close(p, vec![if same { YES } else { NO }])
        }
        Cola => {
            // "grammatical" = strictly alternating low/high filler halves
            let len = 8;
            let half = vocab.n_filler / 2;
            let good = rng.chance(0.5);
            let mut toks = Vec::with_capacity(len);
            for i in 0..len {
                let lo = i % 2 == 0;
                toks.push(if lo {
                    vocab.filler(rng.below(half))
                } else {
                    vocab.filler(half + rng.below(vocab.n_filler - half))
                });
            }
            if !good {
                // violate alternation at a random position
                let i = rng.below(len - 1);
                toks[i + 1] = toks[i];
            }
            let mut p = vec![BOS];
            p.extend(&toks);
            Sample::close(p, vec![if good { YES } else { NO }])
        }
        Qnli => {
            // does the query token occur in the passage?
            let len = 8 + rng.below(4);
            let pass: Vec<i32> = (0..len).map(|_| vocab.filler(rng.below(100))).collect();
            let present = rng.chance(0.5);
            let q = if present {
                pass[rng.below(len)]
            } else {
                loop {
                    let t = vocab.filler(rng.below(100));
                    if !pass.contains(&t) {
                        break t;
                    }
                }
            };
            let mut p = vec![BOS, q, SEP];
            p.extend(&pass);
            Sample::close(p, vec![if present { YES } else { NO }])
        }
        Rte => {
            // entailment-as-subset over sets of 3
            let a: Vec<i32> = (0..6).map(|_| vocab.filler(rng.below(60))).collect();
            let entail = rng.chance(0.5);
            let b: Vec<i32> = if entail {
                (0..3).map(|_| a[rng.below(6)]).collect()
            } else {
                let mut b: Vec<i32> = (0..2).map(|_| a[rng.below(6)]).collect();
                b.push(loop {
                    let t = vocab.filler(rng.below(60));
                    if !a.contains(&t) {
                        break t;
                    }
                });
                b
            };
            let mut p = vec![BOS];
            p.extend(&a);
            p.push(SEP);
            p.extend(&b);
            Sample::close(p, vec![if entail { YES } else { NO }])
        }
        Stsb => {
            // similarity bucket = #shared tokens between two length-5 seqs
            let a: Vec<i32> = (0..5).map(|_| vocab.filler(rng.below(50))).collect();
            let shared = rng.below(6);
            let mut b = Vec::with_capacity(5);
            for item in a.iter().take(shared) {
                b.push(*item);
            }
            while b.len() < 5 {
                b.push(loop {
                    let t = vocab.filler(rng.below(50));
                    if !a.contains(&t) {
                        break t;
                    }
                });
            }
            rng.shuffle(&mut b);
            let mut p = vec![BOS];
            p.extend(&a);
            p.push(SEP);
            p.extend(&b);
            // exact bucket = |a ∩ b| (a has distinct-ish tokens; recount)
            let k = a.iter().filter(|t| b.contains(t)).count().min(5) as u32;
            Sample::close(p, vec![vocab.digit(k)])
        }
        // ---------- extras ----------
        Gpqa => {
            let (e, r1, _m1, r2, _m2, r3, t) = kg.sample_3hop(rng);
            let correct = rng.below(4);
            let mut p = vec![
                BOS,
                QMARK,
                vocab.entity(e),
                vocab.relation(r1),
                vocab.relation(r2),
                vocab.relation(r3),
                SEP,
            ];
            for (i, &label) in CHOICE[..4].iter().enumerate() {
                p.push(label);
                let shown = if i == correct { t } else { kg.distractor(rng, t) };
                p.push(vocab.entity(shown));
            }
            Sample::close(p, vec![CHOICE[correct]])
        }
        CodeGen => {
            // "programs": opcode + 4 digits -> transformed 4 digits
            let op = rng.below(3);
            let digits: Vec<u32> = (0..4).map(|_| rng.below(10) as u32).collect();
            let out: Vec<u32> = match op {
                0 => digits.iter().rev().copied().collect(), // reverse
                1 => {
                    let mut s = digits.clone();
                    s.sort_unstable();
                    s
                } // sort
                _ => digits.iter().map(|d| (d + 1) % 10).collect(), // inc
            };
            let mut p = vec![BOS, vocab.filler(op)];
            p.extend(digits.iter().map(|&d| vocab.digit(d)));
            Sample::close(p, out.iter().map(|&d| vocab.digit(d)).collect())
        }
        StrategyQa => {
            let (e, r1, _m, r2, t) = kg.sample_2hop(rng);
            let truthy = rng.chance(0.5);
            let shown = if truthy { t } else { kg.distractor(rng, t) };
            let p = vec![
                BOS,
                QMARK,
                vocab.entity(e),
                vocab.relation(r1),
                vocab.relation(r2),
                vocab.entity(shown),
            ];
            Sample::close(p, vec![if truthy { YES } else { NO }])
        }
    }
}

/// Convert samples into training/eval batches, one sample per row; loss
/// mask covers exactly the answer span (position i predicts token i+1).
/// Returns (batch, rows-used) pairs.
pub fn samples_to_batches(
    samples: &[Sample],
    batch: usize,
    seq: usize,
) -> Vec<(Batch, usize)> {
    let mut out = Vec::new();
    for chunk in samples.chunks(batch) {
        let mut b = Batch::empty(batch, seq);
        for (row, s) in chunk.iter().enumerate() {
            write_row(&mut b, row, s, seq);
        }
        out.push((b, chunk.len()));
    }
    out
}

fn write_row(b: &mut Batch, row: usize, s: &Sample, seq: usize) {
    let n = s.tokens.len().min(seq);
    for i in 0..n {
        b.tokens[row * seq + i] = s.tokens[i];
    }
    for i in 0..n.saturating_sub(1) {
        b.targets[row * seq + i] = s.tokens[i + 1];
    }
    // mask positions predicting the answer span: i+1 in [start, start+len)
    let lo = s.answer_start.saturating_sub(1);
    let hi = (s.answer_start + s.answer_len - 1).min(seq - 1);
    for i in lo..hi {
        b.loss_mask[row * seq + i] = 1.0;
    }
}

/// Training source: uniform mixture over families' train splits.
/// Samples are *packed* back-to-back in each row (loss still masked to
/// answer spans only) — with short samples this multiplies the learning
/// signal per step ~4-6x over one-sample-per-row.
pub struct TaskMixSource {
    pub sets: Vec<TaskSet>,
    pub batch: usize,
    pub seq: usize,
}

impl BatchSource for TaskMixSource {
    fn next_batch(&mut self, rng: &mut Rng) -> Batch {
        let mut b = Batch::empty(self.batch, self.seq);
        for row in 0..self.batch {
            let mut pos = 0usize;
            loop {
                let set = &self.sets[rng.below(self.sets.len())];
                let s = &set.train[rng.below(set.train.len())];
                if pos + s.tokens.len() + 1 > self.seq {
                    break;
                }
                write_sample_at(&mut b, row, pos, s, self.seq);
                pos += s.tokens.len();
            }
            if pos == 0 {
                // degenerate: sample longer than seq; truncate-write one
                let set = &self.sets[rng.below(self.sets.len())];
                let s = &set.train[rng.below(set.train.len())];
                write_row(&mut b, row, s, self.seq);
            }
        }
        b
    }

    fn shape(&self) -> (usize, usize) {
        (self.batch, self.seq)
    }
}

/// Write a sample at a row offset with packed next-token targets.
fn write_sample_at(b: &mut Batch, row: usize, pos: usize, s: &Sample, seq: usize) {
    let base = row * seq + pos;
    let n = s.tokens.len();
    debug_assert!(pos + n <= seq);
    for i in 0..n {
        b.tokens[base + i] = s.tokens[i];
    }
    for i in 0..n.saturating_sub(1) {
        b.targets[base + i] = s.tokens[i + 1];
    }
    let lo = s.answer_start - 1;
    let hi = s.answer_start + s.answer_len - 1;
    for i in lo..hi.min(n - 1).max(lo) {
        b.loss_mask[base + i] = 1.0;
    }
    // also learn to predict the answer's final position -> nothing beyond
    let _ = hi;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> (Vocab, Kg) {
        let v = Vocab::new(512);
        let kg = Kg::new(7, v.n_entities, v.n_relations);
        (v, kg)
    }

    const ALL: [TaskFamily; 26] = [
        TaskFamily::MultiArith,
        TaskFamily::GsmHard,
        TaskFamily::AddSub,
        TaskFamily::AQuA,
        TaskFamily::SingleEq,
        TaskFamily::Svamp,
        TaskFamily::Mawps,
        TaskFamily::BoolQ,
        TaskFamily::Piqa,
        TaskFamily::Siqa,
        TaskFamily::HellaSwag,
        TaskFamily::Winogrande,
        TaskFamily::ArcE,
        TaskFamily::ArcC,
        TaskFamily::Obqa,
        TaskFamily::Mnli,
        TaskFamily::Sst2,
        TaskFamily::Mrpc,
        TaskFamily::Cola,
        TaskFamily::Qnli,
        TaskFamily::Qqp,
        TaskFamily::Rte,
        TaskFamily::Stsb,
        TaskFamily::Gpqa,
        TaskFamily::CodeGen,
        TaskFamily::StrategyQa,
    ];

    #[test]
    fn all_families_generate_valid_samples() {
        let (v, kg) = env();
        let mut rng = Rng::new(1);
        for fam in ALL {
            for _ in 0..50 {
                let s = gen_sample(fam, &v, &kg, &mut rng);
                assert_eq!(s.tokens[0], BOS, "{fam:?}");
                assert!(s.answer_len >= 1, "{fam:?}");
                assert_eq!(s.tokens[s.answer_start - 1], ANS, "{fam:?}");
                assert!(
                    s.answer_start + s.answer_len <= s.tokens.len(),
                    "{fam:?}"
                );
                assert!(s.tokens.len() <= 60, "{fam:?} too long: {}", s.tokens.len());
                for &t in &s.tokens {
                    assert!((t as usize) < v.size, "{fam:?} token {t}");
                }
            }
        }
    }

    #[test]
    fn arithmetic_answers_are_correct() {
        let (v, kg) = env();
        let mut rng = Rng::new(2);
        // decode digits back for MultiArith and verify the sum
        for _ in 0..50 {
            let s = gen_sample(TaskFamily::MultiArith, &v, &kg, &mut rng);
            let nums = decode_numbers(&s.tokens[..s.answer_start - 1]);
            assert_eq!(nums.len(), 3, "{:?}", s.tokens);
            let ans = decode_numbers(s.answer());
            assert_eq!(ans[0], nums.iter().sum::<i64>());
        }
    }

    fn decode_numbers(toks: &[i32]) -> Vec<i64> {
        let mut out = Vec::new();
        let mut cur: Option<i64> = None;
        let mut neg = false;
        for &t in toks {
            if t == MINUS {
                neg = true;
            } else if (DIGIT0..DIGIT0 + 10).contains(&t) {
                cur = Some(cur.unwrap_or(0) * 10 + (t - DIGIT0) as i64);
            } else {
                if let Some(x) = cur.take() {
                    out.push(if neg { -x } else { x });
                }
                neg = false;
            }
        }
        if let Some(x) = cur {
            out.push(if neg { -x } else { x });
        }
        out
    }

    #[test]
    fn splits_are_disjoint_and_sized() {
        let (v, kg) = env();
        let ts = TaskSet::generate(TaskFamily::AddSub, &v, &kg, 300, 60, 42);
        assert_eq!(ts.train.len(), 300);
        assert_eq!(ts.test.len(), 60);
        let train_prompts: HashSet<Vec<i32>> =
            ts.train.iter().map(|s| s.prompt().to_vec()).collect();
        for t in &ts.test {
            assert!(!train_prompts.contains(t.prompt()), "split leak");
        }
    }

    #[test]
    fn batch_masks_cover_answer_span_only() {
        let (v, kg) = env();
        let mut rng = Rng::new(3);
        let s = gen_sample(TaskFamily::BoolQ, &v, &kg, &mut rng);
        let bs = samples_to_batches(&[s.clone()], 2, 32);
        assert_eq!(bs.len(), 1);
        let (b, used) = &bs[0];
        assert_eq!(*used, 1);
        let mask_count = b.loss_mask.iter().filter(|&&m| m == 1.0).count();
        assert_eq!(mask_count, s.answer_len);
        // the masked positions' targets are exactly the answer tokens
        let got: Vec<i32> = (0..32)
            .filter(|&i| b.loss_mask[i] == 1.0)
            .map(|i| b.targets[i])
            .collect();
        assert_eq!(got, s.answer());
        // row 1 untouched
        assert!(b.loss_mask[32..].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn task_mix_source_shapes() {
        let (v, kg) = env();
        let sets = vec![
            TaskSet::generate(TaskFamily::AddSub, &v, &kg, 50, 10, 1),
            TaskSet::generate(TaskFamily::BoolQ, &v, &kg, 50, 10, 1),
        ];
        let mut src = TaskMixSource {
            sets,
            batch: 4,
            seq: 64,
        };
        let mut rng = Rng::new(5);
        let b = src.next_batch(&mut rng);
        assert_eq!(b.tokens.len(), 4 * 64);
        assert!(b.loss_mask.iter().any(|&m| m == 1.0));
    }

    #[test]
    fn larger_vocab_tasks_stay_in_range() {
        let v = Vocab::new(4096);
        let kg = Kg::new(11, v.n_entities, v.n_relations);
        let mut rng = Rng::new(6);
        for fam in ALL {
            let s = gen_sample(fam, &v, &kg, &mut rng);
            for &t in &s.tokens {
                assert!((t as usize) < v.size);
            }
        }
    }
}

//! Token-id layout, parameterized by vocabulary size.
//!
//! Fixed special/digit/operator prefix, then relations, entities, and a
//! filler tail (template words for word-problem surfaces and the language
//! mixture). Numbers are digit-tokenized (base 10, optional minus).

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const ANS: i32 = 4;
pub const QMARK: i32 = 5;
pub const YES: i32 = 6;
pub const NO: i32 = 7;
pub const MINUS: i32 = 8;
/// Multiple-choice labels A..E.
pub const CHOICE: [i32; 5] = [9, 10, 11, 12, 13];
pub const VAR_X: i32 = 14;
pub const MAYBE: i32 = 15;

pub const DIGIT0: i32 = 16; // ..25
pub const PLUS: i32 = 26;
pub const SUB: i32 = 27;
pub const MUL: i32 = 28;
pub const DIV: i32 = 29;
pub const EQ: i32 = 30;
pub const LPAR: i32 = 31;
pub const RPAR: i32 = 32;
pub const COMMA: i32 = 33;
pub const DOT: i32 = 34;
pub const COLON: i32 = 35;

pub const REL0: i32 = 36;

#[derive(Clone, Debug)]
pub struct Vocab {
    pub size: usize,
    pub n_relations: usize,
    pub n_entities: usize,
    pub n_filler: usize,
    ent0: i32,
    fill0: i32,
}

impl Vocab {
    /// Carve the given vocab size. Needs >= 128 tokens.
    pub fn new(size: usize) -> Vocab {
        assert!(size >= 128, "vocab too small: {size}");
        let n_relations = 24usize;
        let remaining = size - REL0 as usize - n_relations;
        // ~60% entities, rest filler
        let n_entities = (remaining * 3 / 5).min(4096);
        let n_filler = remaining - n_entities;
        Vocab {
            size,
            n_relations,
            n_entities,
            n_filler,
            ent0: REL0 + n_relations as i32,
            fill0: REL0 + (n_relations + n_entities) as i32,
        }
    }

    pub fn relation(&self, r: usize) -> i32 {
        debug_assert!(r < self.n_relations);
        REL0 + (r % self.n_relations) as i32
    }

    pub fn entity(&self, e: usize) -> i32 {
        debug_assert!(e < self.n_entities);
        self.ent0 + (e % self.n_entities) as i32
    }

    pub fn filler(&self, f: usize) -> i32 {
        self.fill0 + (f % self.n_filler) as i32
    }

    pub fn is_entity(&self, tok: i32) -> bool {
        tok >= self.ent0 && tok < self.fill0
    }

    pub fn entity_index(&self, tok: i32) -> Option<usize> {
        if self.is_entity(tok) {
            Some((tok - self.ent0) as usize)
        } else {
            None
        }
    }

    pub fn digit(&self, d: u32) -> i32 {
        debug_assert!(d < 10);
        DIGIT0 + d as i32
    }

    /// Digit-tokenize an integer (optional minus, no leading zeros).
    pub fn number(&self, x: i64) -> Vec<i32> {
        let mut out = Vec::new();
        if x < 0 {
            out.push(MINUS);
        }
        let mut mag = x.unsigned_abs();
        if mag == 0 {
            return vec![self.digit(0)];
        }
        let mut digits = Vec::new();
        while mag > 0 {
            digits.push(self.digit((mag % 10) as u32));
            mag /= 10;
        }
        digits.reverse();
        out.extend(digits);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_disjoint() {
        let v = Vocab::new(512);
        assert_eq!(v.size, 512);
        let r_last = v.relation(v.n_relations - 1);
        let e_first = v.entity(0);
        let e_last = v.entity(v.n_entities - 1);
        let f_first = v.filler(0);
        let f_last = v.filler(v.n_filler - 1);
        assert!(r_last < e_first);
        assert!(e_last < f_first);
        assert!((f_last as usize) < v.size);
        assert!(v.is_entity(e_first) && v.is_entity(e_last));
        assert!(!v.is_entity(r_last) && !v.is_entity(f_first));
    }

    #[test]
    fn number_tokenization() {
        let v = Vocab::new(512);
        assert_eq!(v.number(0), vec![DIGIT0]);
        assert_eq!(v.number(7), vec![DIGIT0 + 7]);
        assert_eq!(v.number(42), vec![DIGIT0 + 4, DIGIT0 + 2]);
        assert_eq!(v.number(-305), vec![MINUS, DIGIT0 + 3, DIGIT0, DIGIT0 + 5]);
    }

    #[test]
    fn scales_to_larger_vocabs() {
        for size in [512usize, 1024, 4096, 16384] {
            let v = Vocab::new(size);
            assert!(v.n_entities >= 200);
            assert!(v.n_filler >= 50);
            assert!((v.filler(v.n_filler - 1) as usize) < size);
        }
    }
}

//! Training loop: drives the train-step executable, hands gradients to the
//! active `Method`, tracks the loss curve and periodic evals.

pub mod eval;
pub mod pretrain;

use anyhow::Result;

use crate::data::BatchSource;
use crate::methods::{Ctx, Method};
use crate::optim::LrSchedule;
use crate::runtime::model_exec::ModelExec;
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr: f32,
    pub warmup_frac: f32,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 300,
            lr: 1e-3,
            warmup_frac: 0.03,
            log_every: 50,
            seed: 0,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    /// wall seconds of the whole run
    pub seconds: f64,
    /// (step, seconds) samples for step-latency accounting
    pub step_times: Vec<f64>,
}

impl TrainLog {
    /// Mean loss over the last `n` steps (convergence summary).
    pub fn tail_loss(&self, n: usize) -> f32 {
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().sum::<f32>() / tail.len() as f32
    }
}

/// Run `cfg.steps` optimizer steps of `method` starting from `params`
/// (mutated in place). Returns the loss curve.
pub fn train(
    exec: &ModelExec,
    src: &mut dyn BatchSource,
    method: &mut dyn Method,
    ctx: &mut Ctx,
    params: &mut [Tensor],
    cfg: &TrainCfg,
) -> Result<TrainLog> {
    let (b, s) = src.shape();
    anyhow::ensure!(
        b == exec.preset.batch && s == exec.preset.seq,
        "data source shape ({b},{s}) != preset ({}, {})",
        exec.preset.batch,
        exec.preset.seq
    );
    let sched = LrSchedule {
        base: cfg.lr,
        warmup: ((cfg.steps as f32) * cfg.warmup_frac) as usize,
        total: cfg.steps,
    };
    let mut data_rng = crate::util::rng::Rng::new(cfg.seed ^ 0xda7a);
    method.init(ctx, params)?;
    let mut log = TrainLog::default();
    let t0 = std::time::Instant::now();
    for step in 0..cfg.steps {
        let st = std::time::Instant::now();
        let batch = src.next_batch(&mut data_rng);
        let (loss, grads) = exec.train_step(params, &batch)?;
        // one batched mask-maintenance call (layer-parallel for sparse
        // methods; no-op for dense/adapter methods), then one batched
        // optimizer step. Order matters: a refresh that swaps mask
        // indices must migrate the Adam moments *before* the step reads
        // them (regression-tested by rust/tests/engine.rs).
        method.refresh_all(ctx, params, &grads, step)?;
        method.step_all(ctx, params, &grads, step, sched.at(step))?;
        log.losses.push(loss);
        log.step_times.push(st.elapsed().as_secs_f64());
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            log::info!(
                "[{}] step {step}/{} loss {loss:.4} lr {:.2e}",
                method.name(),
                cfg.steps,
                sched.at(step)
            );
        }
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
    }
    log.seconds = t0.elapsed().as_secs_f64();
    Ok(log)
}

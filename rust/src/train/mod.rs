//! Training loop: drives the train-step executable, hands gradients to the
//! active `Method`, tracks the loss curve and periodic evals. The core
//! loop ([`train_with`]) is generic over the gradient source and
//! checkpoint/resume-aware: with `TrainCfg::ckpt_every` set it writes a
//! versioned snapshot (`crate::ckpt`) every N steps, and [`resume`]
//! continues one bit-exactly — weights, optimizer moments, refresh
//! scheduling and both RNG streams (asserted by `rust/tests/ckpt.rs`).
//!
//! # Checkpoint I/O stays off the hot loop
//!
//! Snapshot persistence is split so the training loop never blocks on
//! disk:
//!
//! * the per-step loss/latency record appends to the buffered
//!   `curve.sidecar` (`ckpt::curve`, 12 bytes/step) instead of being
//!   cloned wholesale into every snapshot — snapshot bytes are flat in
//!   step count;
//! * snapshot bytes are serialized in-loop (O(model) memcpy, needs the
//!   live state) and handed to the double-buffered background
//!   `ckpt::AsyncSnapshotWriter`, which performs the atomic write and
//!   applies the `ckpt_keep` keep-last-N retention policy;
//! * the writer is drained before `train_with` returns — on the error
//!   path too — so crash-resume always sees every snapshot the run
//!   reported writing, and a resumed run reconstructs the full campaign
//!   curve from the sidecar next to the snapshot it restores.

pub mod eval;
pub mod pretrain;

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::ckpt;
use crate::data::BatchSource;
use crate::methods::{Ctx, Method};
use crate::optim::LrSchedule;
use crate::runtime::model_exec::ModelExec;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr: f32,
    pub warmup_frac: f32,
    pub log_every: usize,
    pub seed: u64,
    /// Write a versioned snapshot every N completed steps (0 = never).
    /// Takes effect only when `ckpt_dir` is set.
    pub ckpt_every: usize,
    /// Snapshot directory (`step_XXXXXXXX.snap` + `curve.sidecar`);
    /// `None` disables checkpointing regardless of `ckpt_every`.
    pub ckpt_dir: Option<PathBuf>,
    /// Keep only the newest N snapshots (0 = keep every snapshot). The
    /// curve sidecar is never pruned — it is the O(steps) record the
    /// snapshots deliberately don't duplicate.
    pub ckpt_keep: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 300,
            lr: 1e-3,
            warmup_frac: 0.03,
            log_every: 50,
            seed: 0,
            ckpt_every: 0,
            ckpt_dir: None,
            ckpt_keep: 0,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    /// wall seconds of the whole run
    pub seconds: f64,
    /// (step, seconds) samples for step-latency accounting
    pub step_times: Vec<f64>,
}

impl TrainLog {
    /// Mean loss over the last `n` steps (convergence summary).
    pub fn tail_loss(&self, n: usize) -> f32 {
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().sum::<f32>() / tail.len() as f32
    }

    /// `exp` of [`tail_loss`](Self::tail_loss) — the perplexity the run
    /// converged to. Artifact-free (`--toy`) matrix cells persist this
    /// as their target-suite metric (`exp::retention`); an empty curve
    /// yields NaN, which the ledger stores as `null`.
    pub fn tail_ppl(&self, n: usize) -> f64 {
        (self.tail_loss(n) as f64).exp()
    }
}

/// One gradient evaluation: given the current parameters and the run's
/// data RNG, produce `(loss, full grads)`. The production source wraps
/// `ModelExec::train_step` over `BatchSource::next_batch`; the
/// crash-resume suite and the `--toy` matrix cells substitute a
/// synthetic stream, exercising the *same* trainer loop without AOT
/// artifacts. Implementations must be a pure function of
/// `(params, rng position)` for resume to be bit-exact.
pub type GradFn<'a> = dyn FnMut(&[Tensor], &mut Rng) -> Result<(f32, Vec<Tensor>)> + 'a;

/// Run `cfg.steps` optimizer steps of `method` starting from `params`
/// (mutated in place). Returns the loss curve.
pub fn train(
    exec: &ModelExec,
    src: &mut dyn BatchSource,
    method: &mut dyn Method,
    ctx: &mut Ctx,
    params: &mut [Tensor],
    cfg: &TrainCfg,
) -> Result<TrainLog> {
    check_shape(exec, src)?;
    let mut step_fn = |params: &[Tensor], rng: &mut Rng| {
        let batch = src.next_batch(rng);
        exec.train_step(params, &batch)
    };
    train_with(&mut step_fn, method, ctx, params, cfg, None)
}

/// Resume a checkpointed run from `snapshot` and continue to
/// `cfg.steps`. The method must be freshly constructed with the same
/// spec as the original run (its state is loaded from the snapshot, not
/// `init`); `params` only supplies shapes — values are overwritten.
pub fn resume(
    exec: &ModelExec,
    src: &mut dyn BatchSource,
    method: &mut dyn Method,
    ctx: &mut Ctx,
    params: &mut [Tensor],
    cfg: &TrainCfg,
    snapshot: &Path,
) -> Result<TrainLog> {
    check_shape(exec, src)?;
    let mut step_fn = |params: &[Tensor], rng: &mut Rng| {
        let batch = src.next_batch(rng);
        exec.train_step(params, &batch)
    };
    train_with(&mut step_fn, method, ctx, params, cfg, Some(snapshot))
}

fn check_shape(exec: &ModelExec, src: &mut dyn BatchSource) -> Result<()> {
    let (b, s) = src.shape();
    anyhow::ensure!(
        b == exec.preset.batch && s == exec.preset.seq,
        "data source shape ({b},{s}) != preset ({}, {})",
        exec.preset.batch,
        exec.preset.seq
    );
    Ok(())
}

/// The core trainer loop over an abstract gradient source. Fresh runs
/// `init` the method at step 0; with `resume_from` the snapshot restores
/// weights, method state, the loss curve and both RNG streams, and the
/// loop continues at the recorded step — so `refresh_all` scheduling
/// (interval refreshes, lazy first-step selection, SpIEL grow/drop
/// cycles) replays on exactly the original step boundaries.
pub fn train_with(
    step_fn: &mut GradFn,
    method: &mut dyn Method,
    ctx: &mut Ctx,
    params: &mut [Tensor],
    cfg: &TrainCfg,
    resume_from: Option<&Path>,
) -> Result<TrainLog> {
    let sched = LrSchedule {
        base: cfg.lr,
        warmup: ((cfg.steps as f32) * cfg.warmup_frac) as usize,
        total: cfg.steps,
    };
    let mut data_rng = Rng::new(cfg.seed ^ 0xda7a);
    let mut log = TrainLog::default();
    let start = match resume_from {
        Some(path) => {
            let state = ckpt::load_trainer(path)?;
            // a different lr / warmup / total changes the LR schedule:
            // the continuation would silently diverge from the
            // uninterrupted run, so refuse instead of hybrid-resuming
            anyhow::ensure!(
                state.lr.to_bits() == cfg.lr.to_bits()
                    && state.warmup_frac.to_bits() == cfg.warmup_frac.to_bits()
                    && state.cfg_steps == cfg.steps,
                "snapshot was written under a different TrainCfg \
                 (lr {} / warmup {} / steps {}) than the resuming run \
                 (lr {} / warmup {} / steps {}) — the LR schedule would diverge",
                state.lr,
                state.warmup_frac,
                state.cfg_steps,
                cfg.lr,
                cfg.warmup_frac,
                cfg.steps
            );
            let (step, seconds) = state.restore(method, params, &mut ctx.rng, &mut data_rng)?;
            anyhow::ensure!(
                step <= cfg.steps,
                "snapshot is at step {step}, past cfg.steps = {}",
                cfg.steps
            );
            // the whole curve prefix — losses and step latencies — is
            // reconstructed from the append-only sidecar next to the
            // snapshot, so the returned log covers the campaign, not
            // just the post-crash tail (snapshots themselves stay
            // O(model))
            let side_dir = path.parent().unwrap_or_else(|| Path::new("."));
            let (losses, step_times) = ckpt::curve::read_curve(side_dir, step)?;
            log = TrainLog {
                losses,
                step_times,
                seconds,
            };
            log::info!(
                "[{}] resumed from {path:?} at step {step}/{}",
                method.name(),
                cfg.steps
            );
            step
        }
        None => {
            method.init(ctx, params)?;
            0
        }
    };
    // off-loop checkpoint plumbing: the buffered curve sidecar (seeded
    // with the restored prefix — which also truncates any crash tail)
    // and the double-buffered background snapshot writer
    let ckpt_on = cfg.ckpt_every > 0 && cfg.ckpt_dir.is_some();
    let mut curve = match (&cfg.ckpt_dir, ckpt_on) {
        (Some(dir), true) => {
            // opening the sidecar rewrites it as the restored prefix. A
            // snapshot AHEAD of this run's start (a fresh run pointed at
            // a used directory, or a resume from an older-than-newest
            // snapshot) depends on the records that rewrite would
            // destroy — refuse loudly instead of silently orphaning it.
            if let Some(newest) = ckpt::latest_snapshot(dir)? {
                let newest_step = ckpt::snapshot_step(&newest).unwrap_or(0);
                anyhow::ensure!(
                    newest_step <= start,
                    "checkpoint dir {dir:?} holds a snapshot at step {newest_step}, ahead of \
                     this run's start step {start}; starting here would truncate the curve \
                     sidecar that snapshot depends on — resume from the newest snapshot \
                     (`--resume latest`) or point at a fresh --ckpt-dir"
                );
                // a dir that already holds snapshots belongs to one
                // campaign: installing a FOREIGN snapshot's curve prefix
                // over its sidecar would silently re-pair the existing
                // snapshots with the wrong campaign's records
                if let Some(src) = resume_from {
                    anyhow::ensure!(
                        src.parent() == Some(dir.as_path()),
                        "resuming snapshot {src:?} into checkpoint dir {dir:?}, which already \
                         holds snapshots from a different run — their curve sidecar would be \
                         overwritten with the resumed campaign's records; migrate into an \
                         empty --ckpt-dir instead"
                    );
                }
            }
            let prefix: Vec<(f32, f64)> = log
                .losses
                .iter()
                .copied()
                .zip(log.step_times.iter().copied())
                .collect();
            Some(ckpt::curve::CurveWriter::open(dir, &prefix)?)
        }
        _ => None,
    };
    // if the run errors out mid-loop, the writer's Drop still drains the
    // queue, so the newest submitted snapshot is durable for resume
    let mut writer = if ckpt_on {
        Some(ckpt::AsyncSnapshotWriter::new())
    } else {
        None
    };
    let t0 = std::time::Instant::now();
    for step in start..cfg.steps {
        let st = std::time::Instant::now();
        let (loss, grads) = step_fn(params, &mut data_rng)?;
        // one batched mask-maintenance call (layer-parallel for sparse
        // methods; no-op for dense/adapter methods), then one batched
        // optimizer step. Order matters: a refresh that swaps mask
        // indices must migrate the Adam moments *before* the step reads
        // them (regression-tested by rust/tests/engine.rs).
        method.refresh_all(ctx, params, &grads, step)?;
        method.step_all(ctx, params, &grads, step, sched.at(step))?;
        let dt = st.elapsed().as_secs_f64();
        log.losses.push(loss);
        log.step_times.push(dt);
        if let Some(c) = curve.as_mut() {
            c.append(loss, dt)?;
        }
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            log::info!(
                "[{}] step {step}/{} loss {loss:.4} lr {:.2e}",
                method.name(),
                cfg.steps,
                sched.at(step)
            );
        }
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
        if ckpt_on && (step + 1) % cfg.ckpt_every == 0 {
            let dir = cfg.ckpt_dir.as_ref().expect("ckpt_on implies ckpt_dir");
            let path = ckpt::snapshot_path(dir, step + 1);
            // the sidecar must cover every step this snapshot claims
            // before the snapshot can land on disk
            if let Some(c) = curve.as_mut() {
                c.flush()?;
            }
            // serialize in-loop (needs the live state), write off-loop;
            // log.seconds still holds the restored-prefix total during
            // the loop, so add this segment's elapsed time
            let bytes = ckpt::trainer_snapshot_bytes(
                step + 1,
                &*method,
                params,
                &ctx.rng,
                &data_rng,
                log.seconds + t0.elapsed().as_secs_f64(),
                cfg,
            )?;
            writer
                .as_mut()
                .expect("ckpt_on implies a writer")
                .submit(path.clone(), bytes, cfg.ckpt_keep)?;
            log::debug!("[{}] snapshot at step {} -> {path:?}", method.name(), step + 1);
        }
    }
    if let Some(c) = curve.as_mut() {
        c.flush()?;
    }
    if let Some(w) = writer {
        // surface any background write error before reporting success
        w.finish()?;
    }
    // accumulate: restored-prefix seconds (0.0 on a fresh run) + this
    // segment, so resumed runs report campaign wall time, not tail time
    log.seconds += t0.elapsed().as_secs_f64();
    Ok(log)
}

//! Evaluation harness: task accuracy (teacher-forced exact match),
//! held-out perplexity, fact-recall probe, and an autoregressive sampler
//! for pass@k (code-gen, Table 12).

use anyhow::Result;

use crate::data::tasks::{samples_to_batches, Sample};
use crate::data::CorpusGen;
use crate::runtime::model_exec::ModelExec;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Accuracy over samples: a sample counts iff every answer position is
/// greedy-predicted correctly.
pub fn accuracy(exec: &ModelExec, params: &[Tensor], samples: &[Sample]) -> Result<f64> {
    if samples.is_empty() {
        return Ok(0.0);
    }
    let (b, s) = (exec.preset.batch, exec.preset.seq);
    let mut correct = 0usize;
    let mut total = 0usize;
    for (batch, used) in samples_to_batches(samples, b, s) {
        let (_, preds) = exec.eval_step(params, &batch)?;
        for row in 0..used {
            let mut ok = true;
            let mut any = false;
            for i in 0..s {
                if batch.loss_mask[row * s + i] == 1.0 {
                    any = true;
                    if preds[row * s + i] != batch.targets[row * s + i] {
                        ok = false;
                        break;
                    }
                }
            }
            if any {
                total += 1;
                if ok {
                    correct += 1;
                }
            }
        }
    }
    Ok(100.0 * correct as f64 / total.max(1) as f64)
}

/// Held-out corpus perplexity (the Wikitext-ppl analog, Fig. 2a).
pub fn perplexity(
    exec: &ModelExec,
    params: &[Tensor],
    corpus: &CorpusGen,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let mut total = 0.0f64;
    let mut n = 0usize;
    for batch in corpus.eval_batches(n_batches, seed) {
        let (loss, _) = exec.eval_step(params, &batch)?;
        total += loss as f64;
        n += 1;
    }
    Ok((total / n.max(1) as f64).exp())
}

/// Fact-recall probe (Fig. 2b): P(correct target | "e r") for a set of
/// frequent KG facts. Returns the mean probability of the ground truth.
pub fn fact_recall(
    rt: &Runtime,
    exec: &ModelExec,
    params: &[Tensor],
    corpus: &CorpusGen,
    n_facts: usize,
    seed: u64,
) -> Result<f64> {
    let mut rng = Rng::new(seed ^ 0xfac7);
    let mut total = 0.0f64;
    let s = exec.preset.seq;
    for _ in 0..n_facts {
        let (e, r, t) = corpus.kg.sample_fact_tier(&mut rng, true);
        let mut toks = vec![crate::data::vocab::PAD; s];
        toks[0] = crate::data::vocab::BOS;
        toks[1] = corpus.vocab.entity(e);
        toks[2] = corpus.vocab.relation(r);
        let probs = exec.probe(rt, params, &toks, 2)?;
        total += probs[corpus.vocab.entity(t) as usize] as f64;
    }
    Ok(total / n_facts.max(1) as f64)
}

/// Autoregressive sampling of `len` answer tokens after a prompt, using
/// the probe executable per position (temperature > 0 => stochastic).
pub fn sample_answer(
    rt: &Runtime,
    exec: &ModelExec,
    params: &[Tensor],
    prompt: &[i32],
    len: usize,
    temperature: f32,
    rng: &mut Rng,
) -> Result<Vec<i32>> {
    let s = exec.preset.seq;
    anyhow::ensure!(prompt.len() + len <= s, "prompt too long for seq");
    let mut toks = vec![crate::data::vocab::PAD; s];
    toks[..prompt.len()].copy_from_slice(prompt);
    let mut out = Vec::with_capacity(len);
    for j in 0..len {
        let pos = prompt.len() + j - 1;
        let probs = exec.probe(rt, params, &toks, pos)?;
        let tok = if temperature <= 0.0 {
            probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(0)
        } else {
            sample_from(&probs, temperature, rng)
        };
        toks[prompt.len() + j] = tok;
        out.push(tok);
    }
    Ok(out)
}

fn sample_from(probs: &[f32], temperature: f32, rng: &mut Rng) -> i32 {
    // temperature re-softmax in log space
    let logits: Vec<f64> = probs
        .iter()
        .map(|&p| (p.max(1e-30) as f64).ln() / temperature as f64)
        .collect();
    let maxl = logits.iter().cloned().fold(f64::MIN, f64::max);
    let exps: Vec<f64> = logits.iter().map(|&l| (l - maxl).exp()).collect();
    let z: f64 = exps.iter().sum();
    let mut u = rng.next_f64() * z;
    for (i, &e) in exps.iter().enumerate() {
        u -= e;
        if u <= 0.0 {
            return i as i32;
        }
    }
    (exps.len() - 1) as i32
}

/// pass@k for generation tasks: a sample passes if any of k temperature
/// samples exactly matches the reference answer.
#[allow(clippy::too_many_arguments)]
pub fn pass_at_k(
    rt: &Runtime,
    exec: &ModelExec,
    params: &[Tensor],
    samples: &[Sample],
    k: usize,
    temperature: f32,
    seed: u64,
    max_samples: usize,
) -> Result<f64> {
    let mut rng = Rng::new(seed ^ 0x9a55);
    let mut pass = 0usize;
    let eval: Vec<&Sample> = samples.iter().take(max_samples).collect();
    for s in &eval {
        let mut ok = false;
        for t in 0..k {
            let temp = if t == 0 { 0.0 } else { temperature };
            let got = sample_answer(rt, exec, params, s.prompt(), s.answer_len, temp, &mut rng)?;
            if got == s.answer() {
                ok = true;
                break;
            }
        }
        if ok {
            pass += 1;
        }
    }
    Ok(100.0 * pass as f64 / eval.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_distribution_sanity() {
        let mut rng = Rng::new(1);
        let probs = vec![0.05f32, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..500 {
            counts[sample_from(&probs, 1.0, &mut rng) as usize] += 1;
        }
        assert!(counts[1] > 350, "{counts:?}");
        // low temperature sharpens toward argmax
        let mut counts = [0usize; 3];
        for _ in 0..200 {
            counts[sample_from(&probs, 0.2, &mut rng) as usize] += 1;
        }
        assert!(counts[1] > 195, "{counts:?}");
    }
}

//! Evaluation harness: task accuracy (teacher-forced exact match),
//! held-out perplexity, fact-recall probe, and an autoregressive sampler
//! for pass@k (code-gen, Table 12).
//!
//! Each metric is split into an executable-driven wrapper and a **pure
//! scoring kernel** ([`exact_match_counts`], [`ppl_from_total_nll`],
//! [`recall_from_probs`], [`pass_at_k_with`]) so the metric arithmetic —
//! including empty-sample and all-wrong edge cases — is locked by
//! hand-computed oracles in `rust/tests/eval_oracle.rs` without AOT
//! artifacts. These metrics also back the scenario matrix's per-cell
//! retention pass (`exp::retention`).

use anyhow::Result;

use crate::data::tasks::{samples_to_batches, Sample};
use crate::data::CorpusGen;
use crate::runtime::model_exec::ModelExec;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Pure exact-match kernel over one batch's rows: `(correct, scored)`.
/// A row is scored iff it has at least one masked position, and counts
/// correct iff **every** masked position is predicted exactly.
pub fn exact_match_counts(
    preds: &[i32],
    targets: &[i32],
    loss_mask: &[f32],
    rows: usize,
    seq: usize,
) -> (usize, usize) {
    let mut correct = 0usize;
    let mut total = 0usize;
    for row in 0..rows {
        let mut ok = true;
        let mut any = false;
        for i in 0..seq {
            if loss_mask[row * seq + i] == 1.0 {
                any = true;
                if preds[row * seq + i] != targets[row * seq + i] {
                    ok = false;
                    break;
                }
            }
        }
        if any {
            total += 1;
            if ok {
                correct += 1;
            }
        }
    }
    (correct, total)
}

/// Percent accuracy from match counts; zero scored rows read as 0.0
/// (no evidence of capability), never a division panic.
pub fn accuracy_from_counts(correct: usize, total: usize) -> f64 {
    100.0 * correct as f64 / total.max(1) as f64
}

/// Accuracy over samples: a sample counts iff every answer position is
/// greedy-predicted correctly.
pub fn accuracy(exec: &ModelExec, params: &[Tensor], samples: &[Sample]) -> Result<f64> {
    if samples.is_empty() {
        return Ok(0.0);
    }
    let (b, s) = (exec.preset.batch, exec.preset.seq);
    let mut correct = 0usize;
    let mut total = 0usize;
    for (batch, used) in samples_to_batches(samples, b, s) {
        let (_, preds) = exec.eval_step(params, &batch)?;
        let (c, t) = exact_match_counts(&preds, &batch.targets, &batch.loss_mask, used, s);
        correct += c;
        total += t;
    }
    Ok(accuracy_from_counts(correct, total))
}

/// Pure perplexity kernel: `exp` of the mean per-batch NLL. Zero
/// batches read as 1.0 — an empty eval stream carries no surprise, not
/// infinite surprise (and the ledger needs a finite value).
pub fn ppl_from_total_nll(total_nll: f64, n_batches: usize) -> f64 {
    (total_nll / n_batches.max(1) as f64).exp()
}

/// Held-out corpus perplexity (the Wikitext-ppl analog, Fig. 2a).
pub fn perplexity(
    exec: &ModelExec,
    params: &[Tensor],
    corpus: &CorpusGen,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let mut total = 0.0f64;
    let mut n = 0usize;
    for batch in corpus.eval_batches(n_batches, seed) {
        let (loss, _) = exec.eval_step(params, &batch)?;
        total += loss as f64;
        n += 1;
    }
    Ok(ppl_from_total_nll(total, n))
}

/// Teacher-forced perplexity over task samples (loss masked to answer
/// spans): `exp` of the mean per-batch eval loss. The scenario matrix's
/// target-suite metric. Empty `samples` read as 1.0 (see
/// [`ppl_from_total_nll`]).
pub fn sample_perplexity(exec: &ModelExec, params: &[Tensor], samples: &[Sample]) -> Result<f64> {
    let (b, s) = (exec.preset.batch, exec.preset.seq);
    let mut total = 0.0f64;
    let mut n = 0usize;
    for (batch, _) in samples_to_batches(samples, b, s) {
        let (loss, _) = exec.eval_step(params, &batch)?;
        total += loss as f64;
        n += 1;
    }
    Ok(ppl_from_total_nll(total, n))
}

/// Pure recall kernel: mean ground-truth probability; zero probes read
/// as 0.0 (nothing recalled), never a division panic.
pub fn recall_from_probs(probs: &[f64]) -> f64 {
    probs.iter().sum::<f64>() / probs.len().max(1) as f64
}

/// Fact-recall probe (Fig. 2b): P(correct target | "e r") for a set of
/// frequent KG facts. Returns the mean probability of the ground truth.
pub fn fact_recall(
    rt: &Runtime,
    exec: &ModelExec,
    params: &[Tensor],
    corpus: &CorpusGen,
    n_facts: usize,
    seed: u64,
) -> Result<f64> {
    let mut rng = Rng::new(seed ^ 0xfac7);
    let mut probs = Vec::with_capacity(n_facts);
    let s = exec.preset.seq;
    for _ in 0..n_facts {
        let (e, r, t) = corpus.kg.sample_fact_tier(&mut rng, true);
        let mut toks = vec![crate::data::vocab::PAD; s];
        toks[0] = crate::data::vocab::BOS;
        toks[1] = corpus.vocab.entity(e);
        toks[2] = corpus.vocab.relation(r);
        let dist = exec.probe(rt, params, &toks, 2)?;
        probs.push(dist[corpus.vocab.entity(t) as usize] as f64);
    }
    Ok(recall_from_probs(&probs))
}

/// Autoregressive sampling of `len` answer tokens after a prompt, using
/// the probe executable per position (temperature > 0 => stochastic).
pub fn sample_answer(
    rt: &Runtime,
    exec: &ModelExec,
    params: &[Tensor],
    prompt: &[i32],
    len: usize,
    temperature: f32,
    rng: &mut Rng,
) -> Result<Vec<i32>> {
    let s = exec.preset.seq;
    anyhow::ensure!(prompt.len() + len <= s, "prompt too long for seq");
    let mut toks = vec![crate::data::vocab::PAD; s];
    toks[..prompt.len()].copy_from_slice(prompt);
    let mut out = Vec::with_capacity(len);
    for j in 0..len {
        let pos = prompt.len() + j - 1;
        let probs = exec.probe(rt, params, &toks, pos)?;
        let tok = if temperature <= 0.0 {
            greedy_argmax(&probs)
        } else {
            sample_from(&probs, temperature, rng)
        };
        toks[prompt.len() + j] = tok;
        out.push(tok);
    }
    Ok(out)
}

/// Greedy argmax over a probability row. NaN entries never win — a
/// diverged model degrades to a deterministic token (the last maximal
/// index, matching `max_by` on clean input) instead of panicking the
/// sampler. An all-NaN row yields token 0.
pub fn greedy_argmax(probs: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_p = f32::NEG_INFINITY;
    for (i, &p) in probs.iter().enumerate() {
        if p >= best_p {
            best = i;
            best_p = p;
        }
    }
    best as i32
}

fn sample_from(probs: &[f32], temperature: f32, rng: &mut Rng) -> i32 {
    // temperature re-softmax in log space
    let logits: Vec<f64> = probs
        .iter()
        .map(|&p| (p.max(1e-30) as f64).ln() / temperature as f64)
        .collect();
    let maxl = logits.iter().cloned().fold(f64::MIN, f64::max);
    let exps: Vec<f64> = logits.iter().map(|&l| (l - maxl).exp()).collect();
    let z: f64 = exps.iter().sum();
    let mut u = rng.next_f64() * z;
    for (i, &e) in exps.iter().enumerate() {
        u -= e;
        if u <= 0.0 {
            return i as i32;
        }
    }
    (exps.len() - 1) as i32
}

/// Pure pass@k driver over an abstract per-attempt sampler: attempt 0
/// is always greedy (temperature 0.0), later attempts receive
/// `temperature`; a sample passes iff **any** attempt reproduces the
/// reference answer exactly (further attempts are skipped). Empty
/// `samples` or `max_samples == 0` read as 0.0.
pub fn pass_at_k_with(
    samples: &[Sample],
    k: usize,
    temperature: f32,
    max_samples: usize,
    sample_fn: &mut dyn FnMut(&Sample, f32) -> Result<Vec<i32>>,
) -> Result<f64> {
    let eval: Vec<&Sample> = samples.iter().take(max_samples).collect();
    let mut pass = 0usize;
    for &s in &eval {
        for t in 0..k {
            let temp = if t == 0 { 0.0 } else { temperature };
            if sample_fn(s, temp)? == s.answer() {
                pass += 1;
                break;
            }
        }
    }
    Ok(100.0 * pass as f64 / eval.len().max(1) as f64)
}

/// pass@k for generation tasks: a sample passes if any of k temperature
/// samples exactly matches the reference answer.
#[allow(clippy::too_many_arguments)]
pub fn pass_at_k(
    rt: &Runtime,
    exec: &ModelExec,
    params: &[Tensor],
    samples: &[Sample],
    k: usize,
    temperature: f32,
    seed: u64,
    max_samples: usize,
) -> Result<f64> {
    let mut rng = Rng::new(seed ^ 0x9a55);
    pass_at_k_with(samples, k, temperature, max_samples, &mut |s, temp| {
        sample_answer(rt, exec, params, s.prompt(), s.answer_len, temp, &mut rng)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_distribution_sanity() {
        let mut rng = Rng::new(1);
        let probs = vec![0.05f32, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..500 {
            counts[sample_from(&probs, 1.0, &mut rng) as usize] += 1;
        }
        assert!(counts[1] > 350, "{counts:?}");
        // low temperature sharpens toward argmax
        let mut counts = [0usize; 3];
        for _ in 0..200 {
            counts[sample_from(&probs, 0.2, &mut rng) as usize] += 1;
        }
        assert!(counts[1] > 195, "{counts:?}");
    }

    #[test]
    fn greedy_argmax_ignores_nan() {
        // regression (ISSUE 10): the old comparator panicked on a
        // NaN logit from a diverged model
        assert_eq!(greedy_argmax(&[0.1, f32::NAN, 0.7, 0.2]), 2);
        // all-NaN row degrades to token 0 rather than panicking
        assert_eq!(greedy_argmax(&[f32::NAN, f32::NAN]), 0);
        // clean rows keep max_by's last-maximal-index tie behavior
        assert_eq!(greedy_argmax(&[0.5, 0.5, 0.1]), 1);
        assert_eq!(greedy_argmax(&[]), 0);
    }
}

//! Pretraining orchestration + checkpoint cache.
//!
//! Every experiment fine-tunes from the *same* pretrained model per preset
//! (the paper starts from public pretrained LLMs). Checkpoints live in
//! runs/ keyed by (preset, steps, seed) so the expensive pretrain happens
//! once per configuration.

use std::path::PathBuf;

use anyhow::Result;

use super::{train, TrainCfg};
use crate::data::{CorpusGen, Kg, Vocab};
use crate::methods::{full::FullFt, Ctx};
use crate::model;
use crate::optim::AdamCfg;
use crate::runtime::model_exec::ModelExec;
use crate::runtime::{Linalg, Runtime};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub const KG_SEED: u64 = 0x5eed_0001;

/// The standard world (vocab + KG + corpus) for a preset.
pub fn world(exec: &ModelExec) -> CorpusGen {
    let vocab = Vocab::new(exec.preset.vocab);
    let kg = Kg::new(KG_SEED, vocab.n_entities, vocab.n_relations);
    CorpusGen::new(vocab, kg, exec.preset.batch, exec.preset.seq)
}

pub fn runs_dir() -> PathBuf {
    std::env::var("LIFT_RUNS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("runs"))
}

pub fn make_ctx(rt: &Runtime, exec: &ModelExec, seed: u64) -> Ctx {
    Ctx {
        la: std::sync::Arc::new(Linalg::new(&rt.client)),
        preset: exec.preset.clone(),
        rng: Rng::new(seed),
        adam: AdamCfg::default(),
        workers: crate::lift::engine::default_workers(),
    }
}

/// Load the cached pretrained checkpoint, or pretrain + cache it.
pub fn ensure_pretrained(
    rt: &Runtime,
    exec: &ModelExec,
    steps: usize,
    seed: u64,
) -> Result<Vec<Tensor>> {
    let path = runs_dir().join(format!(
        "{}_pretrain_s{}_seed{}.ckpt",
        exec.preset.name, steps, seed
    ));
    if path.exists() {
        let params = model::load_checkpoint(&path)?;
        model::check_params(&exec.preset, &params)?;
        log::info!("loaded pretrained checkpoint {path:?}");
        return Ok(params);
    }
    log::info!(
        "pretraining {} for {steps} steps (cached at {path:?})",
        exec.preset.name
    );
    let mut rng = Rng::new(seed);
    let mut params = model::init_params(&exec.preset, &mut rng);
    let mut corpus = world(exec);
    let mut method = FullFt::new();
    let mut ctx = make_ctx(rt, exec, seed);
    let cfg = TrainCfg {
        steps,
        lr: 1e-3,
        warmup_frac: 0.05,
        log_every: 100,
        seed,
        ..Default::default()
    };
    let log = train(exec, &mut corpus, &mut method, &mut ctx, &mut params, &cfg)?;
    log::info!(
        "pretrain done: loss {:.3} -> {:.3} ({:.1}s)",
        log.losses.first().copied().unwrap_or(f32::NAN),
        log.tail_loss(20),
        log.seconds
    );
    model::save_checkpoint(&path, &params)?;
    Ok(params)
}

//! `lift` — CLI launcher for the LIFT reproduction.
//!
//! Subcommands:
//!   pretrain  --preset <p> [--steps N] [--seed S]
//!   train     --preset <p> --method <m> [--rank R] [--suite arith|commonsense|nlu]
//!             [--steps N] [--lr F] [--interval N] [--seed S]
//!   eval      --preset <p> [--suite ...]   (pretrained model, no fine-tune)
//!   exp       <id> [--fast] [--seeds N]    (regenerate a paper table/figure)
//!   list-exp                                (show available experiment ids)
//!   inspect                                 (manifest summary)

use anyhow::Result;
use lift::data::tasks::{TaskMixSource, TaskSet, ARITH, COMMONSENSE, NLU};
use lift::exp;
use lift::lift::LiftCfg;
use lift::methods::{make_method, Scope};
use lift::runtime::{model_exec::ModelExec, Runtime};
use lift::train::{eval, pretrain, train, TrainCfg};
use lift::util::cli::Args;

fn main() -> Result<()> {
    lift::util::logging::init();
    let args = Args::from_env();
    match args.cmd.as_str() {
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "exp" => exp::run(&args),
        "list-exp" => {
            for (id, desc) in exp::REGISTRY {
                println!("{id:<14} {desc}");
            }
            Ok(())
        }
        "inspect" => cmd_inspect(&args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try `lift help`)"),
    }
}

const HELP: &str = "\
lift — Low-rank Informed Sparse Fine-Tuning (ICML 2025) reproduction

USAGE:
  lift pretrain --preset tiny [--steps 1500] [--seed 1]
  lift train --preset tiny --method lift --rank 32 --suite arith [--steps 300]
  lift eval --preset tiny --suite arith
  lift exp table2 [--fast]        regenerate a paper table/figure
  lift list-exp                   list experiment ids
  lift inspect                    manifest summary

Methods: full lift lift_mlp lift_structured lora dora pissa spectral s2ft
         sift spiel weight_mag grad_mag movement random
";

fn cmd_pretrain(args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &preset)?;
    let steps = args.usize("steps", lift::exp::default_pretrain_steps(&preset));
    let seed = args.u64("seed", 1);
    args.finish()?;
    let params = pretrain::ensure_pretrained(&rt, &exec, steps, seed)?;
    let corpus = pretrain::world(&exec);
    let ppl = eval::perplexity(&exec, &params, &corpus, 8, 99)?;
    let recall = eval::fact_recall(&rt, &exec, &params, &corpus, 50, 7)?;
    println!("preset={preset} steps={steps} heldout_ppl={ppl:.3} fact_recall={recall:.3}");
    Ok(())
}

fn suite_families(suite: &str) -> Vec<lift::data::TaskFamily> {
    match suite {
        "arith" => ARITH.to_vec(),
        "commonsense" => COMMONSENSE.to_vec(),
        "nlu" => NLU.to_vec(),
        "gpqa" => vec![lift::data::TaskFamily::Gpqa],
        other => panic!("unknown suite '{other}'"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let method_name = args.str("method", "lift");
    let rank = args.usize("rank", 32);
    let suite = args.str("suite", "arith");
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &preset)?;
    let steps = args.usize("steps", 300);
    let lr = args.f32("lr", 1e-3);
    let interval = args.usize("interval", 100);
    let seed = args.u64("seed", 1);
    let pt_steps = args.usize("pretrain-steps", lift::exp::default_pretrain_steps(&preset));
    let n_train = args.usize("train-samples", 1000);
    let n_test = args.usize("test-samples", 100);
    args.finish()?;

    let mut params = pretrain::ensure_pretrained(&rt, &exec, pt_steps, 1)?;
    let corpus = pretrain::world(&exec);
    let fams = suite_families(&suite);
    let sets: Vec<TaskSet> = fams
        .iter()
        .map(|&f| TaskSet::generate(f, &corpus.vocab, &corpus.kg, n_train, n_test, seed))
        .collect();
    let mut src = TaskMixSource {
        sets: sets.clone(),
        batch: exec.preset.batch,
        seq: exec.preset.seq,
    };
    let mut ctx = pretrain::make_ctx(&rt, &exec, seed);
    let lift_cfg = LiftCfg {
        rank: args.usize("lra-rank", rank),
        ..Default::default()
    };
    let mut method = make_method(&method_name, rank, lift_cfg, interval, Scope::default())?;
    let cfg = TrainCfg {
        steps,
        lr,
        warmup_frac: 0.03,
        log_every: 50,
        seed,
    };
    let log = train(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg)?;
    println!(
        "method={} trainable={} opt_bytes={} final_loss={:.4} ({:.1}s)",
        method.name(),
        method.trainable(),
        method.opt_bytes(),
        log.tail_loss(20),
        log.seconds
    );
    for set in &sets {
        let acc = eval::accuracy(&exec, &params, &set.test)?;
        println!("  {:<12} {acc:.2}", set.family.name());
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let suite = args.str("suite", "arith");
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &preset)?;
    let pt_steps = args.usize("pretrain-steps", lift::exp::default_pretrain_steps(&preset));
    let n_test = args.usize("test-samples", 100);
    args.finish()?;
    let params = pretrain::ensure_pretrained(&rt, &exec, pt_steps, 1)?;
    let corpus = pretrain::world(&exec);
    for &f in &suite_families(&suite) {
        let set = TaskSet::generate(f, &corpus.vocab, &corpus.kg, 1, n_test, 1);
        let acc = eval::accuracy(&exec, &params, &set.test)?;
        println!("{:<12} {acc:.2}", set.family.name());
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let rt = Runtime::from_default()?;
    args.finish()?;
    println!("artifacts: {:?}", Runtime::default_dir());
    for (name, p) in &rt.manifest.presets {
        println!(
            "preset {name:<6} d={} L={} ffn={} vocab={} seq={} batch={} params={:.2}M execs={:?}",
            p.d,
            p.layers,
            p.ffn,
            p.vocab,
            p.seq,
            p.batch,
            p.n_params() as f64 / 1e6,
            p.executables.keys().collect::<Vec<_>>()
        );
    }
    println!("kernels: {}", rt.manifest.kernels.len());
    Ok(())
}

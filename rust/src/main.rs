//! `lift` — CLI launcher for the LIFT reproduction.
//!
//! Subcommands:
//!   pretrain  --preset <p> [--steps N] [--seed S]
//!   train     --preset <p> --method <m> [--rank R] [--suite arith|commonsense|nlu]
//!             [--steps N] [--lr F] [--interval N] [--seed S] [--qscan]
//!             [--ckpt-every N --ckpt-dir D] [--resume latest|<path>]
//!   matrix    resumable N-axis scenario grid: --methods a,b --selectors c,d
//!             --ranks 8,32 --seeds 1,2 --suites arith,nlu --intervals 50,100
//!             --presets tiny,small [--axis "key=v1,v2;key2=..."] [--steps N]
//!             [--out D] [--ckpt-every N] [--workers W] [--toy] [--migrate-v1]
//!             [--runner-id R] [--lease-ttl SECS] [--no-lease]
//!             (N runners sharing --out shard one campaign via leases)
//!   serve     per-tenant sparse-delta serving demo over the toy base:
//!             [--tenants N] [--requests N] [--batch N] [--budget-kb KB]
//!             [--rank R] [--seed S] [--workers W] [--dir D]
//!             [--expect-resident N] [--swaps N] [--dump PATH]
//!   torture   seeded crash/fault torture over ckpt + lease + serve:
//!             [--schedules N] [--seed S] [--out D] [--faults N] [--horizon N]
//!   eval      --preset <p> [--suite ...]   (pretrained model, no fine-tune)
//!   exp       <id> [--fast] [--seeds N]    (regenerate a paper table/figure)
//!   list-exp                                (show available experiment ids)
//!   inspect                                 (manifest summary)
//!
//! Env: LIFT_FAULT_SCHEDULE / LIFT_FAULT_SEED arm the deterministic fault
//! seam (`util::fault`) for any subcommand; LIFT_NO_FSYNC=1 disables the
//! durability fsyncs around atomic writes (tests/smoke only).

use std::path::PathBuf;

use anyhow::Result;
use lift::data::tasks::{suite_families, TaskMixSource, TaskSet};
use lift::exp;
use lift::lift::LiftCfg;
use lift::methods::{make_method, Scope};
use lift::runtime::{model_exec::ModelExec, Runtime};
use lift::train::{eval, pretrain, resume as train_resume, train, TrainCfg};
use lift::util::cli::Args;

fn main() -> Result<()> {
    lift::util::logging::init();
    // LIFT_FAULT_SCHEDULE (+ LIFT_FAULT_SEED) arms the deterministic
    // fault-injection seam for ANY subcommand — a no-op when unset
    lift::util::fault::arm_from_env()?;
    let args = Args::from_env();
    match args.cmd.as_str() {
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "matrix" => cmd_matrix(&args),
        "serve" => cmd_serve(&args),
        "torture" => cmd_torture(&args),
        "eval" => cmd_eval(&args),
        "exp" => exp::run(&args),
        "list-exp" => {
            for (id, desc) in exp::REGISTRY {
                println!("{id:<14} {desc}");
            }
            Ok(())
        }
        "inspect" => cmd_inspect(&args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try `lift help`)"),
    }
}

const HELP: &str = "\
lift — Low-rank Informed Sparse Fine-Tuning (ICML 2025) reproduction

USAGE:
  lift pretrain --preset tiny [--steps 1500] [--seed 1]
  lift train --preset tiny --method lift --rank 32 --suite arith [--steps 300]
       [--ckpt-every 50 --ckpt-dir runs/ckpt]   periodic versioned snapshots
                                  (written off-loop by a background writer;
                                  the loss curve streams to curve.sidecar)
       [--ckpt-keep 3]            keep-last-N snapshot retention (0 = all)
       [--ckpt-dir runs/ckpt --resume latest]   continue the newest snapshot
       [--resume path/to/step_00000050.snap]    continue a specific snapshot
       [--qscan]                  int8 blockwise quantized rank-reduce scan
                                  (selection only; the training update stays
                                  f32/f64 — see util::eigh::LIFT_QSCAN_TOL
                                  for the mask-overlap contract)
  lift matrix --methods lift,full --selectors weight_mag,random \\
       --ranks 8,32 --seeds 1,2 --steps 200 --out results/matrix
                                  resumable scenario grid: finished cells are
                                  skipped on rerun, interrupted cells resume
                                  from their newest snapshot; --toy runs the
                                  artifact-free synthetic cells; ends with a
                                  target-vs-retention summary (summary.txt);
                                  [--ckpt-keep N] prunes per-cell snapshots
       [--suites arith,nlu --intervals 50,100 --presets tiny,small]
       [--axis \"interval=50,100;seed=1,2,3\"]  any subset of the seven axes
                                  (preset, method, suite, rank, interval,
                                  seed, qscan) as one spec string; merges with
                                  explicitly passed flags, and dimensions
                                  nobody swept take single-value defaults
       [--migrate-v1]             migrate a pre-v2 outcome ledger in place
                                  (v1 entries otherwise refuse to run —
                                  they are never silently recomputed)
       [--runner-id R]            stable runner identity for multi-runner
                                  campaigns (default <hostname>-<pid>);
                                  reuse it across restarts to reclaim your
                                  own leases immediately
       [--lease-ttl SECS]         lease expiry deadline (default 600) —
                                  size it above the slowest cell; a
                                  crashed runner's cells are recovered by
                                  takeover after this long
       [--no-lease]               disable cell leases (single-process
                                  campaigns only). Leases are otherwise
                                  ON: launch N `lift matrix` processes at
                                  one --out (even on different hosts over
                                  NFS) and they shard the campaign with no
                                  coordinator — live leases defer, expired
                                  ones are fenced-token taken over
       [--defer-retries N]        re-poll deferred cells up to N times
                                  (default 2) before reporting them; the
                                  first re-poll is immediate, later ones
                                  sleep half the lease TTL (≤10s)
  lift serve [--tenants 120] [--requests 256] [--budget-kb 4096]
                                  LIFT-as-a-service demo: one resident toy
                                  base, N per-tenant sparse deltas overlaid
                                  at request time through a byte-budgeted
                                  LRU; asserts overlay ≡ full-materialization
                                  bit-identity, per-tenant divergence from
                                  the base, hot-swap atomicity, and 1-worker
                                  ≡ N-worker outputs
       [--batch 32 --rank 2 --seed 7 --workers W --dir results/serve_demo]
       [--expect-resident N]      fail unless ≥ N tenants stay resident
                                  (default min(tenants, 100); 0 disables)
       [--swaps 2]                hot-swap this many tenants mid-stream
       [--dump PATH]              write served outputs as hex lines (byte-
                                  for-byte comparable across budgets/workers)
  lift torture [--schedules 8] [--seed 7] [--out results/torture]
                                  replay seeded fault schedules (ENOSPC, EIO,
                                  EACCES, short writes, crash-around-rename)
                                  across train-resume, a 2-runner lease
                                  campaign, and a serve register/swap/evict
                                  mix; every schedule must recover to the
                                  straight run bit-identically or fail
                                  loudly by fault name, with zero torn
                                  artifacts left behind. Same seed => byte-
                                  identical report (torture_report.txt)
       [--faults 3]               faults drawn per scenario schedule
       [--horizon 40]             per-class call horizon faults land in
  lift eval --preset tiny --suite arith
  lift exp table2 [--fast]        regenerate a paper table/figure
  lift list-exp                   list experiment ids
  lift inspect                    manifest summary

Methods: full lift lift_mlp lift_structured lora dora pissa spectral s2ft
         sift spiel weight_mag grad_mag movement random
";

fn cmd_pretrain(args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &preset)?;
    let steps = args.usize("steps", lift::exp::default_pretrain_steps(&preset));
    let seed = args.u64("seed", 1);
    args.finish()?;
    let params = pretrain::ensure_pretrained(&rt, &exec, steps, seed)?;
    let corpus = pretrain::world(&exec);
    let ppl = eval::perplexity(&exec, &params, &corpus, 8, 99)?;
    let recall = eval::fact_recall(&rt, &exec, &params, &corpus, 50, 7)?;
    println!("preset={preset} steps={steps} heldout_ppl={ppl:.3} fact_recall={recall:.3}");
    Ok(())
}


fn cmd_train(args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let method_name = args.str("method", "lift");
    let rank = args.usize("rank", 32);
    let suite = args.str("suite", "arith");
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &preset)?;
    let steps = args.usize("steps", 300);
    let lr = args.f32("lr", 1e-3);
    let interval = args.usize("interval", 100);
    let seed = args.u64("seed", 1);
    let pt_steps = args.usize("pretrain-steps", lift::exp::default_pretrain_steps(&preset));
    let n_train = args.usize("train-samples", 1000);
    let n_test = args.usize("test-samples", 100);
    let ckpt_every = args.usize("ckpt-every", 0);
    let ckpt_dir = args.opt_str("ckpt-dir").map(PathBuf::from);
    let ckpt_keep = args.usize("ckpt-keep", 0);
    let resume_arg = args.opt_str("resume");
    let qscan = args.bool("qscan", false);
    // consumed BEFORE finish(): the typo guard treats any flag read
    // after it as unknown (this read used to sit below and made
    // --lra-rank unusable)
    let lra_rank = args.usize("lra-rank", rank);
    args.finish()?;

    let mut params = pretrain::ensure_pretrained(&rt, &exec, pt_steps, 1)?;
    let corpus = pretrain::world(&exec);
    let fams = suite_families(&suite)?;
    let sets: Vec<TaskSet> = fams
        .iter()
        .map(|&f| TaskSet::generate(f, &corpus.vocab, &corpus.kg, n_train, n_test, seed))
        .collect();
    let mut src = TaskMixSource {
        sets: sets.clone(),
        batch: exec.preset.batch,
        seq: exec.preset.seq,
    };
    let mut ctx = pretrain::make_ctx(&rt, &exec, seed);
    let lift_cfg = LiftCfg {
        rank: lra_rank,
        qscan,
        ..Default::default()
    };
    let mut method = make_method(&method_name, rank, lift_cfg, interval, Scope::default())?;
    let cfg = TrainCfg {
        steps,
        lr,
        warmup_frac: 0.03,
        log_every: 50,
        seed,
        ckpt_every,
        ckpt_dir: ckpt_dir.clone(),
        ckpt_keep,
    };
    let snapshot = match resume_arg.as_deref() {
        Some("latest") => {
            let dir = ckpt_dir
                .ok_or_else(|| anyhow::anyhow!("--resume latest needs --ckpt-dir"))?;
            Some(lift::ckpt::latest_snapshot(&dir)?.ok_or_else(|| {
                anyhow::anyhow!("--resume latest: no step_*.snap under {dir:?}")
            })?)
        }
        Some(path) => Some(PathBuf::from(path)),
        None => None,
    };
    let log = match &snapshot {
        Some(snap) => {
            train_resume(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg, snap)?
        }
        None => train(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg)?,
    };
    println!(
        "method={} trainable={} opt_bytes={} final_loss={:.4} ({:.1}s)",
        method.name(),
        method.trainable(),
        method.opt_bytes(),
        log.tail_loss(20),
        log.seconds
    );
    for set in &sets {
        let acc = eval::accuracy(&exec, &params, &set.test)?;
        println!("  {:<12} {acc:.2}", set.family.name());
    }
    Ok(())
}

/// Resumable N-axis scenario matrix (`exp::grid`): preset × method ×
/// suite × rank × interval × seed cells, persisted per cell under
/// `--out`, finished cells skipped on rerun, unfinished ones fanned
/// over the `lift::engine::par_map` pool (each cell resumes from its
/// newest snapshot — resume-mid-axis works at any grid position).
/// `--toy` drives the artifact-free synthetic cells so the machinery
/// runs without `make artifacts`; `--migrate-v1` upgrades a pre-v2
/// outcome ledger in place (v1 entries otherwise refuse the run).
fn cmd_matrix(args: &Args) -> Result<()> {
    use lift::exp::grid::{parse_axes, Axis, AxisKind, Grid};
    use lift::exp::matrix::{self, RealCellCfg};
    use lift::exp::retention::{score_source, RetentionCfg};
    // a dedicated flag seeds its axis only when the user actually passed
    // it — otherwise an --axis sweep of the same dimension would merge
    // with the flag's DEFAULT (e.g. `--axis interval=2,4` silently
    // gaining interval 100). Absent dimensions default at expansion
    // (`Axis::default_for`); the one historical exception is the method
    // axis, whose CLI default is `lift,full` (seeded below).
    let explicit = |key: &str| -> Option<Vec<String>> {
        args.opt_str(key).map(|v| {
            v.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect()
        })
    };
    let presets = explicit("presets")
        .or_else(|| args.opt_str("preset").map(|p| vec![p]))
        .unwrap_or_default();
    let methods = explicit("methods");
    let selectors = explicit("selectors");
    let ranks: Vec<usize> = explicit("ranks")
        .unwrap_or_default()
        .iter()
        .map(|r| r.parse().unwrap_or_else(|_| panic!("--ranks expects integers, got '{r}'")))
        .collect();
    let seeds: Vec<u64> = explicit("seeds")
        .unwrap_or_default()
        .iter()
        .map(|s| s.parse().unwrap_or_else(|_| panic!("--seeds expects integers, got '{s}'")))
        .collect();
    let steps = args.usize("steps", 200);
    let intervals: Vec<usize> = explicit("intervals")
        .or_else(|| args.opt_str("interval").map(|i| vec![i]))
        .unwrap_or_default()
        .iter()
        .map(|v| v.parse().unwrap_or_else(|_| panic!("--intervals expects integers, got '{v}'")))
        .collect();
    let suites = explicit("suites")
        .or_else(|| args.opt_str("suite").map(|s| vec![s]))
        .unwrap_or_default();
    let axis_spec = args.str("axis", "");
    let out = PathBuf::from(args.str("out", "results/matrix"));
    let ckpt_every = args.usize("ckpt-every", 50);
    let ckpt_keep = args.usize("ckpt-keep", 0);
    let workers = args.usize("workers", lift::lift::engine::default_workers());
    let toy = args.bool("toy", false);
    let migrate = args.bool("migrate-v1", false);
    // multi-runner leases default ON: a lone runner pays one tiny lease
    // file per cell, and any co-runner pointed at the same --out then
    // shards the campaign safely (exp::matrix module doc)
    let no_lease = args.bool("no-lease", false);
    let runner_id = args
        .opt_str("runner-id")
        .unwrap_or_else(lift::exp::lease::LeaseCfg::default_runner_id);
    let lease_ttl = args.u64("lease-ttl", 600);
    let defer_retries = args.usize("defer-retries", 2);
    // None = the per-preset default, so a multi-preset grid pretrains
    // each base for its own step count (the runs/ cache keys on it)
    let pt_steps: Option<usize> = args.opt_str("pretrain-steps").map(|v| {
        v.parse().unwrap_or_else(|_| panic!("--pretrain-steps expects an integer, got '{v}'"))
    });
    let n_train = args.usize("train-samples", 1000);
    let n_test = args.usize("test-samples", 100);
    args.finish()?;

    let method_flags_given = methods.is_some() || selectors.is_some();
    let mut grid = Grid::new(steps)
        .with_axis(Axis::Preset(presets))
        .with_axis(Axis::Method(methods.unwrap_or_default()))
        .with_axis(Axis::Method(selectors.unwrap_or_default()))
        .with_axis(Axis::Suite(suites))
        .with_axis(Axis::Rank(ranks))
        .with_axis(Axis::Interval(intervals))
        .with_axis(Axis::Seed(seeds));
    for axis in parse_axes(&axis_spec)? {
        grid = grid.with_axis(axis);
    }
    if !grid.has_axis(AxisKind::Method) {
        // the user explicitly passed empty method/selector lists: loud
        // error, not an unrequested default campaign
        anyhow::ensure!(!method_flags_given, "empty grid: no methods/selectors given");
        grid = grid.with_axis(Axis::Method(vec!["lift".to_string(), "full".to_string()]));
    }
    if toy {
        // toy cells run the artifact-free preset whatever the flags say
        grid = grid.set_axis(Axis::Preset(vec!["toy".to_string()]));
    }
    let cells = grid.expand();
    for s in cells.iter().map(|c| &c.suite).collect::<std::collections::BTreeSet<_>>() {
        suite_families(s)?; // reject unknown suite axis values before running
    }
    if migrate {
        let migrated = matrix::migrate_v1(&out, &cells)?;
        println!("migrated {} v1 ledger entr(ies) under {}", migrated.len(), out.display());
        for id in &migrated {
            println!("  migrated -> {id}");
        }
    }
    let lease_cfg = if no_lease {
        None
    } else {
        Some(lift::exp::lease::LeaseCfg::new(&runner_id, lease_ttl))
    };
    let report = if toy {
        matrix::run_matrix_retry(
            &out,
            &cells,
            workers,
            lease_cfg.as_ref(),
            defer_retries,
            |spec, ckpt_dir| matrix::run_toy_cell_in(spec, ckpt_dir, ckpt_every, ckpt_keep, 1),
        )?
    } else {
        // pre-warm each preset's pretrained base sequentially so
        // parallel cells hit the runs/ checkpoint cache read-only, and
        // score the base's source-domain knowledge ONCE per preset (it
        // is the same retention denominator for every cell of a preset)
        let rcfg = RetentionCfg::default();
        let mut base_source = std::collections::BTreeMap::new();
        for p in cells.iter().map(|c| &c.preset).collect::<std::collections::BTreeSet<_>>() {
            let rt = Runtime::from_default()?;
            let exec = ModelExec::load(&rt, p)?;
            let pt = pt_steps.unwrap_or_else(|| lift::exp::default_pretrain_steps(p));
            let base = pretrain::ensure_pretrained(&rt, &exec, pt, 1)?;
            let corpus = pretrain::world(&exec);
            base_source.insert(p.clone(), score_source(&rt, &exec, &base, &corpus, &rcfg)?);
        }
        let rc = RealCellCfg {
            pt_steps,
            n_train,
            n_test,
            ckpt_every,
            ckpt_keep,
            inner_workers: 1,
            retention: rcfg,
            base_source,
        };
        matrix::run_matrix_retry(
            &out,
            &cells,
            workers,
            lease_cfg.as_ref(),
            defer_retries,
            |spec, ckpt_dir| matrix::run_real_cell_in(spec, ckpt_dir, &rc),
        )?
    };
    println!(
        "matrix: {} ran, {} skipped, {} deferred, {} failed (out: {})",
        report.ran.len(),
        report.skipped.len(),
        report.deferred.len(),
        report.failed.len(),
        out.display()
    );
    for c in &cells {
        if let Some(o) = matrix::read_outcome(&out, &c.id()) {
            let ret = o
                .retention
                .map(|r| format!("{r:.4}"))
                .unwrap_or_else(|| "-".to_string());
            println!(
                "  {:<52} avg={:>5.1} tail_loss={:.4} ret={ret} trainable={}",
                c.id(),
                o.avg,
                o.tail_loss,
                o.trainable
            );
        }
    }
    for (id, why) in &report.deferred {
        println!("  DEFERRED {id}: {why}");
    }
    for (id, err) in &report.failed {
        println!("  FAILED {id}: {err}");
    }
    if !report.deferred.is_empty() {
        println!(
            "{} cell(s) deferred to other runners — rerun after they finish to pick up stragglers",
            report.deferred.len()
        );
    }
    // the campaign's readable artifact: the paper-style target-vs-
    // retention table over every persisted outcome, saved as summary.txt
    let (summary_path, table) = matrix::write_summary(&out, &cells)?;
    println!("\n{table}");
    println!("summary written to {}", summary_path.display());
    anyhow::ensure!(report.failed.is_empty(), "{} matrix cells failed", report.failed.len());
    Ok(())
}

/// Seeded crash/fault torture harness (`exp::torture`): replay N fault
/// schedules across train-resume, a 2-runner lease campaign, and a
/// serve register/swap/evict mix, asserting per schedule that recovery
/// reproduces the straight run bit-identically, that every injected
/// fault was retried/recovered or surfaced loudly by name, and that no
/// torn artifact survives. The report is deterministic: two runs with
/// the same `--seed` produce byte-identical `torture_report.txt`.
fn cmd_torture(args: &Args) -> Result<()> {
    use lift::exp::torture::{run_torture, TortureCfg};
    let cfg = TortureCfg {
        schedules: args.usize("schedules", 8),
        seed: args.u64("seed", 7),
        out: PathBuf::from(args.str("out", "results/torture")),
        faults: args.usize("faults", 3),
        horizon: args.u64("horizon", 40),
    };
    args.finish()?;
    let report = run_torture(&cfg)?;
    print!("{}", report.text);
    anyhow::ensure!(
        report.failed.is_empty(),
        "{} torture schedule(s) failed: {}",
        report.failed.len(),
        report.failed.join(", ")
    );
    Ok(())
}

/// LIFT-as-a-service demo (`rust/src/serve/`): one resident toy base,
/// N per-tenant sparse deltas registered on disk and overlaid at request
/// time through a byte-budgeted LRU of row-granular views. The demo is
/// also the acceptance harness — it asserts overlay-apply ≡ full tenant
/// materialization bitwise, per-tenant divergence from the base, LRU
/// residency, hot-swap atomicity (unrelated tenants stay resident, fresh
/// reads see exactly the new version), and 1-worker ≡ N-worker output
/// bit-identity. `--dump` writes every served output as a hex line so two
/// runs (e.g. eviction-churn vs no-LRU in `make serve-smoke`) can be
/// compared byte-for-byte.
fn cmd_serve(args: &Args) -> Result<()> {
    use lift::exp::matrix::{toy_params, toy_preset};
    use lift::serve::{base_digest, forward_one, synth_delta, BaseModel, Request, Server, TenantView};
    use lift::util::rng::Rng;
    use std::time::Instant;

    let tenants = args.usize("tenants", 120);
    let requests = args.usize("requests", 256);
    let batch = args.usize("batch", 32);
    let budget_kb = args.usize("budget-kb", 4096);
    let rank = args.usize("rank", 2);
    let seed = args.u64("seed", 7);
    let workers = args.usize("workers", lift::lift::engine::default_workers());
    let dir = PathBuf::from(args.str("dir", "results/serve_demo"));
    let expect_resident = args.usize("expect-resident", tenants.min(100));
    let swaps = args.usize("swaps", 2.min(tenants));
    let dump = args.opt_str("dump").map(PathBuf::from);
    args.finish()?;
    anyhow::ensure!(tenants > 0 && requests > 0 && batch > 0, "--tenants/--requests/--batch must be > 0");

    let base = toy_params(seed);
    let preset = toy_preset();
    let digest = base_digest(&base);
    let budget = budget_kb * 1024;
    let tenant_name = |i: usize| format!("t{i:04}");

    let mut server = Server::new(&base, &preset, &dir, budget, workers)?;
    // clear deltas from previous runs (a different --seed means a
    // different base digest, which stale files would loudly refuse)
    for old in server.store().list()? {
        server.store().delete(&old)?;
    }
    let t0 = Instant::now();
    for i in 0..tenants {
        let delta = synth_delta(&base, &tenant_name(i), digest, rank, seed.wrapping_add(i as u64));
        server.store().register(&delta)?;
    }
    println!(
        "serve: registered {tenants} tenant deltas under {} in {:.2}s (base digest {digest:016x})",
        dir.display(),
        t0.elapsed().as_secs_f64()
    );

    // ---- request stream: warm sweep (one request per tenant, so every
    // tenant is exercised) then a seeded random mix -----------------------
    let mut stream: Vec<Request> = (0..tenants)
        .map(|i| Request { tenant: tenant_name(i), seed: seed ^ (0xABCD + i as u64) })
        .collect();
    let mut rng = Rng::new(seed ^ 0xbead);
    stream.extend((0..requests).map(|_| Request {
        tenant: tenant_name(rng.below(tenants)),
        seed: rng.next_u64(),
    }));

    let mut outs: Vec<Vec<f32>> = Vec::with_capacity(stream.len());
    let mut batch_secs: Vec<f64> = Vec::new();
    for chunk in stream.chunks(batch) {
        let tb = Instant::now();
        outs.extend(server.handle_batch(chunk)?);
        batch_secs.push(tb.elapsed().as_secs_f64());
    }
    batch_secs.sort_by(|a, b| a.total_cmp(b));
    let p95 = batch_secs[((batch_secs.len() as f64 * 0.95) as usize).min(batch_secs.len() - 1)];

    // every tenant's sweep output must differ from the base's answer
    for (i, out) in outs.iter().take(tenants).enumerate() {
        anyhow::ensure!(
            *out != server.base_forward(stream[i].seed),
            "tenant {} output identical to base — delta not applied",
            stream[i].tenant
        );
    }

    // ---- overlay-apply ≡ full tenant materialization (bitwise) ----------
    for i in (0..tenants).step_by((tenants / 8).max(1)) {
        let delta = server.store().load(&tenant_name(i))?;
        let view = TenantView::materialize(&base, &delta)?;
        let dense = TenantView::full_materialize(&base, &delta)?;
        for probe in [1u64, seed ^ i as u64] {
            let over = forward_one(
                &lift::serve::OverlayModel { base: &base, view: &view },
                server.plan(),
                probe,
            );
            let full = forward_one(&BaseModel { base: &dense }, server.plan(), probe);
            anyhow::ensure!(
                over == full,
                "tenant {}: overlay-apply != full materialization (seed {probe})",
                tenant_name(i)
            );
        }
    }

    // ---- determinism: 1-worker fresh server replays the stream bitwise --
    let mut server1 = Server::new(&base, &preset, &dir, budget, 1)?;
    let mut outs1: Vec<Vec<f32>> = Vec::with_capacity(stream.len());
    for chunk in stream.chunks(batch) {
        outs1.extend(server1.handle_batch(chunk)?);
    }
    anyhow::ensure!(
        outs.iter().zip(&outs1).all(|(a, b)| a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()))
            && outs.len() == outs1.len(),
        "{workers}-worker and 1-worker outputs differ — determinism contract broken"
    );

    // ---- hot-swap atomicity --------------------------------------------
    let mut swap_outs: Vec<(Request, Vec<f32>)> = Vec::new();
    for i in 0..swaps {
        let name = tenant_name(i);
        let probe = Request { tenant: name.clone(), seed: 0x5eed ^ i as u64 };
        let v1_out = server.handle_batch(std::slice::from_ref(&probe))?.remove(0);
        let before = server.lru().resident_tenants();
        let v2 = synth_delta(&base, &name, digest, rank, seed.wrapping_add(0xD00D + i as u64));
        server.hot_swap(&v2)?;
        anyhow::ensure!(
            server.lru().resident_tenants() == before,
            "hot-swap of {name} changed the resident set"
        );
        let v2_out = server.handle_batch(std::slice::from_ref(&probe))?.remove(0);
        anyhow::ensure!(v2_out != v1_out, "hot-swap of {name} did not change its output");
        // a fresh server over the same store must agree bitwise with the
        // post-swap answer (the swap really serves v2, not a torn mix)
        let mut fresh = Server::new(&base, &preset, &dir, budget, workers)?;
        let fresh_out = fresh.handle_batch(std::slice::from_ref(&probe))?.remove(0);
        anyhow::ensure!(
            fresh_out.iter().zip(&v2_out).all(|(x, y)| x.to_bits() == y.to_bits()),
            "hot-swapped {name} view disagrees with a fresh materialization"
        );
        swap_outs.push((probe.clone(), v1_out));
        swap_outs.push((probe, v2_out));
    }

    // ---- residency + summary -------------------------------------------
    let s = server.lru().stats;
    let resident = server.lru().resident();
    let resident_bytes = server.lru().resident_bytes();
    println!(
        "serve: lru resident={resident}/{tenants} bytes={resident_bytes}/{budget} \
         evictions={} hits={} misses={} swaps={} uncacheable={}",
        s.evictions, s.hits, s.misses, s.swaps, s.uncacheable
    );
    if resident > 0 {
        let per_tenant = resident_bytes as f64 / resident as f64;
        println!(
            "serve: {:.0} B/tenant resident -> {:.0} tenants/GB (vs {:.0} as dense copies)",
            per_tenant,
            1e9 / per_tenant,
            1e9 / (base.iter().map(|t| t.len() * 4).sum::<usize>() as f64)
        );
    }
    println!(
        "serve: {} requests in {} batches, p95 batch latency {:.3}ms ({workers} workers)",
        stream.len(),
        batch_secs.len(),
        p95 * 1e3
    );
    if expect_resident > 0 {
        anyhow::ensure!(
            resident >= expect_resident,
            "only {resident} tenants resident, expected >= {expect_resident} \
             (budget {budget} B too small?)"
        );
    }

    if let Some(path) = dump {
        let hex = |out: &[f32]| {
            out.iter().map(|x| format!("{:08x}", x.to_bits())).collect::<Vec<_>>().join("")
        };
        let mut text = String::new();
        for (r, out) in stream.iter().zip(&outs) {
            text.push_str(&format!("req {} {} {}\n", r.tenant, r.seed, hex(out)));
        }
        for (r, out) in &swap_outs {
            text.push_str(&format!("swap {} {} {}\n", r.tenant, r.seed, hex(out)));
        }
        std::fs::write(&path, text)?;
        println!("serve: dumped {} output lines to {}", stream.len() + swap_outs.len(), path.display());
    }
    println!("serve demo OK: overlay ≡ full materialization, hot-swap atomic, 1w ≡ {workers}w");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let suite = args.str("suite", "arith");
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &preset)?;
    let pt_steps = args.usize("pretrain-steps", lift::exp::default_pretrain_steps(&preset));
    let n_test = args.usize("test-samples", 100);
    args.finish()?;
    let params = pretrain::ensure_pretrained(&rt, &exec, pt_steps, 1)?;
    let corpus = pretrain::world(&exec);
    for &f in &suite_families(&suite)? {
        let set = TaskSet::generate(f, &corpus.vocab, &corpus.kg, 1, n_test, 1);
        let acc = eval::accuracy(&exec, &params, &set.test)?;
        println!("{:<12} {acc:.2}", set.family.name());
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let rt = Runtime::from_default()?;
    args.finish()?;
    println!("artifacts: {:?}", Runtime::default_dir());
    for (name, p) in &rt.manifest.presets {
        println!(
            "preset {name:<6} d={} L={} ffn={} vocab={} seq={} batch={} params={:.2}M execs={:?}",
            p.d,
            p.layers,
            p.ffn,
            p.vocab,
            p.seq,
            p.batch,
            p.n_params() as f64 / 1e6,
            p.executables.keys().collect::<Vec<_>>()
        );
    }
    println!("kernels: {}", rt.manifest.kernels.len());
    Ok(())
}

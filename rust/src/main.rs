//! `lift` — CLI launcher for the LIFT reproduction.
//!
//! Subcommands:
//!   pretrain  --preset <p> [--steps N] [--seed S]
//!   train     --preset <p> --method <m> [--rank R] [--suite arith|commonsense|nlu]
//!             [--steps N] [--lr F] [--interval N] [--seed S]
//!             [--ckpt-every N --ckpt-dir D] [--resume latest|<path>]
//!   matrix    resumable scenario grid: --methods a,b --selectors c,d
//!             --ranks 8,32 --seeds 1,2 [--steps N] [--out D]
//!             [--ckpt-every N] [--workers W] [--toy]
//!   eval      --preset <p> [--suite ...]   (pretrained model, no fine-tune)
//!   exp       <id> [--fast] [--seeds N]    (regenerate a paper table/figure)
//!   list-exp                                (show available experiment ids)
//!   inspect                                 (manifest summary)

use std::path::PathBuf;

use anyhow::Result;
use lift::data::tasks::{TaskMixSource, TaskSet, ARITH, COMMONSENSE, NLU};
use lift::exp;
use lift::lift::LiftCfg;
use lift::methods::{make_method, Scope};
use lift::runtime::{model_exec::ModelExec, Runtime};
use lift::train::{eval, pretrain, resume as train_resume, train, TrainCfg};
use lift::util::cli::Args;

fn main() -> Result<()> {
    lift::util::logging::init();
    let args = Args::from_env();
    match args.cmd.as_str() {
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "matrix" => cmd_matrix(&args),
        "eval" => cmd_eval(&args),
        "exp" => exp::run(&args),
        "list-exp" => {
            for (id, desc) in exp::REGISTRY {
                println!("{id:<14} {desc}");
            }
            Ok(())
        }
        "inspect" => cmd_inspect(&args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try `lift help`)"),
    }
}

const HELP: &str = "\
lift — Low-rank Informed Sparse Fine-Tuning (ICML 2025) reproduction

USAGE:
  lift pretrain --preset tiny [--steps 1500] [--seed 1]
  lift train --preset tiny --method lift --rank 32 --suite arith [--steps 300]
       [--ckpt-every 50 --ckpt-dir runs/ckpt]   periodic versioned snapshots
                                  (written off-loop by a background writer;
                                  the loss curve streams to curve.sidecar)
       [--ckpt-keep 3]            keep-last-N snapshot retention (0 = all)
       [--ckpt-dir runs/ckpt --resume latest]   continue the newest snapshot
       [--resume path/to/step_00000050.snap]    continue a specific snapshot
  lift matrix --methods lift,full --selectors weight_mag,random \\
       --ranks 8,32 --seeds 1,2 --steps 200 --out results/matrix
                                  resumable scenario grid: finished cells are
                                  skipped on rerun, interrupted cells resume
                                  from their newest snapshot; --toy runs the
                                  artifact-free synthetic cells; ends with a
                                  method × rank summary table (summary.txt);
                                  [--ckpt-keep N] prunes per-cell snapshots
  lift eval --preset tiny --suite arith
  lift exp table2 [--fast]        regenerate a paper table/figure
  lift list-exp                   list experiment ids
  lift inspect                    manifest summary

Methods: full lift lift_mlp lift_structured lora dora pissa spectral s2ft
         sift spiel weight_mag grad_mag movement random
";

fn cmd_pretrain(args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &preset)?;
    let steps = args.usize("steps", lift::exp::default_pretrain_steps(&preset));
    let seed = args.u64("seed", 1);
    args.finish()?;
    let params = pretrain::ensure_pretrained(&rt, &exec, steps, seed)?;
    let corpus = pretrain::world(&exec);
    let ppl = eval::perplexity(&exec, &params, &corpus, 8, 99)?;
    let recall = eval::fact_recall(&rt, &exec, &params, &corpus, 50, 7)?;
    println!("preset={preset} steps={steps} heldout_ppl={ppl:.3} fact_recall={recall:.3}");
    Ok(())
}

fn suite_families(suite: &str) -> Vec<lift::data::TaskFamily> {
    match suite {
        "arith" => ARITH.to_vec(),
        "commonsense" => COMMONSENSE.to_vec(),
        "nlu" => NLU.to_vec(),
        "gpqa" => vec![lift::data::TaskFamily::Gpqa],
        other => panic!("unknown suite '{other}'"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let method_name = args.str("method", "lift");
    let rank = args.usize("rank", 32);
    let suite = args.str("suite", "arith");
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &preset)?;
    let steps = args.usize("steps", 300);
    let lr = args.f32("lr", 1e-3);
    let interval = args.usize("interval", 100);
    let seed = args.u64("seed", 1);
    let pt_steps = args.usize("pretrain-steps", lift::exp::default_pretrain_steps(&preset));
    let n_train = args.usize("train-samples", 1000);
    let n_test = args.usize("test-samples", 100);
    let ckpt_every = args.usize("ckpt-every", 0);
    let ckpt_dir = args.opt_str("ckpt-dir").map(PathBuf::from);
    let ckpt_keep = args.usize("ckpt-keep", 0);
    let resume_arg = args.opt_str("resume");
    args.finish()?;

    let mut params = pretrain::ensure_pretrained(&rt, &exec, pt_steps, 1)?;
    let corpus = pretrain::world(&exec);
    let fams = suite_families(&suite);
    let sets: Vec<TaskSet> = fams
        .iter()
        .map(|&f| TaskSet::generate(f, &corpus.vocab, &corpus.kg, n_train, n_test, seed))
        .collect();
    let mut src = TaskMixSource {
        sets: sets.clone(),
        batch: exec.preset.batch,
        seq: exec.preset.seq,
    };
    let mut ctx = pretrain::make_ctx(&rt, &exec, seed);
    let lift_cfg = LiftCfg {
        rank: args.usize("lra-rank", rank),
        ..Default::default()
    };
    let mut method = make_method(&method_name, rank, lift_cfg, interval, Scope::default())?;
    let cfg = TrainCfg {
        steps,
        lr,
        warmup_frac: 0.03,
        log_every: 50,
        seed,
        ckpt_every,
        ckpt_dir: ckpt_dir.clone(),
        ckpt_keep,
    };
    let snapshot = match resume_arg.as_deref() {
        Some("latest") => {
            let dir = ckpt_dir
                .ok_or_else(|| anyhow::anyhow!("--resume latest needs --ckpt-dir"))?;
            Some(lift::ckpt::latest_snapshot(&dir)?.ok_or_else(|| {
                anyhow::anyhow!("--resume latest: no step_*.snap under {dir:?}")
            })?)
        }
        Some(path) => Some(PathBuf::from(path)),
        None => None,
    };
    let log = match &snapshot {
        Some(snap) => {
            train_resume(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg, snap)?
        }
        None => train(&exec, &mut src, &mut *method, &mut ctx, &mut params, &cfg)?,
    };
    println!(
        "method={} trainable={} opt_bytes={} final_loss={:.4} ({:.1}s)",
        method.name(),
        method.trainable(),
        method.opt_bytes(),
        log.tail_loss(20),
        log.seconds
    );
    for set in &sets {
        let acc = eval::accuracy(&exec, &params, &set.test)?;
        println!("  {:<12} {acc:.2}", set.family.name());
    }
    Ok(())
}

/// Resumable scenario matrix: method × selector × sparsity cells,
/// persisted per cell under `--out`, finished cells skipped on rerun,
/// unfinished ones fanned over the `lift::engine::par_map` pool (each
/// cell resumes from its newest snapshot). `--toy` drives the
/// artifact-free synthetic cells so the machinery runs without
/// `make artifacts`.
fn cmd_matrix(args: &Args) -> Result<()> {
    use lift::exp::matrix::{self, RealCellCfg};
    let preset = args.str("preset", "tiny");
    let methods = args.list("methods", "lift,full");
    let selectors = args.list("selectors", "");
    let ranks: Vec<usize> = args
        .list("ranks", "32")
        .iter()
        .map(|r| r.parse().unwrap_or_else(|_| panic!("--ranks expects integers, got '{r}'")))
        .collect();
    let seeds: Vec<u64> = args
        .list("seeds", "1")
        .iter()
        .map(|s| s.parse().unwrap_or_else(|_| panic!("--seeds expects integers, got '{s}'")))
        .collect();
    let steps = args.usize("steps", 200);
    let interval = args.usize("interval", 100);
    let out = PathBuf::from(args.str("out", "results/matrix"));
    let ckpt_every = args.usize("ckpt-every", 50);
    let ckpt_keep = args.usize("ckpt-keep", 0);
    let workers = args.usize("workers", lift::lift::engine::default_workers());
    let toy = args.bool("toy", false);
    let suite = args.str("suite", "arith");
    let pt_steps = args.usize("pretrain-steps", lift::exp::default_pretrain_steps(&preset));
    let n_train = args.usize("train-samples", 1000);
    let n_test = args.usize("test-samples", 100);
    args.finish()?;

    let cell_preset = if toy { "toy".to_string() } else { preset.clone() };
    let cells =
        matrix::expand_grid(&cell_preset, &methods, &selectors, &ranks, &seeds, steps, interval);
    anyhow::ensure!(!cells.is_empty(), "empty grid: no methods/selectors given");
    let report = if toy {
        matrix::run_matrix(&out, &cells, workers, |spec| {
            matrix::run_toy_cell(spec, &out, ckpt_every, ckpt_keep, 1)
        })?
    } else {
        // pre-warm the pretrained base sequentially so parallel cells
        // hit the runs/ checkpoint cache read-only
        {
            let rt = Runtime::from_default()?;
            let exec = ModelExec::load(&rt, &preset)?;
            pretrain::ensure_pretrained(&rt, &exec, pt_steps, 1)?;
        }
        let rc = RealCellCfg {
            families: suite_families(&suite),
            pt_steps,
            n_train,
            n_test,
            ckpt_every,
            ckpt_keep,
            inner_workers: 1,
        };
        matrix::run_matrix(&out, &cells, workers, |spec| {
            matrix::run_real_cell(spec, &out, &rc)
        })?
    };
    println!(
        "matrix: {} ran, {} skipped, {} failed (out: {})",
        report.ran.len(),
        report.skipped.len(),
        report.failed.len(),
        out.display()
    );
    for c in &cells {
        if let Some(o) = matrix::read_outcome(&out, &c.id()) {
            println!(
                "  {:<44} avg={:>5.1} tail_loss={:.4} trainable={}",
                c.id(),
                o.avg,
                o.tail_loss,
                o.trainable
            );
        }
    }
    for (id, err) in &report.failed {
        println!("  FAILED {id}: {err}");
    }
    // the campaign's readable artifact: a paper-style method × rank
    // table over every persisted outcome, also saved as summary.txt
    let (summary_path, table) = matrix::write_summary(&out, &cells)?;
    println!("\n{table}");
    println!("summary written to {}", summary_path.display());
    anyhow::ensure!(report.failed.is_empty(), "{} matrix cells failed", report.failed.len());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let preset = args.str("preset", "tiny");
    let suite = args.str("suite", "arith");
    let rt = Runtime::from_default()?;
    let exec = ModelExec::load(&rt, &preset)?;
    let pt_steps = args.usize("pretrain-steps", lift::exp::default_pretrain_steps(&preset));
    let n_test = args.usize("test-samples", 100);
    args.finish()?;
    let params = pretrain::ensure_pretrained(&rt, &exec, pt_steps, 1)?;
    let corpus = pretrain::world(&exec);
    for &f in &suite_families(&suite) {
        let set = TaskSet::generate(f, &corpus.vocab, &corpus.kg, 1, n_test, 1);
        let acc = eval::accuracy(&exec, &params, &set.test)?;
        println!("{:<12} {acc:.2}", set.family.name());
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let rt = Runtime::from_default()?;
    args.finish()?;
    println!("artifacts: {:?}", Runtime::default_dir());
    for (name, p) in &rt.manifest.presets {
        println!(
            "preset {name:<6} d={} L={} ffn={} vocab={} seq={} batch={} params={:.2}M execs={:?}",
            p.d,
            p.layers,
            p.ffn,
            p.vocab,
            p.seq,
            p.batch,
            p.n_params() as f64 / 1e6,
            p.executables.keys().collect::<Vec<_>>()
        );
    }
    println!("kernels: {}", rt.manifest.kernels.len());
    Ok(())
}

//! Performance benches over the hot paths (criterion is unavailable
//! offline; util::bench provides the harness). Run with `cargo bench`.
//!
//! Sections map to the §Perf plan in DESIGN.md / EXPERIMENTS.md:
//!   [step]         L2+L3 train/eval step latency per preset
//!   [mask]         LIFT mask construction: artifact kernel vs rust-built
//!                  graph vs exact host SVD, per shape and rank
//!   [mask-refresh] full-model batched refresh: sequential vs
//!                  layer-parallel MaskEngine (ISSUE-1 acceptance row)
//!   [exact-svd]    exact oracle: top-r subspace path vs full-spectrum
//!                  Jacobi, plus the layer-parallel exact-refresh
//!                  speedup row (ISSUE-2 acceptance)
//!   [step-all]     batched optimizer step: sequential vs layer-parallel
//!                  (ISSUE-2 acceptance row)
//!   [warm-refresh] warm-started exact refresh vs cold on a drifting
//!                  steady state (ISSUE-4 acceptance row)
//!   [arena-step]   per-worker scratch arenas vs per-job allocation on
//!                  the refresh/step hot paths (ISSUE-4 acceptance row)
//!   [async-ckpt]   double-buffered background snapshot writes vs
//!                  synchronous saves (ISSUE-4 acceptance row)
//!   [gemm-simd]    scalar vs runtime-detected SIMD GEMM microkernels
//!                  (ISSUE-7 acceptance row; >=1.5x floor on AVX2 hosts)
//!   [gemm-par]     serial vs intra-matrix-parallel tiled GEMM over the
//!                  engine pool (ISSUE-7 acceptance row)
//!   [gemm-q]       f64 vs int8 blockwise quantized Gram build — the
//!                  qscan scan tier (ISSUE-10 acceptance row; >=1.1x
//!                  absolute floor on hosts with the SIMD path live)
//!   [serve]        per-tenant sparse-delta serving: overlay-apply vs
//!                  full tenant materialization (tenants/GB), plus p95
//!                  of a batched multi-tenant request mix (ISSUE-8
//!                  acceptance rows)
//!   [ckpt]         versioned snapshot save/restore throughput
//!                  (ISSUE-3 acceptance row)
//!   [adam]         sparse Adam: host loop vs Pallas kernel via PJRT
//!   [marshal]      literal marshalling overhead (params -> device)
//!   [linalg]       matmul throughput through the XlaBuilder toolkit
//!   [data]         batch generation throughput
//!   [e2e]          full optimizer step for lift / full / lora
//!
//! Sections that need AOT artifacts ([step], [data], [e2e], the kernel
//! halves of [mask]/[adam]) skip themselves when `make artifacts` has
//! not run; everything routed through the XlaBuilder toolkit still runs.
//!
//! Every run appends a machine-readable entry (raw bench rows + the
//! measured speedup rows) to `BENCH_trajectory.json` (override with
//! $BENCH_TRAJECTORY) so perf is diffable across PRs. With `--check`
//! the run then gates on that history: every speedup row is compared
//! against the previous run of the same mode and the bench exits
//! nonzero if any regressed beyond the documented tolerance
//! ($BENCH_CHECK_TOL, default 0.4 — i.e. a 40% drop; speedup ratios
//! are self-normalizing against machine speed, which is what makes a
//! CI gate on shared runners tenable at all).

use std::sync::Arc;

use lift::data::tasks::{TaskFamily, TaskMixSource, TaskSet};
use lift::data::BatchSource;
use lift::exp::harness::{
    measure_exact_refresh, measure_gemm_par, measure_gemm_q, measure_gemm_simd,
    measure_mask_refresh, measure_serve_overlay, measure_step_all, measure_warm_refresh, Speedup,
};
use lift::lift::engine::default_workers;
use lift::lift::{budget_for, principal_indices, LiftCfg};
use lift::methods::{make_method, Scope};
use lift::optim::{AdamCfg, KernelAdam, SparseAdam};
use lift::runtime::{model_exec::ModelExec, ArtifactStatus, Linalg, Runtime};
use lift::tensor::Tensor;
use lift::train::pretrain;
use lift::util::bench::Bencher;
use lift::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    lift::util::logging::init();
    let fast = std::env::args().any(|a| a == "--fast");
    let check = std::env::args().any(|a| a == "--check");
    let mut b = if fast { Bencher::fast() } else { Bencher::default() };
    // `?` on a broken artifacts dir aborts the bench loudly; the skip
    // policy itself lives in Runtime::artifact_status
    let rt = match Runtime::artifact_status()? {
        ArtifactStatus::Ready(rt) => Some(rt),
        ArtifactStatus::StubOnly => {
            println!(
                "(artifacts present but this build links the host-interpreter xla \
                 stub — artifact-backed sections skipped; link the native xla crate)"
            );
            None
        }
        ArtifactStatus::Missing(e) => {
            println!("(artifacts not generated — artifact-backed sections skipped: {e})");
            None
        }
    };
    let client = match &rt {
        Some(rt) => rt.client.clone(),
        None => xla::PjRtClient::cpu()?,
    };
    // one shared toolkit: the [mask] benches warm the same compile cache
    // the [mask-refresh] engine measurement then reuses
    let la = Arc::new(Linalg::new(&client));
    let mut rng = Rng::new(1);
    // measured seq-vs-parallel rows, collected for the JSON trajectory
    let mut speedups: Vec<Speedup> = Vec::new();

    if let Some(rt) = &rt {
        println!("\n-- [step] model step latency --");
        for preset in ["tiny", "small", "base"] {
            if !rt.manifest.presets.contains_key(preset) {
                continue;
            }
            let exec = ModelExec::load(rt, preset)?;
            let params = lift::model::init_params(&exec.preset, &mut rng);
            let mut corpus = pretrain::world(&exec);
            let batch = corpus.next_batch(&mut rng);
            let toks = exec.preset.batch * exec.preset.seq;
            b.bench(&format!("train_step/{preset}"), || {
                let _ = exec.train_step(&params, &batch).unwrap();
            });
            let mean = b.results.last().unwrap().mean_ns;
            println!(
                "{:<44} {:.0} tokens/s",
                format!("train_step/{preset} [throughput]"),
                toks as f64 / (mean / 1e9)
            );
            b.bench(&format!("eval_step/{preset}"), || {
                let _ = exec.eval_step(&params, &batch).unwrap();
            });
        }
    }

    println!("\n-- [mask] LIFT mask construction (128x352, rank-32 budget) --");
    let w = Tensor::randn(&[128, 352], 0.05, &mut rng);
    let k = budget_for(128, 352, 32);
    for (name, cfg) in [
        ("mask/randomized_r32", LiftCfg { rank: 32, ..Default::default() }),
        ("mask/randomized_r128", LiftCfg { rank: 128, ..Default::default() }),
        ("mask/exact_jacobi_r32", LiftCfg { rank: 32, exact: true, ..Default::default() }),
    ] {
        let mut r = Rng::new(2);
        b.bench(name, || {
            let _ = principal_indices(&la, &w, k, &cfg, &mut r).unwrap();
        });
    }
    // artifact kernel path (Pallas subspace-iteration lowering)
    if let Some(rt) = &rt {
        if let Some(file) = rt.manifest.kernels.get("svd_128x352_r40") {
            let exe = rt.load_artifact(file)?;
            let g0 = Tensor::randn(&[352, 40], 1.0, &mut rng);
            let wl = lift::runtime::literal::tensor_to_literal(&w)?;
            let gl = lift::runtime::literal::tensor_to_literal(&g0)?;
            b.bench("mask/artifact_svd_r32", || {
                let _ = exe.execute(&[&wl, &gl]).unwrap();
            });
        }
    }

    println!("\n-- [mask-refresh] batched refresh: sequential vs layer-parallel --");
    {
        // a tiny-preset-shaped model, several layers' worth of matrices
        let layers = if fast { 2 } else { 4 };
        let mut shapes = Vec::new();
        for _ in 0..layers {
            shapes.extend(lift::exp::harness::tiny_layer_shapes());
        }
        let workers = default_workers();
        let reps = if fast { 2 } else { 5 };
        let row = measure_mask_refresh(&la, &shapes, 32, 32, workers, reps)?;
        println!("{}", row.row());
        speedups.push(row);
    }

    println!("\n-- [exact-svd] exact oracle: top-r subspace vs full Jacobi --");
    {
        let (m, n, r) = (96usize, 288usize, 16usize);
        let we = Tensor::randn(&[m, n], 0.05, &mut rng);
        b.bench(&format!("exact_svd/full_jacobi_{m}x{n}"), || {
            let _ = lift::util::eigh::svd(&we.data, m, n);
        });
        b.bench(&format!("exact_svd/topr_r{r}_{m}x{n}"), || {
            let _ = lift::util::eigh::svd_topr(&we.data, m, n, r);
        });
        // layer-parallel exact refresh: per-matrix top-r decompositions
        // fanned across the worker pool (the ISSUE-2 acceptance row)
        let layers = if fast { 1 } else { 2 };
        let mut shapes = Vec::new();
        for _ in 0..layers {
            shapes.extend(lift::exp::harness::tiny_layer_shapes());
        }
        let reps = if fast { 2 } else { 3 };
        let row = measure_exact_refresh(&la, &shapes, 8, 32, default_workers(), reps)?;
        println!("{}", row.row());
        speedups.push(row);
    }

    println!("\n-- [step-all] batched sparse-Adam step: sequential vs layer-parallel --");
    {
        let layers = if fast { 4 } else { 8 };
        let mut shapes = Vec::new();
        for _ in 0..layers {
            shapes.extend(lift::exp::harness::tiny_layer_shapes());
        }
        let reps = if fast { 3 } else { 5 };
        let row = measure_step_all(&shapes, 64, default_workers(), reps, 10)?;
        println!("{}", row.row());
        speedups.push(row);
    }

    println!("\n-- [warm-refresh] warm-started exact refresh vs cold --");
    {
        // the steady-state fixture: a model's worth of matrices that
        // drifted slightly since their last refresh (carrier reuse)
        let layers = if fast { 1 } else { 2 };
        let mut shapes = Vec::new();
        for _ in 0..layers {
            shapes.extend(lift::exp::harness::tiny_layer_shapes());
        }
        let reps = if fast { 2 } else { 3 };
        let row = measure_warm_refresh(&shapes, 16, reps)?;
        println!("{}", row.row());
        speedups.push(row);
    }

    println!("\n-- [gemm-simd] scalar vs SIMD GEMM microkernels --");
    {
        let reps = if fast { 3 } else { 6 };
        let row = measure_gemm_simd(reps);
        println!("{}", row.row());
        println!(
            "   (runtime SIMD: {})",
            if lift::util::gemm::simd_enabled() {
                "avx2"
            } else {
                "scalar fallback — row emitted at ~1.0x so the label stays in the trajectory"
            }
        );
        speedups.push(row);
    }

    println!("\n-- [gemm-par] serial vs intra-matrix-parallel tiled GEMM --");
    {
        let reps = if fast { 2 } else { 4 };
        let row = measure_gemm_par(default_workers(), reps);
        println!("{}", row.row());
        speedups.push(row);
    }

    println!("\n-- [gemm-q] f64 vs int8 blockwise quantized Gram (qscan tier) --");
    {
        let reps = if fast { 3 } else { 6 };
        let row = measure_gemm_q(reps);
        println!("{}", row.row());
        speedups.push(row);
    }

    println!("\n-- [serve] per-tenant sparse-delta serving --");
    {
        use lift::exp::matrix::{toy_params, toy_preset};
        use lift::serve::{base_digest, synth_delta, Request, Server, TenantView};
        // overlay-apply vs full tenant materialization (tenants/GB row);
        // an algorithmic invariant, so the row is always emitted
        let reps = if fast { 3 } else { 6 };
        let (row, view_bytes, dense_bytes) = measure_serve_overlay(reps)?;
        println!("{}", row.row());
        println!(
            "   tenants/GB: {:.0} ({view_bytes} B/tenant resident) vs {:.0} as dense copies \
             ({dense_bytes} B)",
            1e9 / view_bytes as f64,
            1e9 / dense_bytes as f64
        );
        speedups.push(row);
        // latency rows: overlay-apply and a batched multi-tenant request
        // mix through the real Server (p95 is the [serve] acceptance
        // metric; util::bench reports it per row)
        let base = toy_params(40);
        let digest = base_digest(&base);
        let dir = std::env::temp_dir().join(format!("lift_bench_serve_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut server = Server::new(&base, &toy_preset(), &dir, 4 << 20, default_workers())?;
        let n_tenants = 16usize;
        for i in 0..n_tenants {
            server.store().register(&synth_delta(&base, &format!("t{i:02}"), digest, 2, 40 + i as u64))?;
        }
        let toy_delta = server.store().load("t00")?;
        b.bench("serve/overlay_apply_toy", || {
            let _ = std::hint::black_box(TenantView::materialize(&base, &toy_delta).unwrap());
        });
        let mut mix_rng = Rng::new(0x7117);
        let batch: Vec<Request> = (0..32)
            .map(|_| Request {
                tenant: format!("t{:02}", mix_rng.below(n_tenants)),
                seed: mix_rng.next_u64(),
            })
            .collect();
        b.bench("serve/request_mix_b32", || {
            let _ = std::hint::black_box(server.handle_batch(&batch).unwrap());
        });
        let p95 = b.results.last().unwrap().p95_ns;
        println!(
            "   request-mix p95: {} per 32-request multi-tenant batch",
            lift::util::bench::fmt_ns(p95)
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    println!("\n-- [arena-step] scratch-arena reuse vs per-job allocation --");
    {
        use lift::util::eigh::{lowrank_approx_warm, EighScratch};
        let layers = if fast { 1 } else { 2 };
        let mut shapes = Vec::new();
        for _ in 0..layers {
            shapes.extend(lift::exp::harness::tiny_layer_shapes());
        }
        let ws: Vec<Tensor> = shapes
            .iter()
            .map(|&(m, n)| Tensor::randn(&[m, n], 0.05, &mut rng))
            .collect();
        let reps = if fast { 2 } else { 4 };
        // fresh-arena side: exactly what every per-job `vec![0.0; ..]`
        // allocation used to cost, via the cold convenience wrapper
        let time_side = |reuse: bool| -> f64 {
            let mut arena = EighScratch::new();
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let t0 = std::time::Instant::now();
                for w in &ws {
                    let (m, n) = w.dims2();
                    if reuse {
                        let _ = lowrank_approx_warm(&w.data, m, n, 16, None, &mut arena);
                    } else {
                        let _ = lift::util::eigh::lowrank_approx(&w.data, m, n, 16);
                    }
                }
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best
        };
        let alloc_s = time_side(false);
        let arena_s = time_side(true);
        let row = Speedup {
            label: "arena_step",
            workers: 1,
            matrices: shapes.len(),
            seq_s: alloc_s,
            par_s: arena_s,
            speedup: alloc_s / arena_s.max(1e-12),
        };
        println!("{}", row.row());
        speedups.push(row);
        // and the optimizer-side arena: batched moment migration reuses
        // one survivor table + moment buffers across every matrix
        let k = 4096;
        let mut st = SparseAdam::new((0..k as u32).collect(), AdamCfg::default());
        let mut scratch = lift::optim::sparse::RefreshScratch::default();
        let mut flip = 0u32;
        b.bench("arena/refresh_migrate_reuse", || {
            flip ^= 1;
            st.refresh_with((flip..k as u32 + flip).collect(), &mut scratch);
        });
    }

    println!("\n-- [async-ckpt] background double-buffered saves vs synchronous --");
    {
        use lift::methods::Method;
        // a training-shaped loop: compute, then snapshot every step —
        // the async side should hide most of the write latency behind
        // the next step's compute
        let mut shapes = Vec::new();
        for _ in 0..4 {
            shapes.extend(lift::exp::harness::tiny_layer_shapes());
        }
        let params: Vec<Tensor> = shapes
            .iter()
            .map(|&(m, n)| Tensor::randn(&[m, n], 0.05, &mut rng))
            .collect();
        let mut ctx = lift::exp::matrix::toy_ctx(1, 11)?;
        let mut method = lift::methods::full::FullFt::new();
        method.init(&mut ctx, &params)?;
        let data_rng = Rng::new(9);
        let tcfg = lift::train::TrainCfg::default();
        let dir = std::env::temp_dir().join(format!("lift_bench_actkpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir)?;
        let steps = if fast { 4 } else { 8 };
        let ca = Tensor::randn(&[192, 192], 1.0, &mut rng);
        let compute = |t: &Tensor| std::hint::black_box(t.matmul(t));
        let tlog = lift::train::TrainLog {
            losses: vec![0.5],
            seconds: 1.0,
            step_times: vec![1.0],
        };
        let reps = if fast { 2 } else { 3 };
        let mut sync_s = f64::INFINITY;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            for step in 1..=steps {
                let _ = compute(&ca);
                lift::ckpt::save_trainer(
                    &lift::ckpt::snapshot_path(&dir, step),
                    step,
                    &method,
                    &params,
                    &ctx.rng,
                    &data_rng,
                    &tlog,
                    &tcfg,
                )?;
            }
            sync_s = sync_s.min(t0.elapsed().as_secs_f64());
        }
        let mut async_s = f64::INFINITY;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            let mut writer = lift::ckpt::AsyncSnapshotWriter::new();
            for step in 1..=steps {
                let _ = compute(&ca);
                let bytes = lift::ckpt::trainer_snapshot_bytes(
                    step, &method, &params, &ctx.rng, &data_rng, 1.0, &tcfg,
                )?;
                writer.submit(lift::ckpt::snapshot_path(&dir, step), bytes, 0)?;
            }
            writer.finish()?;
            async_s = async_s.min(t0.elapsed().as_secs_f64());
        }
        let row = Speedup {
            label: "async_ckpt",
            workers: 1,
            matrices: steps,
            seq_s: sync_s,
            par_s: async_s,
            speedup: sync_s / async_s.max(1e-12),
        };
        println!("{}", row.row());
        speedups.push(row);
        let _ = std::fs::remove_dir_all(&dir);
    }

    println!("\n-- [ckpt] versioned snapshot save/restore --");
    {
        use lift::methods::Method;
        // FullFT carries the heaviest state (dense moments for every
        // tensor), so it bounds snapshot throughput; 4 layers' worth of
        // tiny-preset matrices makes a few-MB snapshot
        let mut shapes = Vec::new();
        for _ in 0..4 {
            shapes.extend(lift::exp::harness::tiny_layer_shapes());
        }
        let params: Vec<Tensor> = shapes
            .iter()
            .map(|&(m, n)| Tensor::randn(&[m, n], 0.05, &mut rng))
            .collect();
        let mut ctx = lift::exp::matrix::toy_ctx(1, 7)?;
        let mut method = lift::methods::full::FullFt::new();
        method.init(&mut ctx, &params)?;
        let dir = std::env::temp_dir().join(format!("lift_bench_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir)?;
        let path = lift::ckpt::snapshot_path(&dir, 1);
        let data_rng = Rng::new(9);
        let tlog = lift::train::TrainLog {
            losses: vec![0.5],
            seconds: 1.0,
            step_times: vec![1.0],
        };
        let tcfg = lift::train::TrainCfg::default();
        lift::ckpt::save_trainer(&path, 1, &method, &params, &ctx.rng, &data_rng, &tlog, &tcfg)?;
        let mb = std::fs::metadata(&path)?.len() as f64 / 1e6;
        b.bench("ckpt/save_snapshot", || {
            lift::ckpt::save_trainer(&path, 1, &method, &params, &ctx.rng, &data_rng, &tlog, &tcfg)
                .unwrap();
        });
        let mean = b.results.last().unwrap().mean_ns;
        println!(
            "{:<44} {:.0} MB/s ({mb:.1} MB snapshot)",
            "ckpt/save_snapshot [throughput]",
            mb / (mean / 1e9)
        );
        b.bench("ckpt/load_snapshot", || {
            let _ = lift::ckpt::load_trainer(&path).unwrap();
        });
        let mean = b.results.last().unwrap().mean_ns;
        println!(
            "{:<44} {:.0} MB/s",
            "ckpt/load_snapshot [throughput]",
            mb / (mean / 1e9)
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    println!("\n-- [adam] sparse AdamW step (k = 65536) --");
    let kk = 65536;
    let mut p = rng.normal_vec(kk, 1.0);
    let g = rng.normal_vec(kk, 1.0);
    let mut host = SparseAdam::new((0..kk as u32).collect(), AdamCfg::default());
    b.bench("adam/host_packed", || {
        host.step(&mut p, &g, 1e-4);
    });
    if let Some(rt) = &rt {
        let kern = KernelAdam::new(rt, kk)?;
        let (mut m, mut v) = (vec![0.0f32; kk], vec![0.0f32; kk]);
        let mut t = 0usize;
        b.bench("adam/pallas_kernel", || {
            t += 1;
            kern.step(&mut p, &g, &mut m, &mut v, &AdamCfg::default(), t, 1e-4)
                .unwrap();
        });
    }

    println!("\n-- [marshal] literal marshalling --");
    let big = Tensor::randn(&[1024, 1024], 1.0, &mut rng);
    b.bench("marshal/tensor_to_literal_4MB", || {
        let _ = lift::runtime::literal::tensor_to_literal(&big).unwrap();
    });

    println!("\n-- [linalg] matmul throughput --");
    for n in [256usize, 512, 1024] {
        let a = Tensor::randn(&[n, n], 1.0, &mut rng);
        let c = Tensor::randn(&[n, n], 1.0, &mut rng);
        b.bench(&format!("linalg/matmul_{n}"), || {
            let _ = la.matmul(&a, &c).unwrap();
        });
        let mean = b.results.last().unwrap().mean_ns;
        let gflops = 2.0 * (n as f64).powi(3) / mean;
        println!("{:<44} {gflops:.2} GFLOP/s", format!("linalg/matmul_{n} [rate]"));
    }

    if let Some(rt) = &rt {
        println!("\n-- [data] batch generation --");
        let exec = ModelExec::load(rt, "tiny")?;
        let corpus = pretrain::world(&exec);
        let set = TaskSet::generate(TaskFamily::GsmHard, &corpus.vocab, &corpus.kg, 500, 50, 1);
        let mut mix = TaskMixSource {
            sets: vec![set],
            batch: exec.preset.batch,
            seq: exec.preset.seq,
        };
        let mut corpus2 = pretrain::world(&exec);
        b.bench("data/corpus_batch", || {
            let _ = corpus2.next_batch(&mut rng);
        });
        b.bench("data/task_batch", || {
            let _ = mix.next_batch(&mut rng);
        });

        println!("\n-- [e2e] one full fine-tune step (tiny, incl. grads) --");
        for mname in ["lift", "full", "lora"] {
            let exec = ModelExec::load(rt, "tiny")?;
            let mut params = lift::model::init_params(&exec.preset, &mut rng);
            let mut ctx = pretrain::make_ctx(rt, &exec, 1);
            let mut method = make_method(
                mname,
                32,
                LiftCfg { rank: 32, ..Default::default() },
                1_000_000, // no refresh inside the bench
                Scope::default(),
            )?;
            use lift::methods::Method;
            method.init(&mut ctx, &params)?;
            let batch = corpus.eval_batches(1, 5).remove(0);
            let mut step = 0usize;
            b.bench(&format!("e2e/step_{mname}"), || {
                let (_, grads) = exec.train_step(&params, &batch).unwrap();
                method.step(&mut ctx, &mut params, &grads, step, 1e-4).unwrap();
                step += 1;
            });
        }
    }

    let traj = std::env::var("BENCH_TRAJECTORY").unwrap_or_else(|_| "BENCH_trajectory.json".into());
    append_trajectory(&traj, &b, &speedups, fast)?;
    println!(
        "\n{} benches done; run appended to {traj} ({} speedup rows).",
        b.results.len(),
        speedups.len()
    );
    if check {
        // absolute floors: warm refresh and the serve overlay (a
        // row-granular view copies a small fraction of the bytes a dense
        // tenant copy moves) are algorithmic invariants on any machine;
        // the SIMD kernel floor (ISSUE-7 acceptance) only applies where
        // the AVX2 path is actually live — on scalar-only hosts (or
        // under LIFT_NO_SIMD) the row honestly reads ~1.0x
        let mut floors: Vec<(&str, f64)> = vec![("warm_refresh", 1.1), ("serve_overlay", 1.1)];
        if lift::util::gemm::simd_enabled() {
            floors.push(("gemm_simd", 1.5));
            // the int8 tier's floor also applies only where the wide
            // integer kernels are live: 32 i8 lanes per AVX2 op against
            // the f64 tier's 4 make >=1.1x conservative there, while a
            // scalar-only host leaves both tiers to the autovectorizer
            // and the ratio is an honest toss-up
            floors.push(("gemm_q", 1.1));
        }
        check_regression(&traj, fast, &floors)?;
    }
    Ok(())
}

/// The `--check` regression gate: compare the just-appended run's
/// speedup rows against the previous run of the same mode (`fast` vs
/// full) and fail when any labeled speedup dropped by more than the
/// tolerance. Tolerance: $BENCH_CHECK_TOL as a fraction, default 0.4 —
/// generous because CI runners are noisy, but speedup *ratios* (seq vs
/// par on the same box, cold vs warm on the same matrices) are
/// self-normalizing, so a real regression (a serialized pool, a
/// disabled warm path) shows up as a 2-10x drop, far outside it.
/// `floors` lists the absolute per-label minimums for rows whose ratio
/// is an algorithmic invariant (main decides which apply on this host).
fn check_regression(path: &str, fast: bool, floors: &[(&str, f64)]) -> anyhow::Result<()> {
    use lift::util::json::Json;
    let tol: f64 = std::env::var("BENCH_CHECK_TOL")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.4);
    let doc = Json::parse(&std::fs::read_to_string(path)?)
        .map_err(|e| anyhow::anyhow!("unparseable {path}: {e:?}"))?;
    let runs = doc
        .get("runs")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| anyhow::anyhow!("{path} has no runs array"))?;
    let same_mode: Vec<&Json> = runs
        .iter()
        .filter(|r| r.get("fast").and_then(|f| f.as_bool()) == Some(fast))
        .collect();
    if same_mode.len() < 2 {
        println!(
            "--check: no prior {} run in {path} to compare against; gate passes vacuously",
            if fast { "fast" } else { "full" }
        );
        return Ok(());
    }
    let rows = |run: &Json| -> Vec<(String, f64)> {
        run.get("speedups")
            .and_then(|s| s.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|s| {
                        Some((
                            s.get("label")?.as_str()?.to_string(),
                            s.get("speedup")?.as_f64()?,
                        ))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let prev = rows(same_mode[same_mode.len() - 2]);
    let cur = rows(same_mode[same_mode.len() - 1]);
    let mut regressed = Vec::new();
    println!("--check: gating against the previous run (tolerance {:.0}%):", tol * 100.0);
    for (label, cur_v) in &cur {
        match prev.iter().find(|(l, _)| l == label) {
            Some((_, prev_v)) => {
                let floor = prev_v * (1.0 - tol);
                let ok = *cur_v >= floor;
                println!(
                    "  {label:<16} prev {prev_v:>7.2}x -> now {cur_v:>7.2}x (floor {floor:.2}x) {}",
                    if ok { "OK" } else { "REGRESSED" }
                );
                if !ok {
                    regressed.push(label.clone());
                }
            }
            None => println!("  {label:<16} new row, no baseline"),
        }
    }
    // reverse pass: a row that silently stopped being measured (a
    // skipped section, an early return) is itself a regression — the
    // gate exists to notice exactly that kind of quiet disablement
    for (label, _) in &prev {
        if !cur.iter().any(|(l, _)| l == label) {
            println!("  {label:<16} VANISHED (present in the previous run, missing now)");
            regressed.push(label.clone());
        }
    }
    // absolute floors for rows whose ratio is an algorithmic invariant
    // rather than a scheduler outcome: warm refresh runs <= 10 iteration
    // passes against a cold start's up-to-60 on the same matrices, so it
    // must beat cold on any machine; the AVX2 GEMM microkernel processes
    // 4 lanes against the scalar path's (at best SSE2-autovectorized)
    // 2, so >=1.5x holds wherever main saw the SIMD path live. This half
    // of the gate works even when the baseline entry comes from the same
    // commit (as in CI, where the committed trajectory starts empty) — a
    // disabled warm path or microkernel fails here regardless of what
    // the previous run measured.
    for &(label, floor) in floors {
        if let Some((_, v)) = cur.iter().find(|(l, _)| l == label) {
            let ok = *v >= floor;
            println!(
                "  {label:<16} absolute floor {floor:.2}x: measured {v:.2}x {}",
                if ok { "OK" } else { "REGRESSED" }
            );
            if !ok {
                regressed.push(format!("{label} (below absolute floor)"));
            }
        }
    }
    anyhow::ensure!(
        regressed.is_empty(),
        "bench regression gate failed: {regressed:?} dropped more than {:.0}% below the previous \
         run (or vanished from it)",
        tol * 100.0
    );
    Ok(())
}

/// Append this run's rows to the machine-readable trajectory file so
/// perf is diffable across PRs (the "measured, not asserted" record the
/// EXPERIMENTS plan calls for). A missing or invalid file is replaced by
/// a fresh `{"format":1,"runs":[]}` container.
fn append_trajectory(
    path: &str,
    b: &Bencher,
    speedups: &[Speedup],
    fast: bool,
) -> anyhow::Result<()> {
    use lift::util::json::Json;
    let unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let results = Json::arr(b.results.iter().map(|r| {
        Json::obj(vec![
            ("name", Json::str(&r.name)),
            ("iters", Json::from(r.iters)),
            ("mean_ns", Json::num(r.mean_ns)),
            ("p50_ns", Json::num(r.p50_ns)),
            ("p95_ns", Json::num(r.p95_ns)),
            ("min_ns", Json::num(r.min_ns)),
        ])
    }));
    let sp = Json::arr(speedups.iter().map(|s| {
        Json::obj(vec![
            ("label", Json::str(s.label)),
            ("workers", Json::from(s.workers)),
            ("matrices", Json::from(s.matrices)),
            ("seq_s", Json::num(s.seq_s)),
            ("par_s", Json::num(s.par_s)),
            ("speedup", Json::num(s.speedup)),
        ])
    }));
    let run = Json::obj(vec![
        ("unix_time", Json::from(unix as usize)),
        ("fast", Json::from(fast)),
        ("workers", Json::from(default_workers())),
        ("results", results),
        ("speedups", sp),
    ]);
    let mut doc = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .filter(|j| j.get("runs").and_then(|r| r.as_arr()).is_some())
        .unwrap_or_else(|| {
            Json::obj(vec![
                ("format", Json::from(1usize)),
                ("runs", Json::arr(vec![])),
            ])
        });
    if let Json::Obj(m) = &mut doc {
        if let Some(Json::Arr(runs)) = m.get_mut("runs") {
            runs.push(run);
        }
    }
    std::fs::write(path, doc.to_string())?;
    Ok(())
}

//! Minimal offline stand-in for the `log` facade crate.
//!
//! Implements the subset this workspace uses: the five level macros, the
//! [`Log`] trait, [`set_logger`] / [`set_max_level`] / [`max_level`], and
//! the [`Level`] / [`LevelFilter`] / [`Metadata`] / [`Record`] types.
//! Like upstream, `set_logger` succeeds once; later calls return an error
//! and leave the installed logger in place.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing — not part of the public upstream API.
#[doc(hidden)]
pub fn __dispatch(level: Level, target: &str, args: fmt::Arguments) {
    if level > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__dispatch($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Null;

    impl Log for Null {
        fn enabled(&self, _m: &Metadata) -> bool {
            true
        }
        fn log(&self, _r: &Record) {}
        fn flush(&self) {}
    }

    #[test]
    fn levels_compare_to_filters() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
    }

    #[test]
    fn second_set_logger_fails_and_macros_are_safe() {
        static NULL: Null = Null;
        let first = set_logger(&NULL);
        let second = set_logger(&NULL);
        assert!(first.is_ok() || second.is_err());
        set_max_level(LevelFilter::Info);
        info!("smoke {}", 1);
        debug!("filtered out {}", 2);
    }
}

//! Host-interpreter stand-in for the `xla` PJRT bindings.
//!
//! The build environment has no native XLA runtime, so this crate
//! re-implements the API surface the workspace uses — `XlaBuilder` graph
//! construction, `PjRtClient::compile`, `PjRtLoadedExecutable::execute`,
//! and `Literal` marshalling — as a small deterministic interpreter that
//! evaluates the built graph on the host. Graphs constructed through
//! `XlaBuilder` (the `runtime::linalg` toolkit: matmuls, subspace
//! iteration, Newton–Schulz) run bit-for-bit reproducibly; repeated
//! execution of the same compiled graph on the same inputs always yields
//! identical results, which the mask-engine determinism tests rely on.
//!
//! AOT HLO *artifacts* (text files produced by `python/compile/aot.py`)
//! are out of scope: `HloModuleProto::from_text_file` loads the text, but
//! compiling an external computation returns an error. Callers gate on
//! artifact availability (see `rust/tests/integration.rs`).
//!
//! Thread-safety contract: `XlaComputation`, `PjRtLoadedExecutable`, and
//! `Literal` own plain data and are `Send + Sync`, so compiled
//! executables can be shared across the mask-engine worker threads behind
//! `Arc`. Only `XlaBuilder`/`XlaOp` (graph construction) are
//! single-threaded, matching how `runtime::linalg` uses them.

mod builder;
mod exec;
mod literal;

use std::fmt;

pub use builder::{XlaBuilder, XlaComputation, XlaOp};
pub use exec::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};
pub use literal::{ArrayShape, Literal, NativeType};

/// Element type of a literal or graph node (the subset this repo uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
}

/// Alias kept distinct to mirror the upstream API (`convert` takes a
/// `PrimitiveType`, `parameter`/`iota` take an `ElementType`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
    Pred,
}

impl PrimitiveType {
    pub(crate) fn element_type(self) -> ElementType {
        match self {
            PrimitiveType::F32 => ElementType::F32,
            PrimitiveType::S32 => ElementType::S32,
            PrimitiveType::Pred => ElementType::Pred,
        }
    }
}

/// Error type for every fallible operation in the stub.
#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    pub(crate) fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Parsed-but-not-interpreted AOT HLO artifact (text form).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    pub(crate) path: String,
}

impl HloModuleProto {
    /// Load HLO text from disk. The file must exist and be readable; the
    /// content is not interpreted (see module docs).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::metadata(path)
            .map_err(|e| Error::new(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto {
            path: path.to_string(),
        })
    }
}

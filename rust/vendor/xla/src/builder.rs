//! Graph construction: `XlaBuilder` / `XlaOp` / `XlaComputation`.
//!
//! Shape and type checking happens at construction time (mirroring the
//! real builder's behavior of failing on the op, not at execute). The
//! built `XlaComputation` owns a plain node list and is `Send + Sync`.

use std::cell::RefCell;
use std::rc::Rc;

use crate::{ElementType, Error, HloModuleProto, PrimitiveType, Result};

#[derive(Clone, Debug)]
pub(crate) enum Op {
    Parameter(usize),
    ConstF32(f32),
    Iota { dim: usize },
    /// 2-D dot with one contracting dim per side and no batch dims — the
    /// only form the linalg toolkit emits.
    Dot { lhs_c: usize, rhs_c: usize },
    Add,
    Sub,
    Mul,
    Div,
    Eq,
    Convert,
    ReduceSum { dims: Vec<usize>, keep: bool },
    Sqrt,
    Tuple,
}

#[derive(Clone, Debug)]
pub(crate) struct Node {
    pub op: Op,
    pub inputs: Vec<usize>,
    pub ty: ElementType,
    pub dims: Vec<i64>,
}

struct Inner {
    #[allow(dead_code)]
    name: String,
    nodes: Vec<Node>,
}

/// Single-threaded graph builder (mirrors upstream usage).
#[derive(Clone)]
pub struct XlaBuilder {
    inner: Rc<RefCell<Inner>>,
}

/// Handle to a node in a builder's graph.
#[derive(Clone)]
pub struct XlaOp {
    id: usize,
    builder: XlaBuilder,
}

/// A finished graph (or a reference to an external AOT HLO artifact).
#[derive(Clone, Debug)]
pub struct XlaComputation {
    pub(crate) kind: CompKind,
}

#[derive(Clone, Debug)]
pub(crate) enum CompKind {
    Graph { nodes: Vec<Node>, root: usize },
    External { path: String },
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            kind: CompKind::External {
                path: proto.path.clone(),
            },
        }
    }
}

fn numel(dims: &[i64]) -> usize {
    dims.iter().product::<i64>() as usize
}

/// Elementwise result dims: equal shapes, or broadcast a one-element
/// operand against the other.
fn broadcast_dims(a: &[i64], b: &[i64]) -> Result<Vec<i64>> {
    if a == b {
        return Ok(a.to_vec());
    }
    if numel(a) == 1 {
        return Ok(b.to_vec());
    }
    if numel(b) == 1 {
        return Ok(a.to_vec());
    }
    Err(Error::new(format!(
        "incompatible elementwise shapes {a:?} vs {b:?}"
    )))
}

impl XlaBuilder {
    pub fn new(name: &str) -> XlaBuilder {
        XlaBuilder {
            inner: Rc::new(RefCell::new(Inner {
                name: name.to_string(),
                nodes: Vec::new(),
            })),
        }
    }

    fn push(&self, node: Node) -> XlaOp {
        let mut inner = self.inner.borrow_mut();
        inner.nodes.push(node);
        XlaOp {
            id: inner.nodes.len() - 1,
            builder: self.clone(),
        }
    }

    fn node_info(&self, id: usize) -> (ElementType, Vec<i64>) {
        let inner = self.inner.borrow();
        (inner.nodes[id].ty, inner.nodes[id].dims.clone())
    }

    pub fn parameter(
        &self,
        number: i64,
        ty: ElementType,
        dims: &[i64],
        _name: &str,
    ) -> Result<XlaOp> {
        if number < 0 {
            return Err(Error::new("negative parameter number"));
        }
        Ok(self.push(Node {
            op: Op::Parameter(number as usize),
            inputs: vec![],
            ty,
            dims: dims.to_vec(),
        }))
    }

    /// Rank-0 f32 constant.
    pub fn c0(&self, v: f32) -> Result<XlaOp> {
        Ok(self.push(Node {
            op: Op::ConstF32(v),
            inputs: vec![],
            ty: ElementType::F32,
            dims: vec![],
        }))
    }

    pub fn iota(&self, ty: ElementType, dims: &[i64], iota_dimension: i64) -> Result<XlaOp> {
        let d = iota_dimension as usize;
        if d >= dims.len() {
            return Err(Error::new(format!(
                "iota dimension {d} out of range for {dims:?}"
            )));
        }
        Ok(self.push(Node {
            op: Op::Iota { dim: d },
            inputs: vec![],
            ty,
            dims: dims.to_vec(),
        }))
    }

    pub fn tuple(&self, elems: &[XlaOp]) -> Result<XlaOp> {
        let ids: Vec<usize> = elems.iter().map(|e| e.id).collect();
        let n = ids.len() as i64;
        Ok(self.push(Node {
            op: Op::Tuple,
            inputs: ids,
            ty: ElementType::F32,
            dims: vec![n],
        }))
    }
}

impl XlaOp {
    fn info(&self) -> (ElementType, Vec<i64>) {
        self.builder.node_info(self.id)
    }

    fn binary(&self, op: Op, rhs: &XlaOp) -> Result<XlaOp> {
        let (lt, ld) = self.info();
        let (rt, rd) = rhs.info();
        if lt != ElementType::F32 || rt != ElementType::F32 {
            return Err(Error::new("arithmetic ops are f32-only in the stub"));
        }
        let dims = broadcast_dims(&ld, &rd)?;
        Ok(self.builder.push(Node {
            op,
            inputs: vec![self.id, rhs.id],
            ty: ElementType::F32,
            dims,
        }))
    }

    /// 2-D dot_general with single contracting dims and no batch dims.
    pub fn dot_general(
        &self,
        rhs: &XlaOp,
        lhs_contracting: &[i64],
        rhs_contracting: &[i64],
        lhs_batch: &[i64],
        rhs_batch: &[i64],
    ) -> Result<XlaOp> {
        if !lhs_batch.is_empty() || !rhs_batch.is_empty() {
            return Err(Error::new("batched dot_general is not supported"));
        }
        if lhs_contracting.len() != 1 || rhs_contracting.len() != 1 {
            return Err(Error::new("dot_general needs exactly one contracting dim per side"));
        }
        let (lt, ld) = self.info();
        let (rt, rd) = rhs.info();
        if lt != ElementType::F32 || rt != ElementType::F32 {
            return Err(Error::new("dot_general is f32-only"));
        }
        if ld.len() != 2 || rd.len() != 2 {
            return Err(Error::new(format!(
                "dot_general supports 2-D operands, got {ld:?} x {rd:?}"
            )));
        }
        let (lc, rc) = (lhs_contracting[0] as usize, rhs_contracting[0] as usize);
        if lc > 1 || rc > 1 {
            return Err(Error::new("contracting dim out of range"));
        }
        if ld[lc] != rd[rc] {
            return Err(Error::new(format!(
                "dot_general contraction mismatch: {ld:?}[{lc}] vs {rd:?}[{rc}]"
            )));
        }
        let dims = vec![ld[1 - lc], rd[1 - rc]];
        Ok(self.builder.push(Node {
            op: Op::Dot { lhs_c: lc, rhs_c: rc },
            inputs: vec![self.id, rhs.id],
            ty: ElementType::F32,
            dims,
        }))
    }

    pub fn eq(&self, rhs: &XlaOp) -> Result<XlaOp> {
        let (lt, ld) = self.info();
        let (rt, rd) = rhs.info();
        if lt != rt {
            return Err(Error::new("eq operand types differ"));
        }
        let dims = broadcast_dims(&ld, &rd)?;
        Ok(self.builder.push(Node {
            op: Op::Eq,
            inputs: vec![self.id, rhs.id],
            ty: ElementType::Pred,
            dims,
        }))
    }

    pub fn convert(&self, ty: PrimitiveType) -> Result<XlaOp> {
        let (_, dims) = self.info();
        Ok(self.builder.push(Node {
            op: Op::Convert,
            inputs: vec![self.id],
            ty: ty.element_type(),
            dims,
        }))
    }

    pub fn reduce_sum(&self, dims: &[i64], keep_dims: bool) -> Result<XlaOp> {
        let (ty, in_dims) = self.info();
        if ty != ElementType::F32 {
            return Err(Error::new("reduce_sum is f32-only"));
        }
        let mut reduce: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        reduce.sort_unstable();
        reduce.dedup();
        if reduce.iter().any(|&d| d >= in_dims.len()) {
            return Err(Error::new(format!(
                "reduce_sum dims {reduce:?} out of range for {in_dims:?}"
            )));
        }
        let mut out_dims = Vec::new();
        for (i, &d) in in_dims.iter().enumerate() {
            if reduce.contains(&i) {
                if keep_dims {
                    out_dims.push(1);
                }
            } else {
                out_dims.push(d);
            }
        }
        Ok(self.builder.push(Node {
            op: Op::ReduceSum {
                dims: reduce,
                keep: keep_dims,
            },
            inputs: vec![self.id],
            ty: ElementType::F32,
            dims: out_dims,
        }))
    }

    pub fn sqrt(&self) -> Result<XlaOp> {
        let (ty, dims) = self.info();
        if ty != ElementType::F32 {
            return Err(Error::new("sqrt is f32-only"));
        }
        Ok(self.builder.push(Node {
            op: Op::Sqrt,
            inputs: vec![self.id],
            ty: ElementType::F32,
            dims,
        }))
    }

    /// Snapshot the graph with this op as root.
    pub fn build(&self) -> Result<XlaComputation> {
        let inner = self.builder.inner.borrow();
        Ok(XlaComputation {
            kind: CompKind::Graph {
                nodes: inner.nodes.clone(),
                root: self.id,
            },
        })
    }
}

macro_rules! impl_bin_op {
    ($trait:ident, $method:ident, $op:expr) => {
        impl std::ops::$trait<&XlaOp> for &XlaOp {
            type Output = Result<XlaOp>;
            fn $method(self, rhs: &XlaOp) -> Result<XlaOp> {
                self.binary($op, rhs)
            }
        }

        impl std::ops::$trait<XlaOp> for &XlaOp {
            type Output = Result<XlaOp>;
            fn $method(self, rhs: XlaOp) -> Result<XlaOp> {
                self.binary($op, &rhs)
            }
        }

        impl std::ops::$trait<&XlaOp> for XlaOp {
            type Output = Result<XlaOp>;
            fn $method(self, rhs: &XlaOp) -> Result<XlaOp> {
                self.binary($op, rhs)
            }
        }

        impl std::ops::$trait<XlaOp> for XlaOp {
            type Output = Result<XlaOp>;
            fn $method(self, rhs: XlaOp) -> Result<XlaOp> {
                self.binary($op, &rhs)
            }
        }
    };
}

impl_bin_op!(Add, add, Op::Add);
impl_bin_op!(Sub, sub, Op::Sub);
impl_bin_op!(Mul, mul, Op::Mul);
impl_bin_op!(Div, div, Op::Div);
